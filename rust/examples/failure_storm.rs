//! Failure storm: push each algorithm to its breaking point by killing
//! an increasing number of processes at the same step boundary, and
//! watch where the paper's 2^s − 1 bound bites.
//!
//! Prints, per (algorithm, step, f): survival measured on the full
//! simulator, against the bound.  All cells run through ONE engine
//! session (`analysis::FullSimSweep` → `engine.campaign`), so the
//! worker pool is reused across every run of the storm.
//!
//! ```bash
//! cargo run --release --example failure_storm
//! ```

use ft_tsqr::analysis::{FullSimSweep, max_tolerated_by_step};
use ft_tsqr::engine::Engine;
use ft_tsqr::report::Table;
use ft_tsqr::tsqr::{Algo, TreePlan};

fn main() {
    let procs = 16;
    let rounds = TreePlan::new(procs).rounds();
    // Full-simulator runs per cell (set STORM_SAMPLES to override).
    let samples: u64 =
        std::env::var("STORM_SAMPLES").ok().and_then(|v| v.parse().ok()).unwrap_or(12);

    let engine = Engine::builder().build().expect("engine");
    println!("Failure storm on P={procs}: f simultaneous failures at round s\n");

    for algo in [Algo::Redundant, Algo::Replace, Algo::SelfHealing] {
        let sweep = FullSimSweep::new(&engine, algo, procs)
            .with_samples(samples)
            .with_concurrency(4);
        let mut table = Table::new(
            format!("{} — fraction of {samples} runs surviving", algo.name()),
            &["round s", "bound 2^s-1", "f=1", "f=2", "f=4", "f=8"],
        );
        for s in 1..rounds {
            let mut row = vec![s.to_string(), max_tolerated_by_step(s).to_string()];
            for f in [1usize, 2, 4, 8] {
                let est = sweep.at_round(s, f).expect("sweep cell");
                let frac = est.probability();
                let mark = if f as u64 <= max_tolerated_by_step(s) { "*" } else { " " };
                row.push(format!("{frac:.2}{mark}"));
            }
            table.row(row);
        }
        print!("{}", table.render());
        println!("  (* = within the paper's bound)\n");
    }

    let stats = engine.stats();
    println!(
        "engine: {} runs through {} pooled workers (peak {})\n",
        stats.jobs_completed, stats.workers, stats.peak_workers
    );

    println!("Reading: replace/self-healing hold 1.00 everywhere the bound promises (cells");
    println!("marked *), and degrade gracefully past it; redundant's give-up cascade loses");
    println!("runs even inside the bound at later rounds — exactly the gap between data");
    println!("redundancy (§III-B3) and execution semantics the benches quantify.");
}
