//! Quickstart: build one engine session, factor a tall-and-skinny
//! matrix with Redundant TSQR on 8 simulated processes, survive a
//! mid-computation failure, and verify the result.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! The engine picks the AOT/PJRT backend automatically when `make
//! artifacts` has run (and the crate is built with `--features pjrt`),
//! and the pure-rust host backend otherwise.

use ft_tsqr::engine::Engine;
use ft_tsqr::fault::KillSchedule;
use ft_tsqr::tsqr::{Algo, RunSpec, TreePlan};

fn main() {
    // A 2048x16 tall-skinny matrix, split across 8 simulated MPI ranks.
    let (procs, rows_per_proc, cols) = (8usize, 256usize, 16usize);

    // One engine per session: owns the backend and the worker pool.
    let engine = Engine::builder().artifact_dir("artifacts").build().expect("engine");

    // Kill rank 5 at the end of step 1 — one failure, well within the
    // 2^1 - 1 = 1 bound the paper proves for that step.
    let spec = RunSpec::new(Algo::Redundant, procs, rows_per_proc, cols)
        .with_schedule(KillSchedule::at(&[(5, 1)]))
        .with_trace(true);

    println!(
        "Redundant TSQR: {}x{cols} matrix on {procs} processes, rank 5 dies at step 1\n",
        procs * rows_per_proc
    );

    let result = engine.submit(spec).wait().expect("run failed");

    print!("{}", result.trace.render(procs, TreePlan::new(procs).rounds()));
    println!();
    println!("success          : {}", result.success());
    println!("R holders        : {:?}", result.r_holders);
    println!("messages / bytes : {} / {}", result.metrics.messages, result.metrics.bytes);
    let v = result.verification.as_ref().expect("verification enabled");
    println!("‖R−R*‖/‖R*‖      : {:.2e}   (upper-triangular: {})", v.rel_fro_err, v.upper_triangular);
    println!("replica agreement: max |Δ| = {:.1e}", result.holder_disagreement);

    assert!(result.success() && v.ok, "quickstart must demonstrate a verified survival");

    // The session is reusable: run a quick 50-seed campaign on the same
    // engine — the pooled workers are recycled run after run.
    let specs = (0..50u64).map(|seed| {
        RunSpec::new(Algo::Redundant, procs, 32, 8)
            .with_seed(seed)
            .with_schedule(KillSchedule::at(&[(5, 1)]))
            .with_verify(false)
    });
    let report = engine.campaign(specs).run().expect("campaign");
    println!("\n50-seed campaign on the same engine: {}", report.summary());
    let stats = engine.stats();
    println!(
        "engine: {} jobs on {} pooled workers (peak {})",
        stats.jobs_completed, stats.workers, stats.peak_workers
    );

    println!("\nOK — the failure was absorbed by redundant computation, no checkpoint needed.");
}
