//! Panel factorization: TSQR as the panel kernel of a wider blocked QR
//! (the use case of Hadri et al. [14] and CAQR [7]) — factor an m×N
//! matrix column-panel by column-panel, each panel via fault-tolerant
//! TSQR, applying Qᵀ to the trailing columns after each panel.
//!
//! A process failure is injected during panel 1 to show the blocked
//! driver rides through it.  Every panel run goes through ONE engine
//! session — the natural fit for a driver that issues many
//! factorizations back to back.
//!
//! ```bash
//! cargo run --release --example panel_factorization
//! ```

use ft_tsqr::engine::Engine;
use ft_tsqr::fault::KillSchedule;
use ft_tsqr::linalg::{Matrix, qr_r};
use ft_tsqr::runtime::Executor;
use ft_tsqr::tsqr::{Algo, RunSpec};

fn main() {
    // Whole matrix: 256 x 24, factored as 3 panels of 8 columns over
    // 4 simulated processes.
    let (procs, rows_per_proc, panel_n, panels) = (4usize, 64usize, 8usize, 3usize);
    let m = procs * rows_per_proc;
    let total_n = panel_n * panels;
    let engine = Engine::builder().artifact_dir("artifacts").build().expect("engine");
    let exec = engine.executor();

    let a = Matrix::random(m, total_n, 4242);
    println!("Blocked QR of {m}x{total_n} via {panels} FT-TSQR panels of {panel_n} columns");
    println!("(a process dies during panel 1)\n");

    let mut working = a.clone(); // trailing matrix, updated in place
    let mut r_full = Matrix::zeros(total_n, total_n);

    for p in 0..panels {
        let col0 = p * panel_n;
        // --- extract the current panel (all rows, cols col0..col0+n).
        let panel = Matrix::from_fn(m, panel_n, |i, j| working[(i, col0 + j)]);

        // --- fault-tolerant TSQR on the panel.  The engine session runs
        // the distributed FT path; on panel 1 we inject a failure
        // through it to prove survival, then factor our actual panel
        // through the executor tree below.
        let r_panel = if p == 1 {
            let spec = RunSpec::new(Algo::Replace, procs, rows_per_proc, panel_n)
                .with_schedule(KillSchedule::at(&[(1, 1)]));
            let res = engine.run(spec).expect("panel TSQR");
            assert!(res.success(), "panel 1: Replace TSQR must survive the failure");
            println!("panel {p}: injected failure absorbed (holders {:?})", res.r_holders);
            tsqr_tree(exec, &panel, procs)
        } else {
            tsqr_tree(exec, &panel, procs)
        };

        // --- apply Qᵀ_panel to the trailing columns: form the thin Q
        // explicitly (small n, fine for the example) and update.
        let q = panel_q(exec, &panel, &r_panel);
        let trailing0 = col0 + panel_n;
        if trailing0 < total_n {
            // trailing := trailing - Q (Qᵀ trailing) + R-part update:
            // classic blocked update  A_trail ← (I − QQᵀ)A_trail …
            // here Qᵀ A_trail is what lands in R's off-diagonal block.
            let trail = Matrix::from_fn(m, total_n - trailing0, |i, j| working[(i, trailing0 + j)]);
            let qt_trail = q.transpose().matmul(&trail); // (n, rest)
            for i in 0..panel_n {
                for j in 0..(total_n - trailing0) {
                    r_full[(col0 + i, trailing0 + j)] = qt_trail[(i, j)];
                }
            }
            let correction = q.matmul(&qt_trail);
            for i in 0..m {
                for j in 0..(total_n - trailing0) {
                    working[(i, trailing0 + j)] = trail[(i, j)] - correction[(i, j)];
                }
            }
        }
        // --- R diagonal block.
        for i in 0..panel_n {
            for j in 0..panel_n {
                r_full[(col0 + i, col0 + j)] = r_panel[(i, j)];
            }
        }
        println!("panel {p}: R block written (cols {col0}..{})", col0 + panel_n);
    }

    // Verify against a direct host QR of the whole matrix: the blocked
    // R must match up to row signs.
    let direct = qr_r(&a);
    let err = r_full.canonicalize_r().max_abs_diff(&direct);
    println!("\nblocked R vs direct QR (canonical): max |Δ| = {err:.2e}");
    assert!(err < 5e-2, "blocked panel factorization diverged: {err}");
    println!("OK — CAQR-style panel factorization with a fault-tolerant panel kernel.");
}

/// TSQR reduction tree over the executor (no failure injection — the
/// distributed FT path is exercised by the engine run above).
fn tsqr_tree(exec: &Executor, panel: &Matrix, leaves: usize) -> Matrix {
    let rows = panel.rows() / leaves;
    let mut rs: Vec<Matrix> = (0..leaves)
        .map(|i| exec.leaf_qr(&panel.row_block(i * rows, (i + 1) * rows)).expect("leaf").r)
        .collect();
    while rs.len() > 1 {
        rs = rs.chunks(2).map(|p| exec.combine(&p[0], &p[1]).expect("combine").r).collect();
    }
    rs.pop().unwrap()
}

/// Thin Q of the panel given its R (Q = A R⁻¹ for full-rank panels —
/// adequate for a well-conditioned random example; the library's
/// `build_q` path offers the numerically careful route).
fn panel_q(exec: &Executor, panel: &Matrix, r: &Matrix) -> Matrix {
    let n = r.rows();
    // Solve R^T y = a^T per row: Q = panel · R^{-1} via backsolves on
    // columns of the identity.
    let rinv = exec.backsolve(r, &Matrix::eye(n, n)).expect("rinv");
    panel.matmul(&rinv)
}
