//! Reliability study: how long-running jobs survive under a realistic
//! per-process failure model (exponential lifetimes, Reed et al. [18]),
//! comparing all four algorithms plus the checkpointing comparator.
//!
//! Two engines, cross-checked:
//!  * the *analytic* simulator (millions of patterns/s) sweeps failure
//!    rates and prints survival curves;
//!  * the *full* simulator replays a sample of the same failure model
//!    through one engine campaign (`analysis::FullSimSweep`) to
//!    confirm the analytic numbers on the real implementation.
//!
//! ```bash
//! cargo run --release --example reliability_study
//! ```

use ft_tsqr::analysis::{FullSimSweep, SurvivalSweep};
use ft_tsqr::engine::Engine;
use ft_tsqr::report::{Table, fmt_prob};
use ft_tsqr::tsqr::Algo;

fn main() {
    let procs = 32;
    let trials = 4000u64;
    let rates = [0.001f64, 0.005, 0.01, 0.05, 0.1, 0.2];

    println!("Survival vs per-process failure rate (P={procs}, exp lifetimes, {trials} trials)\n");

    let mut table = Table::new(
        format!("P(job completes) — {procs} processes, exponential MTBF"),
        &["rate (deaths/step)", "baseline", "checkpointed", "redundant", "replace", "self-healing"],
    );
    for &rate in &rates {
        let mut row = vec![format!("{rate}")];
        for algo in [
            Algo::Baseline,
            Algo::Checkpointed,
            Algo::Redundant,
            Algo::Replace,
            Algo::SelfHealing,
        ] {
            let est = SurvivalSweep::new(algo, procs).with_trials(trials).exponential(rate);
            row.push(fmt_prob(est.probability(), est.ci95()));
        }
        table.row(row);
    }
    print!("{}", table.render());

    // Cross-check one cell on the full simulator, batched through one
    // engine session (rate = 0.05, 40 runs per algorithm).
    let engine = Engine::host();
    println!("\nCross-check on the full simulator (rate=0.05, 40 runs each):");
    for algo in [Algo::Baseline, Algo::Replace, Algo::SelfHealing] {
        let full = FullSimSweep::new(&engine, algo, procs)
            .with_shape(16, 8)
            .with_samples(40)
            .with_concurrency(4)
            .exponential(0.05)
            .expect("full-sim sweep");
        let analytic =
            SurvivalSweep::new(algo, procs).with_trials(trials).exponential(0.05).probability();
        println!(
            "  {:13} full-sim {:>2}/{} = {:.2}   analytic {:.2}",
            algo.name(),
            full.successes,
            full.trials,
            full.probability(),
            analytic
        );
    }
    let stats = engine.stats();
    println!(
        "  (one engine session: {} runs, {} pooled workers)",
        stats.jobs_completed, stats.workers
    );
    println!("\nReading: the redundant family turns a job that dies with near-certainty at");
    println!("realistic rates into one that survives — with zero additional messages (the");
    println!("exchange replaces the one-way send) while checkpointing pays extra traffic.");
}
