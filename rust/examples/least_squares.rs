//! Fault-tolerant linear least squares — the workload TSQR panels come
//! from in practice: solve min‖Ax − b‖ for a tall A via the R factor
//! computed by *Replace TSQR* while a process dies mid-run.
//!
//! Pipeline (all through the session engine; the solve path runs the
//! AOT `apply_qt` + `backsolve` kernels when artifacts are present):
//!   1. distributed fault-tolerant TSQR → R (survives the failure)
//!   2. Qᵀb reduction along the same tree shape
//!   3. back-substitution R x = (Qᵀ b)[:n]
//!
//! ```bash
//! cargo run --release --example least_squares
//! ```

use ft_tsqr::engine::Engine;
use ft_tsqr::fault::KillSchedule;
use ft_tsqr::linalg::Matrix;
use ft_tsqr::tsqr::{Algo, RunSpec};

fn main() {
    let (procs, rows_per_proc, n) = (4usize, 64usize, 8usize);
    let m = procs * rows_per_proc;
    let engine = Engine::builder().artifact_dir("artifacts").build().expect("engine");
    let exec = engine.executor();

    // Ground truth: b = A x*.
    let spec = RunSpec::new(Algo::Replace, procs, rows_per_proc, n)
        .with_schedule(KillSchedule::at(&[(2, 1)])); // P2 dies at step 1
    let a = spec.input_matrix();
    let x_true = Matrix::random(n, 1, 999);
    let b = a.matmul(&x_true);

    println!("Least squares via Replace TSQR: A is {m}x{n}, P2 dies at step 1\n");

    // 1. Fault-tolerant factorization: proves R survives the failure.
    let result = engine.run(spec).expect("TSQR failed");
    assert!(result.success(), "Replace TSQR must survive one step-1 failure");
    let r_ft = result.final_r.clone().expect("R available");
    println!(
        "FT-TSQR done: success={} holders={:?} (rank 2 died, replica served P0)",
        result.success(),
        result.r_holders
    );

    // 2. Qᵀb along the same reduction tree, reusing the exact kernels:
    // each node keeps (R, top-n rows of Qᵀ·rhs).
    let mut nodes: Vec<(Matrix, Matrix)> = (0..procs)
        .map(|rank| {
            let panel = a.row_block(rank * rows_per_proc, (rank + 1) * rows_per_proc);
            let rhs = b.row_block(rank * rows_per_proc, (rank + 1) * rows_per_proc);
            let f = exec.leaf_qr(&panel).expect("leaf");
            let qtb = exec.apply_qt(&f, &rhs).expect("apply_qt");
            (f.r, qtb.row_block(0, n))
        })
        .collect();
    while nodes.len() > 1 {
        nodes = nodes
            .chunks(2)
            .map(|pair| {
                let f = exec.combine(&pair[0].0, &pair[1].0).expect("combine");
                let stacked = pair[0].1.vstack(&pair[1].1);
                let qtc = exec.apply_qt(&f, &stacked).expect("apply_qt tree");
                (f.r, qtc.row_block(0, n))
            })
            .collect();
    }
    let (r_tree, qtb_top) = nodes.pop().unwrap();

    // Consistency: the fault-tolerant R equals the tree R up to row
    // signs (QR uniqueness) — the failure changed nothing numerically.
    let drift = r_ft.canonicalize_r().max_abs_diff(&r_tree.canonicalize_r());
    println!("FT R vs tree R (canonical): max |Δ| = {drift:.2e}");
    assert!(drift < 1e-3, "fault-tolerant R diverged from the clean tree R");

    // 3. Solve R x = (Qᵀb)[:n] with the sign-consistent (R, rhs) pair.
    let x = exec.backsolve(&r_tree, &qtb_top).expect("backsolve");

    let err = x.max_abs_diff(&x_true);
    println!("recovered x vs x*: max |Δ| = {err:.2e}");
    assert!(err < 5e-2, "least-squares solution too far off: {err}");
    println!("\nOK — least squares solved through a failure without restarting the job.");
}
