//! # ft-tsqr — Fault-Tolerant Communication-Avoiding TSQR
//!
//! Production-grade reproduction of *"Exploiting Redundant Computation
//! in Communication-Avoiding Algorithms for Algorithm-Based Fault
//! Tolerance"* (Camille Coti, 2015).
//!
//! The paper's observation: communication-avoiding algorithms (TSQR)
//! already perform redundant computation; letting the "idle half" of
//! the reduction tree keep computing turns that redundancy into
//! fault tolerance for free.  Three algorithms result — Redundant,
//! Replace and Self-Healing TSQR — all tolerating `2^s − 1` failures
//! by step `s`.
//!
//! ## Architecture (three layers, python never at runtime)
//!
//! * **L1 (Pallas)** `python/compile/kernels/` — Householder QR leaf +
//!   structure-aware TSQR combine kernels.
//! * **L2 (JAX)** `python/compile/model.py` — jitted graphs, AOT-lowered
//!   to HLO text (`make artifacts`).
//! * **L3 (this crate)** — the simulated ULFM world, the four TSQR
//!   algorithms, fault injection, robustness analysis, benches and CLI;
//!   kernels execute through one zero-copy call convention
//!   (`KernelCall { op, views, workspace }`, see [`runtime::Kernel`])
//!   dispatched to PJRT or to the blocked pure-rust view kernels in
//!   [`linalg::view`].  Matrix state crosses the simulated network as
//!   shared `Arc<Matrix>` handles, and kernel scratch comes from
//!   pooled, reusable [`linalg::Workspace`] arenas — steady-state
//!   campaign runs do not touch the allocator in the kernel path.
//!   The compute-heavy CAQR paths additionally offer a deterministic
//!   fast-kernel layer ([`runtime::KernelProfile::Blocked`]):
//!   compact-WY trailing updates ([`linalg::wy`]) over a packed,
//!   fixed-summation-order f64 GEMM microkernel ([`linalg::gemm`]),
//!   with lookahead pipelining in the CAQR scheduler.
//!
//! ## Quick start
//!
//! The execution API is session-oriented: build a long-lived
//! [`engine::Engine`] once (backend selection + reusable worker pool),
//! then submit as many runs as you like — setup cost is paid per
//! session, not per factorization.
//!
//! ```no_run
//! use ft_tsqr::engine::Engine;
//! use ft_tsqr::fault::KillSchedule;
//! use ft_tsqr::tsqr::{Algo, RunSpec};
//!
//! // One engine per session: picks PJRT when `make artifacts` has
//! // run (and the `pjrt` feature is on), pure-rust host otherwise.
//! let engine = Engine::builder().artifact_dir("artifacts").build().unwrap();
//!
//! // Redundant TSQR on 8 simulated processes, one failure at step 1.
//! let spec = RunSpec::new(Algo::Redundant, 8, 128, 8)
//!     .with_schedule(KillSchedule::at(&[(5, 1)]));
//! let result = engine.submit(spec).wait().unwrap();
//! assert!(result.success());
//!
//! // Batched sweeps amortize setup across thousands of runs and
//! // aggregate survival statistics.
//! let specs = (0..1000).map(|seed| {
//!     RunSpec::new(Algo::Replace, 8, 128, 8).with_seed(seed).with_verify(false)
//! });
//! let report = engine.campaign(specs).concurrency(4).run().unwrap();
//! println!("{}", report.summary());
//! assert_eq!(report.successes(), 1000);
//! ```
//!
//! The pre-engine one-shot entry point survives as a shim:
//! `ft_tsqr::tsqr::run(&spec)` builds a single-use engine around the
//! spec's executor — identical semantics, none of the amortization.
//!
//! ## General matrices: CAQR
//!
//! Tall-and-skinny is TSQR's home turf; for general `m x n` matrices
//! the [`caqr`] subsystem factors by block column and replicates the
//! trailing-matrix updates — the extension of the follow-up paper
//! (arXiv:1604.02504) — so a process death *mid-update* is recovered
//! from a surviving replica, bit for bit:
//!
//! ```
//! use ft_tsqr::caqr::CaqrSpec;
//! use ft_tsqr::engine::Engine;
//! use ft_tsqr::tsqr::Algo;
//!
//! let engine = Engine::host();
//! let res = engine.run_caqr(CaqrSpec::new(Algo::SelfHealing, 4, 32, 16, 8)).unwrap();
//! assert!(res.success() && res.verification.unwrap().ok);
//! ```
//!
//! ## Beyond replication: the checksum ABFT layer
//!
//! Replication tolerates one loss per replica pair; the [`abft`]
//! subsystem survives the *pair wipe* — both copies of a task gone in
//! one stage — by encoding `c` Vandermonde checksum blocks per panel
//! stage and reconstructing lost results algebraically (the
//! `Replica → Checksum → Abort` recovery ladder,
//! [`abft::RecoveryPolicy`]):
//!
//! ```
//! use ft_tsqr::abft::RecoveryPolicy;
//! use ft_tsqr::caqr::CaqrSpec;
//! use ft_tsqr::engine::Engine;
//! use ft_tsqr::fault::{CaqrStage, PairWipeSchedule};
//! use ft_tsqr::tsqr::Algo;
//!
//! let engine = Engine::builder().host_only()
//!     .recovery_policy(RecoveryPolicy::Hybrid).build().unwrap();
//! let spec = CaqrSpec::new(Algo::SelfHealing, 4, 24, 12, 4)
//!     .with_checksums(1)
//!     .with_schedule(PairWipeSchedule::new(2, 0, CaqrStage::Update).schedule());
//! let res = engine.run_caqr(spec).unwrap();
//! assert!(res.success(), "fatal under replication alone");
//! assert_eq!(res.metrics.pair_wipes_survived, 1);
//! ```
//!
//! ## Many tenants, one engine: the service layer
//!
//! A single engine serves one caller; the [`service`] subsystem turns
//! it into a multi-tenant front door — bounded admission queues,
//! deficit-round-robin fair scheduling across tenants (configurable
//! weights, no starvation), load-shedding with typed
//! [`Error::Submission`] rejections under overload, zero-copy
//! submission of shared inputs, and streaming per-tenant metrics
//! (survival, queue-wait/service-time histograms, shed counts):
//!
//! ```
//! use ft_tsqr::engine::Engine;
//! use ft_tsqr::service::{Job, ServiceBuilder};
//! use ft_tsqr::tsqr::{Algo, RunSpec};
//!
//! let service = ServiceBuilder::new().queue_depth(64).build(Engine::host());
//! let alice = service.register_tenant("alice", 3).unwrap();
//! let ticket = service.submit(alice, Job::Tsqr(RunSpec::new(Algo::Redundant, 4, 16, 4)));
//! assert!(ticket.unwrap().wait().unwrap().success());
//! ```
//!
//! ## Mega-scale campaigns: the discrete-event simulator
//!
//! The thread-based executor tops out at tens of ranks; the [`sim`]
//! subsystem replays the same panel walk and recovery ladder as
//! events on a virtual clock — matrix-free, thread-free — so survival
//! campaigns run at P = 10⁵–10⁶ ranks with Poisson churn, rack-wipe
//! bursts, and network models, in seconds
//! (`repro simulate --scenario rust/scenarios/mega_1e5.toml`).  At
//! small P the simulator reproduces the executor's survival/abort
//! outcomes *exactly* (pinned in `tests/integration_sim.rs`), which is
//! what licenses the extrapolation:
//!
//! ```
//! use ft_tsqr::sim::{SimScenario, run_scenario};
//!
//! let sc = SimScenario { procs: 100_000, ..Default::default() };
//! let report = run_scenario(&sc).unwrap();
//! assert!(report.success() && report.virtual_ns > 0);
//! ```
//!
//! See `docs/ARCHITECTURE.md` for the layer-by-layer walkthrough of
//! the whole stack, `docs/TUTORIAL.md` (mirrored as the runnable
//! [`tutorial`] module) for the end-to-end guided tour, and
//! `docs/PAPER_MAP.md` for the section-by-section map from the papers
//! to the types and functions implementing them.

#![warn(missing_docs)]

pub mod abft;
pub mod analysis;
pub mod caqr;
pub mod checkpoint;
pub mod config;
pub mod engine;
pub mod error;
pub mod fault;
pub mod linalg;
pub mod metrics;
pub mod report;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod tsqr;
pub mod ulfm;
pub mod util;

#[doc = include_str!("../../docs/TUTORIAL.md")]
pub mod tutorial {}

pub use error::{Error, Result};
