//! Checksum-coded algorithm-based fault tolerance: surviving the
//! failures that replication alone cannot.
//!
//! The source paper's replica pairs tolerate one loss per pair per
//! panel step; when **both** members of a pair die in the same step (a
//! *pair wipe*), the data they held has no surviving copy and the
//! replication-only engine must abort.  Classic checksum ABFT
//! (Bosilca et al., arXiv:0806.3121) and coded-computing QR (Nguyen et
//! al., arXiv:2311.11943) recover such losses *algebraically*: encode
//! `c` weighted checksum blocks alongside the data, and any `≤ c` lost
//! blocks are reconstructible from the survivors — no re-execution
//! from scratch, no checkpoint.
//!
//! This module provides the two ingredients, policy and arithmetic:
//!
//! * [`RecoveryPolicy`] — the recovery **ladder** a CAQR run walks
//!   when a task result is needed: surviving replica first, checksum
//!   reconstruction second, abort last.
//! * [`Encoder`] — deterministic Vandermonde checksum encoding and the
//!   reconstruction solve, for both shapes CAQR protects (trailing
//!   column blocks and panel row shards).
//!
//! The f32 view-kernel siblings in [`kernels`] back the runtime's
//! `KernelOp::EncodeChecksum` / `KernelOp::ReconstructBlock` dispatch;
//! `crate::caqr` threads the ladder through its pre-simulated
//! [`Timeline`] so reconstruction decisions are deterministic.
//!
//! ## What a pair wipe loses, and what rebuilds it
//!
//! * **Update stage** — both copies of a trailing-update task's output
//!   are gone.  The update `B ↦ Q₁ᵀB` is linear, so `c` *checksum
//!   update tasks* (the same kernel applied to
//!   `S_l = Σ_j w(l,j)·B_j`) ran alongside the data tasks, and the
//!   lost outputs are solved back out of the surviving outputs — the
//!   Bosilca-style output reconstruction.
//! * **Factor stage** — both copies of the panel-factor result are
//!   gone, *and* QR is nonlinear, so the result cannot be solved back.
//!   Instead the **input** panel is rebuilt from its row-shard
//!   checksums (each replica pair holds one contiguous row shard plus
//!   rotated checksum shards) and the factor re-executes on the
//!   lowest-ranked survivor — reconstruct-then-recompute.
//!
//! Both paths round-trip the data through one encode + one solve, so a
//! survived pair wipe perturbs the result by at most `c·n·ε·‖A‖`
//! (pinned in `tests/integration_abft.rs`); with **zero** failures the
//! checksum tasks are pure bystanders and the factorization reproduces
//! the un-checksummed run bit for bit.
//!
//! [`Timeline`]: crate::caqr
//!
//! ## Quick start
//!
//! ```
//! use ft_tsqr::abft::RecoveryPolicy;
//! use ft_tsqr::caqr::{self, CaqrSpec};
//! use ft_tsqr::fault::{CaqrStage, PairWipeSchedule};
//! use ft_tsqr::tsqr::Algo;
//!
//! // Kill BOTH replicas of rank 1's pair during panel 0's updates —
//! // fatal under replication alone, survived with one checksum.
//! // (Self-Healing respawns the pair at the panel boundary; under
//! // Redundant the dead stay dead and every later panel pays the
//! // checksum rung again.)
//! let wipe = PairWipeSchedule::new(1, 0, CaqrStage::Update);
//! let spec = CaqrSpec::new(Algo::SelfHealing, 4, 24, 12, 4)
//!     .with_schedule(wipe.schedule())
//!     .with_policy(RecoveryPolicy::Hybrid)
//!     .with_checksums(1);
//! let res = caqr::factorize(spec).unwrap();
//! assert!(res.success());
//! assert_eq!(res.metrics.pair_wipes_survived, 1);
//! assert!(res.metrics.checksum_reconstructions >= 1);
//! ```

pub mod encoder;
pub mod kernels;

pub use encoder::Encoder;

use crate::error::{Error, Result};

/// The recovery ladder a CAQR run walks when a task's result must be
/// harvested: **surviving replica → checksum reconstruction → abort**.
///
/// The variants select which rungs exist:
///
/// | Policy | Task replication | Checksum tasks | Survives per stage |
/// |---|---|---|---|
/// | [`Replica`](Self::Replica) | owner + buddy | none | 1 loss per pair (the papers' scheme) |
/// | [`Checksum`](Self::Checksum) | owner only | `c` | up to `c` lost tasks |
/// | [`Hybrid`](Self::Hybrid) | owner + buddy | `c` | 1 loss per pair **and** up to `c` pair wipes |
///
/// `Replica` is the default and reproduces PR 1–4 behaviour exactly.
/// `Checksum` trades the 2× replicated flops for the much cheaper
/// `c`-checksum redundancy (the coded-computing end of the spectrum);
/// `Hybrid` pays both and survives everything either rung covers.
/// With zero failures all three produce bit-identical factorizations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum RecoveryPolicy {
    /// Replication only: a task that loses every replica aborts the
    /// run (the source papers' semantics).
    #[default]
    Replica,
    /// Checksums only: tasks run un-replicated; up to `c` lost task
    /// results per stage are reconstructed algebraically.
    Checksum,
    /// Replication first, checksums when a whole pair is wiped — the
    /// full ladder.
    Hybrid,
}

impl RecoveryPolicy {
    /// Stable name (`replica` / `checksum` / `hybrid`).
    pub fn name(&self) -> &'static str {
        match self {
            RecoveryPolicy::Replica => "replica",
            RecoveryPolicy::Checksum => "checksum",
            RecoveryPolicy::Hybrid => "hybrid",
        }
    }

    /// Does this policy run every task on a replica pair?
    pub fn replicates(&self) -> bool {
        !matches!(self, RecoveryPolicy::Checksum)
    }

    /// Does this policy encode (and reconstruct from) checksums?
    pub fn uses_checksums(&self) -> bool {
        !matches!(self, RecoveryPolicy::Replica)
    }
}

impl std::fmt::Display for RecoveryPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for RecoveryPolicy {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "replica" | "replication" => Ok(RecoveryPolicy::Replica),
            "checksum" | "coded" => Ok(RecoveryPolicy::Checksum),
            "hybrid" => Ok(RecoveryPolicy::Hybrid),
            other => Err(Error::Config(format!(
                "unknown recovery policy '{other}' (replica|checksum|hybrid)"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parses_and_prints() {
        assert_eq!("replica".parse::<RecoveryPolicy>().unwrap(), RecoveryPolicy::Replica);
        assert_eq!("checksum".parse::<RecoveryPolicy>().unwrap(), RecoveryPolicy::Checksum);
        assert_eq!("coded".parse::<RecoveryPolicy>().unwrap(), RecoveryPolicy::Checksum);
        assert_eq!("hybrid".parse::<RecoveryPolicy>().unwrap(), RecoveryPolicy::Hybrid);
        assert!("raid".parse::<RecoveryPolicy>().is_err());
        assert_eq!(RecoveryPolicy::default(), RecoveryPolicy::Replica);
        assert_eq!(RecoveryPolicy::Hybrid.to_string(), "hybrid");
    }

    #[test]
    fn ladder_rungs_per_policy() {
        assert!(RecoveryPolicy::Replica.replicates());
        assert!(!RecoveryPolicy::Replica.uses_checksums());
        assert!(!RecoveryPolicy::Checksum.replicates());
        assert!(RecoveryPolicy::Checksum.uses_checksums());
        assert!(RecoveryPolicy::Hybrid.replicates());
        assert!(RecoveryPolicy::Hybrid.uses_checksums());
    }
}
