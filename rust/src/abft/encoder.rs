//! The checksum [`Encoder`]: Vandermonde-weighted row/column checksum
//! blocks and their algebraic inverse, the block reconstruction solve.
//!
//! ## The encoding
//!
//! Given `N` data blocks `B_0 … B_{N−1}` (each row-major
//! `rows × widths[j]`, padded conceptually to `pad` columns with
//! zeros), the encoder produces `c` checksum blocks
//!
//! ```text
//! S_l = Σ_j  w(l, j) · B_j          w(l, j) = (l + 1)^j
//! ```
//!
//! entrywise in f64 with a fixed summation order (ascending `j`), so
//! encoding is deterministic bit for bit.  The weight family is a
//! Vandermonde system with distinct positive nodes `1, 2, …, c`: every
//! square submatrix formed by choosing `t` checksums and `t` lost
//! blocks is nonsingular, so **any `t ≤ c` lost blocks are recoverable
//! from any `t` surviving checksums** (the classic ABFT property of
//! Bosilca et al., arXiv:0806.3121).  Checksum `0` has all weights
//! `1` — a plain sum — so the common single-loss reconstruction is a
//! perfectly conditioned subtract-and-done.
//!
//! ## The two shapes the CAQR subsystem encodes
//!
//! * **Column blocks** (the trailing-update tasks): blocks share
//!   `rows`, widths may differ (the ragged last block).  Because the
//!   trailing update `B ↦ Q₁ᵀB` is *linear*, a checksum carried
//!   through the update kernel equals the checksum of the updated
//!   blocks (up to rounding): reconstruction recovers a lost task
//!   *output* without re-execution.
//! * **Row shards** (the panel-factor input): a `rows × cols` panel
//!   split into contiguous row ranges is encoded by treating each
//!   shard as a `1 × len` block — same code path, `rows = 1`.
//!
//! Reconstruction accuracy: one encode + one solve round-trip differs
//! from the original data by `O(c · N · ε)` relative to the block
//! norms — the `c · n · ε · ‖A‖` bound `tests/integration_abft.rs`
//! pins.

use crate::error::{Error, Result};

/// Deterministic Vandermonde checksum encoder over `c` checksum blocks.
///
/// See the [module docs](self) for the weight family and the recovery
/// guarantee.  The encoder is pure arithmetic — *which* simulated rank
/// holds which checksum, and when reconstruction is permitted, is the
/// recovery policy's business (`crate::caqr` / [`super::RecoveryPolicy`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Encoder {
    c: usize,
}

impl Encoder {
    /// An encoder producing `c` checksum blocks.
    pub fn new(c: usize) -> Self {
        Self { c }
    }

    /// Number of checksum blocks this encoder produces.
    pub fn checksums(&self) -> usize {
        self.c
    }

    /// The Vandermonde weight of data block `j` in checksum `l`:
    /// `(l + 1)^j`.  Exact in f64 for every shape this crate schedules
    /// (small `l`, block counts far below the 2^53 mantissa limit).
    pub fn weight(l: usize, j: usize) -> f64 {
        ((l + 1) as f64).powi(j as i32)
    }

    /// Encode `c` checksum blocks over `blocks` (row-major
    /// `rows × widths[j]` each), padded to `pad ≥ max(widths)` columns.
    ///
    /// Entry `(i, col)` of block `j` participates iff `col < widths[j]`
    /// — narrower blocks are implicitly zero-padded on the right.
    pub fn encode(
        &self,
        rows: usize,
        widths: &[usize],
        blocks: &[&[f64]],
        pad: usize,
    ) -> Vec<Vec<f64>> {
        assert_eq!(blocks.len(), widths.len(), "encode: one width per block");
        for (j, (b, &w)) in blocks.iter().zip(widths).enumerate() {
            assert_eq!(b.len(), rows * w, "encode: block {j} length != rows*width");
            assert!(w <= pad, "encode: block {j} wider than pad");
        }
        let mut out = Vec::with_capacity(self.c);
        for l in 0..self.c {
            let mut s = vec![0.0f64; rows * pad];
            for (j, (b, &w)) in blocks.iter().zip(widths).enumerate() {
                let wt = Self::weight(l, j);
                for i in 0..rows {
                    for col in 0..w {
                        s[i * pad + col] += wt * b[i * w + col];
                    }
                }
            }
            out.push(s);
        }
        out
    }

    /// Reconstruct every lost block (`blocks[j] == None`) from the
    /// surviving blocks and the available checksum outputs
    /// `checks = [(l, S_l), …]`.
    ///
    /// Per padded column the solve uses the first `t` available
    /// checksums, where `t` is the number of lost blocks wide enough to
    /// reach that column — the `t × t` Vandermonde submatrix is
    /// LU-factored once per column and back-substituted per row
    /// (deterministic: fixed pivot order, fixed summation order).
    ///
    /// Returns `(j, reconstructed rows × widths[j] block)` pairs in
    /// ascending `j`.  Errors if more blocks were lost than checksums
    /// are available.
    pub fn reconstruct(
        &self,
        rows: usize,
        widths: &[usize],
        blocks: &[Option<&[f64]>],
        checks: &[(usize, &[f64])],
        pad: usize,
    ) -> Result<Vec<(usize, Vec<f64>)>> {
        assert_eq!(blocks.len(), widths.len(), "reconstruct: one width per block");
        let lost: Vec<usize> =
            blocks.iter().enumerate().filter(|(_, b)| b.is_none()).map(|(j, _)| j).collect();
        if lost.is_empty() {
            return Ok(Vec::new());
        }
        if checks.len() < lost.len() {
            return Err(Error::Other(format!(
                "checksum reconstruction infeasible: {} blocks lost, {} checksums available",
                lost.len(),
                checks.len()
            )));
        }
        for (l, s) in checks {
            assert_eq!(s.len(), rows * pad, "reconstruct: checksum {l} length != rows*pad");
        }
        let mut out: Vec<(usize, Vec<f64>)> =
            lost.iter().map(|&j| (j, vec![0.0f64; rows * widths[j]])).collect();
        let mut a = Vec::new();
        let mut b = Vec::new();
        let mut perm = Vec::new();
        for col in 0..pad {
            // Lost blocks wide enough to reach this column.
            let live_lost: Vec<usize> =
                lost.iter().copied().filter(|&j| widths[j] > col).collect();
            let t = live_lost.len();
            if t == 0 {
                continue;
            }
            // LU-factor the t×t weight submatrix once for the column.
            a.clear();
            for &(l, _) in checks.iter().take(t) {
                for &j in &live_lost {
                    a.push(Self::weight(l, j));
                }
            }
            lu_factor(&mut a, t, &mut perm)?;
            for i in 0..rows {
                b.clear();
                for &(l, s) in checks.iter().take(t) {
                    let mut rhs = s[i * pad + col];
                    for (j, blk) in blocks.iter().enumerate() {
                        if let Some(blk) = blk {
                            if widths[j] > col {
                                rhs -= Self::weight(l, j) * blk[i * widths[j] + col];
                            }
                        }
                    }
                    b.push(rhs);
                }
                lu_solve(&a, t, &perm, &mut b);
                for (q, &j) in live_lost.iter().enumerate() {
                    let slot = out.iter_mut().find(|(oj, _)| *oj == j).expect("lost entry");
                    slot.1[i * widths[j] + col] = b[q];
                }
            }
        }
        Ok(out)
    }

    /// Split `rows` into `parts` contiguous row ranges (ceil-balanced),
    /// the sharding the panel-factor reconstruction path uses.  Returns
    /// `(start, end)` pairs; trailing shards may be empty.
    pub fn shard_rows(rows: usize, parts: usize) -> Vec<(usize, usize)> {
        assert!(parts >= 1, "shard_rows: need at least one part");
        let chunk = rows.div_ceil(parts);
        (0..parts)
            .map(|i| {
                let s = (i * chunk).min(rows);
                let e = ((i + 1) * chunk).min(rows);
                (s, e)
            })
            .collect()
    }
}

/// In-place LU factorization with partial pivoting of a dense `n×n`
/// row-major matrix (deterministic: ties keep the earlier row).
fn lu_factor(a: &mut [f64], n: usize, perm: &mut Vec<usize>) -> Result<()> {
    debug_assert_eq!(a.len(), n * n);
    perm.clear();
    perm.extend(0..n);
    for k in 0..n {
        let mut p = k;
        let mut best = a[perm[k] * n + k].abs();
        for (idx, &r) in perm.iter().enumerate().skip(k + 1) {
            let v = a[r * n + k].abs();
            if v > best {
                best = v;
                p = idx;
            }
        }
        if best == 0.0 {
            return Err(Error::Other("checksum weight system is singular".into()));
        }
        perm.swap(k, p);
        let piv = a[perm[k] * n + k];
        for &r in perm.iter().skip(k + 1) {
            let f = a[r * n + k] / piv;
            a[r * n + k] = f;
            for j in k + 1..n {
                a[r * n + j] -= f * a[perm[k] * n + j];
            }
        }
    }
    Ok(())
}

/// Solve `A x = b` given [`lu_factor`]'s output; `b` becomes `x`
/// (entries in original, unpermuted unknown order).
fn lu_solve(a: &[f64], n: usize, perm: &[usize], b: &mut [f64]) {
    debug_assert_eq!(b.len(), n);
    // Forward substitution on the permuted rows.
    let mut y = vec![0.0f64; n];
    for k in 0..n {
        let mut v = b[perm[k]];
        for j in 0..k {
            v -= a[perm[k] * n + j] * y[j];
        }
        y[k] = v;
    }
    // Back substitution.
    for k in (0..n).rev() {
        let mut v = y[k];
        for j in k + 1..n {
            v -= a[perm[k] * n + j] * b[j];
        }
        b[k] = v / a[perm[k] * n + k];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block(rows: usize, w: usize, seed: u64) -> Vec<f64> {
        // Small integer-valued data: exact under the weight-1 checksum.
        (0..rows * w).map(|i| ((i as u64).wrapping_mul(seed + 3) % 17) as f64 - 8.0).collect()
    }

    #[test]
    fn weights_are_a_vandermonde_family() {
        assert_eq!(Encoder::weight(0, 0), 1.0);
        assert_eq!(Encoder::weight(0, 7), 1.0, "checksum 0 is the plain sum");
        assert_eq!(Encoder::weight(1, 3), 8.0);
        assert_eq!(Encoder::weight(2, 2), 9.0);
    }

    #[test]
    fn single_loss_roundtrip_is_exact_on_integer_data() {
        let enc = Encoder::new(1);
        let (rows, w) = (6, 4);
        let b: Vec<Vec<f64>> = (0..3).map(|j| block(rows, w, j)).collect();
        let refs: Vec<&[f64]> = b.iter().map(|x| x.as_slice()).collect();
        let checks = enc.encode(rows, &[w, w, w], &refs, w);
        assert_eq!(checks.len(), 1);
        for lost in 0..3 {
            let opts: Vec<Option<&[f64]>> =
                (0..3).map(|j| if j == lost { None } else { Some(refs[j]) }).collect();
            let got = enc
                .reconstruct(rows, &[w, w, w], &opts, &[(0, checks[0].as_slice())], w)
                .unwrap();
            assert_eq!(got.len(), 1);
            assert_eq!(got[0].0, lost);
            assert_eq!(got[0].1, b[lost], "integer data reconstructs exactly");
        }
    }

    #[test]
    fn double_loss_recovers_within_rounding_on_ragged_blocks() {
        let enc = Encoder::new(3);
        let rows = 5;
        let widths = [4usize, 4, 4, 2]; // ragged last block
        let b: Vec<Vec<f64>> = widths
            .iter()
            .enumerate()
            .map(|(j, &w)| (0..rows * w).map(|i| ((i + 7 * j) as f64).sin()).collect())
            .collect();
        let refs: Vec<&[f64]> = b.iter().map(|x| x.as_slice()).collect();
        let checks = enc.encode(rows, &widths, &refs, 4);
        // Lose blocks 1 and 3; use checksums 0 and 2 (any pair works).
        let opts: Vec<Option<&[f64]>> =
            (0..4).map(|j| if j == 1 || j == 3 { None } else { Some(refs[j]) }).collect();
        let got = enc
            .reconstruct(
                rows,
                &widths,
                &opts,
                &[(0, checks[0].as_slice()), (2, checks[2].as_slice())],
                4,
            )
            .unwrap();
        assert_eq!(got.len(), 2);
        for (j, data) in &got {
            assert_eq!(data.len(), rows * widths[*j]);
            for (x, y) in data.iter().zip(&b[*j]) {
                assert!((x - y).abs() < 1e-12, "block {j}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn row_shard_mode_reconstructs_a_panel_shard() {
        // Row shards are 1×len blocks: same code path, rows = 1.
        let enc = Encoder::new(1);
        let panel: Vec<f64> = (0..48).map(|i| (i as f64) * 0.25 - 3.0).collect();
        let shards = Encoder::shard_rows(12, 3); // 12 rows of width 4
        assert_eq!(shards, vec![(0, 4), (4, 8), (8, 12)]);
        let parts: Vec<&[f64]> = shards.iter().map(|&(s, e)| &panel[s * 4..e * 4]).collect();
        let lens: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        let pad = *lens.iter().max().unwrap();
        let checks = enc.encode(1, &lens, &parts, pad);
        let opts = [Some(parts[0]), None, Some(parts[2])];
        let got =
            enc.reconstruct(1, &lens, &opts, &[(0, checks[0].as_slice())], pad).unwrap();
        assert_eq!(got[0].0, 1);
        for (x, y) in got[0].1.iter().zip(parts[1]) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn reconstruction_is_deterministic() {
        let enc = Encoder::new(2);
        let rows = 4;
        let b: Vec<Vec<f64>> = (0..3).map(|j| block(rows, 3, 100 + j)).collect();
        let refs: Vec<&[f64]> = b.iter().map(|x| x.as_slice()).collect();
        let checks = enc.encode(rows, &[3, 3, 3], &refs, 3);
        let run = || {
            let opts = [None, Some(refs[1]), None];
            enc.reconstruct(
                rows,
                &[3, 3, 3],
                &opts,
                &[(0, checks[0].as_slice()), (1, checks[1].as_slice())],
                3,
            )
            .unwrap()
        };
        let (a, b2) = (run(), run());
        for ((ja, da), (jb, db)) in a.iter().zip(&b2) {
            assert_eq!(ja, jb);
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(da), bits(db), "reconstruction must be bit-deterministic");
        }
    }

    #[test]
    fn too_many_losses_error_cleanly() {
        let enc = Encoder::new(1);
        let b = block(2, 2, 1);
        let checks = enc.encode(2, &[2, 2], &[&b, &b], 2);
        let opts: [Option<&[f64]>; 2] = [None, None];
        assert!(
            enc.reconstruct(2, &[2, 2], &opts, &[(0, checks[0].as_slice())], 2).is_err(),
            "2 losses with 1 checksum must be infeasible"
        );
        // Zero losses is a no-op.
        let opts = [Some(b.as_slice()), Some(b.as_slice())];
        assert!(enc.reconstruct(2, &[2, 2], &opts, &[], 2).unwrap().is_empty());
    }
}
