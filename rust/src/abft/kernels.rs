//! f32 view kernels for the runtime's checksum ops
//! (`KernelOp::EncodeChecksum` / `KernelOp::ReconstructBlock`):
//! single-precision siblings of the f64 [`Encoder`](super::Encoder)
//! paths, shaped like every other view kernel — borrowed inputs, f64
//! accumulation in pooled [`Workspace`] scratch, one terminal rounding.

use crate::linalg::{MatrixView, MatrixViewMut, Workspace};

/// Encode ONE weighted checksum block: `out = Σ_j weights[j] · blocks[j]`.
///
/// `weights` is a `1 × N` row vector; the `N` blocks share their row
/// count and may be narrower than `out` (implicit zero padding on the
/// right, the ragged-last-block convention).  Accumulation is f64 in
/// workspace scratch with a fixed order (ascending `j`), rounded to
/// f32 once.
pub fn encode_checksum_into(
    weights: MatrixView<'_>,
    blocks: &[MatrixView<'_>],
    out: &mut MatrixViewMut<'_>,
    ws: &mut Workspace,
) {
    let n = blocks.len();
    assert!(n >= 1, "encode_checksum_into: need at least one block");
    assert_eq!(weights.shape(), (1, n), "encode_checksum_into: weights must be 1x{n}");
    let (rows, pad) = out.shape();
    let acc = ws.f64_scratch(rows * pad);
    acc.fill(0.0);
    for (j, b) in blocks.iter().enumerate() {
        assert_eq!(b.rows(), rows, "encode_checksum_into: block {j} row mismatch");
        assert!(b.cols() <= pad, "encode_checksum_into: block {j} wider than out");
        let w = weights.at(0, j) as f64;
        for i in 0..rows {
            for col in 0..b.cols() {
                acc[i * pad + col] += w * b.at(i, col) as f64;
            }
        }
    }
    for i in 0..rows {
        for col in 0..pad {
            out.set(i, col, acc[i * pad + col] as f32);
        }
    }
}

/// Reconstruct ONE lost block from one checksum and the survivors:
/// `out = (checksum − Σ_q weights[q + 1] · survivors[q]) / weights[0]`.
///
/// Convention: `weights` is `1 × N` with the **lost block's weight
/// first**, followed by the survivors' weights in the same order as
/// `survivors` — the single-loss fast path of
/// [`Encoder::reconstruct`](super::Encoder::reconstruct) (multi-loss
/// solves run coordinator-side in f64).  The output has the checksum's
/// (padded) shape; callers slice the lost block's true width.
pub fn reconstruct_block_into(
    weights: MatrixView<'_>,
    checksum: MatrixView<'_>,
    survivors: &[MatrixView<'_>],
    out: &mut MatrixViewMut<'_>,
    ws: &mut Workspace,
) {
    let n = survivors.len() + 1;
    assert_eq!(weights.shape(), (1, n), "reconstruct_block_into: weights must be 1x{n}");
    let w0 = weights.at(0, 0) as f64;
    assert!(w0 != 0.0, "reconstruct_block_into: lost block's weight must be nonzero");
    let (rows, pad) = out.shape();
    assert_eq!(checksum.shape(), (rows, pad), "reconstruct_block_into: checksum shape");
    let acc = ws.f64_scratch(rows * pad);
    for i in 0..rows {
        for col in 0..pad {
            acc[i * pad + col] = checksum.at(i, col) as f64;
        }
    }
    for (q, s) in survivors.iter().enumerate() {
        assert_eq!(s.rows(), rows, "reconstruct_block_into: survivor {q} row mismatch");
        assert!(s.cols() <= pad, "reconstruct_block_into: survivor {q} wider than out");
        let w = weights.at(0, q + 1) as f64;
        for i in 0..rows {
            for col in 0..s.cols() {
                acc[i * pad + col] -= w * s.at(i, col) as f64;
            }
        }
    }
    for i in 0..rows {
        for col in 0..pad {
            out.set(i, col, (acc[i * pad + col] / w0) as f32);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    #[test]
    fn encode_then_reconstruct_roundtrips_in_f32() {
        let rows = 6;
        let blocks: Vec<Matrix> = (0..3).map(|s| Matrix::random(rows, 4, s)).collect();
        let weights = Matrix::from_vec(1, 3, vec![1.0, 1.0, 1.0]);
        let mut ws = Workspace::new();
        let mut sum = Matrix::zeros(rows, 4);
        let views: Vec<_> = blocks.iter().map(|b| b.as_view()).collect();
        encode_checksum_into(weights.as_view(), &views, &mut sum.as_view_mut(), &mut ws);

        // Lose block 1: weights reordered lost-first.
        let rw = Matrix::from_vec(1, 3, vec![1.0, 1.0, 1.0]);
        let mut got = Matrix::zeros(rows, 4);
        reconstruct_block_into(
            rw.as_view(),
            sum.as_view(),
            &[blocks[0].as_view(), blocks[2].as_view()],
            &mut got.as_view_mut(),
            &mut ws,
        );
        assert!(
            got.max_abs_diff(&blocks[1]) < 1e-5,
            "f32 roundtrip must recover the lost block within rounding"
        );
    }

    #[test]
    fn ragged_blocks_pad_with_zeros() {
        let rows = 3;
        let wide = Matrix::random(rows, 4, 1);
        let narrow = Matrix::random(rows, 2, 2);
        let weights = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let mut ws = Workspace::new();
        let mut sum = Matrix::zeros(rows, 4);
        encode_checksum_into(
            weights.as_view(),
            &[wide.as_view(), narrow.as_view()],
            &mut sum.as_view_mut(),
            &mut ws,
        );
        // Columns past the narrow block's width carry only the wide block.
        for i in 0..rows {
            assert_eq!(sum[(i, 3)], wide[(i, 3)]);
            let want = wide[(i, 0)] as f64 + 2.0 * narrow[(i, 0)] as f64;
            assert!((sum[(i, 0)] as f64 - want).abs() < 1e-6);
        }
    }
}
