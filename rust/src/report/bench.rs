//! Minimal benchmarking harness (the vendored crate set has no
//! criterion): warmup + N timed iterations, reporting min/median/mean.
//! Every `rust/benches/*.rs` target builds its tables with this.

use std::time::{Duration, Instant};

/// Result of one measurement.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    /// Timed iterations.
    pub iters: u32,
    /// Fastest iteration.
    pub min: Duration,
    /// Median iteration.
    pub median: Duration,
    /// Mean iteration.
    pub mean: Duration,
}

impl Sample {
    /// Median in microseconds.
    pub fn median_us(&self) -> f64 {
        self.median.as_secs_f64() * 1e6
    }
    /// Median in milliseconds.
    pub fn median_ms(&self) -> f64 {
        self.median.as_secs_f64() * 1e3
    }
    /// Pretty duration with adaptive unit.
    pub fn fmt_median(&self) -> String {
        fmt_duration(self.median)
    }
}

/// Pretty-print a duration with an adaptive unit (ns/µs/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-6 {
        format!("{:.0}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Time `f` with `warmup` throwaway runs then `iters` measured runs.
pub fn bench(warmup: u32, iters: u32, mut f: impl FnMut()) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort_unstable();
    let min = times[0];
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<Duration>() / iters.max(1);
    Sample { iters, min, median, mean }
}

/// Quick-mode switch: `BENCH_QUICK=1` shrinks iteration counts so the
/// full `cargo bench` suite stays tractable in CI.
pub fn quick() -> bool {
    std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Regression-gate switch: `BENCH_REGRESS=1` makes the bench binaries
/// compare their higher-is-better metrics against the committed
/// baselines in `benches/baselines/` and exit non-zero on a drop
/// beyond the tolerance (the CI `bench-regress` job).
pub fn regress_enabled() -> bool {
    std::env::var("BENCH_REGRESS").map(|v| v == "1").unwrap_or(false)
}

/// Outcome of one [`regress_check`] comparison.
#[derive(Debug, Clone, PartialEq)]
pub enum Regression {
    /// Every compared metric is within tolerance of the baseline.
    Pass(String),
    /// No baseline file exists yet — nothing to gate against.
    NoBaseline(String),
    /// At least one metric dropped beyond the tolerance.
    Fail(String),
}

/// Tolerant comparator for the CI perf gate: compare `current`
/// higher-is-better metrics against the committed baseline JSON.
///
/// A metric regresses when `current < baseline * (1 − tolerance)`
/// (e.g. `tolerance = 0.20` fails on a >20 % drop).  Keys absent from
/// the baseline are skipped — adding a metric to a bench never breaks
/// the gate until the baseline is refreshed.  Two escape hatches keep
/// the gate honest rather than noisy:
///
/// * a baseline carrying `"provisional": true` (the seeded floors
///   committed before the first measured refresh) reports drops as
///   warnings inside [`Regression::Pass`] instead of failing;
/// * a baseline whose recorded `"quick"` flag differs from the current
///   run's mode also only warns — quick and full runs use different
///   bench shapes, and ratios are only comparable like-for-like (the
///   CI gate runs quick, so baselines must be refreshed with
///   `BENCH_QUICK=1` to arm it);
/// * a baseline whose recorded `"host_fingerprint"` differs from
///   `host_fingerprint` (the current host, see
///   `runtime::CpuInfo::fingerprint`) also only warns — an absolute
///   GFLOP/s number measured on one machine is not a contract for a
///   different machine.  The check is skipped when either side is
///   empty (legacy baselines without a fingerprint stay hard-gated).
///
/// Baselines are deliberately dominated by machine-*relative* metrics
/// (speedup ratios, not absolute runs/sec): CI hosts vary widely in
/// absolute speed, but a fast path that stops beating its reference
/// path regresses on every machine.  The host fingerprint protects the
/// few absolute metrics (GEMM GFLOP/s) that a heterogeneous host would
/// otherwise trip.
pub fn regress_check(
    bench: &str,
    baseline_path: &str,
    current: &[(&str, f64)],
    tolerance: f64,
    quick_mode: bool,
    host_fingerprint: &str,
) -> Regression {
    let text = match std::fs::read_to_string(baseline_path) {
        Ok(t) => t,
        Err(_) => {
            return Regression::NoBaseline(format!(
                "{bench}: no baseline at {baseline_path}; run with BENCH_WRITE_BASELINE=1 \
                 to seed one"
            ));
        }
    };
    let json = match crate::util::Json::parse(&text) {
        Ok(j) => j,
        Err(e) => return Regression::Fail(format!("{bench}: unreadable baseline: {e}")),
    };
    let baseline_quick = json.get("quick").and_then(crate::util::Json::as_bool);
    let mode_mismatch = baseline_quick.is_some_and(|q| q != quick_mode);
    let baseline_host = json
        .get("host_fingerprint")
        .and_then(crate::util::Json::as_str)
        .unwrap_or_default()
        .to_string();
    let host_mismatch = !baseline_host.is_empty()
        && !host_fingerprint.is_empty()
        && baseline_host != host_fingerprint;
    let provisional =
        json.get("provisional").and_then(crate::util::Json::as_bool).unwrap_or(false)
            || mode_mismatch
            || host_mismatch;
    let mut drops = Vec::new();
    let mut compared = 0usize;
    for &(key, cur) in current {
        let Some(base) = json.get(key).and_then(crate::util::Json::as_f64) else {
            continue;
        };
        if base <= 0.0 {
            continue;
        }
        compared += 1;
        if cur < base * (1.0 - tolerance) {
            drops.push(format!(
                "{key}: {cur:.3} vs baseline {base:.3} (-{:.1}% > {:.0}% tolerance)",
                (1.0 - cur / base) * 100.0,
                tolerance * 100.0
            ));
        }
    }
    if drops.is_empty() {
        Regression::Pass(format!("{bench}: {compared} metrics within tolerance of baseline"))
    } else if provisional {
        let why = if host_mismatch {
            format!("HOST-MISMATCHED (baseline '{baseline_host}' vs current '{host_fingerprint}')")
        } else if mode_mismatch {
            "MODE-MISMATCHED (quick vs full)".to_string()
        } else {
            "PROVISIONAL".to_string()
        };
        Regression::Pass(format!(
            "{bench}: drops vs {why} baseline (warning only): {}",
            drops.join("; ")
        ))
    } else {
        Regression::Fail(format!("{bench}: perf regression: {}", drops.join("; ")))
    }
}

/// Bench-binary helper: run the gate when `BENCH_REGRESS=1`, print the
/// host identity and the verdict, and exit non-zero on a real
/// regression.  The current run's [`quick`] mode and the host's
/// `CpuInfo` fingerprint are compared against the baseline's recorded
/// ones, so metrics are never hard-gated across different bench shapes
/// or different machines.
pub fn enforce_regress_gate(bench: &str, baseline_path: &str, current: &[(&str, f64)]) {
    if !regress_enabled() {
        return;
    }
    let cpu = crate::runtime::CpuInfo::cached();
    println!("bench-regress host: {}", cpu.summary());
    match regress_check(bench, baseline_path, current, 0.20, quick(), &cpu.fingerprint()) {
        Regression::Pass(msg) | Regression::NoBaseline(msg) => println!("bench-regress: {msg}"),
        Regression::Fail(msg) => {
            eprintln!("bench-regress: {msg}");
            std::process::exit(3);
        }
    }
}

/// JSON fields (no surrounding braces, no trailing comma) identifying
/// the host a bench report was measured on — spliced into every
/// `BENCH_*.json` so [`regress_check`] can refuse to hard-gate across
/// machines and humans can see what hardware produced a number.
pub fn host_json_fields() -> String {
    let cpu = crate::runtime::CpuInfo::cached();
    let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
    format!(
        "\"host_fingerprint\": \"{}\", \"host_model\": \"{}\", \"host_arch\": \"{}\", \
         \"host_isa\": \"{}\", \"host_features\": \"{}\", \"host_threads\": {}",
        esc(&cpu.fingerprint()),
        esc(&cpu.model),
        cpu.arch,
        cpu.isa.name(),
        cpu.features.join("+"),
        cpu.threads
    )
}

/// Pick an iteration count depending on quick mode.
pub fn iters(full: u32, quick_n: u32) -> u32 {
    if quick() { quick_n } else { full }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut count = 0u64;
        let s = bench(1, 5, || {
            count += 1;
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(count, 6, "warmup + iters");
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median.as_nanos() > 0);
    }

    #[test]
    fn regress_comparator_tolerates_and_fails() {
        let tmp = crate::util::TestDir::new();
        let p = tmp.write(
            "BENCH_x.json",
            r#"{"bench":"x","quick":true,"speedup":2.0,"gflops":4.0,"zero":0.0}"#,
        );
        let path = p.to_str().unwrap();
        // Within tolerance (10% drop < 20%).
        assert!(matches!(
            regress_check("x", path, &[("speedup", 1.8), ("gflops", 4.5)], 0.20, true, ""),
            Regression::Pass(_)
        ));
        // Beyond tolerance, same mode: hard fail.
        assert!(matches!(
            regress_check("x", path, &[("speedup", 1.5)], 0.20, true, ""),
            Regression::Fail(_)
        ));
        // Same drop, but the baseline was recorded in a different mode
        // (different bench shapes): warning only.
        assert!(matches!(
            regress_check("x", path, &[("speedup", 1.5)], 0.20, false, ""),
            Regression::Pass(_)
        ));
        // Unknown + non-positive keys are skipped, missing file is soft.
        assert!(matches!(
            regress_check("x", path, &[("new_metric", 0.1), ("zero", 0.0)], 0.20, true, ""),
            Regression::Pass(_)
        ));
        assert!(matches!(
            regress_check("x", "/nonexistent/b.json", &[("speedup", 1.0)], 0.20, true, ""),
            Regression::NoBaseline(_)
        ));
        // Provisional baselines warn instead of failing.
        let p2 = tmp.write(
            "BENCH_y.json",
            r#"{"bench":"y","quick":true,"provisional":true,"speedup":2.0}"#,
        );
        assert!(matches!(
            regress_check("y", p2.to_str().unwrap(), &[("speedup", 0.5)], 0.20, true, ""),
            Regression::Pass(_)
        ));
    }

    #[test]
    fn armed_baseline_hard_fails_synthetic_drop_on_matching_host() {
        // The acceptance-criterion shape: a measured (non-provisional)
        // baseline with a matching host fingerprint MUST hard-fail a
        // synthetic >20% drop — the gate is a contract, not a warning.
        let tmp = crate::util::TestDir::new();
        let p = tmp.write(
            "BENCH_armed.json",
            r#"{"bench":"armed","quick":true,"provisional":false,
                "host_fingerprint":"x86_64|TestCpu|avx2+fma|4t","gemm_gflops":4.0}"#,
        );
        let path = p.to_str().unwrap();
        let host = "x86_64|TestCpu|avx2+fma|4t";
        match regress_check("armed", path, &[("gemm_gflops", 2.0)], 0.20, true, host) {
            Regression::Fail(msg) => {
                assert!(msg.contains("perf regression"), "{msg}");
                assert!(msg.contains("-50.0%"), "must quantify the drop: {msg}");
            }
            other => panic!("measured baseline + matching host must FAIL, not {other:?}"),
        }
        // Within tolerance still passes on the same armed baseline.
        assert!(matches!(
            regress_check("armed", path, &[("gemm_gflops", 3.9)], 0.20, true, host),
            Regression::Pass(_)
        ));
    }

    #[test]
    fn host_fingerprint_mismatch_warns_with_both_hosts_and_the_drop() {
        let tmp = crate::util::TestDir::new();
        let p = tmp.write(
            "BENCH_h.json",
            r#"{"bench":"h","quick":true,"provisional":false,
                "host_fingerprint":"x86_64|CpuA|avx2|8t","gemm_gflops":4.0}"#,
        );
        let path = p.to_str().unwrap();
        // Different host: the same >20% drop becomes a visible warning
        // naming BOTH fingerprints and keeping the drop quantified.
        match regress_check("h", path, &[("gemm_gflops", 1.0)], 0.20, true, "arm64|CpuB|neon|2t") {
            Regression::Pass(msg) => {
                assert!(msg.contains("HOST-MISMATCHED"), "must name the reason: {msg}");
                assert!(msg.contains("CpuA") && msg.contains("CpuB"), "both hosts: {msg}");
                assert!(msg.contains("-75.0%"), "must quantify the drop: {msg}");
            }
            other => panic!("host-mismatched drop must warn, not {other:?}"),
        }
        // Legacy baseline without a fingerprint stays hard-gated even
        // when the current host is known.
        let legacy =
            tmp.write("BENCH_l.json", r#"{"bench":"l","quick":true,"gemm_gflops":4.0}"#);
        assert!(matches!(
            regress_check("l", legacy.to_str().unwrap(), &[("gemm_gflops", 1.0)], 0.2, true, "any"),
            Regression::Fail(_)
        ));
        // An empty current fingerprint skips the check (hard gate holds).
        assert!(matches!(
            regress_check("h", path, &[("gemm_gflops", 1.0)], 0.20, true, ""),
            Regression::Fail(_)
        ));
    }

    #[test]
    fn host_json_fields_carry_the_cached_fingerprint() {
        let fields = host_json_fields();
        let cpu = crate::runtime::CpuInfo::cached();
        assert!(fields.contains("\"host_fingerprint\""), "{fields}");
        assert!(fields.contains(&format!("\"host_threads\": {}", cpu.threads)), "{fields}");
        assert!(fields.contains(cpu.isa.name()), "{fields}");
        // Splicing into an object yields parseable JSON whose
        // fingerprint round-trips through the gate's reader.
        let doc = format!("{{{fields}}}");
        let json = crate::util::Json::parse(&doc).expect("host fields must be valid JSON");
        assert_eq!(
            json.get("host_fingerprint").and_then(crate::util::Json::as_str),
            Some(cpu.fingerprint().as_str())
        );
    }

    #[test]
    fn provisional_warn_path_names_its_reason_and_keeps_the_drop_visible() {
        // The warn path must stay a *warning* (Pass) yet say WHY it did
        // not gate, and carry the drop text — otherwise a provisional
        // baseline silently hides real regressions from the CI log.
        let tmp = crate::util::TestDir::new();
        let p = tmp.write(
            "BENCH_p.json",
            r#"{"bench":"p","quick":true,"provisional":true,"speedup":2.0}"#,
        );
        match regress_check("p", p.to_str().unwrap(), &[("speedup", 0.5)], 0.20, true, "") {
            Regression::Pass(msg) => {
                assert!(msg.contains("PROVISIONAL"), "must name the escape hatch: {msg}");
                assert!(msg.contains("speedup"), "must keep the dropped metric visible: {msg}");
                assert!(msg.contains("-75.0%"), "must quantify the drop: {msg}");
            }
            other => panic!("provisional drop must warn, not {other:?}"),
        }
        // Mode mismatch is the other warn reason, and it must say so.
        let q = tmp.write("BENCH_q.json", r#"{"bench":"q","quick":true,"speedup":2.0}"#);
        match regress_check("q", q.to_str().unwrap(), &[("speedup", 0.5)], 0.20, false, "") {
            Regression::Pass(msg) => {
                assert!(msg.contains("MODE-MISMATCHED"), "must name the reason: {msg}");
            }
            other => panic!("mode-mismatched drop must warn, not {other:?}"),
        }
        // A provisional baseline with NO drop passes with the normal
        // within-tolerance message (no scare words).
        match regress_check("p", p.to_str().unwrap(), &[("speedup", 2.1)], 0.20, true, "") {
            Regression::Pass(msg) => assert!(!msg.contains("PROVISIONAL"), "{msg}"),
            other => panic!("clean provisional run must pass, not {other:?}"),
        }
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(Duration::from_nanos(50)).ends_with("ns"));
        assert!(fmt_duration(Duration::from_micros(50)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(50)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
    }
}
