//! Minimal benchmarking harness (the vendored crate set has no
//! criterion): warmup + N timed iterations, reporting min/median/mean.
//! Every `rust/benches/*.rs` target builds its tables with this.

use std::time::{Duration, Instant};

/// Result of one measurement.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    /// Timed iterations.
    pub iters: u32,
    /// Fastest iteration.
    pub min: Duration,
    /// Median iteration.
    pub median: Duration,
    /// Mean iteration.
    pub mean: Duration,
}

impl Sample {
    /// Median in microseconds.
    pub fn median_us(&self) -> f64 {
        self.median.as_secs_f64() * 1e6
    }
    /// Median in milliseconds.
    pub fn median_ms(&self) -> f64 {
        self.median.as_secs_f64() * 1e3
    }
    /// Pretty duration with adaptive unit.
    pub fn fmt_median(&self) -> String {
        fmt_duration(self.median)
    }
}

/// Pretty-print a duration with an adaptive unit (ns/µs/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-6 {
        format!("{:.0}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Time `f` with `warmup` throwaway runs then `iters` measured runs.
pub fn bench(warmup: u32, iters: u32, mut f: impl FnMut()) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort_unstable();
    let min = times[0];
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<Duration>() / iters.max(1);
    Sample { iters, min, median, mean }
}

/// Quick-mode switch: `BENCH_QUICK=1` shrinks iteration counts so the
/// full `cargo bench` suite stays tractable in CI.
pub fn quick() -> bool {
    std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Regression-gate switch: `BENCH_REGRESS=1` makes the bench binaries
/// compare their higher-is-better metrics against the committed
/// baselines in `benches/baselines/` and exit non-zero on a drop
/// beyond the tolerance (the CI `bench-regress` job).
pub fn regress_enabled() -> bool {
    std::env::var("BENCH_REGRESS").map(|v| v == "1").unwrap_or(false)
}

/// Outcome of one [`regress_check`] comparison.
#[derive(Debug, Clone, PartialEq)]
pub enum Regression {
    /// Every compared metric is within tolerance of the baseline.
    Pass(String),
    /// No baseline file exists yet — nothing to gate against.
    NoBaseline(String),
    /// At least one metric dropped beyond the tolerance.
    Fail(String),
}

/// Tolerant comparator for the CI perf gate: compare `current`
/// higher-is-better metrics against the committed baseline JSON.
///
/// A metric regresses when `current < baseline * (1 − tolerance)`
/// (e.g. `tolerance = 0.20` fails on a >20 % drop).  Keys absent from
/// the baseline are skipped — adding a metric to a bench never breaks
/// the gate until the baseline is refreshed.  Two escape hatches keep
/// the gate honest rather than noisy:
///
/// * a baseline carrying `"provisional": true` (the seeded floors
///   committed before the first measured refresh) reports drops as
///   warnings inside [`Regression::Pass`] instead of failing;
/// * a baseline whose recorded `"quick"` flag differs from the current
///   run's mode also only warns — quick and full runs use different
///   bench shapes, and ratios are only comparable like-for-like (the
///   CI gate runs quick, so baselines must be refreshed with
///   `BENCH_QUICK=1` to arm it).
///
/// Baselines are deliberately dominated by machine-*relative* metrics
/// (speedup ratios, not absolute runs/sec): CI hosts vary widely in
/// absolute speed, but a fast path that stops beating its reference
/// path regresses on every machine.
pub fn regress_check(
    bench: &str,
    baseline_path: &str,
    current: &[(&str, f64)],
    tolerance: f64,
    quick_mode: bool,
) -> Regression {
    let text = match std::fs::read_to_string(baseline_path) {
        Ok(t) => t,
        Err(_) => {
            return Regression::NoBaseline(format!(
                "{bench}: no baseline at {baseline_path}; run with BENCH_WRITE_BASELINE=1 \
                 to seed one"
            ));
        }
    };
    let json = match crate::util::Json::parse(&text) {
        Ok(j) => j,
        Err(e) => return Regression::Fail(format!("{bench}: unreadable baseline: {e}")),
    };
    let baseline_quick = json.get("quick").and_then(crate::util::Json::as_bool);
    let mode_mismatch = baseline_quick.is_some_and(|q| q != quick_mode);
    let provisional =
        json.get("provisional").and_then(crate::util::Json::as_bool).unwrap_or(false)
            || mode_mismatch;
    let mut drops = Vec::new();
    let mut compared = 0usize;
    for &(key, cur) in current {
        let Some(base) = json.get(key).and_then(crate::util::Json::as_f64) else {
            continue;
        };
        if base <= 0.0 {
            continue;
        }
        compared += 1;
        if cur < base * (1.0 - tolerance) {
            drops.push(format!(
                "{key}: {cur:.3} vs baseline {base:.3} (-{:.1}% > {:.0}% tolerance)",
                (1.0 - cur / base) * 100.0,
                tolerance * 100.0
            ));
        }
    }
    if drops.is_empty() {
        Regression::Pass(format!("{bench}: {compared} metrics within tolerance of baseline"))
    } else if provisional {
        let why = if mode_mismatch { "MODE-MISMATCHED (quick vs full)" } else { "PROVISIONAL" };
        Regression::Pass(format!(
            "{bench}: drops vs {why} baseline (warning only): {}",
            drops.join("; ")
        ))
    } else {
        Regression::Fail(format!("{bench}: perf regression: {}", drops.join("; ")))
    }
}

/// Bench-binary helper: run the gate when `BENCH_REGRESS=1`, print the
/// verdict, and exit non-zero on a real regression.  The current run's
/// [`quick`] mode is compared against the baseline's recorded mode so
/// ratios are never hard-gated across different bench shapes.
pub fn enforce_regress_gate(bench: &str, baseline_path: &str, current: &[(&str, f64)]) {
    if !regress_enabled() {
        return;
    }
    match regress_check(bench, baseline_path, current, 0.20, quick()) {
        Regression::Pass(msg) | Regression::NoBaseline(msg) => println!("bench-regress: {msg}"),
        Regression::Fail(msg) => {
            eprintln!("bench-regress: {msg}");
            std::process::exit(3);
        }
    }
}

/// Pick an iteration count depending on quick mode.
pub fn iters(full: u32, quick_n: u32) -> u32 {
    if quick() { quick_n } else { full }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut count = 0u64;
        let s = bench(1, 5, || {
            count += 1;
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(count, 6, "warmup + iters");
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median.as_nanos() > 0);
    }

    #[test]
    fn regress_comparator_tolerates_and_fails() {
        let tmp = crate::util::TestDir::new();
        let p = tmp.write(
            "BENCH_x.json",
            r#"{"bench":"x","quick":true,"speedup":2.0,"gflops":4.0,"zero":0.0}"#,
        );
        let path = p.to_str().unwrap();
        // Within tolerance (10% drop < 20%).
        assert!(matches!(
            regress_check("x", path, &[("speedup", 1.8), ("gflops", 4.5)], 0.20, true),
            Regression::Pass(_)
        ));
        // Beyond tolerance, same mode: hard fail.
        assert!(matches!(
            regress_check("x", path, &[("speedup", 1.5)], 0.20, true),
            Regression::Fail(_)
        ));
        // Same drop, but the baseline was recorded in a different mode
        // (different bench shapes): warning only.
        assert!(matches!(
            regress_check("x", path, &[("speedup", 1.5)], 0.20, false),
            Regression::Pass(_)
        ));
        // Unknown + non-positive keys are skipped, missing file is soft.
        assert!(matches!(
            regress_check("x", path, &[("new_metric", 0.1), ("zero", 0.0)], 0.20, true),
            Regression::Pass(_)
        ));
        assert!(matches!(
            regress_check("x", "/nonexistent/b.json", &[("speedup", 1.0)], 0.20, true),
            Regression::NoBaseline(_)
        ));
        // Provisional baselines warn instead of failing.
        let p2 = tmp.write(
            "BENCH_y.json",
            r#"{"bench":"y","quick":true,"provisional":true,"speedup":2.0}"#,
        );
        assert!(matches!(
            regress_check("y", p2.to_str().unwrap(), &[("speedup", 0.5)], 0.20, true),
            Regression::Pass(_)
        ));
    }

    #[test]
    fn provisional_warn_path_names_its_reason_and_keeps_the_drop_visible() {
        // The warn path must stay a *warning* (Pass) yet say WHY it did
        // not gate, and carry the drop text — otherwise a provisional
        // baseline silently hides real regressions from the CI log.
        let tmp = crate::util::TestDir::new();
        let p = tmp.write(
            "BENCH_p.json",
            r#"{"bench":"p","quick":true,"provisional":true,"speedup":2.0}"#,
        );
        match regress_check("p", p.to_str().unwrap(), &[("speedup", 0.5)], 0.20, true) {
            Regression::Pass(msg) => {
                assert!(msg.contains("PROVISIONAL"), "must name the escape hatch: {msg}");
                assert!(msg.contains("speedup"), "must keep the dropped metric visible: {msg}");
                assert!(msg.contains("-75.0%"), "must quantify the drop: {msg}");
            }
            other => panic!("provisional drop must warn, not {other:?}"),
        }
        // Mode mismatch is the other warn reason, and it must say so.
        let q = tmp.write("BENCH_q.json", r#"{"bench":"q","quick":true,"speedup":2.0}"#);
        match regress_check("q", q.to_str().unwrap(), &[("speedup", 0.5)], 0.20, false) {
            Regression::Pass(msg) => {
                assert!(msg.contains("MODE-MISMATCHED"), "must name the reason: {msg}");
            }
            other => panic!("mode-mismatched drop must warn, not {other:?}"),
        }
        // A provisional baseline with NO drop passes with the normal
        // within-tolerance message (no scare words).
        match regress_check("p", p.to_str().unwrap(), &[("speedup", 2.1)], 0.20, true) {
            Regression::Pass(msg) => assert!(!msg.contains("PROVISIONAL"), "{msg}"),
            other => panic!("clean provisional run must pass, not {other:?}"),
        }
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(Duration::from_nanos(50)).ends_with("ns"));
        assert!(fmt_duration(Duration::from_micros(50)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(50)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
    }
}
