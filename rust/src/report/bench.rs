//! Minimal benchmarking harness (the vendored crate set has no
//! criterion): warmup + N timed iterations, reporting min/median/mean.
//! Every `rust/benches/*.rs` target builds its tables with this.

use std::time::{Duration, Instant};

/// Result of one measurement.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    /// Timed iterations.
    pub iters: u32,
    /// Fastest iteration.
    pub min: Duration,
    /// Median iteration.
    pub median: Duration,
    /// Mean iteration.
    pub mean: Duration,
}

impl Sample {
    /// Median in microseconds.
    pub fn median_us(&self) -> f64 {
        self.median.as_secs_f64() * 1e6
    }
    /// Median in milliseconds.
    pub fn median_ms(&self) -> f64 {
        self.median.as_secs_f64() * 1e3
    }
    /// Pretty duration with adaptive unit.
    pub fn fmt_median(&self) -> String {
        fmt_duration(self.median)
    }
}

/// Pretty-print a duration with an adaptive unit (ns/µs/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-6 {
        format!("{:.0}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Time `f` with `warmup` throwaway runs then `iters` measured runs.
pub fn bench(warmup: u32, iters: u32, mut f: impl FnMut()) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort_unstable();
    let min = times[0];
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<Duration>() / iters.max(1);
    Sample { iters, min, median, mean }
}

/// Quick-mode switch: `BENCH_QUICK=1` shrinks iteration counts so the
/// full `cargo bench` suite stays tractable in CI.
pub fn quick() -> bool {
    std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Pick an iteration count depending on quick mode.
pub fn iters(full: u32, quick_n: u32) -> u32 {
    if quick() { quick_n } else { full }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut count = 0u64;
        let s = bench(1, 5, || {
            count += 1;
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(count, 6, "warmup + iters");
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median.as_nanos() > 0);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(Duration::from_nanos(50)).ends_with("ns"));
        assert!(fmt_duration(Duration::from_micros(50)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(50)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
    }
}
