//! Table/figure writers used by the benches and the CLI: aligned text
//! to stdout (what the paper's tables look like) plus CSV for plotting.

pub mod bench;

use std::fmt::Write as _;
use std::path::Path;

use crate::error::{Error, Result};

/// A simple column-aligned table.
#[derive(Debug, Clone)]
pub struct Table {
    /// Title printed above the table (and slugged into the CSV name).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Data rows (each the header arity).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with a title and column headers.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; panics if the arity mismatches the header (tables
    /// are built by our own benches — a mismatch is a bug).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity != header arity");
        self.rows.push(cells);
        self
    }

    /// Render as aligned text.
    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "## {}", self.title);
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::from("| ");
            for i in 0..cols {
                let _ = write!(s, "{:>w$} | ", cells[i], w = widths[i]);
            }
            s.trim_end().to_string()
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{:-<w$}|", "", w = w + 2);
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV rendering (RFC-4180-ish; our cells never contain commas or
    /// quotes, but escape defensively anyway).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV next to the bench outputs (`dir/<slug>.csv`).
    pub fn save_csv(&self, dir: impl AsRef<Path>) -> Result<std::path::PathBuf> {
        let slug: String = self
            .title
            .chars()
            .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)
            .map_err(|e| Error::Other(format!("mkdir {}: {e}", dir.display())))?;
        let path = dir.join(format!("{slug}.csv"));
        std::fs::write(&path, self.to_csv())
            .map_err(|e| Error::Other(format!("write {}: {e}", path.display())))?;
        Ok(path)
    }
}

/// Conventional output directory for bench-generated tables.
pub const REPORT_DIR: &str = "target/reports";

/// Format a float tersely for tables.
pub fn fmt_f(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 0.01 && x.abs() < 1000.0 {
        format!("{x:.3}")
    } else {
        format!("{x:.2e}")
    }
}

/// Format a probability with its 95% CI.
pub fn fmt_prob(p: f64, ci: f64) -> String {
    format!("{p:.3}±{ci:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("Demo", &["algo", "p"]);
        t.row(vec!["baseline".into(), "1.0".into()]);
        t.row(vec!["sh".into(), "0.5".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("baseline"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[1].len(), lines[3].len(), "aligned rows");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        Table::new("x", &["a", "b"]).row(vec!["1".into()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("c", &["a"]);
        t.row(vec!["has,comma".into()]);
        assert!(t.to_csv().contains("\"has,comma\""));
    }

    #[test]
    fn save_csv_slugifies() {
        let tmp = crate::util::TestDir::new();
        let mut t = Table::new("TAB-R1: Survival", &["x"]);
        t.row(vec!["1".into()]);
        let p = t.save_csv(tmp.path()).unwrap();
        assert!(p.file_name().unwrap().to_str().unwrap().starts_with("tab_r1"));
        assert!(p.exists());
    }

    #[test]
    fn float_formatting() {
        assert_eq!(fmt_f(0.0), "0");
        assert_eq!(fmt_f(0.5), "0.500");
        assert!(fmt_f(1e-9).contains('e'));
    }
}
