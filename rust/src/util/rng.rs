//! Deterministic PRNG — SplitMix64 seeding a xoshiro256**.
//!
//! The vendored crate set has no `rand`, so the fault injector, the
//! Monte-Carlo sweeps and the property-test harness use this.  Quality
//! is far beyond what survival estimation needs (xoshiro256** passes
//! BigCrush); determinism per seed is the property the tests rely on.

/// Derive an independent child seed from a `(base, stream)` pair —
/// one SplitMix64 round over the mixed words.
///
/// This is THE seed-derivation rule of the crate: every Monte-Carlo
/// campaign (`repro campaign`/`sweep`/`simulate`, the [`crate::analysis`]
/// sweeps, the [`crate::sim`] sample streams) derives its per-sample
/// seeds through this, so a CLI `--seed` reproduces the exact sample
/// stream everywhere.  Unlike the ad-hoc `base.wrapping_add(i)` it
/// replaces, nearby streams produce statistically unrelated seeds
/// (`seed + 1` of one cell can never collide into the stream of the
/// next cell).
pub fn derive_seed(base: u64, stream: u64) -> u64 {
    let mut z = base ^ stream.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(17);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// A generator deterministically derived from `seed`.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to expand the seed into four non-zero words.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Self { s }
    }

    /// Next raw 64 bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Rejection-free multiply-shift (Lemire); bias < 2^-64 * n.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform u64 in [lo, hi).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + ((self.next_u64() as u128 * (hi - lo) as u128) >> 64) as u64
    }

    /// Standard-normal-ish sample (sum of 12 uniforms − 6; adequate for
    /// test matrices, never used in numerics under test).
    pub fn normal(&mut self) -> f64 {
        (0..12).map(|_| self.f64()).sum::<f64>() - 6.0
    }

    /// Exponential(rate) sample.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        let u = self.f64().max(1e-300);
        -u.ln() / rate
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct items from `pool` (order unspecified).
    pub fn sample_distinct<T: Copy>(&mut self, pool: &[T], k: usize) -> Vec<T> {
        let mut pool = pool.to_vec();
        let k = k.min(pool.len());
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            let i = self.below(pool.len());
            out.push(pool.swap_remove(i));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_seed_is_deterministic_and_spreads() {
        assert_eq!(derive_seed(42, 7), derive_seed(42, 7));
        assert_ne!(derive_seed(42, 7), derive_seed(42, 8));
        assert_ne!(derive_seed(42, 7), derive_seed(43, 7));
        // The failure mode of wrapping_add streams: (base, i+1) must
        // not collide with (base+1, i).
        assert_ne!(derive_seed(42, 8), derive_seed(43, 7));
        // Streams stay distinct over a long run.
        let mut seen: std::collections::HashSet<u64> =
            (0..10_000).map(|i| derive_seed(0xC0712, i)).collect();
        assert_eq!(seen.len(), 10_000, "no collisions in 10k streams");
        seen.extend((0..10_000).map(|i| derive_seed(0xC0713, i)));
        assert_eq!(seen.len(), 20_000, "neighbouring bases do not overlap");
    }

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = (0..8).map({ let mut r = Rng::new(7); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..8).map({ let mut r = Rng::new(7); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
        let c: Vec<u64> = (0..8).map({ let mut r = Rng::new(8); move |_| r.next_u64() }).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&x| x), "all buckets hit");
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn exponential_mean_near_inverse_rate() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Rng::new(5);
        let pool: Vec<usize> = (0..20).collect();
        let mut s = r.sample_distinct(&pool, 10);
        s.sort_unstable();
        let before = s.len();
        s.dedup();
        assert_eq!(s.len(), before);
        assert_eq!(before, 10);
        assert_eq!(r.sample_distinct(&pool, 50).len(), 20, "capped at pool");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(6);
        let mut xs: Vec<u32> = (0..16).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..16).collect::<Vec<u32>>());
        assert_ne!(xs, (0..16).collect::<Vec<u32>>(), "16! leaves ~0 chance of identity");
    }
}
