//! Scratch directories for tests (in lieu of the `tempfile` crate).
//! Unique per process + counter; removed on drop (best effort).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A scratch directory deleted when dropped.
pub struct TestDir {
    path: PathBuf,
}

impl TestDir {
    /// Create a fresh unique scratch directory.
    pub fn new() -> Self {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "ft-tsqr-test-{}-{}-{n}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.subsec_nanos())
                .unwrap_or(0),
        ));
        std::fs::create_dir_all(&path).expect("create test dir");
        Self { path }
    }

    /// The directory's path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Write a file under the directory, creating parents.
    pub fn write(&self, rel: &str, contents: &str) -> PathBuf {
        let p = self.path.join(rel);
        if let Some(parent) = p.parent() {
            std::fs::create_dir_all(parent).expect("create parents");
        }
        std::fs::write(&p, contents).expect("write test file");
        p
    }
}

impl Default for TestDir {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for TestDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let keep;
        {
            let d = TestDir::new();
            keep = d.path().to_path_buf();
            assert!(keep.exists());
            let f = d.write("sub/a.txt", "hi");
            assert_eq!(std::fs::read_to_string(f).unwrap(), "hi");
        }
        assert!(!keep.exists(), "removed on drop");
    }

    #[test]
    fn unique_paths() {
        let a = TestDir::new();
        let b = TestDir::new();
        assert_ne!(a.path(), b.path());
    }
}
