//! In-tree utility substrates (the vendored crate set is minimal, so
//! these replace `serde_json`, `rand`, `tempfile` and `toml`):
//!
//! * [`json`]    — minimal JSON parser (manifest.json)
//! * [`rng`]     — xoshiro256** PRNG (fault injection, Monte-Carlo)
//! * [`testdir`] — scratch directories for tests
//! * [`kv`]      — the flat `key = value` config format

pub mod json;
pub mod kv;
pub mod rng;
pub mod testdir;

pub use json::Json;
pub use rng::{Rng, derive_seed};
pub use testdir::TestDir;
