//! Minimal JSON parser — enough to read `artifacts/manifest.json`.
//!
//! The vendored crate set has no `serde_json`, and the manifest is the
//! only JSON this crate touches, so a small recursive-descent parser
//! (strings, numbers, bools, null, arrays, objects; `\uXXXX` escapes)
//! is the honest dependency-free answer.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (f64 precision).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field by key (`None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The value as a usize, if it is a non-negative integer.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| x.fract() == 0.0 && *x >= 0.0).map(|x| x as usize)
    }

    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The fields, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Artifacts(format!("json: {msg} at byte {}", self.i))
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Copy one UTF-8 scalar (manifest strings are ASCII,
                    // but stay correct anyway).
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shaped_json() {
        let j = Json::parse(
            r#"{"dtype":"f32","entries":[
                {"name":"leaf_qr_64x4","kind":"leaf_qr","params":{"m":64,"n":4},
                 "file":"leaf_qr_64x4.hlo.txt","inputs":[[64,4]],"out_arity":3}]}"#,
        )
        .unwrap();
        assert_eq!(j.get("dtype").unwrap().as_str(), Some("f32"));
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("out_arity").unwrap().as_usize(), Some(3));
        assert_eq!(e.get("params").unwrap().get("m").unwrap().as_usize(), Some(64));
        let inputs = e.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].as_arr().unwrap()[1].as_usize(), Some(4));
    }

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn nested_and_whitespace() {
        let j = Json::parse(" [ 1 , [ 2, 3 ] , { \"k\" : [] } ] ").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[1].as_arr().unwrap().len(), 2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::parse("3.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-2").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn unicode_passthrough() {
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }
}
