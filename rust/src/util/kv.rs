//! The flat config-file format (in lieu of `toml`):
//!
//! ```text
//! # comment
//! algo = "replace"
//! procs = 16
//! verify = true
//! [failures]
//! mode = "at"
//! kills = [[2, 1], [5, 2]]
//! ```
//!
//! Sections prefix keys with `section.`: the `kills` line above is
//! stored under `failures.kills`.  Values: quoted strings, integers,
//! floats, booleans, and (nested) arrays of integers.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// One parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// A quoted string.
    Str(String),
    /// An integer.
    Int(i64),
    /// A float.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// Arrays of integers or integer pairs (`[[2,1],[5,2]]` flattens to
    /// nested `Arr`).
    Arr(Vec<Value>),
}

impl Value {
    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The integer value, if this is an integer.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }
    /// The value as a usize, if it is a non-negative integer.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().filter(|x| *x >= 0).map(|x| x as usize)
    }
    /// The numeric value (floats and integers).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed key-value document (keys are `section.key` or bare `key`).
#[derive(Debug, Clone, Default)]
pub struct Doc {
    map: BTreeMap<String, Value>,
}

impl Doc {
    /// Parse a whole config-file text.
    pub fn parse(text: &str) -> Result<Doc> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected 'key = value'", lineno + 1))
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let value = parse_value(v.trim())
                .map_err(|e| Error::Config(format!("line {}: {e}", lineno + 1)))?;
            map.insert(key, value);
        }
        Ok(Doc { map })
    }

    /// Raw value by (section-qualified) key.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    /// Every key in the document, sorted.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }

    /// String value of a key, if present and a string.
    pub fn str_of(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }
    /// usize value of a key, if present and a non-negative integer.
    pub fn usize_of(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(Value::as_usize)
    }
    /// u64 value of a key, if present and a non-negative integer.
    pub fn u64_of(&self, key: &str) -> Option<u64> {
        self.get(key).and_then(Value::as_i64).filter(|x| *x >= 0).map(|x| x as u64)
    }
    /// f64 value of a key, if present and numeric.
    pub fn f64_of(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_f64)
    }
    /// bool value of a key, if present and boolean.
    pub fn bool_of(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Value::as_bool)
    }

    /// `[[2,1],[5,2]]` → vec![(2,1), (5,2)].
    pub fn pairs_of(&self, key: &str) -> Option<Vec<(usize, u32)>> {
        let arr = self.get(key)?.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for item in arr {
            let pair = item.as_arr()?;
            if pair.len() != 2 {
                return None;
            }
            out.push((pair[0].as_usize()?, pair[1].as_usize()? as u32));
        }
        Some(out)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"').and_then(|t| t.strip_suffix('"')) {
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        return parse_array(s);
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

fn parse_array(s: &str) -> std::result::Result<Value, String> {
    // Tiny recursive parser over a char cursor.
    fn inner(b: &[u8], i: &mut usize) -> std::result::Result<Value, String> {
        // *i points at '['.
        *i += 1;
        let mut items = Vec::new();
        loop {
            while *i < b.len() && (b[*i] as char).is_whitespace() {
                *i += 1;
            }
            match b.get(*i) {
                None => return Err("unterminated array".into()),
                Some(b']') => {
                    *i += 1;
                    return Ok(Value::Arr(items));
                }
                Some(b'[') => items.push(inner(b, i)?),
                Some(_) => {
                    let start = *i;
                    while *i < b.len() && !matches!(b[*i], b',' | b']' | b'[') {
                        *i += 1;
                    }
                    let tok = std::str::from_utf8(&b[start..*i]).unwrap().trim();
                    if tok.is_empty() {
                        return Err("empty array element".into());
                    }
                    items.push(
                        tok.parse::<i64>()
                            .map(Value::Int)
                            .or_else(|_| tok.parse::<f64>().map(Value::Float))
                            .map_err(|_| format!("bad array element '{tok}'"))?,
                    );
                }
            }
            while *i < b.len() && (b[*i] as char).is_whitespace() {
                *i += 1;
            }
            match b.get(*i) {
                Some(b',') => *i += 1,
                Some(b']') => {}
                _ => return Err("expected ',' or ']'".into()),
            }
        }
    }
    let b = s.as_bytes();
    let mut i = 0;
    let v = inner(b, &mut i)?;
    if s[i..].trim().is_empty() {
        Ok(v)
    } else {
        Err("trailing characters after array".into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let d = Doc::parse(
            r#"
            # a comment
            algo = "replace"
            procs = 16
            rate = 0.25
            verify = true
            [failures]
            mode = "at"
            kills = [[2, 1], [5, 2]]
            "#,
        )
        .unwrap();
        assert_eq!(d.str_of("algo"), Some("replace"));
        assert_eq!(d.usize_of("procs"), Some(16));
        assert_eq!(d.f64_of("rate"), Some(0.25));
        assert_eq!(d.bool_of("verify"), Some(true));
        assert_eq!(d.str_of("failures.mode"), Some("at"));
        assert_eq!(d.pairs_of("failures.kills"), Some(vec![(2, 1), (5, 2)]));
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let d = Doc::parse("a = 1 # inline\n\n# whole line\nb = \"x # not a comment\"\n").unwrap();
        assert_eq!(d.usize_of("a"), Some(1));
        assert_eq!(d.str_of("b"), Some("x # not a comment"));
    }

    #[test]
    fn flat_arrays() {
        let d = Doc::parse("xs = [1, 2, 3]").unwrap();
        let xs = d.get("xs").unwrap().as_arr().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[2].as_i64(), Some(3));
    }

    #[test]
    fn errors_are_lined() {
        let err = Doc::parse("a = 1\nbogus line\n").unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
        assert!(Doc::parse("a = [1,").is_err());
        assert!(Doc::parse("a = nope").is_err());
    }

    #[test]
    fn empty_array_and_nested() {
        let d = Doc::parse("a = []\nb = [[1,2],[3,4]]").unwrap();
        assert_eq!(d.get("a").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(d.pairs_of("b"), Some(vec![(1, 2), (3, 4)]));
    }

    #[test]
    fn negative_numbers() {
        let d = Doc::parse("x = -5\ny = -0.5").unwrap();
        assert_eq!(d.get("x").unwrap().as_i64(), Some(-5));
        assert_eq!(d.f64_of("y"), Some(-0.5));
        assert_eq!(d.usize_of("x"), None, "negatives are not usize");
    }
}
