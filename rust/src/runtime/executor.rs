//! Typed kernel entry points over the shared [`Kernel`] call
//! convention, with backend policy and per-executor workspace pooling.
//!
//! Every simulated process holds a cheap `Executor` clone and calls
//! `leaf_qr` / `combine` / ... — it never sees HLO files, literals, or
//! workspaces.  Internally every operation is one [`KernelCall`]
//! dispatched to a `&dyn Kernel` (host or PJRT), with scratch checked
//! out of the executor's [`WorkspacePool`] — so a steady-state
//! campaign performs zero scratch allocations (see `linalg::view`).
//!
//! Dispatch policy (`Backend`):
//!   * `Pjrt` — artifacts only; error if a shape is missing (strict mode
//!     for the integration tests and benches).
//!   * `Host` — pure-rust blocked Householder path (no artifacts).
//!   * `Auto` — PJRT when the manifest has the shape, host otherwise
//!     (the default for examples: works out of the box, accelerates
//!     when `make artifacts` has run).

use std::sync::Arc;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};
use crate::linalg::{Matrix, MatrixView, Workspace};

use super::kernel::{
    HostKernel, Kernel, KernelCall, KernelOp, PjrtKernel, WorkspacePool, WorkspaceStats,
};
use super::manifest::Manifest;
use super::service::PjrtService;
use super::threaded::{BackendChoice, BackendPlan, ThreadedKernel};

#[cfg(debug_assertions)]
use super::kernel::Contract;

/// Which compute path executes kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT artifacts only; error on shapes outside the manifest.
    Pjrt,
    /// Pure-rust blocked Householder path (no artifacts).
    Host,
    /// PJRT when the manifest has the shape, host otherwise.
    Auto,
}

impl std::str::FromStr for Backend {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "pjrt" => Ok(Backend::Pjrt),
            "host" => Ok(Backend::Host),
            "auto" => Ok(Backend::Auto),
            _ => Err(Error::Config(format!("unknown backend '{s}' (pjrt|host|auto)"))),
        }
    }
}

/// Result of a leaf factorization: R plus the implicit-Q representation.
#[derive(Debug, Clone)]
pub struct Factorization {
    /// The `n x n` upper-triangular R factor.
    pub r: Matrix,
    /// LAPACK `geqrf` packed factor (R above/on the diagonal,
    /// reflector tails below).
    pub packed: Matrix,
    /// The `(n, 1)` reflector coefficients.
    pub tau: Matrix,
}

/// Per-executor dispatch counters (relaxed atomics).
#[derive(Default, Debug)]
pub struct ExecutorStats {
    /// Kernel calls served by the PJRT backend.
    pub pjrt_calls: AtomicU64,
    /// Kernel calls served by the pure-rust host backend.
    pub host_calls: AtomicU64,
}

/// Shared kernel executor. `Clone` is cheap (Arcs inside); clones share
/// the backend, the stats, and the workspace pool — one pool per
/// engine session.
#[derive(Clone)]
pub struct Executor {
    pjrt: Option<PjrtKernel>,
    host: HostKernel,
    threaded: ThreadedKernel,
    backend: Backend,
    plan: BackendPlan,
    stats: Arc<ExecutorStats>,
    workspaces: Arc<WorkspacePool>,
}

impl Executor {
    /// Host-only executor (no artifacts required).
    pub fn host() -> Self {
        Self {
            pjrt: None,
            host: HostKernel,
            threaded: ThreadedKernel::new(),
            backend: Backend::Host,
            plan: BackendPlan::default(),
            stats: Arc::default(),
            workspaces: Arc::default(),
        }
    }

    /// Executor over an artifact directory.  `shards` = PJRT service
    /// threads (see service.rs).
    pub fn with_artifacts(dir: impl AsRef<std::path::Path>, backend: Backend, shards: usize) -> Result<Self> {
        if backend == Backend::Host {
            return Ok(Self::host());
        }
        let manifest = Manifest::load(dir)?;
        let service = PjrtService::start(manifest, shards)?;
        Ok(Self {
            pjrt: Some(PjrtKernel::new(service)),
            host: HostKernel,
            threaded: ThreadedKernel::new(),
            backend,
            plan: BackendPlan::default(),
            stats: Arc::default(),
            workspaces: Arc::default(),
        })
    }

    /// `Auto` from the conventional `artifacts/` location: PJRT if the
    /// manifest loads, silently host-only otherwise.
    pub fn auto(dir: impl AsRef<std::path::Path>) -> Self {
        // 2 shards measured optimal: each CPU PjRtClient spawns its own
        // internal thread pool, so more shards oversubscribe the cores
        // (see EXPERIMENTS.md §Perf for the sweep).
        Self::with_artifacts(dir, Backend::Auto, 2).unwrap_or_else(|_| Self::host())
    }

    /// The dispatch policy this executor was built with.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Route in-process ops per `plan` (builder style).  Orthogonal to
    /// [`Backend`]: PJRT dispatch still wins where the manifest has the
    /// shape; the plan picks which *in-process* kernel serves the rest.
    pub fn with_backend_plan(mut self, plan: BackendPlan) -> Self {
        self.plan = plan;
        self
    }

    /// The in-process backend plan (default: everything on host).
    pub fn backend_plan(&self) -> &BackendPlan {
        &self.plan
    }

    /// Dispatch counters (PJRT vs host calls).
    pub fn stats(&self) -> &ExecutorStats {
        &self.stats
    }

    /// True if this executor has a live PJRT service.
    pub fn has_pjrt(&self) -> bool {
        self.pjrt.is_some()
    }

    /// Pre-size the workspace pool for a run: at least `count`
    /// workspaces, each able to factor an `rows x cols` panel without
    /// growing.  Idempotent — the engine calls this per run with
    /// shapes precomputed by `tsqr::plan`, and after the first run of
    /// a campaign it is a no-op.
    pub fn warm_workspaces(&self, count: usize, rows: usize, cols: usize) {
        self.workspaces.warm(count, rows, cols);
    }

    /// Workspace-pool counters (`reused` = scratch allocations avoided).
    pub fn workspace_stats(&self) -> WorkspaceStats {
        self.workspaces.stats()
    }

    fn dispatch_pjrt(&self, entry: &str) -> Option<&PjrtKernel> {
        let k = self.pjrt.as_ref()?;
        match self.backend {
            Backend::Host => None,
            Backend::Pjrt => Some(k),
            Backend::Auto => {
                if k.supports(entry) {
                    Some(k)
                } else {
                    None
                }
            }
        }
    }

    fn host_guard(&self, entry: &str) -> Result<()> {
        if self.backend == Backend::Pjrt {
            return Err(Error::Artifacts(format!(
                "backend=pjrt but no artifact for entry '{entry}' — run `make artifacts` or use auto/host"
            )));
        }
        self.stats.host_calls.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// The in-process kernel the [`BackendPlan`] routes `op` to.
    fn plan_kernel(&self, op: KernelOp) -> &dyn Kernel {
        match self.plan.select(op) {
            BackendChoice::Host => &self.host,
            BackendChoice::Threaded => &self.threaded,
        }
    }

    /// Backend selection for one call.  The manifest entry name (a
    /// `format!` allocation) is only computed when there is a PJRT
    /// service to consult or a strict-mode error to phrase — the
    /// host-only hot path stays allocation-free.
    fn select_kernel(&self, op: KernelOp, views: &[MatrixView<'_>]) -> Result<&dyn Kernel> {
        if self.pjrt.is_none() && self.backend != Backend::Pjrt {
            self.stats.host_calls.fetch_add(1, Ordering::Relaxed);
            return Ok(self.plan_kernel(op));
        }
        let entry = op.entry_name(views);
        match self.dispatch_pjrt(&entry) {
            Some(p) => {
                self.stats.pjrt_calls.fetch_add(1, Ordering::Relaxed);
                Ok(p)
            }
            None => {
                self.host_guard(&entry)?;
                Ok(self.plan_kernel(op))
            }
        }
    }

    /// The single dispatch point: pick the backend, hand it scratch
    /// (pooled only when this backend+op actually consumes it), run
    /// the call.  Both backends see the identical [`KernelCall`].
    fn call(&self, op: KernelOp, views: &[MatrixView<'_>]) -> Result<Vec<Matrix>> {
        let kernel = self.select_kernel(op, views)?;
        let out = if kernel.wants_workspace(op) {
            let mut ws = self.workspaces.acquire();
            let out = kernel.execute(KernelCall { op, views, workspace: &mut ws });
            self.workspaces.release(ws);
            out
        } else {
            // No scratch consumer: an empty Workspace is two empty
            // Vecs — stack-only, no pool traffic, no counter noise.
            let mut ws = Workspace::new();
            kernel.execute(KernelCall { op, views, workspace: &mut ws })
        };
        #[cfg(debug_assertions)]
        if kernel.name() == "threaded" {
            if let Ok(got) = &out {
                self.enforce_contract(op, views, got);
            }
        }
        out
    }

    /// Debug-build contract enforcement: every threaded dispatch is
    /// replayed on the host oracle and held to the op's declared
    /// [`Contract`] — `Bitwise` ops must agree to the bit, `Tolerance`
    /// ops must land their canonicalized R within `c·n·ε·‖A‖`.
    #[cfg(debug_assertions)]
    fn enforce_contract(&self, op: KernelOp, views: &[MatrixView<'_>], got: &[Matrix]) {
        let mut ws = Workspace::new();
        let want = HostKernel
            .execute(KernelCall { op, views, workspace: &mut ws })
            .expect("host oracle failed while enforcing a backend contract");
        match op.contract() {
            Contract::Bitwise => {
                for (idx, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        g.shape(),
                        w.shape(),
                        "contract violation: {op:?} output {idx} shape {:?} != host {:?}",
                        g.shape(),
                        w.shape()
                    );
                    for (k, (x, y)) in g.data().iter().zip(w.data()).enumerate() {
                        assert!(
                            x.to_bits() == y.to_bits(),
                            "contract violation: {op:?} declared Bitwise but output {idx} \
                             element {k} differs (threaded {x} vs host {y})"
                        );
                    }
                }
            }
            Contract::Tolerance { .. } => {
                let n = views[0].cols();
                let norm = views
                    .iter()
                    .flat_map(|v| v.data().iter())
                    .map(|&x| (x as f64) * (x as f64))
                    .sum::<f64>()
                    .sqrt();
                let bound = op.contract().bound(n, norm);
                let diff = got[0].canonicalize_r().max_abs_diff(&want[0].canonicalize_r());
                assert!(
                    diff <= bound,
                    "contract violation: {op:?} declared Tolerance but canonical R diverges \
                     by {diff:e} (> bound {bound:e}, n={n}, norm={norm:e})"
                );
            }
        }
    }

    /// TSQR leaf: factor the local (m, n) panel.
    pub fn leaf_qr(&self, a: &Matrix) -> Result<Factorization> {
        let mut out = self.call(KernelOp::LeafQr, &[a.as_view()])?;
        let tau = out.pop().expect("arity 3");
        let packed = out.pop().expect("arity 3");
        let r = out.pop().expect("arity 3");
        Ok(Factorization { r, packed, tau })
    }

    /// Hot path: just the R̃ of the local panel — the only thing the
    /// coordinator ships between buddies.  Uses the R-only AOT variant
    /// when available (saves the packed/tau device→host transfers; see
    /// EXPERIMENTS.md §Perf), falling back to the full entry, then to
    /// the host path.
    pub fn leaf_r(&self, a: &Matrix) -> Result<Matrix> {
        // The fallback ladder only exists where a PJRT service (or a
        // strict-mode error) is in play — the host path skips straight
        // to the kernel without touching entry-name strings.
        if self.pjrt.is_some() || self.backend == Backend::Pjrt {
            let (m, n) = a.shape();
            let entry = Manifest::leaf_r_name(m, n);
            if self.dispatch_pjrt(&entry).is_none()
                && (self.backend == Backend::Pjrt
                    || self.dispatch_pjrt(&Manifest::leaf_qr_name(m, n)).is_some())
            {
                return Ok(self.leaf_qr(a)?.r);
            }
        }
        let mut out = self.call(KernelOp::LeafR, &[a.as_view()])?;
        Ok(out.pop().expect("arity 1"))
    }

    /// Hot path: just the R̃ of the stacked [r_top; r_bot] combine.
    pub fn combine_r(&self, r_top: &Matrix, r_bot: &Matrix) -> Result<Matrix> {
        if self.pjrt.is_some() || self.backend == Backend::Pjrt {
            let n = r_top.cols();
            let entry = Manifest::combine_r_name(n);
            if self.dispatch_pjrt(&entry).is_none()
                && (self.backend == Backend::Pjrt
                    || self.dispatch_pjrt(&Manifest::combine_name(n)).is_some())
            {
                return Ok(self.combine(r_top, r_bot)?.r);
            }
        }
        let mut out = self.call(KernelOp::CombineR, &[r_top.as_view(), r_bot.as_view()])?;
        Ok(out.pop().expect("arity 1"))
    }

    /// TSQR combine: QR of [r_top; r_bot] (both n×n upper triangular).
    pub fn combine(&self, r_top: &Matrix, r_bot: &Matrix) -> Result<Factorization> {
        let mut out = self.call(KernelOp::Combine, &[r_top.as_view(), r_bot.as_view()])?;
        let tau = out.pop().expect("arity 3");
        let packed = out.pop().expect("arity 3");
        let r = out.pop().expect("arity 3");
        Ok(Factorization { r, packed, tau })
    }

    /// Solve R x = b (R upper triangular n×n, b n×k).
    pub fn backsolve(&self, r: &Matrix, b: &Matrix) -> Result<Matrix> {
        let mut out = self.call(KernelOp::Backsolve, &[r.as_view(), b.as_view()])?;
        Ok(out.pop().expect("arity 1"))
    }

    /// Qᵀ @ b from a packed factorization.
    pub fn apply_qt(&self, f: &Factorization, b: &Matrix) -> Result<Matrix> {
        let mut out =
            self.call(KernelOp::ApplyQt, &[f.packed.as_view(), f.tau.as_view(), b.as_view()])?;
        Ok(out.pop().expect("arity 1"))
    }

    /// CAQR trailing-matrix update: apply a packed panel factorization
    /// to a trailing block.  Same product as [`apply_qt`](Self::apply_qt)
    /// but accumulated in pooled f64 workspace scratch with a single
    /// terminal rounding — the single-precision twin of the f64 update
    /// tasks `crate::caqr` schedules.
    pub fn apply_update(&self, f: &Factorization, block: &Matrix) -> Result<Matrix> {
        let mut out = self.call(
            KernelOp::ApplyUpdate,
            &[f.packed.as_view(), f.tau.as_view(), block.as_view()],
        )?;
        Ok(out.pop().expect("arity 1"))
    }

    /// Build the compact-WY T factor of a packed panel factorization —
    /// the setup half of the [`apply_wy`](Self::apply_wy) fast path
    /// (one T per panel, reused across every trailing block).
    pub fn build_t(&self, f: &Factorization) -> Result<Matrix> {
        let mut out = self.call(KernelOp::BuildT, &[f.packed.as_view(), f.tau.as_view()])?;
        Ok(out.pop().expect("arity 1"))
    }

    /// Compact-WY trailing-matrix update: the same product as
    /// [`apply_update`](Self::apply_update) computed as two GEMMs
    /// through the packed microkernel (`KernelProfile::Blocked`'s
    /// single-precision twin).  `t` comes from [`build_t`](Self::build_t).
    pub fn apply_wy(&self, f: &Factorization, t: &Matrix, block: &Matrix) -> Result<Matrix> {
        let mut out =
            self.call(KernelOp::ApplyWy, &[f.packed.as_view(), t.as_view(), block.as_view()])?;
        Ok(out.pop().expect("arity 1"))
    }

    /// Materialize the thin Q of a packed factorization.
    pub fn build_q(&self, f: &Factorization) -> Result<Matrix> {
        let mut out = self.call(KernelOp::BuildQ, &[f.packed.as_view(), f.tau.as_view()])?;
        Ok(out.pop().expect("arity 1"))
    }

    /// ABFT: encode one weighted checksum block over `blocks`
    /// (`weights` is `1 × blocks.len()`; see
    /// [`crate::abft::kernels::encode_checksum_into`]).  Scratch comes
    /// from the pooled workspaces, like every other op.
    pub fn encode_checksum(&self, weights: &Matrix, blocks: &[&Matrix]) -> Result<Matrix> {
        let views: Vec<MatrixView<'_>> = std::iter::once(weights.as_view())
            .chain(blocks.iter().map(|b| b.as_view()))
            .collect();
        let mut out = self.call(KernelOp::EncodeChecksum, &views)?;
        Ok(out.pop().expect("arity 1"))
    }

    /// ABFT: reconstruct one lost block from one checksum and the
    /// survivors (`weights` is `1 × (survivors.len() + 1)` with the
    /// lost block's weight first; see
    /// [`crate::abft::kernels::reconstruct_block_into`]).
    pub fn reconstruct_block(
        &self,
        weights: &Matrix,
        checksum: &Matrix,
        survivors: &[&Matrix],
    ) -> Result<Matrix> {
        let views: Vec<MatrixView<'_>> = [weights.as_view(), checksum.as_view()]
            .into_iter()
            .chain(survivors.iter().map(|b| b.as_view()))
            .collect();
        let mut out = self.call(KernelOp::ReconstructBlock, &views)?;
        Ok(out.pop().expect("arity 1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_executor_leaf_and_combine() {
        let ex = Executor::host();
        let a = Matrix::random(32, 4, 1);
        let f = ex.leaf_qr(&a).unwrap();
        assert_eq!(f.r.shape(), (4, 4));
        assert!(f.r.is_upper_triangular(1e-6));
        let q = ex.build_q(&f).unwrap();
        let recon = q.matmul(&f.r);
        assert!(recon.rel_fro_err(&a) < 1e-5);

        let g = ex.combine(&f.r, &f.r).unwrap();
        assert_eq!(g.r.shape(), (4, 4));
        assert!(g.r.is_upper_triangular(1e-6));
        assert_eq!(ex.stats().host_calls.load(Ordering::Relaxed), 3);
        assert_eq!(ex.stats().pjrt_calls.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn host_backsolve_and_apply_qt() {
        let ex = Executor::host();
        let a = Matrix::random(24, 4, 5);
        let xt = Matrix::random(4, 1, 6);
        let b = a.matmul(&xt);
        let f = ex.leaf_qr(&a).unwrap();
        let qtb = ex.apply_qt(&f, &b).unwrap();
        let x = ex.backsolve(&f.r, &qtb.row_block(0, 4)).unwrap();
        assert!(x.max_abs_diff(&xt) < 1e-2);
    }

    #[test]
    fn host_apply_update_matches_apply_qt() {
        let ex = Executor::host();
        let a = Matrix::random(24, 4, 5);
        let f = ex.leaf_qr(&a).unwrap();
        let b = Matrix::random(24, 3, 6);
        let upd = ex.apply_update(&f, &b).unwrap();
        let qt = ex.apply_qt(&f, &b).unwrap();
        assert_eq!(upd.shape(), (24, 3));
        assert!(upd.max_abs_diff(&qt) < 1e-4, "ApplyUpdate must compute Qᵀ·block");
    }

    #[test]
    fn host_apply_wy_matches_apply_update() {
        let ex = Executor::host();
        let a = Matrix::random(32, 8, 7);
        let f = ex.leaf_qr(&a).unwrap();
        let t = ex.build_t(&f).unwrap();
        assert_eq!(t.shape(), (8, 8));
        let block = Matrix::random(32, 5, 8);
        let fast = ex.apply_wy(&f, &t, &block).unwrap();
        let slow = ex.apply_update(&f, &block).unwrap();
        assert!(fast.max_abs_diff(&slow) < 1e-4, "WY fast path must match the rank-1 op");
        // Deterministic: the fast path reproduces its own bits.
        let again = ex.apply_wy(&f, &t, &block).unwrap();
        assert_eq!(fast, again);
    }

    #[test]
    fn pjrt_strict_errors_without_artifacts() {
        // Backend::Pjrt with a host-only executor is a config error path.
        let ex = Executor {
            pjrt: None,
            host: HostKernel,
            threaded: ThreadedKernel::new(),
            backend: Backend::Pjrt,
            plan: BackendPlan::default(),
            stats: Arc::default(),
            workspaces: Arc::default(),
        };
        let err = ex.leaf_qr(&Matrix::zeros(8, 4)).unwrap_err();
        assert!(matches!(err, Error::Artifacts(_)));
    }

    #[test]
    fn backend_parses() {
        assert_eq!("pjrt".parse::<Backend>().unwrap(), Backend::Pjrt);
        assert_eq!("host".parse::<Backend>().unwrap(), Backend::Host);
        assert_eq!("auto".parse::<Backend>().unwrap(), Backend::Auto);
        assert!("gpu".parse::<Backend>().is_err());
    }

    #[test]
    fn workspace_pool_settles_across_calls() {
        let ex = Executor::host();
        let a = Matrix::random(32, 4, 9);
        ex.leaf_qr(&a).unwrap();
        let after_first = ex.workspace_stats();
        for _ in 0..10 {
            ex.leaf_r(&a).unwrap();
        }
        let s = ex.workspace_stats();
        assert_eq!(s.created, after_first.created, "steady state must not create workspaces");
        assert_eq!(s.reused, after_first.reused + 10);
    }

    #[test]
    fn warm_workspaces_preallocates() {
        let ex = Executor::host();
        ex.warm_workspaces(2, 32, 4);
        let s0 = ex.workspace_stats();
        assert_eq!(s0.created, 2);
        ex.leaf_r(&Matrix::random(32, 4, 3)).unwrap();
        let s1 = ex.workspace_stats();
        assert_eq!(s1.created, 2, "warmed pool serves the call");
        assert_eq!(s1.reused, 1);
    }

    #[test]
    fn checksum_ops_roundtrip_through_the_dispatch() {
        // A leaf panel split into row blocks: encode a plain-sum
        // checksum, lose one block, reconstruct it through the same
        // &dyn Kernel dispatch the factor/update ops use.
        let ex = Executor::host();
        let blocks: Vec<Matrix> = (0..3).map(|s| Matrix::random(8, 4, s)).collect();
        let refs: Vec<&Matrix> = blocks.iter().collect();
        let weights = Matrix::from_vec(1, 3, vec![1.0, 1.0, 1.0]);
        let sum = ex.encode_checksum(&weights, &refs).unwrap();
        assert_eq!(sum.shape(), (8, 4));
        let got = ex
            .reconstruct_block(&weights, &sum, &[&blocks[0], &blocks[2]])
            .unwrap();
        assert!(got.max_abs_diff(&blocks[1]) < 1e-5, "lost row block must reconstruct");
        // Pooled scratch: steady state creates nothing.
        let before = ex.workspace_stats();
        for _ in 0..4 {
            ex.encode_checksum(&weights, &refs).unwrap();
        }
        assert_eq!(ex.workspace_stats().created, before.created);
        assert_eq!(ex.workspace_stats().reused, before.reused + 4);
    }

    #[test]
    fn backend_plan_defaults_to_host_and_is_builder_settable() {
        let ex = Executor::host();
        assert_eq!(*ex.backend_plan(), BackendPlan::host());
        let ex = ex.with_backend_plan(BackendPlan::threaded());
        assert!(ex.backend_plan().uses_threaded());
    }

    #[test]
    fn threaded_plan_keeps_bitwise_ops_bitwise_through_the_executor() {
        // Same inputs through both plans: the Bitwise-contract ops must
        // agree to the bit (and in debug builds the dispatch itself
        // re-checks this against the host oracle).
        let host = Executor::host();
        let thr = Executor::host().with_backend_plan(BackendPlan::threaded());
        let a = Matrix::random(48, 8, 11);
        let f = host.leaf_qr(&a).unwrap();
        let block = Matrix::random(48, 9, 12);
        let want = host.apply_update(&f, &block).unwrap();
        let got = thr.apply_update(&f, &block).unwrap();
        assert_eq!(got, want, "ApplyUpdate is Bitwise across plans");
        let wq = host.build_q(&f).unwrap();
        let gq = thr.build_q(&f).unwrap();
        assert_eq!(gq, wq, "BuildQ is Bitwise across plans");
    }

    #[test]
    fn threaded_plan_factorizations_hold_their_tolerance() {
        let host = Executor::host();
        let thr = Executor::host().with_backend_plan(BackendPlan::threaded());
        let a = Matrix::random(64, 12, 13);
        let fr_host = host.leaf_qr(&a).unwrap();
        let fr_thr = thr.leaf_qr(&a).unwrap();
        let bound = KernelOp::LeafQr.contract().bound(12, a.fro_norm());
        let diff = fr_thr.r.canonicalize_r().max_abs_diff(&fr_host.r.canonicalize_r());
        assert!(diff <= bound, "LeafQr diff {diff} > bound {bound}");
        // The threaded factorization interoperates with the (host)
        // apply kernels: Q·R reconstructs A.
        let q = thr.build_q(&fr_thr).unwrap();
        assert!(q.matmul(&fr_thr.r).rel_fro_err(&a) < 1e-5);
    }

    #[test]
    fn per_op_override_routes_only_that_op() {
        let plan = BackendPlan::host().with_op(KernelOp::EncodeChecksum, BackendChoice::Threaded);
        let ex = Executor::host().with_backend_plan(plan);
        assert_eq!(ex.backend_plan().select(KernelOp::EncodeChecksum), BackendChoice::Threaded);
        assert_eq!(ex.backend_plan().select(KernelOp::LeafQr), BackendChoice::Host);
        let blocks: Vec<Matrix> = (0..3).map(|s| Matrix::random(8, 4, s)).collect();
        let refs: Vec<&Matrix> = blocks.iter().collect();
        let weights = Matrix::from_vec(1, 3, vec![1.0, 2.0, 4.0]);
        let got = ex.encode_checksum(&weights, &refs).unwrap();
        let want = Executor::host().encode_checksum(&weights, &refs).unwrap();
        assert_eq!(got, want, "EncodeChecksum is Bitwise under the override");
    }

    #[test]
    fn executor_clones_share_the_pool() {
        let ex = Executor::host();
        let ex2 = ex.clone();
        ex.leaf_r(&Matrix::random(16, 4, 1)).unwrap();
        ex2.leaf_r(&Matrix::random(16, 4, 2)).unwrap();
        let s = ex.workspace_stats();
        assert_eq!(s.created, 1, "second call reuses the clone-shared workspace");
        assert_eq!(s.reused, 1);
    }
}
