//! Typed kernel entry points over the PJRT service, with a host-linalg
//! fallback for shapes outside the AOT manifest.
//!
//! Every simulated process holds a cheap `Executor` clone and calls
//! `leaf_qr` / `combine` / ... — it never sees HLO files or literals.
//! Dispatch policy (`Backend`):
//!   * `Pjrt` — artifacts only; error if a shape is missing (strict mode
//!     for the integration tests and benches).
//!   * `Host` — pure-rust Householder path (no artifacts needed).
//!   * `Auto` — PJRT when the manifest has the shape, host otherwise
//!     (the default for examples: works out of the box, accelerates
//!     when `make artifacts` has run).

use std::sync::Arc;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};
use crate::linalg::{Matrix, PackedQr, householder_qr};

use super::manifest::Manifest;
use super::service::PjrtService;

/// Which compute path executes kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Pjrt,
    Host,
    Auto,
}

impl std::str::FromStr for Backend {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "pjrt" => Ok(Backend::Pjrt),
            "host" => Ok(Backend::Host),
            "auto" => Ok(Backend::Auto),
            _ => Err(Error::Config(format!("unknown backend '{s}' (pjrt|host|auto)"))),
        }
    }
}

/// Result of a leaf factorization: R plus the implicit-Q representation.
#[derive(Debug, Clone)]
pub struct Factorization {
    pub r: Matrix,
    pub packed: Matrix,
    pub tau: Matrix, // (n, 1)
}

#[derive(Default, Debug)]
pub struct ExecutorStats {
    pub pjrt_calls: AtomicU64,
    pub host_calls: AtomicU64,
}

/// Shared kernel executor. `Clone` is cheap (Arc inside).
#[derive(Clone)]
pub struct Executor {
    service: Option<PjrtService>,
    backend: Backend,
    stats: Arc<ExecutorStats>,
}

impl Executor {
    /// Host-only executor (no artifacts required).
    pub fn host() -> Self {
        Self { service: None, backend: Backend::Host, stats: Arc::default() }
    }

    /// Executor over an artifact directory.  `shards` = PJRT service
    /// threads (see service.rs).
    pub fn with_artifacts(dir: impl AsRef<std::path::Path>, backend: Backend, shards: usize) -> Result<Self> {
        if backend == Backend::Host {
            return Ok(Self::host());
        }
        let manifest = Manifest::load(dir)?;
        let service = PjrtService::start(manifest, shards)?;
        Ok(Self { service: Some(service), backend, stats: Arc::default() })
    }

    /// `Auto` from the conventional `artifacts/` location: PJRT if the
    /// manifest loads, silently host-only otherwise.
    pub fn auto(dir: impl AsRef<std::path::Path>) -> Self {
        // 2 shards measured optimal: each CPU PjRtClient spawns its own
        // internal thread pool, so more shards oversubscribe the cores
        // (see EXPERIMENTS.md §Perf for the sweep).
        Self::with_artifacts(dir, Backend::Auto, 2).unwrap_or_else(|_| Self::host())
    }

    pub fn backend(&self) -> Backend {
        self.backend
    }

    pub fn stats(&self) -> &ExecutorStats {
        &self.stats
    }

    /// True if this executor has a live PJRT service.
    pub fn has_pjrt(&self) -> bool {
        self.service.is_some()
    }

    fn dispatch_pjrt(&self, entry: &str) -> Option<&PjrtService> {
        let svc = self.service.as_ref()?;
        match self.backend {
            Backend::Host => None,
            Backend::Pjrt => Some(svc),
            Backend::Auto => {
                if svc.manifest().get(entry).is_some() {
                    Some(svc)
                } else {
                    None
                }
            }
        }
    }

    fn host_guard(&self, entry: &str) -> Result<()> {
        if self.backend == Backend::Pjrt {
            return Err(Error::Artifacts(format!(
                "backend=pjrt but no artifact for entry '{entry}' — run `make artifacts` or use auto/host"
            )));
        }
        self.stats.host_calls.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// TSQR leaf: factor the local (m, n) panel.
    pub fn leaf_qr(&self, a: &Matrix) -> Result<Factorization> {
        let (m, n) = a.shape();
        let entry = Manifest::leaf_qr_name(m, n);
        if let Some(svc) = self.dispatch_pjrt(&entry) {
            self.stats.pjrt_calls.fetch_add(1, Ordering::Relaxed);
            let mut out = svc.execute(&entry, vec![a.clone()])?;
            let tau = out.pop().expect("arity 3");
            let packed = out.pop().expect("arity 3");
            let r = out.pop().expect("arity 3");
            return Ok(Factorization { r, packed, tau });
        }
        self.host_guard(&entry)?;
        let f = host_factorization(a);
        Ok(f)
    }

    /// Hot path: just the R̃ of the local panel — the only thing the
    /// coordinator ships between buddies.  Uses the R-only AOT variant
    /// when available (saves the packed/tau device→host transfers; see
    /// EXPERIMENTS.md §Perf), falling back to the full entry, then to
    /// the host path.
    pub fn leaf_r(&self, a: &Matrix) -> Result<Matrix> {
        let (m, n) = a.shape();
        let entry = Manifest::leaf_r_name(m, n);
        if let Some(svc) = self.dispatch_pjrt(&entry) {
            self.stats.pjrt_calls.fetch_add(1, Ordering::Relaxed);
            let mut out = svc.execute(&entry, vec![a.clone()])?;
            return Ok(out.pop().expect("arity 1"));
        }
        if self.backend == Backend::Pjrt || self.dispatch_pjrt(&Manifest::leaf_qr_name(m, n)).is_some()
        {
            return Ok(self.leaf_qr(a)?.r);
        }
        self.host_guard(&entry)?;
        Ok(crate::linalg::householder_qr(a).r())
    }

    /// Hot path: just the R̃ of the stacked [r_top; r_bot] combine.
    pub fn combine_r(&self, r_top: &Matrix, r_bot: &Matrix) -> Result<Matrix> {
        let n = r_top.cols();
        let entry = Manifest::combine_r_name(n);
        if let Some(svc) = self.dispatch_pjrt(&entry) {
            self.stats.pjrt_calls.fetch_add(1, Ordering::Relaxed);
            let mut out = svc.execute(&entry, vec![r_top.clone(), r_bot.clone()])?;
            return Ok(out.pop().expect("arity 1"));
        }
        if self.backend == Backend::Pjrt || self.dispatch_pjrt(&Manifest::combine_name(n)).is_some()
        {
            return Ok(self.combine(r_top, r_bot)?.r);
        }
        self.host_guard(&entry)?;
        Ok(crate::linalg::householder_qr(&r_top.vstack(r_bot)).r())
    }

    /// TSQR combine: QR of [r_top; r_bot] (both n×n upper triangular).
    pub fn combine(&self, r_top: &Matrix, r_bot: &Matrix) -> Result<Factorization> {
        let n = r_top.cols();
        let entry = Manifest::combine_name(n);
        if let Some(svc) = self.dispatch_pjrt(&entry) {
            self.stats.pjrt_calls.fetch_add(1, Ordering::Relaxed);
            let mut out = svc.execute(&entry, vec![r_top.clone(), r_bot.clone()])?;
            let tau = out.pop().expect("arity 3");
            let packed = out.pop().expect("arity 3");
            let r = out.pop().expect("arity 3");
            return Ok(Factorization { r, packed, tau });
        }
        self.host_guard(&entry)?;
        Ok(host_factorization(&r_top.vstack(r_bot)))
    }

    /// Solve R x = b (R upper triangular n×n, b n×k).
    pub fn backsolve(&self, r: &Matrix, b: &Matrix) -> Result<Matrix> {
        let entry = Manifest::backsolve_name(r.rows(), b.cols());
        if let Some(svc) = self.dispatch_pjrt(&entry) {
            self.stats.pjrt_calls.fetch_add(1, Ordering::Relaxed);
            let mut out = svc.execute(&entry, vec![r.clone(), b.clone()])?;
            return Ok(out.pop().expect("arity 1"));
        }
        self.host_guard(&entry)?;
        Ok(crate::linalg::backsolve(r, b))
    }

    /// Qᵀ @ b from a packed factorization.
    pub fn apply_qt(&self, f: &Factorization, b: &Matrix) -> Result<Matrix> {
        let (m, n) = f.packed.shape();
        let entry = Manifest::apply_qt_name(m, n, b.cols());
        if let Some(svc) = self.dispatch_pjrt(&entry) {
            self.stats.pjrt_calls.fetch_add(1, Ordering::Relaxed);
            let mut out =
                svc.execute(&entry, vec![f.packed.clone(), f.tau.clone(), b.clone()])?;
            return Ok(out.pop().expect("arity 1"));
        }
        self.host_guard(&entry)?;
        Ok(packed_of(f).apply_qt(b))
    }

    /// Materialize the thin Q of a packed factorization.
    pub fn build_q(&self, f: &Factorization) -> Result<Matrix> {
        let (m, n) = f.packed.shape();
        let entry = Manifest::build_q_name(m, n);
        if let Some(svc) = self.dispatch_pjrt(&entry) {
            self.stats.pjrt_calls.fetch_add(1, Ordering::Relaxed);
            let mut out = svc.execute(&entry, vec![f.packed.clone(), f.tau.clone()])?;
            return Ok(out.pop().expect("arity 1"));
        }
        self.host_guard(&entry)?;
        Ok(packed_of(f).q())
    }
}

fn packed_of(f: &Factorization) -> PackedQr {
    PackedQr { packed: f.packed.clone(), tau: f.tau.data().to_vec() }
}

fn host_factorization(a: &Matrix) -> Factorization {
    let f = householder_qr(a);
    let n = a.cols();
    Factorization {
        r: f.packed.row_block(0, n).triu(),
        tau: Matrix::from_vec(n, 1, f.tau.clone()),
        packed: f.packed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_executor_leaf_and_combine() {
        let ex = Executor::host();
        let a = Matrix::random(32, 4, 1);
        let f = ex.leaf_qr(&a).unwrap();
        assert_eq!(f.r.shape(), (4, 4));
        assert!(f.r.is_upper_triangular(1e-6));
        let q = ex.build_q(&f).unwrap();
        let recon = q.matmul(&f.r);
        assert!(recon.rel_fro_err(&a) < 1e-5);

        let g = ex.combine(&f.r, &f.r).unwrap();
        assert_eq!(g.r.shape(), (4, 4));
        assert!(g.r.is_upper_triangular(1e-6));
        assert_eq!(ex.stats().host_calls.load(Ordering::Relaxed), 3);
        assert_eq!(ex.stats().pjrt_calls.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn host_backsolve_and_apply_qt() {
        let ex = Executor::host();
        let a = Matrix::random(24, 4, 5);
        let xt = Matrix::random(4, 1, 6);
        let b = a.matmul(&xt);
        let f = ex.leaf_qr(&a).unwrap();
        let qtb = ex.apply_qt(&f, &b).unwrap();
        let x = ex.backsolve(&f.r, &qtb.row_block(0, 4)).unwrap();
        assert!(x.max_abs_diff(&xt) < 1e-2);
    }

    #[test]
    fn pjrt_strict_errors_without_artifacts() {
        // Backend::Pjrt with a host-only executor is a config error path.
        let ex = Executor { service: None, backend: Backend::Pjrt, stats: Arc::default() };
        let err = ex.leaf_qr(&Matrix::zeros(8, 4)).unwrap_err();
        assert!(matches!(err, Error::Artifacts(_)));
    }

    #[test]
    fn backend_parses() {
        assert_eq!("pjrt".parse::<Backend>().unwrap(), Backend::Pjrt);
        assert_eq!("host".parse::<Backend>().unwrap(), Backend::Host);
        assert_eq!("auto".parse::<Backend>().unwrap(), Backend::Auto);
        assert!("gpu".parse::<Backend>().is_err());
    }
}
