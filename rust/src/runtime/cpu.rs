//! Host CPU introspection and the engine-wide parallelism knob.
//!
//! [`CpuInfo`] is detected once at `EngineBuilder::build()` and stamped
//! into every `BENCH_*.json` the report layer writes: the perf gate
//! (`report::bench::regress_check`) only hard-fails a drop when the
//! baseline's [`CpuInfo::fingerprint`] matches the current host — a
//! GFLOP/s number measured on one machine is not a contract for a
//! different one.
//!
//! [`Parallelism`] is the single struct the `--threads` CLI knob flows
//! through: CLI/config → `EngineBuilder` → `Engine` → `CaqrSpec` → the
//! GEMM slab scheduler ([`crate::linalg::gemm::gemm_into_pooled`]) and
//! the trailing-update fan-out in `caqr::exec`.  `threads = 1` is the
//! sequential path itself (not merely equivalent to it), so the
//! historical bit-level behaviour is preserved exactly.

use crate::linalg::gemm::Isa;

/// Degree of intra-task parallelism for the kernel layer.
///
/// One value, threaded everywhere — prewarmed pool workers and GEMM
/// slab fan-out always agree.  Every thread count produces bitwise
/// identical results (see [`crate::linalg::gemm`]); this knob trades
/// wall-clock only.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    threads: usize,
}

impl Parallelism {
    /// Sequential execution (the default; bit-identical to every other
    /// setting, but uses no pool workers inside a kernel call).
    pub fn single() -> Parallelism {
        Parallelism { threads: 1 }
    }

    /// `threads`-way parallelism; `0` is the CLI's "unset" and maps to
    /// sequential.
    pub fn new(threads: usize) -> Parallelism {
        Parallelism { threads: threads.max(1) }
    }

    /// Worker threads a pooled GEMM may fan out across (≥ 1).
    pub fn gemm_threads(&self) -> usize {
        self.threads
    }

    /// Does this setting ever dispatch kernel work to the pool?
    pub fn is_parallel(&self) -> bool {
        self.threads > 1
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Parallelism::single()
    }
}

/// What the engine learned about the host at build time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CpuInfo {
    /// Human-readable CPU model (`/proc/cpuinfo` "model name", or the
    /// architecture when unavailable).
    pub model: String,
    /// Target architecture (`x86_64`, `aarch64`, …).
    pub arch: &'static str,
    /// Microkernel path the GEMM dispatcher selected for this process
    /// (post `FT_GEMM_ISA` override).
    pub isa: Isa,
    /// Runtime-detected SIMD features relevant to the kernel layer.
    pub features: Vec<&'static str>,
    /// Hardware threads available to this process.
    pub threads: usize,
}

impl CpuInfo {
    /// Detect the current host (cheap; feature probes are cached by
    /// `std`).
    pub fn detect() -> CpuInfo {
        CpuInfo {
            model: cpu_model(),
            arch: std::env::consts::ARCH,
            isa: Isa::detect(),
            features: detected_features(),
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        }
    }

    /// The process-wide cached detection (one `/proc` read per process;
    /// `EngineBuilder::build` warms it so every engine shares it).
    pub fn cached() -> &'static CpuInfo {
        static CACHED: std::sync::OnceLock<CpuInfo> = std::sync::OnceLock::new();
        CACHED.get_or_init(CpuInfo::detect)
    }

    /// Stable like-for-like identity for baseline comparison: two runs
    /// with equal fingerprints ran on comparable hardware with the same
    /// kernel dispatch.  Format: `arch|model|features|Nt`.
    pub fn fingerprint(&self) -> String {
        format!("{}|{}|{}|{}t", self.arch, self.model, self.features.join("+"), self.threads)
    }

    /// One-line human summary for bench logs and the CI perf gate.
    pub fn summary(&self) -> String {
        format!(
            "{} ({}, isa={}, features=[{}], {} threads)",
            self.model,
            self.arch,
            self.isa.name(),
            self.features.join(", "),
            self.threads
        )
    }
}

/// Best-effort CPU model string.
fn cpu_model() -> String {
    if let Ok(text) = std::fs::read_to_string("/proc/cpuinfo") {
        for line in text.lines() {
            // x86 uses "model name", aarch64 often only "CPU part".
            if let Some(rest) = line.strip_prefix("model name") {
                if let Some((_, v)) = rest.split_once(':') {
                    let v = v.trim();
                    if !v.is_empty() {
                        return v.to_string();
                    }
                }
            }
        }
    }
    std::env::consts::ARCH.to_string()
}

/// The SIMD features the kernel layer cares about, in a fixed order so
/// fingerprints compare stably.
fn detected_features() -> Vec<&'static str> {
    let mut f = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") {
            f.push("avx2");
        }
        if std::is_x86_feature_detected!("fma") {
            f.push("fma");
        }
        if std::is_x86_feature_detected!("avx512f") {
            f.push("avx512f");
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            f.push("neon");
        }
    }
    if f.is_empty() {
        f.push("baseline");
    }
    f
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallelism_defaults_and_clamps() {
        assert_eq!(Parallelism::default(), Parallelism::single());
        assert_eq!(Parallelism::new(0).gemm_threads(), 1, "0 means unset, maps to sequential");
        assert!(!Parallelism::new(1).is_parallel());
        assert!(Parallelism::new(4).is_parallel());
        assert_eq!(Parallelism::new(4).gemm_threads(), 4);
    }

    #[test]
    fn cpu_info_detects_and_fingerprints_stably() {
        let a = CpuInfo::detect();
        let b = CpuInfo::detect();
        assert!(!a.model.is_empty());
        assert!(a.threads >= 1);
        assert!(!a.features.is_empty());
        assert!(a.isa.usable(), "selected ISA must run on this host");
        assert_eq!(a.fingerprint(), b.fingerprint(), "fingerprint is stable within a process");
        assert!(a.fingerprint().contains(a.arch));
        assert!(a.summary().contains(a.isa.name()));
    }
}
