//! The AOT artifact manifest — the contract between `python/compile/aot.py`
//! and the rust runtime.  The runtime never hard-codes shapes; everything
//! it knows about the artifact set comes from `artifacts/manifest.json`
//! (parsed with the in-tree JSON parser, `util::json`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::Json;

/// One AOT entry point (`leaf_qr_256x8`, `combine_16`, ...).
#[derive(Debug, Clone)]
pub struct Entry {
    /// Entry-point name (`leaf_qr_256x8`, ...).
    pub name: String,
    /// Kind tag: `leaf_qr` | `combine` | `backsolve` | `apply_qt` | `build_q`.
    pub kind: String,
    /// Shape parameters (m, n, k as applicable).
    pub params: HashMap<String, usize>,
    /// HLO-text file name, relative to the artifact dir.
    pub file: String,
    /// Input shapes, outer-to-inner.
    pub inputs: Vec<Vec<usize>>,
    /// Number of results in the output tuple.
    pub out_arity: usize,
}

impl Entry {
    /// Shape parameter by name (`m`, `n`, `k`).
    pub fn param(&self, key: &str) -> Option<usize> {
        self.params.get(key).copied()
    }

    fn from_json(j: &Json) -> Result<Entry> {
        let bad = |what: &str| Error::Artifacts(format!("manifest entry missing/invalid {what}"));
        let name = j.get("name").and_then(Json::as_str).ok_or_else(|| bad("name"))?.to_string();
        let kind = j.get("kind").and_then(Json::as_str).ok_or_else(|| bad("kind"))?.to_string();
        let file = j.get("file").and_then(Json::as_str).ok_or_else(|| bad("file"))?.to_string();
        let out_arity =
            j.get("out_arity").and_then(Json::as_usize).ok_or_else(|| bad("out_arity"))?;
        let mut params = HashMap::new();
        for (k, v) in j.get("params").and_then(Json::as_obj).ok_or_else(|| bad("params"))? {
            params.insert(k.clone(), v.as_usize().ok_or_else(|| bad("params"))?);
        }
        let mut inputs = Vec::new();
        for shape in j.get("inputs").and_then(Json::as_arr).ok_or_else(|| bad("inputs"))? {
            let dims: Option<Vec<usize>> =
                shape.as_arr().map(|a| a.iter().filter_map(Json::as_usize).collect());
            let dims = dims.ok_or_else(|| bad("inputs"))?;
            inputs.push(dims);
        }
        Ok(Entry { name, kind, params, file, inputs, out_arity })
    }
}

/// Parsed manifest plus the directory it was loaded from.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// The artifact directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Element dtype of every artifact (`f32`).
    pub dtype: String,
    entries: HashMap<String, Entry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Artifacts(format!("cannot read {}: {e}", path.display())))?;
        let j = Json::parse(&text)?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Artifacts("manifest missing dtype".into()))?
            .to_string();
        let mut entries = HashMap::new();
        for e in j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Artifacts("manifest missing entries".into()))?
        {
            let entry = Entry::from_json(e)?;
            entries.insert(entry.name.clone(), entry);
        }
        Ok(Self { dir, dtype, entries })
    }

    /// Look up an entry point by exact name.
    pub fn get(&self, name: &str) -> Option<&Entry> {
        self.entries.get(name)
    }

    /// Number of entry points.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the manifest carries no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// All entry-point names (unordered).
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, entry: &Entry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// Canonical `leaf_qr_{m}x{n}` entry name (must match `aot.py`).
    pub fn leaf_qr_name(m: usize, n: usize) -> String {
        format!("leaf_qr_{m}x{n}")
    }
    /// Canonical `leaf_r_{m}x{n}` entry name.
    pub fn leaf_r_name(m: usize, n: usize) -> String {
        format!("leaf_r_{m}x{n}")
    }
    /// Canonical `combine_r_{n}` entry name.
    pub fn combine_r_name(n: usize) -> String {
        format!("combine_r_{n}")
    }
    /// Canonical `combine_{n}` entry name.
    pub fn combine_name(n: usize) -> String {
        format!("combine_{n}")
    }
    /// Canonical `backsolve_{n}x{k}` entry name.
    pub fn backsolve_name(n: usize, k: usize) -> String {
        format!("backsolve_{n}x{k}")
    }
    /// Canonical `apply_qt_{m}x{n}x{k}` entry name.
    pub fn apply_qt_name(m: usize, n: usize, k: usize) -> String {
        format!("apply_qt_{m}x{n}x{k}")
    }
    /// `apply_update_{m}x{n}x{k}` — the CAQR trailing-update kernel.
    pub fn apply_update_name(m: usize, n: usize, k: usize) -> String {
        format!("apply_update_{m}x{n}x{k}")
    }
    /// `build_t_{m}x{n}` — the compact-WY T-factor kernel.
    pub fn build_t_name(m: usize, n: usize) -> String {
        format!("build_t_{m}x{n}")
    }
    /// `apply_wy_{m}x{n}x{k}` — the compact-WY trailing-update kernel.
    pub fn apply_wy_name(m: usize, n: usize, k: usize) -> String {
        format!("apply_wy_{m}x{n}x{k}")
    }
    /// Canonical `build_q_{m}x{n}` entry name.
    pub fn build_q_name(m: usize, n: usize) -> String {
        format!("build_q_{m}x{n}")
    }
    /// `apply_q_wy_{m}x{n}x{k}` — the compact-WY *forward* (Q-side)
    /// apply kernel used by coded Q assembly.
    pub fn apply_q_wy_name(m: usize, n: usize, k: usize) -> String {
        format!("apply_q_wy_{m}x{n}x{k}")
    }
    /// `build_q_panel_{m}x{n}x{k}` — materialize a `k`-column shard of
    /// the explicit Q from one packed panel + T factor.
    pub fn build_q_panel_name(m: usize, n: usize, k: usize) -> String {
        format!("build_q_panel_{m}x{n}x{k}")
    }
    /// `encode_checksum_{m}x{k}x{b}` — the ABFT checksum-encode kernel
    /// (`m` rows, `k` padded columns, `b` data blocks).
    pub fn encode_checksum_name(m: usize, k: usize, b: usize) -> String {
        format!("encode_checksum_{m}x{k}x{b}")
    }
    /// `reconstruct_block_{m}x{k}x{b}` — the ABFT single-loss
    /// reconstruction kernel (`m` rows, `k` padded columns, `b` data
    /// blocks counting the lost one).
    pub fn reconstruct_block_name(m: usize, k: usize, b: usize) -> String {
        format!("reconstruct_block_{m}x{k}x{b}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TestDir;

    #[test]
    fn loads_and_indexes_entries() {
        let tmp = TestDir::new();
        tmp.write(
            "manifest.json",
            r#"{"dtype":"f32","entries":[
                {"name":"leaf_qr_64x4","kind":"leaf_qr","params":{"m":64,"n":4},
                 "file":"leaf_qr_64x4.hlo.txt","inputs":[[64,4]],"out_arity":3}
            ]}"#,
        );
        let m = Manifest::load(tmp.path()).unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(m.dtype, "f32");
        let e = m.get("leaf_qr_64x4").unwrap();
        assert_eq!(e.kind, "leaf_qr");
        assert_eq!(e.param("m"), Some(64));
        assert_eq!(e.out_arity, 3);
        assert_eq!(e.inputs, vec![vec![64, 4]]);
        assert!(m.hlo_path(e).ends_with("leaf_qr_64x4.hlo.txt"));
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn missing_manifest_is_artifacts_error() {
        let tmp = TestDir::new();
        match Manifest::load(tmp.path()) {
            Err(Error::Artifacts(_)) => {}
            other => panic!("expected Artifacts error, got {other:?}"),
        }
    }

    #[test]
    fn malformed_entry_rejected() {
        let tmp = TestDir::new();
        tmp.write("manifest.json", r#"{"dtype":"f32","entries":[{"name":"x"}]}"#);
        assert!(Manifest::load(tmp.path()).is_err());
    }

    #[test]
    fn name_builders_match_aot_convention() {
        assert_eq!(Manifest::leaf_qr_name(256, 8), "leaf_qr_256x8");
        assert_eq!(Manifest::combine_name(16), "combine_16");
        assert_eq!(Manifest::backsolve_name(8, 1), "backsolve_8x1");
        assert_eq!(Manifest::apply_qt_name(64, 8, 1), "apply_qt_64x8x1");
        assert_eq!(Manifest::build_t_name(64, 8), "build_t_64x8");
        assert_eq!(Manifest::apply_wy_name(64, 8, 16), "apply_wy_64x8x16");
        assert_eq!(Manifest::build_q_name(64, 8), "build_q_64x8");
        assert_eq!(Manifest::apply_q_wy_name(64, 8, 16), "apply_q_wy_64x8x16");
        assert_eq!(Manifest::build_q_panel_name(64, 8, 4), "build_q_panel_64x8x4");
    }
}
