//! The kernel call convention shared by every compute backend.
//!
//! One object-safe trait — [`Kernel`] — replaces the ad-hoc per-op
//! entry points: a backend receives a [`KernelCall`] (operation tag,
//! borrowed input [`MatrixView`]s, and a mutable [`Workspace`] for
//! scratch) and returns the freshly produced output matrices.  The
//! [`super::Executor`] owns backend selection and a [`WorkspacePool`]
//! so concurrent simulated ranks reuse scratch arenas instead of
//! allocating per call.
//!
//! Ownership rules (see also `linalg::view`):
//! * inputs are **borrowed** — a kernel never clones a view except to
//!   cross a device boundary (the PJRT backend materializes host
//!   copies because the transfer copies regardless);
//! * scratch belongs to the **workspace**, which the executor acquires
//!   from its pool around each call and returns afterwards;
//! * outputs are **owned** results — the only allocations a host-side
//!   kernel performs.

use std::sync::Mutex;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};
use crate::linalg::{Matrix, MatrixView, Workspace, view};

use super::manifest::Manifest;
use super::service::PjrtService;

/// Which kernel implementation family the compute-heavy paths run.
///
/// * [`Reference`](KernelProfile::Reference) keeps the bitwise-pinned
///   kernels: rank-1 trailing updates whose results are bit-identical
///   to `householder_qr_reference` — the oracle the recovery tests pin.
/// * [`Blocked`](KernelProfile::Blocked) is the compact-WY fast path:
///   trailing updates become two GEMMs through the packed
///   [`crate::linalg::gemm`] microkernel.  Its results differ from the
///   oracle by normal rounding, but every kernel is *deterministic*
///   (fixed summation order), which is all the replica-comparison
///   fault tolerance needs: both buddies run the identical kernel, so
///   recovery still restores the exact bits the dead owner held.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum KernelProfile {
    /// Bitwise-pinned rank-1 kernels (the oracle path).
    #[default]
    Reference,
    /// Compact-WY + GEMM fast path (deterministic, not bit-pinned).
    Blocked,
}

impl KernelProfile {
    /// Stable name (`reference` / `blocked`).
    pub fn name(&self) -> &'static str {
        match self {
            KernelProfile::Reference => "reference",
            KernelProfile::Blocked => "blocked",
        }
    }
}

impl std::fmt::Display for KernelProfile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for KernelProfile {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "reference" | "ref" => Ok(KernelProfile::Reference),
            "blocked" | "wy" => Ok(KernelProfile::Blocked),
            other => Err(Error::Config(format!(
                "unknown kernel profile '{other}' (reference|blocked)"
            ))),
        }
    }
}

/// Which kernel a [`KernelCall`] requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelOp {
    /// Tall-skinny panel factorization → `[r, packed, tau]`.
    LeafQr,
    /// Panel factorization, R only (the exchange hot path) → `[r]`.
    LeafR,
    /// QR of the stacked `[r_top; r_bot]` → `[r, packed, tau]`.
    Combine,
    /// Stacked combine, R only (the exchange hot path) → `[r]`.
    CombineR,
    /// Upper-triangular solve `R x = b` → `[x]`.
    Backsolve,
    /// `Qᵀ b` from a packed factorization → `[qtb]`.
    ApplyQt,
    /// CAQR trailing-matrix update: apply a packed panel's reflectors
    /// to a trailing block with f64 workspace accumulation and a
    /// single rounding → `[updated_block]` (see
    /// [`crate::linalg::view::apply_update_into`]).
    ApplyUpdate,
    /// Build the compact-WY T factor of a packed panel → `[t]` (see
    /// [`crate::linalg::view::build_t_into`]).
    BuildT,
    /// Compact-WY trailing update: two GEMMs instead of n rank-1
    /// sweeps → `[updated_block]` (see
    /// [`crate::linalg::view::apply_wy_into`]).  The
    /// [`KernelProfile::Blocked`] sibling of [`ApplyUpdate`](Self::ApplyUpdate).
    ApplyWy,
    /// Materialize the thin Q of a packed factorization → `[q]`.
    BuildQ,
    /// Compact-WY **forward** apply `Q·C` (T untransposed) → `[q_block]`
    /// (views: `[packed, t (n×n), block]`; see
    /// [`crate::linalg::view::apply_wy_forward_into`]).  The Q-side
    /// sibling of [`ApplyWy`](Self::ApplyWy), used by coded Q assembly.
    ApplyQWy,
    /// Materialize one column shard of the explicit Q from a packed
    /// panel → `[q_shard]` (views: `[packed, t (n×n), params
    /// (1×width)]` where `params[0,0]` carries the shard's first global
    /// column as an f32).  The kernel seeds the identity shard itself —
    /// callers never allocate the `E_j` operand.
    BuildQPanel,
    /// ABFT: encode one Vandermonde-weighted checksum block over `N`
    /// data blocks → `[checksum]` (views: `[weights (1×N), block_0,
    /// …, block_{N−1}]`; see
    /// [`crate::abft::kernels::encode_checksum_into`]).
    EncodeChecksum,
    /// ABFT: reconstruct one lost block from one checksum and the
    /// `N − 1` survivors → `[block]` (views: `[weights (1×N,
    /// lost-first), checksum, survivor_0, …]`; see
    /// [`crate::abft::kernels::reconstruct_block_into`]).  Multi-loss
    /// solves run coordinator-side through [`crate::abft::Encoder`].
    ReconstructBlock,
}

/// The cross-backend agreement contract of one [`KernelOp`] — what a
/// differential harness (and the debug-build dispatch check in
/// [`super::Executor`]) may assert when two backends run the same call.
///
/// * [`Bitwise`](Contract::Bitwise): every output matrix is
///   bit-identical across backends.  This is the contract replica
///   recovery rests on — a surviving replica's bits *are* the dead
///   owner's bits — so any op whose threaded implementation merely
///   re-partitions independent per-column/per-element arithmetic (or
///   delegates to the identical sequential kernel) declares it.
/// * [`Tolerance`](Contract::Tolerance): backends may reassociate
///   floating-point reductions (e.g. chunked partial sums inside a
///   pool-parallel factorization), so only the mathematically unique
///   output — the canonicalized R factor, `outputs[0]` — is compared,
///   within `c·n·ε_f32·max(1, ‖A‖_F)`.  The packed reflectors and tau
///   are backend-private under this contract.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Contract {
    /// Outputs are bit-identical across backends.
    Bitwise,
    /// `outputs[0]`, canonicalized, agrees within `c·n·ε_f32·max(1, ‖A‖_F)`.
    Tolerance {
        /// The dimensionless constant `c` in the bound.
        c: f64,
    },
}

impl Contract {
    /// The concrete comparison bound for a problem of column count `n`
    /// and input magnitude `norm` (Frobenius).  [`Bitwise`](Self::Bitwise)
    /// returns `0.0` — nothing but exact equality passes.
    pub fn bound(&self, n: usize, norm: f64) -> f64 {
        match self {
            Contract::Bitwise => 0.0,
            Contract::Tolerance { c } => c * n as f64 * f32::EPSILON as f64 * norm.max(1.0),
        }
    }
}

/// Element precision of the CAQR compute path.
///
/// [`F32`](Precision::F32) rounds every task-grid intermediate (panel
/// factors, trailing updates, Q chains) to `f32` while the ABFT
/// checksum arithmetic **stays f64** — the coded-reconstruction
/// guarantee (arXiv:0806.3121) only holds when checksums carry more
/// precision than the data they protect.  [`F64`](Precision::F64) is
/// the historical path and is byte-identical to pre-precision builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Precision {
    /// f32 task-grid intermediates, f64 checksums (mixed precision).
    F32,
    /// Full f64 task grid (the bitwise-pinned default).
    #[default]
    F64,
}

impl Precision {
    /// Stable name (`f32` / `f64`).
    pub fn name(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F64 => "f64",
        }
    }

    /// Is this the mixed-precision (f32 data) path?
    pub fn is_f32(&self) -> bool {
        matches!(self, Precision::F32)
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Precision {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "f32" | "single" | "mixed" => Ok(Precision::F32),
            "f64" | "double" => Ok(Precision::F64),
            other => Err(Error::Config(format!("unknown precision '{other}' (f32|f64)"))),
        }
    }
}

impl KernelOp {
    /// Every operation, in declaration order — the iteration basis of
    /// the differential conformance suite (`tests/backend_conformance.rs`)
    /// and the exhaustive classification tests below.
    pub const ALL: [KernelOp; 14] = [
        KernelOp::LeafQr,
        KernelOp::LeafR,
        KernelOp::Combine,
        KernelOp::CombineR,
        KernelOp::Backsolve,
        KernelOp::ApplyQt,
        KernelOp::ApplyUpdate,
        KernelOp::BuildT,
        KernelOp::ApplyWy,
        KernelOp::BuildQ,
        KernelOp::ApplyQWy,
        KernelOp::BuildQPanel,
        KernelOp::EncodeChecksum,
        KernelOp::ReconstructBlock,
    ];

    /// The declared Host-vs-Threaded agreement contract of this op —
    /// the table `tests/backend_conformance.rs` pins and the executor
    /// enforces at dispatch in debug builds.
    ///
    /// Factorizations are [`Contract::Tolerance`]: the threaded
    /// backend reassociates its reduction sums (fixed-size chunked
    /// partial sums, deterministic for any worker count, but a
    /// different association than the sequential host kernel).  Every
    /// other op is [`Contract::Bitwise`]: the threaded implementation
    /// either fans out arithmetic that is independent per column /
    /// element (slab re-partitioning cannot change any bit) or runs
    /// the identical sequential kernel.
    pub fn contract(&self) -> Contract {
        match self {
            KernelOp::LeafQr
            | KernelOp::LeafR
            | KernelOp::Combine
            | KernelOp::CombineR => Contract::Tolerance { c: 64.0 },
            KernelOp::Backsolve
            | KernelOp::ApplyQt
            | KernelOp::ApplyUpdate
            | KernelOp::BuildT
            | KernelOp::ApplyWy
            | KernelOp::BuildQ
            | KernelOp::ApplyQWy
            | KernelOp::BuildQPanel
            | KernelOp::EncodeChecksum
            | KernelOp::ReconstructBlock => Contract::Bitwise,
        }
    }

    /// The AOT manifest entry this call maps to, derived from the input
    /// view shapes (one naming scheme for every backend).
    pub fn entry_name(&self, views: &[MatrixView<'_>]) -> String {
        match self {
            KernelOp::LeafQr => Manifest::leaf_qr_name(views[0].rows(), views[0].cols()),
            KernelOp::LeafR => Manifest::leaf_r_name(views[0].rows(), views[0].cols()),
            KernelOp::Combine => Manifest::combine_name(views[0].cols()),
            KernelOp::CombineR => Manifest::combine_r_name(views[0].cols()),
            KernelOp::Backsolve => Manifest::backsolve_name(views[0].rows(), views[1].cols()),
            KernelOp::ApplyQt => {
                Manifest::apply_qt_name(views[0].rows(), views[0].cols(), views[2].cols())
            }
            KernelOp::ApplyUpdate => {
                Manifest::apply_update_name(views[0].rows(), views[0].cols(), views[2].cols())
            }
            KernelOp::BuildT => Manifest::build_t_name(views[0].rows(), views[0].cols()),
            KernelOp::ApplyWy => {
                Manifest::apply_wy_name(views[0].rows(), views[0].cols(), views[2].cols())
            }
            KernelOp::BuildQ => Manifest::build_q_name(views[0].rows(), views[0].cols()),
            KernelOp::ApplyQWy => {
                Manifest::apply_q_wy_name(views[0].rows(), views[0].cols(), views[2].cols())
            }
            KernelOp::BuildQPanel => {
                Manifest::build_q_panel_name(views[0].rows(), views[0].cols(), views[2].cols())
            }
            KernelOp::EncodeChecksum => Manifest::encode_checksum_name(
                views[1].rows(),
                views[1].cols(),
                views.len() - 1,
            ),
            KernelOp::ReconstructBlock => Manifest::reconstruct_block_name(
                views[1].rows(),
                views[1].cols(),
                views.len() - 1,
            ),
        }
    }
}

/// One kernel invocation: operation, borrowed inputs, scratch arena.
pub struct KernelCall<'call> {
    /// Which kernel to run.
    pub op: KernelOp,
    /// Borrowed inputs, in manifest order.
    pub views: &'call [MatrixView<'call>],
    /// Scratch arena (pooled by the executor).
    pub workspace: &'call mut Workspace,
}

/// Object-safe backend interface: Host and PJRT implement the same
/// call convention, so dispatch is one `&dyn Kernel` decision instead
/// of per-op branching.
pub trait Kernel: Send + Sync {
    /// Stable backend name (`host` / `pjrt`).
    fn name(&self) -> &'static str;
    /// Whether this backend consumes [`KernelCall::workspace`] for the
    /// given op — lets the executor skip pool traffic for ops (or
    /// backends) that use no scratch.
    fn wants_workspace(&self, op: KernelOp) -> bool;
    /// Execute the call, returning the output matrices in manifest
    /// order (e.g. `[r, packed, tau]` for factorizations).
    fn execute(&self, call: KernelCall<'_>) -> Result<Vec<Matrix>>;
}

/// Pure-rust backend over the blocked view kernels in `linalg::view`.
#[derive(Debug, Default, Clone, Copy)]
pub struct HostKernel;

impl Kernel for HostKernel {
    fn name(&self) -> &'static str {
        "host"
    }

    fn wants_workspace(&self, op: KernelOp) -> bool {
        // Factorizations, the CAQR trailing updates (rank-1 and
        // compact-WY), the T build, and the ABFT checksum ops run
        // through the f64 scratch arena (the WY ops additionally draw
        // their GEMM packing buffers from it); the solve/apply kernels
        // work in place on their outputs.  Exhaustive on purpose:
        // adding a KernelOp without classifying its scratch behaviour
        // must fail to compile, not silently default at runtime.
        match op {
            KernelOp::LeafQr
            | KernelOp::LeafR
            | KernelOp::Combine
            | KernelOp::CombineR
            | KernelOp::ApplyUpdate
            | KernelOp::BuildT
            | KernelOp::ApplyWy
            | KernelOp::ApplyQWy
            | KernelOp::BuildQPanel
            | KernelOp::EncodeChecksum
            | KernelOp::ReconstructBlock => true,
            KernelOp::Backsolve | KernelOp::ApplyQt | KernelOp::BuildQ => false,
        }
    }

    fn execute(&self, call: KernelCall<'_>) -> Result<Vec<Matrix>> {
        let v = call.views;
        let ws = call.workspace;
        match call.op {
            KernelOp::LeafQr => {
                let (m, n) = v[0].shape();
                let mut packed = Matrix::zeros(m, n);
                let mut tau = vec![0.0f32; n];
                view::householder_qr_into(v[0], &mut packed.as_view_mut(), &mut tau, ws);
                let mut r = Matrix::zeros(n, n);
                view::triu_into(packed.as_view().rows_range(0, n), &mut r.as_view_mut());
                Ok(vec![r, packed, Matrix::from_vec(n, 1, tau)])
            }
            KernelOp::LeafR => {
                let n = v[0].cols();
                let mut r = Matrix::zeros(n, n);
                view::leaf_r_into(v[0], &mut r.as_view_mut(), ws);
                Ok(vec![r])
            }
            KernelOp::Combine => {
                let n = v[0].cols();
                let m = v[0].rows() + v[1].rows();
                let mut packed = Matrix::zeros(m, n);
                let mut tau = vec![0.0f32; n];
                view::combine_qr_into(v[0], v[1], &mut packed.as_view_mut(), &mut tau, ws);
                let mut r = Matrix::zeros(n, n);
                view::triu_into(packed.as_view().rows_range(0, n), &mut r.as_view_mut());
                Ok(vec![r, packed, Matrix::from_vec(n, 1, tau)])
            }
            KernelOp::CombineR => {
                let n = v[0].cols();
                let mut r = Matrix::zeros(n, n);
                view::combine_r_into(v[0], v[1], &mut r.as_view_mut(), ws);
                Ok(vec![r])
            }
            KernelOp::Backsolve => {
                let mut x = Matrix::zeros(v[0].rows(), v[1].cols());
                view::backsolve_into(v[0], v[1], &mut x.as_view_mut());
                Ok(vec![x])
            }
            KernelOp::ApplyQt => {
                // views: [packed, tau (n×1), b]
                let mut out = v[2].to_matrix();
                view::apply_qt_in_place(v[0], v[1].data(), &mut out.as_view_mut());
                Ok(vec![out])
            }
            KernelOp::ApplyUpdate => {
                // views: [packed, tau (n×1), block]
                let mut out = Matrix::zeros(v[2].rows(), v[2].cols());
                view::apply_update_into(v[0], v[1].data(), v[2], &mut out.as_view_mut(), ws);
                Ok(vec![out])
            }
            KernelOp::BuildT => {
                // views: [packed, tau (n×1)]
                let n = v[0].cols();
                let mut t = Matrix::zeros(n, n);
                view::build_t_into(v[0], v[1].data(), &mut t.as_view_mut(), ws);
                Ok(vec![t])
            }
            KernelOp::ApplyWy => {
                // views: [packed, t (n×n), block]
                let mut out = Matrix::zeros(v[2].rows(), v[2].cols());
                view::apply_wy_into(v[0], v[1], v[2], &mut out.as_view_mut(), ws);
                Ok(vec![out])
            }
            KernelOp::BuildQ => {
                let (m, n) = v[0].shape();
                let mut out = Matrix::eye(m, n);
                view::apply_q_in_place(v[0], v[1].data(), &mut out.as_view_mut());
                Ok(vec![out])
            }
            KernelOp::ApplyQWy => {
                // views: [packed, t (n×n), block]
                let mut out = Matrix::zeros(v[2].rows(), v[2].cols());
                view::apply_wy_forward_into(v[0], v[1], v[2], &mut out.as_view_mut(), ws);
                Ok(vec![out])
            }
            KernelOp::BuildQPanel => {
                // views: [packed, t (n×n), params (1×width)] — the
                // identity shard is seeded here, not by the caller.
                let m = v[0].rows();
                let width = v[2].cols();
                let offset = v[2].at(0, 0) as usize;
                let shard =
                    Matrix::from_fn(m, width, |i, j| if i == offset + j { 1.0 } else { 0.0 });
                let mut out = Matrix::zeros(m, width);
                view::apply_wy_forward_into(
                    v[0],
                    v[1],
                    shard.as_view(),
                    &mut out.as_view_mut(),
                    ws,
                );
                Ok(vec![out])
            }
            KernelOp::EncodeChecksum => {
                // views: [weights (1×N), block_0, …]; pad = widest block.
                let blocks = &v[1..];
                let pad = blocks.iter().map(|b| b.cols()).max().unwrap_or(0);
                let mut out = Matrix::zeros(blocks[0].rows(), pad);
                crate::abft::kernels::encode_checksum_into(
                    v[0],
                    blocks,
                    &mut out.as_view_mut(),
                    ws,
                );
                Ok(vec![out])
            }
            KernelOp::ReconstructBlock => {
                // views: [weights (1×N, lost-first), checksum, survivors…].
                let mut out = Matrix::zeros(v[1].rows(), v[1].cols());
                crate::abft::kernels::reconstruct_block_into(
                    v[0],
                    v[1],
                    &v[2..],
                    &mut out.as_view_mut(),
                    ws,
                );
                Ok(vec![out])
            }
        }
    }
}

/// PJRT backend adapter: same call convention, executed through the
/// AOT artifact service.  Views are materialized into owned matrices
/// at the boundary — the device transfer copies the payload anyway.
#[derive(Clone)]
pub struct PjrtKernel {
    service: PjrtService,
}

impl PjrtKernel {
    /// Wrap a running PJRT service as a [`Kernel`].
    pub fn new(service: PjrtService) -> Self {
        Self { service }
    }

    /// The artifact manifest the service was started over.
    pub fn manifest(&self) -> &Manifest {
        self.service.manifest()
    }

    /// Does the manifest carry this entry?
    pub fn supports(&self, entry: &str) -> bool {
        self.service.manifest().get(entry).is_some()
    }
}

impl Kernel for PjrtKernel {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn wants_workspace(&self, _op: KernelOp) -> bool {
        false // scratch lives device-side
    }

    fn execute(&self, call: KernelCall<'_>) -> Result<Vec<Matrix>> {
        let entry = call.op.entry_name(call.views);
        let inputs: Vec<Matrix> = call.views.iter().map(|v| v.to_matrix()).collect();
        self.service.execute(&entry, inputs)
    }
}

/// Counters of [`WorkspacePool`] behaviour (all-relaxed atomics).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkspaceStats {
    /// Workspaces ever created (pool misses + warming).
    pub created: u64,
    /// Acquisitions served from the pool — each one is a full scratch
    /// allocation (O(m·n) f64) that did NOT happen.
    pub reused: u64,
}

/// Shared pool of [`Workspace`] arenas, one checked out per in-flight
/// kernel call.  An [`super::Executor`] (and therefore an engine
/// session) owns one pool; it is shared across executor clones, so a
/// campaign's workspaces survive from run to run — the pool settles at
/// the concurrency high-water mark and stops allocating.
#[derive(Default)]
pub struct WorkspacePool {
    free: Mutex<Vec<Workspace>>,
    created: AtomicU64,
    reused: AtomicU64,
}

impl WorkspacePool {
    /// An empty pool (workspaces are created on demand).
    pub fn new() -> Self {
        Self::default()
    }

    /// Check a workspace out (pop, or create on a cold pool).
    pub fn acquire(&self) -> Workspace {
        let ws = self.free.lock().unwrap().pop();
        match ws {
            Some(ws) => {
                self.reused.fetch_add(1, Ordering::Relaxed);
                ws
            }
            None => {
                self.created.fetch_add(1, Ordering::Relaxed);
                Workspace::new()
            }
        }
    }

    /// Return a workspace to the pool (its grown buffers come with it).
    pub fn release(&self, ws: Workspace) {
        self.free.lock().unwrap().push(ws);
    }

    /// Ensure at least `count` pooled workspaces exist, each pre-sized
    /// for an `rows x cols` factorization (idempotent; called from the
    /// run setup with shapes precomputed by `tsqr::plan`).
    pub fn warm(&self, count: usize, rows: usize, cols: usize) {
        let mut free = self.free.lock().unwrap();
        while free.len() < count {
            self.created.fetch_add(1, Ordering::Relaxed);
            free.push(Workspace::new());
        }
        for ws in free.iter_mut() {
            ws.reserve(rows, cols);
        }
    }

    /// Workspaces currently parked in the pool.
    pub fn pooled(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    /// Created/reused counters.
    pub fn stats(&self) -> WorkspaceStats {
        WorkspaceStats {
            created: self.created.load(Ordering::Relaxed),
            reused: self.reused.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{combine_r, householder_qr};

    fn call<'c>(
        op: KernelOp,
        views: &'c [MatrixView<'c>],
        ws: &'c mut Workspace,
    ) -> KernelCall<'c> {
        KernelCall { op, views, workspace: ws }
    }

    #[test]
    fn host_kernel_leaf_matches_shim() {
        let a = Matrix::random(32, 4, 1);
        let mut ws = Workspace::new();
        let views = [a.as_view()];
        let out = HostKernel.execute(call(KernelOp::LeafQr, &views, &mut ws)).unwrap();
        let f = householder_qr(&a);
        assert_eq!(out[1], f.packed);
        assert_eq!(out[0], f.r());
        assert_eq!(out[2].data(), &f.tau[..]);
    }

    #[test]
    fn host_kernel_combine_r_matches_shim() {
        let top = householder_qr(&Matrix::random(8, 4, 2)).r();
        let bot = householder_qr(&Matrix::random(8, 4, 3)).r();
        let mut ws = Workspace::new();
        let views = [top.as_view(), bot.as_view()];
        let out = HostKernel.execute(call(KernelOp::CombineR, &views, &mut ws)).unwrap();
        assert_eq!(out[0], combine_r(&top, &bot));
    }

    #[test]
    fn entry_names_follow_manifest_scheme() {
        let a = Matrix::zeros(32, 4);
        let b = Matrix::zeros(4, 4);
        assert_eq!(
            KernelOp::LeafQr.entry_name(&[a.as_view()]),
            Manifest::leaf_qr_name(32, 4)
        );
        assert_eq!(
            KernelOp::CombineR.entry_name(&[b.as_view(), b.as_view()]),
            Manifest::combine_r_name(4)
        );
        assert_eq!(
            KernelOp::Backsolve.entry_name(&[b.as_view(), Matrix::zeros(4, 2).as_view()]),
            Manifest::backsolve_name(4, 2)
        );
        let w = Matrix::zeros(1, 2);
        assert_eq!(
            KernelOp::EncodeChecksum.entry_name(&[w.as_view(), b.as_view(), b.as_view()]),
            Manifest::encode_checksum_name(4, 4, 2)
        );
        assert_eq!(
            KernelOp::ReconstructBlock.entry_name(&[w.as_view(), b.as_view(), b.as_view()]),
            Manifest::reconstruct_block_name(4, 4, 2)
        );
        let p = Matrix::zeros(1, 2);
        assert_eq!(
            KernelOp::ApplyQWy.entry_name(&[a.as_view(), b.as_view(), Matrix::zeros(32, 3).as_view()]),
            Manifest::apply_q_wy_name(32, 4, 3)
        );
        assert_eq!(
            KernelOp::BuildQPanel.entry_name(&[a.as_view(), b.as_view(), p.as_view()]),
            Manifest::build_q_panel_name(32, 4, 2)
        );
    }

    #[test]
    fn host_kernel_wy_ops_agree_with_rank1_update() {
        let a = Matrix::random(24, 4, 5);
        let f = householder_qr(&a);
        let block = Matrix::random(24, 3, 6);
        let tau = Matrix::from_vec(4, 1, f.tau.clone());
        let mut ws = Workspace::new();
        let t_views = [f.packed.as_view(), tau.as_view()];
        let t = HostKernel
            .execute(call(KernelOp::BuildT, &t_views, &mut ws))
            .unwrap()
            .pop()
            .unwrap();
        assert_eq!(t.shape(), (4, 4));
        let wy_views = [f.packed.as_view(), t.as_view(), block.as_view()];
        let fast = HostKernel
            .execute(call(KernelOp::ApplyWy, &wy_views, &mut ws))
            .unwrap()
            .pop()
            .unwrap();
        let upd_views = [f.packed.as_view(), tau.as_view(), block.as_view()];
        let slow = HostKernel
            .execute(call(KernelOp::ApplyUpdate, &upd_views, &mut ws))
            .unwrap()
            .pop()
            .unwrap();
        assert!(fast.max_abs_diff(&slow) < 1e-4, "WY op must match the rank-1 op");
    }

    #[test]
    fn host_kernel_q_side_ops_build_and_invert() {
        let a = Matrix::random(24, 4, 7);
        let f = householder_qr(&a);
        let tau = Matrix::from_vec(4, 1, f.tau.clone());
        let mut ws = Workspace::new();
        let t_views = [f.packed.as_view(), tau.as_view()];
        let t = HostKernel
            .execute(call(KernelOp::BuildT, &t_views, &mut ws))
            .unwrap()
            .pop()
            .unwrap();

        // BuildQPanel's shard must match the same columns of BuildQ's
        // thin Q (Householder reference path).
        let q_views = [f.packed.as_view(), tau.as_view()];
        let q = HostKernel
            .execute(call(KernelOp::BuildQ, &q_views, &mut ws))
            .unwrap()
            .pop()
            .unwrap();
        let params = Matrix::from_fn(1, 2, |_, j| if j == 0 { 1.0 } else { 0.0 });
        let shard_views = [f.packed.as_view(), t.as_view(), params.as_view()];
        let shard = HostKernel
            .execute(call(KernelOp::BuildQPanel, &shard_views, &mut ws))
            .unwrap()
            .pop()
            .unwrap();
        assert_eq!(shard.shape(), (24, 2));
        for i in 0..24 {
            for j in 0..2 {
                assert!(
                    (shard.as_view().at(i, j) - q.as_view().at(i, 1 + j)).abs() < 1e-4,
                    "shard column {j} must match thin-Q column {}",
                    1 + j
                );
            }
        }

        // ApplyQWy (Q·C) inverts ApplyWy (Qᵀ·C).
        let block = Matrix::random(24, 3, 8);
        let wy_views = [f.packed.as_view(), t.as_view(), block.as_view()];
        let qt_block = HostKernel
            .execute(call(KernelOp::ApplyWy, &wy_views, &mut ws))
            .unwrap()
            .pop()
            .unwrap();
        let fwd_views = [f.packed.as_view(), t.as_view(), qt_block.as_view()];
        let roundtrip = HostKernel
            .execute(call(KernelOp::ApplyQWy, &fwd_views, &mut ws))
            .unwrap()
            .pop()
            .unwrap();
        assert!(
            roundtrip.max_abs_diff(&block) < 1e-4,
            "forward apply must invert the transpose apply"
        );
    }

    #[test]
    fn kernel_profile_parses_and_prints() {
        use super::KernelProfile;
        assert_eq!("reference".parse::<KernelProfile>().unwrap(), KernelProfile::Reference);
        assert_eq!("blocked".parse::<KernelProfile>().unwrap(), KernelProfile::Blocked);
        assert_eq!("wy".parse::<KernelProfile>().unwrap(), KernelProfile::Blocked);
        assert!("fast".parse::<KernelProfile>().is_err());
        assert_eq!(KernelProfile::default(), KernelProfile::Reference);
        assert_eq!(KernelProfile::Blocked.to_string(), "blocked");
    }

    #[test]
    fn kernel_op_all_is_complete_and_in_declaration_order() {
        // Exhaustiveness backstop for the const table: every variant
        // appears exactly once.  (The compiler already forces the
        // `contract`/`wants_workspace` matches to stay exhaustive.)
        let mut seen = std::collections::HashSet::new();
        for op in KernelOp::ALL {
            assert!(seen.insert(op), "{op:?} listed twice in KernelOp::ALL");
        }
        assert_eq!(seen.len(), 14);
        assert_eq!(KernelOp::ALL[0], KernelOp::LeafQr);
        assert_eq!(KernelOp::ALL[13], KernelOp::ReconstructBlock);
    }

    #[test]
    fn contract_table_pins_factorizations_as_tolerance_rest_bitwise() {
        for op in KernelOp::ALL {
            let want_tolerance = matches!(
                op,
                KernelOp::LeafQr | KernelOp::LeafR | KernelOp::Combine | KernelOp::CombineR
            );
            match op.contract() {
                Contract::Tolerance { c } => {
                    assert!(want_tolerance, "{op:?} must be Bitwise");
                    assert!(c > 0.0);
                }
                Contract::Bitwise => assert!(!want_tolerance, "{op:?} must be Tolerance"),
            }
        }
    }

    #[test]
    fn contract_bounds_scale_with_n_and_norm() {
        assert_eq!(Contract::Bitwise.bound(64, 1e6), 0.0);
        let t = Contract::Tolerance { c: 64.0 };
        assert!(t.bound(8, 1.0) > 0.0);
        assert!(t.bound(16, 1.0) > t.bound(8, 1.0));
        assert!(t.bound(8, 100.0) > t.bound(8, 1.0));
        // Sub-unit norms are floored at 1 so tiny inputs keep a
        // usable absolute bound.
        assert_eq!(t.bound(8, 0.001), t.bound(8, 1.0));
    }

    #[test]
    fn wants_workspace_classification_is_pinned_per_op() {
        // The in-place solve/apply kernels take no scratch; everything
        // else draws from the pooled arena.  This is the full 14-op
        // table — a new op must be added here AND in the (exhaustive)
        // match above to land.
        let scratch_free = [KernelOp::Backsolve, KernelOp::ApplyQt, KernelOp::BuildQ];
        for op in KernelOp::ALL {
            assert_eq!(
                HostKernel.wants_workspace(op),
                !scratch_free.contains(&op),
                "wants_workspace misclassifies {op:?}"
            );
        }
    }

    #[test]
    fn precision_parses_prints_and_defaults_to_f64() {
        assert_eq!("f32".parse::<Precision>().unwrap(), Precision::F32);
        assert_eq!("mixed".parse::<Precision>().unwrap(), Precision::F32);
        assert_eq!("f64".parse::<Precision>().unwrap(), Precision::F64);
        assert_eq!("double".parse::<Precision>().unwrap(), Precision::F64);
        assert!("f16".parse::<Precision>().is_err());
        assert_eq!(Precision::default(), Precision::F64);
        assert!(Precision::F32.is_f32() && !Precision::F64.is_f32());
        assert_eq!(Precision::F32.to_string(), "f32");
    }

    #[test]
    fn workspace_pool_reuses() {
        let pool = WorkspacePool::new();
        let ws = pool.acquire();
        pool.release(ws);
        let ws = pool.acquire();
        pool.release(ws);
        let s = pool.stats();
        assert_eq!(s.created, 1);
        assert_eq!(s.reused, 1);
        assert_eq!(pool.pooled(), 1);
    }

    #[test]
    fn workspace_pool_warm_is_idempotent() {
        let pool = WorkspacePool::new();
        pool.warm(3, 64, 8);
        pool.warm(3, 64, 8);
        assert_eq!(pool.pooled(), 3);
        assert_eq!(pool.stats().created, 3);
        // Warmed workspaces factor without growing.
        let mut ws = pool.acquire();
        assert_eq!(ws.f64_scratch(64 * 8 + 8).len(), 64 * 8 + 8);
        assert_eq!(ws.grows(), 0);
        pool.release(ws);
    }
}
