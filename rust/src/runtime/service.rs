//! The PJRT execution service.
//!
//! Not to be confused with the *multi-tenant job service*
//! ([`crate::service`]): this module is the backend-internal bridge
//! that marshals kernel calls onto XLA's non-`Send` PJRT handles,
//! while `crate::service` is the user-facing front door that admits
//! and schedules whole campaigns across tenants.
//!
//! The `xla` crate's PJRT handles hold raw pointers and are not `Send`,
//! so all XLA state lives on dedicated *service threads*; simulated MPI
//! processes (OS threads) talk to them through an mpsc request channel.
//! This mirrors how a real deployment would pin one PJRT context per
//! device and route work to it.
//!
//! Compilation is lazy and cached: the first request for an entry point
//! pays `HloModuleProto::from_text_file` + `client.compile`; subsequent
//! requests reuse the loaded executable (hit counters are exported for
//! the perf pass).
//!
//! Work distribution: requests round-robin across `shards` service
//! threads (an atomic counter), so concurrent calls to the SAME entry
//! point execute in parallel too — a TSQR round issues P identical
//! leaf/combine calls at once, and hashing by name would serialize
//! them on one shard (measured 6x slower at P=64; EXPERIMENTS.md
//! §Perf).  Each shard compiles lazily and caches per-thread.
//!
//! The whole XLA-facing half is gated behind the `pjrt` cargo feature:
//! without it this module exposes an uninhabited stub with the same
//! API whose `start` always fails, so the default build needs no
//! native XLA toolchain and `Executor::auto` falls back to the host
//! path.

use std::sync::atomic::AtomicU64;

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::sync::atomic::{AtomicUsize, Ordering};
#[cfg(feature = "pjrt")]
use std::sync::{Arc, mpsc};

use crate::error::{Error, Result};
use crate::linalg::Matrix;

use super::manifest::Manifest;

/// Cheap shared counters exported to the perf harness.
#[derive(Default, Debug)]
pub struct ServiceStats {
    /// Entry-point executions served.
    pub executions: AtomicU64,
    /// Lazy HLO compilations performed (cache misses).
    pub compiles: AtomicU64,
    /// Executions served from the per-shard executable cache.
    pub cache_hits: AtomicU64,
}

/// One kernel invocation: entry-point name + input matrices.
#[cfg(feature = "pjrt")]
struct Request {
    entry: String,
    inputs: Vec<Matrix>,
    reply: mpsc::Sender<Result<Vec<Matrix>>>,
}

/// Handle to the PJRT service — `Clone + Send + Sync`.
#[cfg(feature = "pjrt")]
#[derive(Clone)]
pub struct PjrtService {
    senders: Vec<mpsc::Sender<Request>>,
    manifest: Arc<Manifest>,
    stats: Arc<ServiceStats>,
    next_shard: Arc<AtomicUsize>,
}

#[cfg(feature = "pjrt")]
impl PjrtService {
    /// Start `shards` service threads over the artifact directory.
    pub fn start(manifest: Manifest, shards: usize) -> Result<Self> {
        let shards = shards.max(1);
        let manifest = Arc::new(manifest);
        let stats = Arc::new(ServiceStats::default());
        let mut senders = Vec::with_capacity(shards);
        for shard in 0..shards {
            let (tx, rx) = mpsc::channel::<Request>();
            let mf = Arc::clone(&manifest);
            let st = Arc::clone(&stats);
            std::thread::Builder::new()
                .name(format!("pjrt-svc-{shard}"))
                .spawn(move || service_loop(rx, mf, st))
                .map_err(|e| Error::Other(format!("spawn pjrt service: {e}")))?;
            senders.push(tx);
        }
        Ok(Self { senders, manifest, stats, next_shard: Arc::new(AtomicUsize::new(0)) })
    }

    /// The manifest the service was started over.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execution/compile/cache counters.
    pub fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    /// Execute an entry point synchronously (blocks the calling thread).
    pub fn execute(&self, entry: &str, inputs: Vec<Matrix>) -> Result<Vec<Matrix>> {
        let ent = self
            .manifest
            .get(entry)
            .ok_or_else(|| Error::Artifacts(format!("no artifact entry '{entry}'")))?;
        // Shape-check inputs against the manifest before shipping.
        if ent.inputs.len() != inputs.len() {
            return Err(Error::Artifacts(format!(
                "entry '{entry}' expects {} inputs, got {}",
                ent.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (spec, m)) in ent.inputs.iter().zip(&inputs).enumerate() {
            let got = vec![m.rows(), m.cols()];
            if *spec != got {
                return Err(Error::Artifacts(format!(
                    "entry '{entry}' input {i}: expected {spec:?}, got {got:?}"
                )));
            }
        }
        // Round-robin: concurrent identical calls spread across shards.
        let shard = self.next_shard.fetch_add(1, Ordering::Relaxed) % self.senders.len();
        let (reply_tx, reply_rx) = mpsc::channel();
        self.senders[shard]
            .send(Request { entry: entry.to_string(), inputs, reply: reply_tx })
            .map_err(|_| Error::Other("pjrt service thread died".into()))?;
        reply_rx.recv().map_err(|_| Error::Other("pjrt service dropped reply".into()))?
    }
}

/// Body of one service thread: owns a PJRT client + executable cache.
#[cfg(feature = "pjrt")]
fn service_loop(rx: mpsc::Receiver<Request>, manifest: Arc<Manifest>, stats: Arc<ServiceStats>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Fail every request with the construction error.
            for req in rx {
                let _ = req.reply.send(Err(Error::Xla(format!("PjRtClient::cpu failed: {e}"))));
            }
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    for req in rx {
        let result = run_one(&client, &mut cache, &manifest, &stats, &req);
        let _ = req.reply.send(result);
    }
}

#[cfg(feature = "pjrt")]
fn run_one(
    client: &xla::PjRtClient,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    manifest: &Manifest,
    stats: &ServiceStats,
    req: &Request,
) -> Result<Vec<Matrix>> {
    let entry = manifest
        .get(&req.entry)
        .ok_or_else(|| Error::Artifacts(format!("no artifact entry '{}'", req.entry)))?;

    if !cache.contains_key(&req.entry) {
        let path = manifest.hlo_path(entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Artifacts("non-utf8 artifact path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        cache.insert(req.entry.clone(), exe);
        stats.compiles.fetch_add(1, Ordering::Relaxed);
    } else {
        stats.cache_hits.fetch_add(1, Ordering::Relaxed);
    }
    let exe = cache.get(&req.entry).expect("just inserted");

    // Host matrices -> device literals.
    let literals: Vec<xla::Literal> = req
        .inputs
        .iter()
        .map(|m| {
            xla::Literal::vec1(m.data())
                .reshape(&[m.rows() as i64, m.cols() as i64])
                .map_err(Error::from)
        })
        .collect::<Result<_>>()?;

    let out = exe.execute::<xla::Literal>(&literals)?;
    let lit = out[0][0].to_literal_sync()?;
    stats.executions.fetch_add(1, Ordering::Relaxed);

    // aot.py lowers with return_tuple=True: output is always a tuple.
    let parts = lit.to_tuple()?;
    if parts.len() != entry.out_arity {
        return Err(Error::Xla(format!(
            "entry '{}': expected {}-tuple, got {}",
            req.entry,
            entry.out_arity,
            parts.len()
        )));
    }
    parts
        .into_iter()
        .map(|p| {
            let shape = p.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let (r, c) = match dims.len() {
                2 => (dims[0], dims[1]),
                1 => (dims[0], 1),
                0 => (1, 1),
                _ => {
                    return Err(Error::Xla(format!("unexpected output rank {}", dims.len())));
                }
            };
            let v = p.to_vec::<f32>()?;
            Ok(Matrix::from_vec(r, c, v))
        })
        .collect()
}

/// Stub used when the crate is built without the `pjrt` feature: an
/// uninhabited type, so no instance ever exists and the non-`start`
/// methods are statically unreachable (`match *self {}`).  `start`
/// fails with a pointer at the feature flag; `Executor::auto` catches
/// that and falls back to the pure-rust host path.
#[cfg(not(feature = "pjrt"))]
#[derive(Clone)]
pub enum PjrtService {}

#[cfg(not(feature = "pjrt"))]
impl PjrtService {
    /// Always fails: the PJRT backend is compiled out.
    pub fn start(_manifest: Manifest, _shards: usize) -> Result<Self> {
        Err(Error::Artifacts(
            "built without the `pjrt` feature — vendor the `xla` crate, add it \
             under [dependencies] in rust/Cargo.toml (see the comment there), \
             and rebuild with `--features pjrt`; or use the host/auto backend"
                .into(),
        ))
    }

    /// Statically unreachable (no stub instance exists).
    pub fn manifest(&self) -> &Manifest {
        match *self {}
    }

    /// Statically unreachable (no stub instance exists).
    pub fn stats(&self) -> &ServiceStats {
        match *self {}
    }

    /// Statically unreachable (no stub instance exists).
    pub fn execute(&self, _entry: &str, _inputs: Vec<Matrix>) -> Result<Vec<Matrix>> {
        match *self {}
    }
}

#[cfg(test)]
mod tests {
    // PJRT-backed tests live in rust/tests/integration_runtime.rs (they
    // need built artifacts). Here: only manifest-validation failures.
    use super::*;

    #[cfg(feature = "pjrt")]
    #[test]
    fn unknown_entry_rejected_without_touching_pjrt() {
        let tmp = crate::util::TestDir::new();
        tmp.write("manifest.json", r#"{"dtype":"f32","entries":[]}"#);
        let svc = PjrtService::start(Manifest::load(tmp.path()).unwrap(), 1).unwrap();
        let err = svc.execute("nope", vec![]).unwrap_err();
        assert!(matches!(err, Error::Artifacts(_)));
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn input_shape_mismatch_rejected() {
        let tmp = crate::util::TestDir::new();
        tmp.write(
            "manifest.json",
            r#"{"dtype":"f32","entries":[
              {"name":"leaf_qr_8x4","kind":"leaf_qr","params":{"m":8,"n":4},
               "file":"leaf_qr_8x4.hlo.txt","inputs":[[8,4]],"out_arity":3}]}"#,
        );
        let svc = PjrtService::start(Manifest::load(tmp.path()).unwrap(), 1).unwrap();
        let err = svc.execute("leaf_qr_8x4", vec![Matrix::zeros(4, 4)]).unwrap_err();
        assert!(err.to_string().contains("expected [8, 4]"), "{err}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_start_points_at_the_feature_flag() {
        let tmp = crate::util::TestDir::new();
        tmp.write("manifest.json", r#"{"dtype":"f32","entries":[]}"#);
        let err = PjrtService::start(Manifest::load(tmp.path()).unwrap(), 1).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
