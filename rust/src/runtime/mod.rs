//! Runtime layer: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`) produced by `python/compile/aot.py` and executes
//! them on the PJRT CPU client from the coordinator's hot path.
//!
//! Python is build-time only; after `make artifacts` the rust binary is
//! self-contained.

pub mod cpu;
pub mod executor;
pub mod kernel;
pub mod manifest;
pub mod service;
pub mod threaded;

pub use cpu::{CpuInfo, Parallelism};
pub use executor::{Backend, Executor, Factorization};
pub use kernel::{
    Contract, HostKernel, Kernel, KernelCall, KernelOp, KernelProfile, Precision, WorkspacePool,
    WorkspaceStats,
};
pub use manifest::Manifest;
pub use service::PjrtService;
pub use threaded::{BackendChoice, BackendPlan, ThreadedKernel};

/// Conventional artifact directory (relative to the repo root).
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
