//! The second in-process backend: a rayon-free, pool-parallel
//! [`Kernel`] implementation over the engine's elastic
//! [`WorkerPool`]/[`TaskGroup`] machinery.
//!
//! Every [`KernelOp`] executes here, but not every op parallelizes the
//! same way — the per-op strategy is chosen so each op can *honestly*
//! declare its [`Contract`](super::kernel::Contract):
//!
//! | ops | strategy | contract |
//! |-----|----------|----------|
//! | `LeafQr` `LeafR` `Combine` `CombineR` | chunked-reduction Householder QR, trailing columns fanned over the pool | `Tolerance` |
//! | `ApplyUpdate` `ApplyQt` `Backsolve` `BuildQ` | column slabs through the identical sequential view kernels | `Bitwise` |
//! | `EncodeChecksum` `ReconstructBlock` | row slabs through the identical sequential ABFT kernels | `Bitwise` |
//! | `BuildT` `ApplyWy` `ApplyQWy` `BuildQPanel` | delegate to [`HostKernel`] | `Bitwise` |
//!
//! The slab ops stay bitwise because their arithmetic is independent
//! per output column (or per element, for the checksum ops): cutting
//! the work into contiguous slabs re-partitions loop iterations
//! without reassociating a single floating-point sum.  The
//! factorizations cannot be split that way — every reflector is a
//! reduction over rows — so the threaded implementation uses
//! fixed-size chunked partial sums (deterministic for *any* worker
//! count, but a different association than the host kernel) and
//! declares `Tolerance`.  The compact-WY family delegates: its
//! parallelism already lives in the pooled GEMM microkernel that
//! `KernelProfile::Blocked` CAQR drives (see `linalg::gemm`), and
//! slabbing GEMM inputs at arbitrary widths is not covered by that
//! kernel's bitwise guarantee.
//!
//! [`BackendPlan`] is the per-op selector: a default backend choice
//! plus overrides, carried by `EngineBuilder::backend_plan(..)` /
//! `CaqrSpec::with_backend(..)` and consulted by the
//! [`Executor`](super::Executor) at its single dispatch point (which,
//! in debug builds, also re-runs the host kernel and enforces the
//! declared contract).

use std::sync::{Arc, Mutex};

use crate::engine::{TaskGroup, WorkerPool};
use crate::error::{Error, Result};
use crate::linalg::{Matrix, Workspace, view};

use super::cpu::{CpuInfo, Parallelism};
use super::kernel::{HostKernel, Kernel, KernelCall, KernelOp};

/// Which in-process implementation a [`BackendPlan`] routes an op to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendChoice {
    /// The sequential [`HostKernel`] (the bitwise-pinned reference).
    #[default]
    Host,
    /// The pool-parallel [`ThreadedKernel`].
    Threaded,
}

impl BackendChoice {
    /// Stable name (`host` / `threaded`).
    pub fn name(&self) -> &'static str {
        match self {
            BackendChoice::Host => "host",
            BackendChoice::Threaded => "threaded",
        }
    }
}

impl std::fmt::Display for BackendChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for BackendChoice {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "host" => Ok(BackendChoice::Host),
            "threaded" => Ok(BackendChoice::Threaded),
            other => Err(Error::Config(format!(
                "unknown in-process backend '{other}' (host|threaded)"
            ))),
        }
    }
}

/// Per-[`KernelOp`] backend selection: one default choice plus
/// targeted overrides.  The executor consults `select(op)` at every
/// dispatch; `Default` routes everything to the host kernel, so the
/// plan is a pure opt-in.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct BackendPlan {
    default: BackendChoice,
    overrides: Vec<(KernelOp, BackendChoice)>,
}

impl BackendPlan {
    /// Everything on the sequential host kernel (the default).
    pub fn host() -> Self {
        Self { default: BackendChoice::Host, overrides: Vec::new() }
    }

    /// Everything on the pool-parallel threaded kernel.
    pub fn threaded() -> Self {
        Self { default: BackendChoice::Threaded, overrides: Vec::new() }
    }

    /// Route one op somewhere specific (last write wins).
    pub fn with_op(mut self, op: KernelOp, choice: BackendChoice) -> Self {
        self.overrides.retain(|(o, _)| *o != op);
        self.overrides.push((op, choice));
        self
    }

    /// The choice this plan makes for `op`.
    pub fn select(&self, op: KernelOp) -> BackendChoice {
        self.overrides
            .iter()
            .find(|(o, _)| *o == op)
            .map(|&(_, c)| c)
            .unwrap_or(self.default)
    }

    /// Does any op route to the threaded kernel?
    pub fn uses_threaded(&self) -> bool {
        self.default == BackendChoice::Threaded
            || self.overrides.iter().any(|&(_, c)| c == BackendChoice::Threaded)
    }
}

impl std::fmt::Display for BackendPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.overrides.is_empty() {
            f.write_str(self.default.name())
        } else {
            write!(f, "{}+{}", self.default.name(), self.overrides.len())
        }
    }
}

impl std::str::FromStr for BackendPlan {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.parse::<BackendChoice>()? {
            BackendChoice::Host => Ok(BackendPlan::host()),
            BackendChoice::Threaded => Ok(BackendPlan::threaded()),
        }
    }
}

/// Fixed chunk size of every reassociated reduction in this module.
/// A compile-time constant — NOT derived from the worker count — so
/// the threaded factorizations produce identical bits whether the
/// pool runs 1 worker or 64.
const DOT_CHUNK: usize = 64;

/// Dot product with fixed-size chunked partial sums: deterministic,
/// but associated differently than a plain ascending accumulation —
/// the arithmetic signature of the `Tolerance` ops.
fn dot_chunked(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut total = 0.0;
    let mut i = 0;
    while i < a.len() {
        let end = (i + DOT_CHUNK).min(a.len());
        let mut partial = 0.0;
        for t in i..end {
            partial += a[t] * b[t];
        }
        total += partial;
        i = end;
    }
    total
}

/// Sequential Householder panel factorization with chunked-reduction
/// dot products: the same packed layout, sign convention, and tau
/// normalization as [`view::factor_panel_f64`], but every row
/// reduction runs through [`dot_chunked`] — the single-task core of
/// the threaded factor ops, and the factor kernel a
/// `BackendPlan::threaded()` CAQR run schedules on its replicas.
///
/// Deterministic (the chunk size is a constant), so replicas remain
/// bit-identical to each other; only the *cross-backend* comparison
/// against the host kernel is tolerance-class.
pub fn factor_panel_chunked_f64(w: &mut [f64], rows: usize, cols: usize, tau64: &mut [f64]) {
    assert!(rows >= cols, "factor_panel_chunked_f64: need tall-skinny, got {rows}x{cols}");
    assert_eq!(w.len(), rows * cols, "factor_panel_chunked_f64: buffer length != rows*cols");
    assert_eq!(tau64.len(), cols, "factor_panel_chunked_f64: tau must have {cols} entries");
    let mut col_j = vec![0.0f64; rows];
    let mut col_c = vec![0.0f64; rows];
    for j in 0..cols {
        for i in j..rows {
            col_j[i - j] = w[i * cols + j];
        }
        let tail = &col_j[..rows - j];
        let normx = dot_chunked(tail, tail).sqrt();
        if normx == 0.0 {
            tau64[j] = 0.0;
            continue;
        }
        let x0 = tail[0];
        let beta = if x0 >= 0.0 { -normx } else { normx };
        let denom = x0 - beta;
        tau64[j] = (beta - x0) / beta;
        for i in j + 1..rows {
            w[i * cols + j] /= denom;
        }
        w[j * cols + j] = beta;
        for i in j + 1..rows {
            col_j[i - j] = w[i * cols + j];
        }
        for c in j + 1..cols {
            for i in j + 1..rows {
                col_c[i - j - 1] = w[i * cols + c];
            }
            let dot = w[j * cols + c]
                + dot_chunked(&col_j[1..rows - j], &col_c[..rows - j - 1]);
            let s = tau64[j] * dot;
            w[j * cols + c] -= s;
            for i in j + 1..rows {
                w[i * cols + c] -= col_j[i - j] * s;
            }
        }
    }
}

/// Split `0..total` into at most `lanes` contiguous, non-empty ranges.
fn slab_ranges(total: usize, lanes: usize) -> Vec<(usize, usize)> {
    if total == 0 {
        return Vec::new();
    }
    let lanes = lanes.clamp(1, total);
    let base = total / lanes;
    let extra = total % lanes;
    let mut ranges = Vec::with_capacity(lanes);
    let mut start = 0;
    for lane in 0..lanes {
        let len = base + usize::from(lane < extra);
        ranges.push((start, start + len));
        start += len;
    }
    ranges
}

/// Pool-parallel backend: every op runs through the shared
/// [`WorkerPool`], with the per-op strategy documented in the
/// [module docs](self).  `Clone` shares the pool (it spawns workers
/// lazily, so an unused threaded kernel costs nothing).
#[derive(Clone)]
pub struct ThreadedKernel {
    pool: WorkerPool,
    parallelism: Parallelism,
}

impl Default for ThreadedKernel {
    fn default() -> Self {
        Self::new()
    }
}

impl ThreadedKernel {
    /// A threaded kernel over a fresh elastic pool, fanning out as
    /// wide as the host has hardware threads.
    pub fn new() -> Self {
        Self::with_parallelism(Parallelism::new(CpuInfo::cached().threads))
    }

    /// Cap the fan-out width (the pool itself stays elastic).  Every
    /// width produces identical bits — this knob trades wall-clock
    /// only, exactly like the GEMM `Parallelism`.
    pub fn with_parallelism(parallelism: Parallelism) -> Self {
        Self { pool: WorkerPool::new(), parallelism }
    }

    fn lanes(&self, work: usize) -> usize {
        self.parallelism.gemm_threads().clamp(1, work.max(1))
    }

    /// Fan `task(range)` over the pool, one spawn per contiguous range
    /// of `0..total`, collecting each range's output matrix in order.
    /// The closure must be self-contained (`'static`): callers capture
    /// `Arc`-shared copies of the inputs.
    fn fan_out<F>(&self, op: KernelOp, total: usize, task: F) -> Result<Vec<((usize, usize), Matrix)>>
    where
        F: Fn(usize, usize) -> Matrix + Send + Sync + 'static,
    {
        let ranges = slab_ranges(total, self.lanes(total));
        if ranges.len() <= 1 {
            // One lane: run inline, no pool traffic.
            return Ok(ranges.into_iter().map(|(a, b)| ((a, b), task(a, b))).collect());
        }
        let task = Arc::new(task);
        let slots: Arc<Mutex<Vec<Option<Matrix>>>> =
            Arc::new(Mutex::new(vec![None; ranges.len()]));
        let group = TaskGroup::new(self.pool.clone());
        for (idx, &(a, b)) in ranges.iter().enumerate() {
            let task = Arc::clone(&task);
            let slots = Arc::clone(&slots);
            group.spawn(move || {
                let out = task(a, b);
                slots.lock().unwrap()[idx] = Some(out);
            });
        }
        group.wait_idle();
        let mut filled = slots.lock().unwrap();
        let mut out = Vec::with_capacity(ranges.len());
        for (idx, &range) in ranges.iter().enumerate() {
            match filled[idx].take() {
                Some(m) => out.push((range, m)),
                None => {
                    return Err(Error::Aborted(format!(
                        "threaded backend lost a {op:?} slab task (worker panic)"
                    )));
                }
            }
        }
        Ok(out)
    }

    /// Stitch column slabs back into one `rows x total_cols` matrix.
    fn stitch_columns(
        rows: usize,
        total_cols: usize,
        slabs: Vec<((usize, usize), Matrix)>,
    ) -> Matrix {
        let mut out = Matrix::zeros(rows, total_cols);
        for ((c0, c1), slab) in slabs {
            debug_assert_eq!(slab.shape(), (rows, c1 - c0));
            for i in 0..rows {
                for (jj, c) in (c0..c1).enumerate() {
                    out[(i, c)] = slab[(i, jj)];
                }
            }
        }
        out
    }

    /// Stitch row slabs back into one `total_rows x cols` matrix.
    fn stitch_rows(total_rows: usize, cols: usize, slabs: Vec<((usize, usize), Matrix)>) -> Matrix {
        let mut out = Matrix::zeros(total_rows, cols);
        for ((r0, r1), slab) in slabs {
            debug_assert_eq!(slab.shape(), (r1 - r0, cols));
            for (ii, i) in (r0..r1).enumerate() {
                for j in 0..cols {
                    out[(i, j)] = slab[(ii, j)];
                }
            }
        }
        out
    }

    /// Copy columns `[c0, c1)` of `m` into an owned slab.
    fn column_slab(m: &Matrix, c0: usize, c1: usize) -> Matrix {
        Matrix::from_fn(m.rows(), c1 - c0, |i, j| m[(i, c0 + j)])
    }

    /// The pool-parallel Householder factorization behind the four
    /// `Tolerance` ops: reflector `j` is computed on the calling
    /// thread (chunked reductions), then the trailing columns are
    /// fanned over the pool in contiguous groups — each column's
    /// arithmetic is self-contained, so the result is independent of
    /// the lane count.  Works column-major so groups of columns can be
    /// *moved* into tasks and back without aliasing.
    fn factor_f64(&self, a_cols: &mut [Vec<f64>], rows: usize, tau: &mut [f64]) {
        let cols = a_cols.len();
        for j in 0..cols.min(rows) {
            let tail = &a_cols[j][j..];
            let normx = dot_chunked(tail, tail).sqrt();
            if normx == 0.0 {
                tau[j] = 0.0;
                continue;
            }
            let x0 = a_cols[j][j];
            let beta = if x0 >= 0.0 { -normx } else { normx };
            let denom = x0 - beta;
            let tau_j = (beta - x0) / beta;
            tau[j] = tau_j;
            for i in j + 1..rows {
                a_cols[j][i] /= denom;
            }
            a_cols[j][j] = beta;
            if j + 1 >= cols {
                continue;
            }
            // v tail (v[j] = 1 implicit), shared read-only by every lane.
            let v: Arc<Vec<f64>> = Arc::new(a_cols[j][j + 1..].to_vec());
            let ranges = slab_ranges(cols - j - 1, self.lanes(cols - j - 1));
            if ranges.len() <= 1 {
                // One lane: update in place, no moves, no pool traffic.
                for c in j + 1..cols {
                    let col = &mut a_cols[c];
                    let dot = col[j] + dot_chunked(&v, &col[j + 1..]);
                    let s = tau_j * dot;
                    col[j] -= s;
                    for i in j + 1..rows {
                        col[i] -= v[i - j - 1] * s;
                    }
                }
                continue;
            }
            let group = TaskGroup::new(self.pool.clone());
            let slots: Arc<Mutex<Vec<Option<Vec<(usize, Vec<f64>)>>>>> =
                Arc::new(Mutex::new(vec![None; ranges.len()]));
            for (idx, &(a, b)) in ranges.iter().enumerate() {
                // Move this lane's columns out of the panel; they come
                // back through the slot after the barrier.
                let mut group_cols: Vec<(usize, Vec<f64>)> = (j + 1 + a..j + 1 + b)
                    .map(|c| (c, std::mem::take(&mut a_cols[c])))
                    .collect();
                let v = Arc::clone(&v);
                let slots = Arc::clone(&slots);
                group.spawn(move || {
                    update_group(&mut group_cols, &v, j, rows, tau_j);
                    slots.lock().unwrap()[idx] = Some(group_cols);
                });
            }
            group.wait_idle();
            let mut filled = slots.lock().unwrap();
            for slot in filled.iter_mut() {
                // A lost lane would leave empty columns behind; treat
                // it as fatal rather than factor garbage.
                let lane = slot.take().expect("threaded factor lane lost (worker panic)");
                for (c, col) in lane {
                    a_cols[c] = col;
                }
            }
        }
    }

    /// Factor a dense stacked input into the `[r, packed, tau]` output
    /// convention of `LeafQr`/`Combine`.
    fn factor_outputs(&self, rows: usize, cols: usize, data: Vec<f64>) -> Vec<Matrix> {
        let mut a_cols: Vec<Vec<f64>> =
            (0..cols).map(|c| (0..rows).map(|i| data[i * cols + c]).collect()).collect();
        let mut tau = vec![0.0f64; cols];
        self.factor_f64(&mut a_cols, rows, &mut tau);
        let packed =
            Matrix::from_fn(rows, cols, |i, j| a_cols[j][i] as f32);
        let mut r = Matrix::zeros(cols, cols);
        for i in 0..cols.min(rows) {
            for j in i..cols {
                r[(i, j)] = a_cols[j][i] as f32;
            }
        }
        let tau32: Vec<f32> = tau.iter().map(|&t| t as f32).collect();
        vec![r, packed, Matrix::from_vec(cols, 1, tau32)]
    }
}

/// One factor lane: apply reflector `j` (tail `v`, `v[j] = 1`
/// implicit) to this lane's owned trailing columns.
fn update_group(group_cols: &mut [(usize, Vec<f64>)], v: &[f64], j: usize, rows: usize, tau_j: f64) {
    for (_, col) in group_cols.iter_mut() {
        let dot = col[j] + dot_chunked(v, &col[j + 1..]);
        let s = tau_j * dot;
        col[j] -= s;
        for i in j + 1..rows {
            col[i] -= v[i - j - 1] * s;
        }
    }
}

impl Kernel for ThreadedKernel {
    fn name(&self) -> &'static str {
        "threaded"
    }

    fn wants_workspace(&self, op: KernelOp) -> bool {
        // Only the delegated compact-WY family consumes the caller's
        // pooled workspace; the factor ops use their own f64 buffers
        // and the slab ops give each lane a private scratch arena.
        // Exhaustive for the same reason as the host table.
        match op {
            KernelOp::BuildT
            | KernelOp::ApplyWy
            | KernelOp::ApplyQWy
            | KernelOp::BuildQPanel => true,
            KernelOp::LeafQr
            | KernelOp::LeafR
            | KernelOp::Combine
            | KernelOp::CombineR
            | KernelOp::Backsolve
            | KernelOp::ApplyQt
            | KernelOp::ApplyUpdate
            | KernelOp::BuildQ
            | KernelOp::EncodeChecksum
            | KernelOp::ReconstructBlock => false,
        }
    }

    fn execute(&self, call: KernelCall<'_>) -> Result<Vec<Matrix>> {
        let v = call.views;
        match call.op {
            // ---- Tolerance: chunked-reduction factorizations -------
            KernelOp::LeafQr => {
                let (m, n) = v[0].shape();
                let data: Vec<f64> = v[0].data().iter().map(|&x| x as f64).collect();
                Ok(self.factor_outputs(m, n, data))
            }
            KernelOp::LeafR => {
                let (m, n) = v[0].shape();
                let data: Vec<f64> = v[0].data().iter().map(|&x| x as f64).collect();
                let mut out = self.factor_outputs(m, n, data);
                out.truncate(1);
                Ok(out)
            }
            KernelOp::Combine | KernelOp::CombineR => {
                let n = v[0].cols();
                let m = v[0].rows() + v[1].rows();
                let mut data = Vec::with_capacity(m * n);
                data.extend(v[0].data().iter().map(|&x| x as f64));
                data.extend(v[1].data().iter().map(|&x| x as f64));
                let mut out = self.factor_outputs(m, n, data);
                if call.op == KernelOp::CombineR {
                    out.truncate(1);
                }
                Ok(out)
            }
            // ---- Bitwise: column slabs ----------------------------
            KernelOp::ApplyUpdate => {
                let packed = Arc::new(v[0].to_matrix());
                let tau = Arc::new(v[1].to_matrix());
                let block = Arc::new(v[2].to_matrix());
                let (rows, k) = block.shape();
                let slabs = self.fan_out(call.op, k, move |c0, c1| {
                    let slab = Self::column_slab(&block, c0, c1);
                    let mut out = Matrix::zeros(slab.rows(), slab.cols());
                    let mut ws = Workspace::new();
                    view::apply_update_into(
                        packed.as_view(),
                        tau.data(),
                        slab.as_view(),
                        &mut out.as_view_mut(),
                        &mut ws,
                    );
                    out
                })?;
                Ok(vec![Self::stitch_columns(rows, k, slabs)])
            }
            KernelOp::ApplyQt => {
                let packed = Arc::new(v[0].to_matrix());
                let tau = Arc::new(v[1].to_matrix());
                let b = Arc::new(v[2].to_matrix());
                let (rows, k) = b.shape();
                let slabs = self.fan_out(call.op, k, move |c0, c1| {
                    let mut slab = Self::column_slab(&b, c0, c1);
                    view::apply_qt_in_place(packed.as_view(), tau.data(), &mut slab.as_view_mut());
                    slab
                })?;
                Ok(vec![Self::stitch_columns(rows, k, slabs)])
            }
            KernelOp::Backsolve => {
                let r = Arc::new(v[0].to_matrix());
                let b = Arc::new(v[1].to_matrix());
                let (rows, k) = (r.rows(), b.cols());
                let slabs = self.fan_out(call.op, k, move |c0, c1| {
                    let slab = Self::column_slab(&b, c0, c1);
                    let mut out = Matrix::zeros(r.rows(), slab.cols());
                    view::backsolve_into(r.as_view(), slab.as_view(), &mut out.as_view_mut());
                    out
                })?;
                Ok(vec![Self::stitch_columns(rows, k, slabs)])
            }
            KernelOp::BuildQ => {
                let packed = Arc::new(v[0].to_matrix());
                let tau = Arc::new(v[1].to_matrix());
                let (m, n) = packed.shape();
                let slabs = self.fan_out(call.op, n, move |c0, c1| {
                    // Each lane seeds its own identity columns of E.
                    let mut slab = Matrix::from_fn(m, c1 - c0, |i, j| {
                        if i == c0 + j { 1.0 } else { 0.0 }
                    });
                    view::apply_q_in_place(packed.as_view(), tau.data(), &mut slab.as_view_mut());
                    slab
                })?;
                Ok(vec![Self::stitch_columns(m, n, slabs)])
            }
            // ---- Bitwise: row slabs (element-wise checksum ops) ----
            KernelOp::EncodeChecksum => {
                let weights = Arc::new(v[0].to_matrix());
                let blocks: Arc<Vec<Matrix>> =
                    Arc::new(v[1..].iter().map(|b| b.to_matrix()).collect());
                let rows = blocks[0].rows();
                let pad = blocks.iter().map(|b| b.cols()).max().unwrap_or(0);
                let slabs = self.fan_out(call.op, rows, move |r0, r1| {
                    let parts: Vec<Matrix> =
                        blocks.iter().map(|b| b.row_block(r0, r1)).collect();
                    let views: Vec<_> = parts.iter().map(|p| p.as_view()).collect();
                    let mut out = Matrix::zeros(r1 - r0, pad);
                    let mut ws = Workspace::new();
                    crate::abft::kernels::encode_checksum_into(
                        weights.as_view(),
                        &views,
                        &mut out.as_view_mut(),
                        &mut ws,
                    );
                    out
                })?;
                Ok(vec![Self::stitch_rows(rows, pad, slabs)])
            }
            KernelOp::ReconstructBlock => {
                let weights = Arc::new(v[0].to_matrix());
                let checksum = Arc::new(v[1].to_matrix());
                let survivors: Arc<Vec<Matrix>> =
                    Arc::new(v[2..].iter().map(|s| s.to_matrix()).collect());
                let (rows, pad) = checksum.shape();
                let slabs = self.fan_out(call.op, rows, move |r0, r1| {
                    let cs = checksum.row_block(r0, r1);
                    let parts: Vec<Matrix> =
                        survivors.iter().map(|s| s.row_block(r0, r1)).collect();
                    let views: Vec<_> = parts.iter().map(|p| p.as_view()).collect();
                    let mut out = Matrix::zeros(r1 - r0, pad);
                    let mut ws = Workspace::new();
                    crate::abft::kernels::reconstruct_block_into(
                        weights.as_view(),
                        cs.as_view(),
                        &views,
                        &mut out.as_view_mut(),
                        &mut ws,
                    );
                    out
                })?;
                Ok(vec![Self::stitch_rows(rows, pad, slabs)])
            }
            // ---- Bitwise: delegated compact-WY family --------------
            KernelOp::BuildT
            | KernelOp::ApplyWy
            | KernelOp::ApplyQWy
            | KernelOp::BuildQPanel => HostKernel.execute(call),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::MatrixView;

    fn run(kernel: &dyn Kernel, op: KernelOp, views: &[MatrixView<'_>]) -> Vec<Matrix> {
        let mut ws = Workspace::new();
        kernel.execute(KernelCall { op, views, workspace: &mut ws }).unwrap()
    }

    #[test]
    fn backend_plan_selects_defaults_and_overrides() {
        let plan = BackendPlan::default();
        assert_eq!(plan.select(KernelOp::LeafQr), BackendChoice::Host);
        assert!(!plan.uses_threaded());
        let plan = BackendPlan::threaded();
        assert!(plan.uses_threaded());
        for op in KernelOp::ALL {
            assert_eq!(plan.select(op), BackendChoice::Threaded);
        }
        let plan = BackendPlan::host().with_op(KernelOp::ApplyUpdate, BackendChoice::Threaded);
        assert_eq!(plan.select(KernelOp::ApplyUpdate), BackendChoice::Threaded);
        assert_eq!(plan.select(KernelOp::LeafQr), BackendChoice::Host);
        assert!(plan.uses_threaded());
        // Last write wins.
        let plan = plan.with_op(KernelOp::ApplyUpdate, BackendChoice::Host);
        assert_eq!(plan.select(KernelOp::ApplyUpdate), BackendChoice::Host);
        assert!(!plan.uses_threaded());
    }

    #[test]
    fn backend_plan_parses_and_prints() {
        assert_eq!("host".parse::<BackendPlan>().unwrap(), BackendPlan::host());
        assert_eq!("threaded".parse::<BackendPlan>().unwrap(), BackendPlan::threaded());
        assert!("gpu".parse::<BackendPlan>().is_err());
        assert_eq!(BackendPlan::threaded().to_string(), "threaded");
        assert_eq!(
            BackendPlan::host()
                .with_op(KernelOp::LeafQr, BackendChoice::Threaded)
                .to_string(),
            "host+1"
        );
    }

    #[test]
    fn slab_ranges_cover_and_never_empty() {
        assert_eq!(slab_ranges(0, 4), Vec::<(usize, usize)>::new());
        for total in [1usize, 2, 7, 64, 65] {
            for lanes in [1usize, 2, 3, 8, 100] {
                let r = slab_ranges(total, lanes);
                assert!(r.len() <= lanes.min(total).max(1));
                assert_eq!(r[0].0, 0);
                assert_eq!(r.last().unwrap().1, total);
                for w in r.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "contiguous");
                }
                assert!(r.iter().all(|&(a, b)| b > a), "non-empty");
            }
        }
    }

    #[test]
    fn dot_chunked_is_deterministic_and_close_to_plain() {
        let a: Vec<f64> = (0..333).map(|i| (i as f64 * 0.37).sin()).collect();
        let b: Vec<f64> = (0..333).map(|i| (i as f64 * 0.11).cos()).collect();
        let d1 = dot_chunked(&a, &b);
        let d2 = dot_chunked(&a, &b);
        assert_eq!(d1.to_bits(), d2.to_bits());
        let plain: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((d1 - plain).abs() < 1e-10 * plain.abs().max(1.0));
    }

    #[test]
    fn chunked_factor_core_matches_reference_within_tolerance() {
        // Same convention as factor_panel_f64 (sign, tau, packed
        // layout), different association: R agrees to f64 rounding
        // noise, tau/packed stay interoperable with the host apply
        // kernels (Q from one, R from the other, reconstructs A).
        let a = Matrix::random(48, 12, 7);
        let mut w: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
        let mut tau = vec![0.0f64; 12];
        factor_panel_chunked_f64(&mut w, 48, 12, &mut tau);
        let mut w_ref: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
        let mut tau_ref = vec![0.0f64; 12];
        view::factor_panel_f64(&mut w_ref, 48, 12, &mut tau_ref);
        for (x, y) in w.iter().zip(&w_ref) {
            assert!((x - y).abs() < 1e-9, "packed drifted: {x} vs {y}");
        }
        for (x, y) in tau.iter().zip(&tau_ref) {
            assert!((x - y).abs() < 1e-9, "tau drifted: {x} vs {y}");
        }
    }

    #[test]
    fn threaded_factor_ops_satisfy_their_r_tolerance() {
        let threaded = ThreadedKernel::new();
        for &(m, n) in &[(16usize, 4usize), (40, 33), (7, 1), (64, 32)] {
            let a = Matrix::random(m, n, (m * 31 + n) as u64);
            let views = [a.as_view()];
            let got = run(&threaded, KernelOp::LeafQr, &views);
            let want = run(&HostKernel, KernelOp::LeafQr, &views);
            let bound = KernelOp::LeafQr.contract().bound(n, a.fro_norm());
            let diff = got[0].canonicalize_r().max_abs_diff(&want[0].canonicalize_r());
            assert!(diff <= bound, "LeafQr {m}x{n}: diff {diff} > bound {bound}");
            // R-only variant returns the same leading output.
            let r_only = run(&threaded, KernelOp::LeafR, &views);
            assert_eq!(r_only.len(), 1);
            assert_eq!(r_only[0], got[0]);
        }
    }

    #[test]
    fn threaded_slab_ops_are_bitwise_vs_host() {
        let threaded = ThreadedKernel::new();
        let a = Matrix::random(40, 8, 3);
        let host_f = run(&HostKernel, KernelOp::LeafQr, &[a.as_view()]);
        let (packed, tau) = (&host_f[1], &host_f[2]);
        let block = Matrix::random(40, 13, 4);
        for op in [KernelOp::ApplyUpdate, KernelOp::ApplyQt] {
            let views = [packed.as_view(), tau.as_view(), block.as_view()];
            let got = run(&threaded, op, &views);
            let want = run(&HostKernel, op, &views);
            assert_eq!(got[0], want[0], "{op:?} must be bitwise");
        }
        let views = [packed.as_view(), tau.as_view()];
        let got = run(&threaded, KernelOp::BuildQ, &views);
        let want = run(&HostKernel, KernelOp::BuildQ, &views);
        assert_eq!(got[0], want[0], "BuildQ must be bitwise");

        let r = &host_f[0];
        let rhs = Matrix::random(8, 9, 5);
        let views = [r.as_view(), rhs.as_view()];
        let got = run(&threaded, KernelOp::Backsolve, &views);
        let want = run(&HostKernel, KernelOp::Backsolve, &views);
        assert_eq!(got[0], want[0], "Backsolve must be bitwise");
    }

    #[test]
    fn threaded_checksum_ops_are_bitwise_vs_host() {
        let threaded = ThreadedKernel::new();
        let blocks: Vec<Matrix> = (0..3).map(|s| Matrix::random(17, 4, s + 9)).collect();
        let weights = Matrix::from_vec(1, 3, vec![1.0, 2.0, 4.0]);
        let mut views = vec![weights.as_view()];
        views.extend(blocks.iter().map(|b| b.as_view()));
        let got = run(&threaded, KernelOp::EncodeChecksum, &views);
        let want = run(&HostKernel, KernelOp::EncodeChecksum, &views);
        assert_eq!(got[0], want[0], "EncodeChecksum must be bitwise");

        let rec_views = [
            weights.as_view(),
            want[0].as_view(),
            blocks[1].as_view(),
            blocks[2].as_view(),
        ];
        let got = run(&threaded, KernelOp::ReconstructBlock, &rec_views);
        let want = run(&HostKernel, KernelOp::ReconstructBlock, &rec_views);
        assert_eq!(got[0], want[0], "ReconstructBlock must be bitwise");
    }
}
