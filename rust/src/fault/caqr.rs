//! Fault injection for the CAQR subsystem: kills that strike *inside*
//! a panel step — during the panel factorization or, crucially, during
//! the trailing-matrix updates the general-matrix extension
//! (arXiv:1604.02504) replicates.
//!
//! TSQR's [`super::KillSchedule`] is round-granular; CAQR failures are
//! `(rank, panel, stage)`-granular: a process killed at
//! `(r, k, Update)` completed panel `k`'s factor stage but dies before
//! its trailing-update results for panel `k` can be harvested — its
//! blocks are recovered from the surviving replica, mid-factorization.

use std::collections::HashSet;
use std::sync::Mutex;

use crate::ulfm::Rank;
use crate::util::Rng;

/// Which stage of a panel step a kill strikes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum CaqrStage {
    /// The redundant panel factorization of the block column.
    Factor,
    /// The replicated trailing-matrix updates — the failure mode the
    /// general-matrix paper adds over plain TSQR.
    Update,
    /// The post-factorization Q-assembly phase: the replicated tasks
    /// that expand the stored WY/Householder reflector chain into the
    /// explicit `m × n` Q, one column block per replica pair.  A kill
    /// here strikes *after* every panel completed — the reflector
    /// state itself is what must survive (arXiv:2311.11943's coded
    /// factorization closes exactly this gap).
    QAssembly,
    /// The `apply_q` phase: replicated tasks applying `Qᵀ` to a
    /// caller-supplied operand (the least-squares / verification
    /// workload).  Same replica-pair + checksum protection as
    /// [`QAssembly`](Self::QAssembly).
    ApplyQ,
}

impl CaqrStage {
    /// Stable name (`factor` / `update` / `q-assembly` / `apply-q`).
    pub fn name(&self) -> &'static str {
        match self {
            CaqrStage::Factor => "factor",
            CaqrStage::Update => "update",
            CaqrStage::QAssembly => "q-assembly",
            CaqrStage::ApplyQ => "apply-q",
        }
    }

    /// True for the post-factorization Q-protection phases
    /// ([`QAssembly`](Self::QAssembly) / [`ApplyQ`](Self::ApplyQ)):
    /// these run once after the panel loop, not once per panel.
    pub fn is_q_phase(&self) -> bool {
        matches!(self, CaqrStage::QAssembly | CaqrStage::ApplyQ)
    }
}

/// One-shot CAQR kill schedule shared by every task of a run.
///
/// Entries are `(rank, panel, stage)`: the rank dies at that point of
/// the factorization.  Like [`super::KillSchedule`], entries are
/// consumed on fire, so a respawned incarnation (Self-Healing mode) is
/// not re-killed by the same entry.
#[derive(Debug, Default)]
pub struct CaqrKillSchedule {
    pending: Mutex<HashSet<(Rank, usize, CaqrStage)>>,
}

impl CaqrKillSchedule {
    /// No failures (fault-free execution).
    pub fn none() -> Self {
        Self::default()
    }

    /// Explicit list of `(rank, panel, stage)` kills.
    pub fn at(entries: &[(Rank, usize, CaqrStage)]) -> Self {
        Self { pending: Mutex::new(entries.iter().copied().collect()) }
    }

    /// Exactly `f` distinct ranks die during a uniformly random
    /// panel's *update* stage (the general-matrix failure model the
    /// survival sweeps measure).
    pub fn random_updates(procs: usize, panels: usize, f: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut pool: Vec<Rank> = (0..procs).collect();
        let mut set = HashSet::new();
        let panels = panels.max(1);
        for _ in 0..f.min(procs) {
            let i = rng.below(pool.len());
            let rank = pool.swap_remove(i);
            let panel = rng.below(panels);
            set.insert((rank, panel, CaqrStage::Update));
        }
        Self { pending: Mutex::new(set) }
    }

    /// Rate-based schedule: every `(rank, panel, stage)` cell fails
    /// independently with probability `1 − exp(−rate)` — the discrete
    /// hazard of a Poisson process with `rate` expected failures per
    /// rank per stage.  This is the bridge between the paper's
    /// "f failures" counting semantics and the failure-*rate* semantics
    /// the [`crate::sim`] campaigns sweep: at small rates the expected
    /// kill count is `2 · procs · panels · rate`.
    ///
    /// Deterministic per `(procs, panels, rate, seed)`; cells are drawn
    /// in `(rank, panel, Factor→Update)` order from one
    /// [`Rng`] stream.
    pub fn poisson(procs: usize, panels: usize, rate: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let p = 1.0 - (-rate.max(0.0)).exp();
        let mut set = HashSet::new();
        for rank in 0..procs {
            for panel in 0..panels {
                for stage in [CaqrStage::Factor, CaqrStage::Update] {
                    if rng.bool(p) {
                        set.insert((rank, panel, stage));
                    }
                }
            }
        }
        Self { pending: Mutex::new(set) }
    }

    /// Should `rank` die at `(panel, stage)`?  Consumes the entry.
    pub fn fire(&self, rank: Rank, panel: usize, stage: CaqrStage) -> bool {
        self.pending.lock().unwrap().remove(&(rank, panel, stage))
    }

    /// Fire **every** pending entry of `stage` for `rank`, regardless
    /// of its panel coordinate.  The Q phases run once after the panel
    /// loop, so all their kills strike at the phase entry — the panel
    /// field of a Q-stage entry is documentation, not a firing time.
    /// Consumes the entries; returns true if any fired.
    pub fn fire_stage(&self, rank: Rank, stage: CaqrStage) -> bool {
        let mut pending = self.pending.lock().unwrap();
        let before = pending.len();
        pending.retain(|&(r, _, s)| !(r == rank && s == stage));
        pending.len() != before
    }

    /// Does any pending entry strike one of the post-factorization Q
    /// phases?  (Arms the Q phases in the executor's timeline.)
    pub fn has_q_stage(&self) -> bool {
        self.pending.lock().unwrap().iter().any(|&(_, _, s)| s.is_q_phase())
    }

    /// Remaining entries (diagnostics).
    pub fn remaining(&self) -> usize {
        self.pending.lock().unwrap().len()
    }

    /// All scheduled kills, sorted (diagnostics / reports).
    pub fn entries(&self) -> Vec<(Rank, usize, CaqrStage)> {
        let mut v: Vec<_> = self.pending.lock().unwrap().iter().copied().collect();
        v.sort_unstable();
        v
    }
}

/// Deterministically kill **both** members of a replica pair at one
/// `(panel, stage)` — the failure replication alone cannot survive.
///
/// The pair of `rank` is `{rank & !1, rank | 1}` (the round-0 buddy
/// pairing every CAQR task replicates across), so a pair wipe destroys
/// every copy of the tasks that pair owned at that stage.  Under
/// [`RecoveryPolicy::Replica`] the run aborts there; with checksums
/// ([`RecoveryPolicy::Hybrid`]) the lost results are reconstructed —
/// `tests/integration_abft.rs` pins both outcomes on every
/// `(rank, panel, stage)`.
///
/// [`RecoveryPolicy::Replica`]: crate::abft::RecoveryPolicy::Replica
/// [`RecoveryPolicy::Hybrid`]: crate::abft::RecoveryPolicy::Hybrid
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PairWipeSchedule {
    /// Either member of the pair to wipe.
    pub rank: Rank,
    /// Panel whose stage the wipe strikes.
    pub panel: usize,
    /// Stage (factor or update) the wipe strikes.
    pub stage: CaqrStage,
}

impl PairWipeSchedule {
    /// Wipe the pair containing `rank` at `(panel, stage)`.
    pub fn new(rank: Rank, panel: usize, stage: CaqrStage) -> Self {
        Self { rank, panel, stage }
    }

    /// The two ranks this schedule kills (lower first).
    pub fn pair(&self) -> (Rank, Rank) {
        (self.rank & !1, self.rank | 1)
    }

    /// The `(rank, panel, stage)` kill entries, lower rank first.
    pub fn kills(&self) -> Vec<(Rank, usize, CaqrStage)> {
        let (a, b) = self.pair();
        vec![(a, self.panel, self.stage), (b, self.panel, self.stage)]
    }

    /// Materialize the one-shot kill schedule.
    pub fn schedule(&self) -> CaqrKillSchedule {
        CaqrKillSchedule::at(&self.kills())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_schedule_fires_once() {
        let s = CaqrKillSchedule::at(&[(1, 2, CaqrStage::Update)]);
        assert!(!s.fire(1, 2, CaqrStage::Factor), "stage is part of the key");
        assert!(!s.fire(1, 1, CaqrStage::Update));
        assert!(s.fire(1, 2, CaqrStage::Update));
        assert!(!s.fire(1, 2, CaqrStage::Update), "one-shot");
        assert_eq!(s.remaining(), 0);
    }

    #[test]
    fn none_never_fires() {
        let s = CaqrKillSchedule::none();
        assert!(!s.fire(0, 0, CaqrStage::Factor));
        assert_eq!(s.remaining(), 0);
    }

    #[test]
    fn random_updates_deterministic_and_distinct_ranks() {
        let a = CaqrKillSchedule::random_updates(8, 4, 3, 7).entries();
        let b = CaqrKillSchedule::random_updates(8, 4, 3, 7).entries();
        assert_eq!(a, b, "same seed, same schedule");
        assert_eq!(a.len(), 3);
        assert!(a.iter().all(|&(_, p, st)| p < 4 && st == CaqrStage::Update));
        let mut ranks: Vec<Rank> = a.iter().map(|&(r, _, _)| r).collect();
        ranks.sort_unstable();
        ranks.dedup();
        assert_eq!(ranks.len(), 3, "distinct ranks");
        assert_ne!(a, CaqrKillSchedule::random_updates(8, 4, 3, 8).entries());
    }

    #[test]
    fn random_updates_caps_at_world_size() {
        assert_eq!(CaqrKillSchedule::random_updates(4, 2, 10, 1).remaining(), 4);
    }

    #[test]
    fn poisson_schedule_rate_extremes_and_determinism() {
        assert_eq!(CaqrKillSchedule::poisson(8, 4, 0.0, 9).remaining(), 0, "rate 0 kills nobody");
        // rate → ∞ saturates every cell: procs × panels × 2 stages.
        assert_eq!(CaqrKillSchedule::poisson(4, 3, 1e9, 9).remaining(), 24);
        let a = CaqrKillSchedule::poisson(16, 8, 0.3, 42).entries();
        assert_eq!(a, CaqrKillSchedule::poisson(16, 8, 0.3, 42).entries(), "seeded");
        assert_ne!(a, CaqrKillSchedule::poisson(16, 8, 0.3, 43).entries());
        assert!(a.iter().all(|&(r, k, _)| r < 16 && k < 8), "cells in range");
        // Expected count 2·16·8·(1−e^−0.3) ≈ 66 of 256 cells; a seeded
        // draw sits well inside ±5σ of that.
        assert!(a.len() > 30 && a.len() < 110, "got {}", a.len());
    }

    #[test]
    fn stage_names() {
        assert_eq!(CaqrStage::Factor.name(), "factor");
        assert_eq!(CaqrStage::Update.name(), "update");
        assert_eq!(CaqrStage::QAssembly.name(), "q-assembly");
        assert_eq!(CaqrStage::ApplyQ.name(), "apply-q");
        assert!(CaqrStage::QAssembly.is_q_phase());
        assert!(CaqrStage::ApplyQ.is_q_phase());
        assert!(!CaqrStage::Factor.is_q_phase());
        assert!(!CaqrStage::Update.is_q_phase());
    }

    #[test]
    fn q_stage_kills_fire_by_stage_not_panel() {
        let s = CaqrKillSchedule::at(&[
            (1, 0, CaqrStage::QAssembly),
            (1, 3, CaqrStage::QAssembly),
            (2, 0, CaqrStage::ApplyQ),
            (1, 0, CaqrStage::Update),
        ]);
        assert!(s.has_q_stage());
        // fire_stage drains every panel coordinate of that stage for the rank.
        assert!(s.fire_stage(1, CaqrStage::QAssembly));
        assert!(!s.fire_stage(1, CaqrStage::QAssembly), "consumed");
        // Other stages and ranks are untouched.
        assert!(s.fire(1, 0, CaqrStage::Update));
        assert!(s.fire_stage(2, CaqrStage::ApplyQ));
        assert_eq!(s.remaining(), 0);
        assert!(!CaqrKillSchedule::none().has_q_stage());
        // Update/Factor entries never read as Q-phase arming.
        let plain = CaqrKillSchedule::at(&[(0, 0, CaqrStage::Update)]);
        assert!(!plain.has_q_stage());
    }

    #[test]
    fn pair_wipe_strikes_q_phases_too() {
        let w = PairWipeSchedule::new(2, 0, CaqrStage::QAssembly);
        let s = w.schedule();
        assert!(s.has_q_stage());
        assert!(s.fire_stage(2, CaqrStage::QAssembly));
        assert!(s.fire_stage(3, CaqrStage::QAssembly));
        assert_eq!(s.remaining(), 0);
    }

    #[test]
    fn pair_wipe_kills_both_buddies() {
        let w = PairWipeSchedule::new(3, 1, CaqrStage::Update);
        assert_eq!(w.pair(), (2, 3));
        assert_eq!(PairWipeSchedule::new(2, 1, CaqrStage::Update).pair(), (2, 3));
        assert_eq!(
            w.kills(),
            vec![(2, 1, CaqrStage::Update), (3, 1, CaqrStage::Update)]
        );
        let s = w.schedule();
        assert!(s.fire(2, 1, CaqrStage::Update));
        assert!(s.fire(3, 1, CaqrStage::Update));
        assert_eq!(s.remaining(), 0);
    }
}
