//! Named failure scenarios — including the exact executions depicted in
//! the paper's Figures 3, 4 and 5 (4 processes, P2 crashes at the end
//! of the first step), plus parametric scenarios the benches sweep.

use crate::tsqr::{Algo, RunSpec};
use crate::ulfm::Rank;

use super::injector::KillSchedule;

/// A named, reproducible failure scenario.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Stable lookup name.
    pub name: &'static str,
    /// One-line description of what it demonstrates.
    pub description: &'static str,
    /// The algorithm it runs under.
    pub algo: Algo,
    /// World size.
    pub procs: usize,
    /// The `(rank, round)` kills.
    pub kills: Vec<(Rank, u32)>,
}

impl Scenario {
    /// Figure 3: Redundant TSQR, 4 processes, P2 crashes at the end of
    /// step 1 (it computed R̃₁ but never exchanges at round 1).
    /// Expected: P0 exits (needed P2), P1 & P3 finish with the final R.
    pub fn fig3() -> Self {
        Scenario {
            name: "fig3",
            description: "Redundant TSQR, 4 procs, P2 dies end of step 1 → \
                          P0 gives up, P1 & P3 hold final R",
            algo: Algo::Redundant,
            procs: 4,
            kills: vec![(2, 1)],
        }
    }

    /// Figure 4: Replace TSQR, same failure. Expected: P0 finds replica
    /// P3 and finishes; P1 & P3 finish too — root P0 holds R.
    pub fn fig4() -> Self {
        Scenario {
            name: "fig4",
            description: "Replace TSQR, 4 procs, P2 dies end of step 1 → \
                          P0 exchanges with replica P3; P0, P1, P3 hold final R",
            algo: Algo::Replace,
            procs: 4,
            kills: vec![(2, 1)],
        }
    }

    /// Figure 5: Self-Healing TSQR, same failure. Expected: P2 is
    /// respawned, recovers R̃₁ from P3, and ALL FOUR processes finish
    /// with the final R (world restored to full size).
    pub fn fig5() -> Self {
        Scenario {
            name: "fig5",
            description: "Self-Healing TSQR, 4 procs, P2 dies end of step 1 → \
                          respawned from P3's replica; all 4 ranks hold final R",
            algo: Algo::SelfHealing,
            procs: 4,
            kills: vec![(2, 1)],
        }
    }

    /// Baseline TSQR with the same failure — shows the ABORT behaviour
    /// the fault-tolerant variants avoid.
    pub fn baseline_abort() -> Self {
        Scenario {
            name: "baseline-abort",
            description: "Plain TSQR, 4 procs, P2 dies end of step 1 → \
                          computation aborts (root never gets R)",
            algo: Algo::Baseline,
            procs: 4,
            kills: vec![(2, 1)],
        }
    }

    /// All named scenarios.
    pub fn all() -> Vec<Scenario> {
        vec![Self::fig3(), Self::fig4(), Self::fig5(), Self::baseline_abort()]
    }

    /// Look a scenario up by name.
    pub fn by_name(name: &str) -> Option<Scenario> {
        Self::all().into_iter().find(|s| s.name == name)
    }

    /// Materialize a run spec (tracing on — scenarios exist to be read).
    pub fn spec(&self, rows_per_proc: usize, cols: usize) -> RunSpec {
        RunSpec::new(self.algo, self.procs, rows_per_proc, cols)
            .with_schedule(KillSchedule::at(&self.kills))
            .with_trace(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_is_complete_and_named_uniquely() {
        let all = Scenario::all();
        assert_eq!(all.len(), 4);
        let mut names: Vec<_> = all.iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 4);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(Scenario::by_name("fig4").unwrap().algo, Algo::Replace);
        assert!(Scenario::by_name("fig9").is_none());
    }

    #[test]
    fn figure_scenarios_match_paper_setup() {
        for s in [Scenario::fig3(), Scenario::fig4(), Scenario::fig5()] {
            assert_eq!(s.procs, 4);
            assert_eq!(s.kills, vec![(2, 1)], "P2 dies at end of step 1");
        }
    }

    #[test]
    fn spec_materialization() {
        let spec = Scenario::fig3().spec(16, 4);
        assert_eq!(spec.procs, 4);
        assert!(spec.collect_trace);
        assert_eq!(spec.schedule.entries(), vec![(2, 1)]);
    }
}
