//! Deterministic fault injection.
//!
//! The paper's robustness analysis is *step-granular* ("no more than 1
//! process has failed by the end of step 1, no more than 3 by the end
//! of step 2, ..."), so kills are injected at exchange-round
//! boundaries: a schedule entry `(rank, round)` crashes `rank` right
//! before it would post for exchange round `round` — i.e. the process
//! completed paper-step `round` (it holds R̃_round) but never takes
//! part in the round-`round` exchange.  That is exactly Figure 3's
//! "P2 crashes at the end of the first step".
//!
//! Entries are one-shot: a respawned incarnation (Self-Healing) is not
//! re-killed by the same entry, but *can* be killed by a later entry
//! for the same rank.

use std::collections::HashSet;
use std::sync::Mutex;

use crate::ulfm::Rank;
use crate::util::Rng;

/// One-shot kill schedule shared by all simulated processes.
#[derive(Debug, Default)]
pub struct KillSchedule {
    pending: Mutex<HashSet<(Rank, u32)>>,
}

impl KillSchedule {
    /// No failures (fault-free execution).
    pub fn none() -> Self {
        Self::default()
    }

    /// Explicit list of (rank, round) kills.
    pub fn at(entries: &[(Rank, u32)]) -> Self {
        Self { pending: Mutex::new(entries.iter().copied().collect()) }
    }

    /// Bernoulli model: every (rank, round) pair fails independently
    /// with probability `p` — the simplest per-step failure model.
    pub fn bernoulli(procs: usize, rounds: u32, p: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut set = HashSet::new();
        for rank in 0..procs {
            for round in 0..rounds {
                if rng.bool(p) {
                    set.insert((rank, round));
                    break; // a process dies at most once per schedule
                }
            }
        }
        Self { pending: Mutex::new(set) }
    }

    /// Exponential-lifetime model (Reed et al. [18]): each rank draws a
    /// lifetime T ~ Exp(rate) in units of steps and dies at the first
    /// round boundary past T (if within the run).
    pub fn exponential(procs: usize, rounds: u32, rate: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut set = HashSet::new();
        for rank in 0..procs {
            let t = rng.exponential(rate);
            let round = t.ceil() as u64;
            if round >= 1 && round <= rounds as u64 {
                // Dies at boundary `round` — completed `round` steps.
                set.insert((rank, round as u32));
            } else if round == 0 {
                set.insert((rank, 0));
            }
        }
        Self { pending: Mutex::new(set) }
    }

    /// Exactly `f` distinct ranks die at round boundary `round`
    /// (never rank `protect`, e.g. keep the tree root alive).
    pub fn random_at_round(
        procs: usize,
        round: u32,
        f: usize,
        protect: Option<Rank>,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed);
        let mut pool: Vec<Rank> = (0..procs).filter(|r| Some(*r) != protect).collect();
        let mut set = HashSet::new();
        for _ in 0..f.min(pool.len()) {
            let i = rng.below(pool.len());
            set.insert((pool.swap_remove(i), round));
        }
        Self { pending: Mutex::new(set) }
    }

    /// Should `rank` die at this round boundary?  Consumes the entry.
    pub fn fire(&self, rank: Rank, round: u32) -> bool {
        self.pending.lock().unwrap().remove(&(rank, round))
    }

    /// Remaining entries (diagnostics).
    pub fn remaining(&self) -> usize {
        self.pending.lock().unwrap().len()
    }

    /// All scheduled kills, sorted (diagnostics / reports).
    pub fn entries(&self) -> Vec<(Rank, u32)> {
        let mut v: Vec<_> = self.pending.lock().unwrap().iter().copied().collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_schedule_fires_once() {
        let s = KillSchedule::at(&[(2, 1)]);
        assert!(!s.fire(2, 0));
        assert!(s.fire(2, 1));
        assert!(!s.fire(2, 1), "one-shot");
        assert_eq!(s.remaining(), 0);
    }

    #[test]
    fn none_never_fires() {
        let s = KillSchedule::none();
        assert!(!s.fire(0, 0));
        assert_eq!(s.remaining(), 0);
    }

    #[test]
    fn bernoulli_deterministic_and_at_most_one_per_rank() {
        let a = KillSchedule::bernoulli(32, 5, 0.3, 7).entries();
        let b = KillSchedule::bernoulli(32, 5, 0.3, 7).entries();
        assert_eq!(a, b, "same seed, same schedule");
        let mut ranks: Vec<_> = a.iter().map(|(r, _)| *r).collect();
        ranks.sort_unstable();
        let before = ranks.len();
        ranks.dedup();
        assert_eq!(ranks.len(), before, "at most one death per rank");
        assert_ne!(a, KillSchedule::bernoulli(32, 5, 0.3, 8).entries());
    }

    #[test]
    fn bernoulli_extremes() {
        assert_eq!(KillSchedule::bernoulli(16, 4, 0.0, 1).remaining(), 0);
        assert_eq!(KillSchedule::bernoulli(16, 4, 1.0, 1).remaining(), 16);
    }

    #[test]
    fn random_at_round_count_and_protection() {
        let s = KillSchedule::random_at_round(16, 2, 5, Some(0), 3);
        let e = s.entries();
        assert_eq!(e.len(), 5);
        assert!(e.iter().all(|&(r, round)| r != 0 && round == 2));
    }

    #[test]
    fn random_at_round_caps_at_pool() {
        let s = KillSchedule::random_at_round(4, 0, 10, Some(0), 1);
        assert_eq!(s.remaining(), 3, "cannot kill more than the pool");
    }

    #[test]
    fn exponential_rates_scale() {
        // Higher rate => more deaths within the horizon.
        let low = KillSchedule::exponential(256, 6, 0.01, 11).remaining();
        let high = KillSchedule::exponential(256, 6, 0.5, 11).remaining();
        assert!(high > low, "high {high} <= low {low}");
    }
}
