//! Fault injection: deterministic and stochastic kill schedules plus
//! the paper's named failure scenarios (Figures 3–5).

pub mod injector;
pub mod scenario;

pub use injector::KillSchedule;
pub use scenario::Scenario;
