//! Fault injection: deterministic and stochastic kill schedules, the
//! paper's named failure scenarios (Figures 3–5), and the CAQR
//! `(rank, panel, stage)` schedules that strike trailing updates.

pub mod caqr;
pub mod injector;
pub mod scenario;

pub use caqr::{CaqrKillSchedule, CaqrStage, PairWipeSchedule};
pub use injector::KillSchedule;
pub use scenario::Scenario;
