//! Analytic cost model: flops, message counts and critical paths for
//! every algorithm variant — the quantities behind the paper's
//! communication-avoidance argument and our extended evaluation tables.

/// Flops of an unblocked Householder QR of an m×n tall-skinny panel:
/// 2mn² − (2/3)n³ (standard LAPACK count).
pub fn leaf_qr_flops(m: usize, n: usize) -> u64 {
    let (m, n) = (m as u64, n as u64);
    2 * m * n * n - (2 * n * n * n) / 3
}

/// Flops of the structure-aware TSQR combine of two n×n triangles:
/// reflector j has support on 1 + (j+1) rows, updating (n − j) columns:
/// Σ_j 4(j+2)(n−j) ≈ (2/3)n³ (vs (8/3)n³ for a dense 2n×n Householder).
pub fn combine_flops(n: usize) -> u64 {
    let n = n as u64;
    (0..n).map(|j| 4 * (j + 2) * (n - j)).sum()
}

/// Flops of a *dense* Householder QR of the stacked 2n×n pair —
/// what the combine would cost without exploiting the triangles.
pub fn combine_flops_dense(n: usize) -> u64 {
    leaf_qr_flops(2 * n, n)
}

/// Messages of one full run (fault-free), by algorithm family.
/// Baseline sends one R̃ per pair per round: P − 1 messages in total.
pub fn baseline_messages(procs: usize) -> u64 {
    (procs as u64).saturating_sub(1)
}

/// The redundant family exchanges (two directed messages per pair per
/// round): P·log2(P) messages in total — exactly twice the information
/// movement of baseline per round, on the same critical path.
pub fn redundant_messages(procs: usize) -> u64 {
    (procs as u64) * procs.trailing_zeros() as u64
}

/// Bytes of one R̃ message (f32 n×n — the full square is shipped; the
/// strictly-lower zeros could be compressed but the paper ships R̃).
pub fn message_bytes(n: usize) -> u64 {
    (n * n * 4) as u64
}

/// Total *computation* flops per process along the critical path:
/// leaf + one combine per round.  Communication-avoiding trade-off:
/// this grows with log2(P) while messages stay at one per round.
pub fn critical_path_flops(rows_per_proc: usize, n: usize, procs: usize) -> u64 {
    leaf_qr_flops(rows_per_proc, n) + procs.trailing_zeros() as u64 * combine_flops(n)
}

/// Total system flops, fault-free.
/// Baseline: P leaves + (P − 1) combines (one per tree node).
/// Redundant family: P leaves + P·log2(P) combines (every process
/// combines every round) — the redundancy the paper repurposes.
pub fn total_flops(algo_redundant: bool, procs: usize, rows_per_proc: usize, n: usize) -> u64 {
    let leaves = procs as u64 * leaf_qr_flops(rows_per_proc, n);
    let combines = if algo_redundant {
        (procs as u64) * procs.trailing_zeros() as u64
    } else {
        (procs as u64).saturating_sub(1)
    };
    leaves + combines * combine_flops(n)
}

/// Redundancy overhead ratio: extra flops of the redundant family over
/// baseline (→ the "price" of the free fault tolerance; tends to 0 as
/// the leaf dominates, i.e. rows_per_proc >> n·log P).
pub fn redundancy_flop_overhead(procs: usize, rows_per_proc: usize, n: usize) -> f64 {
    let base = total_flops(false, procs, rows_per_proc, n) as f64;
    let red = total_flops(true, procs, rows_per_proc, n) as f64;
    (red - base) / base
}

/// Where a simulated run's virtual time went — the discrete-event
/// simulator's ([`crate::sim`]) analogue of wall-clock profiling.
///
/// Every stage the runner schedules charges its duration to exactly one
/// bucket: useful work to `compute_ns`, modelled message latency (and
/// lossy retransmits) to `network_ns`, and ladder penalties — factor
/// re-execution, checksum reconstruction of wiped blocks — to
/// `recovery_ns`.  The buckets therefore sum to the run's total virtual
/// time, so `recovery_fraction()` is the stall share the paper's §III
/// recovery semantics cost under a given failure rate.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct VirtualTimeBreakdown {
    /// Virtual nanoseconds spent in factor/update work proper.
    pub compute_ns: u64,
    /// Virtual nanoseconds of modelled network latency, jitter and
    /// retransmits.
    pub network_ns: u64,
    /// Virtual nanoseconds of recovery stalls (rebuilds and checksum
    /// reconstructions).
    pub recovery_ns: u64,
}

impl VirtualTimeBreakdown {
    /// Sum of all buckets.
    pub fn total_ns(&self) -> u64 {
        self.compute_ns + self.network_ns + self.recovery_ns
    }

    /// Share of virtual time lost to recovery, in [0, 1] (0 for an
    /// empty breakdown).
    pub fn recovery_fraction(&self) -> f64 {
        let total = self.total_ns();
        if total == 0 { 0.0 } else { self.recovery_ns as f64 / total as f64 }
    }

    /// Accumulate another run's breakdown (campaign aggregation).
    pub fn merge(&mut self, other: &VirtualTimeBreakdown) {
        self.compute_ns += other.compute_ns;
        self.network_ns += other.network_ns;
        self.recovery_ns += other.recovery_ns;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_time_breakdown_accounting() {
        let mut t = VirtualTimeBreakdown::default();
        assert_eq!(t.total_ns(), 0);
        assert_eq!(t.recovery_fraction(), 0.0);
        t.merge(&VirtualTimeBreakdown { compute_ns: 60, network_ns: 20, recovery_ns: 20 });
        assert_eq!(t.total_ns(), 100);
        assert!((t.recovery_fraction() - 0.2).abs() < 1e-12);
        t.merge(&VirtualTimeBreakdown { compute_ns: 40, network_ns: 0, recovery_ns: 60 });
        assert_eq!(t, VirtualTimeBreakdown { compute_ns: 100, network_ns: 20, recovery_ns: 80 });
    }

    #[test]
    fn leaf_flops_formula() {
        // 2mn^2 - (2/3)n^3 at m=8, n=2: 64 - 5 = 59 (integer div).
        assert_eq!(leaf_qr_flops(8, 2), 59);
        assert!(leaf_qr_flops(1024, 32) > leaf_qr_flops(512, 32));
    }

    #[test]
    fn combine_cheaper_than_dense() {
        // (Constant factors dominate below n=4: at n=2 the structure-
        // aware loop's +2 row bookkeeping outweighs the saved flops.)
        for n in [4, 8, 16, 32, 64] {
            assert!(
                combine_flops(n) < combine_flops_dense(n),
                "structure-aware combine must beat dense at n={n}"
            );
        }
        // Asymptotic ratio ~ (2/3) / (8/3) = 1/4.
        let ratio = combine_flops(64) as f64 / combine_flops_dense(64) as f64;
        assert!(ratio < 0.35, "ratio {ratio}");
    }

    #[test]
    fn message_counts() {
        assert_eq!(baseline_messages(16), 15);
        assert_eq!(redundant_messages(16), 64); // 16 * 4 rounds
        assert_eq!(baseline_messages(1), 0);
        assert_eq!(redundant_messages(1), 0);
        assert_eq!(message_bytes(8), 256);
    }

    #[test]
    fn overhead_vanishes_with_tall_leaves() {
        let thin = redundancy_flop_overhead(16, 64, 32);
        let tall = redundancy_flop_overhead(16, 8192, 32);
        assert!(tall < thin, "taller leaves amortize redundancy");
        assert!(tall < 0.05, "paper's regime: redundancy nearly free ({tall})");
    }

    #[test]
    fn critical_path_grows_logarithmically() {
        let p4 = critical_path_flops(1024, 16, 4);
        let p16 = critical_path_flops(1024, 16, 16);
        assert_eq!(p16 - p4, 2 * combine_flops(16));
    }
}
