//! Analytic cost model: flops, message counts and critical paths for
//! every algorithm variant — the quantities behind the paper's
//! communication-avoidance argument and our extended evaluation tables.

/// Flops of an unblocked Householder QR of an m×n tall-skinny panel:
/// 2mn² − (2/3)n³ (standard LAPACK count).
pub fn leaf_qr_flops(m: usize, n: usize) -> u64 {
    let (m, n) = (m as u64, n as u64);
    2 * m * n * n - (2 * n * n * n) / 3
}

/// Flops of the structure-aware TSQR combine of two n×n triangles:
/// reflector j has support on 1 + (j+1) rows, updating (n − j) columns:
/// Σ_j 4(j+2)(n−j) ≈ (2/3)n³ (vs (8/3)n³ for a dense 2n×n Householder).
pub fn combine_flops(n: usize) -> u64 {
    let n = n as u64;
    (0..n).map(|j| 4 * (j + 2) * (n - j)).sum()
}

/// Flops of a *dense* Householder QR of the stacked 2n×n pair —
/// what the combine would cost without exploiting the triangles.
pub fn combine_flops_dense(n: usize) -> u64 {
    leaf_qr_flops(2 * n, n)
}

/// Messages of one full run (fault-free), by algorithm family.
/// Baseline sends one R̃ per pair per round: P − 1 messages in total.
pub fn baseline_messages(procs: usize) -> u64 {
    (procs as u64).saturating_sub(1)
}

/// The redundant family exchanges (two directed messages per pair per
/// round): P·log2(P) messages in total — exactly twice the information
/// movement of baseline per round, on the same critical path.
pub fn redundant_messages(procs: usize) -> u64 {
    (procs as u64) * procs.trailing_zeros() as u64
}

/// Bytes of one R̃ message (f32 n×n — the full square is shipped; the
/// strictly-lower zeros could be compressed but the paper ships R̃).
pub fn message_bytes(n: usize) -> u64 {
    (n * n * 4) as u64
}

/// Total *computation* flops per process along the critical path:
/// leaf + one combine per round.  Communication-avoiding trade-off:
/// this grows with log2(P) while messages stay at one per round.
pub fn critical_path_flops(rows_per_proc: usize, n: usize, procs: usize) -> u64 {
    leaf_qr_flops(rows_per_proc, n) + procs.trailing_zeros() as u64 * combine_flops(n)
}

/// Total system flops, fault-free.
/// Baseline: P leaves + (P − 1) combines (one per tree node).
/// Redundant family: P leaves + P·log2(P) combines (every process
/// combines every round) — the redundancy the paper repurposes.
pub fn total_flops(algo_redundant: bool, procs: usize, rows_per_proc: usize, n: usize) -> u64 {
    let leaves = procs as u64 * leaf_qr_flops(rows_per_proc, n);
    let combines = if algo_redundant {
        (procs as u64) * procs.trailing_zeros() as u64
    } else {
        (procs as u64).saturating_sub(1)
    };
    leaves + combines * combine_flops(n)
}

/// Redundancy overhead ratio: extra flops of the redundant family over
/// baseline (→ the "price" of the free fault tolerance; tends to 0 as
/// the leaf dominates, i.e. rows_per_proc >> n·log P).
pub fn redundancy_flop_overhead(procs: usize, rows_per_proc: usize, n: usize) -> f64 {
    let base = total_flops(false, procs, rows_per_proc, n) as f64;
    let red = total_flops(true, procs, rows_per_proc, n) as f64;
    (red - base) / base
}

/// Where a simulated run's virtual time went — the discrete-event
/// simulator's ([`crate::sim`]) analogue of wall-clock profiling.
///
/// Every stage the runner schedules charges its duration to exactly one
/// bucket: useful work to `compute_ns`, modelled message latency (and
/// lossy retransmits) to `network_ns`, and ladder penalties — factor
/// re-execution, checksum reconstruction of wiped blocks — to
/// `recovery_ns`.  The buckets therefore sum to the run's total virtual
/// time, so `recovery_fraction()` is the stall share the paper's §III
/// recovery semantics cost under a given failure rate.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct VirtualTimeBreakdown {
    /// Virtual nanoseconds spent in factor/update work proper.
    pub compute_ns: u64,
    /// Virtual nanoseconds of modelled network latency, jitter and
    /// retransmits.
    pub network_ns: u64,
    /// Virtual nanoseconds of recovery stalls (rebuilds and checksum
    /// reconstructions).
    pub recovery_ns: u64,
}

impl VirtualTimeBreakdown {
    /// Sum of all buckets.
    pub fn total_ns(&self) -> u64 {
        self.compute_ns + self.network_ns + self.recovery_ns
    }

    /// Share of virtual time lost to recovery, in [0, 1] (0 for an
    /// empty breakdown).
    pub fn recovery_fraction(&self) -> f64 {
        let total = self.total_ns();
        if total == 0 { 0.0 } else { self.recovery_ns as f64 / total as f64 }
    }

    /// Accumulate another run's breakdown (campaign aggregation).
    pub fn merge(&mut self, other: &VirtualTimeBreakdown) {
        self.compute_ns += other.compute_ns;
        self.network_ns += other.network_ns;
        self.recovery_ns += other.recovery_ns;
    }
}

/// Number of bucket slots in a [`LatencyHistogram`]: 4 exact sub-4ns
/// buckets plus 4 minor buckets per power of two up to 2⁶³.
const LATENCY_BUCKETS: usize = 252;

/// Fixed-footprint latency histogram with log₂ major buckets and 4
/// linear minor buckets each (HDR-style), covering 0 ns to `u64::MAX`
/// ns with ≤ 25 % relative quantile error and no allocation.
///
/// The service layer ([`crate::service`]) keeps two per tenant —
/// queue-wait and service-time — and merges worker-side recordings
/// into streaming snapshots.  Merging is a plain bucket-wise sum, so
/// aggregated histograms are independent of recording order (the
/// property the service's determinism tests rely on for *counts*;
/// the recorded durations themselves are wall-clock and excluded from
/// determinism assertions).
///
/// ```
/// use ft_tsqr::metrics::LatencyHistogram;
/// use std::time::Duration;
///
/// let mut h = LatencyHistogram::new();
/// for us in [100u64, 200, 300, 400, 50_000] {
///     h.record(Duration::from_micros(us));
/// }
/// assert_eq!(h.count(), 5);
/// // p50 is the 300µs sample, reported ≤ 25% above its true value;
/// // p99 is the 50ms outlier.
/// assert!(h.quantile_ns(0.50) >= 300_000 && h.quantile_ns(0.50) <= 375_000);
/// assert!(h.quantile_ns(0.99) >= 50_000_000);
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LatencyHistogram {
    buckets: [u64; LATENCY_BUCKETS],
    count: u64,
    total_ns: u64,
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        LatencyHistogram { buckets: [0; LATENCY_BUCKETS], count: 0, total_ns: 0, max_ns: 0 }
    }

    /// Bucket index of a duration: values < 4 ns get exact buckets
    /// 0..=3; above that, major = floor(log₂ ns) and the next two bits
    /// pick one of 4 minor buckets → index (major−1)·4 + minor.
    fn bucket(ns: u64) -> usize {
        if ns < 4 {
            return ns as usize;
        }
        let major = 63 - ns.leading_zeros() as usize; // ≥ 2
        let minor = ((ns >> (major - 2)) & 3) as usize;
        (major - 1) * 4 + minor
    }

    /// Inclusive upper bound (ns) of the bucket at `idx` — what
    /// quantile queries report.
    fn bucket_upper(idx: usize) -> u64 {
        if idx < 4 {
            return idx as u64;
        }
        let major = idx / 4 + 1;
        let minor = (idx % 4) as u64;
        let low = (1u64 << major) + minor * (1u64 << (major - 2));
        low + (1u64 << (major - 2)) - 1
    }

    /// Record one duration.
    pub fn record(&mut self, d: std::time::Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Record one duration given in nanoseconds.
    pub fn record_ns(&mut self, ns: u64) {
        self.buckets[Self::bucket(ns)] += 1;
        self.count += 1;
        self.total_ns = self.total_ns.saturating_add(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    /// Number of recorded durations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// True if nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Mean of all recorded durations in nanoseconds (0 when empty).
    pub fn mean_ns(&self) -> u64 {
        if self.count == 0 { 0 } else { self.total_ns / self.count }
    }

    /// Largest recorded duration in nanoseconds.
    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Value (ns) at quantile `q` ∈ [0, 1]: the upper bound of the
    /// bucket containing the ⌈q·count⌉-th smallest recording (≤ 25 %
    /// above the true value).  Returns 0 when empty.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (idx, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_upper(idx).min(self.max_ns);
            }
        }
        self.max_ns
    }

    /// Median latency ([`quantile_ns`](Self::quantile_ns) at 0.50).
    pub fn p50(&self) -> std::time::Duration {
        std::time::Duration::from_nanos(self.quantile_ns(0.50))
    }

    /// Tail latency ([`quantile_ns`](Self::quantile_ns) at 0.99).
    pub fn p99(&self) -> std::time::Duration {
        std::time::Duration::from_nanos(self.quantile_ns(0.99))
    }

    /// Accumulate another histogram (bucket-wise sum — order-free).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.total_ns = self.total_ns.saturating_add(other.total_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_time_breakdown_accounting() {
        let mut t = VirtualTimeBreakdown::default();
        assert_eq!(t.total_ns(), 0);
        assert_eq!(t.recovery_fraction(), 0.0);
        t.merge(&VirtualTimeBreakdown { compute_ns: 60, network_ns: 20, recovery_ns: 20 });
        assert_eq!(t.total_ns(), 100);
        assert!((t.recovery_fraction() - 0.2).abs() < 1e-12);
        t.merge(&VirtualTimeBreakdown { compute_ns: 40, network_ns: 0, recovery_ns: 60 });
        assert_eq!(t, VirtualTimeBreakdown { compute_ns: 100, network_ns: 20, recovery_ns: 80 });
    }

    #[test]
    fn latency_histogram_buckets_are_exact_below_4ns() {
        let mut h = LatencyHistogram::new();
        for ns in [0u64, 1, 2, 3] {
            h.record_ns(ns);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.quantile_ns(0.0), 0); // rank 1 → first sample
        assert_eq!(h.quantile_ns(1.0), 3);
        assert_eq!(h.mean_ns(), 1);
        assert_eq!(h.max_ns(), 3);
    }

    #[test]
    fn latency_histogram_quantile_error_bound() {
        // Upper-bound reporting: quantile ≥ true value and ≤ 1.25×.
        let mut h = LatencyHistogram::new();
        for ns in [5u64, 17, 100, 1_000, 65_537, 1_000_000, u64::MAX / 2] {
            h.record_ns(ns);
            let q = h.quantile_ns(1.0);
            assert!(q >= ns, "q={q} < ns={ns}");
            assert!(q - ns <= ns / 4 + 1, "q={q} too far above ns={ns}");
        }
    }

    #[test]
    fn latency_histogram_merge_is_order_free() {
        let samples = [3u64, 40, 500, 6_000, 70_000, 800_000];
        let mut forward = LatencyHistogram::new();
        let mut split_a = LatencyHistogram::new();
        let mut split_b = LatencyHistogram::new();
        for (i, &ns) in samples.iter().enumerate() {
            forward.record_ns(ns);
            if i % 2 == 0 {
                split_a.record_ns(ns);
            } else {
                split_b.record_ns(ns);
            }
        }
        // Merge in the "wrong" order: b ← a-recorded-backwards.
        let mut merged = split_b.clone();
        merged.merge(&split_a);
        assert_eq!(merged, forward);
        assert_eq!(merged.count(), 6);
        assert_eq!(merged.mean_ns(), forward.mean_ns());
    }

    #[test]
    fn latency_histogram_empty_and_saturating() {
        let h = LatencyHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile_ns(0.99), 0);
        assert_eq!(h.p50(), std::time::Duration::ZERO);
        let mut h = LatencyHistogram::new();
        h.record_ns(u64::MAX);
        h.record_ns(u64::MAX); // total_ns saturates, no overflow panic
        assert_eq!(h.quantile_ns(1.0), u64::MAX);
    }

    #[test]
    fn leaf_flops_formula() {
        // 2mn^2 - (2/3)n^3 at m=8, n=2: 64 - 5 = 59 (integer div).
        assert_eq!(leaf_qr_flops(8, 2), 59);
        assert!(leaf_qr_flops(1024, 32) > leaf_qr_flops(512, 32));
    }

    #[test]
    fn combine_cheaper_than_dense() {
        // (Constant factors dominate below n=4: at n=2 the structure-
        // aware loop's +2 row bookkeeping outweighs the saved flops.)
        for n in [4, 8, 16, 32, 64] {
            assert!(
                combine_flops(n) < combine_flops_dense(n),
                "structure-aware combine must beat dense at n={n}"
            );
        }
        // Asymptotic ratio ~ (2/3) / (8/3) = 1/4.
        let ratio = combine_flops(64) as f64 / combine_flops_dense(64) as f64;
        assert!(ratio < 0.35, "ratio {ratio}");
    }

    #[test]
    fn message_counts() {
        assert_eq!(baseline_messages(16), 15);
        assert_eq!(redundant_messages(16), 64); // 16 * 4 rounds
        assert_eq!(baseline_messages(1), 0);
        assert_eq!(redundant_messages(1), 0);
        assert_eq!(message_bytes(8), 256);
    }

    #[test]
    fn overhead_vanishes_with_tall_leaves() {
        let thin = redundancy_flop_overhead(16, 64, 32);
        let tall = redundancy_flop_overhead(16, 8192, 32);
        assert!(tall < thin, "taller leaves amortize redundancy");
        assert!(tall < 0.05, "paper's regime: redundancy nearly free ({tall})");
    }

    #[test]
    fn critical_path_grows_logarithmically() {
        let p4 = critical_path_flops(1024, 16, 4);
        let p16 = critical_path_flops(1024, 16, 16);
        assert_eq!(p16 - p4, 2 * combine_flops(16));
    }
}
