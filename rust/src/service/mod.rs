//! Multi-tenant engine service: the "millions of users" front door.
//!
//! One [`Engine`] is a single-process library; this layer turns it into
//! a *service* that many concurrent clients share safely.  The shape is
//! active-message-style dispatch (cf. lamellar's `exec_am_pe`):
//! [`EngineService::submit`] never blocks and never runs the job on the
//! caller's thread — it either admits the job into a **bounded queue**
//! and returns a [`Ticket`] immediately, or *sheds* it with a typed
//! [`Error::Submission`] rejection the caller can distinguish from an
//! execution failure.  A single dispatcher thread drains the queues in
//! **deficit-round-robin** order (per-tenant weights, no starvation —
//! see [`queue`](self) internals) onto the engine's elastic worker
//! pool, keeping at most `max_inflight` campaigns running at once;
//! everything beyond that is backpressure, and everything beyond the
//! queue bounds is load-shedding with per-tenant shed counters.
//!
//! Per tenant the service streams a [`TenantSnapshot`]: survival stats,
//! aggregated run [`MetricsSnapshot`]s, queue-wait and service-time
//! [`LatencyHistogram`]s, and admission/shed/completion counters.
//! Aggregation is order-free (sums and bucket-wise histogram merges),
//! so per-tenant counts are independent of thread interleaving — the
//! property `tests/integration_service.rs` pins.
//!
//! ```
//! use ft_tsqr::engine::Engine;
//! use ft_tsqr::service::{Job, ServiceBuilder};
//! use ft_tsqr::tsqr::{Algo, RunSpec};
//!
//! let service = ServiceBuilder::new().queue_depth(64).max_inflight(2).build(Engine::host());
//! let alice = service.register_tenant("alice", 3).unwrap();
//! let bob = service.register_tenant("bob", 1).unwrap();
//!
//! let t1 = service.submit(alice, Job::Tsqr(RunSpec::new(Algo::Redundant, 4, 16, 4))).unwrap();
//! let t2 = service.submit(bob, Job::Tsqr(RunSpec::new(Algo::Baseline, 2, 8, 4))).unwrap();
//! assert!(t1.wait().unwrap().success());
//! assert!(t2.wait().unwrap().success());
//!
//! let snap = service.tenant_snapshot(alice).unwrap();
//! assert_eq!((snap.completed, snap.shed), (1, 0));
//! assert_eq!(snap.survival().probability(), 1.0);
//! ```

mod driver;
mod queue;

pub use driver::{TenantLoad, TenantTrafficReport, TrafficReport, TrafficSpec, run_traffic};

use std::panic::{AssertUnwindSafe, catch_unwind};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Instant;

use crate::analysis::SurvivalEstimate;
use crate::caqr::{CaqrResult, CaqrSpec};
use crate::engine::Engine;
use crate::error::{Error, Rejection, Result};
use crate::metrics::LatencyHistogram;
use crate::tsqr::{RunResult, RunSpec};
use crate::ulfm::world::MetricsSnapshot;

use queue::{DrrQueues, Overflow};

/// Opaque per-service tenant handle returned by
/// [`EngineService::register_tenant`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TenantId(usize);

impl TenantId {
    /// Registration index of the tenant (stable for the service's
    /// lifetime; also its position in [`EngineService::tenant_snapshots`]).
    pub fn index(&self) -> usize {
        self.0
    }
}

/// One unit of tenant work: a full factorization campaign run.
#[derive(Clone)]
pub enum Job {
    /// A tall-skinny TSQR run (Algorithms 1–6 of the paper).
    Tsqr(RunSpec),
    /// A general-matrix CAQR run.
    Caqr(CaqrSpec),
}

impl Job {
    /// Validate the underlying spec — submission surfaces shape or
    /// world-size errors immediately as [`Error::Config`] (they are
    /// *not* sheds: the job was never admissible).
    pub fn validate(&self) -> Result<()> {
        match self {
            Job::Tsqr(s) => s.validate(),
            Job::Caqr(s) => s.validate(),
        }
    }
}

/// What a completed [`Job`] produced.
#[derive(Debug)]
pub enum JobOutcome {
    /// Result of a [`Job::Tsqr`] run.
    Tsqr(RunResult),
    /// Result of a [`Job::Caqr`] run.
    Caqr(CaqrResult),
}

impl JobOutcome {
    /// Success under the algorithm's own semantics (at least one
    /// survivor holding R / factorization completed).
    pub fn success(&self) -> bool {
        match self {
            JobOutcome::Tsqr(r) => r.success(),
            JobOutcome::Caqr(r) => r.success(),
        }
    }

    /// The run's communication/recovery counters.
    pub fn metrics(&self) -> MetricsSnapshot {
        match self {
            JobOutcome::Tsqr(r) => r.metrics,
            JobOutcome::Caqr(r) => r.metrics,
        }
    }

    /// The TSQR result, if this was a TSQR job.
    pub fn as_tsqr(&self) -> Option<&RunResult> {
        match self {
            JobOutcome::Tsqr(r) => Some(r),
            JobOutcome::Caqr(_) => None,
        }
    }

    /// The CAQR result, if this was a CAQR job.
    pub fn as_caqr(&self) -> Option<&CaqrResult> {
        match self {
            JobOutcome::Caqr(r) => Some(r),
            JobOutcome::Tsqr(_) => None,
        }
    }
}

/// Claim check for an admitted job: delivery handle for its result.
/// Dropping the ticket abandons the result but never the job — once
/// admitted, a job always runs (accepted work is a promise; shedding
/// happens only at the submission boundary).
pub struct Ticket {
    id: u64,
    tenant: TenantId,
    rx: mpsc::Receiver<Result<JobOutcome>>,
}

impl Ticket {
    /// Service-wide monotone job id (admission order).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The tenant the job was submitted under.
    pub fn tenant(&self) -> TenantId {
        self.tenant
    }

    /// Block until the job finishes and take its outcome.
    pub fn wait(self) -> Result<JobOutcome> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err(Error::Other("service job result channel closed".into())))
    }

    /// Non-blocking poll: `Some` once the job has finished.
    pub fn poll(&self) -> Option<Result<JobOutcome>> {
        self.rx.try_recv().ok()
    }
}

/// Streaming per-tenant accounting — everything the service knows
/// about one tenant at a point in time.  Counters and the aggregated
/// [`MetricsSnapshot`] are order-free sums (deterministic under
/// interleaving); the two histograms record wall-clock durations and
/// are excluded from determinism guarantees.
#[derive(Debug, Clone)]
pub struct TenantSnapshot {
    /// Tenant name as registered.
    pub name: String,
    /// DRR scheduling weight.
    pub weight: u64,
    /// Jobs offered via `submit` (accepted + shed).
    pub submitted: u64,
    /// Jobs admitted into the queue.
    pub accepted: u64,
    /// Jobs shed by admission control (global or per-tenant bound).
    pub shed: u64,
    /// Jobs that ran to completion (successfully or not — see
    /// `successes`).
    pub completed: u64,
    /// Jobs that returned an execution error.
    pub failed: u64,
    /// Completed jobs whose outcome reported success (survived their
    /// fault schedule).
    pub successes: u64,
    /// Jobs currently waiting in this tenant's queue.
    pub queued: usize,
    /// Aggregated run counters over every completed job.
    pub metrics: MetricsSnapshot,
    /// Admission-to-dispatch wait-time distribution.
    pub queue_wait: LatencyHistogram,
    /// Dispatch-to-completion service-time distribution.
    pub service_time: LatencyHistogram,
}

impl TenantSnapshot {
    /// Survival statistics over completed jobs (the per-tenant
    /// analogue of a campaign's survival estimate).
    pub fn survival(&self) -> SurvivalEstimate {
        SurvivalEstimate { trials: self.completed, successes: self.successes }
    }
}

/// Point-in-time service-wide totals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServiceSnapshot {
    /// Registered tenants.
    pub tenants: usize,
    /// Jobs offered across all tenants (accepted + shed).
    pub submitted: u64,
    /// Jobs admitted into the queue.
    pub accepted: u64,
    /// Jobs shed by admission control.
    pub shed: u64,
    /// Jobs handed to the engine so far.
    pub dispatched: u64,
    /// Jobs completed (with or without execution success).
    pub completed: u64,
    /// Jobs that returned an execution error.
    pub failed: u64,
    /// Jobs currently waiting across all tenant queues.
    pub queued: usize,
    /// High-water mark of `queued`.
    pub peak_queued: usize,
    /// Jobs currently executing on the engine.
    pub inflight: usize,
    /// High-water mark of `inflight`.
    pub peak_inflight: usize,
}

struct QueuedJob {
    job: Job,
    enqueued: Instant,
    reply: mpsc::Sender<Result<JobOutcome>>,
}

struct TenantState {
    name: String,
    weight: u64,
    submitted: u64,
    accepted: u64,
    shed: u64,
    completed: u64,
    failed: u64,
    successes: u64,
    metrics: MetricsSnapshot,
    queue_wait: LatencyHistogram,
    service_time: LatencyHistogram,
}

impl TenantState {
    fn new(name: String, weight: u64) -> Self {
        TenantState {
            name,
            weight,
            submitted: 0,
            accepted: 0,
            shed: 0,
            completed: 0,
            failed: 0,
            successes: 0,
            metrics: MetricsSnapshot::default(),
            queue_wait: LatencyHistogram::new(),
            service_time: LatencyHistogram::new(),
        }
    }
}

struct ServiceState {
    queues: DrrQueues<QueuedJob>,
    tenants: Vec<TenantState>,
    inflight: usize,
    peak_inflight: usize,
    paused: bool,
    shutdown: bool,
    next_job_id: u64,
    submitted: u64,
    accepted: u64,
    shed: u64,
    dispatched: u64,
    completed: u64,
    failed: u64,
    dispatch_log: Option<Vec<TenantId>>,
}

struct Shared {
    state: Mutex<ServiceState>,
    /// Wakes the dispatcher: new work, freed inflight slot, resume,
    /// shutdown.
    work_cv: Condvar,
    /// Wakes `wait_idle` when a job completes.
    idle_cv: Condvar,
    max_inflight: usize,
}

/// Configuration for an [`EngineService`] (bounded-queue depths,
/// dispatch window, test hooks).
///
/// Defaults: global queue depth 256, per-tenant depth 256, 4 campaigns
/// in flight, running (not paused), dispatch-order recording off.
#[derive(Debug, Clone)]
pub struct ServiceBuilder {
    queue_depth: usize,
    tenant_depth: usize,
    max_inflight: usize,
    start_paused: bool,
    record_dispatch: bool,
}

impl Default for ServiceBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl ServiceBuilder {
    /// Builder with the default bounds.
    pub fn new() -> Self {
        ServiceBuilder {
            queue_depth: 256,
            tenant_depth: 256,
            max_inflight: 4,
            start_paused: false,
            record_dispatch: false,
        }
    }

    /// Global bound on *waiting* jobs (≥ 1).  Submissions beyond it are
    /// shed with [`Rejection::Overloaded`].  Jobs already dispatched do
    /// not count — up to [`max_inflight`](Self::max_inflight) more are
    /// executing.
    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth.max(1);
        self
    }

    /// Per-tenant bound on waiting jobs (≥ 1); beyond it a tenant's
    /// submissions are shed with [`Rejection::TenantOverloaded`] while
    /// other tenants are still admitted.
    pub fn tenant_depth(mut self, depth: usize) -> Self {
        self.tenant_depth = depth.max(1);
        self
    }

    /// Campaigns the dispatcher keeps running concurrently (≥ 1) —
    /// the backpressure window between the queue and the engine.
    pub fn max_inflight(mut self, n: usize) -> Self {
        self.max_inflight = n.max(1);
        self
    }

    /// Start with the dispatcher paused: jobs are admitted (and shed)
    /// but none dispatched until [`EngineService::resume`] — the hook
    /// the deterministic overload/fairness tests use.
    pub fn start_paused(mut self, paused: bool) -> Self {
        self.start_paused = paused;
        self
    }

    /// Record the tenant order of every dispatch for
    /// [`EngineService::dispatch_log`] (fairness tests; off by default).
    pub fn record_dispatch(mut self, on: bool) -> Self {
        self.record_dispatch = on;
        self
    }

    /// Start the service over an engine: spawns the dispatcher thread
    /// and takes ownership of the engine (all access now flows through
    /// the service; [`EngineService::engine`] lends it back out).
    pub fn build(self, engine: Engine) -> EngineService {
        let engine = Arc::new(engine);
        let shared = Arc::new(Shared {
            state: Mutex::new(ServiceState {
                queues: DrrQueues::new(self.queue_depth, self.tenant_depth),
                tenants: Vec::new(),
                inflight: 0,
                peak_inflight: 0,
                paused: self.start_paused,
                shutdown: false,
                next_job_id: 0,
                submitted: 0,
                accepted: 0,
                shed: 0,
                dispatched: 0,
                completed: 0,
                failed: 0,
                dispatch_log: self.record_dispatch.then(Vec::new),
            }),
            work_cv: Condvar::new(),
            idle_cv: Condvar::new(),
            max_inflight: self.max_inflight,
        });
        let dispatcher = {
            let shared = Arc::clone(&shared);
            let engine = Arc::clone(&engine);
            thread::Builder::new()
                .name("svc-dispatch".into())
                .spawn(move || dispatch_loop(shared, engine))
                .expect("spawn service dispatcher")
        };
        EngineService { shared, engine, dispatcher: Mutex::new(Some(dispatcher)) }
    }
}

/// The running service: bounded admission + DRR dispatch over one
/// shared [`Engine`].  See the [module docs](self) for the full
/// contract; construct via [`ServiceBuilder`].
pub struct EngineService {
    shared: Arc<Shared>,
    engine: Arc<Engine>,
    dispatcher: Mutex<Option<thread::JoinHandle<()>>>,
}

impl EngineService {
    /// Service with default bounds over an engine
    /// (`ServiceBuilder::new().build(engine)`).
    pub fn over(engine: Engine) -> Self {
        ServiceBuilder::new().build(engine)
    }

    /// Register a tenant with a DRR weight (≥ 1): its long-run service
    /// share under saturation is `weight / Σ weights`.  Names must be
    /// unique per service.
    pub fn register_tenant(&self, name: impl Into<String>, weight: u64) -> Result<TenantId> {
        let name = name.into();
        if weight == 0 {
            return Err(Error::Config(format!("tenant '{name}': weight must be >= 1")));
        }
        let mut st = self.shared.state.lock().unwrap();
        if st.shutdown {
            return Err(Error::Submission(Rejection::ShuttingDown));
        }
        if st.tenants.iter().any(|t| t.name == name) {
            return Err(Error::Config(format!("tenant '{name}' already registered")));
        }
        let idx = st.queues.add_tenant(weight);
        st.tenants.push(TenantState::new(name, weight));
        debug_assert_eq!(idx + 1, st.tenants.len());
        Ok(TenantId(idx))
    }

    /// Submit a job under a tenant.  Never blocks and never executes on
    /// the caller's thread: returns a [`Ticket`] on admission, or —
    /// when the global or per-tenant bound is hit — sheds the job with
    /// a typed [`Error::Submission`] ([`Error::is_overload`] is true
    /// for the retryable kinds).  Invalid specs fail with
    /// [`Error::Config`] and count as neither accepted nor shed.
    pub fn submit(&self, tenant: TenantId, job: Job) -> Result<Ticket> {
        job.validate()?;
        let (tx, rx) = mpsc::channel();
        let mut st = self.shared.state.lock().unwrap();
        if st.shutdown {
            return Err(Error::Submission(Rejection::ShuttingDown));
        }
        let idx = tenant.0;
        if idx >= st.tenants.len() {
            return Err(Error::Config(format!("unknown tenant id {idx}")));
        }
        st.submitted += 1;
        st.tenants[idx].submitted += 1;
        let qj = QueuedJob { job, enqueued: Instant::now(), reply: tx };
        match st.queues.try_enqueue(idx, qj) {
            Ok(()) => {
                let id = st.next_job_id;
                st.next_job_id += 1;
                st.accepted += 1;
                st.tenants[idx].accepted += 1;
                drop(st);
                self.shared.work_cv.notify_all();
                Ok(Ticket { id, tenant, rx })
            }
            Err((overflow, _job_back)) => {
                st.shed += 1;
                st.tenants[idx].shed += 1;
                let rejection = match overflow {
                    Overflow::Global { queued, depth } => Rejection::Overloaded { queued, depth },
                    Overflow::Tenant { queued, depth } => Rejection::TenantOverloaded {
                        tenant: st.tenants[idx].name.clone(),
                        queued,
                        depth,
                    },
                };
                Err(Error::Submission(rejection))
            }
        }
    }

    /// Stop dispatching (admission continues).  Queued work resumes on
    /// [`resume`](Self::resume); in-flight jobs are unaffected.
    pub fn pause(&self) {
        self.shared.state.lock().unwrap().paused = true;
    }

    /// Restart dispatching after [`pause`](Self::pause) (or
    /// [`ServiceBuilder::start_paused`]).
    pub fn resume(&self) {
        self.shared.state.lock().unwrap().paused = false;
        self.shared.work_cv.notify_all();
    }

    /// Is the dispatcher currently paused?
    pub fn is_paused(&self) -> bool {
        self.shared.state.lock().unwrap().paused
    }

    /// Block until no work is queued or in flight.  A *paused* service
    /// with backlog never goes idle — resume first.
    pub fn wait_idle(&self) {
        let mut st = self.shared.state.lock().unwrap();
        while st.queues.total_queued() > 0 || st.inflight > 0 {
            st = self.shared.idle_cv.wait(st).unwrap();
        }
    }

    /// Service-wide totals at this instant.
    pub fn snapshot(&self) -> ServiceSnapshot {
        let st = self.shared.state.lock().unwrap();
        ServiceSnapshot {
            tenants: st.tenants.len(),
            submitted: st.submitted,
            accepted: st.accepted,
            shed: st.shed,
            dispatched: st.dispatched,
            completed: st.completed,
            failed: st.failed,
            queued: st.queues.total_queued(),
            peak_queued: st.queues.peak_queued(),
            inflight: st.inflight,
            peak_inflight: st.peak_inflight,
        }
    }

    /// This tenant's streaming accounting at this instant (`None` for
    /// a foreign [`TenantId`]).
    pub fn tenant_snapshot(&self, tenant: TenantId) -> Option<TenantSnapshot> {
        let st = self.shared.state.lock().unwrap();
        let t = st.tenants.get(tenant.0)?;
        Some(Self::snapshot_tenant(&st, tenant.0, t))
    }

    /// Snapshots of every tenant, in registration order.
    pub fn tenant_snapshots(&self) -> Vec<TenantSnapshot> {
        let st = self.shared.state.lock().unwrap();
        st.tenants.iter().enumerate().map(|(i, t)| Self::snapshot_tenant(&st, i, t)).collect()
    }

    fn snapshot_tenant(st: &ServiceState, idx: usize, t: &TenantState) -> TenantSnapshot {
        TenantSnapshot {
            name: t.name.clone(),
            weight: t.weight,
            submitted: t.submitted,
            accepted: t.accepted,
            shed: t.shed,
            completed: t.completed,
            failed: t.failed,
            successes: t.successes,
            queued: st.queues.queued(idx),
            metrics: t.metrics,
            queue_wait: t.queue_wait.clone(),
            service_time: t.service_time.clone(),
        }
    }

    /// The tenant order of every dispatch so far — `Some` only when
    /// built with [`ServiceBuilder::record_dispatch`].
    pub fn dispatch_log(&self) -> Option<Vec<TenantId>> {
        self.shared.state.lock().unwrap().dispatch_log.clone()
    }

    /// The engine this service dispatches onto.
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Configured dispatch window.
    pub fn max_inflight(&self) -> usize {
        self.shared.max_inflight
    }

    /// Configured global queue depth.
    pub fn queue_depth(&self) -> usize {
        self.shared.state.lock().unwrap().queues.depth()
    }

    /// Configured per-tenant queue depth.
    pub fn tenant_depth(&self) -> usize {
        self.shared.state.lock().unwrap().queues.tenant_depth()
    }

    /// Jobs currently waiting across all tenants.
    pub fn queued(&self) -> usize {
        self.shared.state.lock().unwrap().queues.total_queued()
    }

    /// Stop admitting work, drain everything already accepted (a
    /// paused service is resumed — admission is a promise), and join
    /// the dispatcher.  Idempotent; also runs on drop.
    pub fn shutdown(&self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            st.paused = false;
        }
        self.shared.work_cv.notify_all();
        if let Some(h) = self.dispatcher.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for EngineService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The dispatcher thread: waits for dispatchable work, pops the next
/// job in DRR order, and hands it to a pool worker.
fn dispatch_loop(shared: Arc<Shared>, engine: Arc<Engine>) {
    loop {
        let mut st = shared.state.lock().unwrap();
        loop {
            if st.shutdown && st.queues.total_queued() == 0 && st.inflight == 0 {
                return;
            }
            let dispatchable =
                !st.paused && st.inflight < shared.max_inflight && st.queues.total_queued() > 0;
            if dispatchable {
                break;
            }
            st = shared.work_cv.wait(st).unwrap();
        }
        let (tenant, qj) = st.queues.dequeue().expect("backlog checked under lock");
        st.inflight += 1;
        st.peak_inflight = st.peak_inflight.max(st.inflight);
        st.dispatched += 1;
        if let Some(log) = st.dispatch_log.as_mut() {
            log.push(TenantId(tenant));
        }
        st.tenants[tenant].queue_wait.record(qj.enqueued.elapsed());
        drop(st);
        let shared = Arc::clone(&shared);
        let engine_for_job = Arc::clone(&engine);
        engine.pool().execute(move || run_job(shared, engine_for_job, tenant, qj));
    }
}

/// Runs on a pool worker: execute the job, fold its outcome into the
/// tenant's streaming accounting, free the inflight slot, deliver the
/// result.
fn run_job(shared: Arc<Shared>, engine: Arc<Engine>, tenant: usize, qj: QueuedJob) {
    let QueuedJob { job, enqueued: _, reply } = qj;
    let started = Instant::now();
    let res = catch_unwind(AssertUnwindSafe(|| match job {
        Job::Tsqr(spec) => engine.run(spec).map(JobOutcome::Tsqr),
        Job::Caqr(spec) => engine.run_caqr(spec).map(JobOutcome::Caqr),
    }))
    .unwrap_or_else(|_| Err(Error::Other("service job panicked".into())));
    let service_time = started.elapsed();
    // Drop the engine handle BEFORE publishing completion: the moment
    // `inflight` hits zero after shutdown, the dispatcher joins and the
    // service releases its own engine Arc — which must then be the
    // *last* one so `Engine::drop` (pool shutdown + join) never runs on
    // a pool worker (a worker cannot join itself).
    drop(engine);
    let mut st = shared.state.lock().unwrap();
    st.inflight -= 1;
    match &res {
        Ok(out) => {
            st.completed += 1;
            let t = &mut st.tenants[tenant];
            t.completed += 1;
            if out.success() {
                t.successes += 1;
            }
            t.metrics.merge(&out.metrics());
        }
        Err(_) => {
            st.failed += 1;
            st.tenants[tenant].failed += 1;
        }
    }
    st.tenants[tenant].service_time.record(service_time);
    drop(st);
    shared.work_cv.notify_all();
    shared.idle_cv.notify_all();
    let _ = reply.send(res);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tsqr::Algo;

    fn tiny(seed: u64) -> Job {
        Job::Tsqr(RunSpec::new(Algo::Redundant, 4, 8, 4).with_seed(seed).with_verify(false))
    }

    #[test]
    fn builder_defaults_and_clamps() {
        let b = ServiceBuilder::new().queue_depth(0).tenant_depth(0).max_inflight(0);
        let svc = b.build(Engine::host());
        assert_eq!(svc.queue_depth(), 1, "depth clamps to >= 1");
        assert_eq!(svc.tenant_depth(), 1);
        assert_eq!(svc.max_inflight(), 1);
        assert!(!svc.is_paused());
        assert!(svc.dispatch_log().is_none(), "recording off by default");
    }

    #[test]
    fn tenant_registration_rules() {
        let svc = EngineService::over(Engine::host());
        let a = svc.register_tenant("alice", 2).unwrap();
        assert_eq!(a.index(), 0);
        assert!(matches!(svc.register_tenant("alice", 1), Err(Error::Config(_))), "dup name");
        assert!(matches!(svc.register_tenant("zero", 0), Err(Error::Config(_))), "weight >= 1");
        let b = svc.register_tenant("bob", 1).unwrap();
        assert_eq!(b.index(), 1);
        assert_eq!(svc.snapshot().tenants, 2);
    }

    #[test]
    fn submit_validates_before_admission() {
        let svc = EngineService::over(Engine::host());
        let t = svc.register_tenant("t", 1).unwrap();
        // 6 procs is not a power of two for the redundant family.
        let bad = Job::Tsqr(RunSpec::new(Algo::Redundant, 6, 16, 4));
        assert!(matches!(svc.submit(t, bad), Err(Error::Config(_))));
        let snap = svc.tenant_snapshot(t).unwrap();
        // Invalid spec counts as neither submitted, accepted nor shed.
        assert_eq!((snap.submitted, snap.accepted, snap.shed), (0, 0, 0));
    }

    #[test]
    fn submit_runs_and_streams_metrics() {
        let svc = EngineService::over(Engine::host());
        let t = svc.register_tenant("t", 1).unwrap();
        let ticket = svc.submit(t, tiny(7)).unwrap();
        assert_eq!(ticket.tenant(), t);
        let out = ticket.wait().unwrap();
        assert!(out.success());
        assert!(out.as_tsqr().is_some() && out.as_caqr().is_none());
        svc.wait_idle();
        let snap = svc.tenant_snapshot(t).unwrap();
        assert_eq!((snap.completed, snap.successes, snap.failed), (1, 1, 0));
        assert_eq!(snap.metrics, out.metrics(), "aggregate of one run is that run");
        assert_eq!(snap.queue_wait.count(), 1);
        assert_eq!(snap.service_time.count(), 1);
        assert_eq!(snap.survival().probability(), 1.0);
        let s = svc.snapshot();
        assert_eq!((s.submitted, s.accepted, s.shed, s.completed), (1, 1, 0, 1));
        assert_eq!(s.inflight, 0);
        assert!(s.peak_inflight >= 1);
    }

    #[test]
    fn shutdown_rejects_new_work_but_drains_accepted() {
        let svc = ServiceBuilder::new().start_paused(true).build(Engine::host());
        let t = svc.register_tenant("t", 1).unwrap();
        let tickets: Vec<Ticket> = (0..3).map(|i| svc.submit(t, tiny(i)).unwrap()).collect();
        // Shutdown while paused: accepted work must still drain.
        svc.shutdown();
        for ticket in tickets {
            assert!(ticket.wait().unwrap().success());
        }
        assert!(matches!(
            svc.submit(t, tiny(9)),
            Err(Error::Submission(Rejection::ShuttingDown))
        ));
        assert!(matches!(
            svc.register_tenant("late", 1),
            Err(Error::Submission(Rejection::ShuttingDown))
        ));
        let snap = svc.tenant_snapshot(t).unwrap();
        assert_eq!((snap.completed, snap.queued), (3, 0));
        // Idempotent (and Drop will call it again harmlessly).
        svc.shutdown();
    }

    #[test]
    fn foreign_tenant_id_is_a_config_error() {
        let svc = EngineService::over(Engine::host());
        assert!(matches!(svc.submit(TenantId(5), tiny(0)), Err(Error::Config(_))));
        assert!(svc.tenant_snapshot(TenantId(5)).is_none());
    }
}
