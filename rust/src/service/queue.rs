//! Bounded per-tenant queues with deficit-round-robin (DRR) dispatch —
//! the pure scheduling core of the service layer, free of threads and
//! clocks so its fairness and admission invariants unit-test directly.
//!
//! Every job costs one quantum unit (a campaign run), so DRR reduces to
//! weighted round-robin with integer deficits: a visit to tenant `i`
//! grants `weight_i` credits and serves up to that many queued jobs
//! before moving on.  Over any dispatch prefix of length `n` during
//! which every tenant stays backlogged, tenant `i`'s served count
//! deviates from its weight share `n·wᵢ/W` by at most one quantum
//! (`wᵢ` jobs) — the bound `tests/integration_service.rs` pins.

use std::collections::VecDeque;

/// Why an enqueue was refused — translated into
/// [`crate::error::Rejection`] by the service front door (the queue
/// core itself stays error-type-agnostic and returns the job).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Overflow {
    /// Global bound hit: `queued` jobs already waiting of `depth` allowed.
    Global { queued: usize, depth: usize },
    /// Per-tenant bound hit for the submitting tenant.
    Tenant { queued: usize, depth: usize },
}

struct TenantQueue<T> {
    weight: u64,
    deficit: u64,
    jobs: VecDeque<T>,
    /// True iff this tenant is in the `active` rotation or is the
    /// tenant currently being served (i.e. it holds backlog the
    /// scheduler knows about).
    in_active: bool,
}

/// The DRR state machine: per-tenant FIFO queues, a rotation of
/// backlogged tenants, and the deficit counters.
pub(crate) struct DrrQueues<T> {
    tenants: Vec<TenantQueue<T>>,
    /// Backlogged tenants awaiting their next visit, in rotation order.
    active: VecDeque<usize>,
    /// Tenant currently being served (holds unspent deficit).
    current: Option<usize>,
    queued_total: usize,
    depth: usize,
    tenant_depth: usize,
    peak_queued: usize,
}

impl<T> DrrQueues<T> {
    /// New queue set with a global bound of `depth` waiting jobs and a
    /// per-tenant bound of `tenant_depth`.
    pub fn new(depth: usize, tenant_depth: usize) -> Self {
        DrrQueues {
            tenants: Vec::new(),
            active: VecDeque::new(),
            current: None,
            queued_total: 0,
            depth: depth.max(1),
            tenant_depth: tenant_depth.max(1),
            peak_queued: 0,
        }
    }

    /// Register a tenant with the given DRR weight (≥ 1) and return
    /// its index.
    pub fn add_tenant(&mut self, weight: u64) -> usize {
        self.tenants.push(TenantQueue {
            weight: weight.max(1),
            deficit: 0,
            jobs: VecDeque::new(),
            in_active: false,
        });
        self.tenants.len() - 1
    }

    /// Number of registered tenants.
    pub fn tenants(&self) -> usize {
        self.tenants.len()
    }

    /// The tenant's DRR weight.
    pub fn weight(&self, tenant: usize) -> u64 {
        self.tenants[tenant].weight
    }

    /// Jobs currently waiting for this tenant.
    pub fn queued(&self, tenant: usize) -> usize {
        self.tenants[tenant].jobs.len()
    }

    /// Jobs currently waiting across all tenants.
    pub fn total_queued(&self) -> usize {
        self.queued_total
    }

    /// High-water mark of `total_queued`.
    pub fn peak_queued(&self) -> usize {
        self.peak_queued
    }

    /// The configured global depth.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// The configured per-tenant depth.
    pub fn tenant_depth(&self) -> usize {
        self.tenant_depth
    }

    /// Admit a job if both the global and the tenant bound allow it;
    /// on refusal the job comes back untouched alongside the reason.
    pub fn try_enqueue(&mut self, tenant: usize, job: T) -> Result<(), (Overflow, T)> {
        if self.queued_total >= self.depth {
            return Err((Overflow::Global { queued: self.queued_total, depth: self.depth }, job));
        }
        let q = &mut self.tenants[tenant];
        if q.jobs.len() >= self.tenant_depth {
            return Err((
                Overflow::Tenant { queued: q.jobs.len(), depth: self.tenant_depth },
                job,
            ));
        }
        q.jobs.push_back(job);
        self.queued_total += 1;
        self.peak_queued = self.peak_queued.max(self.queued_total);
        if !q.in_active {
            q.in_active = true;
            self.active.push_back(tenant);
        }
        Ok(())
    }

    /// Pop the next job under DRR order; `None` when nothing is queued.
    pub fn dequeue(&mut self) -> Option<(usize, T)> {
        loop {
            if let Some(t) = self.current {
                let q = &mut self.tenants[t];
                if q.deficit >= 1 && !q.jobs.is_empty() {
                    let job = q.jobs.pop_front().expect("non-empty checked");
                    q.deficit -= 1;
                    self.queued_total -= 1;
                    if q.jobs.is_empty() {
                        // Backlog drained: forfeit leftover credit so an
                        // idle tenant cannot bank deficit for a later
                        // burst (standard DRR reset-on-empty rule).
                        q.deficit = 0;
                        q.in_active = false;
                        self.current = None;
                    }
                    return Some((t, job));
                }
                if q.jobs.is_empty() {
                    q.deficit = 0;
                    q.in_active = false;
                } else {
                    // Credit spent but backlog remains: rejoin the
                    // rotation at the back.
                    self.active.push_back(t);
                }
                self.current = None;
                continue;
            }
            let t = self.active.pop_front()?;
            let q = &mut self.tenants[t];
            if q.jobs.is_empty() {
                q.deficit = 0;
                q.in_active = false;
                continue;
            }
            // One quantum: `weight` job credits for this visit.
            q.deficit += q.weight;
            self.current = Some(t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drain everything, recording the tenant order.
    fn drain(q: &mut DrrQueues<u32>) -> Vec<usize> {
        let mut order = Vec::new();
        while let Some((t, _)) = q.dequeue() {
            order.push(t);
        }
        order
    }

    #[test]
    fn bounded_admission_global_and_per_tenant() {
        let mut q = DrrQueues::new(5, 3);
        let a = q.add_tenant(1);
        let b = q.add_tenant(1);
        for i in 0..3 {
            q.try_enqueue(a, i).unwrap();
        }
        // Tenant bound: a's 4th job refused, job handed back.
        match q.try_enqueue(a, 99) {
            Err((Overflow::Tenant { queued: 3, depth: 3 }, 99)) => {}
            other => panic!("expected tenant overflow, got {other:?}"),
        }
        // b still admitted (per-tenant isolation).
        q.try_enqueue(b, 0).unwrap();
        q.try_enqueue(b, 1).unwrap();
        // Global bound (5) now full: even b's within-quota job is shed.
        match q.try_enqueue(b, 99) {
            Err((Overflow::Global { queued: 5, depth: 5 }, 99)) => {}
            other => panic!("expected global overflow, got {other:?}"),
        }
        assert_eq!(q.total_queued(), 5);
        assert_eq!(q.peak_queued(), 5);
        assert_eq!(q.queued(a), 3);
        assert_eq!(q.queued(b), 2);
    }

    #[test]
    fn equal_weights_round_robin() {
        let mut q = DrrQueues::new(64, 64);
        let a = q.add_tenant(1);
        let b = q.add_tenant(1);
        for i in 0..4 {
            q.try_enqueue(a, i).unwrap();
            q.try_enqueue(b, i).unwrap();
        }
        assert_eq!(drain(&mut q), vec![a, b, a, b, a, b, a, b]);
        assert_eq!(q.total_queued(), 0);
    }

    #[test]
    fn weighted_service_shares() {
        // Weights 1:3 → each full round serves 1 of a, 3 of b.
        let mut q = DrrQueues::new(64, 64);
        let a = q.add_tenant(1);
        let b = q.add_tenant(3);
        for i in 0..4 {
            q.try_enqueue(a, i).unwrap();
        }
        for i in 0..12 {
            q.try_enqueue(b, i).unwrap();
        }
        let order = drain(&mut q);
        assert_eq!(order.len(), 16);
        // Per-round structure: [a, b, b, b] × 4.
        for round in 0..4 {
            assert_eq!(order[round * 4], a, "round {round}");
            assert_eq!(&order[round * 4 + 1..round * 4 + 4], &[b, b, b], "round {round}");
        }
    }

    #[test]
    fn drr_prefix_bound_holds_while_backlogged() {
        // Weights 2:5:1, long backlogs: at every prefix n (all tenants
        // still backlogged) |served_i·W − w_i·n| ≤ w_i·W.
        let weights = [2u64, 5, 1];
        let w_sum: u64 = weights.iter().sum();
        let mut q = DrrQueues::new(1024, 1024);
        let ids: Vec<usize> = weights.iter().map(|&w| q.add_tenant(w)).collect();
        let per = 40u64;
        for &t in &ids {
            for i in 0..per {
                q.try_enqueue(t, i as u32).unwrap();
            }
        }
        let mut served = [0u64; 3];
        let mut n = 0u64;
        while let Some((t, _)) = q.dequeue() {
            served[t] += 1;
            n += 1;
            let backlogged = served.iter().all(|&s| s < per);
            if backlogged {
                for (i, &w) in weights.iter().enumerate() {
                    let share = (served[i] * w_sum) as i128 - (w * n) as i128;
                    assert!(
                        share.unsigned_abs() <= (w * w_sum) as u128,
                        "prefix {n}: tenant {i} served {} (weights {weights:?})",
                        served[i]
                    );
                }
            }
        }
        assert_eq!(served, [per; 3]);
    }

    #[test]
    fn drained_tenant_forfeits_deficit() {
        // a drains mid-visit, goes idle, then returns: it must NOT have
        // banked credit from the idle period.
        let mut q = DrrQueues::new(64, 64);
        let a = q.add_tenant(4);
        let b = q.add_tenant(1);
        q.try_enqueue(a, 0).unwrap();
        q.try_enqueue(b, 0).unwrap();
        q.try_enqueue(b, 1).unwrap();
        // a serves its one job (visit grants 4, forfeits 3 on drain).
        assert_eq!(q.dequeue().unwrap().0, a);
        assert_eq!(q.dequeue().unwrap().0, b);
        // a returns with fresh backlog while b still queued: new visit
        // starts from zero credit (grants exactly one quantum again).
        q.try_enqueue(a, 1).unwrap();
        let rest = drain(&mut q);
        assert_eq!(rest.len(), 2);
        assert!(rest.contains(&a) && rest.contains(&b));
        assert_eq!(q.total_queued(), 0);
    }

    #[test]
    fn interleaved_enqueue_dequeue_keeps_rotation_consistent() {
        let mut q = DrrQueues::new(8, 8);
        let a = q.add_tenant(1);
        let b = q.add_tenant(1);
        q.try_enqueue(a, 0).unwrap();
        assert_eq!(q.dequeue().unwrap().0, a);
        assert!(q.dequeue().is_none());
        // Re-enqueue after empty: tenant must re-enter the rotation.
        q.try_enqueue(b, 0).unwrap();
        q.try_enqueue(a, 1).unwrap();
        let order = drain(&mut q);
        assert_eq!(order, vec![b, a], "arrival order of backlog sets the rotation");
        assert!(q.dequeue().is_none());
    }
}
