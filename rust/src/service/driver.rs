//! Synthetic many-client traffic driver: one OS thread per tenant
//! submitting a deterministic stream of jobs against a running
//! [`EngineService`] — the load generator behind `repro serve` and the
//! `service_throughput` bench.
//!
//! Job streams are seed-deterministic via [`crate::util::derive_seed`]
//! (tenant stream = `derive_seed(seed, tenant_index)`, job seed =
//! `derive_seed(tenant_stream, job_index)`), so two drives of the same
//! [`TrafficSpec`] offer byte-identical work no matter how the client
//! threads interleave.  Overloaded submissions are counted and dropped
//! (no retry): shed rate under a given offered load is itself the
//! measurement.

use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::fault::KillSchedule;
use crate::linalg::Matrix;
use crate::tsqr::{Algo, RunSpec};
use crate::util::derive_seed;

use super::{EngineService, Job, ServiceSnapshot, TenantId, TenantSnapshot, Ticket};

/// One synthetic client: a tenant identity plus its offered load.
#[derive(Debug, Clone)]
pub struct TenantLoad {
    /// Tenant name to register.
    pub name: String,
    /// DRR weight to register with.
    pub weight: u64,
    /// Jobs this client submits.
    pub jobs: u64,
    /// Pause between consecutive submissions — the offered-load knob
    /// (`Duration::ZERO` = flood as fast as the service sheds).
    pub think: Duration,
}

/// A deterministic synthetic workload for [`run_traffic`].
#[derive(Debug, Clone)]
pub struct TrafficSpec {
    /// The synthetic clients (at least one).
    pub tenants: Vec<TenantLoad>,
    /// TSQR world size of every job.
    pub procs: usize,
    /// Leaf rows per process of every job.
    pub rows_per_proc: usize,
    /// Matrix columns of every job.
    pub cols: usize,
    /// Base seed; tenant/job streams derive from it.
    pub seed: u64,
    /// Arm a survivable single-failure [`KillSchedule`] on every 4th
    /// job (Self-Healing absorbs it — survival stays 1.0, but the
    /// recovery path is on the clock).
    pub failures: bool,
    /// Share one input matrix per tenant across all its jobs
    /// ([`RunSpec::with_input`] zero-copy path) instead of generating
    /// a fresh matrix per job.
    pub share_input: bool,
}

impl TrafficSpec {
    /// A workload skeleton with no tenants yet (add them with
    /// [`tenant`](Self::tenant)); seed 42, failures off, shared inputs
    /// on.
    pub fn new(procs: usize, rows_per_proc: usize, cols: usize) -> Self {
        TrafficSpec {
            tenants: Vec::new(),
            procs,
            rows_per_proc,
            cols,
            seed: 42,
            failures: false,
            share_input: true,
        }
    }

    /// Add a flooding client (no think time).
    pub fn tenant(mut self, name: impl Into<String>, weight: u64, jobs: u64) -> Self {
        self.tenants.push(TenantLoad {
            name: name.into(),
            weight,
            jobs,
            think: Duration::ZERO,
        });
        self
    }

    /// Replace the base seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Toggle the injected-failure leg.
    pub fn with_failures(mut self, on: bool) -> Self {
        self.failures = on;
        self
    }

    /// Toggle per-tenant shared-input submission.
    pub fn with_share_input(mut self, on: bool) -> Self {
        self.share_input = on;
        self
    }

    /// Set the think time of the most recently added tenant (panics if
    /// no tenant has been added).
    pub fn with_think(mut self, think: Duration) -> Self {
        self.tenants.last_mut().expect("add a tenant before with_think").think = think;
        self
    }

    /// The job a given tenant submits at a given stream position —
    /// exposed so tests can rebuild the exact spec a client offered.
    pub fn job_for(&self, tenant_index: usize, job_index: u64, input: Option<&Arc<Matrix>>) -> Job {
        let stream = derive_seed(self.seed, tenant_index as u64);
        let job_seed = derive_seed(stream, job_index);
        let mut spec = RunSpec::new(Algo::SelfHealing, self.procs, self.rows_per_proc, self.cols)
            .with_seed(job_seed)
            .with_verify(false);
        if let Some(m) = input {
            spec = spec.with_input(Arc::clone(m));
        }
        if self.failures && job_index % 4 == 3 {
            spec = spec
                .with_schedule(KillSchedule::random_at_round(self.procs, 1, 1, None, job_seed));
        }
        Job::Tsqr(spec)
    }

    /// The shared input matrix of a tenant (when
    /// [`share_input`](Self::share_input) is on): deterministic in the
    /// tenant's stream seed.
    pub fn shared_input(&self, tenant_index: usize) -> Arc<Matrix> {
        let stream = derive_seed(self.seed, tenant_index as u64);
        Arc::new(Matrix::random(self.procs * self.rows_per_proc, self.cols, stream))
    }
}

/// What one synthetic client saw, paired with the service's streaming
/// accounting for its tenant.
#[derive(Debug, Clone)]
pub struct TenantTrafficReport {
    /// The tenant's service handle.
    pub id: TenantId,
    /// Jobs the client attempted to submit.
    pub offered: u64,
    /// Submissions shed at the front door (client-side count — equals
    /// the snapshot's `shed`).
    pub shed: u64,
    /// Completed jobs whose outcome reported success.
    pub ok: u64,
    /// Completed jobs that returned an execution error.
    pub exec_failed: u64,
    /// The tenant's [`TenantSnapshot`] after the drive went idle.
    pub snapshot: TenantSnapshot,
}

/// Outcome of one [`run_traffic`] drive.
#[derive(Debug, Clone)]
pub struct TrafficReport {
    /// Wall clock from first submission to service idle.
    pub wall: Duration,
    /// Service-wide totals after the drive.
    pub service: ServiceSnapshot,
    /// Per-tenant reports, in [`TrafficSpec::tenants`] order.
    pub tenants: Vec<TenantTrafficReport>,
}

impl TrafficReport {
    /// Completed jobs per second over the drive.
    pub fn throughput(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs > 0.0 { self.service.completed as f64 / secs } else { 0.0 }
    }

    /// Shed fraction of all offered jobs (0 when nothing was offered).
    pub fn shed_rate(&self) -> f64 {
        if self.service.submitted == 0 {
            0.0
        } else {
            self.service.shed as f64 / self.service.submitted as f64
        }
    }
}

struct ClientOutcome {
    offered: u64,
    shed: u64,
    ok: u64,
    exec_failed: u64,
}

/// Drive the workload: register every tenant, spawn one real client
/// thread per tenant, submit its deterministic job stream (dropping
/// shed jobs), harvest every ticket, wait for the service to go idle
/// and collect the per-tenant snapshots.
///
/// ```
/// use ft_tsqr::engine::Engine;
/// use ft_tsqr::service::{ServiceBuilder, TrafficSpec, run_traffic};
///
/// let service = ServiceBuilder::new().max_inflight(2).build(Engine::host());
/// let spec = TrafficSpec::new(4, 8, 4).tenant("alice", 2, 3).tenant("bob", 1, 3);
/// let report = run_traffic(&service, &spec).unwrap();
/// assert_eq!(report.service.completed, 6, "nothing shed at this load");
/// assert!(report.tenants.iter().all(|t| t.ok == 3));
/// ```
pub fn run_traffic(service: &EngineService, spec: &TrafficSpec) -> Result<TrafficReport> {
    if spec.tenants.is_empty() {
        return Err(Error::Config("traffic spec needs at least one tenant".into()));
    }
    let ids = spec
        .tenants
        .iter()
        .map(|t| service.register_tenant(t.name.as_str(), t.weight))
        .collect::<Result<Vec<TenantId>>>()?;

    let started = Instant::now();
    let outcomes: Vec<Result<ClientOutcome>> = thread::scope(|scope| {
        let handles: Vec<_> = spec
            .tenants
            .iter()
            .enumerate()
            .map(|(index, load)| {
                let id = ids[index];
                scope.spawn(move || client_loop(service, spec, index, id, load))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread panicked")).collect()
    });
    service.wait_idle();
    let wall = started.elapsed();

    let mut tenants = Vec::with_capacity(ids.len());
    for (index, outcome) in outcomes.into_iter().enumerate() {
        let outcome = outcome?;
        let snapshot = service.tenant_snapshot(ids[index]).expect("registered above");
        tenants.push(TenantTrafficReport {
            id: ids[index],
            offered: outcome.offered,
            shed: outcome.shed,
            ok: outcome.ok,
            exec_failed: outcome.exec_failed,
            snapshot,
        });
    }
    Ok(TrafficReport { wall, service: service.snapshot(), tenants })
}

/// One client's submission + harvest loop.
fn client_loop(
    service: &EngineService,
    spec: &TrafficSpec,
    index: usize,
    id: TenantId,
    load: &TenantLoad,
) -> Result<ClientOutcome> {
    let input = spec.share_input.then(|| spec.shared_input(index));
    let mut out = ClientOutcome { offered: 0, shed: 0, ok: 0, exec_failed: 0 };
    let mut tickets: Vec<Ticket> = Vec::new();
    for i in 0..load.jobs {
        out.offered += 1;
        match service.submit(id, spec.job_for(index, i, input.as_ref())) {
            Ok(ticket) => tickets.push(ticket),
            Err(e) if e.is_overload() => out.shed += 1,
            Err(e) => return Err(e),
        }
        if !load.think.is_zero() {
            thread::sleep(load.think);
        }
    }
    for ticket in tickets {
        match ticket.wait() {
            Ok(outcome) if outcome.success() => out.ok += 1,
            Ok(_) => out.exec_failed += 1,
            Err(_) => out.exec_failed += 1,
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::ServiceBuilder;

    #[test]
    fn traffic_streams_are_deterministic() {
        let spec = TrafficSpec::new(4, 8, 4).tenant("a", 1, 8).with_failures(true);
        // Same (tenant, index) → same job spec, different index →
        // different seed stream.
        let j1 = spec.job_for(0, 2, None);
        let j2 = spec.job_for(0, 2, None);
        let j3 = spec.job_for(0, 3, None);
        let (Job::Tsqr(s1), Job::Tsqr(s2), Job::Tsqr(s3)) = (j1, j2, j3) else {
            panic!("driver emits TSQR jobs")
        };
        assert_eq!(s1.seed, s2.seed);
        assert_ne!(s1.seed, s3.seed);
        // Every 4th job (index % 4 == 3) carries the armed schedule.
        assert!(s2.schedule.remaining() == 0 && s3.schedule.remaining() == 1);
        // Shared inputs are per-tenant deterministic.
        assert_eq!(*spec.shared_input(0), *spec.shared_input(0));
    }

    #[test]
    fn empty_spec_is_a_config_error() {
        let service = ServiceBuilder::new().build(crate::engine::Engine::host());
        let spec = TrafficSpec::new(4, 8, 4);
        assert!(run_traffic(&service, &spec).is_err());
    }

    #[test]
    fn overloaded_drive_sheds_but_completes_the_rest() {
        // Tiny queue + paused start: the flood must shed most of its
        // jobs, yet everything admitted completes once resumed.
        let service = ServiceBuilder::new()
            .queue_depth(4)
            .tenant_depth(4)
            .max_inflight(1)
            .start_paused(true)
            .build(crate::engine::Engine::host());
        let spec = TrafficSpec::new(4, 8, 4).tenant("flood", 1, 12);
        let report = thread::scope(|scope| {
            let h = scope.spawn(|| run_traffic(&service, &spec).unwrap());
            // Let the client fill the queue, then open the tap.  (The
            // sleep only makes the shed count LARGER if the client is
            // slow; the assertions below hold either way.)
            thread::sleep(Duration::from_millis(50));
            service.resume();
            h.join().expect("driver thread")
        });
        let t = &report.tenants[0];
        assert_eq!(t.offered, 12);
        assert_eq!(t.shed + t.ok + t.exec_failed, 12, "every job accounted");
        assert!(t.shed >= 1, "paused 4-deep queue must shed under a 12-job flood");
        assert_eq!(t.exec_failed, 0);
        assert_eq!(t.snapshot.shed, t.shed, "client and service agree on sheds");
        assert_eq!(report.service.completed, t.ok);
    }
}
