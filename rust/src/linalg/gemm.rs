//! Deterministic packed f64 GEMM — the level-3 engine of the
//! compact-WY fast path, with runtime-dispatched SIMD microkernels and
//! pool-parallel column slabs.
//!
//! ## Why hand-rolled
//!
//! The crate builds with zero external dependencies (no BLAS, no
//! `matrixmultiply`), and the CAQR fault-tolerance contract adds a
//! constraint most BLAS libraries do not make: results must be
//! **bit-reproducible run to run** so that two replicas of the same
//! update task — the redundancy the paper's fault tolerance is paid
//! with — always produce identical bit patterns.  This kernel fixes
//! the summation order by construction:
//!
//! * the k dimension is consumed in ascending [`KC`]-sized chunks, and
//!   within a chunk the microkernel accumulates k ascending — so every
//!   `C[i][j]` is a left-to-right ordered sum, the same order every
//!   run;
//! * packing pads partial register tiles with zeros, which never
//!   perturbs a sum.
//!
//! ## ISA dispatch and the no-FMA rule
//!
//! The [`MR`]×[`NR`] register tile has three implementations selected
//! **once per process** ([`Isa::detect`], cached in
//! [`GemmParams::tuned`]): a scalar kernel (the fallback and the
//! desk-checkable reference), an AVX2 kernel (gated on runtime
//! detection of `avx2` *and* `fma`), and a NEON kernel on aarch64.
//! Every implementation computes each `C[i][j]` with the **same
//! per-element operation sequence** — an IEEE multiply followed by an
//! IEEE add, k ascending.  The AVX2 kernel deliberately does **not**
//! use `fmadd`: a fused multiply-add rounds once where `mul`+`add`
//! rounds twice, which would make the SIMD path bitwise-diverge from
//! the scalar kernel and (worse) make results depend on which host a
//! replica ran on.  Dropping the contraction costs a little peak
//! throughput and buys the property the whole recovery story rests on:
//! **every ISA path produces identical bits** (pinned by the
//! `simd_paths_match_scalar_bitwise` test through the forced-dispatch
//! override).
//!
//! ## Tile autotuning
//!
//! Cache-block sizes are runtime values ([`GemmParams`]), picked once
//! per process by a short timed probe (`EngineBuilder::build` warms it
//! eagerly; the first GEMM call warms it lazily otherwise) and cached
//! in a process-global `OnceLock` so every task — and every *replica*
//! — in the process uses the same tiles.  Two classes of parameter are
//! treated very differently:
//!
//! * `MC`/`NC` only reorder the traversal of *independent* `C`
//!   elements; they never change any sum's association, so the probe
//!   may pick them freely (bit-neutral).
//! * [`KC`] sets the chunk boundaries of the k-summation, so changing
//!   it changes bits for `k > KC`.  It is therefore **frozen** at its
//!   compile-time value; the autotuner never moves it.
//!
//! Environment overrides (all optional): `FT_GEMM_ISA=scalar|avx2|neon`
//! forces the dispatch (used by the equivalence tests; silently
//! downgraded to `scalar` when the hardware lacks the ISA),
//! `FT_GEMM_TILES=mc,nc` pins the bit-neutral tiles, and
//! `FT_GEMM_AUTOTUNE=0` skips the probe (defaults apply).
//!
//! ## Pool-parallel slabs
//!
//! [`gemm_into_pooled`] partitions `C` into contiguous [`NR`]-aligned
//! column slabs, one per thread: every worker *reads* the shared `A`
//! and `B` operands and *writes only its own slab* (write-local /
//! read-all, no locks on the hot path).  Within a slab the traversal
//! is exactly the sequential kernel's, so **any thread count produces
//! the sequential bits** — `threads = 1` is not just equivalent, it is
//! the same code path, and `threads = 64` reproduces it bitwise.
//! Slab tasks run on the engine's elastic
//! [`WorkerPool`](crate::engine::WorkerPool) (nested spawning is safe:
//! the pool spawns a worker whenever the queue outgrows the free set).
//!
//! Scratch (the two packing buffers) is caller-provided — hot paths
//! hand in a [`crate::linalg::Workspace`] slice so steady-state calls
//! allocate nothing (see `tests/alloc_steady_state.rs`).  Pool-side
//! slab tasks use a per-worker thread-local arena, grown once per
//! worker thread.
#![deny(unsafe_op_in_unsafe_fn)]

use std::cell::RefCell;
use std::sync::OnceLock;

use crate::engine::{TaskGroup, WorkerPool};

/// Register-tile rows (A strip height).
pub const MR: usize = 4;
/// Register-tile columns (B strip width).
pub const NR: usize = 8;
/// k-dimension cache block: one packed A strip (`MR·KC` f64 = 8 KiB)
/// stays in L1 while it is reused across the whole B slab.  **Frozen**:
/// KC sets the chunk boundaries of the fixed summation order, so the
/// autotuner never moves it (see the module docs).
pub const KC: usize = 256;
/// Default row cache block (multiple of [`MR`]): the packed `MC×KC` A
/// block (~192 KiB) targets L2.  The autotune probe may pick a larger
/// or smaller value at runtime ([`GemmParams`]); bit-neutral.
pub const MC: usize = 96;
/// Default column cache block (multiple of [`NR`]): the packed `KC×NC`
/// B slab (~512 KiB) targets L3.  Runtime-tunable like [`MC`];
/// bit-neutral.
pub const NC: usize = 256;

/// Upper bound the autotuner (and `FT_GEMM_TILES`) may raise `mc` to.
const MC_MAX: usize = 192;
/// Upper bound the autotuner (and `FT_GEMM_TILES`) may raise `nc` to.
const NC_MAX: usize = 512;

/// f64 scratch (both packing buffers) one [`gemm_into`] call needs.
/// Sized for the **largest** tile configuration the autotuner may
/// select, so a buffer of this size is sufficient whatever
/// [`GemmParams::tuned`] resolves to on this host.
pub const GEMM_SCRATCH: usize = MC_MAX * KC + KC * NC_MAX;

/// A parallel slab dispatch is only worth the pool hop when the GEMM
/// is at least this many flops (`2·m·n·k`); smaller calls run
/// sequentially whatever the thread budget.  Shape-only — never data-
/// or timing-dependent — so the sequential/parallel choice is
/// deterministic (and bit-irrelevant anyway: both paths produce the
/// same bits).
const PAR_MIN_FLOPS: u64 = 2_000_000;

thread_local! {
    /// Per-worker packing arena for pool-side slab tasks: grown to
    /// [`GEMM_SCRATCH`] on the first slab a worker executes, reused
    /// (zero allocation) for every slab after that.
    static SLAB_SCRATCH: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

// ---------------------------------------------------------------------
// ISA detection and forced dispatch
// ---------------------------------------------------------------------

/// Instruction-set paths the microkernel dispatches over.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar kernel — the fallback on every target and the
    /// reference the SIMD paths are bitwise-pinned against.
    Scalar,
    /// 4-lane f64 AVX2 kernel (x86_64; requires runtime `avx2` + `fma`
    /// detection — `fma` is required as a target-generation gate even
    /// though the kernel deliberately never fuses, see module docs).
    Avx2,
    /// 2-lane f64 NEON kernel (aarch64).
    Neon,
}

impl Isa {
    /// Stable lowercase name (recorded in `CpuInfo` and bench JSON).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Parse a [`name`](Self::name) (the `FT_GEMM_ISA` syntax).
    pub fn parse(s: &str) -> Option<Isa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }

    /// Is this path executable on the current hardware?
    pub fn usable(self) -> bool {
        match self {
            Isa::Scalar => true,
            Isa::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            Isa::Neon => {
                #[cfg(target_arch = "aarch64")]
                {
                    std::arch::is_aarch64_feature_detected!("neon")
                }
                #[cfg(not(target_arch = "aarch64"))]
                {
                    false
                }
            }
        }
    }

    /// Every path usable on this host (always includes `Scalar`) — the
    /// equivalence tests iterate this to cover each reachable kernel.
    pub fn available() -> Vec<Isa> {
        [Isa::Scalar, Isa::Avx2, Isa::Neon].into_iter().filter(|i| i.usable()).collect()
    }

    /// The best usable path, honoring a `FT_GEMM_ISA` override (an
    /// override naming an unusable path downgrades to `Scalar` rather
    /// than risking an illegal instruction).
    pub fn detect() -> Isa {
        let forced = std::env::var("FT_GEMM_ISA").ok();
        Self::detect_from(forced.as_deref())
    }

    /// [`detect`](Self::detect) with the override injected (testable
    /// without touching process environment).
    pub fn detect_from(forced: Option<&str>) -> Isa {
        if let Some(name) = forced {
            let want = Isa::parse(name).unwrap_or(Isa::Scalar);
            return if want.usable() { want } else { Isa::Scalar };
        }
        if Isa::Avx2.usable() {
            Isa::Avx2
        } else if Isa::Neon.usable() {
            Isa::Neon
        } else {
            Isa::Scalar
        }
    }

    /// Downgrade to a usable path (guards a hand-built
    /// [`GemmParams`] naming an ISA this hardware lacks — the unsafe
    /// kernels are only ever entered behind this check).
    fn validated(self) -> Isa {
        if self.usable() { self } else { Isa::Scalar }
    }
}

// ---------------------------------------------------------------------
// Runtime tile parameters + autotune
// ---------------------------------------------------------------------

/// Runtime kernel configuration: the dispatched [`Isa`] plus the cache
/// tiles.  `kc` is always [`KC`] (frozen, bit-affecting); `mc`/`nc` are
/// bit-neutral and autotuned.  Obtain via [`GemmParams::tuned`] (the
/// process-wide cached probe) or build one explicitly for tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmParams {
    /// Microkernel path (validated against the hardware at call time).
    pub isa: Isa,
    /// k cache block — always [`KC`] after normalization.
    pub kc: usize,
    /// Row cache block (multiple of [`MR`], at most `MC_MAX`).
    pub mc: usize,
    /// Column cache block (multiple of [`NR`], at most `NC_MAX`).
    pub nc: usize,
}

impl GemmParams {
    /// The compile-time default tiles on the scalar path — the pinned
    /// configuration the bitwise tests reference.
    pub fn pinned() -> GemmParams {
        GemmParams { isa: Isa::Scalar, kc: KC, mc: MC, nc: NC }
    }

    /// Default tiles on an explicit ISA path.
    pub fn with_isa(isa: Isa) -> GemmParams {
        GemmParams { isa, ..Self::pinned() }
    }

    /// f64 packing scratch one sequential call with these tiles needs
    /// (always ≤ [`GEMM_SCRATCH`] after normalization).
    pub fn scratch_len(&self) -> usize {
        self.mc * self.kc + self.kc * self.nc
    }

    /// Clamp to legal values: `kc` frozen at [`KC`], `mc`/`nc` rounded
    /// down to register-tile multiples within the [`GEMM_SCRATCH`]
    /// budget, ISA downgraded if the hardware lacks it.
    pub fn normalized(mut self) -> GemmParams {
        self.isa = self.isa.validated();
        self.kc = KC;
        self.mc = (self.mc.clamp(MR, MC_MAX) / MR) * MR;
        self.nc = (self.nc.clamp(NR, NC_MAX) / NR) * NR;
        self
    }

    /// The process-wide tuned configuration: detected ISA + probed
    /// tiles, computed once and cached (every replica in the process
    /// shares it — see the module docs on determinism).
    pub fn tuned() -> &'static GemmParams {
        static TUNED: OnceLock<GemmParams> = OnceLock::new();
        TUNED.get_or_init(|| {
            let isa = Isa::detect();
            let tiles = std::env::var("FT_GEMM_TILES").ok();
            let skip = std::env::var("FT_GEMM_AUTOTUNE").is_ok_and(|v| v == "0");
            resolve_params(isa, tiles.as_deref(), skip)
        })
    }
}

/// Resolve the tuned parameters from the (injected) environment: an
/// explicit `FT_GEMM_TILES=mc,nc` wins, `FT_GEMM_AUTOTUNE=0` falls
/// back to the defaults, otherwise the timed probe picks the tiles.
fn resolve_params(isa: Isa, tiles: Option<&str>, skip_probe: bool) -> GemmParams {
    if let Some(p) = parse_tiles(isa, tiles) {
        return p;
    }
    if skip_probe {
        return GemmParams::with_isa(isa).normalized();
    }
    autotune_probe(isa)
}

/// Parse `FT_GEMM_TILES=mc,nc` (normalized; `None` on absent/bad input).
fn parse_tiles(isa: Isa, tiles: Option<&str>) -> Option<GemmParams> {
    let spec = tiles?;
    let mut it = spec.split(',').map(|t| t.trim().parse::<usize>());
    match (it.next(), it.next(), it.next()) {
        (Some(Ok(mc)), Some(Ok(nc)), None) => {
            Some(GemmParams { isa, kc: KC, mc, nc }.normalized())
        }
        _ => None,
    }
}

/// Short timed probe over bit-neutral `(mc, nc)` candidates: one fixed
/// synthetic GEMM per candidate, fastest wins with hysteresis toward
/// the default (a candidate must beat it by >5 % to displace it).  The
/// *choice* is timing-dependent but every choice is bit-neutral, and
/// the result is cached process-wide, so numerical reproducibility is
/// unaffected (see module docs).
fn autotune_probe(isa: Isa) -> GemmParams {
    const CANDIDATES: &[(usize, usize)] = &[(MC, NC), (48, NC), (192, NC), (MC, 512), (192, 512)];
    let (m, n, k) = (192, 256, KC);
    // Deterministic synthetic operands (cheap xorshift fill).
    let mut s = 0x9E3779B97F4A7C15u64;
    let mut fill = |len: usize| -> Vec<f64> {
        (0..len)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s >> 11) as f64 / (1u64 << 53) as f64 - 0.5
            })
            .collect()
    };
    let a = fill(m * k);
    let b = fill(k * n);
    let mut c = vec![0.0f64; m * n];
    let mut scratch = vec![0.0f64; GEMM_SCRATCH];
    let mut time = |p: &GemmParams| {
        // Two runs, keep the faster (smooths one-off cache misses).
        let mut best = std::time::Duration::MAX;
        for _ in 0..2 {
            let t0 = std::time::Instant::now();
            gemm_into_with(p, m, n, k, &a, false, &b, Accum::Set, &mut c, &mut scratch);
            best = best.min(t0.elapsed());
        }
        best
    };
    let default = GemmParams::with_isa(isa).normalized();
    let t_default = time(&default);
    let mut best = default;
    let mut t_best = t_default;
    for &(mc, nc) in CANDIDATES {
        let p = GemmParams { isa, kc: KC, mc, nc }.normalized();
        if p == default {
            continue;
        }
        let t = time(&p);
        if t < t_best {
            best = p;
            t_best = t;
        }
    }
    // Hysteresis: stay on the default unless the winner is >5% faster.
    if best != default && t_best.as_secs_f64() > t_default.as_secs_f64() * 0.95 {
        best = default;
    }
    std::hint::black_box(&c);
    best
}

/// How [`gemm_into`] combines the product with the existing `C`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Accum {
    /// `C = A·B` (C's prior contents are ignored).
    Set,
    /// `C += A·B`.
    Add,
    /// `C -= A·B`.
    Sub,
}

// ---------------------------------------------------------------------
// Packing
// ---------------------------------------------------------------------

/// Pack the `mc×kc` block of A at `(ic, pc)` into [`MR`]-row strips.
///
/// `a` is row-major `m×k` when `a_trans` is false, or row-major `k×m`
/// holding Aᵀ when true (the packing absorbs the transpose, so the
/// microkernel never strides).  Partial strips are zero-padded.
#[allow(clippy::too_many_arguments)] // BLAS-shaped: dims + operands + block offsets
pub fn pack_a(
    a: &[f64],
    a_trans: bool,
    m: usize,
    k: usize,
    ic: usize,
    pc: usize,
    mc: usize,
    kc: usize,
    out: &mut [f64],
) {
    debug_assert!(out.len() >= mc.div_ceil(MR) * MR * kc);
    for s in 0..mc.div_ceil(MR) {
        let base = s * MR * kc;
        for p in 0..kc {
            for r in 0..MR {
                let i = ic + s * MR + r;
                out[base + p * MR + r] = if s * MR + r < mc {
                    if a_trans { a[(pc + p) * m + i] } else { a[i * k + (pc + p)] }
                } else {
                    0.0
                };
            }
        }
    }
}

/// Pack the `kc×nc` block of row-major `k×n` B at `(pc, jc)` into
/// [`NR`]-column strips (zero-padded).
pub fn pack_b(
    b: &[f64],
    n: usize,
    pc: usize,
    jc: usize,
    kc: usize,
    nc: usize,
    out: &mut [f64],
) {
    debug_assert!(out.len() >= nc.div_ceil(NR) * NR * kc);
    for t in 0..nc.div_ceil(NR) {
        let base = t * NR * kc;
        for p in 0..kc {
            for c in 0..NR {
                let j = jc + t * NR + c;
                out[base + p * NR + c] =
                    if t * NR + c < nc { b[(pc + p) * n + j] } else { 0.0 };
            }
        }
    }
}

// ---------------------------------------------------------------------
// Microkernels (one per ISA; all bitwise-identical by construction)
// ---------------------------------------------------------------------

/// The scalar [`MR`]×[`NR`] register tile: `acc += a_strip · b_strip`
/// over one `kc` chunk, k ascending (the fixed summation order).
#[inline(always)]
fn microkernel(kc: usize, a: &[f64], b: &[f64], acc: &mut [f64; MR * NR]) {
    debug_assert!(a.len() >= kc * MR && b.len() >= kc * NR);
    for p in 0..kc {
        let ap = &a[p * MR..p * MR + MR];
        let bp = &b[p * NR..p * NR + NR];
        for (i, &ai) in ap.iter().enumerate() {
            let row = &mut acc[i * NR..i * NR + NR];
            for (j, &bj) in bp.iter().enumerate() {
                row[j] += ai * bj;
            }
        }
    }
}

/// AVX2 variant of [`microkernel`]: 8 ymm accumulators (4 rows × 2
/// vectors of 4 lanes).  Uses separate `mul` + `add` — **never**
/// `fmadd` — so every lane performs bit-for-bit the scalar kernel's
/// round-twice arithmetic (see the module docs on the no-FMA rule).
///
/// # Safety
///
/// Caller must have verified `avx2` is available on the running CPU
/// (this module only calls it behind [`Isa::usable`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn microkernel_avx2(kc: usize, a: &[f64], b: &[f64], acc: &mut [f64; MR * NR]) {
    use std::arch::x86_64::{
        __m256d, _mm256_add_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd, _mm256_storeu_pd,
    };
    debug_assert!(a.len() >= kc * MR && b.len() >= kc * NR);
    // SAFETY: every pointer below stays within the slices whose lengths
    // were just asserted (a: kc*MR, b: kc*NR, acc: MR*NR); loadu/storeu
    // have no alignment requirement.
    unsafe {
        let mut c: [[__m256d; 2]; MR] = [[_mm256_set1_pd(0.0); 2]; MR];
        for (i, ci) in c.iter_mut().enumerate() {
            ci[0] = _mm256_loadu_pd(acc.as_ptr().add(i * NR));
            ci[1] = _mm256_loadu_pd(acc.as_ptr().add(i * NR + 4));
        }
        for p in 0..kc {
            let bp = b.as_ptr().add(p * NR);
            let b0 = _mm256_loadu_pd(bp);
            let b1 = _mm256_loadu_pd(bp.add(4));
            let ap = a.as_ptr().add(p * MR);
            for (i, ci) in c.iter_mut().enumerate() {
                let ai = _mm256_set1_pd(*ap.add(i));
                // mul then add, never fmadd: bit-parity with scalar.
                ci[0] = _mm256_add_pd(ci[0], _mm256_mul_pd(ai, b0));
                ci[1] = _mm256_add_pd(ci[1], _mm256_mul_pd(ai, b1));
            }
        }
        for (i, ci) in c.iter().enumerate() {
            _mm256_storeu_pd(acc.as_mut_ptr().add(i * NR), ci[0]);
            _mm256_storeu_pd(acc.as_mut_ptr().add(i * NR + 4), ci[1]);
        }
    }
}

/// NEON variant of [`microkernel`]: 16 q-register accumulators (4 rows
/// × 4 vectors of 2 lanes), `vmul` + `vadd` (never `vfma`) for bit
/// parity with the scalar kernel.
///
/// # Safety
///
/// Caller must have verified `neon` is available on the running CPU
/// (this module only calls it behind [`Isa::usable`]).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn microkernel_neon(kc: usize, a: &[f64], b: &[f64], acc: &mut [f64; MR * NR]) {
    use std::arch::aarch64::{
        float64x2_t, vaddq_f64, vdupq_n_f64, vld1q_f64, vmulq_f64, vst1q_f64,
    };
    debug_assert!(a.len() >= kc * MR && b.len() >= kc * NR);
    // SAFETY: every pointer below stays within the slices whose lengths
    // were just asserted; vld1q/vst1q are alignment-free on aarch64.
    unsafe {
        let mut c: [[float64x2_t; 4]; MR] = [[vdupq_n_f64(0.0); 4]; MR];
        for (i, ci) in c.iter_mut().enumerate() {
            for (v, cv) in ci.iter_mut().enumerate() {
                *cv = vld1q_f64(acc.as_ptr().add(i * NR + 2 * v));
            }
        }
        for p in 0..kc {
            let bp = b.as_ptr().add(p * NR);
            let bv = [
                vld1q_f64(bp),
                vld1q_f64(bp.add(2)),
                vld1q_f64(bp.add(4)),
                vld1q_f64(bp.add(6)),
            ];
            let ap = a.as_ptr().add(p * MR);
            for (i, ci) in c.iter_mut().enumerate() {
                let ai = vdupq_n_f64(*ap.add(i));
                for (v, cv) in ci.iter_mut().enumerate() {
                    // mul then add, never vfma: bit-parity with scalar.
                    *cv = vaddq_f64(*cv, vmulq_f64(ai, bv[v]));
                }
            }
        }
        for (i, ci) in c.iter().enumerate() {
            for (v, cv) in ci.iter().enumerate() {
                vst1q_f64(acc.as_mut_ptr().add(i * NR + 2 * v), *cv);
            }
        }
    }
}

/// Dispatch one register tile to the ISA's kernel.  `isa` must be
/// pre-validated ([`Isa::validated`]) — that check is the safety
/// argument for entering the `target_feature` kernels.
#[inline(always)]
fn run_microkernel(isa: Isa, kc: usize, a: &[f64], b: &[f64], acc: &mut [f64; MR * NR]) {
    match isa {
        Isa::Scalar => microkernel(kc, a, b, acc),
        Isa::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: Isa::Avx2 reaches here only via Isa::validated(),
            // which confirmed runtime avx2+fma support.
            unsafe {
                microkernel_avx2(kc, a, b, acc)
            }
            #[cfg(not(target_arch = "x86_64"))]
            microkernel(kc, a, b, acc)
        }
        Isa::Neon => {
            #[cfg(target_arch = "aarch64")]
            // SAFETY: Isa::Neon reaches here only via Isa::validated(),
            // which confirmed runtime neon support.
            unsafe {
                microkernel_neon(kc, a, b, acc)
            }
            #[cfg(not(target_arch = "aarch64"))]
            microkernel(kc, a, b, acc)
        }
    }
}

// ---------------------------------------------------------------------
// The blocked loop nest (sequential core, window-addressed)
// ---------------------------------------------------------------------

/// The packed loop nest over the column window `[j_lo, j_hi)` of C.
///
/// Raw-pointer C is what lets the pool-parallel slabs write disjoint
/// windows of one buffer without aliasing `&mut`s.
///
/// # Safety
///
/// `c` must point to a row-major `m×n` f64 buffer that is valid for
/// writes, and no other thread may concurrently access elements in
/// columns `[j_lo, j_hi)` while this runs (slab disjointness).
#[allow(clippy::too_many_arguments)]
unsafe fn gemm_window_raw(
    params: &GemmParams,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    a_trans: bool,
    b: &[f64],
    acc: Accum,
    c: *mut f64,
    j_lo: usize,
    j_hi: usize,
    scratch: &mut [f64],
) {
    let (kc_blk, mc_blk, nc_blk) = (params.kc, params.mc, params.nc);
    let (apack, rest) = scratch.split_at_mut(mc_blk * kc_blk);
    let bpack = &mut rest[..kc_blk * nc_blk];

    let mut jc = j_lo;
    while jc < j_hi {
        let nc = nc_blk.min(j_hi - jc);
        let mut pc = 0;
        while pc < k {
            let kc = kc_blk.min(k - pc);
            // How this kc chunk lands in C: the first chunk carries the
            // caller's Accum, later chunks accumulate on top of it.
            let chunk_acc = if pc == 0 {
                acc
            } else if acc == Accum::Sub {
                Accum::Sub
            } else {
                Accum::Add
            };
            pack_b(b, n, pc, jc, kc, nc, bpack);
            let mut ic = 0;
            while ic < m {
                let mc = mc_blk.min(m - ic);
                pack_a(a, a_trans, m, k, ic, pc, mc, kc, apack);
                for jr in (0..nc).step_by(NR) {
                    let nr = NR.min(nc - jr);
                    let bstrip = &bpack[(jr / NR) * NR * kc..(jr / NR + 1) * NR * kc];
                    for ir in (0..mc).step_by(MR) {
                        let mr = MR.min(mc - ir);
                        let astrip = &apack[(ir / MR) * MR * kc..(ir / MR + 1) * MR * kc];
                        let mut tile = [0.0f64; MR * NR];
                        run_microkernel(params.isa, kc, astrip, bstrip, &mut tile);
                        for i in 0..mr {
                            let crow = (ic + ir + i) * n + jc + jr;
                            for j in 0..nr {
                                let v = tile[i * NR + j];
                                // SAFETY: (ic+ir+i) < m and jc+jr+j <
                                // j_hi ≤ n, so the element is inside the
                                // m×n buffer and inside this window —
                                // the caller's disjointness contract.
                                unsafe {
                                    let p = c.add(crow + j);
                                    match chunk_acc {
                                        Accum::Set => *p = v,
                                        Accum::Add => *p += v,
                                        Accum::Sub => *p -= v,
                                    }
                                }
                            }
                        }
                    }
                }
                ic += mc;
            }
            pc += kc;
        }
        jc += nc;
    }
}

/// Validate operand shapes shared by every entry point.
fn check_shapes(m: usize, n: usize, k: usize, a: &[f64], b: &[f64], c: &[f64]) {
    assert_eq!(a.len(), m * k, "gemm: A length != m*k");
    assert_eq!(b.len(), k * n, "gemm: B length != k*n");
    assert_eq!(c.len(), m * n, "gemm: C length != m*n");
}

/// `k == 0` degenerate handling: `Set` zeroes C, `Add`/`Sub` leave it.
fn handle_k0(acc: Accum, c: &mut [f64]) {
    if acc == Accum::Set {
        c.fill(0.0);
    }
}

// ---------------------------------------------------------------------
// Public entry points
// ---------------------------------------------------------------------

/// Packed, cache-blocked, register-tiled `C (m×n) ?= A (m×k) · B (k×n)`
/// with a fixed summation order (bit-reproducible run to run and across
/// every ISA path; see the module docs).  All operands row-major;
/// `a_trans` reinterprets `a` as a row-major `k×m` buffer holding Aᵀ.
/// `scratch` must provide at least [`GEMM_SCRATCH`] f64 (packing
/// buffers — no allocation inside).  Uses the process-wide
/// [`GemmParams::tuned`] configuration.
#[allow(clippy::too_many_arguments)] // the classic GEMM signature
pub fn gemm_into(
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    a_trans: bool,
    b: &[f64],
    acc: Accum,
    c: &mut [f64],
    scratch: &mut [f64],
) {
    gemm_into_with(GemmParams::tuned(), m, n, k, a, a_trans, b, acc, c, scratch);
}

/// [`gemm_into`] under an explicit configuration — the forced-dispatch
/// entry the SIMD/scalar equivalence tests (and the autotune probe)
/// drive.  `params` is re-normalized, so a hand-built value can never
/// reach an unsupported kernel or overrun `scratch`.
#[allow(clippy::too_many_arguments)]
pub fn gemm_into_with(
    params: &GemmParams,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    a_trans: bool,
    b: &[f64],
    acc: Accum,
    c: &mut [f64],
    scratch: &mut [f64],
) {
    check_shapes(m, n, k, a, b, c);
    let p = params.normalized();
    assert!(
        scratch.len() >= p.scratch_len(),
        "gemm_into: scratch must hold at least {} f64 (have {})",
        p.scratch_len(),
        scratch.len()
    );
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        handle_k0(acc, c);
        return;
    }
    // SAFETY: `c` is an exclusive borrow covering the full [0, n)
    // window; no other thread can touch it.
    unsafe {
        gemm_window_raw(&p, m, n, k, a, a_trans, b, acc, c.as_mut_ptr(), 0, n, scratch);
    }
}

/// Shared operand pointers smuggled into pool tasks.  Sound because
/// [`gemm_into_pooled`] joins every slab task before returning (the
/// borrows strictly outlive every access) and slabs write disjoint
/// column windows of `c` (read-all / write-local).
#[derive(Clone, Copy)]
struct RawOperands {
    a: *const f64,
    b: *const f64,
    c: *mut f64,
}
// SAFETY: see `RawOperands` — accesses are read-only (a, b) or
// disjoint-window writes (c), all joined before the borrows end.
unsafe impl Send for RawOperands {}

/// Pool-parallel [`gemm_into`]: C is split into `threads` contiguous
/// [`NR`]-aligned column slabs, each computed by the sequential kernel
/// — so the result is **bitwise identical to the sequential call for
/// every thread count** (each element sees exactly the same operation
/// sequence; only the traversal interleaving across independent
/// elements changes).  `threads <= 1`, degenerate shapes, and GEMMs
/// under the flop threshold take the sequential path outright.
///
/// The calling thread computes slab 0 on `scratch`; slabs 1.. run on
/// `pool` workers with per-worker thread-local arenas (zero steady-
/// state allocation once each worker has warmed).
#[allow(clippy::too_many_arguments)]
pub fn gemm_into_pooled(
    pool: &WorkerPool,
    threads: usize,
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    a_trans: bool,
    b: &[f64],
    acc: Accum,
    c: &mut [f64],
    scratch: &mut [f64],
) {
    let t = threads.min(n.div_ceil(NR)).max(1);
    if t <= 1 || gemm_flops(m, n, k) < PAR_MIN_FLOPS {
        return gemm_into(m, n, k, a, a_trans, b, acc, c, scratch);
    }
    check_shapes(m, n, k, a, b, c);
    let p = GemmParams::tuned().normalized();
    assert!(
        scratch.len() >= p.scratch_len(),
        "gemm_into_pooled: scratch must hold at least {} f64",
        p.scratch_len()
    );
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        handle_k0(acc, c);
        return;
    }
    // NR-aligned slab bounds: slab i covers columns [bound(i), bound(i+1)).
    let units = n.div_ceil(NR);
    let bound = |i: usize| ((units * i / t) * NR).min(n);
    let ops = RawOperands { a: a.as_ptr(), b: b.as_ptr(), c: c.as_mut_ptr() };
    let group = TaskGroup::new(pool.clone());
    for i in 1..t {
        let (j_lo, j_hi) = (bound(i), bound(i + 1));
        group.spawn(move || {
            SLAB_SCRATCH.with(|cell| {
                let mut arena = cell.borrow_mut();
                if arena.len() < GEMM_SCRATCH {
                    arena.resize(GEMM_SCRATCH, 0.0);
                }
                // SAFETY: the pointers outlive this task (the caller
                // joins the group before returning), a/b are only
                // read, and this slab writes only columns
                // [j_lo, j_hi) — disjoint from every other slab.
                unsafe {
                    let av = std::slice::from_raw_parts(ops.a, m * k);
                    let bv = std::slice::from_raw_parts(ops.b, k * n);
                    gemm_window_raw(
                        &p, m, n, k, av, a_trans, bv, acc, ops.c, j_lo, j_hi, &mut arena,
                    );
                }
            });
        });
    }
    // Slab 0 on the calling thread, using the caller's scratch.
    // SAFETY: exclusive ownership of columns [bound(0), bound(1)).
    unsafe {
        let (j_lo, j_hi) = (bound(0), bound(1));
        gemm_window_raw(&p, m, n, k, a, a_trans, b, acc, c.as_mut_ptr(), j_lo, j_hi, scratch);
    }
    group.wait_idle();
}

/// Modelled flop count of one `m×n×k` GEMM (`2·m·n·k`).
pub fn gemm_flops(m: usize, n: usize, k: usize) -> u64 {
    2 * m as u64 * n as u64 * k as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(m: usize, n: usize, k: usize, a: &[f64], a_trans: bool, b: &[f64]) -> Vec<f64> {
        let mut c = vec![0.0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    let av = if a_trans { a[p * m + i] } else { a[i * k + p] };
                    acc += av * b[p * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn randvec(rng: &mut Rng, len: usize) -> Vec<f64> {
        (0..len).map(|_| rng.f64() - 0.5).collect()
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn matches_naive_exactly_when_k_fits_one_chunk() {
        // One KC chunk ⇒ identical left-to-right summation order as the
        // naive loop ⇒ bitwise equality, including ragged tile edges —
        // on EVERY usable ISA path.
        let mut rng = Rng::new(7);
        for (m, n, k) in [(1, 1, 1), (5, 9, 3), (13, 17, 31), (MC + 3, NC + 5, KC), (4, 8, 64)] {
            let a = randvec(&mut rng, m * k);
            let b = randvec(&mut rng, k * n);
            let want = naive(m, n, k, &a, false, &b);
            for isa in Isa::available() {
                let params = GemmParams::with_isa(isa);
                let mut c = vec![f64::NAN; m * n];
                let mut scratch = vec![0.0f64; GEMM_SCRATCH];
                gemm_into_with(&params, m, n, k, &a, false, &b, Accum::Set, &mut c, &mut scratch);
                assert_eq!(bits(&c), bits(&want), "bitwise mismatch at {m}x{n}x{k} on {isa:?}");
            }
        }
    }

    #[test]
    fn simd_paths_match_scalar_bitwise() {
        // Forced-dispatch equivalence sweep: every usable ISA equals
        // the scalar kernel bit for bit — ragged edges (m, n not
        // multiples of MR/NR), transposed A, multi-chunk k, every
        // accumulate mode.
        let mut rng = Rng::new(0xA5A5);
        let isas = Isa::available();
        for case in 0..24 {
            let m = 1 + rng.below(2 * MR * 3 + 1);
            let n = 1 + rng.below(2 * NR * 3 + 1);
            let k = 1 + rng.below(2 * KC + 17); // crosses chunk boundaries
            let a_trans = rng.bool(0.5);
            let a = randvec(&mut rng, m * k);
            let b = randvec(&mut rng, k * n);
            for acc_mode in [Accum::Set, Accum::Add, Accum::Sub] {
                let c0 = randvec(&mut rng, m * n);
                let mut scratch = vec![0.0f64; GEMM_SCRATCH];
                let mut want = c0.clone();
                gemm_into_with(
                    &GemmParams::with_isa(Isa::Scalar),
                    m,
                    n,
                    k,
                    &a,
                    a_trans,
                    &b,
                    acc_mode,
                    &mut want,
                    &mut scratch,
                );
                for &isa in &isas {
                    let mut got = c0.clone();
                    gemm_into_with(
                        &GemmParams::with_isa(isa),
                        m,
                        n,
                        k,
                        &a,
                        a_trans,
                        &b,
                        acc_mode,
                        &mut got,
                        &mut scratch,
                    );
                    assert_eq!(
                        bits(&got),
                        bits(&want),
                        "case {case}: {isa:?} diverged from scalar at \
                         {m}x{n}x{k} trans={a_trans} acc={acc_mode:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn tile_sizes_are_bit_neutral() {
        // MC/NC only reorder independent C elements: any normalized
        // tile pair must reproduce the pinned configuration's bits.
        let mut rng = Rng::new(0xBEEF);
        let (m, n, k) = (37, 53, KC + 29);
        let a = randvec(&mut rng, m * k);
        let b = randvec(&mut rng, k * n);
        let mut scratch = vec![0.0f64; GEMM_SCRATCH];
        let mut want = vec![0.0f64; m * n];
        let pinned = GemmParams::pinned();
        gemm_into_with(&pinned, m, n, k, &a, false, &b, Accum::Set, &mut want, &mut scratch);
        for (mc, nc) in [(48, 128), (192, 512), (MR, NR), (100, 260)] {
            let p = GemmParams { isa: Isa::Scalar, kc: KC, mc, nc }.normalized();
            let mut c = vec![0.0f64; m * n];
            gemm_into_with(&p, m, n, k, &a, false, &b, Accum::Set, &mut c, &mut scratch);
            assert_eq!(bits(&c), bits(&want), "tiles ({mc},{nc}) changed bits");
        }
    }

    #[test]
    fn pooled_any_thread_count_matches_sequential_bitwise() {
        let mut rng = Rng::new(0x717A);
        // Big enough to clear PAR_MIN_FLOPS so slabs really dispatch.
        let (m, n, k) = (64, 160, 160);
        assert!(gemm_flops(m, n, k) >= PAR_MIN_FLOPS, "shape must take the parallel path");
        let a = randvec(&mut rng, m * k);
        let b = randvec(&mut rng, k * n);
        let mut scratch = vec![0.0f64; GEMM_SCRATCH];
        let mut want = vec![0.0f64; m * n];
        gemm_into(m, n, k, &a, false, &b, Accum::Set, &mut want, &mut scratch);
        let pool = WorkerPool::new();
        for threads in [1, 2, 3, 5, 16] {
            let mut c = vec![f64::NAN; m * n];
            gemm_into_pooled(
                &pool, threads, m, n, k, &a, false, &b, Accum::Set, &mut c, &mut scratch,
            );
            assert_eq!(bits(&c), bits(&want), "threads={threads} changed bits");
            // Run-to-run: a second parallel run reproduces the bits.
            let mut c2 = vec![0.0f64; m * n];
            gemm_into_pooled(
                &pool, threads, m, n, k, &a, false, &b, Accum::Set, &mut c2, &mut scratch,
            );
            assert_eq!(bits(&c), bits(&c2), "threads={threads} not run-to-run stable");
        }
        pool.shutdown();
    }

    #[test]
    fn pooled_small_problems_stay_sequential() {
        // Under the flop threshold nothing is dispatched to the pool.
        let pool = WorkerPool::new();
        let mut rng = Rng::new(3);
        let (m, n, k) = (8, 16, 8);
        let a = randvec(&mut rng, m * k);
        let b = randvec(&mut rng, k * n);
        let mut scratch = vec![0.0f64; GEMM_SCRATCH];
        let mut c = vec![0.0f64; m * n];
        gemm_into_pooled(&pool, 8, m, n, k, &a, false, &b, Accum::Set, &mut c, &mut scratch);
        assert_eq!(pool.tasks_executed(), 0, "small GEMM must not touch the pool");
        assert_eq!(bits(&c), bits(&naive(m, n, k, &a, false, &b)));
        pool.shutdown();
    }

    #[test]
    fn transposed_a_and_accumulate_modes() {
        let mut rng = Rng::new(11);
        let (m, n, k) = (10, 12, 20);
        let at = randvec(&mut rng, k * m); // row-major k×m = Aᵀ
        let b = randvec(&mut rng, k * n);
        let want = naive(m, n, k, &at, true, &b);
        let mut scratch = vec![0.0f64; GEMM_SCRATCH];
        let mut c = vec![0.0f64; m * n];
        gemm_into(m, n, k, &at, true, &b, Accum::Set, &mut c, &mut scratch);
        for (g, w) in c.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12, "trans: {g} vs {w}");
        }
        // Add then Sub round-trips back to the Set result.
        let set = c.clone();
        gemm_into(m, n, k, &at, true, &b, Accum::Add, &mut c, &mut scratch);
        gemm_into(m, n, k, &at, true, &b, Accum::Sub, &mut c, &mut scratch);
        assert_eq!(bits(&c), bits(&set), "Add then Sub of the same product must cancel bitwise");
    }

    #[test]
    fn multi_chunk_k_is_accurate_and_run_to_run_deterministic() {
        let mut rng = Rng::new(13);
        let (m, n, k) = (9, 11, 2 * KC + 37); // forces chunked accumulation
        let a = randvec(&mut rng, m * k);
        let b = randvec(&mut rng, k * n);
        let want = naive(m, n, k, &a, false, &b);
        let mut scratch = vec![0.0f64; GEMM_SCRATCH];
        let run = |scratch: &mut Vec<f64>| {
            let mut c = vec![0.0f64; m * n];
            gemm_into(m, n, k, &a, false, &b, Accum::Set, &mut c, scratch);
            c
        };
        let c1 = run(&mut scratch);
        let c2 = run(&mut scratch);
        assert_eq!(bits(&c1), bits(&c2), "identical inputs must give identical bits");
        for (g, w) in c1.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10 * k as f64, "{g} vs {w}");
        }
    }

    #[test]
    fn degenerate_dims() {
        let mut scratch = vec![0.0f64; GEMM_SCRATCH];
        let mut c = vec![5.0f64; 6];
        gemm_into(2, 3, 0, &[], false, &[], Accum::Set, &mut c, &mut scratch);
        assert!(c.iter().all(|&x| x == 0.0), "k=0 Set zeroes C");
        let mut c = vec![5.0f64; 6];
        gemm_into(2, 3, 0, &[], false, &[], Accum::Add, &mut c, &mut scratch);
        assert!(c.iter().all(|&x| x == 5.0), "k=0 Add leaves C");
        gemm_into(0, 0, 4, &[], false, &[], Accum::Set, &mut [], &mut scratch);
        // Pooled degenerates behave identically.
        let pool = WorkerPool::new();
        let mut c = vec![5.0f64; 6];
        gemm_into_pooled(&pool, 4, 2, 3, 0, &[], false, &[], Accum::Set, &mut c, &mut scratch);
        assert!(c.iter().all(|&x| x == 0.0));
        pool.shutdown();
    }

    #[test]
    fn isa_parsing_detection_and_fallback() {
        assert_eq!(Isa::parse("scalar"), Some(Isa::Scalar));
        assert_eq!(Isa::parse(" AVX2 "), Some(Isa::Avx2));
        assert_eq!(Isa::parse("neon"), Some(Isa::Neon));
        assert_eq!(Isa::parse("sse9"), None);
        assert!(Isa::Scalar.usable(), "scalar is always usable");
        let avail = Isa::available();
        assert!(avail.contains(&Isa::Scalar));
        assert!(avail.contains(&Isa::detect_from(None)), "detected ISA must be usable");
        // A forced-but-unusable (or unknown) override degrades to scalar.
        assert_eq!(Isa::detect_from(Some("warp9")), Isa::Scalar);
        for isa in [Isa::Avx2, Isa::Neon] {
            if !isa.usable() {
                assert_eq!(Isa::detect_from(Some(isa.name())), Isa::Scalar);
            } else {
                assert_eq!(Isa::detect_from(Some(isa.name())), isa);
            }
        }
        assert_eq!(Isa::detect_from(Some("scalar")), Isa::Scalar, "scalar can always be forced");
    }

    #[test]
    fn params_normalize_and_env_tile_parsing() {
        let p = GemmParams { isa: Isa::Scalar, kc: 9999, mc: 1000, nc: 7 }.normalized();
        assert_eq!(p.kc, KC, "kc is frozen");
        assert_eq!(p.mc, MC_MAX, "mc clamped to the scratch budget");
        assert_eq!(p.nc, NR, "nc rounded to an NR multiple");
        assert!(p.scratch_len() <= GEMM_SCRATCH);
        assert_eq!(GemmParams::pinned().scratch_len(), MC * KC + KC * NC);
        // FT_GEMM_TILES parsing (injected, no process-env mutation).
        let t = parse_tiles(Isa::Scalar, Some("192, 512")).unwrap();
        assert_eq!((t.mc, t.nc), (192, 512));
        assert!(parse_tiles(Isa::Scalar, Some("192")).is_none(), "two fields required");
        assert!(parse_tiles(Isa::Scalar, Some("a,b")).is_none());
        assert!(parse_tiles(Isa::Scalar, None).is_none());
        // resolve_params: explicit tiles win; skip-probe takes defaults.
        let r = resolve_params(Isa::Scalar, Some("48,128"), false);
        assert_eq!((r.mc, r.nc), (48, 128));
        let d = resolve_params(Isa::Scalar, None, true);
        assert_eq!((d.mc, d.nc), (MC, NC));
        // The cached process-wide params are normalized and stable.
        let a = GemmParams::tuned();
        let b = GemmParams::tuned();
        assert!(std::ptr::eq(a, b), "tuned params are cached once");
        assert_eq!(*a, a.normalized(), "cached params are normalized");
    }
}
