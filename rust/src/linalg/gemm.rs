//! Deterministic packed f64 GEMM — the level-3 engine of the
//! compact-WY fast path.
//!
//! ## Why hand-rolled
//!
//! The crate builds with zero external dependencies (no BLAS, no
//! `matrixmultiply`), and the CAQR fault-tolerance contract adds a
//! constraint most BLAS libraries do not make: results must be
//! **bit-reproducible run to run** so that two replicas of the same
//! update task — the redundancy the paper's fault tolerance is paid
//! with — always produce identical bit patterns.  This kernel fixes
//! the summation order by construction:
//!
//! * single-threaded, no reduction-tree reassociation;
//! * the k dimension is consumed in ascending [`KC`]-sized chunks, and
//!   within a chunk the microkernel accumulates k ascending — so every
//!   `C[i][j]` is a left-to-right ordered sum, the same order every
//!   run;
//! * packing pads partial register tiles with zeros, which never
//!   perturbs a sum.
//!
//! ## Shape of the kernel
//!
//! Classic three-level blocking (BLIS-style): `NC`-wide column slabs of
//! B × `KC`-deep k chunks × `MC`-tall row slabs of A, with A packed
//! into [`MR`]-row strips and B into [`NR`]-column strips so the inner
//! [`MR`]×[`NR`] register tile streams both operands contiguously.
//! Plain safe rust — the 4×8 f64 tile autovectorizes on every target
//! the CI builds for; no intrinsics, no `unsafe`.
//!
//! Scratch (the two packing buffers) is caller-provided — hot paths
//! hand in a [`crate::linalg::Workspace`] slice so steady-state calls
//! allocate nothing (see `tests/alloc_steady_state.rs`).

/// Register-tile rows (A strip height).
pub const MR: usize = 4;
/// Register-tile columns (B strip width).
pub const NR: usize = 8;
/// k-dimension cache block: one packed A strip (`MR·KC` f64 = 8 KiB)
/// stays in L1 while it is reused across the whole B slab.
pub const KC: usize = 256;
/// Row cache block (multiple of [`MR`]): the packed `MC×KC` A block
/// (~192 KiB) targets L2.
pub const MC: usize = 96;
/// Column cache block (multiple of [`NR`]): the packed `KC×NC` B slab
/// (~512 KiB) targets L3.
pub const NC: usize = 256;

/// f64 scratch (both packing buffers) one [`gemm_into`] call needs.
pub const GEMM_SCRATCH: usize = MC * KC + KC * NC;

/// How [`gemm_into`] combines the product with the existing `C`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Accum {
    /// `C = A·B` (C's prior contents are ignored).
    Set,
    /// `C += A·B`.
    Add,
    /// `C -= A·B`.
    Sub,
}

/// Pack the `mc×kc` block of A at `(ic, pc)` into [`MR`]-row strips.
///
/// `a` is row-major `m×k` when `a_trans` is false, or row-major `k×m`
/// holding Aᵀ when true (the packing absorbs the transpose, so the
/// microkernel never strides).  Partial strips are zero-padded.
#[allow(clippy::too_many_arguments)] // BLAS-shaped: dims + operands + block offsets
pub fn pack_a(
    a: &[f64],
    a_trans: bool,
    m: usize,
    k: usize,
    ic: usize,
    pc: usize,
    mc: usize,
    kc: usize,
    out: &mut [f64],
) {
    debug_assert!(out.len() >= mc.div_ceil(MR) * MR * kc);
    for s in 0..mc.div_ceil(MR) {
        let base = s * MR * kc;
        for p in 0..kc {
            for r in 0..MR {
                let i = ic + s * MR + r;
                out[base + p * MR + r] = if s * MR + r < mc {
                    if a_trans { a[(pc + p) * m + i] } else { a[i * k + (pc + p)] }
                } else {
                    0.0
                };
            }
        }
    }
}

/// Pack the `kc×nc` block of row-major `k×n` B at `(pc, jc)` into
/// [`NR`]-column strips (zero-padded).
pub fn pack_b(
    b: &[f64],
    n: usize,
    pc: usize,
    jc: usize,
    kc: usize,
    nc: usize,
    out: &mut [f64],
) {
    debug_assert!(out.len() >= nc.div_ceil(NR) * NR * kc);
    for t in 0..nc.div_ceil(NR) {
        let base = t * NR * kc;
        for p in 0..kc {
            for c in 0..NR {
                let j = jc + t * NR + c;
                out[base + p * NR + c] =
                    if t * NR + c < nc { b[(pc + p) * n + j] } else { 0.0 };
            }
        }
    }
}

/// The [`MR`]×[`NR`] register tile: `acc += a_strip · b_strip` over one
/// `kc` chunk, k ascending (the fixed summation order).
#[inline(always)]
fn microkernel(kc: usize, a: &[f64], b: &[f64], acc: &mut [f64; MR * NR]) {
    debug_assert!(a.len() >= kc * MR && b.len() >= kc * NR);
    for p in 0..kc {
        let ap = &a[p * MR..p * MR + MR];
        let bp = &b[p * NR..p * NR + NR];
        for (i, &ai) in ap.iter().enumerate() {
            let row = &mut acc[i * NR..i * NR + NR];
            for (j, &bj) in bp.iter().enumerate() {
                row[j] += ai * bj;
            }
        }
    }
}

/// Packed, cache-blocked, register-tiled `C (m×n) ?= A (m×k) · B (k×n)`
/// with a fixed summation order (bit-reproducible run to run; see the
/// module docs).  All operands row-major; `a_trans` reinterprets `a` as
/// a row-major `k×m` buffer holding Aᵀ.  `scratch` must provide at
/// least [`GEMM_SCRATCH`] f64 (packing buffers — no allocation inside).
#[allow(clippy::too_many_arguments)] // the classic GEMM signature
pub fn gemm_into(
    m: usize,
    n: usize,
    k: usize,
    a: &[f64],
    a_trans: bool,
    b: &[f64],
    acc: Accum,
    c: &mut [f64],
    scratch: &mut [f64],
) {
    assert_eq!(a.len(), m * k, "gemm_into: A length != m*k");
    assert_eq!(b.len(), k * n, "gemm_into: B length != k*n");
    assert_eq!(c.len(), m * n, "gemm_into: C length != m*n");
    assert!(scratch.len() >= GEMM_SCRATCH, "gemm_into: scratch must hold GEMM_SCRATCH f64");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        if acc == Accum::Set {
            c.fill(0.0);
        }
        return;
    }
    let (apack, bpack) = scratch.split_at_mut(MC * KC);

    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            // How this kc chunk lands in C: the first chunk carries the
            // caller's Accum, later chunks accumulate on top of it.
            let chunk_acc = if pc == 0 {
                acc
            } else if acc == Accum::Sub {
                Accum::Sub
            } else {
                Accum::Add
            };
            pack_b(b, n, pc, jc, kc, nc, bpack);
            let mut ic = 0;
            while ic < m {
                let mc = MC.min(m - ic);
                pack_a(a, a_trans, m, k, ic, pc, mc, kc, apack);
                for jr in (0..nc).step_by(NR) {
                    let nr = NR.min(nc - jr);
                    let bstrip = &bpack[(jr / NR) * NR * kc..(jr / NR + 1) * NR * kc];
                    for ir in (0..mc).step_by(MR) {
                        let mr = MR.min(mc - ir);
                        let astrip = &apack[(ir / MR) * MR * kc..(ir / MR + 1) * MR * kc];
                        let mut tile = [0.0f64; MR * NR];
                        microkernel(kc, astrip, bstrip, &mut tile);
                        for i in 0..mr {
                            let crow = (ic + ir + i) * n + jc + jr;
                            for j in 0..nr {
                                let v = tile[i * NR + j];
                                match chunk_acc {
                                    Accum::Set => c[crow + j] = v,
                                    Accum::Add => c[crow + j] += v,
                                    Accum::Sub => c[crow + j] -= v,
                                }
                            }
                        }
                    }
                }
                ic += mc;
            }
            pc += kc;
        }
        jc += nc;
    }
}

/// Modelled flop count of one `m×n×k` GEMM (`2·m·n·k`).
pub fn gemm_flops(m: usize, n: usize, k: usize) -> u64 {
    2 * m as u64 * n as u64 * k as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(m: usize, n: usize, k: usize, a: &[f64], a_trans: bool, b: &[f64]) -> Vec<f64> {
        let mut c = vec![0.0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    let av = if a_trans { a[p * m + i] } else { a[i * k + p] };
                    acc += av * b[p * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn randvec(rng: &mut Rng, len: usize) -> Vec<f64> {
        (0..len).map(|_| rng.f64() - 0.5).collect()
    }

    #[test]
    fn matches_naive_exactly_when_k_fits_one_chunk() {
        // One KC chunk ⇒ identical left-to-right summation order as the
        // naive loop ⇒ bitwise equality, including ragged tile edges.
        let mut rng = Rng::new(7);
        for (m, n, k) in [(1, 1, 1), (5, 9, 3), (13, 17, 31), (MC + 3, NC + 5, KC), (4, 8, 64)] {
            let a = randvec(&mut rng, m * k);
            let b = randvec(&mut rng, k * n);
            let want = naive(m, n, k, &a, false, &b);
            let mut c = vec![f64::NAN; m * n];
            let mut scratch = vec![0.0f64; GEMM_SCRATCH];
            gemm_into(m, n, k, &a, false, &b, Accum::Set, &mut c, &mut scratch);
            let cb: Vec<u64> = c.iter().map(|x| x.to_bits()).collect();
            let wb: Vec<u64> = want.iter().map(|x| x.to_bits()).collect();
            assert_eq!(cb, wb, "bitwise mismatch at {m}x{n}x{k}");
        }
    }

    #[test]
    fn transposed_a_and_accumulate_modes() {
        let mut rng = Rng::new(11);
        let (m, n, k) = (10, 12, 20);
        let at = randvec(&mut rng, k * m); // row-major k×m = Aᵀ
        let b = randvec(&mut rng, k * n);
        let want = naive(m, n, k, &at, true, &b);
        let mut scratch = vec![0.0f64; GEMM_SCRATCH];
        let mut c = vec![0.0f64; m * n];
        gemm_into(m, n, k, &at, true, &b, Accum::Set, &mut c, &mut scratch);
        for (g, w) in c.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12, "trans: {g} vs {w}");
        }
        // Add then Sub round-trips back to the Set result.
        let set = c.clone();
        gemm_into(m, n, k, &at, true, &b, Accum::Add, &mut c, &mut scratch);
        gemm_into(m, n, k, &at, true, &b, Accum::Sub, &mut c, &mut scratch);
        let cb: Vec<u64> = c.iter().map(|x| x.to_bits()).collect();
        let sb: Vec<u64> = set.iter().map(|x| x.to_bits()).collect();
        assert_eq!(cb, sb, "Add then Sub of the same product must cancel bitwise");
    }

    #[test]
    fn multi_chunk_k_is_accurate_and_run_to_run_deterministic() {
        let mut rng = Rng::new(13);
        let (m, n, k) = (9, 11, 2 * KC + 37); // forces chunked accumulation
        let a = randvec(&mut rng, m * k);
        let b = randvec(&mut rng, k * n);
        let want = naive(m, n, k, &a, false, &b);
        let mut scratch = vec![0.0f64; GEMM_SCRATCH];
        let run = |scratch: &mut Vec<f64>| {
            let mut c = vec![0.0f64; m * n];
            gemm_into(m, n, k, &a, false, &b, Accum::Set, &mut c, scratch);
            c
        };
        let c1 = run(&mut scratch);
        let c2 = run(&mut scratch);
        assert_eq!(
            c1.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            c2.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "identical inputs must give identical bits"
        );
        for (g, w) in c1.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10 * k as f64, "{g} vs {w}");
        }
    }

    #[test]
    fn degenerate_dims() {
        let mut scratch = vec![0.0f64; GEMM_SCRATCH];
        let mut c = vec![5.0f64; 6];
        gemm_into(2, 3, 0, &[], false, &[], Accum::Set, &mut c, &mut scratch);
        assert!(c.iter().all(|&x| x == 0.0), "k=0 Set zeroes C");
        let mut c = vec![5.0f64; 6];
        gemm_into(2, 3, 0, &[], false, &[], Accum::Add, &mut c, &mut scratch);
        assert!(c.iter().all(|&x| x == 5.0), "k=0 Add leaves C");
        gemm_into(0, 0, 4, &[], false, &[], Accum::Set, &mut [], &mut scratch);
    }
}
