//! Compact-WY accumulation: apply a panel's Householder reflectors to
//! the trailing matrix as **two GEMMs** instead of `n` rank-1 sweeps.
//!
//! ## The representation
//!
//! A panel factorization `H_0 H_1 … H_{b-1}` (each `H_j = I − τ_j v_j
//! v_jᵀ`) has the compact-WY form `Q = I − V T Vᵀ` (LAPACK `larft`,
//! forward columnwise): `V` is the unit-lower-trapezoidal matrix of
//! reflector tails and `T` is `b×b` upper triangular.  The trailing
//! update then becomes
//!
//! ```text
//! QᵀC = C − V · (Tᵀ · (Vᵀ · C))
//! ```
//!
//! — two large level-3 products ([`crate::linalg::gemm`]) plus one tiny
//! `b×b` triangular one, instead of `b` memory-bound rank-1 passes over
//! `C`.  That is the classic CAQR answer to the paper's cost model: the
//! *replicated* trailing updates are the bulk of the redundant flops,
//! so turning them into GEMM is the single biggest end-to-end lever.
//!
//! ## Determinism, not bit-identity
//!
//! The WY update reassociates the arithmetic, so its results differ
//! from the rank-1 reference path by normal rounding (bounded by the
//! usual `c·n·ε‖A‖`).  What the fault-tolerance contract actually
//! needs is weaker and fully preserved: every kernel here is
//! **deterministic** (fixed summation order — and the pool-parallel
//! path partitions work so that every thread count reproduces the
//! sequential bits, see [`crate::linalg::gemm`]), so two replicas of
//! the same update task still produce identical bit patterns, and
//! recovery still hands back exactly the bits the dead owner would
//! have produced.  The `KernelProfile::Reference` path keeps the
//! bitwise-pinned kernels for the oracle tests.

use super::gemm::{self, Accum, GEMM_SCRATCH};
use super::view;
use crate::engine::WorkerPool;

/// A panel's compact-WY factor: `Q = I − V T Vᵀ`.
#[derive(Debug, Clone)]
pub struct WyFactor {
    /// Materialized unit-lower-trapezoidal `rows×cols` V (1 on the
    /// diagonal, reflector tails below, zeros above) — dense so the
    /// GEMMs stream it without special-casing the triangle.
    pub v: Vec<f64>,
    /// The `cols×cols` upper-triangular T (forward `larft`).
    pub t: Vec<f64>,
    /// Panel rows.
    pub rows: usize,
    /// Panel columns (reflector count).
    pub cols: usize,
}

/// Materialize the unit-lower-trapezoidal V from a packed (`geqrf`
/// layout) panel: `v[i][j] = packed[i][j]` below the diagonal, 1 on it,
/// 0 above.
pub fn materialize_v(packed: &[f64], rows: usize, cols: usize, v: &mut [f64]) {
    debug_assert_eq!(packed.len(), rows * cols);
    debug_assert_eq!(v.len(), rows * cols);
    for i in 0..rows {
        for j in 0..cols {
            v[i * cols + j] = match i.cmp(&j) {
                std::cmp::Ordering::Greater => packed[i * cols + j],
                std::cmp::Ordering::Equal => 1.0,
                std::cmp::Ordering::Less => 0.0,
            };
        }
    }
}

/// Build the upper-triangular T of the compact-WY form from a
/// materialized V and the reflector coefficients (LAPACK `larft`,
/// forward columnwise).  `t` is `cols×cols`, fully overwritten;
/// `w` is a `cols`-length scratch column (caller-provided so warm
/// callers allocate nothing).
///
/// A zero `τ_j` (identity reflector from a zero column) produces an
/// all-zero column `j` of T, which drops `H_j = I` from the product —
/// the same skip the rank-1 path takes.
pub fn build_t_f64(
    v: &[f64],
    rows: usize,
    cols: usize,
    tau: &[f64],
    t: &mut [f64],
    w: &mut [f64],
) {
    debug_assert_eq!(v.len(), rows * cols);
    debug_assert_eq!(tau.len(), cols);
    debug_assert_eq!(t.len(), cols * cols);
    debug_assert!(w.len() >= cols);
    t.fill(0.0);
    for j in 0..cols {
        let tj = tau[j];
        if tj == 0.0 {
            continue; // identity reflector: column j of T stays zero
        }
        // w = V[:, 0..j]ᵀ · v_j.  Both columns are zero above their own
        // diagonal, so the product is supported on rows j..rows.
        for (i, wi) in w.iter_mut().enumerate().take(j) {
            let mut acc = 0.0f64;
            for r in j..rows {
                acc += v[r * cols + i] * v[r * cols + j];
            }
            *wi = acc;
        }
        // T[0..j, j] = −τ_j · T[0..j, 0..j] · w
        for i in 0..j {
            let mut acc = 0.0f64;
            for (p, wp) in w.iter().enumerate().take(j).skip(i) {
                acc += t[i * cols + p] * wp;
            }
            t[i * cols + j] = -tj * acc;
        }
        t[j * cols + j] = tj;
    }
}

/// Build a [`WyFactor`] from a packed panel factorization (allocating
/// convenience for the f64 CAQR task path; the zero-allocation view
/// kernels in [`crate::linalg::view`] build into caller buffers).
pub fn build_wy(packed: &[f64], rows: usize, cols: usize, tau: &[f64]) -> WyFactor {
    let mut v = vec![0.0f64; rows * cols];
    materialize_v(packed, rows, cols, &mut v);
    let mut t = vec![0.0f64; cols * cols];
    let mut w = vec![0.0f64; cols];
    build_t_f64(&v, rows, cols, tau, &mut t, &mut w);
    WyFactor { v, t, rows, cols }
}

/// Blocked variant of [`view::factor_panel_f64`] that emits the
/// compact-WY T factor alongside the packed panel: `w` is factored in
/// place (bit-for-bit identical to the unblocked-profile factor — the
/// panelled core is bitwise independent of its block width) and the
/// returned [`WyFactor`] is what the trailing updates consume.
pub fn factor_panel_blocked_f64(
    w: &mut [f64],
    rows: usize,
    cols: usize,
    tau64: &mut [f64],
) -> WyFactor {
    view::factor_panel_f64(w, rows, cols, tau64);
    build_wy(w, rows, cols, tau64)
}

/// f64 scratch [`apply_wyt_with_scratch`] needs for a `cols`-reflector
/// panel applied to a `block_cols`-wide trailing block.
pub const fn apply_wyt_scratch(cols: usize, block_cols: usize) -> usize {
    2 * cols * block_cols + GEMM_SCRATCH
}

/// `block ← Qᵀ·block = block − V·(Tᵀ·(Vᵀ·block))` with caller-provided
/// scratch (at least [`apply_wyt_scratch`] f64) — the allocation-free
/// core shared by the f64 CAQR tasks and the runtime's `ApplyWy` view
/// kernel.
pub fn apply_wyt_with_scratch(
    v: &[f64],
    t: &[f64],
    rows: usize,
    cols: usize,
    block: &mut [f64],
    block_cols: usize,
    scratch: &mut [f64],
) {
    assert_eq!(v.len(), rows * cols, "apply_wyt: V length != rows*cols");
    assert_eq!(t.len(), cols * cols, "apply_wyt: T must be cols x cols");
    assert_eq!(block.len(), rows * block_cols, "apply_wyt: block length != rows*block_cols");
    assert!(
        scratch.len() >= apply_wyt_scratch(cols, block_cols),
        "apply_wyt: scratch too small"
    );
    let (wbuf, rest) = scratch.split_at_mut(cols * block_cols);
    let (w2, gs) = rest.split_at_mut(cols * block_cols);
    // W = Vᵀ · C
    gemm::gemm_into(cols, block_cols, rows, v, true, block, Accum::Set, wbuf, gs);
    // W₂ = Tᵀ · W  (T is upper triangular; the zeros cost one tiny GEMM)
    gemm::gemm_into(cols, block_cols, cols, t, true, wbuf, Accum::Set, w2, gs);
    // C −= V · W₂
    gemm::gemm_into(rows, block_cols, cols, v, false, w2, Accum::Sub, block, gs);
}

/// `block ← Q·block = block − V·(T·(Vᵀ·block))` — the **forward**
/// (Q-side) companion of [`apply_wyt_with_scratch`]: same three GEMMs,
/// `T` untransposed.  This is what Q *assembly* needs: seeding `block`
/// with identity columns and applying each panel's `Q_k` forward (in
/// reverse panel order) materializes the explicit Q, with every
/// arithmetic step deterministic so replicas stay bit-identical.
/// Scratch requirement is the same [`apply_wyt_scratch`].
pub fn apply_wy_forward_with_scratch(
    v: &[f64],
    t: &[f64],
    rows: usize,
    cols: usize,
    block: &mut [f64],
    block_cols: usize,
    scratch: &mut [f64],
) {
    assert_eq!(v.len(), rows * cols, "apply_wy: V length != rows*cols");
    assert_eq!(t.len(), cols * cols, "apply_wy: T must be cols x cols");
    assert_eq!(block.len(), rows * block_cols, "apply_wy: block length != rows*block_cols");
    assert!(
        scratch.len() >= apply_wyt_scratch(cols, block_cols),
        "apply_wy: scratch too small"
    );
    let (wbuf, rest) = scratch.split_at_mut(cols * block_cols);
    let (w2, gs) = rest.split_at_mut(cols * block_cols);
    // W = Vᵀ · C
    gemm::gemm_into(cols, block_cols, rows, v, true, block, Accum::Set, wbuf, gs);
    // W₂ = T · W  (forward: T, not Tᵀ)
    gemm::gemm_into(cols, block_cols, cols, t, false, wbuf, Accum::Set, w2, gs);
    // C −= V · W₂
    gemm::gemm_into(rows, block_cols, cols, v, false, w2, Accum::Sub, block, gs);
}

/// [`apply_wy_forward_with_scratch`] over a [`WyFactor`], growing a
/// reusable caller `Vec` for scratch — the Q-assembly task entry point.
pub fn apply_wy_forward_into(
    wy: &WyFactor,
    block: &mut [f64],
    block_cols: usize,
    scratch: &mut Vec<f64>,
) {
    let need = apply_wyt_scratch(wy.cols, block_cols);
    if scratch.len() < need {
        scratch.resize(need, 0.0);
    }
    apply_wy_forward_with_scratch(&wy.v, &wy.t, wy.rows, wy.cols, block, block_cols, scratch);
}

/// [`apply_wyt_with_scratch`] over a [`WyFactor`], growing a reusable
/// caller `Vec` for scratch — the CAQR update-task entry point (each
/// task reuses one scratch vector across its panel's GEMM calls).
pub fn apply_wyt_into(
    wy: &WyFactor,
    block: &mut [f64],
    block_cols: usize,
    scratch: &mut Vec<f64>,
) {
    let need = apply_wyt_scratch(wy.cols, block_cols);
    if scratch.len() < need {
        scratch.resize(need, 0.0);
    }
    apply_wyt_with_scratch(&wy.v, &wy.t, wy.rows, wy.cols, block, block_cols, scratch);
}

/// Pool-parallel [`apply_wyt_into`]: the two large GEMMs (`Vᵀ·C` and
/// `C −= V·W₂`) fan their column slabs out across up to `threads`
/// workers of `pool`; the tiny `cols×cols` triangular product stays
/// sequential.  **Bitwise identical to the sequential path for every
/// thread count** (each slab runs the sequential kernel on disjoint
/// columns — see [`gemm::gemm_into_pooled`]); `threads <= 1` *is* the
/// sequential path.
pub fn apply_wyt_pooled(
    wy: &WyFactor,
    block: &mut [f64],
    block_cols: usize,
    scratch: &mut Vec<f64>,
    pool: &WorkerPool,
    threads: usize,
) {
    if threads <= 1 {
        return apply_wyt_into(wy, block, block_cols, scratch);
    }
    let (rows, cols) = (wy.rows, wy.cols);
    assert_eq!(block.len(), rows * block_cols, "apply_wyt: block length != rows*block_cols");
    let need = apply_wyt_scratch(cols, block_cols);
    if scratch.len() < need {
        scratch.resize(need, 0.0);
    }
    let (wbuf, rest) = scratch.split_at_mut(cols * block_cols);
    let (w2, gs) = rest.split_at_mut(cols * block_cols);
    // W = Vᵀ · C
    gemm::gemm_into_pooled(
        pool, threads, cols, block_cols, rows, &wy.v, true, block, Accum::Set, wbuf, gs,
    );
    // W₂ = Tᵀ · W (tiny; never worth a pool hop)
    gemm::gemm_into(cols, block_cols, cols, &wy.t, true, wbuf, Accum::Set, w2, gs);
    // C −= V · W₂
    gemm::gemm_into_pooled(
        pool, threads, rows, block_cols, cols, &wy.v, false, w2, Accum::Sub, block, gs,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::linalg::view::{apply_update_f64, factor_panel_f64};

    fn factored_panel(rows: usize, cols: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let a = Matrix::random(rows, cols, seed);
        let mut w: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
        let mut tau = vec![0.0f64; cols];
        factor_panel_f64(&mut w, rows, cols, &mut tau);
        (w, tau)
    }

    #[test]
    fn blocked_factor_is_bitwise_the_reference_factor() {
        let (rows, cols) = (40, 12);
        let a = Matrix::random(rows, cols, 5);
        let mut wr: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
        let mut tr = vec![0.0f64; cols];
        factor_panel_f64(&mut wr, rows, cols, &mut tr);
        let mut wb: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
        let mut tb = vec![0.0f64; cols];
        let wy = factor_panel_blocked_f64(&mut wb, rows, cols, &mut tb);
        assert_eq!(
            wr.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            wb.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "blocked factor must leave the identical packed panel"
        );
        assert_eq!(
            tr.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            tb.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!((wy.rows, wy.cols), (rows, cols));
    }

    #[test]
    fn wy_update_matches_rank1_reference_numerically() {
        for (rows, cols, bk) in [(24, 6, 5), (48, 16, 16), (33, 7, 2), (16, 1, 4)] {
            let (packed, tau) = factored_panel(rows, cols, (rows * 7 + bk) as u64);
            let wy = build_wy(&packed, rows, cols, &tau);
            let block = Matrix::random(rows, bk, 99);
            let b0: Vec<f64> = block.data().iter().map(|&x| x as f64).collect();

            let mut want = b0.clone();
            apply_update_f64(&packed, rows, cols, &tau, &mut want, bk);

            let mut got = b0.clone();
            let mut scratch = Vec::new();
            apply_wyt_into(&wy, &mut got, bk, &mut scratch);

            let scale: f64 =
                b0.iter().fold(1.0f64, |m, x| m.max(x.abs())) * cols as f64;
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g - w).abs() <= 1e-12 * scale.max(1.0),
                    "{rows}x{cols} on {bk}-wide block: {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn wy_update_is_run_to_run_deterministic() {
        let (rows, cols, bk) = (64, 16, 24);
        let (packed, tau) = factored_panel(rows, cols, 3);
        let block = Matrix::random(rows, bk, 4);
        let run = || {
            let wy = build_wy(&packed, rows, cols, &tau);
            let mut b: Vec<f64> = block.data().iter().map(|&x| x as f64).collect();
            let mut scratch = Vec::new();
            apply_wyt_into(&wy, &mut b, bk, &mut scratch);
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        };
        assert_eq!(run(), run(), "identical inputs must produce identical bits");
    }

    #[test]
    fn zero_column_panel_yields_identity_contribution() {
        // A zero column gives τ = 0; the WY product must skip it just
        // like the rank-1 path does (zero T column).
        let (rows, cols) = (12, 3);
        let z = Matrix::zeros(rows, cols);
        let mut wz: Vec<f64> = z.data().iter().map(|&x| x as f64).collect();
        let mut tz = vec![0.0f64; cols];
        let wy = factor_panel_blocked_f64(&mut wz, rows, cols, &mut tz);
        assert!(tz.iter().all(|&t| t == 0.0));
        let block = Matrix::random(rows, 4, 9);
        let mut b: Vec<f64> = block.data().iter().map(|&x| x as f64).collect();
        let before = b.clone();
        let mut scratch = Vec::new();
        apply_wyt_into(&wy, &mut b, 4, &mut scratch);
        assert_eq!(
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            before.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "all-identity panel must leave the block untouched"
        );
    }

    #[test]
    fn pooled_update_matches_sequential_bitwise_for_every_thread_count() {
        // Large enough that the slab scheduler actually dispatches
        // (gemm::PAR_MIN_FLOPS): the pooled trailing update must be
        // indistinguishable — bit for bit — from the sequential one.
        let (rows, cols, bk) = (256, 16, 256);
        let (packed, tau) = factored_panel(rows, cols, 21);
        let wy = build_wy(&packed, rows, cols, &tau);
        let block = Matrix::random(rows, bk, 77);
        let b0: Vec<f64> = block.data().iter().map(|&x| x as f64).collect();

        let mut want = b0.clone();
        let mut scratch = Vec::new();
        apply_wyt_into(&wy, &mut want, bk, &mut scratch);
        let want_bits: Vec<u64> = want.iter().map(|x| x.to_bits()).collect();

        let pool = crate::engine::WorkerPool::new();
        for threads in [1, 2, 4, 7] {
            let mut got = b0.clone();
            let mut scratch = Vec::new();
            apply_wyt_pooled(&wy, &mut got, bk, &mut scratch, &pool, threads);
            assert_eq!(
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                want_bits,
                "threads={threads} diverged from the sequential update"
            );
        }
        assert!(pool.tasks_executed() > 0, "threads>1 must really fan out");
        pool.shutdown();
    }

    /// The forward (Q-side) apply must invert the transpose apply:
    /// `Q·(Qᵀ·C) = C` up to rounding — and must genuinely differ from
    /// applying `Qᵀ` twice (i.e. the `T` vs `Tᵀ` distinction matters).
    #[test]
    fn forward_apply_inverts_transpose_apply() {
        for (rows, cols, bk) in [(24, 6, 5), (48, 16, 16), (33, 7, 2)] {
            let (packed, tau) = factored_panel(rows, cols, (rows + cols) as u64);
            let wy = build_wy(&packed, rows, cols, &tau);
            let block = Matrix::random(rows, bk, 13);
            let b0: Vec<f64> = block.data().iter().map(|&x| x as f64).collect();

            let mut b = b0.clone();
            let mut scratch = Vec::new();
            apply_wyt_into(&wy, &mut b, bk, &mut scratch); // Qᵀ·C
            let qt_c = b.clone();
            apply_wy_forward_into(&wy, &mut b, bk, &mut scratch); // Q·(Qᵀ·C)

            let scale: f64 = b0.iter().fold(1.0f64, |m, x| m.max(x.abs())) * cols as f64;
            for (g, w) in b.iter().zip(&b0) {
                assert!(
                    (g - w).abs() <= 1e-12 * scale.max(1.0),
                    "{rows}x{cols}: roundtrip {g} vs {w}"
                );
            }
            // Qᵀ·(Qᵀ·C) ≠ C for a generic panel: if the forward path
            // accidentally transposed T it would fail the roundtrip.
            let mut wrong = qt_c.clone();
            apply_wyt_into(&wy, &mut wrong, bk, &mut scratch);
            let drift: f64 =
                wrong.iter().zip(&b0).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
            assert!(drift > 1e-9 * scale.max(1.0), "QᵀQᵀ must not look like QQᵀ");
        }
    }

    #[test]
    fn forward_apply_is_run_to_run_deterministic() {
        let (rows, cols, bk) = (40, 8, 12);
        let (packed, tau) = factored_panel(rows, cols, 17);
        let block = Matrix::random(rows, bk, 18);
        let run = || {
            let wy = build_wy(&packed, rows, cols, &tau);
            let mut b: Vec<f64> = block.data().iter().map(|&x| x as f64).collect();
            let mut scratch = Vec::new();
            apply_wy_forward_into(&wy, &mut b, bk, &mut scratch);
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn t_is_upper_triangular() {
        let (rows, cols) = (20, 6);
        let (packed, tau) = factored_panel(rows, cols, 2);
        let wy = build_wy(&packed, rows, cols, &tau);
        for i in 0..cols {
            for j in 0..i {
                assert_eq!(wy.t[i * cols + j], 0.0, "T[{i}][{j}] below diagonal");
            }
            assert_eq!(wy.t[i * cols + i], tau[i], "diagonal is tau");
        }
    }
}
