//! Dense row-major matrix — the payload type of the whole simulation.
//!
//! Element type is `f32` to match the AOT artifacts (the manifest is
//! emitted with `dtype: f32`); the verification oracles accumulate in
//! `f64` where it matters.

use std::fmt;

use super::view::{MatrixView, MatrixViewMut};

/// Dense row-major `f32` matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity-like rectangular matrix (ones on the main diagonal).
    pub fn eye(rows: usize, cols: usize) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows.min(cols) {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major buffer. Panics if the length mismatches.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length != rows*cols");
        Self { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// The row-major element buffer.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable access to the row-major element buffer.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume the matrix, keeping its buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Borrowed view — the zero-copy input convention of the view
    /// kernels in [`super::view`].
    pub fn as_view(&self) -> MatrixView<'_> {
        MatrixView::new(self.rows, self.cols, &self.data)
    }

    /// Mutable borrowed view — the in-place output convention of the
    /// view kernels.
    pub fn as_view_mut(&mut self) -> MatrixViewMut<'_> {
        MatrixViewMut::new(self.rows, self.cols, &mut self.data)
    }

    /// Bytes of payload — what a sendrecv of this matrix "costs".
    pub fn size_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// Row slice view.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of column j.
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Sub-block of consecutive rows [r0, r1) — allocating shim over
    /// the zero-copy [`MatrixView::rows_range`].
    pub fn row_block(&self, r0: usize, r1: usize) -> Matrix {
        self.as_view().rows_range(r0, r1).to_matrix()
    }

    /// Vertical concatenation [self; other].
    pub fn vstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "vstack: column mismatch");
        let mut data = Vec::with_capacity((self.rows + other.rows) * self.cols);
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Matrix::from_vec(self.rows + other.rows, self.cols, data)
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Matrix product (f64 accumulation — this is a verification path,
    /// not the hot path; the hot path runs matmuls through PJRT).
    /// Allocating shim over [`super::view::matmul_into`].
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul: inner dim mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        super::view::matmul_into(self.as_view(), other.as_view(), &mut out.as_view_mut());
        out
    }

    /// Frobenius norm (f64 accumulation).
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!(self.shape(), other.shape(), "max_abs_diff: shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| ((a as f64) - (b as f64)).abs())
            .fold(0.0, f64::max)
    }

    /// ‖A − B‖_F / ‖B‖_F (relative error against reference B).
    pub fn rel_fro_err(&self, reference: &Matrix) -> f64 {
        let den = reference.fro_norm();
        let mut num = 0.0f64;
        for (a, b) in self.data.iter().zip(&reference.data) {
            let d = (*a as f64) - (*b as f64);
            num += d * d;
        }
        if den == 0.0 {
            num.sqrt()
        } else {
            num.sqrt() / den
        }
    }

    /// True if strictly-lower-triangular part is (near) zero.
    pub fn is_upper_triangular(&self, atol: f64) -> bool {
        for i in 0..self.rows {
            for j in 0..self.cols.min(i) {
                if (self[(i, j)] as f64).abs() > atol {
                    return false;
                }
            }
        }
        true
    }

    /// Keep the upper triangle, zero below the diagonal — allocating
    /// shim over [`super::view::triu_into`].
    pub fn triu(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        super::view::triu_into(self.as_view(), &mut out.as_view_mut());
        out
    }

    /// Canonical R: flip row signs so every diagonal entry is >= 0.
    /// (R of a QR factorization is unique only up to row signs; every
    /// cross-algorithm comparison in the test/bench suites uses this.)
    pub fn canonicalize_r(&self) -> Matrix {
        let mut out = self.clone();
        for i in 0..self.rows.min(self.cols) {
            if out[(i, i)] < 0.0 {
                for j in 0..self.cols {
                    out[(i, j)] = -out[(i, j)];
                }
            }
        }
        out
    }

    /// Deterministic pseudo-random matrix (xorshift-based; seeds the
    /// workload generators without pulling `rand` into the core type).
    pub fn random(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        Matrix::from_fn(rows, cols, |_, _| {
            // xorshift64*
            state ^= state >> 12;
            state ^= state << 25;
            state ^= state >> 27;
            let bits = state.wrapping_mul(0x2545F4914F6CDD1D);
            // Map to (-1, 1): take the top 24 bits as a fraction.
            let frac = ((bits >> 40) as f64) / ((1u64 << 24) as f64);
            (2.0 * frac - 1.0) as f32
        })
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        debug_assert!(
            i < self.rows && j < self.cols,
            "Matrix index ({i}, {j}) out of bounds for shape {}x{}",
            self.rows,
            self.cols
        );
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        debug_assert!(
            i < self.rows && j < self.cols,
            "Matrix index ({i}, {j}) out of bounds for shape {}x{}",
            self.rows,
            self.cols
        );
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show = self.rows.min(8);
        for i in 0..show {
            write!(f, "  [")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:>10.4}", self[(i, j)])?;
            }
            writeln!(f, "{}]", if self.cols > 8 { " ..." } else { "" })?;
        }
        if self.rows > show {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_eye_shapes() {
        let z = Matrix::zeros(3, 2);
        assert_eq!(z.shape(), (3, 2));
        assert!(z.data().iter().all(|&x| x == 0.0));
        let e = Matrix::eye(3, 3);
        assert_eq!(e[(0, 0)], 1.0);
        assert_eq!(e[(0, 1)], 0.0);
        assert_eq!(e[(2, 2)], 1.0);
    }

    #[test]
    fn from_fn_indexing_row_major() {
        let m = Matrix::from_fn(2, 3, |i, j| (i * 10 + j) as f32);
        assert_eq!(m.data(), &[0.0, 1.0, 2.0, 10.0, 11.0, 12.0]);
        assert_eq!(m[(1, 2)], 12.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0]);
        assert_eq!(m.col(2), vec![2.0, 12.0]);
    }

    #[test]
    #[should_panic]
    fn from_vec_length_checked() {
        Matrix::from_vec(2, 2, vec![1.0; 3]);
    }

    #[test]
    fn vstack_and_row_block_roundtrip() {
        let a = Matrix::random(4, 3, 1);
        let b = Matrix::random(2, 3, 2);
        let s = a.vstack(&b);
        assert_eq!(s.shape(), (6, 3));
        assert_eq!(s.row_block(0, 4), a);
        assert_eq!(s.row_block(4, 6), b);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::random(5, 4, 3);
        let i4 = Matrix::eye(4, 4);
        assert!(a.matmul(&i4).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::random(3, 5, 9);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn fro_norm_matches_manual() {
        let a = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn triu_and_is_upper_triangular() {
        let a = Matrix::random(4, 4, 5);
        assert!(!a.is_upper_triangular(1e-9));
        assert!(a.triu().is_upper_triangular(0.0));
    }

    #[test]
    fn canonicalize_makes_diag_nonneg() {
        let mut r = Matrix::eye(3, 3);
        r[(1, 1)] = -2.0;
        r[(1, 2)] = 4.0;
        let c = r.canonicalize_r();
        assert_eq!(c[(1, 1)], 2.0);
        assert_eq!(c[(1, 2)], -4.0);
        assert_eq!(c[(0, 0)], 1.0);
    }

    #[test]
    fn random_is_deterministic_and_bounded() {
        let a = Matrix::random(10, 10, 42);
        let b = Matrix::random(10, 10, 42);
        assert_eq!(a, b);
        assert!(a.data().iter().all(|&x| x > -1.0 && x < 1.0));
        assert!(a.data().iter().any(|&x| x != 0.0));
        assert_ne!(a, Matrix::random(10, 10, 43));
    }

    #[test]
    fn rel_fro_err_zero_for_equal() {
        let a = Matrix::random(6, 3, 7);
        assert_eq!(a.rel_fro_err(&a), 0.0);
    }
}
