//! Zero-copy kernel subsystem: borrowed matrix views, the reusable
//! [`Workspace`] scratch arena, and blocked in-place Householder
//! kernels.
//!
//! ## Why this module exists
//!
//! The TSQR hot path is thousands of small leaf/combine QR kernels per
//! campaign.  The original kernels allocated on every call (a fresh
//! `f64` working buffer, a fresh packed output, plus `vstack` copies
//! for every combine) — at Monte-Carlo campaign scale the allocator
//! dominated the wall clock.  The kernels here separate three concerns:
//!
//! * **inputs** are [`MatrixView`]s — borrowed slices, never copied;
//! * **scratch** comes from a caller-supplied [`Workspace`] — grown on
//!   first use, reused forever after (zero steady-state allocations);
//! * **outputs** are written into caller-provided buffers
//!   ([`MatrixViewMut`] / `&mut [f32]`) — the caller decides whether
//!   that buffer is fresh or recycled.
//!
//! ## Ownership rules (the call convention)
//!
//! 1. The *caller* owns every buffer: views borrow, kernels never free
//!    or resize anything except the workspace's internal arena.
//! 2. A [`Workspace`] may be used by one kernel call at a time (take
//!    `&mut`); pools of workspaces (see `runtime::WorkspacePool`)
//!    provide concurrency.
//! 3. Kernels fully overwrite the scratch they use — no state leaks
//!    between calls, so workspaces can be shared across unrelated runs.
//!
//! ## Blocked, yet bit-for-bit reproducible
//!
//! [`householder_qr_into`] is a column-panel blocked factorization
//! (panel width [`PANEL`]): reflectors are formed panel by panel and
//! the trailing matrix is updated a column-panel at a time, which keeps
//! the working set in cache for tall panels.  Crucially the result is
//! **bit-for-bit identical** to the classic unblocked loop
//! (`qr::householder_qr_reference`): blocking only reorders *which
//! column* receives its rank-1 update when — never the order of
//! updates applied to any single column, nor the accumulation order
//! inside a dot product — and every update reads only reflector
//! columns that are already final.  The redundancy invariant of the
//! whole paper (replicas are bit-identical) therefore survives the
//! optimization, and the property tests in `tests/prop_invariants.rs`
//! pin it down.

use super::matrix::Matrix;

/// Column-panel width of the blocked factorization.  32 keeps a
/// 32-column f64 panel of a 1024-row leaf (~256 KiB) inside L2.
pub const PANEL: usize = 32;

// ---------------------------------------------------------------------
// Views
// ---------------------------------------------------------------------

/// Borrowed, immutable row-major view of an `rows x cols` f32 block.
#[derive(Clone, Copy)]
pub struct MatrixView<'a> {
    rows: usize,
    cols: usize,
    data: &'a [f32],
}

impl<'a> MatrixView<'a> {
    /// View over a row-major buffer.  Panics if the length mismatches.
    pub fn new(rows: usize, cols: usize, data: &'a [f32]) -> Self {
        assert_eq!(data.len(), rows * cols, "MatrixView: buffer length != rows*cols");
        Self { rows, cols, data }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// The underlying row-major slice.
    pub fn data(&self) -> &'a [f32] {
        self.data
    }

    /// Element access (debug-checked with shape context).
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(
            i < self.rows && j < self.cols,
            "MatrixView index ({i}, {j}) out of bounds for shape {}x{}",
            self.rows,
            self.cols
        );
        self.data[i * self.cols + j]
    }

    /// Sub-view of consecutive rows `[r0, r1)` — zero-copy (row-major
    /// rows are contiguous).
    pub fn rows_range(&self, r0: usize, r1: usize) -> MatrixView<'a> {
        assert!(
            r0 <= r1 && r1 <= self.rows,
            "rows_range [{r0}, {r1}) out of bounds for {} rows",
            self.rows
        );
        MatrixView { rows: r1 - r0, cols: self.cols, data: &self.data[r0 * self.cols..r1 * self.cols] }
    }

    /// Materialize an owned copy (the explicit, visible allocation).
    pub fn to_matrix(&self) -> Matrix {
        Matrix::from_vec(self.rows, self.cols, self.data.to_vec())
    }
}

impl std::ops::Index<(usize, usize)> for MatrixView<'_> {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        debug_assert!(
            i < self.rows && j < self.cols,
            "MatrixView index ({i}, {j}) out of bounds for shape {}x{}",
            self.rows,
            self.cols
        );
        &self.data[i * self.cols + j]
    }
}

impl std::fmt::Debug for MatrixView<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MatrixView {}x{}", self.rows, self.cols)
    }
}

/// Borrowed, mutable row-major view — the output half of the kernel
/// call convention.
pub struct MatrixViewMut<'a> {
    rows: usize,
    cols: usize,
    data: &'a mut [f32],
}

impl<'a> MatrixViewMut<'a> {
    /// Mutable view over a row-major buffer.  Panics on length mismatch.
    pub fn new(rows: usize, cols: usize, data: &'a mut [f32]) -> Self {
        assert_eq!(data.len(), rows * cols, "MatrixViewMut: buffer length != rows*cols");
        Self { rows, cols, data }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Immutable re-borrow.
    pub fn as_view(&self) -> MatrixView<'_> {
        MatrixView { rows: self.rows, cols: self.cols, data: self.data }
    }

    /// Element write (debug-checked with shape context).
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(
            i < self.rows && j < self.cols,
            "MatrixViewMut index ({i}, {j}) out of bounds for shape {}x{}",
            self.rows,
            self.cols
        );
        self.data[i * self.cols + j] = v;
    }

    /// Set every element to `v`.
    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// Overwrite from an equally-shaped source view.
    pub fn copy_from(&mut self, src: MatrixView<'_>) {
        assert_eq!(self.shape(), src.shape(), "copy_from: shape mismatch");
        self.data.copy_from_slice(src.data());
    }
}

impl std::ops::Index<(usize, usize)> for MatrixViewMut<'_> {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        debug_assert!(
            i < self.rows && j < self.cols,
            "MatrixViewMut index ({i}, {j}) out of bounds for shape {}x{}",
            self.rows,
            self.cols
        );
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for MatrixViewMut<'_> {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        debug_assert!(
            i < self.rows && j < self.cols,
            "MatrixViewMut index ({i}, {j}) out of bounds for shape {}x{}",
            self.rows,
            self.cols
        );
        &mut self.data[i * self.cols + j]
    }
}

impl std::fmt::Debug for MatrixViewMut<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MatrixViewMut {}x{}", self.rows, self.cols)
    }
}

// ---------------------------------------------------------------------
// Workspace
// ---------------------------------------------------------------------

/// Reusable scratch arena for the view kernels.
///
/// Buffers grow to the high-water mark of the shapes they have seen and
/// are then reused without further allocation — the steady state every
/// campaign run settles into after its first round.  `grows()` exposes
/// the number of reallocation events, which the allocation-counting
/// tests use to assert steady state.
#[derive(Default)]
pub struct Workspace {
    f64_buf: Vec<f64>,
    f32_buf: Vec<f32>,
    grows: u64,
}

impl Workspace {
    /// An empty workspace (grows on first use).
    pub fn new() -> Self {
        Self::default()
    }

    /// A workspace pre-sized for factoring an `rows x cols` panel (and
    /// anything smaller), so the first kernel call allocates nothing.
    pub fn sized_for(rows: usize, cols: usize) -> Self {
        let mut ws = Self::new();
        ws.reserve(rows, cols);
        ws
    }

    /// Ensure capacity for an `rows x cols` factorization without
    /// counting it as a steady-state grow (setup-time warming).
    pub fn reserve(&mut self, rows: usize, cols: usize) {
        let need64 = rows * cols + cols; // working copy + f64 tau
        if self.f64_buf.len() < need64 {
            self.f64_buf.resize(need64, 0.0);
        }
        let need32 = rows * cols;
        if self.f32_buf.len() < need32 {
            self.f32_buf.resize(need32, 0.0);
        }
    }

    /// Times a scratch request outgrew the arena (0 after warm-up ⇒
    /// the workspace is allocation-free in steady state).
    pub fn grows(&self) -> u64 {
        self.grows
    }

    /// f64 scratch of exactly `len` elements (grown if needed, counted).
    pub fn f64_scratch(&mut self, len: usize) -> &mut [f64] {
        if self.f64_buf.len() < len {
            self.grows += 1;
            self.f64_buf.resize(len, 0.0);
        }
        &mut self.f64_buf[..len]
    }

    /// f32 scratch of exactly `len` elements (grown if needed, counted).
    pub fn f32_scratch(&mut self, len: usize) -> &mut [f32] {
        if self.f32_buf.len() < len {
            self.grows += 1;
            self.f32_buf.resize(len, 0.0);
        }
        &mut self.f32_buf[..len]
    }
}

/// Round every element through f32 and back (`x as f32 as f64`): the
/// single primitive behind the mixed-precision CAQR path.  An f32 run
/// is the f64 schedule with this rounding applied at every task
/// boundary, so f64 checksums keep protecting f32 data (the checksum
/// arithmetic itself is never rounded — see `abft::kernels`).
/// Idempotent, and the identity on data already f32-representable —
/// which is why `Precision::F64` runs are byte-identical with the
/// precision plumbing in place.
pub fn round_f32_in_place(buf: &mut [f64]) {
    for x in buf.iter_mut() {
        *x = *x as f32 as f64;
    }
}

// ---------------------------------------------------------------------
// Blocked factorization core
// ---------------------------------------------------------------------

/// Blocked Householder factorization of the row-major f64 working
/// buffer `w` (`m x n`, `m >= n`), LAPACK `geqrf` packed layout.
/// `tau64` receives the n reflector coefficients in full precision
/// (the trailing updates must use the f64 value — rounding it through
/// f32 would break bitwise equality with the unblocked reference).
fn factor_packed_f64(w: &mut [f64], m: usize, n: usize, tau64: &mut [f64]) {
    factor_packed_f64_panelled(w, m, n, tau64, PANEL);
}

/// [`factor_packed_f64`] with an explicit column-panel width — the
/// f64 core shared by the blocked kernels (`panel = PANEL`) and the
/// CAQR oracle (`panel` = the caller's block-column width).  The
/// result is bit-for-bit independent of `panel`: blocking only decides
/// *when* a trailing column receives a reflector's rank-1 update,
/// never the order of updates applied to any single column nor the
/// accumulation order inside a dot product (see the module docs).
pub(crate) fn factor_packed_f64_panelled(
    w: &mut [f64],
    m: usize,
    n: usize,
    tau64: &mut [f64],
    panel: usize,
) {
    debug_assert!(m >= n, "factor_packed_f64: panel must be tall-skinny, got {m}x{n}");
    debug_assert_eq!(w.len(), m * n);
    debug_assert_eq!(tau64.len(), n);
    debug_assert!(panel >= 1, "panel width must be >= 1");
    let idx = |i: usize, j: usize| i * n + j;

    let mut k0 = 0;
    while k0 < n {
        let k1 = (k0 + panel).min(n);
        // Panel factorization: classic unblocked loop restricted to
        // columns k0..k1 (updates touch panel columns only).
        for j in k0..k1 {
            let mut norm2 = 0.0f64;
            for i in j..m {
                norm2 += w[idx(i, j)] * w[idx(i, j)];
            }
            let normx = norm2.sqrt();
            let x0 = w[idx(j, j)];
            if normx == 0.0 {
                tau64[j] = 0.0; // zero column: identity reflector
                continue;
            }
            let beta = if x0 >= 0.0 { -normx } else { normx };
            let denom = x0 - beta;
            let tj = (beta - x0) / beta;
            tau64[j] = tj;
            // v tail = x[j+1..] / denom (v[j] = 1 implicit).
            for i in j + 1..m {
                w[idx(i, j)] /= denom;
            }
            // Apply H_j to the remaining panel columns.
            for c in j + 1..k1 {
                let mut dot = w[idx(j, c)];
                for i in j + 1..m {
                    dot += w[idx(i, j)] * w[idx(i, c)];
                }
                let s = tj * dot;
                w[idx(j, c)] -= s;
                for i in j + 1..m {
                    w[idx(i, c)] -= w[idx(i, j)] * s;
                }
            }
            w[idx(j, j)] = beta;
        }
        // Trailing update: apply the panel's reflectors to each column
        // beyond the panel, column by column so the column stays hot.
        // Per trailing column this is the same H_k0..H_{k1-1} sequence
        // (same operands, same accumulation order) the unblocked loop
        // performs — hence bit-for-bit identical results.
        for c in k1..n {
            for j in k0..k1 {
                if tau64[j] == 0.0 {
                    continue; // identity reflector (zero column)
                }
                let mut dot = w[idx(j, c)];
                for i in j + 1..m {
                    dot += w[idx(i, j)] * w[idx(i, c)];
                }
                let s = tau64[j] * dot;
                w[idx(j, c)] -= s;
                for i in j + 1..m {
                    w[idx(i, c)] -= w[idx(i, j)] * s;
                }
            }
        }
        k0 = k1;
    }
}

/// Load an f32 view into an f64 row-major buffer.
fn load_f64(dst: &mut [f64], src: MatrixView<'_>) {
    debug_assert_eq!(dst.len(), src.rows() * src.cols());
    for (d, &s) in dst.iter_mut().zip(src.data()) {
        *d = s as f64;
    }
}

/// Cast an f64 buffer back to f32 (single rounding, as the unblocked
/// reference does).
fn store_f32(dst: &mut [f32], src: &[f64]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = s as f32;
    }
}

// ---------------------------------------------------------------------
// In-place kernels (the view call convention)
// ---------------------------------------------------------------------

/// Blocked Householder QR of a tall-skinny panel into caller buffers:
/// `packed` (m×n, LAPACK `geqrf` layout) and `tau` (n).  Scratch comes
/// from `ws`; nothing else is allocated.
pub fn householder_qr_into(
    a: MatrixView<'_>,
    packed: &mut MatrixViewMut<'_>,
    tau: &mut [f32],
    ws: &mut Workspace,
) {
    let (m, n) = a.shape();
    assert!(m >= n, "householder_qr_into: panel must be tall-skinny, got {m}x{n}");
    assert_eq!(packed.shape(), (m, n), "householder_qr_into: packed must be {m}x{n}");
    assert_eq!(tau.len(), n, "householder_qr_into: tau must have {n} entries");
    let buf = ws.f64_scratch(m * n + n);
    let (w, t) = buf.split_at_mut(m * n);
    load_f64(w, a);
    factor_packed_f64(w, m, n, t);
    store_f32(packed.data, w);
    store_f32(tau, t);
}

/// Just the R factor of a tall-skinny panel, written into the caller's
/// n×n buffer (upper triangle of the factorization; zeros below the
/// diagonal) — the TSQR leaf hot path.
pub fn leaf_r_into(a: MatrixView<'_>, out: &mut MatrixViewMut<'_>, ws: &mut Workspace) {
    let (m, n) = a.shape();
    assert!(m >= n, "leaf_r_into: panel must be tall-skinny, got {m}x{n}");
    assert_eq!(out.shape(), (n, n), "leaf_r_into: out must be {n}x{n}");
    let buf = ws.f64_scratch(m * n + n);
    let (w, t) = buf.split_at_mut(m * n);
    load_f64(w, a);
    factor_packed_f64(w, m, n, t);
    write_triu_top(w, n, out);
}

/// TSQR combine hot path: R of the stacked `[r_top; r_bot]` written
/// into the caller's n×n buffer.  The stack is formed directly in the
/// f64 scratch — no `vstack` copy, no intermediate matrix.
pub fn combine_r_into(
    r_top: MatrixView<'_>,
    r_bot: MatrixView<'_>,
    out: &mut MatrixViewMut<'_>,
    ws: &mut Workspace,
) {
    let n = r_top.cols();
    assert_eq!(r_bot.cols(), n, "combine_r_into: column mismatch");
    let m = r_top.rows() + r_bot.rows();
    assert!(m >= n, "combine_r_into: stack must be tall-skinny, got {m}x{n}");
    assert_eq!(out.shape(), (n, n), "combine_r_into: out must be {n}x{n}");
    let buf = ws.f64_scratch(m * n + n);
    let (w, t) = buf.split_at_mut(m * n);
    let split = r_top.rows() * n;
    load_f64(&mut w[..split], r_top);
    load_f64(&mut w[split..], r_bot);
    factor_packed_f64(w, m, n, t);
    write_triu_top(w, n, out);
}

/// Full combine factorization (packed + tau) of the stacked
/// `[r_top; r_bot]` — the retained-Q path.
pub fn combine_qr_into(
    r_top: MatrixView<'_>,
    r_bot: MatrixView<'_>,
    packed: &mut MatrixViewMut<'_>,
    tau: &mut [f32],
    ws: &mut Workspace,
) {
    let n = r_top.cols();
    assert_eq!(r_bot.cols(), n, "combine_qr_into: column mismatch");
    let m = r_top.rows() + r_bot.rows();
    assert!(m >= n, "combine_qr_into: stack must be tall-skinny, got {m}x{n}");
    assert_eq!(packed.shape(), (m, n), "combine_qr_into: packed must be {m}x{n}");
    assert_eq!(tau.len(), n, "combine_qr_into: tau must have {n} entries");
    let buf = ws.f64_scratch(m * n + n);
    let (w, t) = buf.split_at_mut(m * n);
    let split = r_top.rows() * n;
    load_f64(&mut w[..split], r_top);
    load_f64(&mut w[split..], r_bot);
    factor_packed_f64(w, m, n, t);
    store_f32(packed.data, w);
    store_f32(tau, t);
}

/// Write the upper triangle of the top n rows of a packed m×n f64
/// buffer into an n×n f32 view (zeros below the diagonal).
fn write_triu_top(w: &[f64], n: usize, out: &mut MatrixViewMut<'_>) {
    for i in 0..n {
        for j in 0..n {
            out.data[i * n + j] = if j >= i { w[i * n + j] as f32 } else { 0.0 };
        }
    }
}

/// Upper-triangular copy: `out = triu(a)`.
pub fn triu_into(a: MatrixView<'_>, out: &mut MatrixViewMut<'_>) {
    assert_eq!(a.shape(), out.shape(), "triu_into: shape mismatch");
    let (rows, cols) = a.shape();
    for i in 0..rows {
        for j in 0..cols {
            out.data[i * cols + j] = if j >= i { a.data()[i * cols + j] } else { 0.0 };
        }
    }
}

/// Back-substitution `R x = b` into the caller's n×k buffer (R upper
/// triangular n×n).  f64 accumulation; allocation-free.
pub fn backsolve_into(r: MatrixView<'_>, b: MatrixView<'_>, out: &mut MatrixViewMut<'_>) {
    let n = r.rows();
    assert_eq!(r.cols(), n, "backsolve_into: R must be square");
    assert_eq!(b.rows(), n, "backsolve_into: rhs rows must match R");
    let k = b.cols();
    assert_eq!(out.shape(), (n, k), "backsolve_into: out must be {n}x{k}");
    for c in 0..k {
        for i in (0..n).rev() {
            let mut acc = b.at(i, c) as f64;
            for j in i + 1..n {
                acc -= r.at(i, j) as f64 * out.at(j, c) as f64;
            }
            out.set(i, c, (acc / r.at(i, i) as f64) as f32);
        }
    }
}

/// Matrix product `out = a @ b` with f64 accumulation — identical
/// numeric semantics to `Matrix::matmul` (which is now a shim over
/// this kernel).
pub fn matmul_into(a: MatrixView<'_>, b: MatrixView<'_>, out: &mut MatrixViewMut<'_>) {
    assert_eq!(a.cols(), b.rows(), "matmul_into: inner dim mismatch");
    assert_eq!(out.shape(), (a.rows(), b.cols()), "matmul_into: out shape mismatch");
    out.fill(0.0);
    let kn = b.cols();
    for i in 0..a.rows() {
        for k in 0..a.cols() {
            let aik = a.at(i, k) as f64;
            if aik == 0.0 {
                continue;
            }
            for j in 0..kn {
                let v = out.at(i, j) as f64 + aik * b.at(k, j) as f64;
                out.set(i, j, v as f32);
            }
        }
    }
}

/// Apply H_j = I − τ_j v_j v_jᵀ (reflector `j` of a packed
/// factorization) to `out` in place — same numerics as
/// `PackedQr::apply_reflector`.
fn apply_reflector(packed: MatrixView<'_>, tau: &[f32], j: usize, out: &mut MatrixViewMut<'_>) {
    let (m, k) = out.shape();
    let tj = tau[j] as f64;
    if tj == 0.0 {
        return;
    }
    for c in 0..k {
        let mut dot = out.at(j, c) as f64; // v[j] = 1
        for i in j + 1..m {
            dot += packed.at(i, j) as f64 * out.at(i, c) as f64;
        }
        let w = tj * dot;
        out.set(j, c, (out.at(j, c) as f64 - w) as f32);
        for i in j + 1..m {
            let v = out.at(i, c) as f64 - packed.at(i, j) as f64 * w;
            out.set(i, c, v as f32);
        }
    }
}

/// Qᵀ @ out in place (reflectors in forward order).
pub fn apply_qt_in_place(packed: MatrixView<'_>, tau: &[f32], out: &mut MatrixViewMut<'_>) {
    for j in 0..packed.cols() {
        apply_reflector(packed, tau, j, out);
    }
}

/// Q @ out in place (reflectors in reverse order).
pub fn apply_q_in_place(packed: MatrixView<'_>, tau: &[f32], out: &mut MatrixViewMut<'_>) {
    for j in (0..packed.cols()).rev() {
        apply_reflector(packed, tau, j, out);
    }
}

// ---------------------------------------------------------------------
// CAQR kernels (f64 end-to-end)
// ---------------------------------------------------------------------
//
// The CAQR subsystem (`crate::caqr`) factors a general m×n matrix
// panel by panel and must reproduce `qr::householder_qr_reference`
// BIT FOR BIT — faults or not.  The reference works in f64 from the
// f32 input with a single terminal rounding, so every inter-task
// handoff in CAQR stays f64: the kernels below are the f64 halves of
// that contract.  (The f32 [`apply_update_into`] view kernel is the
// dispatchable single-precision sibling used by the runtime's
// `ApplyUpdate` op.)

/// Householder-factor an f64 row-major `rows x cols` panel in place
/// (LAPACK `geqrf` packed layout), writing the `cols` reflector
/// coefficients into `tau64`.
///
/// This is exactly the arithmetic [`factor_packed_f64_panelled`]
/// performs on one block column restricted to the panel itself, so a
/// CAQR run that factors panels with this kernel and updates trailing
/// blocks with [`apply_update_f64`] is bit-for-bit identical to the
/// unblocked whole-matrix reference.  Every replica of a panel-factor
/// task therefore produces the identical bit pattern — the redundancy
/// invariant CAQR's fault tolerance rests on.
pub fn factor_panel_f64(w: &mut [f64], rows: usize, cols: usize, tau64: &mut [f64]) {
    assert!(rows >= cols, "factor_panel_f64: panel must be tall-skinny, got {rows}x{cols}");
    assert_eq!(w.len(), rows * cols, "factor_panel_f64: buffer length != rows*cols");
    assert_eq!(tau64.len(), cols, "factor_panel_f64: tau must have {cols} entries");
    factor_packed_f64_panelled(w, rows, cols, tau64, PANEL);
}

/// Apply the reflectors of a packed f64 panel (`rows x cols`, from
/// [`factor_panel_f64`]) to an f64 trailing block (`rows x block_cols`)
/// in place — the CAQR trailing-matrix update.
///
/// Column by column, reflectors in ascending order, f64 dot products —
/// the exact accumulation order of the trailing loop inside
/// [`factor_packed_f64_panelled`], so updating a trailing block as a
/// separate (replicable) task is bit-for-bit identical to factoring
/// the whole matrix in one buffer.  Distinct blocks touch disjoint
/// columns, so update tasks parallelize without reordering any
/// arithmetic.
pub fn apply_update_f64(
    panel: &[f64],
    rows: usize,
    cols: usize,
    tau64: &[f64],
    block: &mut [f64],
    block_cols: usize,
) {
    assert_eq!(panel.len(), rows * cols, "apply_update_f64: panel length != rows*cols");
    assert_eq!(tau64.len(), cols, "apply_update_f64: tau must have {cols} entries");
    assert_eq!(
        block.len(),
        rows * block_cols,
        "apply_update_f64: block length != rows*block_cols"
    );
    for c in 0..block_cols {
        for j in 0..cols {
            if tau64[j] == 0.0 {
                continue; // identity reflector (zero column)
            }
            let mut dot = block[j * block_cols + c];
            for i in j + 1..rows {
                dot += panel[i * cols + j] * block[i * block_cols + c];
            }
            let s = tau64[j] * dot;
            block[j * block_cols + c] -= s;
            for i in j + 1..rows {
                block[i * block_cols + c] -= panel[i * cols + j] * s;
            }
        }
    }
}

/// Forward (Q-side) sibling of [`apply_update_f64`]: overwrite `block`
/// with `Q·block`, reflectors in *descending* order — the inverse
/// composition, so a forward apply after an [`apply_update_f64`]
/// round-trips the block (up to rounding).  Same packed layout, same
/// f64 accumulation, deterministic summation order.
pub fn apply_q_f64(
    panel: &[f64],
    rows: usize,
    cols: usize,
    tau64: &[f64],
    block: &mut [f64],
    block_cols: usize,
) {
    assert_eq!(panel.len(), rows * cols, "apply_q_f64: panel length != rows*cols");
    assert_eq!(tau64.len(), cols, "apply_q_f64: tau must have {cols} entries");
    assert_eq!(block.len(), rows * block_cols, "apply_q_f64: block length != rows*block_cols");
    for c in 0..block_cols {
        for j in (0..cols).rev() {
            if tau64[j] == 0.0 {
                continue; // identity reflector (zero column)
            }
            let mut dot = block[j * block_cols + c];
            for i in j + 1..rows {
                dot += panel[i * cols + j] * block[i * block_cols + c];
            }
            let s = tau64[j] * dot;
            block[j * block_cols + c] -= s;
            for i in j + 1..rows {
                block[i * block_cols + c] -= panel[i * cols + j] * s;
            }
        }
    }
}

/// f32 trailing-update view kernel: apply the reflectors of a packed
/// f32 factorization to `block`, writing the updated block into `out`.
///
/// The single-precision sibling of [`apply_update_f64`], shaped for
/// the runtime's `ApplyUpdate` kernel op: the block is loaded into the
/// workspace's f64 arena, every reflector accumulates in f64, and the
/// result is rounded to f32 exactly once — one rounding per element
/// regardless of the panel width (the in-place `apply_qt_in_place`
/// rounds after every reflector).
pub fn apply_update_into(
    packed: MatrixView<'_>,
    tau: &[f32],
    block: MatrixView<'_>,
    out: &mut MatrixViewMut<'_>,
    ws: &mut Workspace,
) {
    let (m, n) = packed.shape();
    assert_eq!(tau.len(), n, "apply_update_into: tau must have {n} entries");
    assert_eq!(block.rows(), m, "apply_update_into: block rows must match packed rows");
    assert_eq!(out.shape(), block.shape(), "apply_update_into: out must match block shape");
    let k = block.cols();
    let buf = ws.f64_scratch(m * (n + k) + n);
    let (pan, rest) = buf.split_at_mut(m * n);
    let (t, blk) = rest.split_at_mut(n);
    load_f64(pan, packed);
    for (d, &s) in t.iter_mut().zip(tau) {
        *d = s as f64;
    }
    load_f64(blk, block);
    apply_update_f64(pan, m, n, t, blk, k);
    store_f32(out.data, blk);
}

// ---------------------------------------------------------------------
// Compact-WY view kernels (the runtime's BuildT / ApplyWy ops)
// ---------------------------------------------------------------------

/// Materialize the unit-lower-trapezoidal V of a packed f32 view into
/// an f64 buffer (the view-input twin of [`super::wy::materialize_v`]):
/// reflector tails below the diagonal, 1 on it, zeros above.
fn load_unit_lower_f64(packed: MatrixView<'_>, v: &mut [f64]) {
    let (m, n) = packed.shape();
    debug_assert_eq!(v.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            v[i * n + j] = match i.cmp(&j) {
                std::cmp::Ordering::Greater => packed.at(i, j) as f64,
                std::cmp::Ordering::Equal => 1.0,
                std::cmp::Ordering::Less => 0.0,
            };
        }
    }
}

/// Build the `n×n` upper-triangular compact-WY T factor of a packed
/// f32 factorization into the caller's buffer (f64 internally, one
/// terminal rounding).  Scratch comes from `ws`; nothing is allocated.
pub fn build_t_into(
    packed: MatrixView<'_>,
    tau: &[f32],
    out: &mut MatrixViewMut<'_>,
    ws: &mut Workspace,
) {
    let (m, n) = packed.shape();
    assert_eq!(tau.len(), n, "build_t_into: tau must have {n} entries");
    assert_eq!(out.shape(), (n, n), "build_t_into: out must be {n}x{n}");
    let buf = ws.f64_scratch(m * n + n * n + 2 * n);
    let (v, rest) = buf.split_at_mut(m * n);
    let (t, rest) = rest.split_at_mut(n * n);
    let (t64, w) = rest.split_at_mut(n);
    load_unit_lower_f64(packed, v);
    for (d, &s) in t64.iter_mut().zip(tau) {
        *d = s as f64;
    }
    super::wy::build_t_f64(v, m, n, t64, t, w);
    store_f32(out.data, t);
}

/// Compact-WY trailing update: apply a packed f32 panel's reflectors to
/// `block` via `out = block − V·(Tᵀ·(Vᵀ·block))` — two GEMMs through
/// the packed [`crate::linalg::gemm`] microkernel instead of `n` rank-1
/// sweeps.  f64 accumulation with a single terminal rounding;
/// allocation-free on a warm workspace.  Deterministic (fixed summation
/// order) but NOT bitwise-identical to [`apply_update_into`] — the
/// level-3 fast path reassociates sums (see `linalg::wy`).
pub fn apply_wy_into(
    packed: MatrixView<'_>,
    t: MatrixView<'_>,
    block: MatrixView<'_>,
    out: &mut MatrixViewMut<'_>,
    ws: &mut Workspace,
) {
    let (m, n) = packed.shape();
    assert_eq!(t.shape(), (n, n), "apply_wy_into: T must be {n}x{n}");
    assert_eq!(block.rows(), m, "apply_wy_into: block rows must match packed rows");
    assert_eq!(out.shape(), block.shape(), "apply_wy_into: out must match block shape");
    let k = block.cols();
    let need = m * n + n * n + m * k + super::wy::apply_wyt_scratch(n, k);
    let buf = ws.f64_scratch(need);
    let (v, rest) = buf.split_at_mut(m * n);
    let (t64, rest) = rest.split_at_mut(n * n);
    let (c, scratch) = rest.split_at_mut(m * k);
    load_unit_lower_f64(packed, v);
    load_f64(t64, t);
    load_f64(c, block);
    super::wy::apply_wyt_with_scratch(v, t64, m, n, c, k, scratch);
    store_f32(out.data, c);
}

/// Forward (Q-side) compact-WY apply: `out = block − V·(T·(Vᵀ·block))`
/// — the `Q·C` sibling of [`apply_wy_into`]'s `Qᵀ·C`, shaped for the
/// runtime's `ApplyQWy` kernel op.  Chaining it over the panels in
/// reverse order against identity columns materializes the explicit Q
/// (that is the Q-assembly task body).  Same scratch discipline: f64
/// accumulation, one terminal rounding, allocation-free when warm.
pub fn apply_wy_forward_into(
    packed: MatrixView<'_>,
    t: MatrixView<'_>,
    block: MatrixView<'_>,
    out: &mut MatrixViewMut<'_>,
    ws: &mut Workspace,
) {
    let (m, n) = packed.shape();
    assert_eq!(t.shape(), (n, n), "apply_wy_forward_into: T must be {n}x{n}");
    assert_eq!(block.rows(), m, "apply_wy_forward_into: block rows must match packed rows");
    assert_eq!(out.shape(), block.shape(), "apply_wy_forward_into: out must match block shape");
    let k = block.cols();
    let need = m * n + n * n + m * k + super::wy::apply_wyt_scratch(n, k);
    let buf = ws.f64_scratch(need);
    let (v, rest) = buf.split_at_mut(m * n);
    let (t64, rest) = rest.split_at_mut(n * n);
    let (c, scratch) = rest.split_at_mut(m * k);
    load_unit_lower_f64(packed, v);
    load_f64(t64, t);
    load_f64(c, block);
    super::wy::apply_wy_forward_with_scratch(v, t64, m, n, c, k, scratch);
    store_f32(out.data, c);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits(m: &Matrix) -> Vec<u32> {
        m.data().iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn views_index_and_subrange() {
        let m = Matrix::from_fn(3, 2, |i, j| (i * 10 + j) as f32);
        let v = m.as_view();
        assert_eq!(v.shape(), (3, 2));
        assert_eq!(v.at(2, 1), 21.0);
        assert_eq!(v[(0, 1)], 1.0);
        let sub = v.rows_range(1, 3);
        assert_eq!(sub.shape(), (2, 2));
        assert_eq!(sub.at(0, 0), 10.0);
        assert_eq!(sub.to_matrix(), m.row_block(1, 3));
    }

    #[test]
    fn view_mut_set_and_copy() {
        let mut m = Matrix::zeros(2, 2);
        {
            let mut v = m.as_view_mut();
            v.set(0, 1, 5.0);
            v[(1, 0)] = 7.0;
        }
        assert_eq!(m[(0, 1)], 5.0);
        assert_eq!(m[(1, 0)], 7.0);
        let src = Matrix::eye(2, 2);
        m.as_view_mut().copy_from(src.as_view());
        assert_eq!(m, src);
    }

    #[test]
    #[should_panic]
    fn view_length_checked() {
        let buf = [0.0f32; 3];
        MatrixView::new(2, 2, &buf);
    }

    #[test]
    fn blocked_qr_bitwise_equals_reference() {
        // Including shapes around the panel boundary and m == n.
        for (m, n) in [(4, 4), (16, 4), (40, 33), (64, 32), (65, 34), (7, 1), (1, 1)] {
            let a = Matrix::random(m, n, (m * 131 + n) as u64);
            let reference = crate::linalg::qr::householder_qr_reference(&a);
            let mut packed = Matrix::zeros(m, n);
            let mut tau = vec![0.0f32; n];
            let mut ws = Workspace::new();
            householder_qr_into(a.as_view(), &mut packed.as_view_mut(), &mut tau, &mut ws);
            assert_eq!(bits(&packed), bits(&reference.packed), "packed differs at {m}x{n}");
            let tb: Vec<u32> = tau.iter().map(|x| x.to_bits()).collect();
            let rb: Vec<u32> = reference.tau.iter().map(|x| x.to_bits()).collect();
            assert_eq!(tb, rb, "tau differs at {m}x{n}");
        }
    }

    #[test]
    fn combine_r_into_matches_vstack_reference() {
        let n = 6;
        let top = crate::linalg::qr::qr_r(&Matrix::random(12, n, 1));
        let bot = crate::linalg::qr::qr_r(&Matrix::random(12, n, 2));
        let reference = crate::linalg::qr::householder_qr_reference(&top.vstack(&bot)).r();
        let mut out = Matrix::zeros(n, n);
        let mut ws = Workspace::new();
        combine_r_into(top.as_view(), bot.as_view(), &mut out.as_view_mut(), &mut ws);
        assert_eq!(bits(&out), bits(&reference));
    }

    #[test]
    fn workspace_reuse_is_allocation_free() {
        let a = Matrix::random(48, 8, 3);
        let mut packed = Matrix::zeros(48, 8);
        let mut tau = vec![0.0f32; 8];
        let mut ws = Workspace::new();
        householder_qr_into(a.as_view(), &mut packed.as_view_mut(), &mut tau, &mut ws);
        let grows_after_first = ws.grows();
        for _ in 0..10 {
            householder_qr_into(a.as_view(), &mut packed.as_view_mut(), &mut tau, &mut ws);
        }
        assert_eq!(ws.grows(), grows_after_first, "warm workspace must not grow");
        // A pre-sized workspace never grows at all.
        let mut warm = Workspace::sized_for(48, 8);
        householder_qr_into(a.as_view(), &mut packed.as_view_mut(), &mut tau, &mut warm);
        assert_eq!(warm.grows(), 0);
    }

    #[test]
    fn backsolve_into_matches_oracle() {
        let r = crate::linalg::qr::qr_r(&Matrix::random(16, 5, 4));
        let b = Matrix::random(5, 3, 5);
        let oracle = crate::linalg::qr::backsolve(&r, &b);
        let mut out = Matrix::zeros(5, 3);
        backsolve_into(r.as_view(), b.as_view(), &mut out.as_view_mut());
        assert_eq!(bits(&out), bits(&oracle));
    }

    #[test]
    fn matmul_into_matches_matrix_matmul() {
        let a = Matrix::random(7, 5, 6);
        let b = Matrix::random(5, 4, 7);
        let oracle = a.matmul(&b);
        let mut out = Matrix::zeros(7, 4);
        matmul_into(a.as_view(), b.as_view(), &mut out.as_view_mut());
        assert_eq!(bits(&out), bits(&oracle));
    }

    #[test]
    fn apply_q_roundtrip_via_views() {
        let a = Matrix::random(24, 6, 11);
        let f = crate::linalg::qr::householder_qr(&a);
        let b = Matrix::random(24, 3, 12);
        let mut out = b.clone();
        apply_qt_in_place(f.packed.as_view(), &f.tau, &mut out.as_view_mut());
        apply_q_in_place(f.packed.as_view(), &f.tau, &mut out.as_view_mut());
        assert!(out.max_abs_diff(&b) < 1e-4);
    }

    #[test]
    fn panel_width_does_not_change_bits() {
        // The blocked factorization is bitwise independent of the
        // panel width — the property CAQR's bitwise contract rests on.
        let (m, n) = (48, 20);
        let a = Matrix::random(m, n, 77);
        let reference = crate::linalg::qr::householder_qr_reference(&a);
        for panel in [1usize, 3, 5, 8, 20, 64] {
            let mut w: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
            let mut tau = vec![0.0f64; n];
            factor_packed_f64_panelled(&mut w, m, n, &mut tau, panel);
            let got: Vec<u32> = w.iter().map(|&x| (x as f32).to_bits()).collect();
            assert_eq!(got, bits(&reference.packed), "packed differs at panel={panel}");
        }
    }

    #[test]
    fn caqr_f64_kernels_recompose_the_reference() {
        // factor_panel_f64 on each block column + apply_update_f64 on
        // the trailing blocks == the whole-matrix reference, bitwise.
        let (m, n, b) = (32, 12, 5);
        let a = Matrix::random(m, n, 31);
        let reference = crate::linalg::qr::householder_qr_reference(&a);
        let mut w: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
        let mut tau_all = vec![0.0f64; n];
        let mut c0 = 0;
        while c0 < n {
            let c1 = (c0 + b).min(n);
            let (rows, cols) = (m - c0, c1 - c0);
            // Extract the panel (rows c0.., cols c0..c1) into a dense buffer.
            let mut panel = vec![0.0f64; rows * cols];
            for i in 0..rows {
                for j in 0..cols {
                    panel[i * cols + j] = w[(c0 + i) * n + (c0 + j)];
                }
            }
            factor_panel_f64(&mut panel, rows, cols, &mut tau_all[c0..c1]);
            // Update each trailing block independently (as CAQR tasks do).
            let mut t0 = c1;
            while t0 < n {
                let t1 = (t0 + b).min(n);
                let bk = t1 - t0;
                let mut block = vec![0.0f64; rows * bk];
                for i in 0..rows {
                    for j in 0..bk {
                        block[i * bk + j] = w[(c0 + i) * n + (t0 + j)];
                    }
                }
                apply_update_f64(&panel, rows, cols, &tau_all[c0..c1], &mut block, bk);
                for i in 0..rows {
                    for j in 0..bk {
                        w[(c0 + i) * n + (t0 + j)] = block[i * bk + j];
                    }
                }
                t0 = t1;
            }
            // Write the factored panel back.
            for i in 0..rows {
                for j in 0..cols {
                    w[(c0 + i) * n + (c0 + j)] = panel[i * cols + j];
                }
            }
            c0 = c1;
        }
        let got: Vec<u32> = w.iter().map(|&x| (x as f32).to_bits()).collect();
        assert_eq!(got, bits(&reference.packed), "CAQR recomposition differs from reference");
        let tb: Vec<u32> = tau_all.iter().map(|&x| (x as f32).to_bits()).collect();
        let rb: Vec<u32> = reference.tau.iter().map(|x| x.to_bits()).collect();
        assert_eq!(tb, rb, "tau differs");
    }

    #[test]
    fn apply_update_into_matches_f64_path_at_f32_inputs() {
        // The f32 view kernel must agree with apply_update_f64 run on
        // the f32-rounded operands (same arithmetic, one rounding).
        let (m, n, k) = (16, 4, 3);
        let a = Matrix::random(m, n, 9);
        let f = crate::linalg::qr::householder_qr(&a);
        let block = Matrix::random(m, k, 10);
        let mut out = Matrix::zeros(m, k);
        let mut ws = Workspace::new();
        apply_update_into(
            f.packed.as_view(),
            &f.tau,
            block.as_view(),
            &mut out.as_view_mut(),
            &mut ws,
        );
        let pan: Vec<f64> = f.packed.data().iter().map(|&x| x as f64).collect();
        let tau: Vec<f64> = f.tau.iter().map(|&x| x as f64).collect();
        let mut blk: Vec<f64> = block.data().iter().map(|&x| x as f64).collect();
        apply_update_f64(&pan, m, n, &tau, &mut blk, k);
        let want: Vec<u32> = blk.iter().map(|&x| (x as f32).to_bits()).collect();
        assert_eq!(bits(&out), want);
        // And it must agree with the in-place Qᵀ application numerically.
        let mut qt = block.clone();
        apply_qt_in_place(f.packed.as_view(), &f.tau, &mut qt.as_view_mut());
        assert!(out.max_abs_diff(&qt) < 1e-4);
    }

    #[test]
    fn build_t_and_apply_wy_match_the_rank1_update() {
        let (m, n, k) = (32, 8, 6);
        let a = Matrix::random(m, n, 21);
        let f = crate::linalg::qr::householder_qr(&a);
        let mut ws = Workspace::new();
        let mut t = Matrix::zeros(n, n);
        build_t_into(f.packed.as_view(), &f.tau, &mut t.as_view_mut(), &mut ws);
        assert!(t.is_upper_triangular(0.0), "T must be upper triangular");
        for j in 0..n {
            assert_eq!(t[(j, j)], f.tau[j], "diag(T) is tau");
        }
        let block = Matrix::random(m, k, 22);
        let mut fast = Matrix::zeros(m, k);
        apply_wy_into(
            f.packed.as_view(),
            t.as_view(),
            block.as_view(),
            &mut fast.as_view_mut(),
            &mut ws,
        );
        let mut slow = Matrix::zeros(m, k);
        apply_update_into(
            f.packed.as_view(),
            &f.tau,
            block.as_view(),
            &mut slow.as_view_mut(),
            &mut ws,
        );
        assert!(
            fast.max_abs_diff(&slow) < 1e-4,
            "WY update must agree with the rank-1 path numerically"
        );
        // Deterministic: same call, same bits.
        let mut again = Matrix::zeros(m, k);
        apply_wy_into(
            f.packed.as_view(),
            t.as_view(),
            block.as_view(),
            &mut again.as_view_mut(),
            &mut ws,
        );
        assert_eq!(bits(&fast), bits(&again), "apply_wy_into must be deterministic");
        // Warm workspace: repeat calls never grow the arena.
        let grows = ws.grows();
        build_t_into(f.packed.as_view(), &f.tau, &mut t.as_view_mut(), &mut ws);
        apply_wy_into(
            f.packed.as_view(),
            t.as_view(),
            block.as_view(),
            &mut again.as_view_mut(),
            &mut ws,
        );
        assert_eq!(ws.grows(), grows, "warm WY kernels must not grow the workspace");
    }

    #[test]
    fn zero_matrix_blocked_does_not_nan() {
        let a = Matrix::zeros(8, 3);
        let mut packed = Matrix::zeros(8, 3);
        let mut tau = vec![9.0f32; 3];
        let mut ws = Workspace::new();
        householder_qr_into(a.as_view(), &mut packed.as_view_mut(), &mut tau, &mut ws);
        assert!(packed.data().iter().all(|x| x.is_finite()));
        assert!(tau.iter().all(|&t| t == 0.0));
    }
}
