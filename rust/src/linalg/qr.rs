//! Host-side Householder QR — the rust-native oracle and fallback.
//!
//! Mirrors the L1 Pallas kernels exactly (same LAPACK `geqrf` packed
//! layout, same sign convention) so that:
//!   * `cargo test` has a full correctness oracle with no artifacts,
//!   * the runtime can fall back for shapes outside the AOT manifest,
//!   * the PJRT path is cross-checked against an independent
//!     implementation (integration_runtime.rs).
//!
//! Internally accumulates in `f64` and stores `f32`, which keeps the
//! oracle at least as accurate as the kernels it validates.

use std::cell::RefCell;

use super::matrix::Matrix;
use super::view::{self, Workspace};

thread_local! {
    /// Per-thread scratch arena for the allocating shims below: the
    /// classic `householder_qr(&a) -> PackedQr` API keeps its
    /// signature, but its O(m·n) f64 working set is reused across
    /// calls on the same thread instead of reallocated.  (The executor
    /// hot path uses an explicit `runtime::WorkspacePool` instead.)
    static SHIM_WS: RefCell<Workspace> = RefCell::new(Workspace::new());
}

fn with_shim_workspace<T>(f: impl FnOnce(&mut Workspace) -> T) -> T {
    SHIM_WS.with(|ws| f(&mut ws.borrow_mut()))
}

/// Packed Householder factorization: R above/on the diagonal, reflector
/// tails below, plus the `tau` coefficients — LAPACK `geqrf` layout and
/// exactly the `[packed, tau]` pair the AOT `leaf_qr` artifact returns.
#[derive(Clone, Debug)]
pub struct PackedQr {
    /// The packed `m x n` factor: R on/above the diagonal, reflector
    /// tails below.
    pub packed: Matrix,
    /// The `n` Householder reflector coefficients.
    pub tau: Vec<f32>,
}

impl PackedQr {
    /// Extract the (n, n) upper-triangular R factor.
    pub fn r(&self) -> Matrix {
        let n = self.packed.cols();
        self.packed.row_block(0, n).triu()
    }

    /// Materialize the thin Q (m, n).
    pub fn q(&self) -> Matrix {
        let (m, n) = self.packed.shape();
        self.apply_q(&Matrix::eye(m, n))
    }

    /// Q @ B — reflectors applied in reverse order.
    pub fn apply_q(&self, b: &Matrix) -> Matrix {
        let n = self.packed.cols();
        let mut out = b.clone();
        for j in (0..n).rev() {
            self.apply_reflector(j, &mut out);
        }
        out
    }

    /// Qᵀ @ B — reflectors applied in forward order.
    pub fn apply_qt(&self, b: &Matrix) -> Matrix {
        let n = self.packed.cols();
        let mut out = b.clone();
        for j in 0..n {
            self.apply_reflector(j, &mut out);
        }
        out
    }

    /// Apply H_j = I − τ_j v_j v_jᵀ to `out` in place (H is symmetric,
    /// so the same routine serves Q and Qᵀ; only the order differs).
    fn apply_reflector(&self, j: usize, out: &mut Matrix) {
        let (m, k) = out.shape();
        let tau = self.tau[j] as f64;
        if tau == 0.0 {
            return;
        }
        // v_j: 1 at row j, packed tail below.
        for c in 0..k {
            let mut dot = out[(j, c)] as f64; // v[j] = 1
            for i in j + 1..m {
                dot += self.packed[(i, j)] as f64 * out[(i, c)] as f64;
            }
            let w = tau * dot;
            out[(j, c)] = (out[(j, c)] as f64 - w) as f32;
            for i in j + 1..m {
                out[(i, c)] = (out[(i, c)] as f64 - self.packed[(i, j)] as f64 * w) as f32;
            }
        }
    }
}

/// Householder QR of a tall-skinny panel (m >= n) — allocating shim
/// over the blocked view kernel [`view::householder_qr_into`] (thread-
/// local workspace; outputs freshly allocated).  Bit-for-bit identical
/// to [`householder_qr_reference`].
///
/// Panics if the panel is wide (m < n) — the TSQR plan guarantees
/// tall-skinny leaves, and the Pallas kernel enforces the same.
pub fn householder_qr(a: &Matrix) -> PackedQr {
    let (m, n) = a.shape();
    assert!(m >= n, "householder_qr: panel must be tall-skinny, got {m}x{n}");
    let mut packed = Matrix::zeros(m, n);
    let mut tau = vec![0.0f32; n];
    with_shim_workspace(|ws| {
        view::householder_qr_into(a.as_view(), &mut packed.as_view_mut(), &mut tau, ws);
    });
    PackedQr { packed, tau }
}

/// The original unblocked Householder loop, kept verbatim as the
/// bitwise oracle for the blocked kernels (see the `blocked_qr_*`
/// property tests): same LAPACK packed layout, same sign convention,
/// f64 end-to-end with a single rounding to f32 at the end.
pub fn householder_qr_reference(a: &Matrix) -> PackedQr {
    let (m, n) = a.shape();
    assert!(m >= n, "householder_qr: panel must be tall-skinny, got {m}x{n}");
    // Work in f64 end-to-end, cast once at the end.
    let mut w: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    let idx = |i: usize, j: usize| i * n + j;
    let mut tau = vec![0.0f32; n];

    for j in 0..n {
        // norm of column j, rows j..m
        let mut norm2 = 0.0f64;
        for i in j..m {
            norm2 += w[idx(i, j)] * w[idx(i, j)];
        }
        let normx = norm2.sqrt();
        let x0 = w[idx(j, j)];
        if normx == 0.0 {
            tau[j] = 0.0; // zero column: identity reflector
            continue;
        }
        let beta = if x0 >= 0.0 { -normx } else { normx };
        let denom = x0 - beta;
        let tj = (beta - x0) / beta;
        tau[j] = tj as f32;
        // v tail = x[j+1..] / denom (v[j] = 1 implicit).
        for i in j + 1..m {
            w[idx(i, j)] /= denom;
        }
        // Apply H to trailing columns j+1..n.
        for c in j + 1..n {
            let mut dot = w[idx(j, c)];
            for i in j + 1..m {
                dot += w[idx(i, j)] * w[idx(i, c)];
            }
            let s = tj * dot;
            w[idx(j, c)] -= s;
            for i in j + 1..m {
                w[idx(i, c)] -= w[idx(i, j)] * s;
            }
        }
        // Diagonal becomes beta (packed layout keeps the tail below).
        w[idx(j, j)] = beta;
    }

    let packed = Matrix::from_vec(m, n, w.into_iter().map(|x| x as f32).collect());
    PackedQr { packed, tau }
}

/// Sequential CAQR oracle: Householder QR of a general `m x n` matrix
/// (`m >= n`) factored block column by block column of width `panel`,
/// each panel's reflectors applied to the trailing matrix before the
/// next panel is touched — the failure-free reference the distributed
/// [`crate::caqr`] subsystem is pinned against.
///
/// **Bit-for-bit identical to [`householder_qr_reference`] for every
/// panel width**: panel decomposition only regroups *when* a trailing
/// column receives each reflector's rank-1 update; per column the
/// reflectors arrive in the same ascending order with the same f64
/// accumulation, and the single f64→f32 rounding happens once at the
/// end.  The property tests pin this for panel widths from 1 to ≥ n.
pub fn caqr_reference(a: &Matrix, panel: usize) -> PackedQr {
    let (m, n) = a.shape();
    assert!(m >= n, "caqr_reference: matrix must satisfy m >= n, got {m}x{n}");
    assert!(panel >= 1, "caqr_reference: panel width must be >= 1");
    let mut w: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    let mut tau64 = vec![0.0f64; n];
    view::factor_packed_f64_panelled(&mut w, m, n, &mut tau64, panel);
    let packed = Matrix::from_vec(m, n, w.into_iter().map(|x| x as f32).collect());
    PackedQr { packed, tau: tau64.into_iter().map(|x| x as f32).collect() }
}

/// Just the canonical R factor (diag >= 0) of a tall-skinny panel —
/// shim over [`view::leaf_r_into`] (skips materializing the packed
/// reflectors entirely).
pub fn qr_r(a: &Matrix) -> Matrix {
    let n = a.cols();
    let mut out = Matrix::zeros(n, n);
    with_shim_workspace(|ws| view::leaf_r_into(a.as_view(), &mut out.as_view_mut(), ws));
    out.canonicalize_r()
}

/// TSQR combine on the host: R of the stacked [r_top; r_bot] — shim
/// over [`view::combine_r_into`] (the stack is formed in workspace
/// scratch; no `vstack` allocation).
pub fn combine_r(r_top: &Matrix, r_bot: &Matrix) -> Matrix {
    let n = r_top.cols();
    let mut out = Matrix::zeros(n, n);
    with_shim_workspace(|ws| {
        view::combine_r_into(r_top.as_view(), r_bot.as_view(), &mut out.as_view_mut(), ws);
    });
    out
}

/// Upper-triangular back-substitution R x = b, b (n, k) — shim over
/// [`view::backsolve_into`].
pub fn backsolve(r: &Matrix, b: &Matrix) -> Matrix {
    let mut x = Matrix::zeros(r.rows(), b.cols());
    view::backsolve_into(r.as_view(), b.as_view(), &mut x.as_view_mut());
    x
}

/// Reference full-matrix QR residuals: (‖A − QR‖_F/‖A‖_F, ‖I − QᵀQ‖_F).
pub fn qr_residuals(a: &Matrix, q: &Matrix, r: &Matrix) -> (f64, f64) {
    let recon = q.matmul(r);
    let rel = recon.rel_fro_err(a);
    // Note rel_fro_err(self=recon, reference=a) = ||recon - a||/||a||.
    let n = q.cols();
    let qtq = q.transpose().matmul(q);
    let mut acc = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            let e = if i == j { 1.0 } else { 0.0 };
            let d = qtq[(i, j)] as f64 - e;
            acc += d * d;
        }
    }
    (rel, acc.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;


    #[test]
    fn qr_reconstructs_a() {
        for (m, n) in [(4, 4), (16, 4), (33, 7), (128, 16), (5, 1)] {
            let a = Matrix::random(m, n, (m * 31 + n) as u64);
            let f = householder_qr(&a);
            let (rel, ortho) = qr_residuals(&a, &f.q(), &f.r());
            assert!(rel < 1e-5, "recon {m}x{n}: {rel}");
            assert!(ortho < 1e-4, "ortho {m}x{n}: {ortho}");
        }
    }

    #[test]
    fn r_is_upper_triangular() {
        let a = Matrix::random(20, 6, 7);
        assert!(householder_qr(&a).r().is_upper_triangular(0.0));
    }

    #[test]
    fn qr_of_identity_is_identity() {
        let f = householder_qr(&Matrix::eye(5, 5));
        assert!(f.r().canonicalize_r().max_abs_diff(&Matrix::eye(5, 5)) < 1e-6);
    }

    #[test]
    fn zero_matrix_does_not_nan() {
        let f = householder_qr(&Matrix::zeros(8, 3));
        assert!(f.packed.data().iter().all(|x| x.is_finite()));
        assert!(f.tau.iter().all(|&t| t == 0.0));
    }

    #[test]
    fn caqr_reference_is_bitwise_householder_reference() {
        for (m, n) in [(24, 24), (40, 17), (64, 8), (9, 9), (16, 1)] {
            let a = Matrix::random(m, n, (m * 17 + n) as u64);
            let reference = householder_qr_reference(&a);
            for panel in [1usize, 2, 7, n, n + 5] {
                let c = caqr_reference(&a, panel);
                let pb: Vec<u32> = c.packed.data().iter().map(|x| x.to_bits()).collect();
                let rb: Vec<u32> = reference.packed.data().iter().map(|x| x.to_bits()).collect();
                assert_eq!(pb, rb, "packed differs at {m}x{n}, panel {panel}");
                assert_eq!(
                    c.tau.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    reference.tau.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "tau differs at {m}x{n}, panel {panel}"
                );
            }
        }
    }

    #[test]
    fn combine_matches_direct_qr_of_stack() {
        let r1 = qr_r(&Matrix::random(12, 4, 1));
        let r2 = qr_r(&Matrix::random(12, 4, 2));
        let combined = combine_r(&r1, &r2).canonicalize_r();
        let direct = qr_r(&r1.vstack(&r2));
        assert!(combined.max_abs_diff(&direct) < 1e-5);
    }

    #[test]
    fn tsqr_tree_equals_direct_qr() {
        // 4-leaf host TSQR == QR of the whole matrix (the invariant the
        // entire paper rests on).
        let a = Matrix::random(64, 8, 99);
        let rs: Vec<Matrix> = (0..4).map(|i| qr_r(&a.row_block(i * 16, (i + 1) * 16))).collect();
        let r01 = combine_r(&rs[0], &rs[1]);
        let r23 = combine_r(&rs[2], &rs[3]);
        let root = combine_r(&r01.canonicalize_r(), &r23.canonicalize_r()).canonicalize_r();
        assert!(root.max_abs_diff(&qr_r(&a)) < 1e-4);
    }

    #[test]
    fn backsolve_solves() {
        let r = qr_r(&Matrix::random(16, 8, 3));
        let xt = Matrix::random(8, 2, 4);
        let b = r.matmul(&xt);
        let x = backsolve(&r, &b);
        assert!(x.max_abs_diff(&xt) < 1e-3);
    }

    #[test]
    #[should_panic]
    fn wide_panel_rejected() {
        householder_qr(&Matrix::zeros(3, 5));
    }

    #[test]
    fn apply_qt_then_q_roundtrip() {
        let a = Matrix::random(24, 6, 11);
        let f = householder_qr(&a);
        let b = Matrix::random(24, 3, 12);
        let roundtrip = f.apply_q(&f.apply_qt(&b));
        assert!(roundtrip.max_abs_diff(&b) < 1e-4);
    }

    #[test]
    fn least_squares_via_qr() {
        let a = Matrix::random(60, 5, 21);
        let xt = Matrix::random(5, 1, 22);
        let b = a.matmul(&xt);
        let f = householder_qr(&a);
        let qtb = f.apply_qt(&b);
        let x = backsolve(&f.r(), &qtb.row_block(0, 5));
        assert!(x.max_abs_diff(&xt) < 1e-2);
    }
}
