//! Host-side dense linear algebra: the `Matrix` payload type plus a
//! pure-rust Householder QR used as verification oracle and as the
//! fallback backend for shapes outside the AOT manifest.

pub mod matrix;
pub mod qr;

pub use matrix::Matrix;
pub use qr::{PackedQr, backsolve, combine_r, householder_qr, qr_r, qr_residuals};
