//! Host-side dense linear algebra: the `Matrix` payload type, borrowed
//! [`MatrixView`]/[`MatrixViewMut`] slices, the reusable [`Workspace`]
//! scratch arena, and the blocked in-place Householder kernels —
//! verification oracle and fallback backend for shapes outside the AOT
//! manifest.
//!
//! The allocating `householder_qr`/`combine_r`/`backsolve` API is a
//! thin shim over the zero-copy view kernels in [`view`]; hot paths
//! (the [`crate::runtime::Executor`]) call the view kernels directly
//! with pooled workspaces.

pub mod matrix;
pub mod qr;
pub mod view;

pub use matrix::Matrix;
pub use qr::{
    PackedQr, backsolve, caqr_reference, combine_r, householder_qr, householder_qr_reference,
    qr_r, qr_residuals,
};
pub use view::{MatrixView, MatrixViewMut, Workspace};
