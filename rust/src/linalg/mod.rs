//! Host-side dense linear algebra: the `Matrix` payload type, borrowed
//! [`MatrixView`]/[`MatrixViewMut`] slices, the reusable [`Workspace`]
//! scratch arena, and the blocked in-place Householder kernels —
//! verification oracle and fallback backend for shapes outside the AOT
//! manifest.
//!
//! The allocating `householder_qr`/`combine_r`/`backsolve` API is a
//! thin shim over the zero-copy view kernels in [`view`]; hot paths
//! (the [`crate::runtime::Executor`]) call the view kernels directly
//! with pooled workspaces.
//!
//! The deterministic fast-kernel layer lives in [`gemm`] (packed,
//! cache-blocked f64 GEMM with runtime-dispatched SIMD microkernels, a
//! fixed summation order, autotuned cache tiles, and pool-parallel
//! column slabs) and [`wy`] (compact-WY accumulation, turning a panel's
//! trailing update into two GEMMs) — the `KernelProfile::Blocked` path
//! of the CAQR subsystem.

pub mod gemm;
pub mod matrix;
pub mod qr;
pub mod view;
pub mod wy;

pub use gemm::{Accum, GemmParams, Isa, gemm_into, gemm_into_pooled};
pub use matrix::Matrix;
pub use qr::{
    PackedQr, backsolve, caqr_reference, combine_r, householder_qr, householder_qr_reference,
    qr_r, qr_residuals,
};
pub use view::{MatrixView, MatrixViewMut, Workspace};
pub use wy::WyFactor;
