//! The event-driven replay of the CAQR coordinator: the
//! [`crate::caqr::exec`] panel walk and recovery ladder re-expressed
//! as heap events over a virtual clock.
//!
//! No matrices, no per-rank threads, no real sleeps: per panel the
//! runner costs `O(blocks + checksums + deaths)` **independent of the
//! world size**, which is what moves fault campaigns from the
//! thread-based executor's P ∈ {4, 8} to P = 10⁵–10⁶ ranks.
//!
//! ## The parity contract
//!
//! For a scenario with no churn and an ideal network, the runner's
//! ladder decisions are *byte-for-byte* the thread-based executor's:
//! it fires the same `(rank, panel, stage)` kills at the same stage
//! boundaries, walks the identical replica → checksum → abort ladder
//! ([`crate::abft::RecoveryPolicy`]), and reproduces the executor's
//! survival/abort outcome and recovery counters exactly.
//! [`replay`] packages that path for a [`CaqrSpec`], and
//! `tests/integration_sim.rs` pins it against
//! [`Engine::run_caqr`](crate::engine::Engine::run_caqr) for
//! P ∈ {4, 8} across all three policies.
//!
//! Churn, bursts, and network delays then *extend* the same machine:
//! they only add liveness flips and virtual-time stretches between
//! the stage boundaries the ladder already evaluates.

use std::collections::HashMap;

use crate::abft::RecoveryPolicy;
use crate::caqr::CaqrSpec;
use crate::error::Result;
use crate::fault::CaqrStage;
use crate::metrics::VirtualTimeBreakdown;
use crate::tsqr::{Algo, PanelPlan};
use crate::util::Rng;

use super::clock::VirtualClock;
use super::heap::EventHeap;
use super::scenario::SimScenario;

/// One simulator event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Event {
    /// Panel `k`'s stage begins: scheduled kills for the stage fire.
    StageStart(usize, CaqrStage),
    /// Panel `k`'s stage barrier: the recovery ladder is evaluated
    /// against current liveness.
    StageEnd(usize, CaqrStage),
    /// Independent churn death of a rank.
    Fail(usize),
    /// A churn-killed rank re-enters the world.
    Rejoin(usize),
    /// Correlated rack wipe.
    Burst,
}

/// Outcome and accounting of one simulated run.
///
/// The counter fields carry the executor's
/// [`MetricsSnapshot`](crate::ulfm::MetricsSnapshot) semantics (that
/// is the parity contract); the churn/virtual-time fields are
/// simulator-only.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimReport {
    /// Simulated world size.
    pub procs: usize,
    /// Panels the plan scheduled.
    pub panels: usize,
    /// Failure semantics the run executed under.
    pub algo: Algo,
    /// Recovery ladder the run executed under.
    pub policy: RecoveryPolicy,
    /// Checksum blocks armed per panel stage.
    pub checksums: usize,
    /// Where the run died, if it did (ladder exhausted).
    pub failed_at: Option<(usize, CaqrStage)>,
    /// Panels whose factor + updates fully completed.
    pub panels_completed: u64,
    /// Live update-task executions (data replicas + armed checksum
    /// tasks), counted exactly as the executor spawns them.
    pub update_tasks: u64,
    /// Blocks harvested from the surviving replica (owner dead).
    pub update_recoveries: u64,
    /// Completed panels whose factor owner was dead at harvest.
    pub factor_recoveries: u64,
    /// Task results rebuilt algebraically from checksums.
    pub checksum_reconstructions: u64,
    /// `(panel, stage)` events the checksum rung carried the run past.
    pub pair_wipes_survived: u64,
    /// Dead ranks respawned at panel boundaries (Self-Healing).
    pub respawns: u64,
    /// Scheduled `(rank, panel, stage)` kills that actually fired.
    pub scheduled_kills: u64,
    /// Independent churn + burst deaths.
    pub failures: u64,
    /// Churn-killed ranks that re-entered the world.
    pub rejoins: u64,
    /// Rack wipes that struck.
    pub bursts: u64,
    /// Ranks dead at the end of the run.
    pub dead: usize,
    /// Events processed (clock advances).
    pub events: u64,
    /// Events ever scheduled (the heap may hold unfired churn events
    /// at termination).
    pub events_scheduled: u64,
    /// Virtual time at termination, nanoseconds.
    pub virtual_ns: u64,
    /// Where the virtual time went.
    pub time: VirtualTimeBreakdown,
}

impl SimReport {
    /// Did the factorization complete?
    pub fn success(&self) -> bool {
        self.failed_at.is_none()
    }
}

/// Aggregate of one simulated campaign: every sample's [`SimReport`]
/// plus the real (wall-clock) time the batch took — the numerator of
/// the simulator's reason to exist, events per *real* second.
/// Produced by [`Engine::simulate`](crate::engine::Engine::simulate).
#[derive(Debug, Clone)]
pub struct SimBatchReport {
    /// Per-sample reports, in sample order.
    pub reports: Vec<SimReport>,
    /// Real time the whole batch took.
    pub wall: std::time::Duration,
}

impl SimBatchReport {
    /// Samples that completed the factorization.
    pub fn successes(&self) -> u64 {
        self.reports.iter().filter(|r| r.success()).count() as u64
    }

    /// Survival statistics over the batch.
    pub fn survival(&self) -> crate::analysis::SurvivalEstimate {
        crate::analysis::SurvivalEstimate {
            trials: self.reports.len() as u64,
            successes: self.successes(),
        }
    }

    /// Total simulator events processed across all samples.
    pub fn events(&self) -> u64 {
        self.reports.iter().map(|r| r.events).sum()
    }

    /// Events processed per real second — the throughput the
    /// `sim_throughput` bench gates on.
    pub fn events_per_sec(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s > 0.0 { self.events() as f64 / s } else { 0.0 }
    }

    /// Total virtual time simulated across all samples, nanoseconds.
    pub fn virtual_ns(&self) -> u64 {
        self.reports.iter().map(|r| r.virtual_ns).sum()
    }

    /// Merged virtual-time breakdown across all samples.
    pub fn time(&self) -> VirtualTimeBreakdown {
        let mut t = VirtualTimeBreakdown::default();
        for r in &self.reports {
            t.merge(&r.time);
        }
        t
    }
}

/// Replay a [`CaqrSpec`]'s kill schedule event-driven — the parity
/// entry point.  Reads the schedule without consuming it, resolves the
/// policy/checksums exactly as the executor does, and runs with zero
/// network delay and no churn.
pub fn replay(spec: &CaqrSpec) -> Result<SimReport> {
    spec.validate()?;
    // One resolution point shared with the executor: explicit knobs or
    // the failure-model-adaptive choice — parity by construction.
    let (policy, armed) = spec.resolved_protection();
    let mut sim = Sim::new(
        spec.plan(),
        spec.algo,
        policy,
        armed,
        super::scenario::CostModel::default(),
        super::NetworkModel::Ideal,
        super::ChurnModel::default(),
        &spec.schedule.entries(),
        spec.seed,
    );
    Ok(sim.run())
}

/// Run one scenario sample (validates first).  Campaigns go through
/// [`Engine::simulate`](crate::engine::Engine::simulate) instead,
/// which fans the samples over the worker pool.
pub fn run_scenario(sc: &SimScenario) -> Result<SimReport> {
    sc.validate()?;
    Ok(run_validated(sc))
}

/// Scenario entry for callers that already validated (the engine).
pub(crate) fn run_validated(sc: &SimScenario) -> SimReport {
    let mut sim = Sim::new(
        sc.plan(),
        sc.algo,
        sc.policy,
        sc.armed_checksums(),
        sc.costs,
        sc.network,
        sc.churn,
        &sc.kills,
        sc.seed,
    );
    sim.run()
}

struct Sim {
    plan: PanelPlan,
    procs: usize,
    algo: Algo,
    policy: RecoveryPolicy,
    checksums: usize,
    use_checksums: bool,
    costs: super::scenario::CostModel,
    network: super::NetworkModel,
    churn: super::ChurnModel,
    rng: Rng,
    heap: EventHeap<Event>,
    clock: VirtualClock,
    alive: Vec<bool>,
    alive_count: usize,
    /// Pending scheduled kills, indexed by the stage they strike.
    kills: HashMap<(usize, CaqrStage), Vec<usize>>,
    /// Journal of liveness at the current panel's start: records a
    /// rank's panel-start value the first time it flips within the
    /// panel.  Cleared at each factor StageStart — O(flips), never
    /// O(P), unlike the executor's full snapshots.
    panel_start: HashMap<usize, bool>,
    /// One pending churn Fail event per rank at most.
    fail_pending: Vec<bool>,
    /// Ranks that died since the last boundary (Self-Healing respawn
    /// set; unused under Redundant).
    died_since_boundary: Vec<usize>,
    /// Factor owner of the in-flight panel was dead at harvest.
    pending_factor_recovered: bool,
    report: SimReport,
    done: bool,
}

impl Sim {
    #[allow(clippy::too_many_arguments)]
    fn new(
        plan: PanelPlan,
        algo: Algo,
        policy: RecoveryPolicy,
        checksums: usize,
        costs: super::scenario::CostModel,
        network: super::NetworkModel,
        churn: super::ChurnModel,
        kills: &[(usize, usize, CaqrStage)],
        seed: u64,
    ) -> Self {
        let procs = plan.procs();
        let mut by_stage: HashMap<(usize, CaqrStage), Vec<usize>> = HashMap::new();
        for &(r, k, stage) in kills {
            by_stage.entry((k, stage)).or_default().push(r);
        }
        for ranks in by_stage.values_mut() {
            ranks.sort_unstable();
            ranks.dedup();
        }
        let report = SimReport {
            procs,
            panels: plan.panels(),
            algo,
            policy,
            checksums,
            failed_at: None,
            panels_completed: 0,
            update_tasks: 0,
            update_recoveries: 0,
            factor_recoveries: 0,
            checksum_reconstructions: 0,
            pair_wipes_survived: 0,
            respawns: 0,
            scheduled_kills: 0,
            failures: 0,
            rejoins: 0,
            bursts: 0,
            dead: 0,
            events: 0,
            events_scheduled: 0,
            virtual_ns: 0,
            time: VirtualTimeBreakdown::default(),
        };
        Self {
            plan,
            procs,
            algo,
            policy,
            checksums,
            use_checksums: policy.uses_checksums() && checksums > 0,
            costs,
            network,
            churn,
            rng: Rng::new(seed),
            heap: EventHeap::new(),
            clock: VirtualClock::new(),
            alive: vec![true; procs],
            alive_count: procs,
            kills: by_stage,
            panel_start: HashMap::new(),
            fail_pending: vec![false; procs],
            died_since_boundary: Vec::new(),
            pending_factor_recovered: false,
            report,
            done: false,
        }
    }

    fn run(&mut self) -> SimReport {
        // Seed the event horizon: one churn lifetime per rank, the
        // first rack wipe, and panel 0's factor stage.
        if self.churn.churns() {
            for r in 0..self.procs {
                let t = self.churn.lifetime_ns(&mut self.rng);
                self.heap.push(t, Event::Fail(r));
                self.fail_pending[r] = true;
            }
        }
        if self.churn.bursts() {
            let gap = self.churn.burst_gap_ns(&mut self.rng);
            self.heap.push(gap, Event::Burst);
        }
        self.heap.push(0, Event::StageStart(0, CaqrStage::Factor));

        while !self.done {
            let Some((t, ev)) = self.heap.pop() else { break };
            self.clock.advance_to(t);
            self.handle(ev);
        }

        self.report.dead = self.procs - self.alive_count;
        self.report.events = self.clock.events_processed();
        self.report.events_scheduled = self.heap.scheduled();
        self.report.virtual_ns = self.clock.now_ns();
        self.report.clone()
    }

    // ------------------------------------------------ liveness flips

    /// Journal `r`'s current liveness as its panel-start value, unless
    /// the panel already saw it flip.
    fn journal(&mut self, r: usize) {
        self.panel_start.entry(r).or_insert(self.alive[r]);
    }

    fn alive_at_panel_start(&self, r: usize) -> bool {
        *self.panel_start.get(&r).unwrap_or(&self.alive[r])
    }

    /// Kill `r` if alive; returns whether it died.
    fn kill(&mut self, r: usize) -> bool {
        if !self.alive[r] {
            return false;
        }
        self.journal(r);
        self.alive[r] = false;
        self.alive_count -= 1;
        if self.algo == Algo::SelfHealing {
            self.died_since_boundary.push(r);
        }
        true
    }

    fn revive(&mut self, r: usize) {
        debug_assert!(!self.alive[r]);
        self.journal(r);
        self.alive[r] = true;
        self.alive_count += 1;
    }

    /// After a revival, re-arm the rank's churn clock (at most one
    /// pending Fail per rank).
    fn rearm_churn(&mut self, r: usize) {
        if self.churn.churns() && !self.fail_pending[r] {
            let t = self.clock.now_ns() + self.churn.lifetime_ns(&mut self.rng);
            self.heap.push(t, Event::Fail(r));
            self.fail_pending[r] = true;
        }
    }

    /// A rank died to churn/burst: count it and schedule its rejoin.
    fn churn_death(&mut self, r: usize) {
        self.report.failures += 1;
        if self.churn.rejoin_ns > 0 {
            self.heap.push(self.clock.now_ns() + self.churn.rejoin_ns, Event::Rejoin(r));
        }
    }

    // ------------------------------------------------- ladder helpers

    /// The ranks that compute panel `k`'s factor under the policy
    /// (mirrors the executor's `factor_task_ranks`).
    fn factor_alive(&self, k: usize) -> bool {
        if self.policy.replicates() {
            self.plan.factor_replicas(k).into_iter().any(|r| self.alive[r])
        } else {
            self.alive[self.plan.factor_owner(k)]
        }
    }

    /// Checksums of panel `k` with a live holder (mirrors the
    /// executor's `live_checksums`), as a count.
    fn live_checksums(&self, k: usize) -> usize {
        (0..self.checksums)
            .filter(|&l| self.plan.checksum_assignees(k, l).into_iter().any(|r| self.alive[r]))
            .count()
    }

    /// Holder groups freshly wiped at panel `k`'s factor stage: of the
    /// groups that held panel data at panel start, how many have no
    /// survivor now (mirrors the executor's `holder_groups` walk).
    fn lost_holder_groups(&self, _k: usize) -> usize {
        let pairs = self.policy.replicates() && self.procs >= 2;
        let groups = if pairs { self.procs / 2 } else { self.procs };
        let mut lost = 0;
        for g in 0..groups {
            let (a, b) = if pairs { (2 * g, 2 * g + 1) } else { (g, g) };
            let held = self.alive_at_panel_start(a) || self.alive_at_panel_start(b);
            if held && !(self.alive[a] || self.alive[b]) {
                lost += 1;
            }
        }
        lost
    }

    // ------------------------------------------------- event handlers

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::StageStart(k, stage) => self.stage_start(k, stage),
            Event::StageEnd(k, stage) => self.stage_end(k, stage),
            Event::Fail(r) => {
                self.fail_pending[r] = false;
                if self.kill(r) {
                    self.churn_death(r);
                }
            }
            Event::Rejoin(r) => {
                if !self.alive[r] {
                    self.revive(r);
                    self.report.rejoins += 1;
                    self.rearm_churn(r);
                }
            }
            Event::Burst => {
                self.report.bursts += 1;
                let g = self.rng.below(self.churn.racks(self.procs));
                let (lo, hi) = self.churn.rack_range(g, self.procs);
                for r in lo..hi {
                    if self.kill(r) {
                        self.churn_death(r);
                    }
                }
                let gap = self.churn.burst_gap_ns(&mut self.rng);
                self.heap.push(self.clock.now_ns() + gap, Event::Burst);
            }
        }
    }

    /// Fire the scheduled kills of `(k, stage)` — exactly the
    /// executor's rule: an entry fires only if its rank is alive.
    fn fire_scheduled(&mut self, k: usize, stage: CaqrStage) {
        if let Some(ranks) = self.kills.remove(&(k, stage)) {
            for r in ranks {
                if self.kill(r) {
                    self.report.scheduled_kills += 1;
                }
            }
        }
    }

    fn stage_start(&mut self, k: usize, stage: CaqrStage) {
        match stage {
            CaqrStage::Factor => {
                // New panel: reset the panel-start journal *before*
                // this stage's kills fire, so the ladder sees who held
                // data when the panel began.
                self.panel_start.clear();
                self.fire_scheduled(k, CaqrStage::Factor);
                let work = self.costs.factor_ns;
                let net = self.network.delay(&mut self.rng);
                self.report.time.compute_ns += work;
                self.report.time.network_ns += net;
                self.heap.push(
                    self.clock.now_ns() + work + net,
                    Event::StageEnd(k, CaqrStage::Factor),
                );
            }
            CaqrStage::Update => {
                self.fire_scheduled(k, CaqrStage::Update);
                let blocks = self.plan.update_blocks(k);
                let repl = if self.policy.replicates() && self.procs >= 2 { 2 } else { 1 };
                let check_holders = if self.procs > 2 { 2 } else { 1 };
                let tasks = blocks * repl
                    + if blocks > 0 { self.checksums * check_holders } else { 0 };
                let slots = self.alive_count.max(1);
                let work = self.costs.update_ns * tasks.div_ceil(slots) as u64;
                let net = self.network.delay(&mut self.rng);
                self.report.time.compute_ns += work;
                self.report.time.network_ns += net;
                self.heap.push(
                    self.clock.now_ns() + work + net,
                    Event::StageEnd(k, CaqrStage::Update),
                );
            }
            // The post-factorization Q phases are an executor-side
            // construct (they cost real matrix work); the simulator's
            // scenarios never schedule them, so no event carries them.
            CaqrStage::QAssembly | CaqrStage::ApplyQ => {
                unreachable!("the simulator does not schedule Q-phase events")
            }
        }
    }

    fn stage_end(&mut self, k: usize, stage: CaqrStage) {
        match stage {
            CaqrStage::Factor => self.factor_barrier(k),
            CaqrStage::Update => self.update_barrier(k),
            CaqrStage::QAssembly | CaqrStage::ApplyQ => {
                unreachable!("the simulator does not schedule Q-phase events")
            }
        }
    }

    /// Factor-stage barrier: the executor's factor ladder.
    fn factor_barrier(&mut self, k: usize) {
        let mut penalty = 0u64;
        if !self.factor_alive(k) {
            // Every factor replica is dead: the checksum rung rebuilds
            // the wiped pairs' input shards and re-executes — if the
            // policy has the rung, a survivor exists, and enough
            // checksum shards survive.
            let lost = self.lost_holder_groups(k);
            let feasible = self.use_checksums
                && self.alive_count > 0
                && lost <= self.live_checksums(k);
            if !feasible {
                self.report.failed_at = Some((k, CaqrStage::Factor));
                self.done = true;
                return;
            }
            self.report.checksum_reconstructions += lost as u64;
            self.report.pair_wipes_survived += 1;
            // Rebuild the lost shards, then re-execute the factor.
            penalty = self.costs.factor_ns + self.costs.update_ns * lost as u64;
            self.report.time.recovery_ns += penalty;
        }
        self.pending_factor_recovered = !self.alive[self.plan.factor_owner(k)];
        self.heap.push(
            self.clock.now_ns() + penalty,
            Event::StageStart(k, CaqrStage::Update),
        );
    }

    /// Update-stage barrier: the executor's update ladder, task
    /// accounting, and panel boundary.
    fn update_barrier(&mut self, k: usize) {
        let blocks = self.plan.update_blocks(k);
        let replicates = self.policy.replicates();
        let (mut lost, mut live_tasks, mut recoveries) = (0u64, 0u64, 0u64);
        for j in 0..blocks {
            let owner = self.plan.update_owner(k, j);
            let (live, owner_alive) = if replicates {
                let asg = self.plan.update_assignees(k, j);
                (
                    asg.iter().filter(|&&r| self.alive[r]).count() as u64,
                    self.alive[owner],
                )
            } else {
                (u64::from(self.alive[owner]), self.alive[owner])
            };
            if live == 0 {
                lost += 1;
            } else {
                live_tasks += live;
                if !owner_alive {
                    recoveries += 1;
                }
            }
        }
        if lost > 0 {
            let feasible = self.use_checksums && lost as usize <= self.live_checksums(k);
            if !feasible {
                self.report.failed_at = Some((k, CaqrStage::Update));
                self.done = true;
                // The executor breaks before spawning this panel's
                // update tasks: count nothing.
                return;
            }
            self.report.checksum_reconstructions += lost;
            self.report.pair_wipes_survived += 1;
            let penalty = self.costs.update_ns * lost;
            self.report.time.recovery_ns += penalty;
        }
        if self.checksums > 0 && blocks > 0 {
            for l in 0..self.checksums {
                live_tasks += self
                    .plan
                    .checksum_assignees(k, l)
                    .into_iter()
                    .filter(|&r| self.alive[r])
                    .count() as u64;
            }
        }
        self.report.update_tasks += live_tasks;
        self.report.update_recoveries += recoveries;
        self.report.factor_recoveries += u64::from(self.pending_factor_recovered);
        self.report.panels_completed += 1;

        // --------------------------------------------- panel boundary
        if self.algo == Algo::SelfHealing && !self.died_since_boundary.is_empty() {
            let mut dead = std::mem::take(&mut self.died_since_boundary);
            dead.sort_unstable();
            dead.dedup();
            for r in dead {
                if !self.alive[r] {
                    self.revive(r);
                    self.report.respawns += 1;
                    self.rearm_churn(r);
                }
            }
        }
        let recovery_lag = self.costs.update_ns * lost;
        if k + 1 < self.plan.panels() {
            self.heap.push(
                self.clock.now_ns() + recovery_lag,
                Event::StageStart(k + 1, CaqrStage::Factor),
            );
        } else {
            self.done = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::CaqrKillSchedule;

    fn spec(procs: usize) -> CaqrSpec {
        CaqrSpec::new(Algo::Redundant, procs, 32, 16, 4).with_verify(false)
    }

    #[test]
    fn fault_free_run_completes_all_panels() {
        let r = replay(&spec(4)).unwrap();
        assert!(r.success());
        assert_eq!(r.panels_completed, 4);
        assert_eq!(r.dead, 0);
        assert_eq!(r.failed_at, None);
        assert_eq!(r.update_tasks, (3 + 2 + 1) * 2, "3 panels of trailing blocks, 2 copies");
        assert!(r.events >= 16, "4 panels x 4 stage events");
        assert!(r.virtual_ns > 0);
        assert_eq!(r.time.recovery_ns, 0);
        assert_eq!(r.time.network_ns, 0, "parity path is an ideal network");
    }

    #[test]
    fn replay_is_deterministic_and_nonconsuming() {
        let s = spec(8).with_schedule(CaqrKillSchedule::at(&[(1, 0, CaqrStage::Update)]));
        let a = replay(&s).unwrap();
        let b = replay(&s).unwrap();
        assert_eq!(a, b, "same spec, same report — and the schedule was not consumed");
        assert_eq!(a.scheduled_kills, 1);
        assert_eq!(a.update_recoveries, 1, "owner's block came from the replica");
        assert_eq!(a.dead, 1);
    }

    #[test]
    fn pair_wipe_aborts_without_checksums_and_survives_with() {
        let wipe = [(2, 0, CaqrStage::Update), (3, 0, CaqrStage::Update)];
        let aborted = replay(&spec(4).with_schedule(CaqrKillSchedule::at(&wipe))).unwrap();
        assert_eq!(aborted.failed_at, Some((0, CaqrStage::Update)));
        assert_eq!(aborted.update_tasks, 0, "no tasks spawn on the failing panel");

        // The wiped pair (2, 3) owns *two* of panel 0's three update
        // blocks (owners 1+j mod 4 = 1, 2, 3, buddies owner^1), so
        // healing needs two checksum blocks, the P = 4 maximum.
        let healed = replay(
            &spec(4)
                .with_schedule(CaqrKillSchedule::at(&wipe))
                .with_policy(RecoveryPolicy::Hybrid)
                .with_checksums(2),
        )
        .unwrap();
        assert!(healed.success());
        assert_eq!(healed.checksum_reconstructions, 2);
        assert_eq!(healed.pair_wipes_survived, 1);
        assert!(healed.time.recovery_ns > 0, "reconstruction costs virtual time");
    }

    #[test]
    fn self_healing_respawns_at_the_boundary() {
        let s = CaqrSpec::new(Algo::SelfHealing, 4, 32, 16, 4)
            .with_verify(false)
            .with_schedule(CaqrKillSchedule::at(&[(1, 0, CaqrStage::Update)]));
        let r = replay(&s).unwrap();
        assert!(r.success());
        assert_eq!(r.respawns, 1);
        assert_eq!(r.dead, 0, "healed world ends at full size");
    }

    #[test]
    fn churn_kills_and_rejoins_ranks() {
        let sc = SimScenario {
            procs: 64,
            panels: 8,
            panel: 8,
            algo: Algo::SelfHealing,
            // ~1 death per rank per virtual second against ~1 ms
            // panels: raise the rate so deaths land inside the run.
            churn: super::super::ChurnModel {
                fail_rate: 2000.0,
                rejoin_ns: 200_000,
                ..Default::default()
            },
            policy: RecoveryPolicy::Hybrid,
            checksums: 8,
            ..Default::default()
        };
        let r = run_scenario(&sc).unwrap();
        assert!(r.failures > 0, "churn must strike at this rate: {r:?}");
        assert!(r.rejoins > 0 || r.respawns > 0, "the world must heal: {r:?}");
        let again = run_scenario(&sc).unwrap();
        assert_eq!(r, again, "churn runs are a pure function of the seed");
    }

    #[test]
    fn bursts_wipe_racks() {
        let sc = SimScenario {
            procs: 32,
            panels: 4,
            panel: 4,
            // ~20µs between wipes against a ~500µs run: the first
            // burst lands well inside the factorization.
            churn: super::super::ChurnModel {
                burst_rate: 50_000.0,
                rack: 8,
                ..Default::default()
            },
            ..Default::default()
        };
        let r = run_scenario(&sc).unwrap();
        assert!(r.bursts > 0, "burst rate of 5000/s must strike: {r:?}");
        assert!(
            r.failures >= 8 || r.failed_at.is_some(),
            "a burst kills a whole rack: {r:?}"
        );
    }

    #[test]
    fn network_latency_stretches_virtual_time_only() {
        let ideal = SimScenario { procs: 8, ..Default::default() };
        let slow = SimScenario {
            procs: 8,
            network: super::super::NetworkModel::Uniform {
                latency_ns: 1_000_000,
                jitter_ns: 0,
            },
            ..Default::default()
        };
        let a = run_scenario(&ideal).unwrap();
        let b = run_scenario(&slow).unwrap();
        assert!(b.virtual_ns > a.virtual_ns, "latency must stretch the clock");
        assert_eq!(b.time.network_ns, 16 * 1_000_000, "8 panels x 2 stage barriers x 1ms");
        assert_eq!(
            (a.failed_at, a.panels_completed, a.update_tasks),
            (b.failed_at, b.panels_completed, b.update_tasks),
            "the network must not change ladder outcomes"
        );
    }

    #[test]
    fn mega_world_runs_in_panel_bounded_work() {
        // 10^5 ranks: the whole point of the event-driven core.  No
        // churn, so the run processes O(panels) events regardless of P.
        let sc = SimScenario {
            procs: 100_000,
            panels: 16,
            panel: 8,
            ..Default::default()
        };
        let r = run_scenario(&sc).unwrap();
        assert!(r.success());
        assert_eq!(r.procs, 100_000);
        assert_eq!(r.panels_completed, 16);
        assert_eq!(r.events, 16 * 4, "4 events per panel, independent of P");
    }
}
