//! `sim` — the mega-scale discrete-event fault simulator.
//!
//! The thread-based executor ([`crate::caqr`]) runs real tasks on a
//! real worker pool: perfect for verifying numerics at P ∈ {4, 8},
//! hopeless for asking *"what fraction of 10⁶-rank runs survive a
//! 5%/s churn?"*.  This module answers that question by replaying the
//! CAQR panel walk and the `Replica → Checksum → Abort` recovery
//! ladder ([`crate::abft::RecoveryPolicy`]) as **events on a virtual
//! clock** — no matrices, no threads, no sleeps — so a fault campaign
//! at P = 10⁵–10⁶ ranks completes in seconds.
//!
//! The pieces:
//!
//! * [`EventHeap`] — binary heap keyed `(virtual time, sequence)`;
//!   the FIFO tie-break makes a run a pure function of
//!   `(scenario, seed)`;
//! * [`VirtualClock`] — monotonic simulated nanoseconds (time travel
//!   panics);
//! * [`NetworkModel`] — ideal / uniform-jitter / lossy-retransmit
//!   stage-barrier delays;
//! * [`ChurnModel`] — per-rank Poisson failures, rejoin after a
//!   delay, and correlated rack wipes generalizing
//!   [`crate::fault::PairWipeSchedule`];
//! * [`SimScenario`] — declarative TOML-subset campaign files
//!   (`repro simulate --scenario FILE`, examples in `rust/scenarios/`);
//! * [`replay`] / [`run_scenario`] — the runner, emitting a
//!   [`SimReport`] whose ladder counters carry the executor's exact
//!   semantics.
//!
//! ## The parity anchor
//!
//! What makes the extrapolation to 10⁶ ranks trustworthy: at small P
//! the simulator is not *approximately* the executor, it **is** the
//! executor's decision procedure.  [`replay`] on a [`CaqrSpec`] with
//! the executor's own kill schedule reproduces
//! [`Engine::run_caqr`](crate::engine::Engine::run_caqr)'s
//! survival/abort outcome and recovery counters exactly — pinned for
//! P ∈ {4, 8} across all three recovery policies in
//! `tests/integration_sim.rs`.
//!
//! [`CaqrSpec`]: crate::caqr::CaqrSpec

mod churn;
mod clock;
mod heap;
mod network;
mod runner;
mod scenario;

pub use churn::ChurnModel;
pub use clock::VirtualClock;
pub use heap::EventHeap;
pub use network::NetworkModel;
pub use runner::{SimBatchReport, SimReport, replay, run_scenario};
pub use scenario::{CostModel, SimScenario};

pub(crate) use runner::run_validated;
