//! Process churn and correlated failures: the failure *processes* the
//! simulator layers on top of (or instead of) explicit kill schedules.
//!
//! Three generators, all driven by one seeded RNG stream:
//!
//! * **independent churn** — every rank's lifetime is
//!   `Exponential(fail_rate)`, i.e. a Poisson failure process per rank
//!   (the paper's §III failure-rate semantics, versus its `f`-failures
//!   counting semantics);
//! * **rejoin** — a crashed rank re-enters the world `rejoin_ns` after
//!   its death (kill + rejoin, not just one-shot kills);
//! * **bursts** — whole *racks* of `rack` consecutive ranks are wiped
//!   together at `Exponential(burst_rate)` intervals.  `rack = 2`
//!   recreates [`crate::fault::PairWipeSchedule`]'s buddy-pair wipe at
//!   a random time; larger racks model correlated hardware failures.

use crate::error::{Error, Result};
use crate::util::Rng;

/// Exponential draws are clamped below u64 range so a tiny rate's
/// multi-century lifetime cannot overflow the nanosecond clock.
const MAX_NS: f64 = (u64::MAX / 4) as f64;

/// Churn parameters for one simulated run (all rates are *per second
/// of virtual time*; zero disables the corresponding process).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnModel {
    /// Independent failure rate per rank per virtual second.
    pub fail_rate: f64,
    /// Virtual nanoseconds after a churn death before the rank
    /// rejoins (0 = crashed ranks never rejoin).
    pub rejoin_ns: u64,
    /// Rack-wipe rate per virtual second (whole world).
    pub burst_rate: f64,
    /// Ranks per rack (burst blast radius); 2 generalizes the buddy
    /// pair wipe.
    pub rack: usize,
}

impl Default for ChurnModel {
    fn default() -> Self {
        Self { fail_rate: 0.0, rejoin_ns: 0, burst_rate: 0.0, rack: 2 }
    }
}

impl ChurnModel {
    /// Does any rank ever die from independent churn?
    pub fn churns(&self) -> bool {
        self.fail_rate > 0.0
    }

    /// Are correlated rack wipes scheduled?
    pub fn bursts(&self) -> bool {
        self.burst_rate > 0.0
    }

    /// Check parameters: rates must be finite and non-negative, the
    /// rack must hold at least one rank when bursts are armed.
    pub fn validate(&self) -> Result<()> {
        for (name, rate) in [("fail-rate", self.fail_rate), ("burst-rate", self.burst_rate)] {
            if !rate.is_finite() || rate < 0.0 {
                return Err(Error::Config(format!(
                    "churn {name} must be finite and >= 0, got {rate}"
                )));
            }
        }
        if self.bursts() && self.rack == 0 {
            return Err(Error::Config("burst rack must hold at least one rank".into()));
        }
        Ok(())
    }

    /// Draw one rank lifetime in virtual nanoseconds
    /// (`Exponential(fail_rate)`).  Only meaningful when
    /// [`churns`](Self::churns).
    pub fn lifetime_ns(&self, rng: &mut Rng) -> u64 {
        (rng.exponential(self.fail_rate) * 1e9).min(MAX_NS) as u64
    }

    /// Draw the gap to the next rack wipe in virtual nanoseconds
    /// (`Exponential(burst_rate)`).  Only meaningful when
    /// [`bursts`](Self::bursts).
    pub fn burst_gap_ns(&self, rng: &mut Rng) -> u64 {
        (rng.exponential(self.burst_rate) * 1e9).min(MAX_NS) as u64
    }

    /// Number of racks a `procs`-rank world partitions into.
    pub fn racks(&self, procs: usize) -> usize {
        procs.div_ceil(self.rack.max(1))
    }

    /// The rank range `[lo, hi)` of rack `g` (the last rack may be
    /// ragged).
    pub fn rack_range(&self, g: usize, procs: usize) -> (usize, usize) {
        let lo = g * self.rack;
        (lo, (lo + self.rack).min(procs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_inert() {
        let c = ChurnModel::default();
        assert!(!c.churns());
        assert!(!c.bursts());
        assert!(c.validate().is_ok());
    }

    #[test]
    fn lifetimes_follow_the_rate() {
        let c = ChurnModel { fail_rate: 2.0, ..Default::default() };
        let mut rng = Rng::new(9);
        let n = 20_000;
        let mean_s: f64 =
            (0..n).map(|_| c.lifetime_ns(&mut rng) as f64 / 1e9).sum::<f64>() / n as f64;
        assert!((mean_s - 0.5).abs() < 0.02, "mean lifetime {mean_s}s, expected 0.5s");
    }

    #[test]
    fn tiny_rates_clamp_instead_of_overflowing() {
        let c = ChurnModel { fail_rate: 1e-15, ..Default::default() };
        let mut rng = Rng::new(10);
        for _ in 0..100 {
            assert!(c.lifetime_ns(&mut rng) <= u64::MAX / 4);
        }
    }

    #[test]
    fn rack_partition_covers_the_world() {
        let c = ChurnModel { rack: 64, ..Default::default() };
        assert_eq!(c.racks(1000), 16);
        assert_eq!(c.rack_range(0, 1000), (0, 64));
        assert_eq!(c.rack_range(15, 1000), (960, 1000), "last rack is ragged");
        let pair = ChurnModel { rack: 2, ..Default::default() };
        assert_eq!(pair.rack_range(1, 8), (2, 4), "rack=2 is the buddy pair");
    }

    #[test]
    fn validation_rejects_bad_parameters() {
        assert!(ChurnModel { fail_rate: -1.0, ..Default::default() }.validate().is_err());
        assert!(ChurnModel { burst_rate: f64::NAN, ..Default::default() }.validate().is_err());
        assert!(
            ChurnModel { burst_rate: 1.0, rack: 0, ..Default::default() }.validate().is_err(),
            "armed bursts need a non-empty rack"
        );
        assert!(ChurnModel { burst_rate: 0.0, rack: 0, ..Default::default() }.validate().is_ok());
    }
}
