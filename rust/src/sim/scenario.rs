//! Declarative fault-campaign scenarios: the TOML-subset files behind
//! `repro simulate --scenario FILE`.
//!
//! A scenario pins everything a simulated campaign needs — world size,
//! panel plan, recovery policy, cost/network/churn models, explicit
//! kills, sample count and seed — so a campaign is reproducible from
//! one committed file (see `rust/scenarios/` for examples).  Format:
//!
//! ```text
//! name = "pair-wipe-demo"
//! procs = 1024
//! panels = 16          # plan is (panels·panel) square, block width `panel`
//! panel = 8
//! algo = "self-healing"
//! policy = "hybrid"
//! checksums = 4
//! samples = 100
//! seed = 42
//!
//! [costs]
//! factor-us = 100      # virtual cost of one panel factor stage
//! update-us = 25       # virtual cost of one update-task slot
//!
//! [network]
//! model = "lossy"      # ideal | uniform | lossy
//! latency-us = 10
//! jitter-us = 2
//! loss = 0.01
//! retransmit-us = 50
//!
//! [churn]
//! fail-rate = 0.05     # deaths per rank per virtual second
//! rejoin-ms = 400      # crashed ranks rejoin (0 = never)
//! burst-rate = 0.2     # rack wipes per virtual second
//! rack = 64            # ranks per rack (2 = buddy-pair wipe)
//!
//! [kills]
//! update = [[2, 0], [3, 0]]   # explicit (rank, panel) update-stage kills
//! factor = [[1, 1]]           # explicit (rank, panel) factor-stage kills
//! ```
//!
//! Parsing reuses [`crate::util::kv::Doc`] (the crate's `toml`
//! replacement) and rejects unknown keys, like [`crate::config`].

use std::path::Path;

use crate::abft::RecoveryPolicy;
use crate::error::{Error, Result};
use crate::fault::CaqrStage;
use crate::tsqr::{Algo, PanelPlan};
use crate::util::kv::Doc;
use crate::util::derive_seed;

use super::churn::ChurnModel;
use super::network::NetworkModel;

/// Virtual cost of one stage of work (what the simulator charges to
/// [`crate::metrics::VirtualTimeBreakdown::compute_ns`] per stage).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CostModel {
    /// Virtual nanoseconds of one panel-factor stage.
    pub factor_ns: u64,
    /// Virtual nanoseconds of one update-task pool slot.
    pub update_ns: u64,
}

impl Default for CostModel {
    fn default() -> Self {
        // 100µs factor / 25µs update slot: panel-factor-bound, the
        // regime the lookahead scheduler was built for.
        Self { factor_ns: 100_000, update_ns: 25_000 }
    }
}

/// Keys accepted in a scenario file (anything else is a config error —
/// catches typos the way serde's `deny_unknown_fields` would).
const KNOWN_KEYS: &[&str] = &[
    "name",
    "procs",
    "panels",
    "panel",
    "algo",
    "policy",
    "checksums",
    "samples",
    "seed",
    "costs.factor-us",
    "costs.update-us",
    "network.model",
    "network.latency-us",
    "network.jitter-us",
    "network.loss",
    "network.retransmit-us",
    "churn.fail-rate",
    "churn.rejoin-ms",
    "churn.burst-rate",
    "churn.rack",
    "kills.update",
    "kills.factor",
];

/// One declarative simulation campaign.
#[derive(Debug, Clone)]
pub struct SimScenario {
    /// Display name (reports and logs).
    pub name: String,
    /// Simulated world size — the axis the simulator exists for
    /// (10⁵–10⁶ ranks are routine).
    pub procs: usize,
    /// Panels in the plan (the factorization is `(panels·panel)`²).
    pub panels: usize,
    /// Block-column width.
    pub panel: usize,
    /// Failure semantics ([`Algo::Redundant`] or [`Algo::SelfHealing`]).
    pub algo: Algo,
    /// Recovery ladder ([`RecoveryPolicy`]).
    pub policy: RecoveryPolicy,
    /// Checksum blocks per panel stage (consumed only when the policy
    /// uses checksums, mirroring [`crate::caqr::CaqrSpec`]).
    pub checksums: usize,
    /// Monte-Carlo samples the campaign runs.
    pub samples: u64,
    /// Base seed; sample `i` runs under
    /// [`derive_seed`]`(seed, i)`.
    pub seed: u64,
    /// Virtual stage costs.
    pub costs: CostModel,
    /// Network model.
    pub network: NetworkModel,
    /// Churn / burst model.
    pub churn: ChurnModel,
    /// Explicit `(rank, panel, stage)` kills, fired exactly like a
    /// [`crate::fault::CaqrKillSchedule`].
    pub kills: Vec<(usize, usize, CaqrStage)>,
}

impl Default for SimScenario {
    fn default() -> Self {
        Self {
            name: "unnamed".into(),
            procs: 8,
            panels: 8,
            panel: 8,
            algo: Algo::Redundant,
            policy: RecoveryPolicy::Replica,
            checksums: 0,
            samples: 1,
            seed: 42,
            costs: CostModel::default(),
            network: NetworkModel::default(),
            churn: ChurnModel::default(),
            kills: Vec::new(),
        }
    }
}

impl SimScenario {
    /// Parse a scenario from file text.
    pub fn from_text(text: &str) -> Result<Self> {
        let doc = Doc::parse(text)?;
        for k in doc.keys() {
            if !KNOWN_KEYS.contains(&k) {
                return Err(Error::Config(format!("unknown scenario key '{k}'")));
            }
        }
        let mut sc = SimScenario::default();
        if let Some(v) = doc.str_of("name") {
            sc.name = v.to_string();
        }
        if let Some(v) = doc.usize_of("procs") {
            sc.procs = v;
        }
        if let Some(v) = doc.usize_of("panels") {
            sc.panels = v;
        }
        if let Some(v) = doc.usize_of("panel") {
            sc.panel = v;
        }
        if let Some(v) = doc.str_of("algo") {
            sc.algo = v.parse()?;
        }
        if let Some(v) = doc.str_of("policy") {
            sc.policy = v.parse()?;
        }
        if let Some(v) = doc.usize_of("checksums") {
            sc.checksums = v;
        }
        if let Some(v) = doc.u64_of("samples") {
            sc.samples = v;
        }
        if let Some(v) = doc.u64_of("seed") {
            sc.seed = v;
        }
        if let Some(v) = doc.usize_of("costs.factor-us") {
            sc.costs.factor_ns = (v as u64) * 1_000;
        }
        if let Some(v) = doc.usize_of("costs.update-us") {
            sc.costs.update_ns = (v as u64) * 1_000;
        }
        sc.network = parse_network(&doc)?;
        if let Some(v) = doc.f64_of("churn.fail-rate") {
            sc.churn.fail_rate = v;
        }
        if let Some(v) = doc.usize_of("churn.rejoin-ms") {
            sc.churn.rejoin_ns = (v as u64) * 1_000_000;
        }
        if let Some(v) = doc.f64_of("churn.burst-rate") {
            sc.churn.burst_rate = v;
        }
        if let Some(v) = doc.usize_of("churn.rack") {
            sc.churn.rack = v;
        }
        for (key, stage) in [("kills.update", CaqrStage::Update), ("kills.factor", CaqrStage::Factor)]
        {
            if doc.get(key).is_some() {
                let pairs = doc.pairs_of(key).ok_or_else(|| {
                    Error::Config(format!("{key} must be [[rank, panel], ...]"))
                })?;
                sc.kills.extend(pairs.into_iter().map(|(r, k)| (r, k as usize, stage)));
            }
        }
        sc.validate()?;
        Ok(sc)
    }

    /// Load a scenario from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Config(format!("cannot read scenario {}: {e}", path.display()))
        })?;
        Self::from_text(&text)
    }

    /// Validate shapes, model parameters, and kill-entry ranges
    /// (mirrors [`crate::caqr::CaqrSpec::validate`]).
    pub fn validate(&self) -> Result<()> {
        if self.procs == 0 {
            return Err(Error::Config("procs must be >= 1".into()));
        }
        if self.procs > 1 && self.procs % 2 != 0 {
            return Err(Error::Config(format!(
                "the replica pairing needs an even world (or 1), got procs = {}",
                self.procs
            )));
        }
        if self.panels == 0 || self.panel == 0 {
            return Err(Error::Config("panels and panel width must be >= 1".into()));
        }
        if self.samples == 0 {
            return Err(Error::Config("samples must be >= 1".into()));
        }
        if self.checksums > 0 {
            if self.procs < 2 {
                return Err(Error::Config("checksums need procs >= 2".into()));
            }
            if self.checksums > self.procs / 2 {
                return Err(Error::Config(format!(
                    "at most procs/2 checksum blocks fit distinct holder pairs: \
                     checksums = {} > {}",
                    self.checksums,
                    self.procs / 2
                )));
            }
        }
        match self.algo {
            Algo::Redundant | Algo::SelfHealing => {}
            other => {
                return Err(Error::Config(format!(
                    "the simulator replays redundant or self-healing semantics, not {}",
                    other.name()
                )));
            }
        }
        self.network.validate()?;
        self.churn.validate()?;
        for &(rank, panel, stage) in &self.kills {
            if rank >= self.procs {
                return Err(Error::Config(format!(
                    "kill ({rank}, {panel}, {}) names rank {rank} outside the \
                     {}-rank world",
                    stage.name(),
                    self.procs
                )));
            }
            if panel >= self.panels {
                return Err(Error::Config(format!(
                    "kill ({rank}, {panel}, {}) names panel {panel} but the scenario \
                     has only {} panels",
                    stage.name(),
                    self.panels
                )));
            }
        }
        Ok(())
    }

    /// The panel plan the runner replays: a `(panels·panel)`-square
    /// matrix in `panel`-column blocks over `procs` ranks — same shape
    /// rules as [`crate::caqr::CaqrSpec::plan`], matrix-free.
    pub fn plan(&self) -> PanelPlan {
        let n = self.panels * self.panel;
        PanelPlan::new(n, n, self.panel, self.procs)
    }

    /// Checksum blocks the ladder actually arms (0 unless the policy
    /// uses checksums — mirroring the executor's resolution).
    pub fn armed_checksums(&self) -> usize {
        if self.policy.uses_checksums() { self.checksums } else { 0 }
    }

    /// Sample `i` of the campaign: the same scenario, single-sample,
    /// reseeded via [`derive_seed`].
    pub fn sample(&self, i: u64) -> SimScenario {
        SimScenario { seed: derive_seed(self.seed, i), samples: 1, ..self.clone() }
    }
}

fn parse_network(doc: &Doc) -> Result<NetworkModel> {
    let latency_ns = doc.usize_of("network.latency-us").unwrap_or(0) as u64 * 1_000;
    let jitter_ns = doc.usize_of("network.jitter-us").unwrap_or(0) as u64 * 1_000;
    match doc.str_of("network.model").unwrap_or("ideal") {
        "ideal" => Ok(NetworkModel::Ideal),
        "uniform" => Ok(NetworkModel::Uniform { latency_ns, jitter_ns }),
        "lossy" => Ok(NetworkModel::Lossy {
            latency_ns,
            jitter_ns,
            loss: doc.f64_of("network.loss").unwrap_or(0.0),
            retransmit_ns: doc.usize_of("network.retransmit-us").unwrap_or(0) as u64 * 1_000,
        }),
        other => Err(Error::Config(format!(
            "unknown network model '{other}' (ideal|uniform|lossy)"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = r#"
        name = "full"
        procs = 1024
        panels = 16
        panel = 8
        algo = "self-healing"
        policy = "hybrid"
        checksums = 4
        samples = 100
        seed = 7
        [costs]
        factor-us = 50
        update-us = 10
        [network]
        model = "lossy"
        latency-us = 10
        jitter-us = 2
        loss = 0.01
        retransmit-us = 50
        [churn]
        fail-rate = 0.05
        rejoin-ms = 400
        burst-rate = 0.2
        rack = 64
        [kills]
        update = [[2, 0], [3, 0]]
        factor = [[1, 1]]
    "#;

    #[test]
    fn parses_every_section() {
        let sc = SimScenario::from_text(FULL).unwrap();
        assert_eq!(sc.name, "full");
        assert_eq!(sc.procs, 1024);
        assert_eq!((sc.panels, sc.panel), (16, 8));
        assert_eq!(sc.algo, Algo::SelfHealing);
        assert_eq!(sc.policy, RecoveryPolicy::Hybrid);
        assert_eq!(sc.checksums, 4);
        assert_eq!((sc.samples, sc.seed), (100, 7));
        assert_eq!(sc.costs, CostModel { factor_ns: 50_000, update_ns: 10_000 });
        assert_eq!(
            sc.network,
            NetworkModel::Lossy {
                latency_ns: 10_000,
                jitter_ns: 2_000,
                loss: 0.01,
                retransmit_ns: 50_000
            }
        );
        assert_eq!(sc.churn.fail_rate, 0.05);
        assert_eq!(sc.churn.rejoin_ns, 400_000_000);
        assert_eq!(sc.churn.rack, 64);
        assert_eq!(
            sc.kills,
            vec![
                (2, 0, CaqrStage::Update),
                (3, 0, CaqrStage::Update),
                (1, 1, CaqrStage::Factor)
            ]
        );
        assert_eq!(sc.plan().panels(), 16);
        assert_eq!(sc.armed_checksums(), 4);
    }

    #[test]
    fn defaults_fill_a_minimal_file() {
        let sc = SimScenario::from_text("procs = 4\n").unwrap();
        assert_eq!(sc.procs, 4);
        assert_eq!(sc.network, NetworkModel::Ideal);
        assert!(!sc.churn.churns());
        assert!(sc.kills.is_empty());
        assert_eq!(sc.armed_checksums(), 0, "replica policy arms nothing");
    }

    #[test]
    fn unknown_keys_and_models_rejected() {
        assert!(SimScenario::from_text("bogus = 1\n").is_err());
        assert!(SimScenario::from_text("[network]\nmodel = \"carrier-pigeon\"\n").is_err());
        assert!(SimScenario::from_text("[kills]\nupdate = [[1, 2, 3]]\n").is_err(), "triples");
    }

    #[test]
    fn validation_rejects_bad_shapes_and_ranges() {
        assert!(SimScenario::from_text("procs = 0\n").is_err());
        assert!(SimScenario::from_text("procs = 3\n").is_err(), "odd world");
        assert!(SimScenario::from_text("samples = 0\n").is_err());
        assert!(SimScenario::from_text("checksums = 5\n").is_err(), "over procs/2");
        assert!(SimScenario::from_text("algo = \"baseline\"\n").is_err());
        assert!(
            SimScenario::from_text("procs = 4\npanels = 2\n[kills]\nupdate = [[9, 0]]\n").is_err(),
            "rank out of range"
        );
        assert!(
            SimScenario::from_text("procs = 4\npanels = 2\n[kills]\nupdate = [[1, 5]]\n").is_err(),
            "panel out of range"
        );
        assert!(SimScenario::from_text("[network]\nmodel = \"lossy\"\nloss = 1.5\n").is_err());
        assert!(SimScenario::from_text("[churn]\nfail-rate = -2.0\n").is_err());
    }

    #[test]
    fn samples_reseed_through_derive_seed() {
        let sc = SimScenario::from_text("procs = 4\nseed = 11\nsamples = 3\n").unwrap();
        let s0 = sc.sample(0);
        let s1 = sc.sample(1);
        assert_eq!(s0.samples, 1);
        assert_eq!(s0.seed, derive_seed(11, 0));
        assert_ne!(s0.seed, s1.seed);
        assert_eq!(s0.procs, sc.procs, "everything but the seed carries over");
    }
}
