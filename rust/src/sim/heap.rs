//! The discrete-event priority queue: a binary heap keyed by virtual
//! time with a **deterministic tie-break**.
//!
//! Two events scheduled for the same virtual nanosecond pop in the
//! order they were pushed (an insertion sequence number is the
//! secondary key).  That single rule is what makes every simulated run
//! a pure function of `(scenario, seed)`: the heap never consults the
//! payload, wall clock, or allocation order, so replaying a scenario
//! replays the exact event interleaving — the invariant the small-P
//! parity suite (`tests/integration_sim.rs`) rests on.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One scheduled event: `(time, seq)` is the total order, `payload`
/// is opaque cargo.
struct Entry<T> {
    time: u64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    /// Reversed on purpose: `BinaryHeap` is a max-heap, and the
    /// "greatest" entry must be the earliest `(time, seq)`.
    fn cmp(&self, other: &Self) -> Ordering {
        (other.time, other.seq).cmp(&(self.time, self.seq))
    }
}

/// Min-heap of timestamped events with FIFO tie-breaking.
pub struct EventHeap<T> {
    heap: BinaryHeap<Entry<T>>,
    next_seq: u64,
}

impl<T> Default for EventHeap<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventHeap<T> {
    /// An empty heap.
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), next_seq: 0 }
    }

    /// Schedule `payload` at virtual time `time` (nanoseconds).
    /// Events at equal times pop in push order.
    pub fn push(&mut self, time: u64, payload: T) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Entry { time, seq, payload });
    }

    /// Pop the earliest event, `None` when drained.
    pub fn pop(&mut self) -> Option<(u64, T)> {
        self.heap.pop().map(|e| (e.time, e.payload))
    }

    /// Virtual time of the next event without popping it.
    pub fn peek_time(&self) -> Option<u64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Events currently scheduled.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Is the heap drained?
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total events ever pushed (scheduled, whether or not processed).
    pub fn scheduled(&self) -> u64 {
        self.next_seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut h = EventHeap::new();
        h.push(30, "c");
        h.push(10, "a");
        h.push(20, "b");
        assert_eq!(h.peek_time(), Some(10));
        assert_eq!(h.pop(), Some((10, "a")));
        assert_eq!(h.pop(), Some((20, "b")));
        assert_eq!(h.pop(), Some((30, "c")));
        assert_eq!(h.pop(), None);
        assert!(h.is_empty());
        assert_eq!(h.scheduled(), 3);
    }

    #[test]
    fn equal_times_pop_fifo() {
        let mut h = EventHeap::new();
        for i in 0..100 {
            h.push(7, i);
        }
        h.push(3, 1000);
        assert_eq!(h.pop(), Some((3, 1000)));
        for i in 0..100 {
            assert_eq!(h.pop(), Some((7, i)), "tie-break must be insertion order");
        }
    }

    #[test]
    fn interleaved_push_pop_keeps_order() {
        let mut h = EventHeap::new();
        h.push(5, 'x');
        h.push(1, 'y');
        assert_eq!(h.pop(), Some((1, 'y')));
        h.push(2, 'z');
        h.push(5, 'w');
        assert_eq!(h.pop(), Some((2, 'z')));
        assert_eq!(h.pop(), Some((5, 'x')), "earlier-pushed 5 first");
        assert_eq!(h.pop(), Some((5, 'w')));
        assert_eq!(h.len(), 0);
    }
}
