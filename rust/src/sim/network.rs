//! Pluggable network models: how long a stage's communication takes in
//! virtual time.
//!
//! The thread-based executor has no network at all (tasks share
//! memory); the simulator models one delay draw per stage barrier —
//! the critical-path message of the stage's reduction/broadcast.  The
//! models only stretch virtual time: they never change *who* is alive
//! at a stage boundary relative to the scheduled kills, so the small-P
//! parity pin holds under every model (parity scenarios use
//! [`NetworkModel::Ideal`], where the draw is identically zero).

use crate::error::{Error, Result};
use crate::util::Rng;

/// Network latency model for one simulated run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NetworkModel {
    /// Zero-latency network (the thread-based executor's semantics).
    Ideal,
    /// Fixed latency plus uniform jitter in `[0, jitter_ns]`.
    Uniform {
        /// Base latency per stage barrier, nanoseconds.
        latency_ns: u64,
        /// Maximum additional uniform jitter, nanoseconds.
        jitter_ns: u64,
    },
    /// [`NetworkModel::Uniform`] plus packet loss: each retransmit
    /// round (probability `loss`, geometric) costs `retransmit_ns`.
    Lossy {
        /// Base latency per stage barrier, nanoseconds.
        latency_ns: u64,
        /// Maximum additional uniform jitter, nanoseconds.
        jitter_ns: u64,
        /// Per-message loss probability in `[0, 1)`.
        loss: f64,
        /// Timeout-and-retransmit penalty per lost round, nanoseconds.
        retransmit_ns: u64,
    },
}

/// Retransmit rounds are capped so a `loss` close to 1 cannot spin the
/// geometric draw unboundedly (64 rounds ≈ a dead link; the virtual
/// time cost is already enormous by then).
const MAX_RETRANSMITS: u32 = 64;

impl Default for NetworkModel {
    fn default() -> Self {
        NetworkModel::Ideal
    }
}

impl NetworkModel {
    /// Stable name (`ideal` / `uniform` / `lossy`).
    pub fn name(&self) -> &'static str {
        match self {
            NetworkModel::Ideal => "ideal",
            NetworkModel::Uniform { .. } => "uniform",
            NetworkModel::Lossy { .. } => "lossy",
        }
    }

    /// Check model parameters (loss must be a probability below 1).
    pub fn validate(&self) -> Result<()> {
        if let NetworkModel::Lossy { loss, .. } = self {
            if !(0.0..1.0).contains(loss) || !loss.is_finite() {
                return Err(Error::Config(format!(
                    "network loss must be in [0, 1), got {loss}"
                )));
            }
        }
        Ok(())
    }

    /// Draw one stage-barrier delay in virtual nanoseconds.
    pub fn delay(&self, rng: &mut Rng) -> u64 {
        match *self {
            NetworkModel::Ideal => 0,
            NetworkModel::Uniform { latency_ns, jitter_ns } => {
                latency_ns + jitter(rng, jitter_ns)
            }
            NetworkModel::Lossy { latency_ns, jitter_ns, loss, retransmit_ns } => {
                let mut d = latency_ns + jitter(rng, jitter_ns);
                let mut rounds = 0;
                while rounds < MAX_RETRANSMITS && rng.bool(loss) {
                    d += retransmit_ns;
                    rounds += 1;
                }
                d
            }
        }
    }
}

fn jitter(rng: &mut Rng, jitter_ns: u64) -> u64 {
    if jitter_ns == 0 { 0 } else { rng.range_u64(0, jitter_ns + 1) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_is_free_and_deterministic() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(NetworkModel::Ideal.delay(&mut rng), 0);
        }
        assert_eq!(NetworkModel::default(), NetworkModel::Ideal);
    }

    #[test]
    fn uniform_stays_in_band() {
        let m = NetworkModel::Uniform { latency_ns: 100, jitter_ns: 50 };
        let mut rng = Rng::new(2);
        for _ in 0..1000 {
            let d = m.delay(&mut rng);
            assert!((100..=150).contains(&d), "delay {d} outside [100, 150]");
        }
        let fixed = NetworkModel::Uniform { latency_ns: 7, jitter_ns: 0 };
        assert_eq!(fixed.delay(&mut rng), 7, "zero jitter draws nothing");
    }

    #[test]
    fn lossy_adds_retransmits_and_caps() {
        let m = NetworkModel::Lossy {
            latency_ns: 10,
            jitter_ns: 0,
            loss: 0.5,
            retransmit_ns: 100,
        };
        let mut rng = Rng::new(3);
        let n = 10_000;
        let total: u64 = (0..n).map(|_| m.delay(&mut rng)).sum();
        // E[delay] = 10 + 100 · loss/(1−loss) = 110.
        let mean = total as f64 / n as f64;
        assert!((mean - 110.0).abs() < 10.0, "mean {mean}");
        // Even a near-dead link terminates.
        let dead = NetworkModel::Lossy {
            latency_ns: 0,
            jitter_ns: 0,
            loss: 0.999999,
            retransmit_ns: 1,
        };
        assert!(dead.delay(&mut rng) <= MAX_RETRANSMITS as u64);
    }

    #[test]
    fn validation_rejects_bad_loss() {
        assert!(NetworkModel::Ideal.validate().is_ok());
        let ok = NetworkModel::Lossy { latency_ns: 1, jitter_ns: 1, loss: 0.3, retransmit_ns: 1 };
        assert!(ok.validate().is_ok());
        for loss in [1.0, 1.5, -0.1, f64::NAN] {
            let bad = NetworkModel::Lossy { latency_ns: 1, jitter_ns: 1, loss, retransmit_ns: 1 };
            assert!(bad.validate().is_err(), "loss {loss} must be rejected");
        }
    }

    #[test]
    fn names() {
        assert_eq!(NetworkModel::Ideal.name(), "ideal");
        assert_eq!(NetworkModel::Uniform { latency_ns: 0, jitter_ns: 0 }.name(), "uniform");
    }
}
