//! The virtual clock: monotonic simulated time, no real sleeps.
//!
//! The runner advances the clock to each popped event's timestamp;
//! time moves only through [`VirtualClock::advance_to`], which
//! enforces the simulator's core invariant — **virtual time never
//! runs backwards** (the event heap's `(time, seq)` order makes every
//! advance non-decreasing; a violation is a scheduling bug and panics
//! immediately rather than silently corrupting the timeline).

/// Monotonic virtual time plus a processed-event counter.
#[derive(Debug, Clone, Copy, Default)]
pub struct VirtualClock {
    now_ns: u64,
    processed: u64,
}

impl VirtualClock {
    /// A clock at virtual time zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time in nanoseconds.
    pub fn now_ns(&self) -> u64 {
        self.now_ns
    }

    /// Events processed so far.
    pub fn events_processed(&self) -> u64 {
        self.processed
    }

    /// Advance to an event's timestamp and count the event.
    ///
    /// # Panics
    /// If `t` is earlier than the current virtual time (monotonicity
    /// violation — an event was scheduled in the past).
    pub fn advance_to(&mut self, t: u64) {
        assert!(
            t >= self.now_ns,
            "virtual clock must be monotonic: {} -> {t}",
            self.now_ns
        );
        self.now_ns = t;
        self.processed += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_and_counts() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now_ns(), 0);
        c.advance_to(10);
        c.advance_to(10); // equal times are fine (tie-broken events)
        c.advance_to(25);
        assert_eq!(c.now_ns(), 25);
        assert_eq!(c.events_processed(), 3);
    }

    #[test]
    #[should_panic(expected = "monotonic")]
    fn rejects_time_travel() {
        let mut c = VirtualClock::new();
        c.advance_to(10);
        c.advance_to(9);
    }
}
