//! Failure-model-adaptive recovery policy: pick the checksum count `c`
//! (and whether to arm checksums at all) from a *failure rate* instead
//! of a CLI flag.
//!
//! The question the adaptive policy answers is the one arXiv:0806.3121
//! poses for ABFT generally: given a world size, a panel plan, and a
//! measured per-rank failure rate, how much coded redundancy does this
//! run actually need?  PR 5's ladder made `c` a knob; this module makes
//! it a *derived quantity*:
//!
//! 1. Each CAQR stage (panel factor, trailing update) has a virtual
//!    duration from the simulator's [`CostModel`] — the same costs the
//!    `sim::` replay charges, so the model and its validator agree on
//!    the time axis.
//! 2. Deaths are Poisson: a stage of `t` seconds on `P` ranks at rate
//!    `λ_r` deaths/rank/second sees `f ~ Poisson(P·λ_r·t)` failures.
//! 3. A stage survives `f` failures under `c` checksums with
//!    probability [`closed_form::survival_with_checksums`] — at most
//!    `c` replica pairs fully wiped.
//! 4. Self-healing respawns at stage boundaries, so run survival is
//!    the *product* of independent per-stage survivals.
//!
//! [`AdaptivePolicy::choose`] then returns the smallest `c` whose
//! predicted run survival clears the target (default 99.9%):
//! replication-only when `c = 0` already suffices, `Hybrid` with the
//! derived `c` otherwise.  `tests/` pin the choice against an
//! independently-coded brute-force search over the same closed form,
//! and validate it empirically with `sim::` replay at 10⁵ ranks.
//!
//! Wired into the stack as [`crate::caqr::CaqrSpec::with_failure_model`]
//! and [`crate::engine::EngineBuilder::adaptive_policy`]; setting an
//! explicit `with_checksums(c)` alongside a failure model is a typed
//! [`crate::error::Error::KnobConflict`].
//!
//! [`CostModel`]: crate::sim::CostModel

use crate::abft::RecoveryPolicy;
use crate::analysis::closed_form;
use crate::sim::CostModel;

/// What the adaptive policy decided for one `(procs, panels)` plan.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PolicyChoice {
    /// The ladder to run (`Replica` when replication alone clears the
    /// target, `Hybrid` when checksums are needed).
    pub policy: RecoveryPolicy,
    /// Checksum blocks per stage (0 iff `policy` is `Replica`).
    pub checksums: usize,
    /// The closed-form run-survival probability of that choice.
    pub predicted_survival: f64,
}

/// A failure-rate model plus a survival target: the inputs from which
/// the recovery policy is *derived* rather than configured.
#[derive(Debug, Clone, Copy)]
pub struct AdaptivePolicy {
    /// Deaths per rank per virtual second (the same unit as
    /// `churn.fail-rate` in scenario files).
    pub rate: f64,
    /// Run-survival probability the chosen policy must clear.
    pub target: f64,
    /// Virtual stage costs (defaults to the simulator's defaults, so
    /// predictions and `sim::` replay share a clock).
    pub costs: CostModel,
}

impl AdaptivePolicy {
    /// Default target: three nines of run survival.
    pub const DEFAULT_TARGET: f64 = 0.999;

    /// A policy for `rate` deaths/rank/second with the default target
    /// and cost model.
    pub fn new(rate: f64) -> Self {
        Self { rate, target: Self::DEFAULT_TARGET, costs: CostModel::default() }
    }

    /// Override the survival target (must be a probability in (0, 1)).
    pub fn with_target(mut self, target: f64) -> Self {
        assert!(
            target > 0.0 && target < 1.0,
            "survival target must be in (0, 1), got {target}"
        );
        self.target = target;
        self
    }

    /// Override the virtual stage costs.
    pub fn with_costs(mut self, costs: CostModel) -> Self {
        self.costs = costs;
        self
    }

    /// Per-stage Poisson means for a `(procs, panels)` CAQR walk: one
    /// factor stage per panel plus one update stage per panel with
    /// trailing blocks, each `procs · rate · stage_seconds`.  Update
    /// stages charge `update_ns` per pool slot exactly like the
    /// simulator: `2·(panels−1−k)` replicated tasks over `procs` slots.
    fn stage_lambdas(&self, procs: usize, panels: usize) -> Vec<f64> {
        let per_ns = procs as f64 * self.rate * 1e-9;
        let mut lambdas = Vec::with_capacity(2 * panels);
        for k in 0..panels {
            lambdas.push(per_ns * self.costs.factor_ns as f64);
            let tasks = 2 * (panels - 1 - k);
            if tasks > 0 {
                let slots = tasks.div_ceil(procs) as u64;
                lambdas.push(per_ns * (self.costs.update_ns * slots) as f64);
            }
        }
        lambdas
    }

    /// Closed-form probability that the whole `(procs, panels)` run
    /// survives under `c` checksum blocks: the product over stages of
    /// the Poisson-mixed [`closed_form::survival_with_checksums`].
    pub fn predicted_survival(&self, procs: usize, panels: usize, c: usize) -> f64 {
        if procs < 2 || self.rate <= 0.0 {
            return 1.0;
        }
        self.stage_lambdas(procs, panels)
            .into_iter()
            .map(|lambda| stage_survival(procs, lambda, c))
            .product()
    }

    /// Pick the cheapest ladder clearing the target: `Replica` if
    /// replication alone does, else `Hybrid` with the smallest
    /// sufficient `c` (capped at `procs/2`, the most distinct holder
    /// pairs a stage can seat).  The search stops early once extra
    /// checksums stop buying survival — at that point the residual risk
    /// is whole-world annihilation, which no `c` fixes.
    pub fn choose(&self, procs: usize, panels: usize) -> PolicyChoice {
        if procs < 2 || self.rate <= 0.0 {
            return PolicyChoice {
                policy: RecoveryPolicy::Replica,
                checksums: 0,
                predicted_survival: 1.0,
            };
        }
        let replication = self.predicted_survival(procs, panels, 0);
        if replication >= self.target {
            return PolicyChoice {
                policy: RecoveryPolicy::Replica,
                checksums: 0,
                predicted_survival: replication,
            };
        }
        let cap = procs / 2;
        let mut best = (1, replication);
        for c in 1..=cap {
            let s = self.predicted_survival(procs, panels, c);
            if s >= self.target {
                return PolicyChoice {
                    policy: RecoveryPolicy::Hybrid,
                    checksums: c,
                    predicted_survival: s,
                };
            }
            if s - best.1 < 1e-12 && c > 1 {
                break; // saturated below target: more coding buys nothing
            }
            best = (c, s);
        }
        PolicyChoice {
            policy: RecoveryPolicy::Hybrid,
            checksums: best.0,
            predicted_survival: best.1,
        }
    }
}

/// P(one stage survives | deaths ~ Poisson(λ), `c` checksum blocks):
/// Σ_f pmf(f; λ) · survival_with_checksums(procs, f, c), with the pmf
/// walked in log space (λ can be in the hundreds at 10⁵ ranks, where
/// `e^{−λ}` underflows) and the tail beyond 12 nines of mass charged
/// at its first term's survival — a pessimistic cut, since survival is
/// non-increasing in `f`.
fn stage_survival(procs: usize, lambda: f64, c: usize) -> f64 {
    if lambda <= 0.0 {
        return 1.0;
    }
    let ln_lambda = lambda.ln();
    let mut ln_pmf = -lambda; // ln P(f = 0)
    let mut acc = 0.0f64;
    let mut mass = 0.0f64;
    let mut f = 0usize;
    loop {
        let p = ln_pmf.exp();
        acc += p * closed_form::survival_with_checksums(procs, f, c);
        mass += p;
        // Past the mode the pmf only shrinks; stop once the tail is
        // negligible or every rank is already dead (survival constant
        // beyond f = procs — the distribution clamps).
        if (mass >= 1.0 - 1e-12 && f as f64 >= lambda) || f >= procs {
            break;
        }
        f += 1;
        ln_pmf += ln_lambda - (f as f64).ln();
    }
    let tail = (1.0 - mass).max(0.0);
    (acc + tail * closed_form::survival_with_checksums(procs, f, c)).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineBuilder;
    use crate::sim::SimScenario;
    use crate::tsqr::Algo;

    /// Independent brute force over the same closed form: a plain
    /// fixed-window Poisson sum (no log-space walk, no early exit) and
    /// a linear scan for the smallest sufficient `c`.  Structurally
    /// different from `choose()` on purpose — agreement pins both.
    fn brute_force_optimum(procs: usize, panels: usize, rate: f64, target: f64) -> (usize, f64) {
        let policy = AdaptivePolicy::new(rate); // only for stage_lambdas
        let lambdas = policy.stage_lambdas(procs, panels);
        let survival_at = |c: usize| -> f64 {
            lambdas
                .iter()
                .map(|&lambda| {
                    if lambda <= 0.0 {
                        return 1.0;
                    }
                    let fmax = procs.min((lambda + 20.0 * lambda.sqrt()) as usize + 20);
                    let mut s = 0.0;
                    let mut mass = 0.0;
                    for f in 0..=fmax {
                        let ln_p = f as f64 * lambda.ln()
                            - lambda
                            - (1..=f).map(|i| (i as f64).ln()).sum::<f64>();
                        let p = ln_p.exp();
                        mass += p;
                        s += p * closed_form::survival_with_checksums(procs, f, c);
                    }
                    s + (1.0 - mass).max(0.0)
                        * closed_form::survival_with_checksums(procs, procs, c)
                })
                .product()
        };
        for c in 0..=procs / 2 {
            let s = survival_at(c);
            if s >= target {
                return (c, s);
            }
        }
        (procs / 2, survival_at(procs / 2))
    }

    #[test]
    fn zero_or_negative_rate_keeps_plain_replication() {
        for rate in [0.0, -1.0] {
            let choice = AdaptivePolicy::new(rate).choose(64, 8);
            assert_eq!(choice.policy, RecoveryPolicy::Replica);
            assert_eq!(choice.checksums, 0);
            assert_eq!(choice.predicted_survival, 1.0);
        }
    }

    #[test]
    fn tiny_rate_clears_target_without_checksums() {
        // 1e-3 deaths/rank/s over a sub-millisecond virtual run: the
        // expected death count is ~1e-4, replication is plenty.
        let choice = AdaptivePolicy::new(1e-3).choose(16, 4);
        assert_eq!(choice.policy, RecoveryPolicy::Replica);
        assert_eq!(choice.checksums, 0);
        assert!(choice.predicted_survival > 0.999, "{}", choice.predicted_survival);
    }

    #[test]
    fn survival_is_monotone_in_c_and_rate() {
        let procs = 64;
        let panels = 6;
        let policy = AdaptivePolicy::new(50.0);
        let mut prev = 0.0;
        for c in 0..=8 {
            let s = policy.predicted_survival(procs, panels, c);
            assert!(s >= prev - 1e-12, "c={c}: {s} < {prev}");
            prev = s;
        }
        // And decreasing in rate at fixed c.
        let lo = AdaptivePolicy::new(5.0).predicted_survival(procs, panels, 1);
        let hi = AdaptivePolicy::new(500.0).predicted_survival(procs, panels, 1);
        assert!(lo > hi, "{lo} vs {hi}");
    }

    /// The acceptance criterion: the adaptive choice matches the
    /// closed-form-predicted optimum on ≥ 3 (P, rate) cells.  The
    /// brute force is an independent implementation of the same model.
    #[test]
    fn chosen_c_matches_closed_form_optimum_on_cells() {
        let cells: [(usize, f64); 4] =
            [(16, 40.0), (64, 60.0), (256, 120.0), (1024, 200.0)];
        let mut nontrivial = 0;
        for (procs, rate) in cells {
            let policy = AdaptivePolicy::new(rate);
            let choice = policy.choose(procs, 8);
            let (want_c, want_s) =
                brute_force_optimum(procs, 8, rate, AdaptivePolicy::DEFAULT_TARGET);
            assert_eq!(
                choice.checksums, want_c,
                "P={procs} rate={rate}: adaptive c={} vs brute-force c={want_c}",
                choice.checksums
            );
            assert!(
                (choice.predicted_survival - want_s).abs() < 1e-6,
                "P={procs} rate={rate}: survival {} vs {want_s}",
                choice.predicted_survival
            );
            if choice.checksums > 0 {
                assert_eq!(choice.policy, RecoveryPolicy::Hybrid);
                nontrivial += 1;
            }
        }
        assert!(nontrivial >= 3, "want ≥3 cells where coding is actually needed");
    }

    #[test]
    fn higher_rates_demand_more_checksums() {
        let procs = 256;
        let mut prev_c = 0;
        for rate in [1.0, 50.0, 200.0, 800.0] {
            let c = AdaptivePolicy::new(rate).choose(procs, 8).checksums;
            assert!(c >= prev_c, "rate={rate}: c={c} < {prev_c}");
            prev_c = c;
        }
        assert!(prev_c >= 1, "the steep end of the sweep must need coding");
    }

    /// `sim::` replay validation at 10⁵ ranks: at a rate where the
    /// model says replication collapses, the adaptively-chosen Hybrid
    /// ladder survives in the event-driven simulator too.  Tolerances
    /// are generous — the analytic model bins deaths per stage while
    /// the simulator fires them on a continuous clock.
    #[test]
    fn sim_replay_validates_choice_at_1e5_ranks() {
        let procs = 100_000;
        let panels = 3;
        let rate = 60.0;
        let policy = AdaptivePolicy::new(rate);
        let choice = policy.choose(procs, panels);
        assert_eq!(choice.policy, RecoveryPolicy::Hybrid, "this rate must need coding");
        let replication = policy.predicted_survival(procs, panels, 0);
        assert!(replication < 0.9, "cell must be past the replication knee: {replication}");
        assert!(choice.predicted_survival >= AdaptivePolicy::DEFAULT_TARGET);

        let engine = EngineBuilder::new().host_only().threads(2).build().unwrap();
        let base = SimScenario {
            name: "adaptive-validation".into(),
            procs,
            panels,
            panel: 4,
            algo: Algo::SelfHealing,
            samples: 4,
            seed: 1105,
            ..SimScenario::default()
        };
        let mut coded = base.clone();
        coded.policy = RecoveryPolicy::Hybrid;
        coded.checksums = choice.checksums;
        coded.churn.fail_rate = rate;
        let mut plain = base;
        plain.policy = RecoveryPolicy::Replica;
        plain.churn.fail_rate = rate;

        let coded_p = engine.simulate(&coded).unwrap().survival().probability();
        let plain_p = engine.simulate(&plain).unwrap().survival().probability();
        assert!(
            coded_p >= plain_p,
            "chosen ladder must not lose to replication: {coded_p} vs {plain_p}"
        );
        assert!(coded_p >= 0.5, "chosen ladder should mostly survive its own cell: {coded_p}");
    }
}
