//! Mixed-precision accuracy-vs-speed sweep: what does dropping the
//! data path to f32 cost in accuracy, and what does it buy in wall
//! time, per shape and per recovery policy?
//!
//! Each cell runs one CAQR factorization at a fixed `(m, n, panel)`
//! shape under a `(policy, c)` ladder and a [`Precision`], then scores
//! it against the f64 oracle (`householder_qr_reference`):
//!
//! * [`Precision::F64`] cells pin the oracle **bitwise** — their bound
//!   is exactly `0.0`, the regression contract every existing test
//!   relies on.  (Under a threaded backend plan the factorizations are
//!   tolerance-contracted, so f64 cells inherit the rounding bound
//!   instead of the bitwise pin.)
//! * [`Precision::F32`] cells must stay within the column-wise rounding
//!   bound `c·n·ε_f32·max(1, ‖R‖_F)` (the same shape as
//!   [`Contract::Tolerance`](crate::runtime::Contract)) — checksums
//!   stay f64 either way, so the coded rung keeps its algebraic
//!   headroom over the f32 data it protects.
//!
//! The `repro precision` subcommand prints the table;
//! `benches/precision_throughput.rs` times the same cells and gates the
//! machine-relative f32-vs-f64 speedup ratio into
//! `BENCH_precision.json`.

use std::time::Duration;

use crate::abft::RecoveryPolicy;
use crate::caqr::CaqrSpec;
use crate::engine::Engine;
use crate::error::Result;
use crate::runtime::Precision;
use crate::tsqr::Algo;

/// One `(shape, policy, precision)` cell of the sweep.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrecisionRow {
    /// Input rows.
    pub m: usize,
    /// Input columns.
    pub n: usize,
    /// Block-column width.
    pub panel: usize,
    /// Recovery ladder the run executed under.
    pub policy: RecoveryPolicy,
    /// Checksum blocks armed.
    pub checksums: usize,
    /// Working precision of the data path.
    pub precision: Precision,
    /// Wall clock of the factorization.
    pub wall: Duration,
    /// `max |R - R_oracle|` against the f64 reference (∞ when the run
    /// produced no R).
    pub max_err: f64,
    /// The accuracy bound this cell must satisfy: `0.0` (bitwise) for
    /// f64 cells on a bitwise backend plan,
    /// `64·n·ε_f32·max(1, ‖R‖_F)` for f32 cells and for any cell run
    /// under a threaded (tolerance-contracted) plan.
    pub bound: f64,
    /// Did the factorization complete?
    pub success: bool,
}

impl PrecisionRow {
    /// Did the cell complete *and* land within its declared accuracy
    /// bound?  (For f64 cells this is the bitwise oracle pin.)
    pub fn within_bound(&self) -> bool {
        self.success && self.max_err <= self.bound
    }
}

/// Accuracy-vs-speed sweep over shapes × recovery policies × working
/// precisions (see the [module docs](self)).
pub struct PrecisionSweep<'e> {
    engine: &'e Engine,
    /// World size (even, ≥ 2).
    pub procs: usize,
    /// Input-matrix seed.
    pub seed: u64,
}

impl<'e> PrecisionSweep<'e> {
    /// A sweep over `procs` simulated processes.
    pub fn new(engine: &'e Engine, procs: usize) -> Self {
        Self { engine, procs, seed: 42 }
    }

    /// Replace the input-matrix seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// The `(m, n, panel)` shapes the sweep visits: one tall-skinny
    /// shape in quick mode; square-ish panels and a wide-panel shape in
    /// the full set.
    pub fn shapes(quick: bool) -> Vec<(usize, usize, usize)> {
        if quick {
            vec![(48, 12, 4)]
        } else {
            vec![(48, 12, 4), (64, 8, 4), (96, 24, 8)]
        }
    }

    /// The `(policy, c)` ladders the sweep compares: replication alone
    /// against the hybrid coded rung.
    pub fn policies() -> Vec<(RecoveryPolicy, usize)> {
        vec![(RecoveryPolicy::Replica, 0), (RecoveryPolicy::Hybrid, 1)]
    }

    /// Run one cell: factor at the given shape/ladder/precision and
    /// score R against the f64 oracle.
    pub fn cell(
        &self,
        m: usize,
        n: usize,
        panel: usize,
        policy: RecoveryPolicy,
        checksums: usize,
        precision: Precision,
    ) -> Result<PrecisionRow> {
        let spec = CaqrSpec::new(Algo::Redundant, self.procs, m, n, panel)
            .with_seed(self.seed)
            .with_verify(false)
            .with_policy(policy)
            .with_checksums(checksums)
            .with_precision(precision);
        let reference = crate::linalg::householder_qr_reference(&spec.input_matrix()).r();
        let res = self.engine.run_caqr(spec)?;
        let max_err = match &res.final_r {
            Some(r) => r.max_abs_diff(&reference),
            None => f64::INFINITY,
        };
        // The bitwise oracle pin only holds for f64 cells on a bitwise
        // backend: when the engine's plan routes any op to the threaded
        // kernel, the factorizations are tolerance-bounded (see
        // `Contract`), so every cell inherits the rounding bound.
        let bitwise = !precision.is_f32() && !self.engine.default_backend_plan().uses_threaded();
        let bound = if bitwise {
            0.0
        } else {
            64.0 * n as f64 * f64::from(f32::EPSILON) * reference.fro_norm().max(1.0)
        };
        Ok(PrecisionRow {
            m,
            n,
            panel,
            policy,
            checksums,
            precision,
            wall: res.wall,
            max_err,
            bound,
            success: res.success(),
        })
    }

    /// The full table: every shape × ladder × precision cell, f64 and
    /// f32 adjacent so accuracy-vs-speed reads off one row pair.
    pub fn table(&self, quick: bool) -> Result<Vec<PrecisionRow>> {
        let mut rows = Vec::new();
        for &(m, n, panel) in &Self::shapes(quick) {
            for &(policy, c) in &Self::policies() {
                for precision in [Precision::F64, Precision::F32] {
                    rows.push(self.cell(m, n, panel, policy, c, precision)?);
                }
            }
        }
        Ok(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_have_the_documented_cells() {
        assert_eq!(PrecisionSweep::shapes(true).len(), 1);
        assert_eq!(PrecisionSweep::shapes(false).len(), 3);
        assert_eq!(PrecisionSweep::policies().len(), 2);
        for (m, n, panel) in PrecisionSweep::shapes(false) {
            assert!(m >= n && n >= panel && n % panel == 0, "({m},{n},{panel}) must tile");
        }
    }

    #[test]
    fn quick_table_pins_f64_bitwise_and_bounds_f32() {
        let engine = Engine::host();
        let rows = PrecisionSweep::new(&engine, 4).table(true).unwrap();
        assert_eq!(rows.len(), 4, "1 shape x 2 ladders x 2 precisions");
        for row in &rows {
            assert!(row.success, "fault-free cell must complete: {row:?}");
            assert!(row.within_bound(), "cell out of bound: {row:?}");
            if !row.precision.is_f32() {
                assert_eq!(row.max_err, 0.0, "f64 cells pin the oracle bitwise: {row:?}");
            } else {
                assert!(row.bound > 0.0);
            }
        }
    }
}
