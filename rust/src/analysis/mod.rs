//! Robustness analysis: the paper's closed-form tolerance bounds
//! (§III-B3, §III-C3, §III-D3) plus the combinatorial machinery the
//! validation benches use to check them empirically.

pub mod adaptive;
pub mod checkpoint_vs_redundant;
pub mod closed_form;
pub mod coded;
pub mod fullsim;
pub mod precision;
pub mod robustness;
pub mod simsweep;
pub mod survival;

pub use adaptive::{AdaptivePolicy, PolicyChoice};
pub use checkpoint_vs_redundant::{CheckpointVsRedundant, CompareCell, Contender};
pub use closed_form::{survival_curve, survival_exact_f_at_round};
pub use coded::{CodedRow, CodedSweep};
pub use fullsim::{CaqrSweep, FullSimSweep};
pub use precision::{PrecisionRow, PrecisionSweep};
pub use robustness::{
    max_tolerated_by_step, redundancy_copies, self_healing_total_tolerated,
    survives_failure_set,
};
pub use simsweep::SimSweep;
pub use survival::{SurvivalEstimate, SurvivalSweep};

/// The one Monte-Carlo cell shape every sweep in this module shares:
/// build `samples` specs, one per sample with its seed drawn from
/// [`crate::util::derive_seed`]`(base, i)`, then hand the whole batch
/// to a campaign runner and return its aggregate.
///
/// Hoisted out of [`FullSimSweep`] and [`CaqrSweep`] (which had grown
/// three copies of the loop between them) so the per-sample seeding
/// rule lives in exactly one place.
pub(crate) fn sample_cell<S, R>(
    samples: u64,
    base: u64,
    spec_at: impl Fn(u64) -> S,
    run: impl FnOnce(Vec<S>) -> crate::error::Result<R>,
) -> crate::error::Result<R> {
    run((0..samples).map(|i| spec_at(crate::util::derive_seed(base, i))).collect())
}
