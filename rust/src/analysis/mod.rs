//! Robustness analysis: the paper's closed-form tolerance bounds
//! (§III-B3, §III-C3, §III-D3) plus the combinatorial machinery the
//! validation benches use to check them empirically.

pub mod closed_form;
pub mod coded;
pub mod fullsim;
pub mod robustness;
pub mod survival;

pub use closed_form::{survival_curve, survival_exact_f_at_round};
pub use coded::{CodedRow, CodedSweep};
pub use fullsim::{CaqrSweep, FullSimSweep};
pub use robustness::{
    max_tolerated_by_step, redundancy_copies, self_healing_total_tolerated,
    survives_failure_set,
};
pub use survival::{SurvivalEstimate, SurvivalSweep};
