//! Full-simulator Monte-Carlo sweeps, batched through an engine
//! [`Campaign`](crate::engine::Campaign) so world/pool setup is
//! amortized across the whole sample instead of paid per run.
//!
//! The analytic sweeps in [`super::survival`] stay the fast path
//! (millions of patterns per second, matrix-free); this is the
//! cross-check on the real concurrent implementation that the
//! robustness benches and the `repro sweep --full` CLI use.  Both
//! report the same [`SurvivalEstimate`] type so tables mix freely.

use crate::caqr::CaqrSpec;
use crate::engine::Engine;
use crate::error::Result;
use crate::fault::{CaqrKillSchedule, KillSchedule};
use crate::tsqr::{Algo, RunSpec, TreePlan};

use super::survival::SurvivalEstimate;

/// Parameterized full-stack Monte-Carlo sweep over a shared engine.
pub struct FullSimSweep<'e> {
    engine: &'e Engine,
    /// Algorithm under test.
    pub algo: Algo,
    /// World size.
    pub procs: usize,
    /// Leaf panel rows per process.
    pub rows_per_proc: usize,
    /// Matrix columns.
    pub cols: usize,
    /// Monte-Carlo samples per cell.
    pub samples: u64,
    /// Base seed of the sample stream.
    pub seed: u64,
    concurrency: usize,
}

impl<'e> FullSimSweep<'e> {
    /// Defaults match the historical bench shapes: 16×4 leaves,
    /// 60 samples per cell.
    pub fn new(engine: &'e Engine, algo: Algo, procs: usize) -> Self {
        Self {
            engine,
            algo,
            procs,
            rows_per_proc: 16,
            cols: 4,
            samples: 60,
            seed: 0xC0712,
            concurrency: 1,
        }
    }

    /// Replace the per-cell sample count.
    pub fn with_samples(mut self, samples: u64) -> Self {
        self.samples = samples;
        self
    }

    /// Replace the leaf shape.
    pub fn with_shape(mut self, rows_per_proc: usize, cols: usize) -> Self {
        self.rows_per_proc = rows_per_proc;
        self.cols = cols;
        self
    }

    /// Replace the base seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Pipeline this many runs concurrently through the engine.
    pub fn with_concurrency(mut self, window: usize) -> Self {
        self.concurrency = window.max(1);
        self
    }

    fn spec(&self, schedule: KillSchedule) -> RunSpec {
        RunSpec::new(self.algo, self.procs, self.rows_per_proc, self.cols)
            .with_seed(self.seed)
            .with_schedule(schedule)
            .with_verify(false)
    }

    /// One cell: sample schedules through [`super::sample_cell`]'s
    /// seeding rule, run them as an engine campaign, aggregate.
    fn estimate(
        &self,
        base: u64,
        schedule_at: impl Fn(u64) -> KillSchedule,
    ) -> Result<SurvivalEstimate> {
        super::sample_cell(
            self.samples,
            base,
            |seed| self.spec(schedule_at(seed)),
            |specs| Ok(self.engine.campaign(specs).concurrency(self.concurrency).run()?.survival()),
        )
    }

    /// P(success | exactly `f` distinct ranks die at round boundary
    /// `round`), measured on the full simulator.
    pub fn at_round(&self, round: u32, f: usize) -> Result<SurvivalEstimate> {
        let base = self.seed ^ ((round as u64) << 32) ^ ((f as u64) << 48);
        self.estimate(base, |seed| {
            KillSchedule::random_at_round(self.procs, round, f, None, seed)
        })
    }

    /// P(success) under per-rank exponential lifetimes (deaths/step).
    pub fn exponential(&self, rate: f64) -> Result<SurvivalEstimate> {
        let rounds = TreePlan::new(self.procs).rounds();
        let base = self.seed ^ rate.to_bits();
        self.estimate(base, |seed| KillSchedule::exponential(self.procs, rounds, rate, seed))
    }

    /// P(success) when every (rank, round) fails independently w.p. `p`.
    pub fn bernoulli(&self, p: f64) -> Result<SurvivalEstimate> {
        let rounds = TreePlan::new(self.procs).rounds();
        let base = self.seed ^ p.to_bits();
        self.estimate(base, |seed| KillSchedule::bernoulli(self.procs, rounds, p, seed))
    }
}

/// Full-stack Monte-Carlo sweep for the CAQR subsystem, batched
/// through engine campaigns — the general-matrix counterpart of
/// [`FullSimSweep`], parameterized over *panel counts*: more panels
/// mean more replicated update stages, so survival under a fixed
/// number of per-run failures is a function of the panel count (one
/// lost replica pair anywhere kills the run under Redundant
/// semantics; Self-Healing resets capacity at every boundary).
pub struct CaqrSweep<'e> {
    engine: &'e Engine,
    /// Failure semantics (`Redundant` or `SelfHealing`).
    pub algo: Algo,
    /// World size.
    pub procs: usize,
    /// Block-column width (the matrix is `procs·panel` rows by
    /// `panels·panel` columns, kept tall for every sampled cell).
    pub panel: usize,
    /// Monte-Carlo samples per cell.
    pub samples: u64,
    /// Base seed of the sample stream.
    pub seed: u64,
    /// Checksum blocks armed per panel stage (0 = replication only;
    /// only consumed when the engine/spec recovery policy uses
    /// checksums — see [`crate::abft::RecoveryPolicy`]).
    pub checksums: usize,
    concurrency: usize,
}

impl<'e> CaqrSweep<'e> {
    /// Defaults: 4-column panels, 40 samples per cell, no checksums.
    pub fn new(engine: &'e Engine, algo: Algo, procs: usize) -> Self {
        Self {
            engine,
            algo,
            procs,
            panel: 4,
            samples: 40,
            seed: 0xCA08,
            checksums: 0,
            concurrency: 1,
        }
    }

    /// Arm `c` checksum blocks on every sampled spec (the sweep's
    /// engine must run a checksum-using recovery policy for them to
    /// matter).
    pub fn with_checksums(mut self, c: usize) -> Self {
        self.checksums = c;
        self
    }

    /// Replace the per-cell sample count.
    pub fn with_samples(mut self, samples: u64) -> Self {
        self.samples = samples;
        self
    }

    /// Replace the block-column width.
    pub fn with_panel(mut self, panel: usize) -> Self {
        self.panel = panel.max(1);
        self
    }

    /// Replace the base seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Pipeline this many runs concurrently through the engine.
    pub fn with_concurrency(mut self, window: usize) -> Self {
        self.concurrency = window.max(1);
        self
    }

    /// P(factorization completes | exactly `f` distinct ranks die
    /// during uniformly random panels' update stages), measured on the
    /// full CAQR stack with `panels` block columns.
    pub fn at_panels(&self, panels: usize, f: usize) -> Result<SurvivalEstimate> {
        let panels = panels.max(1);
        let n = panels * self.panel;
        let m = n.max(self.procs * self.panel);
        let base = self.seed ^ ((panels as u64) << 32) ^ ((f as u64) << 48);
        super::sample_cell(
            self.samples,
            base,
            |seed| {
                CaqrSpec::new(self.algo, self.procs, m, n, self.panel)
                    .with_seed(self.seed)
                    .with_verify(false)
                    .with_checksums(self.checksums)
                    .with_schedule(CaqrKillSchedule::random_updates(self.procs, panels, f, seed))
            },
            |specs| {
                Ok(self.engine.caqr_campaign(specs).concurrency(self.concurrency).run()?.survival())
            },
        )
    }

    /// The survival curve over a list of panel counts at fixed `f` —
    /// the `FullSimSweep`-over-panel-counts mode `repro caqr --sweep`
    /// prints.
    pub fn over_panel_counts(
        &self,
        panel_counts: &[usize],
        f: usize,
    ) -> Result<Vec<(usize, SurvivalEstimate)>> {
        panel_counts.iter().map(|&p| Ok((p, self.at_panels(p, f)?))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn within_bound_replace_is_certain_on_the_full_stack() {
        let engine = Engine::host();
        let sweep = FullSimSweep::new(&engine, Algo::Replace, 8).with_samples(12);
        let est = sweep.at_round(1, 1).unwrap();
        assert_eq!(est.trials, 12);
        assert_eq!(est.probability(), 1.0, "f=1 at s=1 is within 2^1-1");
    }

    #[test]
    fn deterministic_in_seed() {
        let engine = Engine::host();
        let a = FullSimSweep::new(&engine, Algo::SelfHealing, 8)
            .with_samples(10)
            .at_round(2, 3)
            .unwrap();
        let b = FullSimSweep::new(&engine, Algo::SelfHealing, 8)
            .with_samples(10)
            .with_concurrency(4)
            .at_round(2, 3)
            .unwrap();
        assert_eq!(a.successes, b.successes, "same seeds, same outcome");
    }

    #[test]
    fn caqr_sweep_single_failure_is_certain() {
        // f = 1 = replication - 1: every single-failure pattern is
        // recoverable from the surviving replica, at any panel count.
        let engine = Engine::host();
        let sweep = CaqrSweep::new(&engine, Algo::Redundant, 4).with_samples(8);
        for panels in [1usize, 3] {
            let est = sweep.at_panels(panels, 1).unwrap();
            assert_eq!(est.trials, 8);
            assert_eq!(est.probability(), 1.0, "panels={panels}");
        }
    }

    #[test]
    fn caqr_sweep_deterministic_in_seed_and_concurrency() {
        let engine = Engine::host();
        let a = CaqrSweep::new(&engine, Algo::SelfHealing, 4)
            .with_samples(6)
            .at_panels(2, 2)
            .unwrap();
        let b = CaqrSweep::new(&engine, Algo::SelfHealing, 4)
            .with_samples(6)
            .with_concurrency(3)
            .at_panels(2, 2)
            .unwrap();
        assert_eq!(a.successes, b.successes);
        let curve = CaqrSweep::new(&engine, Algo::Redundant, 4)
            .with_samples(4)
            .over_panel_counts(&[1, 2], 1)
            .unwrap();
        assert_eq!(curve.len(), 2);
        assert_eq!(curve[0].0, 1);
    }

    #[test]
    fn caqr_sweep_checksums_reach_the_specs() {
        // On a hybrid-ladder engine, an armed sweep survives EVERY
        // f=2 update-kill pattern at P=4, panels=2: the only fatal
        // pattern (both members of the block-owning pair at panel 0)
        // becomes a reconstruction, and dead factor pairs re-execute.
        use crate::abft::RecoveryPolicy;
        let engine = crate::engine::Engine::builder()
            .host_only()
            .recovery_policy(RecoveryPolicy::Hybrid)
            .build()
            .unwrap();
        let hybrid = CaqrSweep::new(&engine, Algo::Redundant, 4)
            .with_samples(10)
            .with_checksums(1)
            .at_panels(2, 2)
            .unwrap();
        assert_eq!(hybrid.probability(), 1.0, "armed sweep must ride every pattern");
        // Same engine, no checksums armed: the ladder has no rung to
        // stand on, so survival can only be lower or equal.
        let bare = CaqrSweep::new(&engine, Algo::Redundant, 4)
            .with_samples(10)
            .at_panels(2, 2)
            .unwrap();
        assert!(bare.probability() <= hybrid.probability());
    }

    #[test]
    fn matches_analytic_engine_on_a_cell() {
        // Same failure model, two engines: the full simulator and the
        // analytic model must agree (their per-sample patterns differ,
        // so compare the certain cells).
        let engine = Engine::host();
        let full = FullSimSweep::new(&engine, Algo::SelfHealing, 8)
            .with_samples(10)
            .at_round(1, 1)
            .unwrap();
        let analytic = super::super::SurvivalSweep::new(Algo::SelfHealing, 8)
            .with_trials(200)
            .at_round(1, 1);
        assert_eq!(full.probability(), 1.0);
        assert_eq!(analytic.probability(), 1.0);
    }
}
