//! Closed-form robustness bounds (§III-B3/C3/D3) and an *analytic
//! simulator* — a matrix-free, synchronous executor of the four
//! algorithms' failure semantics.
//!
//! The analytic simulator serves two purposes:
//! 1. A fast engine for exhaustive / Monte-Carlo robustness sweeps
//!    (millions of failure patterns per second, no tokio, no QR).
//! 2. An independent oracle for the full-stack simulator: property
//!    tests assert that the real run and the analytic prediction agree
//!    on who ends up with the final R (rust/tests/prop_invariants.rs).

use std::collections::HashMap;

use crate::tsqr::{Algo, TreePlan};
use crate::ulfm::Rank;

/// §III-B3: number of copies of each intermediate R̃ after paper-step
/// `s` (= `s` completed exchange rounds): `2^s`.
pub fn redundancy_copies(s: u32) -> u64 {
    1u64 << s
}

/// §III-B3/C3: the bound — `2^s − 1` failures tolerable by the end of
/// paper-step `s` (at least one copy of every block survives).
pub fn max_tolerated_by_step(s: u32) -> u64 {
    (1u64 << s) - 1
}

/// §III-D3: Self-Healing respawns the dead, so it tolerates `2^s − 1`
/// *at each* step; the cumulative capacity over `rounds` steps.
pub fn self_healing_total_tolerated(rounds: u32) -> u64 {
    (1..=rounds).map(max_tolerated_by_step).sum()
}

/// Per-rank liveness in the analytic simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AState {
    /// Still in the computation (ends holding the final R).
    Active,
    /// Crashed by the failure pattern.
    Dead,
    /// Returned early (peer failed / no replica).
    GaveUp,
    /// Finished its role without the final R (baseline sender).
    DoneNoR,
}

/// Prediction for one failure pattern.
#[derive(Debug, Clone)]
pub struct AnalyticOutcome {
    /// Final per-rank states.
    pub states: Vec<AState>,
    /// Ranks predicted to end holding the final R.
    pub holders: Vec<Rank>,
    /// Ranks that were respawned (Self-Healing only).
    pub respawned: Vec<Rank>,
}

impl AnalyticOutcome {
    /// Success under the paper's per-algorithm semantics (baseline:
    /// root holds R; redundant family: someone holds R).
    pub fn success(&self, algo: Algo) -> bool {
        match algo {
            Algo::Baseline => self.holders.contains(&0),
            _ => !self.holders.is_empty(),
        }
    }
}

/// Predict the outcome of `algo` on `procs` ranks under the failure
/// pattern `kill_round` (rank → boundary at which it crashes; a rank
/// killed at boundary `s` completed paper-step `s` but does not take
/// part in exchange round `s`).  One kill per rank — exactly what the
/// stochastic schedule generators produce.
pub fn survives_failure_set(
    algo: Algo,
    procs: usize,
    kill_round: &HashMap<Rank, u32>,
) -> AnalyticOutcome {
    let plan = TreePlan::new(procs);
    let rounds = plan.rounds();
    let mut st = vec![AState::Active; procs];
    let mut respawned: Vec<Rank> = Vec::new();

    for s in 0..rounds {
        // Who entered this round alive (before this boundary's kills)?
        // The checkpointed comparator posts its checkpoint before the
        // kill check, so checkpoint availability keys off this.
        let entry_active: Vec<bool> = st.iter().map(|x| *x == AState::Active).collect();
        // Phase 1 — fault injection at this round boundary.
        for r in 0..procs {
            if st[r] == AState::Active && kill_round.get(&r) == Some(&s) {
                st[r] = AState::Dead;
            }
        }
        // Phase 2 — who posts for exchange round s?  Everyone still
        // active (they post before fetching; baseline senders post,
        // receivers don't need to for the analysis).
        let posted: Vec<bool> = st.iter().map(|x| *x == AState::Active).collect();

        // Phase 3 — resolve the fetches.
        match algo {
            Algo::Baseline => {
                for r in 0..procs {
                    if st[r] != AState::Active || !plan.participates(r, s) {
                        continue;
                    }
                    let Some(b) = plan.buddy(r, s) else { continue };
                    if plan.is_sender(r, s) {
                        st[r] = AState::DoneNoR;
                    } else if !posted[b] {
                        st[r] = AState::GaveUp;
                    }
                }
            }
            Algo::Redundant => {
                // Exact-buddy exchange only (Alg. 2 line 7).
                let snapshot = st.clone();
                for r in 0..procs {
                    if snapshot[r] != AState::Active {
                        continue;
                    }
                    let Some(b) = plan.buddy(r, s) else { continue };
                    if !posted[b] {
                        st[r] = AState::GaveUp;
                    }
                }
            }
            Algo::Replace => {
                // Any poster in the buddy's replica group will do
                // (posted-then-died still delivers; findReplica covers
                // live-but-later cases — timing-independent).
                let snapshot = st.clone();
                for r in 0..procs {
                    if snapshot[r] != AState::Active {
                        continue;
                    }
                    let Some(b) = plan.buddy(r, s) else { continue };
                    let ok = plan.replicas_of(b, s).iter().any(|&q| posted[q]);
                    if !ok {
                        st[r] = AState::GaveUp;
                    }
                }
            }
            Algo::Checkpointed => {
                // Baseline tree + diskless checkpoints: a receiver whose
                // sender died *this round* recovers the sender's R̃ from
                // the checkpoint (taken before the kill), provided the
                // checkpoint's holder (the sender's neighbour) is alive.
                // A sender dead since an earlier round never produced
                // the needed R̃, checkpoint or not.
                for r in 0..procs {
                    if st[r] != AState::Active || !plan.participates(r, s) {
                        continue;
                    }
                    let Some(b) = plan.buddy(r, s) else { continue };
                    if plan.is_sender(r, s) {
                        st[r] = AState::DoneNoR;
                        continue;
                    }
                    if posted[b] {
                        continue;
                    }
                    // The sender's R̃_s checkpoint exists iff it entered
                    // round s alive (it posts before dying at this
                    // boundary); it is *readable* iff its holder
                    // SURVIVED the round-s boundary (heartbeat witness —
                    // `posted` is the post-kill active snapshot).
                    let recoverable = st[b] == AState::Dead
                        && kill_round.get(&b) == Some(&s)
                        && entry_active[b]
                        && {
                            let holder = crate::checkpoint::partner(b, s, procs);
                            holder == r || posted[holder]
                        };
                    if !recoverable {
                        st[r] = AState::GaveUp;
                    }
                }
            }
            Algo::SelfHealing => {
                // Like Replace, but a dead buddy with a surviving
                // replica is respawned and rejoins from this round.
                let snapshot = st.clone();
                for r in 0..procs {
                    if snapshot[r] != AState::Active {
                        continue;
                    }
                    let Some(b) = plan.buddy(r, s) else { continue };
                    let group_has_poster = plan.replicas_of(b, s).iter().any(|&q| posted[q]);
                    if !group_has_poster {
                        st[r] = AState::GaveUp;
                        continue;
                    }
                    if st[b] == AState::Dead {
                        st[b] = AState::Active; // spawnNew(b) + Alg. 5 recovery
                        respawned.push(b);
                    } else if matches!(st[b], AState::GaveUp | AState::DoneNoR) {
                        // Exited processes cannot be respawned.
                        st[r] = AState::GaveUp;
                    }
                }
            }
        }
    }

    let holders: Vec<Rank> =
        (0..procs).filter(|&r| st[r] == AState::Active).collect();
    respawned.sort_unstable();
    respawned.dedup();
    AnalyticOutcome { states: st, holders, respawned }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kills(entries: &[(Rank, u32)]) -> HashMap<Rank, u32> {
        entries.iter().copied().collect()
    }

    #[test]
    fn formulas() {
        assert_eq!(redundancy_copies(0), 1);
        assert_eq!(redundancy_copies(3), 8);
        assert_eq!(max_tolerated_by_step(1), 1);
        assert_eq!(max_tolerated_by_step(2), 3);
        assert_eq!(self_healing_total_tolerated(3), 1 + 3 + 7);
    }

    #[test]
    fn fault_free_all_hold_r() {
        for algo in [Algo::Redundant, Algo::Replace, Algo::SelfHealing] {
            let out = survives_failure_set(algo, 8, &kills(&[]));
            assert_eq!(out.holders.len(), 8, "{algo:?}");
        }
        let out = survives_failure_set(Algo::Baseline, 8, &kills(&[]));
        assert_eq!(out.holders, vec![0], "baseline: only the root");
    }

    #[test]
    fn fig3_redundant_p2_dies() {
        // Paper Figure 3: P0 gives up, P1 & P3 hold the final R.
        let out = survives_failure_set(Algo::Redundant, 4, &kills(&[(2, 1)]));
        assert_eq!(out.holders, vec![1, 3]);
        assert_eq!(out.states[0], AState::GaveUp);
        assert_eq!(out.states[2], AState::Dead);
        assert!(out.success(Algo::Redundant));
    }

    #[test]
    fn fig4_replace_p2_dies() {
        // Paper Figure 4: P0 exchanges with the replica P3; P0/P1/P3 end with R.
        let out = survives_failure_set(Algo::Replace, 4, &kills(&[(2, 1)]));
        assert_eq!(out.holders, vec![0, 1, 3]);
        assert!(out.success(Algo::Replace));
    }

    #[test]
    fn fig5_self_healing_p2_dies() {
        // Paper Figure 5: P2 respawned; all four ranks end with R.
        let out = survives_failure_set(Algo::SelfHealing, 4, &kills(&[(2, 1)]));
        assert_eq!(out.holders, vec![0, 1, 2, 3]);
        assert_eq!(out.respawned, vec![2]);
    }

    #[test]
    fn baseline_aborts_on_any_failure_on_root_path() {
        let out = survives_failure_set(Algo::Baseline, 4, &kills(&[(2, 1)]));
        assert!(!out.success(Algo::Baseline));
    }

    #[test]
    fn baseline_survives_failure_of_already_done_sender() {
        // Rank 3 sent its R̃ at round 0 and exited; killing it later
        // (entry at round 1) is harmless — it's not Active anymore.
        let out = survives_failure_set(Algo::Baseline, 4, &kills(&[(3, 1)]));
        assert!(out.success(Algo::Baseline));
    }

    #[test]
    fn step0_failure_is_fatal_for_everyone_needing_it() {
        // 2^0 - 1 = 0 failures tolerable before the first exchange.
        for algo in [Algo::Replace, Algo::SelfHealing] {
            let out = survives_failure_set(algo, 2, &kills(&[(1, 0)]));
            assert!(!out.success(algo), "{algo:?}: leaf data had one copy");
        }
    }

    #[test]
    fn replace_survives_adversarial_pattern_that_kills_redundant() {
        // P=8, kills P1@1, P2@2, P4@2: within the paper bound
        // (f(1)=1 <= 1, f(2)=3 <= 3) yet Redundant's give-up cascade
        // eliminates every process; Replace survives via replicas.
        // (This nuance is measured by the robustness bench.)
        let pattern = kills(&[(1, 1), (2, 2), (4, 2)]);
        let red = survives_failure_set(Algo::Redundant, 8, &pattern);
        assert!(!red.success(Algo::Redundant), "give-up cascade");
        let rep = survives_failure_set(Algo::Replace, 8, &pattern);
        assert!(rep.success(Algo::Replace));
        let sh = survives_failure_set(Algo::SelfHealing, 8, &pattern);
        assert!(sh.success(Algo::SelfHealing));
    }

    #[test]
    fn replace_guarantee_exhaustive_p8() {
        // §III-C3 as a worst-case guarantee: Replace succeeds for EVERY
        // pattern with cumulative failures f(s) <= 2^s - 1.  Exhaustive
        // over all single-kill-per-rank patterns on P=8 (4^8 = 65536).
        let procs = 8;
        let rounds = 3u32;
        let mut checked = 0u64;
        for code in 0..(4u64.pow(procs as u32)) {
            let mut pattern = HashMap::new();
            let mut c = code;
            for r in 0..procs {
                let v = (c % 4) as u32;
                c /= 4;
                if v < rounds {
                    pattern.insert(r, v);
                }
            }
            // Cumulative failure counts at each boundary.
            let within_bound = (0..rounds).all(|s| {
                let f: u64 = pattern.values().filter(|&&k| k <= s).count() as u64;
                f <= max_tolerated_by_step(s)
            });
            if !within_bound {
                continue;
            }
            checked += 1;
            let out = survives_failure_set(Algo::Replace, procs, &pattern);
            assert!(out.success(Algo::Replace), "pattern {pattern:?} within bound failed");
            let sh = survives_failure_set(Algo::SelfHealing, procs, &pattern);
            assert!(sh.success(Algo::SelfHealing), "SH failed on {pattern:?}");
        }
        assert!(checked > 100, "sweep must actually cover patterns ({checked})");
    }

    #[test]
    fn bound_is_tight_killing_a_full_group_is_fatal() {
        // 2^s failures CAN be fatal: kill the entire group {0,1} at
        // boundary 1 — both copies of that block's R̃₁ are lost.
        let pattern = kills(&[(0, 1), (1, 1)]);
        for algo in [Algo::Redundant, Algo::Replace, Algo::SelfHealing] {
            let out = survives_failure_set(algo, 4, &pattern);
            assert!(!out.success(algo), "{algo:?} must fail when a whole group dies");
        }
    }

    #[test]
    fn self_healing_respawn_chain_per_step_capacity() {
        // P=8: 1 failure at step 1, 3 more at step 2 — the §III-D3
        // example ("1 process can fail at step 1; it will be respawned
        // and 3 additional processes can fail at step 2").
        let pattern = kills(&[(0, 1), (1, 2), (2, 2), (4, 2)]);
        let out = survives_failure_set(Algo::SelfHealing, 8, &pattern);
        assert!(out.success(Algo::SelfHealing), "within per-step capacity");
        assert!(!out.respawned.is_empty());
    }

    #[test]
    fn dead_ranks_never_hold_r_unless_respawned() {
        let out = survives_failure_set(Algo::Replace, 8, &kills(&[(5, 1)]));
        assert!(!out.holders.contains(&5));
        let out = survives_failure_set(Algo::SelfHealing, 8, &kills(&[(5, 1)]));
        assert!(out.holders.contains(&5), "SH respawns 5 when its buddy needs it");
    }
}
