//! Closed-form survival probability for the canonical experiment
//! "exactly `f` uniformly random failures at round boundary `s`".
//!
//! For Replace / Self-Healing TSQR the run survives iff **no level-`s`
//! replica group is wiped out entirely** (each group of size `m = 2^s`
//! holds all copies of one block's R̃; §III-B3).  With `f` failures
//! drawn uniformly without replacement from `P` ranks split into
//! `G = P/m` groups, inclusion–exclusion over "group j fully dead"
//! gives
//!
//! ```text
//! P(survive) = Σ_{j=0..min(G, f/m)} (−1)^j C(G,j) C(P−jm, f−jm) / C(P,f)
//! ```
//!
//! This is an *independent derivation* of the same quantity the
//! Monte-Carlo sweep estimates — the tests pin them against each other,
//! which validates both the sampler and the analytic simulator.

use crate::tsqr::TreePlan;

/// ln C(n, k) via ln-gamma (Stirling–Lanczos), stable for large n.
fn ln_choose(n: u64, k: u64) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    ln_gamma((n + 1) as f64) - ln_gamma((k + 1) as f64) - ln_gamma((n - k + 1) as f64)
}

/// Lanczos approximation of ln Γ(x), x > 0.
fn ln_gamma(x: f64) -> f64 {
    // g = 7, n = 9 Lanczos coefficients.
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection (not needed for factorials, kept for completeness).
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// P(no level-`s` group fully killed | exactly `f` uniform failures at
/// boundary `s`) on a power-of-two world of `procs` ranks — the
/// survival probability of Replace/Self-Healing TSQR in that setting.
pub fn survival_exact_f_at_round(procs: usize, s: u32, f: usize) -> f64 {
    assert!(procs.is_power_of_two(), "closed form defined for power-of-two worlds");
    let p = procs as u64;
    let m = 1u64 << s; // group size
    let g = p / m; // number of groups
    let f = f as u64;
    if f > p {
        return 0.0;
    }
    let denom = ln_choose(p, f);
    let jmax = std::cmp::min(g, f / m);
    let mut acc = 0.0f64;
    for j in 0..=jmax {
        let term = (ln_choose(g, j) + ln_choose(p - j * m, f - j * m) - denom).exp();
        if j % 2 == 0 {
            acc += term;
        } else {
            acc -= term;
        }
    }
    acc.clamp(0.0, 1.0)
}

/// The smallest `f` at which survival is no longer certain: exactly
/// `2^s` (one full group) — the tightness statement of §III-B3.
pub fn certain_survival_threshold(s: u32) -> u64 {
    (1u64 << s) - 1
}

/// Convenience: the survival curve over f = 0..=procs at round `s`.
pub fn survival_curve(procs: usize, s: u32) -> Vec<(usize, f64)> {
    (0..=procs).map(|f| (f, survival_exact_f_at_round(procs, s, f))).collect()
}

/// Expected number of tolerated failures at round `s` (where the curve
/// crosses 1/2 — a scalar summary used by the reliability report).
pub fn median_tolerated(procs: usize, s: u32) -> usize {
    survival_curve(procs, s)
        .iter()
        .take_while(|(_, p)| *p >= 0.5)
        .last()
        .map(|(f, _)| *f)
        .unwrap_or(0)
}

/// Check that a world/step combination is in range for the formula.
pub fn applicable(procs: usize, s: u32) -> bool {
    procs.is_power_of_two() && s < TreePlan::new(procs).rounds()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::SurvivalSweep;
    use crate::tsqr::Algo;

    #[test]
    fn ln_gamma_factorials() {
        // Γ(n+1) = n!
        for (n, fact) in [(1u64, 1.0f64), (2, 2.0), (5, 120.0), (10, 3628800.0)] {
            let got = ln_gamma((n + 1) as f64).exp();
            assert!((got - fact).abs() / fact < 1e-10, "{n}! -> {got}");
        }
    }

    #[test]
    fn choose_small_values() {
        assert!((ln_choose(5, 2).exp() - 10.0).abs() < 1e-9);
        assert!((ln_choose(16, 8).exp() - 12870.0).abs() < 1e-6);
        assert_eq!(ln_choose(3, 5), f64::NEG_INFINITY);
    }

    #[test]
    fn survival_certain_within_bound() {
        // f <= 2^s - 1 cannot wipe a group of size 2^s.
        for procs in [8usize, 16, 64] {
            for s in 1..3u32 {
                let f = certain_survival_threshold(s) as usize;
                let p = survival_exact_f_at_round(procs, s, f);
                assert!((p - 1.0).abs() < 1e-12, "P={procs} s={s} f={f}: {p}");
            }
        }
    }

    #[test]
    fn survival_below_one_past_bound() {
        let p = survival_exact_f_at_round(16, 1, 2); // f = 2^1 can wipe a pair
        assert!(p < 1.0 && p > 0.9, "{p}");
        // Exact value: 1 - C(8,1)*C(14,0)/C(16,2) = 1 - 8/120.
        assert!((p - (1.0 - 8.0 / 120.0)).abs() < 1e-12);
    }

    #[test]
    fn kill_everyone_is_fatal() {
        assert!(survival_exact_f_at_round(8, 1, 8) < 1e-9);
        assert_eq!(survival_exact_f_at_round(8, 1, 9), 0.0);
    }

    #[test]
    fn monotone_decreasing_in_f() {
        let curve = survival_curve(32, 2);
        for w in curve.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-12, "survival must not increase with f");
        }
    }

    #[test]
    fn matches_monte_carlo() {
        // The independent Monte-Carlo estimate must agree within CI.
        for (procs, s, f) in [(16usize, 1u32, 3usize), (16, 2, 6), (32, 2, 8)] {
            let exact = survival_exact_f_at_round(procs, s, f);
            let est = SurvivalSweep::new(Algo::Replace, procs).with_trials(20_000).at_round(s, f);
            let diff = (est.probability() - exact).abs();
            assert!(
                diff < est.ci95() + 0.01,
                "P={procs} s={s} f={f}: exact {exact} vs MC {} (±{})",
                est.probability(),
                est.ci95()
            );
        }
    }

    #[test]
    fn median_tolerated_grows_with_s() {
        let m1 = median_tolerated(64, 1);
        let m3 = median_tolerated(64, 3);
        assert!(m3 > m1, "robustness grows with the step: {m1} vs {m3}");
    }

    #[test]
    fn applicability() {
        assert!(applicable(16, 3));
        assert!(!applicable(12, 1));
        assert!(!applicable(16, 4));
    }
}
