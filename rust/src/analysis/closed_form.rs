//! Closed-form survival probability for the canonical experiment
//! "exactly `f` uniformly random failures at round boundary `s`".
//!
//! For Replace / Self-Healing TSQR the run survives iff **no level-`s`
//! replica group is wiped out entirely** (each group of size `m = 2^s`
//! holds all copies of one block's R̃; §III-B3).  With `f` failures
//! drawn uniformly without replacement from `P` ranks split into
//! `G = P/m` groups, inclusion–exclusion over "group j fully dead"
//! gives
//!
//! ```text
//! P(survive) = Σ_{j=0..min(G, f/m)} (−1)^j C(G,j) C(P−jm, f−jm) / C(P,f)
//! ```
//!
//! This is an *independent derivation* of the same quantity the
//! Monte-Carlo sweep estimates — the tests pin them against each other,
//! which validates both the sampler and the analytic simulator.

use crate::tsqr::TreePlan;

/// ln C(n, k) via ln-gamma (Stirling–Lanczos), stable for large n.
fn ln_choose(n: u64, k: u64) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    ln_gamma((n + 1) as f64) - ln_gamma((k + 1) as f64) - ln_gamma((n - k + 1) as f64)
}

/// Lanczos approximation of ln Γ(x), x > 0.
fn ln_gamma(x: f64) -> f64 {
    // g = 7, n = 9 Lanczos coefficients.
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection (not needed for factorials, kept for completeness).
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// P(no level-`s` group fully killed | exactly `f` uniform failures at
/// boundary `s`) on a power-of-two world of `procs` ranks — the
/// survival probability of Replace/Self-Healing TSQR in that setting.
pub fn survival_exact_f_at_round(procs: usize, s: u32, f: usize) -> f64 {
    assert!(procs.is_power_of_two(), "closed form defined for power-of-two worlds");
    let p = procs as u64;
    let m = 1u64 << s; // group size
    let g = p / m; // number of groups
    let f = f as u64;
    if f > p {
        return 0.0;
    }
    let denom = ln_choose(p, f);
    let jmax = std::cmp::min(g, f / m);
    let mut acc = 0.0f64;
    for j in 0..=jmax {
        let term = (ln_choose(g, j) + ln_choose(p - j * m, f - j * m) - denom).exp();
        if j % 2 == 0 {
            acc += term;
        } else {
            acc -= term;
        }
    }
    acc.clamp(0.0, 1.0)
}

/// The smallest `f` at which survival is no longer certain: exactly
/// `2^s` (one full group) — the tightness statement of §III-B3.
pub fn certain_survival_threshold(s: u32) -> u64 {
    (1u64 << s) - 1
}

/// Convenience: the survival curve over f = 0..=procs at round `s`.
pub fn survival_curve(procs: usize, s: u32) -> Vec<(usize, f64)> {
    (0..=procs).map(|f| (f, survival_exact_f_at_round(procs, s, f))).collect()
}

/// Expected number of tolerated failures at round `s` (where the curve
/// crosses 1/2 — a scalar summary used by the reliability report).
pub fn median_tolerated(procs: usize, s: u32) -> usize {
    survival_curve(procs, s)
        .iter()
        .take_while(|(_, p)| *p >= 0.5)
        .last()
        .map(|(f, _)| *f)
        .unwrap_or(0)
}

/// Check that a world/step combination is in range for the formula.
pub fn applicable(procs: usize, s: u32) -> bool {
    procs.is_power_of_two() && s < TreePlan::new(procs).rounds()
}

/// P(exactly `j` replica pairs fully wiped | exactly `f` uniform
/// failures without replacement) on an even world of `procs` ranks
/// paired `(2g, 2g+1)` — the CAQR ladder's group structure.  Returned
/// as the full distribution over `j = 0..=min(procs/2, f/2)`.
///
/// Unlike the inclusion–exclusion of [`survival_exact_f_at_round`]
/// this is a direct count (pairs have only two members, so "exactly
/// `j` wiped" factors cleanly): choose the `j` dead pairs, then spread
/// the remaining `f − 2j` failures one-per-pair over the other
/// `G − j` pairs with a side choice each —
///
/// ```text
/// P(j) = C(G,j) · C(G−j, f−2j) · 2^(f−2j) / C(2G, f),   G = procs/2
/// ```
///
/// Needs only an *even* world (not power-of-two): this is the pair
/// structure of `PanelPlan`, not the TSQR tree.  `f` is clamped to
/// `procs` (more failures than ranks kills everyone).
pub fn pair_wipe_distribution(procs: usize, f: usize) -> Vec<f64> {
    assert!(procs >= 2 && procs % 2 == 0, "pair structure needs an even world");
    let g = (procs / 2) as u64;
    let f = f.min(procs) as u64;
    let denom = ln_choose(2 * g, f);
    let jmax = std::cmp::min(g, f / 2);
    let mut dist = Vec::with_capacity(jmax as usize + 1);
    for j in 0..=jmax {
        let singles = f - 2 * j;
        let p = if singles > g - j {
            0.0 // not enough surviving pairs to absorb one failure each
        } else {
            (ln_choose(g, j)
                + ln_choose(g - j, singles)
                + singles as f64 * std::f64::consts::LN_2
                - denom)
                .exp()
        };
        dist.push(p);
    }
    dist
}

/// P(a CAQR stage survives `f` simultaneous uniform failures under the
/// Hybrid ladder with `c` checksum blocks): survival iff at most `c`
/// replica pairs are fully wiped.  `c = 0` is the replication-only
/// ladder and agrees with [`survival_exact_f_at_round`]`(procs, 1, f)`
/// on power-of-two worlds (the tests pin the two derivations against
/// each other).
pub fn survival_with_checksums(procs: usize, f: usize, c: usize) -> f64 {
    pair_wipe_distribution(procs, f).iter().take(c + 1).sum::<f64>().clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::SurvivalSweep;
    use crate::tsqr::Algo;

    #[test]
    fn ln_gamma_factorials() {
        // Γ(n+1) = n!
        for (n, fact) in [(1u64, 1.0f64), (2, 2.0), (5, 120.0), (10, 3628800.0)] {
            let got = ln_gamma((n + 1) as f64).exp();
            assert!((got - fact).abs() / fact < 1e-10, "{n}! -> {got}");
        }
    }

    #[test]
    fn choose_small_values() {
        assert!((ln_choose(5, 2).exp() - 10.0).abs() < 1e-9);
        assert!((ln_choose(16, 8).exp() - 12870.0).abs() < 1e-6);
        assert_eq!(ln_choose(3, 5), f64::NEG_INFINITY);
    }

    #[test]
    fn survival_certain_within_bound() {
        // f <= 2^s - 1 cannot wipe a group of size 2^s.
        for procs in [8usize, 16, 64] {
            for s in 1..3u32 {
                let f = certain_survival_threshold(s) as usize;
                let p = survival_exact_f_at_round(procs, s, f);
                assert!((p - 1.0).abs() < 1e-12, "P={procs} s={s} f={f}: {p}");
            }
        }
    }

    #[test]
    fn survival_below_one_past_bound() {
        let p = survival_exact_f_at_round(16, 1, 2); // f = 2^1 can wipe a pair
        assert!(p < 1.0 && p > 0.9, "{p}");
        // Exact value: 1 - C(8,1)*C(14,0)/C(16,2) = 1 - 8/120.
        assert!((p - (1.0 - 8.0 / 120.0)).abs() < 1e-12);
    }

    #[test]
    fn kill_everyone_is_fatal() {
        assert!(survival_exact_f_at_round(8, 1, 8) < 1e-9);
        assert_eq!(survival_exact_f_at_round(8, 1, 9), 0.0);
    }

    #[test]
    fn monotone_decreasing_in_f() {
        let curve = survival_curve(32, 2);
        for w in curve.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-12, "survival must not increase with f");
        }
    }

    #[test]
    fn matches_monte_carlo() {
        // The independent Monte-Carlo estimate must agree within CI.
        for (procs, s, f) in [(16usize, 1u32, 3usize), (16, 2, 6), (32, 2, 8)] {
            let exact = survival_exact_f_at_round(procs, s, f);
            let est = SurvivalSweep::new(Algo::Replace, procs).with_trials(20_000).at_round(s, f);
            let diff = (est.probability() - exact).abs();
            assert!(
                diff < est.ci95() + 0.01,
                "P={procs} s={s} f={f}: exact {exact} vs MC {} (±{})",
                est.probability(),
                est.ci95()
            );
        }
    }

    #[test]
    fn median_tolerated_grows_with_s() {
        let m1 = median_tolerated(64, 1);
        let m3 = median_tolerated(64, 3);
        assert!(m3 > m1, "robustness grows with the step: {m1} vs {m3}");
    }

    #[test]
    fn applicability() {
        assert!(applicable(16, 3));
        assert!(!applicable(12, 1));
        assert!(!applicable(16, 4));
    }

    #[test]
    fn pair_wipe_distribution_sums_to_one() {
        for (procs, f) in [(8usize, 0usize), (8, 3), (8, 5), (16, 7), (6, 4), (100, 13)] {
            let d = pair_wipe_distribution(procs, f);
            let total: f64 = d.iter().sum();
            assert!((total - 1.0).abs() < 1e-10, "P={procs} f={f}: Σ={total}");
            assert!(d.iter().all(|&p| (0.0..=1.0 + 1e-12).contains(&p)));
        }
    }

    #[test]
    fn pair_wipe_zero_failures_wipes_nothing() {
        let d = pair_wipe_distribution(8, 0);
        assert_eq!(d.len(), 1);
        assert!((d[0] - 1.0).abs() < 1e-12);
        // One failure can never complete a pair either.
        assert!((pair_wipe_distribution(8, 1)[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pair_wipe_exact_small_case() {
        // P=4 (pairs {0,1},{2,3}), f=2: of C(4,2)=6 kill sets, exactly
        // 2 complete a pair.
        let d = pair_wipe_distribution(4, 2);
        assert!((d[0] - 4.0 / 6.0).abs() < 1e-12, "{d:?}");
        assert!((d[1] - 2.0 / 6.0).abs() < 1e-12, "{d:?}");
        // f = procs kills every pair with certainty.
        let all = pair_wipe_distribution(4, 4);
        assert!((all[2] - 1.0).abs() < 1e-12);
        // f beyond procs clamps to "everyone dead".
        let over = pair_wipe_distribution(4, 9);
        assert!((over[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn survival_with_zero_checksums_matches_inclusion_exclusion() {
        // Two independent derivations of replication-only survival:
        // the pairs are exactly the level-1 groups of the round formula.
        for procs in [8usize, 16, 32] {
            for f in 0..=procs {
                let direct = survival_with_checksums(procs, f, 0);
                let incl_excl = survival_exact_f_at_round(procs, 1, f);
                assert!(
                    (direct - incl_excl).abs() < 1e-9,
                    "P={procs} f={f}: {direct} vs {incl_excl}"
                );
            }
        }
    }

    #[test]
    fn checksums_lift_survival_monotonically() {
        let procs = 16;
        let f = 6;
        let mut prev = 0.0;
        for c in 0..=procs / 2 {
            let s = survival_with_checksums(procs, f, c);
            assert!(s >= prev - 1e-12, "c={c}: {s} < {prev}");
            prev = s;
        }
        // Enough checksums to cover every possible wipe: certainty.
        assert!((survival_with_checksums(procs, f, f / 2) - 1.0).abs() < 1e-10);
        // The bound is tight: c covers exactly c wipes, not c+1.
        assert!(survival_with_checksums(4, 4, 1) < 1.0);
        assert!((survival_with_checksums(4, 4, 2) - 1.0).abs() < 1e-12);
    }
}
