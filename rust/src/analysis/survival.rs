//! Monte-Carlo survival estimation over the analytic simulator — the
//! engine behind the robustness tables (TAB-R1/R2/R3) and the
//! reliability sweep (TAB-S1).

use std::collections::HashMap;

use crate::tsqr::{Algo, TreePlan};
use crate::ulfm::Rank;
use crate::util::Rng;

use super::robustness::survives_failure_set;

/// One survival estimate.
#[derive(Debug, Clone, Copy)]
pub struct SurvivalEstimate {
    /// Samples drawn.
    pub trials: u64,
    /// Samples that survived.
    pub successes: u64,
}

impl SurvivalEstimate {
    /// Point estimate `successes / trials` (0 on zero trials).
    pub fn probability(&self) -> f64 {
        if self.trials == 0 {
            return 0.0;
        }
        self.successes as f64 / self.trials as f64
    }

    /// 95% normal-approximation half-width.
    pub fn ci95(&self) -> f64 {
        let p = self.probability();
        1.96 * (p * (1.0 - p) / self.trials.max(1) as f64).sqrt()
    }
}

/// Parameterized Monte-Carlo sweep.
#[derive(Debug, Clone, Copy)]
pub struct SurvivalSweep {
    /// Algorithm under test.
    pub algo: Algo,
    /// World size.
    pub procs: usize,
    /// Samples per cell.
    pub trials: u64,
    /// Base seed of the sample stream.
    pub seed: u64,
}

impl SurvivalSweep {
    /// A sweep with 2000 trials per cell.
    pub fn new(algo: Algo, procs: usize) -> Self {
        Self { algo, procs, trials: 2000, seed: 0xC0711 }
    }

    /// Replace the per-cell trial count.
    pub fn with_trials(mut self, t: u64) -> Self {
        self.trials = t;
        self
    }

    /// Replace the base seed.
    pub fn with_seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// P(success | exactly `f` distinct ranks die at round boundary
    /// `round`) — the direct check of the `2^s − 1` claim: for
    /// `f <= 2^round − 1` Replace/Self-Healing must be at 1.0.
    pub fn at_round(&self, round: u32, f: usize) -> SurvivalEstimate {
        let mut rng = Rng::new(self.seed ^ ((round as u64) << 32) ^ f as u64);
        let mut successes = 0;
        for _ in 0..self.trials {
            let pattern = sample_distinct(&mut rng, self.procs, round, f);
            if survives_failure_set(self.algo, self.procs, &pattern).success(self.algo) {
                successes += 1;
            }
        }
        SurvivalEstimate { trials: self.trials, successes }
    }

    /// P(success) when every rank dies independently at each boundary
    /// with probability `p` (Bernoulli-per-step model).
    pub fn bernoulli(&self, p: f64) -> SurvivalEstimate {
        let plan = TreePlan::new(self.procs);
        let rounds = plan.rounds();
        let mut rng = Rng::new(self.seed ^ p.to_bits());
        let mut successes = 0;
        for _ in 0..self.trials {
            let mut pattern: HashMap<Rank, u32> = HashMap::new();
            for r in 0..self.procs {
                for s in 0..rounds {
                    if rng.bool(p) {
                        pattern.insert(r, s);
                        break;
                    }
                }
            }
            if survives_failure_set(self.algo, self.procs, &pattern).success(self.algo) {
                successes += 1;
            }
        }
        SurvivalEstimate { trials: self.trials, successes }
    }

    /// P(success) under per-rank exponential lifetimes with the given
    /// rate (deaths per step) — the Reed-et-al-style model (TAB-S1).
    pub fn exponential(&self, rate: f64) -> SurvivalEstimate {
        let plan = TreePlan::new(self.procs);
        let rounds = plan.rounds();
        let mut rng = Rng::new(self.seed ^ rate.to_bits());
        let mut successes = 0;
        for _ in 0..self.trials {
            let mut pattern: HashMap<Rank, u32> = HashMap::new();
            for r in 0..self.procs {
                let t = rng.exponential(rate);
                let round = t.ceil() as u64;
                if round <= rounds as u64 {
                    pattern.insert(r, (round as u32).min(rounds.saturating_sub(1)).max(0));
                }
            }
            if survives_failure_set(self.algo, self.procs, &pattern).success(self.algo) {
                successes += 1;
            }
        }
        SurvivalEstimate { trials: self.trials, successes }
    }
}

/// Sample `f` distinct ranks killed at `round` (uniform without
/// replacement).
fn sample_distinct(rng: &mut Rng, procs: usize, round: u32, f: usize) -> HashMap<Rank, u32> {
    let mut pool: Vec<Rank> = (0..procs).collect();
    let mut pattern = HashMap::new();
    for _ in 0..f.min(procs) {
        let i = rng.below(pool.len());
        pattern.insert(pool.swap_remove(i), round);
    }
    pattern
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimate_arithmetic() {
        let e = SurvivalEstimate { trials: 100, successes: 50 };
        assert!((e.probability() - 0.5).abs() < 1e-12);
        assert!(e.ci95() > 0.0 && e.ci95() < 0.2);
        assert_eq!(SurvivalEstimate { trials: 0, successes: 0 }.probability(), 0.0);
    }

    #[test]
    fn replace_is_certain_within_bound() {
        // f <= 2^s - 1 failures at boundary s: Replace always survives.
        let sweep = SurvivalSweep::new(Algo::Replace, 16).with_trials(300);
        for s in 1..4u32 {
            let f = ((1u64 << s) - 1) as usize;
            let est = sweep.at_round(s, f);
            assert_eq!(est.probability(), 1.0, "round {s}, f {f}");
        }
    }

    #[test]
    fn replace_can_fail_past_bound() {
        // Killing 2^s ranks at boundary s sometimes wipes a whole group.
        let sweep = SurvivalSweep::new(Algo::Replace, 8).with_trials(2000);
        let est = sweep.at_round(1, 4); // far beyond 2^1 - 1 = 1
        assert!(est.probability() < 1.0, "p = {}", est.probability());
        assert!(est.probability() > 0.0, "most patterns still survive");
    }

    #[test]
    fn redundant_weaker_than_replace_at_same_f() {
        let f = 4;
        let red = SurvivalSweep::new(Algo::Redundant, 16).with_trials(1500).at_round(2, f);
        let rep = SurvivalSweep::new(Algo::Replace, 16).with_trials(1500).at_round(2, f);
        assert!(
            rep.probability() >= red.probability(),
            "replace {} < redundant {}",
            rep.probability(),
            red.probability()
        );
    }

    #[test]
    fn bernoulli_monotone_in_p() {
        let sweep = SurvivalSweep::new(Algo::Replace, 16).with_trials(800);
        let lo = sweep.bernoulli(0.01).probability();
        let hi = sweep.bernoulli(0.2).probability();
        assert!(lo >= hi, "more failures, lower survival ({lo} vs {hi})");
    }

    #[test]
    fn exponential_baseline_dies_fast() {
        let base = SurvivalSweep::new(Algo::Baseline, 16).with_trials(800).exponential(0.05);
        let rep = SurvivalSweep::new(Algo::Replace, 16).with_trials(800).exponential(0.05);
        assert!(rep.probability() > base.probability());
    }

    #[test]
    fn deterministic_in_seed() {
        let a = SurvivalSweep::new(Algo::Replace, 8).with_trials(200).at_round(1, 2);
        let b = SurvivalSweep::new(Algo::Replace, 8).with_trials(200).at_round(1, 2);
        assert_eq!(a.successes, b.successes);
    }
}
