//! Coded-vs-replicated tolerance sweep: how many simultaneous process
//! failures each [`RecoveryPolicy`] rides through, measured on the
//! full CAQR stack.
//!
//! Replication alone tolerates one loss per replica pair per stage —
//! an *adversarial* failure pattern that completes a pair kills the
//! run at `f = 2`.  The checksum rung lifts that: every wiped pair
//! costs checksum capacity instead of the run, until the `c` checksums
//! are exhausted.  [`CodedSweep`] measures the crossover empirically:
//! for a fixed world it kills `f = 1, 2, …` ranks (pair-completing
//! order, the worst case for replication) during panel 0's update
//! stage and reports the largest `f` each `(policy, c)` survives — the
//! tables `docs/PAPER_MAP.md` quotes and `tests/failure_semantics.rs`
//! pins.

use crate::abft::RecoveryPolicy;
use crate::caqr::CaqrSpec;
use crate::engine::Engine;
use crate::error::Result;
use crate::fault::{CaqrKillSchedule, CaqrStage};
use crate::tsqr::Algo;
use crate::ulfm::Rank;

/// One row of the coded-tolerance table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CodedRow {
    /// Recovery ladder measured.
    pub policy: RecoveryPolicy,
    /// Checksum blocks armed.
    pub checksums: usize,
    /// Largest adversarial same-stage failure count survived.
    pub tolerated: usize,
}

/// Deterministic tolerated-failure sweep over recovery policies (see
/// the [module docs](self)).  Runs under [`Algo::Redundant`] — the
/// worst case: the dead stay dead, so panel 0's losses echo through
/// every later panel.
pub struct CodedSweep<'e> {
    engine: &'e Engine,
    /// World size (even, ≥ 2).
    pub procs: usize,
    /// Block-column width; the sweep factors a square
    /// `(procs·panel) × (procs·panel)` matrix, one panel per process.
    pub panel: usize,
    /// Input-matrix seed.
    pub seed: u64,
}

impl<'e> CodedSweep<'e> {
    /// A sweep over `procs` simulated processes (4-column panels).
    pub fn new(engine: &'e Engine, procs: usize) -> Self {
        Self { engine, procs, panel: 4, seed: 42 }
    }

    /// Replace the block-column width.
    pub fn with_panel(mut self, panel: usize) -> Self {
        self.panel = panel.max(1);
        self
    }

    /// Replace the input-matrix seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// The adversarial kill order: complete replica pairs one by one,
    /// hitting each pair's update-task *owner* first (`1, 0, 3, 2, …`)
    /// — the pattern replication is weakest against.
    pub fn kill_order(procs: usize) -> Vec<Rank> {
        (0..procs / 2).flat_map(|g| [2 * g + 1, 2 * g]).collect()
    }

    /// Does one run with the first `f` kills of the adversarial order
    /// (fired during panel 0's update stage) complete?
    pub fn survives(&self, policy: RecoveryPolicy, checksums: usize, f: usize) -> Result<bool> {
        let n = self.procs * self.panel;
        let kills: Vec<(Rank, usize, CaqrStage)> = Self::kill_order(self.procs)
            .into_iter()
            .take(f)
            .map(|r| (r, 0, CaqrStage::Update))
            .collect();
        let spec = CaqrSpec::new(Algo::Redundant, self.procs, n, n, self.panel)
            .with_seed(self.seed)
            .with_verify(false)
            .with_policy(policy)
            .with_checksums(checksums)
            .with_schedule(CaqrKillSchedule::at(&kills));
        Ok(self.engine.run_caqr(spec)?.success())
    }

    /// Largest `f` the `(policy, c)` pair survives.  Monotone in `f`
    /// (the kill sets are nested), so the scan stops at the first
    /// failure.
    pub fn tolerated_failures(&self, policy: RecoveryPolicy, checksums: usize) -> Result<usize> {
        let mut tolerated = 0;
        for f in 1..=self.procs {
            if self.survives(policy, checksums, f)? {
                tolerated = f;
            } else {
                break;
            }
        }
        Ok(tolerated)
    }

    /// The tolerance table: replication-only, then replication +
    /// checksums for each requested `c` (and the un-replicated
    /// checksum-only ladder alongside) — the comparison the ABFT layer
    /// exists to win.
    pub fn table(&self, checksum_counts: &[usize]) -> Result<Vec<CodedRow>> {
        let mut rows = vec![CodedRow {
            policy: RecoveryPolicy::Replica,
            checksums: 0,
            tolerated: self.tolerated_failures(RecoveryPolicy::Replica, 0)?,
        }];
        for &c in checksum_counts {
            rows.push(CodedRow {
                policy: RecoveryPolicy::Hybrid,
                checksums: c,
                tolerated: self.tolerated_failures(RecoveryPolicy::Hybrid, c)?,
            });
        }
        for &c in checksum_counts {
            rows.push(CodedRow {
                policy: RecoveryPolicy::Checksum,
                checksums: c,
                tolerated: self.tolerated_failures(RecoveryPolicy::Checksum, c)?,
            });
        }
        Ok(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kill_order_completes_pairs_owner_first() {
        assert_eq!(CodedSweep::kill_order(4), vec![1, 0, 3, 2]);
        assert_eq!(CodedSweep::kill_order(8), vec![1, 0, 3, 2, 5, 4, 7, 6]);
    }

    #[test]
    fn replication_only_dies_at_the_first_completed_pair() {
        let engine = Engine::host();
        let sweep = CodedSweep::new(&engine, 4);
        assert_eq!(sweep.tolerated_failures(RecoveryPolicy::Replica, 0).unwrap(), 1);
    }

    #[test]
    fn hybrid_tolerates_strictly_more_than_replication() {
        let engine = Engine::host();
        let sweep = CodedSweep::new(&engine, 4);
        let replica = sweep.tolerated_failures(RecoveryPolicy::Replica, 0).unwrap();
        let hybrid = sweep.tolerated_failures(RecoveryPolicy::Hybrid, 1).unwrap();
        assert!(
            hybrid > replica,
            "one checksum must beat replication alone ({hybrid} vs {replica})"
        );
    }

    #[test]
    fn table_rows_cover_every_requested_cell() {
        let engine = Engine::host();
        let rows = CodedSweep::new(&engine, 4).with_panel(2).table(&[1]).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].policy, RecoveryPolicy::Replica);
        assert_eq!(rows[1].policy, RecoveryPolicy::Hybrid);
        assert_eq!(rows[2].policy, RecoveryPolicy::Checksum);
        assert!(rows[1].tolerated >= rows[0].tolerated);
    }
}
