//! The paper-level question (arXiv:0806.3121, ROADMAP open item 1):
//! at which failure rate does coded ABFT beat plain replication beat
//! checkpoint/restart?
//!
//! [`CheckpointVsRedundant`] races the three contenders over one
//! `(procs, panels)` plan on **one virtual clock**:
//!
//! * **replication** — the `sim::` replay under
//!   [`RecoveryPolicy::Replica`]: free redundancy, dies on the first
//!   pair wipe;
//! * **coded** — the `sim::` replay under [`RecoveryPolicy::Hybrid`]
//!   with `c` picked by [`AdaptivePolicy`] for the cell's rate (floored
//!   at 1 so the column is always actually coded);
//! * **checkpoint** — [`CheckpointBaseline`], periodic R/reflector
//!   snapshots with restart cost in
//!   [`VirtualTimeBreakdown::recovery_ns`].
//!
//! A cell's winner is the contender with the highest survival, ties
//! (usually everyone-survives at low rates) broken by total virtual
//! time — which is where checkpointing loses fault-free (snapshot
//! traffic) and replication wins (its redundancy costs nothing extra).
//! The crossover table is what `repro compare` prints and the
//! `checkpoint_vs_redundant` bench ships as `BENCH_compare.json`;
//! [`CompareCell::engine_default`] maps the winner onto the recovery
//! ladder the engine should default to (checkpointing is a baseline,
//! not an execution path, so a checkpoint win falls back to the better
//! redundant ladder).
//!
//! [`AdaptivePolicy`]: crate::analysis::AdaptivePolicy
//! [`CheckpointBaseline`]: crate::checkpoint::CheckpointBaseline

use crate::abft::RecoveryPolicy;
use crate::analysis::adaptive::AdaptivePolicy;
use crate::checkpoint::CheckpointBaseline;
use crate::engine::Engine;
use crate::error::Result;
use crate::metrics::VirtualTimeBreakdown;
use crate::sim::{CostModel, SimScenario};
use crate::tsqr::Algo;

/// The three fault-tolerance families under comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Contender {
    /// Pair replication only (the paper's redundancy-for-free).
    Replication,
    /// Replication + Vandermonde checksums, `c` from the failure model.
    Coded,
    /// Periodic neighbour checkpointing with rollback restart.
    Checkpoint,
}

impl Contender {
    /// Display name (tables and JSON).
    pub fn name(&self) -> &'static str {
        match self {
            Contender::Replication => "replication",
            Contender::Coded => "coded",
            Contender::Checkpoint => "checkpoint",
        }
    }
}

/// One contender's result at one failure rate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Outcome {
    /// Fraction of samples that completed.
    pub survival: f64,
    /// Merged virtual time across samples.
    pub time: VirtualTimeBreakdown,
    /// Checksum blocks armed (0 for replication and checkpoint).
    pub checksums: usize,
}

/// One row of the crossover table.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompareCell {
    /// Deaths per rank per virtual second.
    pub rate: f64,
    /// Replication-only ladder.
    pub replication: Outcome,
    /// Adaptive coded ladder.
    pub coded: Outcome,
    /// Checkpoint/restart baseline.
    pub checkpoint: Outcome,
    /// Best contender at this rate.
    pub winner: Contender,
}

impl CompareCell {
    /// The recovery ladder the engine should default to given this
    /// cell's winner.  Checkpointing is a comparator baseline, not an
    /// engine execution path, so a checkpoint win defers to whichever
    /// redundant ladder did better.
    pub fn engine_default(&self) -> RecoveryPolicy {
        match self.winner {
            Contender::Replication => RecoveryPolicy::Replica,
            Contender::Coded => RecoveryPolicy::Hybrid,
            Contender::Checkpoint => {
                if better(&self.coded, &self.replication) {
                    RecoveryPolicy::Hybrid
                } else {
                    RecoveryPolicy::Replica
                }
            }
        }
    }
}

/// `a` beats `b`: higher survival, ties broken by less virtual time.
fn better(a: &Outcome, b: &Outcome) -> bool {
    if (a.survival - b.survival).abs() > 1e-9 {
        return a.survival > b.survival;
    }
    a.time.total_ns() < b.time.total_ns()
}

/// The comparator: three contenders, one plan, one clock.
#[derive(Debug, Clone, Copy)]
pub struct CheckpointVsRedundant<'e> {
    engine: &'e Engine,
    /// World size (even, as the replica pairing requires).
    pub procs: usize,
    /// Panels in the plan.
    pub panels: usize,
    /// Block-column width.
    pub panel: usize,
    /// Monte-Carlo samples per contender per cell.
    pub samples: u64,
    /// Base seed (shared by all three contenders for fairness).
    pub seed: u64,
    /// Checkpoint interval in panels.
    pub interval: usize,
    /// Virtual stage costs, shared across contenders.
    pub costs: CostModel,
}

impl<'e> CheckpointVsRedundant<'e> {
    /// A comparator over `(procs, panels)` with simulator-default
    /// costs, 32 samples per cell, checkpointing every panel.
    pub fn new(engine: &'e Engine, procs: usize, panels: usize) -> Self {
        Self {
            engine,
            procs,
            panels,
            panel: 8,
            samples: 32,
            seed: 0xc0de,
            interval: 1,
            costs: CostModel::default(),
        }
    }

    /// Block-column width.
    pub fn with_panel(mut self, panel: usize) -> Self {
        self.panel = panel;
        self
    }

    /// Samples per contender per cell.
    pub fn with_samples(mut self, samples: u64) -> Self {
        self.samples = samples;
        self
    }

    /// Base seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Checkpoint interval in panels.
    pub fn with_interval(mut self, interval: usize) -> Self {
        self.interval = interval;
        self
    }

    /// Virtual stage costs.
    pub fn with_costs(mut self, costs: CostModel) -> Self {
        self.costs = costs;
        self
    }

    /// The scenario both redundant contenders replay, minus the ladder.
    fn scenario(&self, rate: f64) -> SimScenario {
        let mut sc = SimScenario {
            name: "compare".into(),
            procs: self.procs,
            panels: self.panels,
            panel: self.panel,
            algo: Algo::SelfHealing,
            samples: self.samples,
            seed: self.seed,
            costs: self.costs,
            ..SimScenario::default()
        };
        sc.churn.fail_rate = rate;
        sc
    }

    fn redundant_outcome(&self, rate: f64, policy: RecoveryPolicy, c: usize) -> Result<Outcome> {
        let mut sc = self.scenario(rate);
        sc.policy = policy;
        sc.checksums = c;
        let report = self.engine.simulate(&sc)?;
        Ok(Outcome {
            survival: report.survival().probability(),
            time: report.time(),
            checksums: sc.armed_checksums(),
        })
    }

    /// Race the three contenders at one failure rate.
    pub fn cell(&self, rate: f64) -> Result<CompareCell> {
        let replication = self.redundant_outcome(rate, RecoveryPolicy::Replica, 0)?;

        // The coded column is always genuinely coded: the adaptive
        // policy picks c for the cell, floored at 1 (when it says
        // "replication suffices" the replication column already shows
        // that outcome).
        let choice = AdaptivePolicy::new(rate).with_costs(self.costs).choose(self.procs, self.panels);
        let c = choice.checksums.clamp(1, self.procs / 2);
        let coded = self.redundant_outcome(rate, RecoveryPolicy::Hybrid, c)?;

        let ckpt = CheckpointBaseline::new(self.procs, self.panels)
            .with_rate(rate)
            .with_interval(self.interval)
            .with_costs(self.costs)
            .with_seed(self.seed)
            .campaign(self.samples);
        let checkpoint =
            Outcome { survival: ckpt.survival(), time: ckpt.time, checksums: 0 };

        let mut winner = Contender::Replication;
        let mut best = replication;
        if better(&coded, &best) {
            winner = Contender::Coded;
            best = coded;
        }
        if better(&checkpoint, &best) {
            winner = Contender::Checkpoint;
        }
        Ok(CompareCell { rate, replication, coded, checkpoint, winner })
    }

    /// The crossover table: one cell per rate.
    pub fn table(&self, rates: &[f64]) -> Result<Vec<CompareCell>> {
        rates.iter().map(|&r| self.cell(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineBuilder;

    fn engine() -> Engine {
        EngineBuilder::new().host_only().threads(2).build().unwrap()
    }

    #[test]
    fn fault_free_cell_everyone_survives_and_replication_wins_on_time() {
        let engine = engine();
        let cmp = CheckpointVsRedundant::new(&engine, 64, 4).with_samples(4);
        let cell = cmp.cell(0.0).unwrap();
        assert_eq!(cell.replication.survival, 1.0);
        assert_eq!(cell.coded.survival, 1.0);
        assert_eq!(cell.checkpoint.survival, 1.0);
        // Checkpointing pays snapshot traffic even fault-free; the
        // redundant families pay nothing extra on the network axis.
        assert!(cell.checkpoint.time.network_ns > 0);
        // All survive, so time decides — and replication is never
        // slower than its own superset ladder plus checksum work.
        assert_eq!(cell.winner, Contender::Replication);
        assert_eq!(cell.engine_default(), RecoveryPolicy::Replica);
        // The coded column is genuinely coded even when the adaptive
        // policy would have said "replication suffices".
        assert!(cell.coded.checksums >= 1);
    }

    #[test]
    fn high_churn_cell_crosses_over_to_coded() {
        let engine = engine();
        // A rate chosen past the replication knee at this world size
        // (the adaptive-policy tests pin the knee's location).
        let cmp = CheckpointVsRedundant::new(&engine, 1024, 4).with_samples(8);
        let lo = cmp.cell(0.5).unwrap();
        let hi = cmp.cell(400.0).unwrap();
        assert!(
            hi.coded.survival >= hi.replication.survival,
            "coded must not lose survival to replication: {} vs {}",
            hi.coded.survival,
            hi.replication.survival
        );
        assert!(hi.coded.checksums >= lo.coded.checksums, "steeper cell, at least as much coding");
        // The table orders by rate and keeps each cell's rate.
        let table = cmp.table(&[0.5, 400.0]).unwrap();
        assert_eq!(table.len(), 2);
        assert_eq!(table[0].rate, 0.5);
        assert_eq!(table[1].rate, 400.0);
    }

    #[test]
    fn winner_maps_onto_an_engine_recovery_policy() {
        let time = VirtualTimeBreakdown::default();
        let o = |survival, total| Outcome {
            survival,
            time: VirtualTimeBreakdown { compute_ns: total, ..time },
            checksums: 0,
        };
        // Checkpoint winner defers to the better redundant ladder.
        let cell = CompareCell {
            rate: 1.0,
            replication: o(0.5, 100),
            coded: o(0.9, 120),
            checkpoint: o(1.0, 200),
            winner: Contender::Checkpoint,
        };
        assert_eq!(cell.engine_default(), RecoveryPolicy::Hybrid);
        let cell2 = CompareCell { replication: o(0.9, 80), coded: o(0.9, 120), ..cell };
        assert_eq!(cell2.engine_default(), RecoveryPolicy::Replica, "tie broken by time");
    }
}
