//! Discrete-event survival sweeps: the mega-scale counterpart of
//! [`super::fullsim`].
//!
//! [`FullSimSweep`](super::FullSimSweep) measures survival on the real
//! concurrent implementation — gold-standard semantics, but each
//! sample actually factors a matrix, which caps the world size at tens
//! of ranks.  [`SimSweep`] measures the same quantity on the
//! [`crate::sim`] event-driven replay, where a sample at P = 10⁶ costs
//! the same per panel as one at P = 8, so survival *curves over the
//! failure rate* at datacenter scale take seconds.  Both report
//! [`SurvivalEstimate`], so tables mix freely — and the small-P parity
//! pin (`tests/integration_sim.rs`) is what licenses quoting the two
//! side by side.

use crate::engine::Engine;
use crate::error::Result;
use crate::sim::{ChurnModel, SimScenario};
use crate::tsqr::Algo;
use crate::abft::RecoveryPolicy;
use crate::util::derive_seed;

use super::survival::SurvivalEstimate;

/// Monte-Carlo survival sweep over Poisson failure rates, batched
/// through [`Engine::simulate`].
pub struct SimSweep<'e> {
    engine: &'e Engine,
    /// Failure semantics (`Redundant` or `SelfHealing`).
    pub algo: Algo,
    /// Simulated world size (this axis is the point: 10⁵–10⁶ work).
    pub procs: usize,
    /// Panels per sampled factorization.
    pub panels: usize,
    /// Block-column width.
    pub panel: usize,
    /// Recovery ladder the samples run.
    pub policy: RecoveryPolicy,
    /// Checksum blocks armed per panel stage.
    pub checksums: usize,
    /// Monte-Carlo samples per rate cell.
    pub samples: u64,
    /// Base seed of the sample stream.
    pub seed: u64,
}

impl<'e> SimSweep<'e> {
    /// Defaults: 16 panels of width 8, replica ladder, 100 samples.
    pub fn new(engine: &'e Engine, algo: Algo, procs: usize) -> Self {
        Self {
            engine,
            algo,
            procs,
            panels: 16,
            panel: 8,
            policy: RecoveryPolicy::Replica,
            checksums: 0,
            samples: 100,
            seed: 0x51A0,
        }
    }

    /// Replace the per-cell sample count.
    pub fn with_samples(mut self, samples: u64) -> Self {
        self.samples = samples.max(1);
        self
    }

    /// Replace the panel shape.
    pub fn with_shape(mut self, panels: usize, panel: usize) -> Self {
        self.panels = panels.max(1);
        self.panel = panel.max(1);
        self
    }

    /// Replace the recovery ladder.
    pub fn with_policy(mut self, policy: RecoveryPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Arm `c` checksum blocks per panel stage.
    pub fn with_checksums(mut self, c: usize) -> Self {
        self.checksums = c;
        self
    }

    /// Replace the base seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// The scenario one rate cell runs (each cell gets its own seed
    /// stream so curves don't share failure patterns across rates).
    fn scenario(&self, rate: f64) -> SimScenario {
        SimScenario {
            name: format!("simsweep-p{}-rate{rate}", self.procs),
            procs: self.procs,
            panels: self.panels,
            panel: self.panel,
            algo: self.algo,
            policy: self.policy,
            checksums: self.checksums,
            samples: self.samples,
            seed: derive_seed(self.seed, rate.to_bits()),
            churn: ChurnModel { fail_rate: rate, ..Default::default() },
            ..Default::default()
        }
    }

    /// P(factorization completes) under independent per-rank Poisson
    /// failures at `rate` deaths per rank per virtual second.
    pub fn at_rate(&self, rate: f64) -> Result<SurvivalEstimate> {
        Ok(self.engine.simulate(&self.scenario(rate))?.survival())
    }

    /// The survival curve over a list of failure rates — what
    /// `repro simulate --curve` prints.
    pub fn curve(&self, rates: &[f64]) -> Result<Vec<(f64, SurvivalEstimate)>> {
        rates.iter().map(|&r| Ok((r, self.at_rate(r)?))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rate_is_certain_even_at_scale() {
        let engine = Engine::host();
        let est = SimSweep::new(&engine, Algo::Redundant, 10_000)
            .with_samples(8)
            .at_rate(0.0)
            .unwrap();
        assert_eq!(est.trials, 8);
        assert_eq!(est.probability(), 1.0, "no churn, no deaths");
    }

    #[test]
    fn deterministic_in_seed_and_monotone_setup() {
        let engine = Engine::host();
        let sweep = SimSweep::new(&engine, Algo::SelfHealing, 64)
            .with_shape(4, 4)
            .with_samples(12)
            .with_policy(RecoveryPolicy::Hybrid)
            .with_checksums(4);
        let a = sweep.at_rate(50.0).unwrap();
        let b = sweep.at_rate(50.0).unwrap();
        assert_eq!(a.successes, b.successes, "same seed stream, same outcome");
        let curve = sweep.curve(&[0.0, 50.0]).unwrap();
        assert_eq!(curve.len(), 2);
        assert_eq!(curve[0].1.probability(), 1.0);
        assert!(curve[1].1.probability() <= 1.0);
    }

    #[test]
    fn rate_cells_use_distinct_seed_streams() {
        let engine = Engine::host();
        let sweep = SimSweep::new(&engine, Algo::Redundant, 16);
        let a = sweep.scenario(0.1).seed;
        let b = sweep.scenario(0.2).seed;
        assert_ne!(a, b, "each rate cell reseeds");
    }
}
