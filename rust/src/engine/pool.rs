//! The engine's reusable worker substrate: an elastic pool of OS
//! threads that replaces the spawn-one-thread-per-rank-per-run
//! lifecycle of the original runner.
//!
//! ## Why elastic
//!
//! Simulated ranks are *blocking* tasks: they park on the world's
//! condvars waiting for a peer's post, so a fixed-size pool smaller
//! than the world would deadlock a run (every worker blocked on a rank
//! that is still queued).  The pool therefore maintains the invariant
//! that **every queued task has a free worker that will pick it up**:
//! `execute` spawns a new worker only when the queue outgrows the set
//! of free (non-busy) workers, and workers are never retired until
//! shutdown.  Steady state — the whole point of the engine — is zero
//! spawns: a campaign of thousands of P=8 runs settles at 8 parked
//! workers that are reused run after run.
//!
//! ## TaskGroup
//!
//! One run spawns its P rank bodies plus, for Self-Healing, any number
//! of dynamically respawned replacements — all through the same pool.
//! [`TaskGroup`] gives the run coordinator a completion latch over
//! exactly *its* tasks (the pool is shared across concurrent runs), so
//! results and traces are only collected once every process body of
//! this run has fully returned.  The latch fires *after* the worker is
//! accounted free again, which is what makes worker counts stable (and
//! assertable) across back-to-back runs.

use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

/// A queued unit of work: the task body plus an optional completion
/// hook that runs after the worker has been marked free again.
struct TaskEntry {
    run: Task,
    done: Option<Task>,
}

#[derive(Default)]
struct PoolState {
    queue: VecDeque<TaskEntry>,
    /// Workers currently executing a task body.
    busy: usize,
    workers: usize,
    peak_workers: usize,
    shutdown: bool,
}

impl PoolState {
    /// Workers that are alive and not executing a task — they are in
    /// the pool loop and guaranteed to drain the queue.
    fn free(&self) -> usize {
        self.workers - self.busy
    }
}

struct Shared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
    handles: Mutex<Vec<JoinHandle<()>>>,
    next_worker_id: AtomicU64,
    tasks_executed: AtomicU64,
    task_panics: AtomicU64,
}

/// Elastic worker pool.  Cheap to clone (`Arc` inside); all clones
/// address the same pool.
#[derive(Clone)]
pub struct WorkerPool {
    shared: Arc<Shared>,
}

impl Default for WorkerPool {
    fn default() -> Self {
        Self::new()
    }
}

impl WorkerPool {
    /// An empty pool (workers spawn on demand).
    pub fn new() -> Self {
        Self {
            shared: Arc::new(Shared {
                state: Mutex::new(PoolState::default()),
                work_cv: Condvar::new(),
                handles: Mutex::new(Vec::new()),
                next_worker_id: AtomicU64::new(0),
                tasks_executed: AtomicU64::new(0),
                task_panics: AtomicU64::new(0),
            }),
        }
    }

    /// A pool with `n` workers already parked (skips the first-run
    /// spawn cost for latency-sensitive sessions).
    pub fn with_prewarmed(n: usize) -> Self {
        let pool = Self::new();
        {
            let mut st = pool.shared.state.lock().unwrap();
            for _ in 0..n {
                pool.spawn_worker(&mut st);
            }
        }
        pool
    }

    /// Hand a task to the pool.  Never blocks on task completion and
    /// never deadlocks: if no free worker exists a new one is spawned.
    pub fn execute(&self, task: impl FnOnce() + Send + 'static) {
        self.enqueue(TaskEntry { run: Box::new(task), done: None });
    }

    /// Like [`execute`](Self::execute), with a completion hook that
    /// runs after the worker is accounted free again ([`TaskGroup`]'s
    /// latch ordering).
    pub fn execute_with_completion(
        &self,
        task: impl FnOnce() + Send + 'static,
        done: impl FnOnce() + Send + 'static,
    ) {
        self.enqueue(TaskEntry { run: Box::new(task), done: Some(Box::new(done)) });
    }

    fn enqueue(&self, entry: TaskEntry) {
        let mut st = self.shared.state.lock().unwrap();
        if st.shutdown {
            // The engine is being torn down but a straggler (e.g. a run
            // the user abandoned mid-flight) still wants to spawn:
            // degrade gracefully to a one-shot thread.
            drop(st);
            std::thread::spawn(move || run_entry(entry, None));
            return;
        }
        st.queue.push_back(entry);
        if st.queue.len() > st.free() {
            self.spawn_worker(&mut st);
        } else {
            self.shared.work_cv.notify_one();
        }
    }

    fn spawn_worker(&self, st: &mut PoolState) {
        st.workers += 1;
        st.peak_workers = st.peak_workers.max(st.workers);
        let id = self.shared.next_worker_id.fetch_add(1, Ordering::Relaxed);
        let shared = Arc::clone(&self.shared);
        let handle = std::thread::Builder::new()
            .name(format!("engine-worker-{id}"))
            .spawn(move || worker_loop(shared))
            .expect("spawn engine worker");
        self.shared.handles.lock().unwrap().push(handle);
    }

    /// Worker threads currently alive (busy + free).
    pub fn workers(&self) -> usize {
        self.shared.state.lock().unwrap().workers
    }

    /// Workers currently free to take new work.
    pub fn free_workers(&self) -> usize {
        self.shared.state.lock().unwrap().free()
    }

    /// High-water mark of concurrent workers over the pool's lifetime.
    pub fn peak_workers(&self) -> usize {
        self.shared.state.lock().unwrap().peak_workers
    }

    /// Tasks executed over the pool's lifetime.
    pub fn tasks_executed(&self) -> u64 {
        self.shared.tasks_executed.load(Ordering::Relaxed)
    }

    /// Tasks whose body panicked (the worker survives).
    pub fn task_panics(&self) -> u64 {
        self.shared.task_panics.load(Ordering::Relaxed)
    }

    /// Drain remaining tasks, stop and join every worker.  Idempotent;
    /// called by `Engine::drop`.
    pub fn shutdown(&self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.shutdown {
                return;
            }
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.shared.handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Run one entry outside the pool (shutdown fallback path).
fn run_entry(mut entry: TaskEntry, shared: Option<&Shared>) {
    let panicked = std::panic::catch_unwind(AssertUnwindSafe(entry.run)).is_err();
    if let Some(shared) = shared {
        if panicked {
            shared.task_panics.fetch_add(1, Ordering::Relaxed);
        }
        shared.tasks_executed.fetch_add(1, Ordering::Relaxed);
    }
    if let Some(done) = entry.done.take() {
        done();
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let mut st = shared.state.lock().unwrap();
    loop {
        if let Some(mut entry) = st.queue.pop_front() {
            st.busy += 1;
            drop(st);
            // Keep the worker alive across a panicking task (a poisoned
            // worker would silently shrink the pool below the
            // elasticity invariant).
            if std::panic::catch_unwind(AssertUnwindSafe(entry.run)).is_err() {
                shared.task_panics.fetch_add(1, Ordering::Relaxed);
            }
            shared.tasks_executed.fetch_add(1, Ordering::Relaxed);
            st = shared.state.lock().unwrap();
            st.busy -= 1;
            if let Some(done) = entry.done.take() {
                // Completion hooks run with the worker already free, so
                // whoever the hook wakes observes consistent counts.
                drop(st);
                done();
                st = shared.state.lock().unwrap();
            }
            continue;
        }
        if st.shutdown {
            st.workers -= 1;
            return;
        }
        st = shared.work_cv.wait(st).unwrap();
    }
}

/// Completion latch over the tasks of ONE run.
///
/// Cloned into every [`crate::tsqr::Ctx`], so Self-Healing replacement
/// processes spawned mid-run (`spawnNew`, Alg. 6) register on the same
/// latch as the primaries.  `wait_idle` is the coordinator's barrier
/// between world quiescence and result collection: quiescence only says
/// every rank's *status* is final, while the latch says every process
/// body has returned — deposits done, trace sinks dropped.
#[derive(Clone)]
pub struct TaskGroup {
    pool: WorkerPool,
    live: Arc<(Mutex<u64>, Condvar)>,
}

impl TaskGroup {
    /// A fresh latch over `pool`.
    pub fn new(pool: WorkerPool) -> Self {
        Self { pool, live: Arc::new((Mutex::new(0), Condvar::new())) }
    }

    /// Spawn a task onto the pool, tracked by this group.  The latch
    /// releases even if the task panics.
    pub fn spawn(&self, f: impl FnOnce() + Send + 'static) {
        {
            let (count, _) = &*self.live;
            *count.lock().unwrap() += 1;
        }
        let live = Arc::clone(&self.live);
        self.pool.execute_with_completion(f, move || {
            let (count, cv) = &*live;
            *count.lock().unwrap() -= 1;
            cv.notify_all();
        });
    }

    /// Block until every task spawned through this group has returned.
    pub fn wait_idle(&self) {
        let (count, cv) = &*self.live;
        let mut n = count.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }

    /// Tasks of this group currently in flight.
    pub fn live_tasks(&self) -> u64 {
        *self.live.0.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn executes_tasks_and_reuses_workers() {
        let pool = WorkerPool::new();
        let hits = Arc::new(AtomicUsize::new(0));
        let group = TaskGroup::new(pool.clone());
        for _ in 0..4 {
            let h = Arc::clone(&hits);
            group.spawn(move || {
                h.fetch_add(1, Ordering::SeqCst);
            });
            group.wait_idle();
        }
        assert_eq!(hits.load(Ordering::SeqCst), 4);
        // Sequential tasks reuse one worker instead of spawning four:
        // the latch only fires once the worker is free again.
        assert_eq!(pool.peak_workers(), 1, "sequential tasks must share a worker");
        assert_eq!(pool.tasks_executed(), 4);
        pool.shutdown();
    }

    #[test]
    fn interdependent_blocking_tasks_cannot_deadlock() {
        // Task A waits for task B through a condvar: the elasticity
        // invariant must give both a worker even from a cold pool.
        let pool = WorkerPool::new();
        let group = TaskGroup::new(pool.clone());
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let (pa, pb) = (Arc::clone(&pair), Arc::clone(&pair));
        group.spawn(move || {
            let (flag, cv) = &*pa;
            let mut done = flag.lock().unwrap();
            while !*done {
                done = cv.wait(done).unwrap();
            }
        });
        group.spawn(move || {
            let (flag, cv) = &*pb;
            *flag.lock().unwrap() = true;
            cv.notify_all();
        });
        group.wait_idle();
        assert!(pool.workers() >= 2);
        pool.shutdown();
    }

    #[test]
    fn panicking_task_does_not_kill_the_worker_or_the_latch() {
        let pool = WorkerPool::new();
        let group = TaskGroup::new(pool.clone());
        group.spawn(|| panic!("boom"));
        group.wait_idle();
        assert_eq!(pool.task_panics(), 1);
        assert_eq!(pool.workers(), 1, "worker survives the panic");
        // The surviving worker still executes new work.
        let ok = Arc::new(AtomicUsize::new(0));
        let ok2 = Arc::clone(&ok);
        group.spawn(move || {
            ok2.fetch_add(1, Ordering::SeqCst);
        });
        group.wait_idle();
        assert_eq!(ok.load(Ordering::SeqCst), 1);
        assert_eq!(pool.peak_workers(), 1);
        pool.shutdown();
    }

    #[test]
    fn prewarm_and_shutdown() {
        let pool = WorkerPool::with_prewarmed(3);
        assert_eq!(pool.workers(), 3);
        assert_eq!(pool.free_workers(), 3);
        pool.shutdown();
        assert_eq!(pool.workers(), 0, "shutdown joins every worker");
        pool.shutdown(); // idempotent
    }
}
