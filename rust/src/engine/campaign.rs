//! Batched execution: run many [`RunSpec`]s through one engine and
//! aggregate the outcome — the session API's answer to the sweep loops
//! that used to be copy-pasted across every bench, example and CLI
//! subcommand.
//!
//! A campaign amortizes world/pool setup across its runs (the engine's
//! workers are reused run after run), optionally pipelines several runs
//! concurrently, and reduces the per-run [`RunResult`]s into compact
//! [`RunRecord`]s plus campaign-level aggregates: summed communication
//! metrics, survival statistics with a confidence interval, wall-clock
//! throughput.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::analysis::SurvivalEstimate;
use crate::error::Result;
use crate::tsqr::{Algo, RunResult, RunSpec};
use crate::ulfm::MetricsSnapshot;

use super::{Engine, JobHandle};

/// Compact per-run outcome kept for every campaign member (full
/// [`RunResult`]s are only retained with [`Campaign::keep_results`] —
/// a thousand-run sweep should not hold a thousand R factors).
#[derive(Debug, Clone)]
pub struct RunRecord {
    /// Position in the campaign's spec list.
    pub index: usize,
    /// The algorithm that ran.
    pub algo: Algo,
    /// World size.
    pub procs: usize,
    /// The spec's input-matrix seed.
    pub seed: u64,
    /// Success under the algorithm's own semantics.
    pub success: bool,
    /// Every rank finished holding the final R (§III-D1).
    pub fully_healed: bool,
    /// Ranks dead at the end of the run.
    pub dead: usize,
    /// Ranks that finished holding the final R.
    pub holders: usize,
    /// `None` when verification was skipped (`with_verify(false)`).
    pub verified_ok: Option<bool>,
    /// Max |Δ| between different holders' canonical R's.
    pub holder_disagreement: f64,
    /// Communication counters of the run.
    pub metrics: MetricsSnapshot,
    /// Wall clock of the run.
    pub wall: Duration,
}

impl RunRecord {
    fn from_result(index: usize, seed: u64, res: &RunResult) -> Self {
        Self {
            index,
            algo: res.spec_algo,
            procs: res.procs,
            seed,
            success: res.success(),
            fully_healed: res.fully_healed(),
            dead: res.dead_count(),
            holders: res.r_holders.len(),
            verified_ok: res.verification.as_ref().map(|v| v.ok),
            holder_disagreement: res.holder_disagreement,
            metrics: res.metrics,
            wall: res.wall,
        }
    }
}

/// A batch of runs bound to an engine.  Built by [`Engine::campaign`];
/// consumed by [`Campaign::run`].
///
/// ```
/// use ft_tsqr::engine::Engine;
/// use ft_tsqr::tsqr::{Algo, RunSpec};
///
/// let engine = Engine::host();
/// let specs = (0..8).map(|seed| {
///     RunSpec::new(Algo::Replace, 4, 16, 4).with_seed(seed).with_verify(false)
/// });
/// let report = engine.campaign(specs).concurrency(2).run().unwrap();
/// assert_eq!(report.successes(), 8);
/// assert!(report.summary().contains("runs=8"));
/// ```
pub struct Campaign<'e> {
    engine: &'e Engine,
    specs: Vec<RunSpec>,
    concurrency: usize,
    keep_results: bool,
}

impl<'e> Campaign<'e> {
    pub(super) fn new(engine: &'e Engine, specs: Vec<RunSpec>) -> Self {
        Self { engine, specs, concurrency: 1, keep_results: false }
    }

    /// Number of runs pipelined concurrently (default 1: sequential).
    /// Each in-flight run occupies up to `procs + 1` pool workers.
    pub fn concurrency(mut self, window: usize) -> Self {
        self.concurrency = window.max(1);
        self
    }

    /// Retain the full [`RunResult`] of every run (R factors included).
    pub fn keep_results(mut self, keep: bool) -> Self {
        self.keep_results = keep;
        self
    }

    /// Runs in the campaign.
    pub fn len(&self) -> usize {
        self.specs.len()
    }

    /// True when the campaign holds no specs.
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Execute every spec and aggregate.  Validation is eager: any
    /// invalid spec fails the campaign before the first run starts.
    pub fn run(self) -> Result<CampaignReport> {
        for spec in &self.specs {
            spec.validate()?;
        }
        let started = Instant::now();
        let seeds: Vec<u64> = self.specs.iter().map(|s| s.seed).collect();
        let mut records: Vec<RunRecord> = Vec::with_capacity(self.specs.len());
        let mut results: Option<Vec<RunResult>> =
            if self.keep_results { Some(Vec::with_capacity(self.specs.len())) } else { None };

        let engine = self.engine;
        drive(
            self.specs,
            self.concurrency,
            |spec| engine.run(spec),
            |spec| engine.submit(spec),
            JobHandle::wait,
            |index, res| {
                records.push(RunRecord::from_result(index, seeds[index], &res));
                if let Some(all) = &mut results {
                    all.push(res);
                }
            },
        )?;

        Ok(CampaignReport { records, results, total_wall: started.elapsed() })
    }
}

/// Shared campaign orchestration: run every spec, sequentially
/// (`concurrency == 1`) or through a sliding window of in-flight
/// submissions, harvesting **in submission order** so records stay
/// ordered.  One copy of the window logic serves both the TSQR
/// [`Campaign`] and the CAQR [`crate::caqr::CaqrCampaign`].
pub(crate) fn drive<S, H, R>(
    specs: Vec<S>,
    concurrency: usize,
    run_sync: impl Fn(S) -> Result<R>,
    submit: impl Fn(S) -> H,
    wait: impl Fn(H) -> Result<R>,
    mut record: impl FnMut(usize, R),
) -> Result<()> {
    if concurrency == 1 {
        for (index, spec) in specs.into_iter().enumerate() {
            record(index, run_sync(spec)?);
        }
        return Ok(());
    }
    let mut pending = specs.into_iter().enumerate();
    let mut inflight: VecDeque<(usize, H)> = VecDeque::new();
    loop {
        while inflight.len() < concurrency {
            let Some((index, spec)) = pending.next() else { break };
            inflight.push_back((index, submit(spec)));
        }
        let Some((index, handle)) = inflight.pop_front() else { break };
        record(index, wait(handle)?);
    }
    Ok(())
}

/// Aggregated outcome of one campaign.
#[derive(Debug)]
pub struct CampaignReport {
    /// One record per run, in spec order.
    pub records: Vec<RunRecord>,
    /// Full results when requested via [`Campaign::keep_results`].
    pub results: Option<Vec<RunResult>>,
    /// Wall clock of the whole campaign (submission to last harvest).
    pub total_wall: Duration,
}

impl CampaignReport {
    /// Runs executed.
    pub fn runs(&self) -> u64 {
        self.records.len() as u64
    }

    /// Runs that succeeded under their algorithm's semantics.
    pub fn successes(&self) -> u64 {
        self.records.iter().filter(|r| r.success).count() as u64
    }

    /// `successes / runs`.
    pub fn success_rate(&self) -> f64 {
        self.survival().probability()
    }

    /// Survival statistics over the campaign (probability + 95% CI).
    pub fn survival(&self) -> SurvivalEstimate {
        SurvivalEstimate { trials: self.runs(), successes: self.successes() }
    }

    /// Runs in which every rank finished holding the final R.
    pub fn fully_healed(&self) -> u64 {
        self.records.iter().filter(|r| r.fully_healed).count() as u64
    }

    /// Runs whose verification oracle ran and failed.
    pub fn verification_failures(&self) -> u64 {
        self.records.iter().filter(|r| r.verified_ok == Some(false)).count() as u64
    }

    /// Communication counters summed over every run.
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut total = MetricsSnapshot::default();
        for r in &self.records {
            total.merge(&r.metrics);
        }
        total
    }

    /// Sum of the per-run wall times (≥ `total_wall` under concurrency).
    pub fn total_run_wall(&self) -> Duration {
        self.records.iter().map(|r| r.wall).sum()
    }

    /// Mean per-run wall time.
    pub fn mean_wall(&self) -> Duration {
        if self.records.is_empty() {
            return Duration::ZERO;
        }
        self.total_run_wall() / self.records.len() as u32
    }

    /// Completed runs per second of campaign wall clock.
    pub fn throughput(&self) -> f64 {
        let secs = self.total_wall.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.runs() as f64 / secs
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let est = self.survival();
        let m = self.metrics();
        format!(
            "runs={} successes={} rate={:.3}±{:.3} fully_healed={} respawns={} \
             mean_wall={:.2}ms throughput={:.1}/s",
            self.runs(),
            self.successes(),
            est.probability(),
            est.ci95(),
            self.fully_healed(),
            m.respawns,
            self.mean_wall().as_secs_f64() * 1e3,
            self.throughput(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::KillSchedule;

    fn small(algo: Algo) -> RunSpec {
        RunSpec::new(algo, 4, 16, 4)
    }

    #[test]
    fn sequential_campaign_aggregates() {
        let engine = Engine::host();
        let specs: Vec<RunSpec> = (0..5).map(|s| small(Algo::Redundant).with_seed(s)).collect();
        let report = engine.campaign(specs).run().unwrap();
        assert_eq!(report.runs(), 5);
        assert_eq!(report.successes(), 5);
        assert_eq!(report.fully_healed(), 5);
        assert_eq!(report.verification_failures(), 0);
        assert!((report.success_rate() - 1.0).abs() < 1e-12);
        assert!(report.metrics().messages > 0);
        assert!(report.results.is_none(), "results dropped by default");
        assert!(report.summary().contains("runs=5"), "{}", report.summary());
    }

    #[test]
    fn concurrent_campaign_matches_sequential() {
        let engine = Engine::host();
        let specs = |_| -> Vec<RunSpec> {
            (0..8u64)
                .map(|s| {
                    small(Algo::Replace)
                        .with_seed(s)
                        .with_schedule(KillSchedule::random_at_round(4, 1, 1, None, s))
                        .with_verify(false)
                })
                .collect()
        };
        let seq = engine.campaign(specs(())).run().unwrap();
        let conc = engine.campaign(specs(())).concurrency(4).run().unwrap();
        let key = |r: &RunRecord| (r.index, r.success, r.holders, r.dead, r.metrics.respawns);
        let a: Vec<_> = seq.records.iter().map(key).collect();
        let b: Vec<_> = conc.records.iter().map(key).collect();
        assert_eq!(a, b, "concurrency must not change per-run outcomes");
    }

    #[test]
    fn keep_results_retains_full_runs() {
        let engine = Engine::host();
        let report =
            engine.campaign(vec![small(Algo::Redundant)]).keep_results(true).run().unwrap();
        let results = report.results.as_ref().unwrap();
        assert_eq!(results.len(), 1);
        assert!(results[0].final_r.is_some());
    }

    #[test]
    fn invalid_spec_fails_eagerly() {
        let engine = Engine::host();
        let specs = vec![small(Algo::Redundant), RunSpec::new(Algo::Redundant, 6, 16, 4)];
        assert!(engine.campaign(specs).run().is_err());
        assert_eq!(engine.stats().jobs_submitted, 0, "validation precedes submission");
    }
}
