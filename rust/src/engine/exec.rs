//! One-run orchestration over the shared engine substrate — the run
//! lifecycle that used to live in `tsqr::runner::run`, with the
//! spawn-per-run thread lifecycle replaced by pooled workers.
//!
//! The flow is unchanged from the paper's harness: build the world,
//! launch one process body per rank, block until the world quiesces
//! (including dynamically respawned Self-Healing replacements), then
//! gather results, check holder consistency and verify against the
//! host oracle.  Only the *substrate* differs: rank bodies run on
//! [`WorkerPool`] workers tracked by a per-run [`TaskGroup`], so a
//! long-lived [`super::Engine`] amortizes thread setup across runs.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::error::Result;
use crate::linalg::Matrix;
use crate::tsqr::algorithms;
use crate::tsqr::context::{Ctx, ResultMap};
use crate::tsqr::plan::TreePlan;
use crate::tsqr::runner::{Algo, RunResult, RunSpec, run_process_wrapper};
use crate::tsqr::trace::TraceSink;
use crate::tsqr::verify;
use crate::ulfm::{Rank, World};

use super::pool::{TaskGroup, WorkerPool};

/// Execute one validated spec end to end on pooled workers.
pub(crate) fn execute(spec: &RunSpec, pool: &WorkerPool) -> Result<RunResult> {
    spec.validate()?;
    let plan = TreePlan::new(spec.procs);
    // Pre-size the executor's workspace pool from the plan: one arena
    // per rank, each big enough for the run's largest kernel, so the
    // kernel path performs zero steady-state allocations.  Idempotent
    // — from the second campaign run on this is a no-op.
    let (ws_rows, ws_cols) = spec.workspace_shape();
    spec.executor.warm_workspaces(spec.procs, ws_rows, ws_cols);
    let world = World::new(spec.procs);
    let (sink, collector) = if spec.collect_trace {
        let (s, c) = TraceSink::channel();
        (s, Some(c))
    } else {
        (TraceSink::disabled(), None)
    };
    let results: ResultMap = Arc::new(Mutex::new(HashMap::new()));
    let tasks = TaskGroup::new(pool.clone());

    // Shared zero-copy override when the spec carries one (service
    // tenants submitting N jobs over one matrix), else generated from
    // the seed.  `row_block` copies the rank's panel either way; the
    // full matrix itself is never duplicated.
    let a = spec.resolve_input();
    let started = Instant::now();

    for rank in 0..spec.procs {
        let ctx = Ctx {
            rank,
            plan,
            world: Arc::clone(&world),
            exec: spec.executor.clone(),
            trace: sink.clone(),
            schedule: Arc::clone(&spec.schedule),
            results: Arc::clone(&results),
            tasks: tasks.clone(),
        };
        let panel = a.row_block(rank * spec.rows_per_proc, (rank + 1) * spec.rows_per_proc);
        let algo = spec.algo;
        tasks.spawn(move || {
            run_process_wrapper(ctx.clone(), move || match algo {
                Algo::Baseline => algorithms::baseline(ctx, panel),
                Algo::Redundant => algorithms::redundant(ctx, panel),
                Algo::Replace => algorithms::replace(ctx, panel),
                Algo::SelfHealing => algorithms::self_healing(ctx, panel),
                Algo::Checkpointed => crate::checkpoint::checkpointed(ctx, panel),
            });
        });
    }

    world.await_quiescent();
    // Quiescence fixes every rank's status; the latch additionally
    // guarantees every process body (and every Self-Healing replacement
    // spawned mid-run) has fully returned — deposits and trace
    // emissions done, per-task sink clones dropped.
    tasks.wait_idle();
    let wall = started.elapsed();
    drop(sink); // release the trace channel so drain sees everything

    let statuses = world.statuses();
    let result_map = std::mem::take(&mut *results.lock().unwrap());
    let mut r_holders: Vec<Rank> = result_map.keys().copied().collect();
    r_holders.sort_unstable();

    // Consistency across holders: all copies of the final R must agree.
    let mut holder_disagreement = 0.0f64;
    let canonical: Option<Matrix> = r_holders.first().map(|r0| result_map[r0].canonicalize_r());
    if let Some(c0) = &canonical {
        for r in &r_holders[1..] {
            holder_disagreement =
                holder_disagreement.max(result_map[r].canonicalize_r().max_abs_diff(c0));
        }
    }

    let verification = if spec.verify && canonical.is_some() {
        Some(verify::verify_r(&a, canonical.as_ref().unwrap()))
    } else {
        None
    };

    Ok(RunResult {
        spec_algo: spec.algo,
        procs: spec.procs,
        statuses,
        r_holders,
        final_r: canonical,
        holder_disagreement,
        metrics: world.metrics().snapshot(),
        trace: collector.map(|c| c.drain()).unwrap_or_default(),
        wall,
        verification,
    })
}
