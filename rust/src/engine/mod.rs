//! Session-oriented execution: a long-lived [`Engine`] owning the
//! kernel executor and a reusable worker pool, so the per-run setup
//! cost (backend/artifact loading, thread spawning) is paid once per
//! *session* instead of once per factorization.
//!
//! * [`EngineBuilder`] — backend selection (host linalg vs PJRT),
//!   artifact directory, PJRT sharding, worker prewarming, and the
//!   [`threads`](EngineBuilder::threads) kernel-parallelism knob
//!   (pool fan-out inside GEMM, bit-identical at every setting).
//! * [`Engine::run`] — one factorization, synchronously.
//! * [`Engine::submit`] — async-style submission returning a
//!   [`JobHandle`]; safe to call concurrently from many threads.
//! * [`Engine::campaign`] — batched sweeps over many [`RunSpec`]s with
//!   aggregated metrics and survival statistics ([`Campaign`]).
//!
//! The one-shot [`crate::tsqr::run`] remains as a thin shim over a
//! single-use engine, so its semantics (per-algorithm success criteria,
//! holder-disagreement check, verification oracle) are unchanged.
//!
//! ```no_run
//! use ft_tsqr::engine::Engine;
//! use ft_tsqr::tsqr::{Algo, RunSpec};
//!
//! let engine = Engine::builder().build().unwrap();
//! let handle = engine.submit(RunSpec::new(Algo::Redundant, 8, 128, 8));
//! assert!(handle.wait().unwrap().success());
//! ```

mod campaign;
mod exec;
mod pool;

pub(crate) use campaign::drive;
pub use campaign::{Campaign, CampaignReport, RunRecord};
pub use pool::{TaskGroup, WorkerPool};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, mpsc};

use crate::abft::RecoveryPolicy;
use crate::caqr::{CaqrCampaign, CaqrResult, CaqrSpec};
use crate::error::{Error, Result};
use crate::runtime::{
    Backend, BackendPlan, CpuInfo, Executor, KernelProfile, Parallelism, DEFAULT_ARTIFACT_DIR,
};
use crate::sim::{SimBatchReport, SimScenario};
use crate::tsqr::{RunResult, RunSpec};

/// Configures and builds an [`Engine`].
#[derive(Debug, Clone)]
pub struct EngineBuilder {
    backend: Backend,
    artifact_dir: String,
    pjrt_shards: usize,
    prewarm: usize,
    threads: usize,
    kernel_profile: KernelProfile,
    recovery_policy: RecoveryPolicy,
    adaptive_rate: Option<f64>,
    backend_plan: BackendPlan,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        Self {
            backend: Backend::Auto,
            artifact_dir: DEFAULT_ARTIFACT_DIR.into(),
            pjrt_shards: 2,
            prewarm: 0,
            threads: 0,
            kernel_profile: KernelProfile::default(),
            recovery_policy: RecoveryPolicy::default(),
            adaptive_rate: None,
            backend_plan: BackendPlan::default(),
        }
    }
}

impl EngineBuilder {
    /// A builder with the defaults (`Auto` backend, `artifacts/`).
    pub fn new() -> Self {
        Self::default()
    }

    /// Compute backend: `Host` (pure rust), `Pjrt` (strict, needs
    /// artifacts) or `Auto` (PJRT when artifacts load, host otherwise).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Shortcut for [`Backend::Host`].
    pub fn host_only(mut self) -> Self {
        self.backend = Backend::Host;
        self
    }

    /// Where to look for AOT artifacts (default `artifacts/`).
    pub fn artifact_dir(mut self, dir: impl Into<String>) -> Self {
        self.artifact_dir = dir.into();
        self
    }

    /// PJRT service threads (see `runtime::service`; default 2).
    pub fn pjrt_shards(mut self, shards: usize) -> Self {
        self.pjrt_shards = shards.max(1);
        self
    }

    /// Pre-spawn this many pool workers so the first run pays no
    /// thread-creation latency (default 0: grow on demand).
    pub fn prewarm(mut self, workers: usize) -> Self {
        self.prewarm = workers;
        self
    }

    /// The `--threads` knob: pre-spawn `n` pool workers **and** let
    /// each kernel call fan its GEMM slabs out across up to `n` workers
    /// (the [`Parallelism`] default CAQR submissions inherit).  `0`
    /// means unset: grow the pool on demand, keep kernels sequential.
    /// Every setting is bit-identical — `threads = 1` *is* the
    /// sequential path, and larger counts reproduce its bits (see
    /// [`crate::linalg::gemm`]).
    pub fn threads(mut self, n: usize) -> Self {
        self.prewarm = n;
        self.threads = n;
        self
    }

    /// Default [`KernelProfile`] for CAQR work submitted through this
    /// engine: `Reference` (bitwise-pinned oracle path, the default) or
    /// `Blocked` (compact-WY + GEMM fast path).  A spec-level
    /// [`CaqrSpec::with_profile`](crate::caqr::CaqrSpec::with_profile)
    /// overrides this per submission.
    pub fn kernel_profile(mut self, profile: KernelProfile) -> Self {
        self.kernel_profile = profile;
        self
    }

    /// Default [`RecoveryPolicy`] for CAQR work submitted through this
    /// engine: `Replica` (the papers' replication-only ladder, the
    /// default), `Checksum`, or `Hybrid` (replication + checksum
    /// reconstruction — survives pair wipes).  A spec-level
    /// [`CaqrSpec::with_policy`](crate::caqr::CaqrSpec::with_policy)
    /// overrides this per submission; the checksum *count* always
    /// comes from the spec
    /// ([`CaqrSpec::with_checksums`](crate::caqr::CaqrSpec::with_checksums)).
    pub fn recovery_policy(mut self, policy: RecoveryPolicy) -> Self {
        self.recovery_policy = policy;
        self
    }

    /// Default in-process [`BackendPlan`] for kernel dispatch: route
    /// every op to the [`HostKernel`](crate::runtime::HostKernel)
    /// oracle (the default), to the pool-parallel
    /// [`ThreadedKernel`](crate::runtime::ThreadedKernel), or mix
    /// per-op via [`BackendPlan::with_op`].  Applies to every kernel
    /// call the executor dispatches on the host path; CAQR submissions
    /// additionally inherit it as their factor-core routing unless the
    /// spec pins its own plan via
    /// [`CaqrSpec::with_backend`](crate::caqr::CaqrSpec::with_backend).
    pub fn backend_plan(mut self, plan: BackendPlan) -> Self {
        self.backend_plan = plan;
        self
    }

    /// Failure-model-adaptive protection: CAQR submissions with no
    /// explicit policy or checksum count inherit
    /// [`CaqrSpec::with_failure_model`](crate::caqr::CaqrSpec::with_failure_model)
    /// at this rate (deaths per rank per virtual second), so the
    /// recovery ladder and `c` are derived per plan by
    /// [`AdaptivePolicy`](crate::analysis::AdaptivePolicy) instead of
    /// hand-picked.  Spec-level knobs always win.
    pub fn adaptive_policy(mut self, rate: f64) -> Self {
        self.adaptive_rate = Some(rate);
        self
    }

    /// Build the engine: load the backend once, start the pool, and
    /// warm the process-wide kernel caches — the GEMM autotune probe
    /// ([`crate::linalg::gemm::GemmParams::tuned`]: ISA dispatch +
    /// cache-tile selection, cached so every task and replica shares
    /// one configuration) and the host [`CpuInfo`] the perf reports
    /// stamp into their JSON.
    pub fn build(self) -> Result<Engine> {
        let _ = crate::linalg::gemm::GemmParams::tuned();
        let _ = CpuInfo::cached();
        let executor = match self.backend {
            Backend::Host => Executor::host(),
            // Like `Executor::auto`, but honoring the configured shard
            // count: PJRT when the artifacts load, host otherwise.
            Backend::Auto => {
                Executor::with_artifacts(&self.artifact_dir, Backend::Auto, self.pjrt_shards)
                    .unwrap_or_else(|_| Executor::host())
            }
            Backend::Pjrt => {
                Executor::with_artifacts(&self.artifact_dir, Backend::Pjrt, self.pjrt_shards)?
            }
        };
        let executor = executor.with_backend_plan(self.backend_plan);
        Ok(Engine::from_parts(
            executor,
            self.prewarm,
            Parallelism::new(self.threads),
            self.kernel_profile,
            self.recovery_policy,
            self.adaptive_rate,
        ))
    }
}

/// Job counters shared with in-flight submissions.
#[derive(Debug, Default)]
struct Counters {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
}

/// Point-in-time engine statistics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineStats {
    /// Jobs ever submitted to this engine.
    pub jobs_submitted: u64,
    /// Jobs that returned a result.
    pub jobs_completed: u64,
    /// Jobs that returned an error (validation failures).
    pub jobs_failed: u64,
    /// Worker threads currently alive.
    pub workers: usize,
    /// High-water mark of concurrent workers.
    pub peak_workers: usize,
    /// Pool tasks executed over the engine's lifetime.
    pub tasks_executed: u64,
}

/// A long-lived execution session: one executor + one worker pool,
/// reused across every run submitted to it.  `Send + Sync`: share it
/// behind a reference or an `Arc` and submit from many threads.
///
/// Dropping the engine shuts the pool down (joining all workers).
///
/// ```
/// use ft_tsqr::engine::Engine;
/// use ft_tsqr::tsqr::{Algo, RunSpec};
///
/// let engine = Engine::host(); // pure-rust backend, no artifacts
/// let result = engine.run(RunSpec::new(Algo::Redundant, 4, 16, 4)).unwrap();
/// assert!(result.success());
/// assert_eq!(result.r_holders, vec![0, 1, 2, 3], "every survivor holds R");
/// ```
pub struct Engine {
    executor: Executor,
    pool: WorkerPool,
    counters: Arc<Counters>,
    default_profile: KernelProfile,
    default_policy: RecoveryPolicy,
    default_parallelism: Parallelism,
    default_failure_model: Option<f64>,
}

impl Engine {
    /// Start configuring an engine.
    pub fn builder() -> EngineBuilder {
        EngineBuilder::new()
    }

    /// Host-backend engine (no artifacts touched) — the cheapest way to
    /// get a session for tests and analytic cross-checks.
    pub fn host() -> Self {
        Self::with_executor(Executor::host())
    }

    /// Wrap an existing executor in a fresh single-session engine (the
    /// substrate of the one-shot `tsqr::run` shim).
    pub fn with_executor(executor: Executor) -> Self {
        Self::from_parts(
            executor,
            0,
            Parallelism::single(),
            KernelProfile::default(),
            RecoveryPolicy::default(),
            None,
        )
    }

    fn from_parts(
        executor: Executor,
        prewarm: usize,
        default_parallelism: Parallelism,
        default_profile: KernelProfile,
        default_policy: RecoveryPolicy,
        default_failure_model: Option<f64>,
    ) -> Self {
        let pool =
            if prewarm > 0 { WorkerPool::with_prewarmed(prewarm) } else { WorkerPool::new() };
        Self {
            executor,
            pool,
            counters: Arc::new(Counters::default()),
            default_profile,
            default_policy,
            default_parallelism,
            default_failure_model,
        }
    }

    /// The session executor every submitted spec runs on.
    pub fn executor(&self) -> &Executor {
        &self.executor
    }

    /// The default [`KernelProfile`] CAQR submissions inherit when
    /// their spec does not pin one.
    pub fn default_kernel_profile(&self) -> KernelProfile {
        self.default_profile
    }

    /// The default [`RecoveryPolicy`] CAQR submissions inherit when
    /// their spec does not pin one.
    pub fn default_recovery_policy(&self) -> RecoveryPolicy {
        self.default_policy
    }

    /// The failure rate CAQR submissions inherit as an adaptive
    /// protection model when the spec pins neither a policy nor a
    /// checksum count (`None` when the engine was not built with
    /// [`EngineBuilder::adaptive_policy`]).
    pub fn default_failure_model(&self) -> Option<f64> {
        self.default_failure_model
    }

    /// The default in-process [`BackendPlan`] kernel calls dispatch
    /// under, and that CAQR submissions inherit when their spec does
    /// not pin one (see [`EngineBuilder::backend_plan`]).
    pub fn default_backend_plan(&self) -> &BackendPlan {
        self.executor.backend_plan()
    }

    /// The default intra-task kernel [`Parallelism`] CAQR submissions
    /// inherit when their spec does not pin one (the `--threads` knob).
    pub fn default_parallelism(&self) -> Parallelism {
        self.default_parallelism
    }

    /// What the engine learned about the host at build time: CPU model,
    /// SIMD features, the microkernel ISA the GEMM dispatcher selected,
    /// and hardware threads.  Stamped into every perf report so the
    /// bench-regress gate only compares like-for-like hosts.
    pub fn cpu_info(&self) -> &'static CpuInfo {
        CpuInfo::cached()
    }

    /// Worker threads currently alive in the pool.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// The session worker pool — the service layer dispatches jobs
    /// onto it directly so service jobs and direct `submit()` calls
    /// share one elastic set of workers.
    pub(crate) fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// Point-in-time job/worker counters.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            jobs_submitted: self.counters.submitted.load(Ordering::Relaxed),
            jobs_completed: self.counters.completed.load(Ordering::Relaxed),
            jobs_failed: self.counters.failed.load(Ordering::Relaxed),
            workers: self.pool.workers(),
            peak_workers: self.pool.peak_workers(),
            tasks_executed: self.pool.tasks_executed(),
        }
    }

    /// The engine owns the backend: whatever executor the spec carried
    /// is replaced by the session executor.
    fn adopt(&self, mut spec: RunSpec) -> RunSpec {
        spec.executor = self.executor.clone();
        spec
    }

    /// Resolve a CAQR spec's kernel profile and recovery policy: a
    /// spec-level pin wins, otherwise the engine's defaults apply.
    fn adopt_caqr(&self, mut spec: CaqrSpec) -> CaqrSpec {
        if spec.profile.is_none() {
            spec.profile = Some(self.default_profile);
        }
        // Protection ladder: a spec pin (policy, checksums, or failure
        // model) always wins.  Otherwise the engine's adaptive rate —
        // when configured — beats the static default policy, because
        // injecting a policy next to a failure model would trip the
        // spec's own KnobConflict validation.
        if spec.policy.is_none() && spec.failure_model.is_none() {
            if spec.checksums == 0 {
                if let Some(rate) = self.default_failure_model {
                    spec.failure_model = Some(rate);
                }
            }
            if spec.failure_model.is_none() {
                spec.policy = Some(self.default_policy);
            }
        }
        if spec.parallelism.is_none() {
            spec.parallelism = Some(self.default_parallelism);
        }
        if spec.backend.is_none() {
            spec.backend = Some(self.executor.backend_plan().clone());
        }
        spec
    }

    /// Run one factorization synchronously on the calling thread (rank
    /// bodies still execute on pooled workers).
    pub fn run(&self, spec: RunSpec) -> Result<RunResult> {
        let spec = self.adopt(spec);
        self.counters.submitted.fetch_add(1, Ordering::Relaxed);
        let res = exec::execute(&spec, &self.pool);
        match &res {
            Ok(_) => self.counters.completed.fetch_add(1, Ordering::Relaxed),
            Err(_) => self.counters.failed.fetch_add(1, Ordering::Relaxed),
        };
        res
    }

    /// Submit a run for asynchronous execution.  The whole run —
    /// coordination included — happens on pooled workers; the returned
    /// handle delivers the result (or the validation error).
    pub fn submit(&self, spec: RunSpec) -> JobHandle {
        let spec = self.adopt(spec);
        let id = self.counters.submitted.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let pool = self.pool.clone();
        let counters = Arc::clone(&self.counters);
        self.pool.execute(move || {
            let res = exec::execute(&spec, &pool);
            match &res {
                Ok(_) => counters.completed.fetch_add(1, Ordering::Relaxed),
                Err(_) => counters.failed.fetch_add(1, Ordering::Relaxed),
            };
            let _ = tx.send(res);
        });
        JobHandle { id, rx }
    }

    /// Start a batched campaign over many specs (see [`Campaign`]).
    pub fn campaign(&self, specs: impl IntoIterator<Item = RunSpec>) -> Campaign<'_> {
        Campaign::new(self, specs.into_iter().collect())
    }

    /// Run one general-matrix CAQR factorization synchronously on this
    /// session's worker pool (see [`crate::caqr`]).
    ///
    /// ```
    /// use ft_tsqr::caqr::CaqrSpec;
    /// use ft_tsqr::engine::Engine;
    /// use ft_tsqr::tsqr::Algo;
    ///
    /// let engine = Engine::host();
    /// let res = engine.run_caqr(CaqrSpec::new(Algo::Redundant, 4, 16, 8, 4)).unwrap();
    /// assert!(res.success() && res.verification.unwrap().ok);
    /// ```
    pub fn run_caqr(&self, spec: CaqrSpec) -> Result<CaqrResult> {
        let spec = self.adopt_caqr(spec);
        self.counters.submitted.fetch_add(1, Ordering::Relaxed);
        let res = crate::caqr::execute(&spec, &self.pool);
        match &res {
            Ok(_) => self.counters.completed.fetch_add(1, Ordering::Relaxed),
            Err(_) => self.counters.failed.fetch_add(1, Ordering::Relaxed),
        };
        res
    }

    /// Submit a CAQR factorization for asynchronous execution — the
    /// whole coordinator runs on pooled workers; the handle delivers
    /// the result.
    pub fn submit_caqr(&self, spec: CaqrSpec) -> CaqrJobHandle {
        let spec = self.adopt_caqr(spec);
        let id = self.counters.submitted.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let pool = self.pool.clone();
        let counters = Arc::clone(&self.counters);
        self.pool.execute(move || {
            let res = crate::caqr::execute(&spec, &pool);
            match &res {
                Ok(_) => counters.completed.fetch_add(1, Ordering::Relaxed),
                Err(_) => counters.failed.fetch_add(1, Ordering::Relaxed),
            };
            let _ = tx.send(res);
        });
        CaqrJobHandle { id, rx }
    }

    /// Start a batched CAQR campaign over many specs (see
    /// [`CaqrCampaign`]).
    pub fn caqr_campaign(&self, specs: impl IntoIterator<Item = CaqrSpec>) -> CaqrCampaign<'_> {
        CaqrCampaign::new(self, specs.into_iter().map(|s| self.adopt_caqr(s)).collect())
    }

    /// Run a discrete-event fault campaign on this session's worker
    /// pool: every sample of the scenario (reseeded through
    /// [`crate::util::derive_seed`]) runs concurrently, and the batch
    /// report aggregates survival and events-per-second throughput.
    ///
    /// Unlike [`run_caqr`](Self::run_caqr), no matrices are touched —
    /// a sample at `procs = 10⁶` costs the same per panel as one at
    /// `procs = 8` (see [`crate::sim`]).
    ///
    /// ```
    /// use ft_tsqr::engine::Engine;
    /// use ft_tsqr::sim::SimScenario;
    ///
    /// let engine = Engine::host();
    /// let sc = SimScenario { procs: 1024, samples: 32, ..Default::default() };
    /// let batch = engine.simulate(&sc).unwrap();
    /// assert_eq!(batch.survival().probability(), 1.0, "no faults armed");
    /// ```
    pub fn simulate(&self, scenario: &SimScenario) -> Result<SimBatchReport> {
        scenario.validate()?;
        self.counters.submitted.fetch_add(1, Ordering::Relaxed);
        let start = std::time::Instant::now();
        let n = scenario.samples as usize;
        let slots: Arc<Vec<Mutex<Option<crate::sim::SimReport>>>> =
            Arc::new((0..n).map(|_| Mutex::new(None)).collect());
        let group = TaskGroup::new(self.pool.clone());
        for i in 0..n {
            let sample = scenario.sample(i as u64);
            let slots = Arc::clone(&slots);
            group.spawn(move || {
                let report = crate::sim::run_validated(&sample);
                *slots[i].lock().unwrap() = Some(report);
            });
        }
        group.wait_idle();
        let reports: Vec<_> = slots.iter().filter_map(|s| s.lock().unwrap().take()).collect();
        self.counters.completed.fetch_add(1, Ordering::Relaxed);
        Ok(SimBatchReport { reports, wall: start.elapsed() })
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.pool.shutdown();
    }
}

/// Handle to one submitted run.
pub struct JobHandle {
    id: u64,
    rx: mpsc::Receiver<Result<RunResult>>,
}

impl JobHandle {
    /// Monotonic per-engine submission id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the run finishes and take its result.
    pub fn wait(self) -> Result<RunResult> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err(Error::Other("engine job lost (worker panicked?)".into())))
    }
}

/// Handle to one submitted CAQR factorization.
pub struct CaqrJobHandle {
    id: u64,
    rx: mpsc::Receiver<Result<CaqrResult>>,
}

impl CaqrJobHandle {
    /// Monotonic per-engine submission id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the factorization finishes and take its result.
    pub fn wait(self) -> Result<CaqrResult> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err(Error::Other("engine job lost (worker panicked?)".into())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tsqr::Algo;

    fn small(algo: Algo) -> RunSpec {
        RunSpec::new(algo, 4, 16, 4)
    }

    #[test]
    fn builder_defaults_and_host() {
        let engine = Engine::builder().host_only().prewarm(2).build().unwrap();
        assert_eq!(engine.workers(), 2);
        let res = engine.run(small(Algo::Redundant)).unwrap();
        assert!(res.success());
        assert!(res.verification.unwrap().ok);
        let stats = engine.stats();
        assert_eq!(stats.jobs_submitted, 1);
        assert_eq!(stats.jobs_completed, 1);
        assert_eq!(stats.jobs_failed, 0);
    }

    #[test]
    fn threads_knob_sets_pool_and_kernel_parallelism() {
        // --threads must govern BOTH pool prewarm and the GEMM slab
        // fan-out (the PR-7 plumbing fix), and build() must have warmed
        // the host introspection caches.
        let engine = Engine::builder().host_only().threads(3).build().unwrap();
        assert_eq!(engine.workers(), 3, "threads prewarms the pool");
        assert_eq!(engine.default_parallelism().gemm_threads(), 3, "threads reaches kernels");
        assert!(engine.cpu_info().threads >= 1);
        assert!(engine.cpu_info().isa.usable());
        // Unset stays sequential: the historical default path.
        assert!(!Engine::host().default_parallelism().is_parallel());
    }

    #[test]
    fn submit_delivers_result() {
        let engine = Engine::host();
        let res = engine.submit(small(Algo::Replace)).wait().unwrap();
        assert!(res.success());
        assert_eq!(res.r_holders, vec![0, 1, 2, 3]);
    }

    #[test]
    fn submit_surfaces_validation_errors() {
        let engine = Engine::host();
        let err = engine.submit(RunSpec::new(Algo::Redundant, 6, 16, 4)).wait();
        assert!(err.is_err(), "non-pow2 redundant world must fail validation");
        assert_eq!(engine.stats().jobs_failed, 1);
    }

    #[test]
    fn kernel_profile_knob_flows_into_caqr_runs() {
        use crate::caqr::CaqrSpec;
        let engine =
            Engine::builder().host_only().kernel_profile(KernelProfile::Blocked).build().unwrap();
        assert_eq!(engine.default_kernel_profile(), KernelProfile::Blocked);
        let res = engine.run_caqr(CaqrSpec::new(Algo::Redundant, 4, 16, 8, 4)).unwrap();
        assert!(res.success());
        assert_eq!(res.profile, KernelProfile::Blocked, "engine default applies");
        // A spec-level pin overrides the engine default.
        let res = engine
            .run_caqr(
                CaqrSpec::new(Algo::Redundant, 4, 16, 8, 4)
                    .with_profile(KernelProfile::Reference),
            )
            .unwrap();
        assert_eq!(res.profile, KernelProfile::Reference);
    }

    #[test]
    fn recovery_policy_knob_flows_into_caqr_runs() {
        use crate::caqr::CaqrSpec;
        let engine = Engine::builder()
            .host_only()
            .recovery_policy(RecoveryPolicy::Hybrid)
            .build()
            .unwrap();
        assert_eq!(engine.default_recovery_policy(), RecoveryPolicy::Hybrid);
        let res = engine
            .run_caqr(CaqrSpec::new(Algo::Redundant, 4, 16, 8, 4).with_checksums(1))
            .unwrap();
        assert!(res.success());
        assert_eq!(res.policy, RecoveryPolicy::Hybrid, "engine default applies");
        assert_eq!(res.checksums, 1);
        // A spec-level pin overrides the engine default.
        let res = engine
            .run_caqr(
                CaqrSpec::new(Algo::Redundant, 4, 16, 8, 4)
                    .with_policy(RecoveryPolicy::Replica),
            )
            .unwrap();
        assert_eq!(res.policy, RecoveryPolicy::Replica);
        assert_eq!(res.checksums, 0, "replica policy never encodes");
    }

    #[test]
    fn backend_plan_knob_flows_into_caqr_runs() {
        use crate::caqr::CaqrSpec;
        let host = Engine::host();
        assert!(!host.default_backend_plan().uses_threaded(), "host-only is the default plan");
        let oracle = host.run_caqr(CaqrSpec::new(Algo::Redundant, 4, 48, 12, 4)).unwrap();
        assert!(oracle.success());
        let oracle_r = oracle.final_r.as_ref().unwrap();

        let threaded =
            Engine::builder().host_only().backend_plan(BackendPlan::threaded()).build().unwrap();
        assert!(threaded.default_backend_plan().uses_threaded());
        // An unpinned spec inherits the engine plan: the chunked factor
        // core runs, so R agrees with the oracle to f32-level accuracy
        // (reassociated reductions) but need not be bitwise.
        let res = threaded.run_caqr(CaqrSpec::new(Algo::Redundant, 4, 48, 12, 4)).unwrap();
        assert!(res.success());
        let got = res.final_r.as_ref().unwrap();
        assert!(got.max_abs_diff(oracle_r) < 1e-3, "threaded plan stays near the oracle");
        // A spec-level pin overrides the engine default: routing back to
        // host reproduces the oracle's exact bits.
        let pinned = threaded
            .run_caqr(
                CaqrSpec::new(Algo::Redundant, 4, 48, 12, 4).with_backend(BackendPlan::host()),
            )
            .unwrap();
        assert_eq!(
            pinned.final_r.as_ref().unwrap(),
            oracle_r,
            "spec-level host pin is bitwise the oracle"
        );
    }

    #[test]
    fn adaptive_policy_knob_flows_into_caqr_runs() {
        use crate::analysis::AdaptivePolicy;
        use crate::caqr::CaqrSpec;
        let rate = 1e-3;
        let engine = Engine::builder().host_only().adaptive_policy(rate).build().unwrap();
        assert_eq!(engine.default_failure_model(), Some(rate));
        // An unpinned spec inherits the failure model, so the run's
        // resolved ladder is exactly what AdaptivePolicy would choose
        // for this plan.
        let spec = CaqrSpec::new(Algo::Redundant, 4, 16, 8, 4);
        let want = AdaptivePolicy::new(rate).choose(spec.procs, spec.plan().panels());
        let res = engine.run_caqr(spec).unwrap();
        assert!(res.success());
        assert_eq!(res.policy, want.policy, "adaptive choice applies");
        assert_eq!(res.checksums, want.checksums);
        // Spec-level pins still win over the engine's adaptive rate.
        let res = engine
            .run_caqr(
                CaqrSpec::new(Algo::Redundant, 4, 16, 8, 4)
                    .with_policy(RecoveryPolicy::Replica),
            )
            .unwrap();
        assert_eq!(res.policy, RecoveryPolicy::Replica);
        // An explicit checksum count suppresses the model: if the rate
        // were injected next to with_checksums the spec's KnobConflict
        // validation would reject the run outright.
        let res = engine
            .run_caqr(CaqrSpec::new(Algo::Redundant, 4, 16, 8, 4).with_checksums(1))
            .unwrap();
        assert!(res.success());
        assert_eq!(res.policy, RecoveryPolicy::default(), "static default still applies");
    }

    #[test]
    fn simulate_runs_every_sample_and_reports_throughput() {
        use crate::sim::SimScenario;
        let engine = Engine::host();
        let sc = SimScenario { procs: 256, samples: 16, ..Default::default() };
        let batch = engine.simulate(&sc).unwrap();
        assert_eq!(batch.reports.len(), 16, "one report per sample");
        assert_eq!(batch.successes(), 16, "no faults armed");
        assert!(batch.events() > 0);
        assert!(batch.virtual_ns() > 0);
        // Bad scenarios fail validation before touching the pool.
        let bad = SimScenario { procs: 0, ..Default::default() };
        assert!(engine.simulate(&bad).is_err());
    }

    #[test]
    fn engine_executor_overrides_spec_executor() {
        // The session owns the backend: a spec carrying a different
        // executor still runs on the engine's.
        let engine = Engine::host();
        let spec = small(Algo::Baseline);
        let res = engine.run(spec).unwrap();
        assert!(res.success());
        assert_eq!(engine.executor().backend(), crate::runtime::Backend::Host);
    }
}
