//! Comparator baseline: TSQR + diskless neighbour checkpointing.
//!
//! The paper motivates redundancy-for-free by contrast with classic
//! ABFT approaches that *add* redundancy: diskless checkpointing
//! (Plank et al. [17]) stores each process's state in the memory of a
//! partner process after every step.  This module implements that
//! comparator on the same simulated world so the benches can put real
//! numbers behind the comparison (TAB-P2):
//!
//! * fault-free cost: baseline TSQR + one extra checkpoint message per
//!   participant per step (the redundant family pays zero extra
//!   messages — the exchange *is* the algorithm's communication);
//! * robustness: a failed participant's R̃ is recovered from its
//!   checkpoint *if the neighbour holding the checkpoint is alive*;
//!   losing a process and its checkpoint partner together is fatal.

use std::sync::Arc;

use crate::linalg::Matrix;
use crate::tsqr::algorithms::ProcOutcome;
use crate::tsqr::context::Ctx;
use crate::tsqr::trace::Event;
use crate::ulfm::Rank;

/// Board-level namespace for checkpoint posts (kept disjoint from
/// exchange rounds, which use plain `0..rounds`).
pub const CKPT_BIT: u32 = 1 << 30;

/// Namespace for the per-round liveness heartbeat: posted right AFTER
/// the fault-injection point, so its existence is the deterministic
/// witness that a process survived boundary `s` (and its memory — with
/// the checkpoints in it — is still addressable during round `s`).
pub const HB_BIT: u32 = 1 << 29;

/// The checkpoint partner of `rank` at `round`: the nearest rank that
/// is still a *participant* of the reduction tree at this round (ranks
/// whose low `round` bits are zero stay; neighbours that already sent
/// and exited would take the checkpoint to the grave).  At the top of
/// the tree the only other participant is the buddy itself, in which
/// case the *receiver* ends up holding the sender's checkpoint — which
/// is exactly what recovery needs.
pub fn partner(rank: Rank, round: u32, procs: usize) -> Rank {
    let far = rank ^ (1usize << (round + 1));
    if far < procs {
        far
    } else {
        rank ^ (1usize << round)
    }
}

/// Checkpointed TSQR process body (drop-in alternative to
/// `tsqr::algorithms::baseline`).
///
/// Identical tree to Algorithm 1, plus: every process checkpoints its
/// current R̃ before each exchange round; a receiver whose sender died
/// recovers the sender's R̃ from the checkpoint — provided the
/// checkpoint's *holder* is still alive.
pub fn checkpointed(ctx: Ctx, a: Matrix) -> ProcOutcome {
    let rank = ctx.rank;
    let mut r = match ctx.leaf_qr(&a) {
        Ok(f) => f.r,
        Err(_) => return ProcOutcome::GaveUpPeerFailed,
    };
    // One heartbeat token per process, shared across every round's
    // post (the payload carries no information — only its existence).
    let heartbeat = Arc::new(Matrix::zeros(1, 1));
    for round in 0..ctx.plan.rounds() {
        if !ctx.plan.participates(rank, round) {
            return ProcOutcome::DoneNoR;
        }
        // Checkpoint my current state to my partner's memory — one real
        // message of R̃ bytes on every step, failure or not.  This is
        // the overhead the paper's approach avoids.  (The *simulator*
        // shares the Arc; the metrics still charge the full payload.)
        ctx.world.post(rank, round | CKPT_BIT, Arc::clone(&r));
        ctx.world.charge_message(r.size_bytes() as u64);

        if ctx.maybe_die(round).is_err() {
            return ProcOutcome::Killed;
        }
        // Survived the boundary: heartbeat. A checkpoint stored in my
        // memory is readable during round `round` iff this post exists
        // (dying at the boundary takes the checkpoints down with me).
        ctx.world.post(rank, round | HB_BIT, Arc::clone(&heartbeat));
        let Some(buddy) = ctx.plan.buddy(rank, round) else {
            continue;
        };
        if ctx.plan.is_sender(rank, round) {
            ctx.world.post(rank, round, r);
            ctx.trace.emit(Event::Send { rank, to: buddy, round });
            return ProcOutcome::DoneNoR;
        }
        let theirs = match ctx.world.fetch(buddy, round) {
            Ok(m) => {
                ctx.trace.emit(Event::Recv { rank, from: buddy, round });
                m
            }
            Err(e) if e.is_rank_failure() => {
                ctx.trace.emit(Event::PeerFailed { rank, peer: buddy, round });
                // Recover the sender's state from its checkpoint — valid
                // only if the checkpoint's *holder* survived boundary
                // `round` (a holder that died at the same boundary takes
                // the checkpoint to the grave).  The deterministic
                // witness is the holder's round-`round` heartbeat,
                // posted right after its fault-injection point: wait for
                // it; if the holder died or gave up, the fetch reports
                // the failure and the checkpoint is lost.  This keeps
                // recovery independent of thread timing — the analytic
                // model in analysis/robustness.rs mirrors it exactly.
                let holder = partner(buddy, round, ctx.plan.procs());
                if holder != rank && ctx.world.fetch(holder, round | HB_BIT).is_err() {
                    return ProcOutcome::GaveUpNoReplica;
                }
                match ctx.world.peek(buddy, round | CKPT_BIT) {
                    Some(m) => {
                        ctx.world.charge_message(m.size_bytes() as u64);
                        ctx.trace.emit(Event::Recovered { rank, from: holder, round });
                        m
                    }
                    None => return ProcOutcome::GaveUpNoReplica,
                }
            }
            Err(_) => return ProcOutcome::GaveUpPeerFailed,
        };
        match ctx.combine(round, &r, &theirs, rank, buddy) {
            Ok(next) => r = next,
            Err(_) => return ProcOutcome::GaveUpPeerFailed,
        }
    }
    ProcOutcome::FinalR(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partner_is_a_surviving_participant() {
        // At round s, participants have their low s bits zero; the
        // partner of a participant must also be a participant.
        let procs = 16;
        for s in 0..3u32 {
            for r in (0..procs).filter(|r| r & ((1 << s) - 1) == 0) {
                let p = partner(r, s, procs);
                assert!(p < procs);
                assert_eq!(p & ((1usize << s) - 1), 0, "partner {p} not in tree at round {s}");
                assert_ne!(p, r);
            }
        }
        // Top of the tree: partner degenerates to the buddy.
        assert_eq!(partner(8, 3, 16), 0);
        assert_eq!(partner(0, 3, 16), 8);
    }

    #[test]
    fn ckpt_namespace_disjoint_from_rounds() {
        for round in 0..30u32 {
            assert_ne!(round | CKPT_BIT, round);
            assert!(round | CKPT_BIT >= CKPT_BIT);
        }
    }
}
