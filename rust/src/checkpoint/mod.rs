//! Comparator baseline: TSQR + diskless neighbour checkpointing.
//!
//! The paper motivates redundancy-for-free by contrast with classic
//! ABFT approaches that *add* redundancy: diskless checkpointing
//! (Plank et al. [17]) stores each process's state in the memory of a
//! partner process after every step.  This module implements that
//! comparator on the same simulated world so the benches can put real
//! numbers behind the comparison (TAB-P2):
//!
//! * fault-free cost: baseline TSQR + one extra checkpoint message per
//!   participant per step (the redundant family pays zero extra
//!   messages — the exchange *is* the algorithm's communication);
//! * robustness: a failed participant's R̃ is recovered from its
//!   checkpoint *if the neighbour holding the checkpoint is alive*;
//!   losing a process and its checkpoint partner together is fatal.

use std::collections::HashSet;
use std::sync::Arc;

use crate::linalg::Matrix;
use crate::metrics::VirtualTimeBreakdown;
use crate::sim::CostModel;
use crate::tsqr::algorithms::ProcOutcome;
use crate::tsqr::context::Ctx;
use crate::tsqr::trace::Event;
use crate::ulfm::Rank;
use crate::util::{Rng, derive_seed};

/// Board-level namespace for checkpoint posts (kept disjoint from
/// exchange rounds, which use plain `0..rounds`).
pub const CKPT_BIT: u32 = 1 << 30;

/// Namespace for the per-round liveness heartbeat: posted right AFTER
/// the fault-injection point, so its existence is the deterministic
/// witness that a process survived boundary `s` (and its memory — with
/// the checkpoints in it — is still addressable during round `s`).
pub const HB_BIT: u32 = 1 << 29;

/// The checkpoint partner of `rank` at `round`.
///
/// On a power-of-two world during a TSQR tree walk (`round <
/// log₂ procs`) this is the nearest rank that is still a *participant*
/// of the reduction tree at this round (ranks whose low `round` bits
/// are zero stay; neighbours that already sent and exited would take
/// the checkpoint to the grave).  At the top of the tree the only
/// other participant is the buddy itself, in which case the *receiver*
/// ends up holding the sender's checkpoint — which is exactly what
/// recovery needs.
///
/// Outside the tree — odd or otherwise non-power-of-two worlds, or
/// rounds past the tree depth (the engine-era baseline snapshots every
/// few panels, indefinitely) — the XOR trick is meaningless (it can
/// even name ranks outside the world), so the partner is a round-robin
/// rotation: offset `1 + round mod (P−1)`, which is never `rank`
/// itself and cycles through every peer as rounds advance, spreading
/// the buddy load evenly.
pub fn partner(rank: Rank, round: u32, procs: usize) -> Rank {
    if procs < 2 {
        return rank;
    }
    if procs.is_power_of_two() && (round as usize) < procs.trailing_zeros() as usize {
        let far = rank ^ (1usize << (round + 1));
        if far < procs {
            return far;
        }
        return rank ^ (1usize << round);
    }
    let offset = 1 + (round as usize % (procs - 1));
    (rank + offset) % procs
}

/// Checkpointed TSQR process body (drop-in alternative to
/// `tsqr::algorithms::baseline`).
///
/// Identical tree to Algorithm 1, plus: every process checkpoints its
/// current R̃ before each exchange round; a receiver whose sender died
/// recovers the sender's R̃ from the checkpoint — provided the
/// checkpoint's *holder* is still alive.
pub fn checkpointed(ctx: Ctx, a: Matrix) -> ProcOutcome {
    let rank = ctx.rank;
    let mut r = match ctx.leaf_qr(&a) {
        Ok(f) => f.r,
        Err(_) => return ProcOutcome::GaveUpPeerFailed,
    };
    // One heartbeat token per process, shared across every round's
    // post (the payload carries no information — only its existence).
    let heartbeat = Arc::new(Matrix::zeros(1, 1));
    for round in 0..ctx.plan.rounds() {
        if !ctx.plan.participates(rank, round) {
            return ProcOutcome::DoneNoR;
        }
        // Checkpoint my current state to my partner's memory — one real
        // message of R̃ bytes on every step, failure or not.  This is
        // the overhead the paper's approach avoids.  (The *simulator*
        // shares the Arc; the metrics still charge the full payload.)
        ctx.world.post(rank, round | CKPT_BIT, Arc::clone(&r));
        ctx.world.charge_message(r.size_bytes() as u64);

        if ctx.maybe_die(round).is_err() {
            return ProcOutcome::Killed;
        }
        // Survived the boundary: heartbeat. A checkpoint stored in my
        // memory is readable during round `round` iff this post exists
        // (dying at the boundary takes the checkpoints down with me).
        ctx.world.post(rank, round | HB_BIT, Arc::clone(&heartbeat));
        let Some(buddy) = ctx.plan.buddy(rank, round) else {
            continue;
        };
        if ctx.plan.is_sender(rank, round) {
            ctx.world.post(rank, round, r);
            ctx.trace.emit(Event::Send { rank, to: buddy, round });
            return ProcOutcome::DoneNoR;
        }
        let theirs = match ctx.world.fetch(buddy, round) {
            Ok(m) => {
                ctx.trace.emit(Event::Recv { rank, from: buddy, round });
                m
            }
            Err(e) if e.is_rank_failure() => {
                ctx.trace.emit(Event::PeerFailed { rank, peer: buddy, round });
                // Recover the sender's state from its checkpoint — valid
                // only if the checkpoint's *holder* survived boundary
                // `round` (a holder that died at the same boundary takes
                // the checkpoint to the grave).  The deterministic
                // witness is the holder's round-`round` heartbeat,
                // posted right after its fault-injection point: wait for
                // it; if the holder died or gave up, the fetch reports
                // the failure and the checkpoint is lost.  This keeps
                // recovery independent of thread timing — the analytic
                // model in analysis/robustness.rs mirrors it exactly.
                let holder = partner(buddy, round, ctx.plan.procs());
                if holder != rank && ctx.world.fetch(holder, round | HB_BIT).is_err() {
                    return ProcOutcome::GaveUpNoReplica;
                }
                match ctx.world.peek(buddy, round | CKPT_BIT) {
                    Some(m) => {
                        ctx.world.charge_message(m.size_bytes() as u64);
                        ctx.trace.emit(Event::Recovered { rank, from: holder, round });
                        m
                    }
                    None => return ProcOutcome::GaveUpNoReplica,
                }
            }
            Err(_) => return ProcOutcome::GaveUpPeerFailed,
        };
        match ctx.combine(round, &r, &theirs, rank, buddy) {
            Ok(next) => r = next,
            Err(_) => return ProcOutcome::GaveUpPeerFailed,
        }
    }
    ProcOutcome::FinalR(r)
}

/// Engine-era checkpoint/restart baseline on the *CAQR* panel walk —
/// the contender `analysis::checkpoint_vs_redundant` races against the
/// replicated and coded ladders.
///
/// The model mirrors the simulator's virtual clock exactly
/// ([`CostModel`] panel costs, deaths/rank/virtual-second churn) so
/// the three contenders are compared on one time axis:
///
/// * every `interval` panels, each rank snapshots its R block and
///   reflector panel into its [`partner`]'s memory — a pure
///   communication cost charged to
///   [`VirtualTimeBreakdown::network_ns`];
/// * deaths in a panel window force a **restart** from the last
///   snapshot: the lost panels are re-executed, their cost moved from
///   `compute_ns` to `recovery_ns` (redundant-family runs charge
///   recovery too, so `repro compare` reads apples-to-apples);
/// * a rank dying *together with its checkpoint partner* in one window
///   loses state irrecoverably — the run fails, exactly the fatality
///   rule `checkpointed()` enforces message-by-message above.
#[derive(Debug, Clone)]
pub struct CheckpointBaseline {
    /// World size.
    pub procs: usize,
    /// Panels in the plan (same shape rules as `SimScenario`).
    pub panels: usize,
    /// Panels between snapshots (1 = checkpoint every panel).
    pub interval: usize,
    /// Deaths per rank per virtual second.
    pub rate: f64,
    /// Virtual cost of one snapshot barrier (R + reflector panel to
    /// the partner's memory).
    pub snapshot_ns: u64,
    /// Virtual stage costs (shared with `sim::` and the adaptive
    /// policy).
    pub costs: CostModel,
    /// Base seed; sample `i` replays under `derive_seed(seed, i)`.
    pub seed: u64,
}

/// What one checkpointed replay did.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointReport {
    /// Whether the run completed (false: a rank and its partner died
    /// in the same window, or the run thrashed past the restart cap).
    pub success: bool,
    /// Panel being executed when the run became unrecoverable.
    pub failed_at: Option<usize>,
    /// Restarts taken (each rolls back to the last snapshot).
    pub restarts: u32,
    /// Snapshots taken.
    pub checkpoints: u32,
    /// Total deaths sampled across the run.
    pub deaths: usize,
    /// Virtual time: useful work in `compute_ns`, snapshot traffic in
    /// `network_ns`, re-executed panels in `recovery_ns`.
    pub time: VirtualTimeBreakdown,
}

/// Aggregate of a checkpointed campaign ([`CheckpointBaseline::campaign`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointCampaign {
    /// Samples replayed.
    pub samples: u64,
    /// Samples that completed.
    pub survived: u64,
    /// Restarts summed over all samples.
    pub restarts: u32,
    /// Merged virtual time across samples.
    pub time: VirtualTimeBreakdown,
}

impl CheckpointCampaign {
    /// Fraction of samples that completed.
    pub fn survival(&self) -> f64 {
        if self.samples == 0 { 1.0 } else { self.survived as f64 / self.samples as f64 }
    }
}

/// A restart count past which the run is declared dead: spending three
/// orders of magnitude more attempts than panels is thrashing, not
/// progress (and it bounds the replay loop at absurd rates).
const MAX_RESTARTS: u32 = 1000;

impl CheckpointBaseline {
    /// A baseline for a `(procs, panels)` walk: checkpoint every
    /// panel, no churn, snapshot costed like one panel factor (R +
    /// reflectors are the same order of bytes as the panel itself).
    pub fn new(procs: usize, panels: usize) -> Self {
        let costs = CostModel::default();
        Self { procs, panels, interval: 1, rate: 0.0, snapshot_ns: costs.factor_ns, costs, seed: 0x5eed }
    }

    /// Panels between snapshots (must be ≥ 1).
    pub fn with_interval(mut self, interval: usize) -> Self {
        assert!(interval >= 1, "checkpoint interval must be >= 1");
        self.interval = interval;
        self
    }

    /// Deaths per rank per virtual second.
    pub fn with_rate(mut self, rate: f64) -> Self {
        self.rate = rate;
        self
    }

    /// Virtual cost of one snapshot barrier.
    pub fn with_snapshot_ns(mut self, ns: u64) -> Self {
        self.snapshot_ns = ns;
        self
    }

    /// Virtual stage costs.
    pub fn with_costs(mut self, costs: CostModel) -> Self {
        self.costs = costs;
        self
    }

    /// Base seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Virtual cost of executing panel `k`: one factor stage plus the
    /// trailing-update pool slots — the same charge `sim::` makes, so
    /// the contenders share a clock.
    fn panel_cost_ns(&self, k: usize) -> u64 {
        let tasks = 2 * (self.panels - 1 - k);
        let slots = if tasks == 0 { 0 } else { tasks.div_ceil(self.procs) as u64 };
        self.costs.factor_ns + self.costs.update_ns * slots
    }

    /// Sample the dead set of one window and test the fatality rule:
    /// any rank whose checkpoint partner died in the same window has
    /// lost both its state and the copy.
    fn window_fatal(&self, rng: &mut Rng, f: usize, round: u32) -> bool {
        let mut dead = HashSet::with_capacity(f);
        while dead.len() < f {
            dead.insert(rng.below(self.procs));
        }
        dead.iter().any(|&r| dead.contains(&partner(r, round, self.procs)))
    }

    /// Replay sample `i`: walk the panels on the virtual clock,
    /// snapshotting every `interval` panels and restarting (or dying)
    /// on churn.  Pure function of `(self, i)`.
    pub fn replay(&self, i: u64) -> CheckpointReport {
        assert!(self.procs >= 1 && self.panels >= 1);
        let mut rng = Rng::new(derive_seed(self.seed, i));
        let mut time = VirtualTimeBreakdown::default();
        let (mut restarts, mut checkpoints, mut deaths) = (0u32, 0u32, 0usize);
        let mut last_snapshot = 0usize; // first panel not covered by a snapshot
        let mut k = 0usize;
        while k < self.panels {
            let cost = self.panel_cost_ns(k);
            time.compute_ns += cost;
            let lambda = self.procs as f64 * self.rate * cost as f64 * 1e-9;
            let f = poisson_sample(&mut rng, lambda).min(self.procs);
            deaths += f;
            if f >= 2 && self.procs >= 2 && self.window_fatal(&mut rng, f, checkpoints) {
                return CheckpointReport {
                    success: false,
                    failed_at: Some(k),
                    restarts,
                    checkpoints,
                    deaths,
                    time,
                };
            }
            if f > 0 {
                // Survivable loss: roll back to the last snapshot.  The
                // work since it — including this panel's attempt — was
                // wasted; move it from `compute` to `recovery`.
                restarts += 1;
                if restarts > MAX_RESTARTS {
                    return CheckpointReport {
                        success: false,
                        failed_at: Some(k),
                        restarts,
                        checkpoints,
                        deaths,
                        time,
                    };
                }
                let lost: u64 = (last_snapshot..=k).map(|j| self.panel_cost_ns(j)).sum();
                time.compute_ns -= lost;
                time.recovery_ns += lost;
                k = last_snapshot;
                continue;
            }
            k += 1;
            if k < self.panels && k % self.interval == 0 {
                time.network_ns += self.snapshot_ns;
                checkpoints += 1;
                last_snapshot = k;
            }
        }
        CheckpointReport { success: true, failed_at: None, restarts, checkpoints, deaths, time }
    }

    /// Replay `samples` reseeded runs and merge.
    pub fn campaign(&self, samples: u64) -> CheckpointCampaign {
        let mut agg = CheckpointCampaign {
            samples,
            survived: 0,
            restarts: 0,
            time: VirtualTimeBreakdown::default(),
        };
        for i in 0..samples {
            let r = self.replay(i);
            agg.survived += r.success as u64;
            agg.restarts += r.restarts;
            agg.time.merge(&r.time);
        }
        agg
    }
}

/// One Poisson draw: Knuth's product method below λ = 30 (exact, cheap
/// there), the normal approximation above (λ at 10⁵ ranks can be in
/// the hundreds, where `e^{−λ}` underflows and Knuth never terminates).
fn poisson_sample(rng: &mut Rng, lambda: f64) -> usize {
    if lambda <= 0.0 {
        return 0;
    }
    if lambda < 30.0 {
        let l = (-lambda).exp();
        let mut k = 0usize;
        let mut p = 1.0f64;
        loop {
            p *= rng.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }
    (lambda + lambda.sqrt() * rng.normal()).round().max(0.0) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partner_is_a_surviving_participant() {
        // At round s, participants have their low s bits zero; the
        // partner of a participant must also be a participant.
        let procs = 16;
        for s in 0..3u32 {
            for r in (0..procs).filter(|r| r & ((1 << s) - 1) == 0) {
                let p = partner(r, s, procs);
                assert!(p < procs);
                assert_eq!(p & ((1usize << s) - 1), 0, "partner {p} not in tree at round {s}");
                assert_ne!(p, r);
            }
        }
        // Top of the tree: partner degenerates to the buddy.
        assert_eq!(partner(8, 3, 16), 0);
        assert_eq!(partner(0, 3, 16), 8);
    }

    #[test]
    fn ckpt_namespace_disjoint_from_rounds() {
        for round in 0..30u32 {
            assert_ne!(round | CKPT_BIT, round);
            assert!(round | CKPT_BIT >= CKPT_BIT);
        }
    }

    /// The satellite fix: on an odd world the XOR trick is meaningless
    /// (it can name ranks ≥ P); the partner must instead be the
    /// round-robin rotation `(rank + 1 + round mod (P−1)) mod P` —
    /// never self, always in range, cycling through every peer.
    #[test]
    fn odd_world_partner_is_round_robin_rotation() {
        for procs in [3usize, 5, 7, 9] {
            for round in 0..2 * procs as u32 {
                for rank in 0..procs {
                    let p = partner(rank, round, procs);
                    assert!(p < procs, "P={procs} r={rank} s={round}: partner {p} out of range");
                    assert_ne!(p, rank, "P={procs} s={round}: self-partner loses the state");
                    let offset = 1 + (round as usize % (procs - 1));
                    assert_eq!(p, (rank + offset) % procs, "pinned rotation");
                }
            }
            // Over P−1 consecutive rounds rank 0 is partnered with
            // every other rank exactly once.
            let mut seen: Vec<Rank> =
                (0..procs as u32 - 1).map(|s| partner(0, s, procs)).collect();
            seen.sort_unstable();
            assert_eq!(seen, (1..procs).collect::<Vec<_>>(), "P={procs}: full coverage");
        }
        // Degenerate single-rank world: nobody else to hold the copy.
        assert_eq!(partner(0, 0, 1), 0);
        // Power-of-two worlds past the tree depth also rotate (the
        // baseline checkpoints indefinitely; XOR would leave range).
        for round in 4..12u32 {
            let p = partner(5, round, 16);
            assert!(p < 16);
            assert_ne!(p, 5);
        }
    }

    #[test]
    fn fault_free_replay_charges_compute_and_snapshots_only() {
        let base = CheckpointBaseline::new(8, 4).with_interval(2);
        let r = base.replay(0);
        assert!(r.success);
        assert_eq!(r.failed_at, None);
        assert_eq!((r.restarts, r.deaths), (0, 0));
        // Snapshots after panels 2 (k=2 is the only interior multiple
        // of the interval): one checkpoint, charged to network.
        assert_eq!(r.checkpoints, 1);
        assert_eq!(r.time.network_ns, base.snapshot_ns);
        assert_eq!(r.time.recovery_ns, 0);
        // Compute: Σ_k factor + update·ceil(2(panels−1−k)/procs).
        let expect: u64 = (0..4).map(|k| base.panel_cost_ns(k)).sum();
        assert_eq!(r.time.compute_ns, expect);
        // Pure function of (baseline, sample).
        assert_eq!(base.replay(0), r);
    }

    #[test]
    fn churn_forces_restarts_and_charges_recovery() {
        // Rate high enough that deaths are near-certain each window
        // but the world is big enough that buddy-pair wipes are rare.
        let base = CheckpointBaseline::new(1024, 6).with_rate(20.0).with_seed(7);
        let c = base.campaign(32);
        assert!(c.restarts > 0, "this rate must force restarts");
        assert!(c.time.recovery_ns > 0, "restarts must charge recovery time");
        assert!(c.survival() > 0.0, "single deaths are survivable by restart");
    }

    #[test]
    fn buddy_pair_wipe_or_thrash_kills_the_run() {
        // A 2-rank world: any window with 2+ deaths wipes rank 0 and
        // its only possible partner together — fatal, not restartable.
        let base = CheckpointBaseline::new(2, 4).with_rate(1e7).with_seed(3);
        let c = base.campaign(16);
        assert!(c.survival() < 1.0, "extreme churn must kill 2-rank runs");
        // And the failure is typed in the per-sample report.
        let dead = (0..16).map(|i| base.replay(i)).find(|r| !r.success).unwrap();
        assert!(dead.failed_at.is_some());
    }

    #[test]
    fn zero_rate_campaign_is_certain_survival() {
        let c = CheckpointBaseline::new(16, 8).campaign(4);
        assert_eq!(c.survival(), 1.0);
        assert_eq!(c.restarts, 0);
        assert_eq!(c.time.recovery_ns, 0);
    }
}
