//! Typed run configuration: config files + CLI overrides → `RunSpec`.
//!
//! Every example and the `repro` CLI build their runs through this, so
//! a downstream user configures the system exactly one way.  The file
//! format is the flat `key = value` dialect of `util::kv` (the vendored
//! crate set has no TOML parser; the subset below is TOML-compatible):
//!
//! ```text
//! # ft-tsqr.conf
//! algo = "replace"
//! procs = 16
//! rows-per-proc = 256
//! cols = 16
//! seed = 7
//! backend = "auto"          # pjrt | host | auto
//! artifact-dir = "artifacts"
//! pjrt-shards = 2
//! verify = true
//! trace = false
//!
//! [failures]
//! mode = "at"               # none | at | bernoulli | exponential | random-at-round
//! kills = [[2, 1]]          # rank 2 dies at the end of step 1
//! ```

use std::path::Path;

use crate::engine::{Engine, EngineBuilder};
use crate::error::{Error, Result};
use crate::fault::KillSchedule;
use crate::runtime::{Backend, Executor, KernelProfile};
use crate::tsqr::{Algo, RunSpec, TreePlan};
use crate::util::kv::Doc;

/// Failure-injection configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub enum FailureConfig {
    /// Fault-free execution.
    #[default]
    None,
    /// Explicit (rank, round) kills — `round` is the boundary at which
    /// the rank crashes ("end of step round" in the paper's words).
    At { kills: Vec<(usize, u32)> },
    /// Each rank fails at each boundary independently w.p. `p`.
    Bernoulli { p: f64, seed: u64 },
    /// Per-rank exponential lifetime with `rate` deaths/step.
    Exponential { rate: f64, seed: u64 },
    /// Exactly `f` random ranks die at boundary `round`.
    RandomAtRound { round: u32, f: usize, seed: u64, protect_root: bool },
}

impl FailureConfig {
    /// Materialize into a schedule for a world of `procs`/`rounds`.
    pub fn schedule(&self, procs: usize, rounds: u32) -> KillSchedule {
        match self {
            FailureConfig::None => KillSchedule::none(),
            FailureConfig::At { kills } => KillSchedule::at(kills),
            FailureConfig::Bernoulli { p, seed } => {
                KillSchedule::bernoulli(procs, rounds, *p, *seed)
            }
            FailureConfig::Exponential { rate, seed } => {
                KillSchedule::exponential(procs, rounds, *rate, *seed)
            }
            FailureConfig::RandomAtRound { round, f, seed, protect_root } => {
                KillSchedule::random_at_round(procs, *round, *f, protect_root.then_some(0), *seed)
            }
        }
    }

    /// Same stochastic model, fresh seed stream — how campaign seed
    /// sweeps draw a fresh failure pattern per run.  Stream `i` draws
    /// its seed through [`crate::util::derive_seed`]`(seed, i)` (the
    /// crate-wide derivation rule, so adjacent streams never overlap
    /// the way `seed + i` does).  Deterministic models (`None`, `At`)
    /// are returned unchanged.
    pub fn reseeded(&self, stream: u64) -> FailureConfig {
        let derive = |seed| crate::util::derive_seed(seed, stream);
        match self.clone() {
            FailureConfig::Bernoulli { p, seed } => {
                FailureConfig::Bernoulli { p, seed: derive(seed) }
            }
            FailureConfig::Exponential { rate, seed } => {
                FailureConfig::Exponential { rate, seed: derive(seed) }
            }
            FailureConfig::RandomAtRound { round, f, seed, protect_root } => {
                FailureConfig::RandomAtRound { round, f, seed: derive(seed), protect_root }
            }
            deterministic => deterministic,
        }
    }

    fn from_doc(doc: &Doc) -> Result<FailureConfig> {
        let Some(mode) = doc.str_of("failures.mode") else {
            return Ok(FailureConfig::None);
        };
        let seed = doc.u64_of("failures.seed").unwrap_or(0);
        match mode {
            "none" => Ok(FailureConfig::None),
            "at" => {
                let kills = doc
                    .pairs_of("failures.kills")
                    .ok_or_else(|| Error::Config("failures.kills must be [[rank, round], ...]".into()))?;
                Ok(FailureConfig::At { kills })
            }
            "bernoulli" => {
                let p = doc
                    .f64_of("failures.p")
                    .ok_or_else(|| Error::Config("failures.p required for bernoulli".into()))?;
                Ok(FailureConfig::Bernoulli { p, seed })
            }
            "exponential" => {
                let rate = doc
                    .f64_of("failures.rate")
                    .ok_or_else(|| Error::Config("failures.rate required for exponential".into()))?;
                Ok(FailureConfig::Exponential { rate, seed })
            }
            "random-at-round" => Ok(FailureConfig::RandomAtRound {
                round: doc
                    .usize_of("failures.round")
                    .ok_or_else(|| Error::Config("failures.round required".into()))?
                    as u32,
                f: doc
                    .usize_of("failures.f")
                    .ok_or_else(|| Error::Config("failures.f required".into()))?,
                seed,
                protect_root: doc.bool_of("failures.protect-root").unwrap_or(false),
            }),
            other => Err(Error::Config(format!("unknown failures.mode '{other}'"))),
        }
    }
}

/// Multi-tenant service-layer knobs (`[service]` section) — the
/// bounded-queue and dispatch-window settings `repro serve` builds its
/// [`crate::service::ServiceBuilder`] from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServiceConfig {
    /// Global bound on waiting jobs (`service.queue-depth`).
    pub queue_depth: usize,
    /// Per-tenant bound on waiting jobs (`service.tenant-depth`).
    pub tenant_depth: usize,
    /// Campaigns kept in flight concurrently (`service.inflight`).
    pub inflight: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self { queue_depth: 256, tenant_depth: 256, inflight: 4 }
    }
}

impl ServiceConfig {
    /// Materialize a builder with these bounds.
    pub fn builder(&self) -> crate::service::ServiceBuilder {
        crate::service::ServiceBuilder::new()
            .queue_depth(self.queue_depth)
            .tenant_depth(self.tenant_depth)
            .max_inflight(self.inflight)
    }

    fn from_doc(doc: &Doc) -> ServiceConfig {
        let mut sc = ServiceConfig::default();
        if let Some(v) = doc.usize_of("service.queue-depth") {
            sc.queue_depth = v;
        }
        if let Some(v) = doc.usize_of("service.tenant-depth") {
            sc.tenant_depth = v;
        }
        if let Some(v) = doc.usize_of("service.inflight") {
            sc.inflight = v;
        }
        sc
    }
}

/// The full run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Which algorithm to run.
    pub algo: Algo,
    /// Simulated world size.
    pub procs: usize,
    /// Leaf panel rows per process.
    pub rows_per_proc: usize,
    /// Matrix columns.
    pub cols: usize,
    /// Input-matrix seed.
    pub seed: u64,
    /// Compute backend (`pjrt` | `host` | `auto`).
    pub backend: Backend,
    /// Where to look for AOT artifacts.
    pub artifact_dir: String,
    /// PJRT service threads.
    pub pjrt_shards: usize,
    /// Verify the final R against the host oracle.
    pub verify: bool,
    /// Collect an execution trace.
    pub trace: bool,
    /// Failure-injection model.
    pub failures: FailureConfig,
    /// Kernel profile (`reference` | `blocked`); `None` keeps the
    /// engine default (`reference`).
    pub profile: Option<KernelProfile>,
    /// The `--threads` knob: pool workers to pre-spawn **and** the
    /// per-kernel GEMM fan-out width (0 = unset: grow on demand,
    /// sequential kernels).  Flows as one `runtime::Parallelism` value
    /// from here through `EngineBuilder::threads` to the GEMM slab
    /// scheduler and the CAQR trailing-update fan-out; every setting is
    /// bit-identical (see `linalg::gemm`).  The pool stays elastic and
    /// can still grow past this count if a run needs more concurrent
    /// blocking tasks (see `engine::WorkerPool`).
    pub threads: usize,
    /// Multi-tenant service bounds (`repro serve`).
    pub service: ServiceConfig,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            algo: Algo::Redundant,
            procs: 8,
            rows_per_proc: 256,
            cols: 8,
            seed: 42,
            backend: Backend::Auto,
            artifact_dir: "artifacts".into(),
            pjrt_shards: 2,
            verify: true,
            trace: false,
            failures: FailureConfig::None,
            profile: None,
            threads: 0,
            service: ServiceConfig::default(),
        }
    }
}

/// Keys accepted at the top level (anything else is a config error —
/// catches typos the way serde's `deny_unknown_fields` would).
const KNOWN_KEYS: &[&str] = &[
    "algo",
    "procs",
    "rows-per-proc",
    "cols",
    "seed",
    "backend",
    "artifact-dir",
    "pjrt-shards",
    "verify",
    "trace",
    "profile",
    "threads",
    "failures.mode",
    "failures.kills",
    "failures.p",
    "failures.rate",
    "failures.round",
    "failures.f",
    "failures.seed",
    "failures.protect-root",
    "service.queue-depth",
    "service.tenant-depth",
    "service.inflight",
];

impl Config {
    /// Parse from config-file text.
    pub fn from_text(text: &str) -> Result<Self> {
        let doc = Doc::parse(text)?;
        for k in doc.keys() {
            if !KNOWN_KEYS.contains(&k) {
                return Err(Error::Config(format!("unknown config key '{k}'")));
            }
        }
        let mut cfg = Config::default();
        if let Some(a) = doc.str_of("algo") {
            cfg.algo = a.parse()?;
        }
        if let Some(v) = doc.usize_of("procs") {
            cfg.procs = v;
        }
        if let Some(v) = doc.usize_of("rows-per-proc") {
            cfg.rows_per_proc = v;
        }
        if let Some(v) = doc.usize_of("cols") {
            cfg.cols = v;
        }
        if let Some(v) = doc.u64_of("seed") {
            cfg.seed = v;
        }
        if let Some(v) = doc.str_of("backend") {
            cfg.backend = v.parse()?;
        }
        if let Some(v) = doc.str_of("artifact-dir") {
            cfg.artifact_dir = v.to_string();
        }
        if let Some(v) = doc.usize_of("pjrt-shards") {
            cfg.pjrt_shards = v;
        }
        if let Some(v) = doc.bool_of("verify") {
            cfg.verify = v;
        }
        if let Some(v) = doc.bool_of("trace") {
            cfg.trace = v;
        }
        if let Some(v) = doc.str_of("profile") {
            cfg.profile = Some(v.parse()?);
        }
        if let Some(v) = doc.usize_of("threads") {
            cfg.threads = v;
        }
        cfg.failures = FailureConfig::from_doc(&doc)?;
        cfg.service = ServiceConfig::from_doc(&doc);
        Ok(cfg)
    }

    /// Load from a config file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error::Config(format!("cannot read {}: {e}", path.as_ref().display())))?;
        Self::from_text(&text)
    }

    /// Build the executor this config asks for.
    pub fn executor(&self) -> Result<Executor> {
        match self.backend {
            Backend::Host => Ok(Executor::host()),
            Backend::Pjrt => {
                Executor::with_artifacts(&self.artifact_dir, Backend::Pjrt, self.pjrt_shards)
            }
            Backend::Auto => Ok(Executor::auto(&self.artifact_dir)),
        }
    }

    /// Build a long-lived [`Engine`] for this config's backend
    /// settings — the session every CLI subcommand submits through.
    pub fn engine(&self) -> Result<Engine> {
        EngineBuilder::new()
            .backend(self.backend)
            .artifact_dir(self.artifact_dir.clone())
            .pjrt_shards(self.pjrt_shards)
            .kernel_profile(self.profile.unwrap_or_default())
            .threads(self.threads)
            .build()
    }

    /// Materialize the full `RunSpec` (validates on the way out).
    pub fn to_spec(&self) -> Result<RunSpec> {
        let spec = self.to_engine_spec()?.with_executor(self.executor()?);
        Ok(spec)
    }

    /// [`to_spec`](Self::to_spec) minus the executor: for submission to
    /// an [`Engine`], which supplies the session executor itself (so
    /// the backend is not loaded twice).
    pub fn to_engine_spec(&self) -> Result<RunSpec> {
        let rounds = TreePlan::new(self.procs.max(1)).rounds();
        let spec = RunSpec::new(self.algo, self.procs, self.rows_per_proc, self.cols)
            .with_seed(self.seed)
            .with_schedule(self.failures.schedule(self.procs, rounds))
            .with_trace(self.trace)
            .with_verify(self.verify);
        spec.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        let cfg = Config::default();
        let spec = cfg.to_spec().unwrap();
        assert_eq!(spec.procs, 8);
        assert!(spec.verify);
    }

    #[test]
    fn parses_full_config() {
        let cfg = Config::from_text(
            r#"
            algo = "replace"
            procs = 16
            rows-per-proc = 128
            cols = 16
            seed = 7
            backend = "host"
            trace = true

            [failures]
            mode = "at"
            kills = [[2, 1], [5, 2]]
            "#,
        )
        .unwrap();
        assert_eq!(cfg.algo, Algo::Replace);
        assert_eq!(cfg.procs, 16);
        assert!(cfg.trace);
        let spec = cfg.to_spec().unwrap();
        assert_eq!(spec.schedule.entries(), vec![(2, 1), (5, 2)]);
    }

    #[test]
    fn profile_and_threads_parse_and_reach_the_engine() {
        let cfg = Config::from_text(
            "backend = \"host\"\nprofile = \"blocked\"\nthreads = 3",
        )
        .unwrap();
        assert_eq!(cfg.profile, Some(KernelProfile::Blocked));
        assert_eq!(cfg.threads, 3);
        let engine = cfg.engine().unwrap();
        assert_eq!(engine.default_kernel_profile(), KernelProfile::Blocked);
        assert_eq!(engine.workers(), 3, "threads prewarms the pool");
        assert_eq!(
            engine.default_parallelism().gemm_threads(),
            3,
            "threads must reach kernel execution, not just prewarm"
        );
        assert!(Config::from_text("profile = \"warp\"").is_err(), "bad profile rejected");
    }

    #[test]
    fn rejects_unknown_keys() {
        assert!(Config::from_text("bogus = 1").is_err());
        assert!(Config::from_text("[failures]\nmystery = 2").is_err());
        assert!(Config::from_text("[service]\nqueue = 9").is_err(), "typo'd service key");
    }

    #[test]
    fn service_section_parses_with_defaults() {
        let cfg = Config::from_text("").unwrap();
        assert_eq!(cfg.service, ServiceConfig::default());
        let cfg = Config::from_text(
            "[service]\nqueue-depth = 32\ntenant-depth = 8\ninflight = 2",
        )
        .unwrap();
        assert_eq!(
            cfg.service,
            ServiceConfig { queue_depth: 32, tenant_depth: 8, inflight: 2 }
        );
        // The builder carries the bounds into a live service.
        let svc = cfg.service.builder().build(Engine::host());
        assert_eq!(svc.queue_depth(), 32);
        assert_eq!(svc.tenant_depth(), 8);
        assert_eq!(svc.max_inflight(), 2);
    }

    #[test]
    fn rejects_invalid_spec() {
        let cfg = Config { procs: 6, ..Config::default() }; // redundant needs pow2
        assert!(cfg.to_spec().is_err());
    }

    #[test]
    fn failure_modes_materialize() {
        assert_eq!(FailureConfig::None.schedule(8, 3).remaining(), 0);
        assert_eq!(
            FailureConfig::At { kills: vec![(1, 0)] }.schedule(8, 3).entries(),
            vec![(1, 0)]
        );
        assert_eq!(FailureConfig::Bernoulli { p: 1.0, seed: 0 }.schedule(8, 3).remaining(), 8);
        let rar = FailureConfig::RandomAtRound { round: 1, f: 3, seed: 0, protect_root: true };
        let entries = rar.schedule(8, 3).entries();
        assert_eq!(entries.len(), 3);
        assert!(entries.iter().all(|&(r, s)| r != 0 && s == 1));
    }

    #[test]
    fn failure_modes_parse() {
        let c = Config::from_text("[failures]\nmode = \"bernoulli\"\np = 0.1\nseed = 3").unwrap();
        assert_eq!(c.failures, FailureConfig::Bernoulli { p: 0.1, seed: 3 });
        let c = Config::from_text("[failures]\nmode = \"exponential\"\nrate = 0.5").unwrap();
        assert_eq!(c.failures, FailureConfig::Exponential { rate: 0.5, seed: 0 });
        let c = Config::from_text(
            "[failures]\nmode = \"random-at-round\"\nround = 2\nf = 3\nprotect-root = true",
        )
        .unwrap();
        assert_eq!(
            c.failures,
            FailureConfig::RandomAtRound { round: 2, f: 3, seed: 0, protect_root: true }
        );
        assert!(Config::from_text("[failures]\nmode = \"bernoulli\"").is_err(), "p required");
        assert!(Config::from_text("[failures]\nmode = \"what\"").is_err());
    }

    #[test]
    fn engine_and_engine_spec() {
        let cfg = Config { backend: Backend::Host, ..Config::default() };
        let engine = cfg.engine().unwrap();
        assert_eq!(engine.executor().backend(), Backend::Host);
        let spec = cfg.to_engine_spec().unwrap();
        let res = engine.run(spec).unwrap();
        assert!(res.success());
    }

    #[test]
    fn reseeding_shifts_stochastic_models_only() {
        use crate::util::derive_seed;
        let b = FailureConfig::Bernoulli { p: 0.1, seed: 3 };
        assert_eq!(b.reseeded(4), FailureConfig::Bernoulli { p: 0.1, seed: derive_seed(3, 4) });
        assert_ne!(b.reseeded(4), b.reseeded(5), "streams are distinct");
        let e = FailureConfig::Exponential { rate: 0.5, seed: 1 };
        assert_eq!(
            e.reseeded(1),
            FailureConfig::Exponential { rate: 0.5, seed: derive_seed(1, 1) }
        );
        let at = FailureConfig::At { kills: vec![(1, 0)] };
        assert_eq!(at.reseeded(9), at, "deterministic schedules unchanged");
        assert_eq!(FailureConfig::None.reseeded(9), FailureConfig::None);
    }

    #[test]
    fn load_from_file() {
        let tmp = crate::util::TestDir::new();
        let p = tmp.write("run.conf", "algo = \"sh\"\nprocs = 4\nrows-per-proc = 8\ncols = 4");
        let cfg = Config::load(p).unwrap();
        assert_eq!(cfg.algo, Algo::SelfHealing);
        assert_eq!(cfg.procs, 4);
    }
}
