//! Fault-aware collectives over a [`Communicator`] — the substrate a
//! ULFM application uses around the factorization itself (result
//! gathering, failure agreement, coordinated shutdown).
//!
//! All collectives operate on the *live* members of the communicator
//! and follow ULFM semantics: they never hang on dead ranks, and they
//! report which members were missing so the caller can repair the
//! communicator and retry (the `MPIX_Comm_agree` + shrink pattern).

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::linalg::Matrix;

use super::comm::Communicator;
use super::world::World;
use super::Rank;

/// Outcome of a fault-aware collective: the per-live-rank results plus
/// the comm ranks that could not participate.
#[derive(Debug, Clone)]
pub struct Gathered<T> {
    /// (comm_rank, value) for every live contributor, ascending rank.
    pub values: Vec<(Rank, T)>,
    /// Comm ranks that were dead / holes at collective time.
    pub missing: Vec<Rank>,
}

impl<T> Gathered<T> {
    /// True when every member contributed.
    pub fn complete(&self) -> bool {
        self.missing.is_empty()
    }
}

/// Gather each live member's round-`level` post (or `None` if it never
/// posted one) — the result-collection collective the coordinator runs
/// after a factorization.
pub fn gather_posts(
    world: &Arc<World>,
    comm: &Communicator,
    level: u32,
) -> Gathered<Option<Arc<Matrix>>> {
    let mut values = Vec::new();
    let mut missing = Vec::new();
    for comm_rank in 0..comm.size() {
        match comm.translate(comm_rank) {
            Ok(w) => values.push((comm_rank, world.peek(w, level))),
            Err(_) => missing.push(comm_rank),
        }
    }
    Gathered { values, missing }
}

/// Agreement on the failure set (trivially consistent here: the world
/// has one failure view — real ULFM needs a consensus round for this).
/// Returns the agreed list of failed comm ranks.
pub fn agree_on_failures(comm: &Communicator) -> Vec<Rank> {
    comm.failed_ranks()
}

/// Fault-aware barrier: returns once every member is *settled* — no
/// longer Alive (exited or dead).  The coordinator's "join" primitive;
/// unlike MPI_Barrier it cannot deadlock on failures.
pub fn await_settled(world: &Arc<World>, comm: &Communicator) -> Result<()> {
    // Reuse the world's quiescence wait when the comm spans everything;
    // otherwise poll member status through the condvar-backed world.
    let members: Vec<Rank> = (0..comm.size()).filter_map(|r| comm.translate(r).ok()).collect();
    if members.len() == world.size() {
        world.await_quiescent();
        return Ok(());
    }
    // Sub-communicator: settle each member (translate errors mean the
    // member is already dead — settled by definition).
    loop {
        let all_settled =
            members.iter().all(|&w| !world.status(w).is_alive());
        if all_settled {
            return Ok(());
        }
        std::thread::yield_now();
        std::hint::spin_loop();
        // Cheap back-off; member exits bump the world condvar, but we
        // poll here to keep the collective independent of board traffic.
        std::thread::sleep(std::time::Duration::from_micros(200));
    }
}

/// Reduce a metric across live members (max) — e.g. agreeing on the
/// highest completed round before a coordinated restart.
pub fn allreduce_max<F>(comm: &Communicator, f: F) -> Result<(usize, Vec<Rank>)>
where
    F: Fn(Rank) -> usize,
{
    let mut missing = Vec::new();
    let mut best: Option<usize> = None;
    for comm_rank in 0..comm.size() {
        match comm.translate(comm_rank) {
            Ok(w) => best = Some(best.map_or(f(w), |b| b.max(f(w)))),
            Err(_) => missing.push(comm_rank),
        }
    }
    match best {
        Some(v) => Ok((v, missing)),
        None => Err(Error::Aborted("allreduce over an empty communicator".into())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ulfm::comm::ErrorSemantics;
    use crate::ulfm::world::ExitKind;

    #[test]
    fn gather_posts_reports_missing() {
        let w = World::new(4);
        w.post(0, 7, Matrix::eye(2, 2));
        w.post(3, 7, Matrix::eye(2, 2));
        w.kill(1, 0);
        let c = Communicator::world_comm(Arc::clone(&w), ErrorSemantics::Blank);
        let g = gather_posts(&w, &c, 7);
        assert_eq!(g.missing, vec![1]);
        assert!(!g.complete());
        let posted: Vec<Rank> =
            g.values.iter().filter(|(_, v)| v.is_some()).map(|(r, _)| *r).collect();
        assert_eq!(posted, vec![0, 3]);
    }

    #[test]
    fn agreement_matches_world_view() {
        let w = World::new(4);
        w.kill(2, 1);
        let c = Communicator::world_comm(Arc::clone(&w), ErrorSemantics::Blank);
        assert_eq!(agree_on_failures(&c), vec![2]);
        // After SHRINK repair, agreement is clean again.
        let c2 = Communicator::world_comm(Arc::clone(&w), ErrorSemantics::Shrink)
            .repair()
            .unwrap();
        assert!(agree_on_failures(&c2).is_empty());
    }

    #[test]
    fn barrier_never_hangs_on_failures() {
        let w = World::new(3);
        let c = Communicator::world_comm(Arc::clone(&w), ErrorSemantics::Blank);
        let w2 = Arc::clone(&w);
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(15));
            w2.exit(0, ExitKind::CompletedWithR);
            w2.kill(1, 0);
            w2.exit(2, ExitKind::GaveUpPeerFailed);
        });
        await_settled(&w, &c).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn barrier_on_subcommunicator() {
        let w = World::new(4);
        let c = Communicator::from_ranks(Arc::clone(&w), &[1, 2], ErrorSemantics::Blank);
        let w2 = Arc::clone(&w);
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            w2.exit(1, ExitKind::CompletedWithR);
            w2.exit(2, ExitKind::CompletedWithR);
            // ranks 0 and 3 stay alive — the subcomm barrier must not care
        });
        await_settled(&w, &c).unwrap();
        assert_eq!(w.alive_ranks(), vec![0, 3]);
        h.join().unwrap();
    }

    #[test]
    fn allreduce_max_skips_dead() {
        let w = World::new(4);
        w.kill(3, 0);
        let c = Communicator::world_comm(Arc::clone(&w), ErrorSemantics::Blank);
        let (v, missing) = allreduce_max(&c, |r| r * 10).unwrap();
        assert_eq!(v, 20, "max over live ranks 0..2");
        assert_eq!(missing, vec![3]);
    }

    #[test]
    fn allreduce_over_dead_comm_errors() {
        let w = World::new(2);
        w.kill(0, 0);
        w.kill(1, 0);
        let c = Communicator::world_comm(Arc::clone(&w), ErrorSemantics::Blank);
        assert!(allreduce_max(&c, |r| r).is_err());
    }
}
