//! The simulated world: process registry, failure detector, and the
//! post board that carries every inter-process message.
//!
//! Each simulated MPI rank is an OS thread; the world is shared state
//! under one mutex with a condvar for blocking receives.  (The
//! vendored crate set has no async runtime — and the algorithms are
//! blocking sendrecv loops anyway, so threads model them exactly.)
//!
//! ## Message semantics
//!
//! `post(rank, level, R)` models the *send* half of the paper's
//! `sendrecv` at exchange round `level`; `fetch(peer, level)` models
//! the *recv* half.  A fetch succeeds iff the peer has posted for that
//! round — even if the peer died afterwards (the message was already
//! in flight, like a buffered MPI send).  If the peer is dead or has
//! exited *without* posting for that round, the fetch returns the ULFM
//! error `Error::RankFailed(peer)`; if the peer is alive but hasn't
//! posted yet, the fetch blocks.
//!
//! This gives exactly the paper's step-granular failure model: a
//! process that "crashes at the end of step s" (Fig. 3) computed R̃_s
//! but never posts it for the round-s exchange, so its buddy observes
//! `FAIL` at that round.
//!
//! ## Why a post board and not point-to-point channels
//!
//! In Replace TSQR a process exchanges with a *replica* of its dead
//! buddy (Fig. 4) — a rank that never addressed it.  All copies of a
//! group's R̃ are bit-identical, so the board (keyed by `(level, rank)`)
//! lets any process read any rank's round-s message exactly the way
//! ULFM lets it re-target a sendrecv, without a request/serve protocol
//! bolted onto every process loop.  Messages and bytes are still
//! counted per fetch, so communication metrics are unchanged.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::error::{Error, Result};
use crate::linalg::Matrix;

use super::Rank;

/// Why a process left the computation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExitKind {
    /// Finished the algorithm holding the final R.
    CompletedWithR,
    /// Finished its role without the final R (e.g. baseline sender).
    CompletedWithoutR,
    /// Returned early because a peer it needed had failed (Alg. 2 line 7).
    GaveUpPeerFailed,
    /// Returned early because no live replica existed (Alg. 3 line 8).
    GaveUpNoReplica,
}

/// Liveness state of one rank.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProcStatus {
    /// Running (or not yet started).
    Alive,
    /// Crashed (fault injector) at the given exchange round.
    Dead { at_round: u32 },
    /// Returned from the algorithm (normally or giving up).
    Exited(ExitKind),
}

impl ProcStatus {
    /// Still running.
    pub fn is_alive(&self) -> bool {
        matches!(self, ProcStatus::Alive)
    }
    /// Failed from a peer's point of view: dead, or exited so it will
    /// never post again ("processes that require data from ended
    /// processes end theirs as well").
    pub fn is_unreachable(&self) -> bool {
        !self.is_alive()
    }
    /// Finished the algorithm holding the final R.
    pub fn has_final_r(&self) -> bool {
        matches!(self, ProcStatus::Exited(ExitKind::CompletedWithR))
    }
}

/// Communication counters (relaxed atomics — read after the run).
#[derive(Debug, Default)]
pub struct WorldMetrics {
    /// Messages delivered (one per fetch).
    pub messages: AtomicU64,
    /// Payload bytes delivered.
    pub bytes: AtomicU64,
    /// Posts placed on the board.
    pub posts: AtomicU64,
    /// Fetches that observed a failure.
    pub failed_fetches: AtomicU64,
    /// Dead ranks brought back (REBUILD).
    pub respawns: AtomicU64,
}

impl WorldMetrics {
    /// Plain-data copy of the counters (the CAQR task counters are not
    /// world-level and stay 0 here; `caqr::exec` fills them).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            messages: self.messages.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            posts: self.posts.load(Ordering::Relaxed),
            failed_fetches: self.failed_fetches.load(Ordering::Relaxed),
            respawns: self.respawns.load(Ordering::Relaxed),
            ..Default::default()
        }
    }
}

/// Plain-data copy of the counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// Messages delivered (one per fetch).
    pub messages: u64,
    /// Payload bytes delivered.
    pub bytes: u64,
    /// Posts placed on the board.
    pub posts: u64,
    /// Fetches that observed a failure (ULFM error or no replica).
    pub failed_fetches: u64,
    /// Dead ranks brought back (Self-Healing / REBUILD).
    pub respawns: u64,
    /// CAQR: panels whose factor + updates fully completed.
    pub panels_completed: u64,
    /// CAQR: trailing-update task executions (replicas and, when a
    /// checksum policy is armed, checksum-update tasks included) —
    /// the redundant computation the fault tolerance is paid with.
    pub update_tasks: u64,
    /// CAQR: trailing-update blocks whose owner was dead at harvest
    /// time and whose result was taken from the surviving replica.
    pub update_recoveries: u64,
    /// CAQR: panels whose factor tasks were dispatched early by the
    /// lookahead scheduler *and* had already completed when the
    /// coordinator reached the panel (zero factor stall).
    pub lookahead_hits: u64,
    /// CAQR: nanoseconds the coordinator spent stalled waiting for
    /// panel-factor results — the critical-path gap lookahead shrinks
    /// (panel 0 always pays its full factor latency here).
    pub panel_stall_ns: u64,
    /// ABFT: task results (trailing-update blocks, panel-input row
    /// shards) rebuilt algebraically from checksums after every
    /// replica of the task was lost (`crate::abft::Encoder`).
    pub checksum_reconstructions: u64,
    /// ABFT: `(panel, stage)` events where some task had lost **every**
    /// replica — fatal under replication alone — and the checksum rung
    /// of the recovery ladder carried the run past it.
    pub pair_wipes_survived: u64,
}

impl MetricsSnapshot {
    /// Accumulate another run's counters — campaign-level aggregation
    /// (`engine::CampaignReport::metrics`).
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        self.messages += other.messages;
        self.bytes += other.bytes;
        self.posts += other.posts;
        self.failed_fetches += other.failed_fetches;
        self.respawns += other.respawns;
        self.panels_completed += other.panels_completed;
        self.update_tasks += other.update_tasks;
        self.update_recoveries += other.update_recoveries;
        self.lookahead_hits += other.lookahead_hits;
        self.panel_stall_ns += other.panel_stall_ns;
        self.checksum_reconstructions += other.checksum_reconstructions;
        self.pair_wipes_survived += other.pair_wipes_survived;
    }
}

/// Outcome of [`World::fetch_peer`].
#[derive(Debug, Clone)]
pub enum PeerFetch {
    /// The peer's post for this round.
    Post(Arc<Matrix>),
    /// ULFM failure: peer dead or exited without posting.
    Unreachable,
    /// Peer is a respawned replacement that has not recovered yet — it
    /// will never post for this round; use a replica instead.
    Recovering,
}

struct Inner {
    status: Vec<ProcStatus>,
    board: HashMap<(u32, Rank), Arc<Matrix>>,
    /// Respawned replacements that have not yet recovered their state:
    /// they hold NO data, so they are not valid replica sources (and
    /// treating them as sources would deadlock two recoveries in the
    /// same dead group against each other).  Cleared on first post.
    recovering: Vec<bool>,
    /// The exchange round each incarnation entered the computation at:
    /// 0 for original processes, the respawn round for replacements.
    /// A replacement NEVER posts for rounds below its entry round, so
    /// fetches at those levels must not wait on it (a fast peer may
    /// respawn a rank at round r2 before a slow peer needs it at
    /// r1 < r2 — waiting would deadlock).
    entry_round: Vec<u32>,
    /// Targeted wakeups (perf): one condvar per awaited (rank → level)
    /// post key, so a post wakes only ITS waiters and a status change
    /// of rank r wakes only fetches directed at r — not every blocked
    /// process (the naive global condvar costs O(P²) wakeups per round
    /// and dominated wall time at P ≥ 32; see EXPERIMENTS.md §Perf).
    /// All condvars pair with the same `World::inner` mutex.
    keyed_cvs: Vec<HashMap<u32, Arc<Condvar>>>,
}

impl Inner {
    fn cv_for(&mut self, level: u32, rank: Rank) -> Arc<Condvar> {
        Arc::clone(self.keyed_cvs[rank].entry(level).or_default())
    }
}

/// The shared world. Cheap to clone via `Arc<World>`.
pub struct World {
    size: usize,
    inner: Mutex<Inner>,
    cv: Condvar,
    metrics: WorldMetrics,
}

impl World {
    /// A fresh world of `size` alive ranks behind an `Arc`.
    pub fn new(size: usize) -> Arc<Self> {
        Arc::new(Self {
            size,
            inner: Mutex::new(Inner {
                status: vec![ProcStatus::Alive; size],
                board: HashMap::new(),
                recovering: vec![false; size],
                entry_round: vec![0; size],
                keyed_cvs: vec![HashMap::new(); size],
            }),
            cv: Condvar::new(),
            metrics: WorldMetrics::default(),
        })
    }

    /// World size (ranks, dead or alive).
    pub fn size(&self) -> usize {
        self.size
    }

    /// The communication counters.
    pub fn metrics(&self) -> &WorldMetrics {
        &self.metrics
    }

    /// Wake the waiters affected by a status change of `rank`: fetches
    /// directed at `rank` (any level) plus the global condvar (group
    /// fetches, quiescence).  Everyone else keeps sleeping.
    fn wake_status_change(&self, inner: &Inner, rank: Rank) {
        for cv in inner.keyed_cvs[rank].values() {
            cv.notify_all();
        }
        self.cv.notify_all();
    }

    /// Current status of one rank.
    pub fn status(&self, rank: Rank) -> ProcStatus {
        self.inner.lock().unwrap().status[rank]
    }

    /// Current status of every rank.
    pub fn statuses(&self) -> Vec<ProcStatus> {
        self.inner.lock().unwrap().status.clone()
    }

    /// Ranks currently alive.
    pub fn alive_ranks(&self) -> Vec<Rank> {
        self.inner
            .lock()
            .unwrap()
            .status
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_alive())
            .map(|(r, _)| r)
            .collect()
    }

    /// Ranks that finished holding the final R.
    pub fn ranks_with_final_r(&self) -> Vec<Rank> {
        self.inner
            .lock()
            .unwrap()
            .status
            .iter()
            .enumerate()
            .filter(|(_, s)| s.has_final_r())
            .map(|(r, _)| r)
            .collect()
    }

    /// Fault injector: crash `rank` at exchange round `round`.
    /// Killing a non-alive rank is a no-op.
    pub fn kill(&self, rank: Rank, round: u32) {
        let mut inner = self.inner.lock().unwrap();
        if inner.status[rank].is_alive() {
            inner.status[rank] = ProcStatus::Dead { at_round: round };
        }
        self.wake_status_change(&inner, rank);
    }

    /// A process records its own (voluntary) termination.
    pub fn exit(&self, rank: Rank, kind: ExitKind) {
        let mut inner = self.inner.lock().unwrap();
        if inner.status[rank].is_alive() {
            inner.status[rank] = ProcStatus::Exited(kind);
        }
        self.wake_status_change(&inner, rank);
    }

    /// REBUILD semantics: bring a dead rank back as a fresh process.
    /// Returns false if the rank was not dead (someone else already
    /// respawned it — the operation must be idempotent under races).
    /// Old posts stay on the board: messages already sent by the dead
    /// incarnation remain deliverable (they are bit-identical replicas
    /// of data other ranks may still legitimately consume).
    pub fn respawn(&self, rank: Rank) -> bool {
        self.respawn_at(rank, 0)
    }

    /// REBUILD with an explicit entry round: the replacement joins the
    /// computation at exchange round `entry_round` and will never post
    /// for rounds below it — fetches at lower levels re-target replicas
    /// instead of waiting (see `fetch_peer`).
    pub fn respawn_at(&self, rank: Rank, entry_round: u32) -> bool {
        let did = {
            let mut inner = self.inner.lock().unwrap();
            match inner.status[rank] {
                ProcStatus::Dead { .. } => {
                    inner.status[rank] = ProcStatus::Alive;
                    inner.recovering[rank] = true;
                    inner.entry_round[rank] = entry_round;
                    true
                }
                _ => false,
            }
        };
        if did {
            self.metrics.respawns.fetch_add(1, Ordering::Relaxed);
            let inner = self.inner.lock().unwrap();
            self.wake_status_change(&inner, rank);
        }
        did
    }

    /// Send half of the round-`level` exchange: make `rank`'s R̃ for this
    /// round visible to whoever fetches it.
    ///
    /// Takes anything convertible into `Arc<Matrix>`: pass an owned
    /// `Matrix` to publish a fresh value, or `Arc::clone` an existing
    /// one to share it at refcount cost — the R factors are immutable
    /// once posted, so the redundant algorithms post the same `Arc`
    /// every receiver reads (no per-receiver deep copies; the
    /// communication *metrics* still charge per fetch).
    pub fn post(&self, rank: Rank, level: u32, payload: impl Into<Arc<Matrix>>) {
        {
            let mut inner = self.inner.lock().unwrap();
            inner.board.insert((level, rank), payload.into());
            inner.recovering[rank] = false; // it holds data again
            // Targeted wakeup: whoever awaits THIS post, plus the
            // global condvar for group-fetch/quiescence waiters.
            if let Some(cv) = inner.keyed_cvs[rank].get(&level) {
                cv.notify_all();
            }
            self.cv.notify_all();
        }
        self.metrics.posts.fetch_add(1, Ordering::Relaxed);
    }

    /// Account for a message that is *sent* regardless of any fetch —
    /// e.g. the diskless-checkpoint comparator pays one message per
    /// checkpoint whether or not the checkpoint is ever read.
    pub fn charge_message(&self, bytes: u64) {
        self.metrics.messages.fetch_add(1, Ordering::Relaxed);
        self.metrics.bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Non-blocking read of a posted message (used by recovery paths).
    pub fn peek(&self, rank: Rank, level: u32) -> Option<Arc<Matrix>> {
        self.inner.lock().unwrap().board.get(&(level, rank)).cloned()
    }

    /// Recv half of the exchange: block until `peer`'s round-`level`
    /// post is available.
    ///
    /// Returns `Error::RankFailed(peer)` — the ULFM error class — iff
    /// the peer is unreachable (dead or exited) and never posted for
    /// this round.  Posted-then-died still delivers.
    pub fn fetch(&self, peer: Rank, level: u32) -> Result<Arc<Matrix>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(m) = inner.board.get(&(level, peer)) {
                self.metrics.messages.fetch_add(1, Ordering::Relaxed);
                self.metrics.bytes.fetch_add(m.size_bytes() as u64, Ordering::Relaxed);
                return Ok(Arc::clone(m));
            }
            if inner.status[peer].is_unreachable() {
                self.metrics.failed_fetches.fetch_add(1, Ordering::Relaxed);
                return Err(Error::RankFailed(peer));
            }
            let cv = inner.cv_for(level, peer);
            inner = cv.wait(inner).unwrap();
        }
    }

    /// Block until no rank is alive (every process crashed or exited) —
    /// how the coordinator knows a run has fully quiesced, including
    /// dynamically respawned Self-Healing processes.
    pub fn await_quiescent(&self) {
        let mut inner = self.inner.lock().unwrap();
        while inner.status.iter().any(|s| s.is_alive()) {
            inner = self.cv.wait(inner).unwrap();
        }
    }

    /// Diagnostics: all (level, rank) post keys currently on the board.
    ///
    /// (See `debug_recovering` / `debug_entry_rounds` for the rest of
    /// the introspection surface used by the deadlock regression
    /// tests.)
    pub fn debug_board_keys(&self) -> Vec<(u32, Rank)> {
        let inner = self.inner.lock().unwrap();
        let mut v: Vec<(u32, Rank)> = inner.board.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// Diagnostics: ranks currently flagged as recovering.
    pub fn debug_recovering(&self) -> Vec<Rank> {
        let inner = self.inner.lock().unwrap();
        (0..self.size).filter(|&r| inner.recovering[r]).collect()
    }

    /// Diagnostics: per-rank incarnation entry rounds.
    pub fn debug_entry_rounds(&self) -> Vec<u32> {
        self.inner.lock().unwrap().entry_round.clone()
    }

    /// Find a live rank (other than `except`) in `candidates` — the
    /// `findReplica` primitive of Algorithm 3.  Deterministic order so
    /// traces are reproducible.
    pub fn find_live(&self, candidates: &[Rank], except: Rank) -> Option<Rank> {
        let inner = self.inner.lock().unwrap();
        candidates
            .iter()
            .copied()
            .find(|&r| r != except && inner.status[r].is_alive())
    }

    /// Tri-state receive used by Self-Healing: wait for `peer`'s
    /// round-`level` post, but also resolve if the peer is unreachable
    /// (dead/exited — the ULFM error that triggers `spawnNew`) or is a
    /// *still-recovering replacement*.  A replacement respawned by a
    /// peer at a LATER round enters the computation there and will
    /// never post for this round — waiting on it would starve the
    /// caller (and can deadlock chains of recoveries), so the caller
    /// must fall back to a replica of the same group instead.
    pub fn fetch_peer(&self, peer: Rank, level: u32) -> PeerFetch {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(m) = inner.board.get(&(level, peer)) {
                self.metrics.messages.fetch_add(1, Ordering::Relaxed);
                self.metrics.bytes.fetch_add(m.size_bytes() as u64, Ordering::Relaxed);
                return PeerFetch::Post(Arc::clone(m));
            }
            if inner.status[peer].is_unreachable() {
                self.metrics.failed_fetches.fetch_add(1, Ordering::Relaxed);
                return PeerFetch::Unreachable;
            }
            if inner.recovering[peer] || inner.entry_round[peer] > level {
                // Still recovering, or an incarnation that entered the
                // computation above this level: it will never post
                // here — re-target a replica instead of waiting.
                return PeerFetch::Recovering;
            }
            let cv = inner.cv_for(level, peer);
            inner = cv.wait(inner).unwrap();
        }
    }

    /// Fetch the round-`level` data of a *replica group*: block until
    /// any candidate's post for this round is available, or until no
    /// candidate can ever produce one.
    ///
    /// A candidate is a *potential source* iff it is alive and not a
    /// still-recovering replacement (a replacement holds no data until
    /// its first post — counting it as a source would let two
    /// recoveries in the same dead group wait on each other forever).
    /// Posted-then-died messages still deliver.
    ///
    /// Used by Replace's `findReplica` retarget (Alg. 3 line 6) and by
    /// Self-Healing's state recovery (Alg. 5).  Returns
    /// `Error::NoReplica(except)` when the group's data is gone — the
    /// `2^s − 1` bound was exceeded for this group.
    pub fn fetch_from_group(
        &self,
        candidates: &[Rank],
        except: Rank,
        level: u32,
    ) -> Result<(Rank, Arc<Matrix>)> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            for &q in candidates {
                if q == except {
                    continue;
                }
                if let Some(m) = inner.board.get(&(level, q)) {
                    self.metrics.messages.fetch_add(1, Ordering::Relaxed);
                    self.metrics.bytes.fetch_add(m.size_bytes() as u64, Ordering::Relaxed);
                    return Ok((q, Arc::clone(m)));
                }
            }
            let possible = candidates.iter().any(|&q| {
                q != except
                    && inner.status[q].is_alive()
                    && !inner.recovering[q]
                    && inner.entry_round[q] <= level
            });
            if !possible {
                self.metrics.failed_fetches.fetch_add(1, Ordering::Relaxed);
                return Err(Error::NoReplica(except));
            }
            inner = self.cv.wait(inner).unwrap();
        }
    }
}

impl std::fmt::Debug for World {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.inner.lock().unwrap();
        f.debug_struct("World")
            .field("size", &self.size)
            .field("status", &inner.status)
            .field("board_entries", &inner.board.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn post_then_fetch_delivers() {
        let w = World::new(2);
        w.post(1, 0, Matrix::eye(2, 2));
        let got = w.fetch(1, 0).unwrap();
        assert_eq!(*got, Matrix::eye(2, 2));
        assert_eq!(w.metrics().snapshot().messages, 1);
        assert_eq!(w.metrics().snapshot().bytes, 16);
    }

    #[test]
    fn posting_an_arc_shares_not_copies() {
        // The zero-copy contract: the board stores the SAME allocation
        // the poster holds, and every fetch hands back another handle
        // to it.
        let w = World::new(2);
        let r = Arc::new(Matrix::random(16, 16, 7));
        w.post(1, 0, Arc::clone(&r));
        let got = w.fetch(1, 0).unwrap();
        assert!(Arc::ptr_eq(&r, &got), "fetch must alias the posted Arc");
        let again = w.fetch(1, 0).unwrap();
        assert!(Arc::ptr_eq(&got, &again));
    }

    #[test]
    fn fetch_waits_for_post() {
        let w = World::new(2);
        let w2 = Arc::clone(&w);
        let waiter = std::thread::spawn(move || w2.fetch(0, 3));
        std::thread::sleep(Duration::from_millis(20));
        w.post(0, 3, Matrix::zeros(1, 1));
        let got = waiter.join().unwrap().unwrap();
        assert_eq!(got.shape(), (1, 1));
    }

    #[test]
    fn fetch_from_dead_without_post_is_rank_failed() {
        let w = World::new(2);
        w.kill(1, 0);
        let err = w.fetch(1, 0).unwrap_err();
        assert!(matches!(err, Error::RankFailed(1)));
        assert_eq!(w.metrics().snapshot().failed_fetches, 1);
    }

    #[test]
    fn posted_then_died_still_delivers() {
        // Buffered-send semantics: the message survives the sender.
        let w = World::new(2);
        w.post(1, 0, Matrix::eye(1, 1));
        w.kill(1, 0);
        assert!(w.fetch(1, 0).is_ok());
    }

    #[test]
    fn exited_without_post_is_unreachable() {
        // "Processes that require data from ended processes end theirs."
        let w = World::new(2);
        w.exit(0, ExitKind::GaveUpPeerFailed);
        let err = w.fetch(0, 1).unwrap_err();
        assert!(matches!(err, Error::RankFailed(0)));
    }

    #[test]
    fn kill_unblocks_pending_fetch() {
        let w = World::new(2);
        let w2 = Arc::clone(&w);
        let waiter = std::thread::spawn(move || w2.fetch(1, 0));
        std::thread::sleep(Duration::from_millis(20));
        w.kill(1, 0);
        let res = waiter.join().unwrap();
        assert!(matches!(res, Err(Error::RankFailed(1))));
    }

    #[test]
    fn respawn_only_revives_dead() {
        let w = World::new(3);
        assert!(!w.respawn(0), "alive rank must not respawn");
        w.kill(0, 2);
        assert!(w.respawn(0));
        assert!(w.status(0).is_alive());
        assert!(!w.respawn(0), "second respawn is a no-op");
        w.exit(1, ExitKind::CompletedWithR);
        assert!(!w.respawn(1), "exited rank is not respawnable");
        assert_eq!(w.metrics().snapshot().respawns, 1);
    }

    #[test]
    fn respawn_keeps_old_posts_deliverable() {
        // Messages already sent survive the sender's death AND its
        // replacement: stragglers still consume them.
        let w = World::new(2);
        w.post(0, 0, Matrix::eye(1, 1));
        w.kill(0, 1);
        w.respawn(0);
        assert!(w.peek(0, 0).is_some());
    }

    #[test]
    fn find_live_skips_dead_and_self() {
        let w = World::new(4);
        w.kill(2, 0);
        assert_eq!(w.find_live(&[2, 3], 99), Some(3));
        assert_eq!(w.find_live(&[2], 99), None);
        assert_eq!(w.find_live(&[3], 3), None, "except self");
    }

    #[test]
    fn status_queries() {
        let w = World::new(4);
        w.kill(1, 0);
        w.exit(2, ExitKind::CompletedWithR);
        w.exit(3, ExitKind::GaveUpPeerFailed);
        assert_eq!(w.alive_ranks(), vec![0]);
        assert_eq!(w.ranks_with_final_r(), vec![2]);
        assert!(w.status(1).is_unreachable());
        assert!(!w.status(1).has_final_r());
    }

    #[test]
    fn kill_then_exit_keeps_dead_status() {
        let w = World::new(1);
        w.kill(0, 5);
        w.exit(0, ExitKind::CompletedWithR); // task raced; must not resurrect
        assert_eq!(w.status(0), ProcStatus::Dead { at_round: 5 });
    }

    #[test]
    fn await_quiescent_returns_when_everyone_gone() {
        let w = World::new(2);
        let w2 = Arc::clone(&w);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            w2.exit(0, ExitKind::CompletedWithR);
            w2.kill(1, 0);
        });
        w.await_quiescent();
        h.join().unwrap();
        assert!(w.alive_ranks().is_empty());
    }

    #[test]
    fn charge_message_counts() {
        let w = World::new(1);
        w.charge_message(64);
        let m = w.metrics().snapshot();
        assert_eq!(m.messages, 1);
        assert_eq!(m.bytes, 64);
    }
}
