//! Simulated MPI + ULFM substrate.
//!
//! The paper's algorithms are written against User-Level Failure
//! Mitigation (ULFM) / FT-MPI semantics (§II): communication with a
//! failed process returns an error (`MPI_ERR_PROC_FAILED`), operations
//! not touching a failed process proceed unknowingly, and a dead rank
//! can be respawned into its old slot (REBUILD).
//!
//! Substitution (DESIGN.md §3): instead of a cluster, each MPI rank is
//! a tokio task; the network is an in-process *post board* with
//! message-passing semantics (a message posted before the sender died
//! is still deliverable — exactly MPI's buffered-send behaviour), and
//! failures are injected deterministically at step boundaries, which is
//! the granularity of the paper's robustness analysis.  This makes the
//! `2^s − 1` claims *exhaustively checkable* rather than anecdotal.

pub mod collectives;
pub mod comm;
pub mod world;

pub use comm::{Communicator, ErrorSemantics};
pub use world::{ExitKind, MetricsSnapshot, PeerFetch, ProcStatus, World};

/// An MPI-style process rank.
pub type Rank = usize;
