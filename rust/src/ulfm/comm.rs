//! FT-MPI error-handling semantics on top of the simulated world (§II).
//!
//! FT-MPI defined four per-communicator semantics for surviving a
//! process failure; the paper's three algorithms are expressible in
//! terms of them (Redundant/Replace ≈ BLANK, Self-Healing ≈ REBUILD).
//! This module implements all four faithfully so the coordinator can
//! manage groups the way an FT-MPI/ULFM application would, and so the
//! semantics themselves are testable in isolation:
//!
//! * `SHRINK`  — repair produces a communicator of size N−f with
//!   survivors renumbered contiguously in [0, N−f−1].
//! * `BLANK`   — repair keeps size N; dead slots become *invalid*:
//!   addressing them returns `RankFailed`, survivors keep their ranks.
//! * `REBUILD` — repair respawns every dead member into its old slot,
//!   restoring size N with the same rank layout.
//! * `ABORT`   — repair fails: the application terminates (default
//!   non-fault-tolerant behaviour).

use std::sync::Arc;

use crate::error::{Error, Result};

use super::world::World;
use super::Rank;

/// FT-MPI per-communicator error-handling semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorSemantics {
    /// Repair renumbers survivors contiguously into a size-`N−f` comm.
    Shrink,
    /// Repair keeps size `N`; dead slots become invalid holes.
    Blank,
    /// Repair respawns every dead member into its old slot.
    Rebuild,
    /// Repair fails: the application terminates.
    Abort,
}

impl std::str::FromStr for ErrorSemantics {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "shrink" => Ok(Self::Shrink),
            "blank" => Ok(Self::Blank),
            "rebuild" => Ok(Self::Rebuild),
            "abort" => Ok(Self::Abort),
            _ => Err(Error::Config(format!("unknown semantics '{s}'"))),
        }
    }
}

/// A communicator: an ordered set of world ranks with failure semantics.
/// Slot i holds `Some(world_rank)` or `None` (a BLANK hole).
#[derive(Debug, Clone)]
pub struct Communicator {
    world: Arc<World>,
    slots: Vec<Option<Rank>>,
    semantics: ErrorSemantics,
}

impl Communicator {
    /// COMM_WORLD over all ranks.
    pub fn world_comm(world: Arc<World>, semantics: ErrorSemantics) -> Self {
        let slots = (0..world.size()).map(Some).collect();
        Self { world, slots, semantics }
    }

    /// Sub-communicator over explicit world ranks.
    pub fn from_ranks(world: Arc<World>, ranks: &[Rank], semantics: ErrorSemantics) -> Self {
        Self { world, slots: ranks.iter().copied().map(Some).collect(), semantics }
    }

    /// This communicator's failure semantics.
    pub fn semantics(&self) -> ErrorSemantics {
        self.semantics
    }

    /// Communicator size, counting BLANK holes (per §II, BLANK keeps
    /// the original numbering [0, N−1]).
    pub fn size(&self) -> usize {
        self.slots.len()
    }

    /// Number of live, addressable members.
    pub fn live_count(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| matches!(s, Some(r) if self.world.status(*r).is_alive()))
            .count()
    }

    /// Translate a communicator rank to a world rank; ULFM-style error
    /// if the slot is a hole or the member has failed.
    pub fn translate(&self, comm_rank: Rank) -> Result<Rank> {
        match self.slots.get(comm_rank) {
            None => Err(Error::Config(format!(
                "rank {comm_rank} out of range for communicator of size {}",
                self.size()
            ))),
            Some(None) => Err(Error::RankFailed(comm_rank)),
            Some(Some(w)) => {
                if self.world.status(*w).is_alive() {
                    Ok(*w)
                } else {
                    Err(Error::RankFailed(comm_rank))
                }
            }
        }
    }

    /// Comm ranks whose member has failed (the agreement step real ULFM
    /// does with `MPIX_Comm_agree`; trivially consistent here because
    /// the world has a single failure view).
    pub fn failed_ranks(&self) -> Vec<Rank> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| match s {
                Some(w) if !self.world.status(*w).is_alive() => Some(i),
                None => Some(i),
                _ => None,
            })
            .collect()
    }

    /// Apply this communicator's failure semantics, producing the
    /// repaired communicator (or terminating under ABORT).
    pub fn repair(&self) -> Result<Communicator> {
        let failed = self.failed_ranks();
        match self.semantics {
            ErrorSemantics::Abort => {
                if failed.is_empty() {
                    Ok(self.clone())
                } else {
                    Err(Error::Aborted(format!(
                        "{} process(es) failed under ABORT semantics",
                        failed.len()
                    )))
                }
            }
            ErrorSemantics::Shrink => {
                // Survivors renumbered contiguously: size N-f, no holes.
                let slots: Vec<Option<Rank>> = self
                    .slots
                    .iter()
                    .filter(|s| matches!(s, Some(w) if self.world.status(*w).is_alive()))
                    .cloned()
                    .collect();
                Ok(Communicator { world: Arc::clone(&self.world), slots, semantics: self.semantics })
            }
            ErrorSemantics::Blank => {
                // Same size; dead members become holes, survivors keep ranks.
                let slots: Vec<Option<Rank>> = self
                    .slots
                    .iter()
                    .map(|s| match s {
                        Some(w) if self.world.status(*w).is_alive() => Some(*w),
                        _ => None,
                    })
                    .collect();
                Ok(Communicator { world: Arc::clone(&self.world), slots, semantics: self.semantics })
            }
            ErrorSemantics::Rebuild => {
                // Respawn every dead member into its old slot.  Exited
                // members are gone for good (they returned; nothing to
                // replace) and become holes.
                let mut slots = Vec::with_capacity(self.slots.len());
                for s in &self.slots {
                    match s {
                        Some(w) => {
                            let st = self.world.status(*w);
                            if st.is_alive() {
                                slots.push(Some(*w));
                            } else if matches!(st, super::world::ProcStatus::Dead { .. }) {
                                self.world.respawn(*w);
                                slots.push(Some(*w));
                            } else {
                                slots.push(None);
                            }
                        }
                        None => slots.push(None),
                    }
                }
                Ok(Communicator { world: Arc::clone(&self.world), slots, semantics: self.semantics })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ulfm::world::ExitKind;

    fn world4() -> Arc<World> {
        World::new(4)
    }

    #[test]
    fn translate_live_ranks() {
        let w = world4();
        let c = Communicator::world_comm(Arc::clone(&w), ErrorSemantics::Blank);
        assert_eq!(c.size(), 4);
        assert_eq!(c.translate(2).unwrap(), 2);
        assert!(c.translate(9).is_err());
    }

    #[test]
    fn shrink_renumbers_contiguously() {
        // §II: after rank p of N dies, SHRINK leaves N-1 procs in [0, N-2].
        let w = world4();
        w.kill(1, 0);
        let c = Communicator::world_comm(Arc::clone(&w), ErrorSemantics::Shrink);
        let repaired = c.repair().unwrap();
        assert_eq!(repaired.size(), 3);
        assert_eq!(repaired.translate(0).unwrap(), 0);
        assert_eq!(repaired.translate(1).unwrap(), 2); // renumbered
        assert_eq!(repaired.translate(2).unwrap(), 3);
        assert!(repaired.failed_ranks().is_empty());
    }

    #[test]
    fn blank_leaves_hole_and_keeps_ranks() {
        // §II: BLANK keeps original ranks in [0, N-1]; dead rank invalid.
        let w = world4();
        w.kill(1, 0);
        let repaired =
            Communicator::world_comm(Arc::clone(&w), ErrorSemantics::Blank).repair().unwrap();
        assert_eq!(repaired.size(), 4);
        assert!(matches!(repaired.translate(1), Err(Error::RankFailed(1))));
        assert_eq!(repaired.translate(3).unwrap(), 3); // original rank kept
        assert_eq!(repaired.live_count(), 3);
        assert_eq!(repaired.failed_ranks(), vec![1]);
    }

    #[test]
    fn rebuild_respawns_into_same_slot() {
        // §II: REBUILD spawns a replacement with the dead process's rank.
        let w = world4();
        w.kill(2, 1);
        let repaired =
            Communicator::world_comm(Arc::clone(&w), ErrorSemantics::Rebuild).repair().unwrap();
        assert_eq!(repaired.size(), 4);
        assert_eq!(repaired.translate(2).unwrap(), 2);
        assert!(w.status(2).is_alive());
        assert_eq!(w.metrics().snapshot().respawns, 1);
    }

    #[test]
    fn rebuild_does_not_resurrect_exited() {
        let w = world4();
        w.exit(3, ExitKind::CompletedWithR);
        let repaired =
            Communicator::world_comm(Arc::clone(&w), ErrorSemantics::Rebuild).repair().unwrap();
        assert!(matches!(repaired.translate(3), Err(Error::RankFailed(3))));
    }

    #[test]
    fn abort_terminates_on_failure() {
        let w = world4();
        let c = Communicator::world_comm(Arc::clone(&w), ErrorSemantics::Abort);
        assert!(c.repair().is_ok(), "no failure, no abort");
        w.kill(0, 0);
        assert!(matches!(c.repair(), Err(Error::Aborted(_))));
    }

    #[test]
    fn translate_dead_is_ulfm_error_before_repair() {
        let w = world4();
        w.kill(3, 0);
        let c = Communicator::world_comm(Arc::clone(&w), ErrorSemantics::Blank);
        assert!(matches!(c.translate(3), Err(Error::RankFailed(3))));
    }

    #[test]
    fn sub_communicator() {
        let w = world4();
        let c = Communicator::from_ranks(Arc::clone(&w), &[1, 3], ErrorSemantics::Shrink);
        assert_eq!(c.size(), 2);
        assert_eq!(c.translate(0).unwrap(), 1);
        w.kill(1, 0);
        let r = c.repair().unwrap();
        assert_eq!(r.size(), 1);
        assert_eq!(r.translate(0).unwrap(), 3);
    }

    #[test]
    fn semantics_parse() {
        assert_eq!("shrink".parse::<ErrorSemantics>().unwrap(), ErrorSemantics::Shrink);
        assert_eq!("rebuild".parse::<ErrorSemantics>().unwrap(), ErrorSemantics::Rebuild);
        assert!("bogus".parse::<ErrorSemantics>().is_err());
    }
}
