//! Fault-tolerant CAQR: QR factorization of **general** `m x n`
//! matrices by block column, with the redundant-computation fault
//! tolerance of the source paper extended to the trailing-matrix
//! updates — the subject of the direct follow-up, *"Fault Tolerant QR
//! Factorization for General Matrices"* (Coti, arXiv:1604.02504).
//!
//! ## The algorithm
//!
//! A general matrix is factored panel by panel (see
//! [`crate::tsqr::PanelPlan`]): panel `k` is a tall-skinny block
//! column, factored over the worker pool, and its Householder
//! reflectors are then applied to every trailing block — the bulk of
//! the flops, scheduled as independent per-block *update tasks* on the
//! same pool.  Fault tolerance comes from the paper's one idea,
//! redundant computation:
//!
//! * the **panel factor** is computed by the owner's whole replica
//!   pair (the level-1 replica group of the per-panel tree plan) —
//!   every copy is bit-identical, so any survivor's copy is *the*
//!   result;
//! * every **trailing-update block** is computed twice, by its owner
//!   and the owner's round-0 buddy.  A process that dies mid-update
//!   loses nothing: the harvest takes the surviving replica's block,
//!   bit for bit what the dead process would have produced.
//!
//! Per panel step the subsystem therefore tolerates the loss of any
//! one member of each replica pair (`replication − 1`, the CAQR
//! analogue of TSQR's `2^s − 1` at `s = 1`); under
//! [`Algo::SelfHealing`] dead ranks are respawned at the panel
//! boundary, restoring full capacity for the next panel, while under
//! [`Algo::Redundant`] the world shrinks monotonically.
//!
//! Beyond replication, a [`CaqrSpec`] can arm the **checksum rung** of
//! the recovery ladder ([`crate::abft`]):
//! [`with_policy`](CaqrSpec::with_policy)`(`[`RecoveryPolicy::Hybrid`]`)`
//! plus [`with_checksums`](CaqrSpec::with_checksums)`(c)` encodes `c`
//! Vandermonde checksum blocks per panel stage, so even a *pair wipe*
//! (both replicas of a task dead in one stage — fatal above) is
//! survived by reconstructing the lost results algebraically.
//!
//! [`RecoveryPolicy::Hybrid`]: crate::abft::RecoveryPolicy::Hybrid
//!
//! ## The bitwise contract
//!
//! Every handoff between tasks stays f64 (the kernels in
//! [`crate::linalg::view`]: [`factor_panel_f64`], [`apply_update_f64`])
//! with one terminal rounding to f32, and panel decomposition never
//! reorders the arithmetic any single column sees.  Consequently
//! [`factorize`] reproduces the classic whole-matrix oracle
//! [`crate::linalg::householder_qr_reference`] **bit for bit** — with
//! zero failures *and* under every recoverable fault scenario, which
//! is exactly the redundancy invariant the paper rests on
//! (`tests/integration_caqr.rs` pins both).
//!
//! [`factor_panel_f64`]: crate::linalg::view::factor_panel_f64
//! [`apply_update_f64`]: crate::linalg::view::apply_update_f64
//!
//! ## Quick start
//!
//! ```
//! use ft_tsqr::caqr::{self, CaqrSpec};
//! use ft_tsqr::fault::{CaqrKillSchedule, CaqrStage};
//! use ft_tsqr::linalg::householder_qr_reference;
//! use ft_tsqr::tsqr::Algo;
//!
//! // 24x12 general matrix, 4-column panels, 4 simulated processes;
//! // rank 1 dies during panel 0's trailing updates.
//! let spec = CaqrSpec::new(Algo::SelfHealing, 4, 24, 12, 4)
//!     .with_schedule(CaqrKillSchedule::at(&[(1, 0, CaqrStage::Update)]));
//! let a = spec.input_matrix();
//! let result = caqr::factorize(spec).unwrap();
//! assert!(result.success());
//! assert!(result.metrics.update_recoveries > 0, "replica carried the update");
//!
//! // The fault-tolerant path is bit-identical to the classic QR.
//! let reference = householder_qr_reference(&a).r();
//! assert_eq!(result.final_r.unwrap().data(), reference.data());
//! ```

mod campaign;
mod exec;

pub use campaign::{CaqrCampaign, CaqrCampaignReport, CaqrRecord};
pub(crate) use exec::execute;

use std::sync::Arc;
use std::time::Duration;

use crate::abft::RecoveryPolicy;
use crate::error::{Error, Result};
use crate::fault::{CaqrKillSchedule, CaqrStage};
use crate::linalg::{Matrix, PackedQr};
use crate::runtime::{BackendPlan, KernelProfile, Parallelism, Precision};
use crate::tsqr::verify::Verification;
use crate::tsqr::{Algo, PanelPlan};
use crate::ulfm::{MetricsSnapshot, ProcStatus, Rank};

/// Everything needed to run one general-matrix CAQR factorization.
#[derive(Clone)]
pub struct CaqrSpec {
    /// Failure semantics: [`Algo::Redundant`] (dead ranks stay dead)
    /// or [`Algo::SelfHealing`] (respawned at panel boundaries).
    pub algo: Algo,
    /// Simulated processes the tasks are spread over.
    pub procs: usize,
    /// Matrix rows.
    pub m: usize,
    /// Matrix columns (no longer required to be ≪ `m`).
    pub n: usize,
    /// Block-column width.
    pub panel: usize,
    /// Input-matrix seed (see [`CaqrSpec::input_matrix`]).
    pub seed: u64,
    /// The `(rank, panel, stage)` kill schedule.
    pub schedule: Arc<CaqrKillSchedule>,
    /// Verify the final R against the host oracle.
    pub verify: bool,
    /// Kernel profile the factor/update tasks run:
    /// [`KernelProfile::Reference`] (bitwise-pinned rank-1 updates) or
    /// [`KernelProfile::Blocked`] (compact-WY + GEMM fast path).
    /// `None` inherits the engine's default (`Reference` for one-shot
    /// [`factorize`] runs).
    pub profile: Option<KernelProfile>,
    /// Recovery ladder this run walks when a task loses replicas
    /// (`Replica → Checksum → Abort`; see [`RecoveryPolicy`]).  `None`
    /// inherits the engine's default (`Replica` for one-shot
    /// [`factorize`] runs — the papers' semantics).
    pub policy: Option<RecoveryPolicy>,
    /// Checksum blocks `c` encoded per panel stage when the resolved
    /// policy uses checksums: up to `c` tasks that lost **every**
    /// replica are reconstructed per stage.  Ignored (and free) under
    /// [`RecoveryPolicy::Replica`].
    pub checksums: usize,
    /// Intra-task kernel parallelism: how many pool workers one
    /// trailing-update GEMM may fan out across (bit-neutral — every
    /// setting reproduces the sequential bits; see
    /// [`crate::linalg::gemm`]).  `None` inherits the engine's default
    /// ([`Parallelism::single`] for one-shot [`factorize`] runs).
    pub parallelism: Option<Parallelism>,
    /// Failure-rate model (deaths per rank per virtual second).  When
    /// set, the recovery ladder and checksum count are **derived** by
    /// [`crate::analysis::AdaptivePolicy`] instead of configured —
    /// setting this together with [`with_checksums`](Self::with_checksums)
    /// is a typed [`Error::KnobConflict`].
    pub failure_model: Option<f64>,
    /// Run the protected Q phases after the panel walk: assemble the
    /// explicit Q (replicated, checksum-encoded under Hybrid) and apply
    /// `Qᵀ` to the input, so a strike — even a pair wipe — during
    /// Q assembly or `apply_q` is recoverable.  Off by default: the
    /// paper's R-only runs don't pay for phases they don't use.
    pub protect_q: bool,
    /// Working precision of the data path.  [`Precision::F64`] (the
    /// default) keeps every inter-task handoff in f64 — the bitwise
    /// contract above.  [`Precision::F32`] rounds each task's result
    /// through f32 at the task boundary (the mixed-precision workload),
    /// while checksum encoding/reconstruction **stays f64** so the
    /// coded rung keeps its algebraic headroom over the data it
    /// protects (arXiv:0806.3121's precision-separation requirement).
    pub precision: Precision,
    /// In-process backend routing for this run's kernels (`None`
    /// inherits the engine's plan; everything-on-host by default).
    /// Factor tasks route per `plan.select(KernelOp::LeafQr)`:
    /// `Threaded` swaps in the chunked-reduction factor core on every
    /// replica at once, so replica bit-identity is preserved per
    /// backend (the invariant recovery rests on).
    pub backend: Option<BackendPlan>,
}

impl CaqrSpec {
    /// Sensible defaults for a fault-free run (seed 42, verify on).
    pub fn new(algo: Algo, procs: usize, m: usize, n: usize, panel: usize) -> Self {
        Self {
            algo,
            procs,
            m,
            n,
            panel,
            seed: 42,
            schedule: Arc::new(CaqrKillSchedule::none()),
            verify: true,
            profile: None,
            policy: None,
            checksums: 0,
            parallelism: None,
            failure_model: None,
            protect_q: false,
            precision: Precision::F64,
            backend: None,
        }
    }

    /// Replace the kill schedule.
    pub fn with_schedule(mut self, s: CaqrKillSchedule) -> Self {
        self.schedule = Arc::new(s);
        self
    }

    /// Replace the input-matrix seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Toggle oracle verification (skippable for survival sweeps).
    pub fn with_verify(mut self, on: bool) -> Self {
        self.verify = on;
        self
    }

    /// Pin the kernel profile for this spec (overrides the engine's
    /// default).
    pub fn with_profile(mut self, profile: KernelProfile) -> Self {
        self.profile = Some(profile);
        self
    }

    /// Pin the recovery policy for this spec (overrides the engine's
    /// default; see [`RecoveryPolicy`]).
    pub fn with_policy(mut self, policy: RecoveryPolicy) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Encode `c` checksum blocks per panel stage (only consumed when
    /// the resolved policy uses checksums).
    pub fn with_checksums(mut self, c: usize) -> Self {
        self.checksums = c;
        self
    }

    /// Pin the intra-task kernel parallelism for this spec (overrides
    /// the engine's default; bit-neutral at every setting).
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.parallelism = Some(par);
        self
    }

    /// Derive the recovery ladder from a failure-rate model (deaths
    /// per rank per virtual second) instead of configuring it: the
    /// resolved policy and checksum count come from
    /// [`crate::analysis::AdaptivePolicy`].  Conflicts with an
    /// explicit [`with_checksums`](Self::with_checksums).
    pub fn with_failure_model(mut self, rate: f64) -> Self {
        self.failure_model = Some(rate);
        self
    }

    /// Toggle the protected Q phases (Q assembly + `Qᵀ·A`) after the
    /// panel walk.
    pub fn with_q_protection(mut self, on: bool) -> Self {
        self.protect_q = on;
        self
    }

    /// Set the working precision of the data path (default
    /// [`Precision::F64`]; see the [`precision`](Self::precision) field
    /// for the mixed-precision semantics).
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Pin the in-process backend plan for this spec (overrides the
    /// engine's plan; see the [`backend`](Self::backend) field).
    pub fn with_backend(mut self, plan: BackendPlan) -> Self {
        self.backend = Some(plan);
        self
    }

    /// Validate shape and semantics.
    pub fn validate(&self) -> Result<()> {
        if self.procs == 0 {
            return Err(Error::Config("procs must be >= 1".into()));
        }
        if self.n == 0 || self.panel == 0 {
            return Err(Error::Config("cols and panel width must be >= 1".into()));
        }
        if self.m < self.n {
            return Err(Error::Config(format!(
                "CAQR factors m >= n matrices, got {}x{}",
                self.m, self.n
            )));
        }
        if self.procs > 1 && self.procs % 2 != 0 {
            // On an odd world the top rank has no round-0 buddy, so its
            // tasks would have a single copy — the replication − 1
            // tolerance claim would silently not hold for it.
            return Err(Error::Config(format!(
                "CAQR replicates tasks across round-0 buddy pairs; procs must be \
                 even (or 1), got {}",
                self.procs
            )));
        }
        if let Some(rate) = self.failure_model {
            if !rate.is_finite() || rate < 0.0 {
                return Err(Error::Config(format!(
                    "failure model rate must be finite and >= 0, got {rate}"
                )));
            }
            if self.checksums > 0 {
                // Both knobs own the checksum count; refusing loudly
                // beats the old last-setter-wins silence.
                return Err(Error::KnobConflict {
                    knob: "with_failure_model",
                    conflicting: "with_checksums",
                    resolution: "the adaptive policy derives the checksum count from the \
                                 failure rate; drop the explicit count (or the model)",
                });
            }
            if self.policy.is_some() {
                return Err(Error::KnobConflict {
                    knob: "with_failure_model",
                    conflicting: "with_policy",
                    resolution: "the adaptive policy derives the recovery ladder from the \
                                 failure rate; drop the explicit policy (or the model)",
                });
            }
        }
        if self.checksums > 0 {
            if self.procs < 2 {
                return Err(Error::Config(
                    "checksums need at least one rank besides the data holders; \
                     procs must be >= 2"
                        .into(),
                ));
            }
            if self.checksums > self.procs / 2 {
                return Err(Error::Config(format!(
                    "at most procs/2 checksum blocks fit distinct holder pairs: \
                     checksums = {} > {}",
                    self.checksums,
                    self.procs / 2
                )));
            }
        }
        match self.algo {
            Algo::Redundant | Algo::SelfHealing => {}
            other => {
                return Err(Error::Config(format!(
                    "CAQR supports redundant or self-healing semantics, not {}",
                    other.name()
                )));
            }
        }
        // An out-of-range kill entry can never fire; reject it here so
        // a typo'd `--kill-update 9@0` fails loudly instead of running
        // a silently fault-free campaign.
        let panels = self.n.div_ceil(self.panel);
        for (rank, panel, stage) in self.schedule.entries() {
            if rank >= self.procs {
                return Err(Error::Config(format!(
                    "kill ({rank}, {panel}, {}) names rank {rank} outside the \
                     {}-rank world",
                    stage.name(),
                    self.procs
                )));
            }
            if panel >= panels {
                return Err(Error::Config(format!(
                    "kill ({rank}, {panel}, {}) names panel {panel} but the plan \
                     has only {panels} panels",
                    stage.name()
                )));
            }
        }
        Ok(())
    }

    /// The panel plan this spec factors under.
    pub fn plan(&self) -> PanelPlan {
        PanelPlan::new(self.m, self.n, self.panel, self.procs)
    }

    /// The recovery ladder this spec actually runs: the single
    /// resolution point shared by the executor and the `sim::` replay
    /// (so exec/sim parity holds by construction).
    ///
    /// With a failure model, [`crate::analysis::AdaptivePolicy`]
    /// derives both the policy and the checksum count; otherwise the
    /// explicit policy (default [`RecoveryPolicy::Replica`]) arms the
    /// explicit count iff it uses checksums.
    pub fn resolved_protection(&self) -> (RecoveryPolicy, usize) {
        if let Some(rate) = self.failure_model {
            let panels = self.n.div_ceil(self.panel);
            let choice = crate::analysis::AdaptivePolicy::new(rate).choose(self.procs, panels);
            return (choice.policy, choice.checksums);
        }
        let policy = self.policy.unwrap_or_default();
        (policy, if policy.uses_checksums() { self.checksums } else { 0 })
    }

    /// The input matrix (deterministic in the seed).
    pub fn input_matrix(&self) -> Matrix {
        Matrix::random(self.m, self.n, self.seed)
    }
}

/// Survival accounting for one panel step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PanelSurvival {
    /// Panel index.
    pub panel: usize,
    /// Ranks alive after the panel step (post-respawn for
    /// Self-Healing).
    pub alive_after: usize,
    /// The panel-factor owner was dead at harvest time; a replica's
    /// bit-identical factor was used.
    pub factor_recovered: bool,
    /// Trailing blocks harvested from the replica because the owner
    /// was dead.
    pub update_recoveries: u64,
    /// Task results rebuilt from checksums at this panel (both
    /// stages), after every replica was lost.
    pub checksum_reconstructions: u64,
    /// Dead ranks respawned at this panel boundary (Self-Healing).
    pub respawns: u64,
}

/// Outcome of one CAQR factorization.
#[derive(Debug)]
pub struct CaqrResult {
    /// The spec's failure semantics.
    pub algo: Algo,
    /// Kernel profile the run executed under (resolved from the spec
    /// or the engine default).
    pub profile: KernelProfile,
    /// Recovery ladder the run executed under (resolved from the spec
    /// or the engine default).
    pub policy: RecoveryPolicy,
    /// Checksum blocks encoded per panel stage (0 under
    /// [`RecoveryPolicy::Replica`]).
    pub checksums: usize,
    /// Working precision the data path ran at (checksums stay f64
    /// either way; see [`CaqrSpec::precision`]).
    pub precision: Precision,
    /// World size.
    pub procs: usize,
    /// Panels the plan scheduled.
    pub panels: usize,
    /// Where the run died, if it did: more failures than the replica
    /// pairs could absorb at this `(panel, stage)`.
    pub failed_at: Option<(usize, CaqrStage)>,
    /// The full packed factorization (R + reflectors + tau) on success.
    pub factors: Option<PackedQr>,
    /// The `n x n` R factor on success — **not** canonicalized, so it
    /// compares bit-for-bit against `householder_qr_reference(a).r()`.
    pub final_r: Option<Matrix>,
    /// The explicit `m x n` Q, assembled by the protected Q-assembly
    /// phase (only when the spec set
    /// [`with_q_protection`](CaqrSpec::with_q_protection) and the run
    /// succeeded).
    pub q: Option<Matrix>,
    /// `Qᵀ·A` from the protected apply-Q phase (same gating; equals R
    /// up to the factorization's roundoff, which the tests bound).
    pub qt_a: Option<Matrix>,
    /// Liveness at the end of the run (`Dead { at_round }` carries the
    /// panel index the rank died at).
    pub statuses: Vec<ProcStatus>,
    /// Task/recovery counters (`update_tasks`, `update_recoveries`,
    /// `panels_completed`, `respawns`).
    pub metrics: MetricsSnapshot,
    /// Per-panel survival accounting, one entry per completed panel.
    pub panel_survival: Vec<PanelSurvival>,
    /// Wall clock of the factorization.
    pub wall: Duration,
    /// Oracle verdict (when the spec asked for verification and the
    /// run succeeded).
    pub verification: Option<Verification>,
}

impl CaqrResult {
    /// Did the factorization complete?  (Per-panel losses that the
    /// replica pairs absorbed still count as success — that is the
    /// point of the redundancy.)
    pub fn success(&self) -> bool {
        self.failed_at.is_none()
    }

    /// Ranks dead at the end of the run.
    pub fn dead_count(&self) -> usize {
        self.statuses.iter().filter(|s| matches!(s, ProcStatus::Dead { .. })).count()
    }
}

/// Run one CAQR factorization end to end (one-shot convenience).
///
/// Thin shim over a single-use [`crate::engine::Engine`]: long-lived
/// callers should hold an engine and use
/// [`Engine::run_caqr`](crate::engine::Engine::run_caqr) /
/// [`Engine::caqr_campaign`](crate::engine::Engine::caqr_campaign) to
/// amortize pool setup across factorizations.
///
/// ```
/// use ft_tsqr::caqr::{self, CaqrSpec};
/// use ft_tsqr::tsqr::Algo;
///
/// let res = caqr::factorize(CaqrSpec::new(Algo::Redundant, 4, 20, 10, 5)).unwrap();
/// assert!(res.success());
/// assert_eq!(res.final_r.unwrap().shape(), (10, 10));
/// ```
pub fn factorize(spec: CaqrSpec) -> Result<CaqrResult> {
    crate::engine::Engine::host().run_caqr(spec)
}

/// A named, reproducible CAQR failure scenario (the general-matrix
/// analogues of the paper's Figures 3–5).
#[derive(Debug, Clone)]
pub struct CaqrScenario {
    /// Stable lookup name.
    pub name: &'static str,
    /// One-line description of what it demonstrates.
    pub description: &'static str,
    /// Failure semantics the scenario runs under.
    pub algo: Algo,
    /// World size.
    pub procs: usize,
    /// The `(rank, panel, stage)` kills.
    pub kills: Vec<(Rank, usize, CaqrStage)>,
    /// Does the factorization survive?
    pub survives: bool,
}

impl CaqrScenario {
    /// One process dies during panel 0's trailing updates; its blocks
    /// are harvested from the buddy replica — the scenario the
    /// general-matrix paper adds over plain TSQR.
    pub fn update_strike() -> Self {
        CaqrScenario {
            name: "update-strike",
            description: "P1 dies during panel 0's trailing updates → \
                          blocks recovered from buddy P0, identical R",
            algo: Algo::Redundant,
            procs: 4,
            kills: vec![(1, 0, CaqrStage::Update)],
            survives: true,
        }
    }

    /// The panel-factor owner dies during the factor stage; the
    /// replica's bit-identical factor is used.
    pub fn factor_strike() -> Self {
        CaqrScenario {
            name: "factor-strike",
            description: "panel 1's factor owner P1 dies during the factor stage → \
                          replica P0's bit-identical factor is used",
            algo: Algo::Redundant,
            procs: 4,
            kills: vec![(1, 1, CaqrStage::Factor)],
            survives: true,
        }
    }

    /// One death per panel, healed at each boundary — the Self-Healing
    /// per-step capacity (`2^s − 1` per step, cumulatively more than
    /// any single step tolerates).
    pub fn healing_storm() -> Self {
        CaqrScenario {
            name: "healing-storm",
            description: "one death during every panel's updates, respawned at each \
                          boundary (self-healing) → identical R",
            algo: Algo::SelfHealing,
            procs: 4,
            kills: vec![
                (1, 0, CaqrStage::Update),
                (2, 1, CaqrStage::Update),
                (3, 2, CaqrStage::Update),
            ],
            survives: true,
        }
    }

    /// Both members of a replica pair die in the same panel step —
    /// past the `replication − 1` bound, so the data is gone and the
    /// run fails (the tightness statement).
    pub fn pair_wipe() -> Self {
        CaqrScenario {
            name: "pair-wipe",
            description: "P2 and P3 (a replica pair) both die during panel 0's \
                          updates → a block has no surviving copy, run fails",
            algo: Algo::Redundant,
            procs: 4,
            kills: vec![(2, 0, CaqrStage::Update), (3, 0, CaqrStage::Update)],
            survives: false,
        }
    }

    /// All named scenarios.
    pub fn all() -> Vec<CaqrScenario> {
        vec![
            Self::update_strike(),
            Self::factor_strike(),
            Self::healing_storm(),
            Self::pair_wipe(),
        ]
    }

    /// Look a scenario up by name.
    pub fn by_name(name: &str) -> Option<CaqrScenario> {
        Self::all().into_iter().find(|s| s.name == name)
    }

    /// Materialize a spec: `m x n` with `panel`-column blocks (the
    /// scenario's kills assume at least 3 panels).
    pub fn spec(&self, m: usize, n: usize, panel: usize) -> CaqrSpec {
        CaqrSpec::new(self.algo, self.procs, m, n, panel)
            .with_schedule(CaqrKillSchedule::at(&self.kills))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_validation() {
        assert!(CaqrSpec::new(Algo::Redundant, 4, 16, 8, 4).validate().is_ok());
        assert!(CaqrSpec::new(Algo::SelfHealing, 4, 16, 16, 4).validate().is_ok());
        assert!(CaqrSpec::new(Algo::Redundant, 0, 16, 8, 4).validate().is_err());
        assert!(CaqrSpec::new(Algo::Redundant, 1, 16, 8, 4).validate().is_ok(), "lone proc ok");
        assert!(
            CaqrSpec::new(Algo::Redundant, 3, 16, 8, 4).validate().is_err(),
            "odd worlds leave the top rank pairless"
        );
        assert!(CaqrSpec::new(Algo::Redundant, 4, 8, 16, 4).validate().is_err(), "wide");
        assert!(CaqrSpec::new(Algo::Redundant, 4, 16, 8, 0).validate().is_err());
        assert!(CaqrSpec::new(Algo::Baseline, 4, 16, 8, 4).validate().is_err(), "semantics");
        assert!(CaqrSpec::new(Algo::Replace, 4, 16, 8, 4).validate().is_err());
        // Checksum budget: at most one per holder pair, never on a
        // lone process.
        assert!(CaqrSpec::new(Algo::Redundant, 4, 16, 8, 4).with_checksums(2).validate().is_ok());
        assert!(CaqrSpec::new(Algo::Redundant, 4, 16, 8, 4).with_checksums(3).validate().is_err());
        assert!(CaqrSpec::new(Algo::Redundant, 1, 16, 8, 4).with_checksums(1).validate().is_err());
    }

    /// The satellite contract: an adaptive failure model and an
    /// explicit checksum count (or policy) both claim the same
    /// decision — the conflict is a typed error naming both knobs, not
    /// a silent last-setter-wins.
    #[test]
    fn failure_model_conflicts_are_typed() {
        let base = || CaqrSpec::new(Algo::SelfHealing, 4, 16, 8, 4);
        assert!(base().with_failure_model(0.5).validate().is_ok());
        let e = base().with_failure_model(0.5).with_checksums(1).validate().unwrap_err();
        assert!(matches!(
            e,
            Error::KnobConflict { knob: "with_failure_model", conflicting: "with_checksums", .. }
        ));
        let msg = e.to_string();
        assert!(msg.contains("with_failure_model") && msg.contains("with_checksums"), "{msg}");
        // Order of setters doesn't matter — the conflict is on state.
        assert!(base().with_checksums(1).with_failure_model(0.5).validate().is_err());
        // An explicit policy conflicts the same way.
        assert!(matches!(
            base().with_failure_model(0.5).with_policy(RecoveryPolicy::Hybrid).validate(),
            Err(Error::KnobConflict { conflicting: "with_policy", .. })
        ));
        // And the rate itself must be a sane number.
        assert!(base().with_failure_model(-1.0).validate().is_err());
        assert!(base().with_failure_model(f64::NAN).validate().is_err());
    }

    #[test]
    fn resolved_protection_is_the_single_resolution_point() {
        let base = || CaqrSpec::new(Algo::SelfHealing, 16, 64, 32, 8);
        // No model: explicit policy arms the explicit count iff it
        // uses checksums.
        assert_eq!(base().resolved_protection(), (RecoveryPolicy::Replica, 0));
        assert_eq!(
            base().with_policy(RecoveryPolicy::Hybrid).with_checksums(2).resolved_protection(),
            (RecoveryPolicy::Hybrid, 2)
        );
        assert_eq!(
            base().with_policy(RecoveryPolicy::Replica).with_checksums(2).resolved_protection(),
            (RecoveryPolicy::Replica, 0),
            "replica-only never arms checksums"
        );
        // With a model the ladder is derived: a zero rate keeps plain
        // replication, a steep one arms Hybrid with the adaptive c.
        assert_eq!(
            base().with_failure_model(0.0).resolved_protection(),
            (RecoveryPolicy::Replica, 0)
        );
        let (policy, c) = base().with_failure_model(500.0).resolved_protection();
        assert_eq!(policy, RecoveryPolicy::Hybrid);
        assert!((1..=8).contains(&c), "adaptive c must fit the holder pairs: {c}");
        // The derived count matches the adaptive policy exactly.
        let choice = crate::analysis::AdaptivePolicy::new(500.0).choose(16, 4);
        assert_eq!((policy, c), (choice.policy, choice.checksums));
    }

    #[test]
    fn precision_and_backend_knobs_default_off() {
        let spec = CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4);
        assert_eq!(spec.precision, Precision::F64);
        assert!(spec.backend.is_none());
        let spec = spec.with_precision(Precision::F32).with_backend(BackendPlan::threaded());
        assert_eq!(spec.precision, Precision::F32);
        assert!(spec.backend.as_ref().unwrap().uses_threaded());
        assert!(spec.validate().is_ok(), "neither knob disturbs validation");
    }

    #[test]
    fn spec_plan_and_matrix_deterministic() {
        let spec = CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4);
        assert_eq!(spec.plan().panels(), 3);
        assert_eq!(spec.input_matrix(), spec.input_matrix());
        assert_eq!(spec.input_matrix().shape(), (24, 12));
    }

    #[test]
    fn scenario_catalog() {
        let all = CaqrScenario::all();
        assert_eq!(all.len(), 4);
        let mut names: Vec<_> = all.iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 4, "names unique");
        assert!(!CaqrScenario::by_name("pair-wipe").unwrap().survives);
        assert!(CaqrScenario::by_name("fig9").is_none());
        let spec = CaqrScenario::update_strike().spec(48, 24, 8);
        assert_eq!(spec.schedule.entries(), vec![(1, 0, CaqrStage::Update)]);
    }
}
