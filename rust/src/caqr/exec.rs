//! One CAQR factorization over the engine's worker pool, with
//! lookahead pipelining and the checksum-coded recovery ladder.
//!
//! The coordinator walks the [`PanelPlan`] panel by panel.  Per panel:
//!
//! 1. **Factor stage** — spawn one factor task per *live* member of
//!    the panel's replica pair (or take the results of a factor the
//!    lookahead scheduler dispatched early, see below).  Every replica
//!    factors its own copy of the identical f64 snapshot with
//!    identical arithmetic, so the copies are bit-identical (debug
//!    builds assert it); the harvest takes the lowest-ranked
//!    survivor's copy.
//! 2. **Update stage** — spawn the replicated trailing-update tasks
//!    (owner + buddy per block).  A kill between spawn and harvest
//!    models the paper's "process dies mid-update": the dead rank's
//!    results are discarded, and each of its blocks is harvested from
//!    the surviving replica instead — a *recovery*, counted in the
//!    metrics.
//! 3. **Panel boundary** — Self-Healing respawns the dead (REBUILD),
//!    restoring capacity for the next panel; Redundant lets the world
//!    shrink.
//!
//! ## The recovery ladder
//!
//! When a task has lost **every** replica (a *pair wipe* — or any loss
//! under the un-replicated [`RecoveryPolicy::Checksum`]), the resolved
//! [`RecoveryPolicy`] decides what happens next:
//!
//! * `Replica` — abort, exactly the source papers' semantics (and
//!   bit-for-bit the pre-ABFT behaviour of this module).
//! * `Checksum` / `Hybrid` — walk down to the **checksum rung**:
//!   * *update stage*: `c` checksum-update tasks ran alongside the
//!     data tasks (the same kernel applied to the Vandermonde
//!     combinations `S_l = Σ_j w(l,j)·B_j` — the update is linear, so
//!     `S_l`'s update IS the combination of the updated blocks).  The
//!     lost outputs are solved back out via [`Encoder::reconstruct`].
//!   * *factor stage*: QR is nonlinear, so the lost *result* cannot be
//!     solved for; instead the factor's **input** panel is rebuilt —
//!     row shards held by the wiped pairs are reconstructed from the
//!     rotated checksum shards ([`PanelPlan::checksum_assignees`]) —
//!     and the factor re-executes on the lowest-ranked survivor.
//!
//! Both rungs are pre-decided by the [`Timeline`] (fault injection is
//! deterministic), reconstruction counts land in
//! [`MetricsSnapshot::checksum_reconstructions`] /
//! [`MetricsSnapshot::pair_wipes_survived`], and with zero failures the
//! checksum tasks never touch the factorization state — checksummed
//! runs reproduce the un-checksummed bits exactly.
//!
//! ## Lookahead
//!
//! Strictly sequential panel processing leaves the pool idle while the
//! coordinator factors panel `k+1`: the classic CAQR fix is to factor
//! ahead.  Update block 0 of panel `k` covers exactly panel `k+1`'s
//! columns ([`PanelPlan::lookahead_block`]), so as soon as **both**
//! copies of that block complete — owner *and* replica, keeping the
//! harvest rule and therefore the recovery semantics unchanged — the
//! coordinator dispatches panel `k+1`'s factor tasks concurrently with
//! panel `k`'s remaining updates.  [`MetricsSnapshot`] exposes the
//! overlap: `lookahead_hits` counts panels whose early factor had
//! already finished when it was needed, `panel_stall_ns` the time the
//! coordinator still spent blocked on factor results.  A panel whose
//! update stage needs reconstruction falls back to the sequential
//! schedule (reconstruction is a barrier: it needs every surviving
//! block *and* checksum output).
//!
//! Fault injection is *pre-simulated*: the `(rank, panel, stage)` kill
//! schedule and the respawn policy are deterministic, so the liveness
//! timeline — who is alive at every stage of every panel, which rung
//! of the ladder each stage takes, where the run fails — is computed
//! up front ([`Timeline`]).  Task dispatch is then free to overlap
//! stages without perturbing replica selection, harvest choices, or
//! failure points: the results (and every byte of the recovery
//! bookkeeping) are identical to the sequential schedule.
//!
//! All inter-task data is `Arc`-shared f64 (never rounded through
//! f32), which is what keeps the fault-tolerant path bit-identical to
//! the failure-free oracle under [`KernelProfile::Reference`] — and
//! deterministic (replicas bit-identical to *each other*) under
//! [`KernelProfile::Blocked`], whose compact-WY updates trade the
//! bitwise pin against the unblocked oracle for level-3 speed.
//!
//! Under [`Precision::F32`] (see [`CaqrSpec::with_precision`]) each
//! *data* task additionally rounds its result through f32 at the task
//! boundary — the mixed-precision workload — while the checksum tasks
//! and the encoder's reconstruction algebra stay f64, so the coded
//! rung retains higher precision than the data it protects.  Replicas
//! round identically, so single-strike recovery stays bit-identical;
//! pair-wipe reconstruction lands within an f32-level bound instead of
//! exactly (the property suite pins both).  `Precision::F64` takes the
//! byte-identical old path: every rounding site is behind an
//! `is_f32()` branch.
//!
//! [`PanelPlan`]: crate::tsqr::PanelPlan
//! [`PanelPlan::checksum_assignees`]: crate::tsqr::PanelPlan::checksum_assignees
//! [`MetricsSnapshot::checksum_reconstructions`]: crate::ulfm::MetricsSnapshot
//! [`MetricsSnapshot::pair_wipes_survived`]: crate::ulfm::MetricsSnapshot

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::abft::{Encoder, RecoveryPolicy};
use crate::engine::{TaskGroup, WorkerPool};
use crate::error::Result;
use crate::fault::CaqrStage;
use crate::linalg::view::{apply_q_f64, apply_update_f64, factor_panel_f64, round_f32_in_place};
use crate::linalg::wy::{self, WyFactor};
use crate::linalg::{Matrix, PackedQr};
use crate::runtime::threaded::factor_panel_chunked_f64;
use crate::runtime::{BackendChoice, KernelOp, KernelProfile, Precision};
use crate::tsqr::{Algo, PanelPlan, verify};
use crate::ulfm::{MetricsSnapshot, ProcStatus};

use super::{CaqrResult, CaqrSpec, PanelSurvival};

thread_local! {
    /// Per-worker GEMM/WY scratch for the Blocked update tasks.  Pool
    /// workers are long-lived, so after the first task on each worker
    /// the fast-path updates allocate nothing (the ~700 KiB packing
    /// arena would otherwise be allocated and zeroed per task).
    static WY_SCRATCH: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

/// The ranks that compute panel `k`'s factor under `policy`: the
/// owner's replica pair, or the owner alone when the policy does not
/// replicate.
fn factor_task_ranks(plan: &PanelPlan, k: usize, policy: RecoveryPolicy) -> Vec<usize> {
    if policy.replicates() {
        plan.factor_replicas(k)
    } else {
        vec![plan.factor_owner(k)]
    }
}

/// The ranks that compute update block `(k, j)` under `policy`.
fn update_task_ranks(
    plan: &PanelPlan,
    k: usize,
    j: usize,
    policy: RecoveryPolicy,
) -> Vec<usize> {
    if policy.replicates() {
        plan.update_assignees(k, j)
    } else {
        vec![plan.update_owner(k, j)]
    }
}

/// The replica groups that hold panel data between stages: buddy pairs
/// under replicating policies (a shard dies only when its whole pair
/// does), single ranks otherwise.
fn holder_groups(procs: usize, policy: RecoveryPolicy) -> Vec<Vec<usize>> {
    if !policy.replicates() || procs < 2 {
        (0..procs).map(|r| vec![r]).collect()
    } else {
        (0..procs / 2).map(|g| vec![2 * g, 2 * g + 1]).collect()
    }
}

/// Checksum indices `l < c` whose holder set has a survivor in `alive`
/// — the checksums a reconstruction at panel `k` may consume, in
/// ascending (deterministic) order.
fn live_checksums(plan: &PanelPlan, k: usize, c: usize, alive: &[bool]) -> Vec<usize> {
    (0..c)
        .filter(|&l| plan.checksum_assignees(k, l).into_iter().any(|r| alive[r]))
        .collect()
}

/// The factor stage's checksum rung, pre-decided by the timeline:
/// which row shards of the panel input must be rebuilt, and who
/// re-executes the factor.
#[derive(Debug, Clone, PartialEq, Eq)]
struct FactorRebuild {
    /// Number of data shards the panel input is split over (the holder
    /// groups with a survivor at panel start).
    holder_count: usize,
    /// Shard indices (into `0..holder_count`) whose holder group was
    /// freshly wiped at this factor stage.
    lost: Vec<usize>,
    /// Lowest-ranked survivor; re-executes the factor task.
    exec_rank: usize,
}

/// One post-factorization Q phase (assembly or apply), pre-decided by
/// the timeline exactly like the panel stages.
struct QPhase {
    /// Which Q stage this is ([`CaqrStage::QAssembly`] or
    /// [`CaqrStage::ApplyQ`]).
    stage: CaqrStage,
    /// Liveness at the phase's task spawn (its kills fired).
    alive: Vec<bool>,
    /// Column shards that lost every replica (checksum rung).
    lost: Vec<usize>,
    /// Ranks respawned at the phase boundary (Self-Healing).
    respawns: u64,
}

/// Pre-simulated liveness *and ladder decisions*: who is alive at every
/// stage of every panel, which stages take the checksum rung, where
/// the run fails.  Computing this up front is what lets the lookahead
/// scheduler dispatch panel `k+1`'s factor mid-way through panel `k`'s
/// updates without changing replica selection or harvest choices.
struct Timeline {
    /// Liveness at panel `k`'s start (before its factor kills fire).
    alive_start: Vec<Vec<bool>>,
    /// Liveness at panel `k`'s factor-task spawn (factor kills fired).
    alive_factor: Vec<Vec<bool>>,
    /// Liveness at panel `k`'s update-task spawn (update kills fired).
    alive_update: Vec<Vec<bool>>,
    /// `Some` when panel `k`'s factor lost every replica and the
    /// checksum rung rebuilds it.
    factor_rebuild: Vec<Option<FactorRebuild>>,
    /// Update blocks of panel `k` that lost every replica and are
    /// reconstructed from the checksum-update outputs.
    update_lost: Vec<Vec<usize>>,
    /// Ranks respawned at panel `k`'s boundary (Self-Healing), one
    /// entry per *completed* panel.
    respawns: Vec<u64>,
    /// Final panel each dead rank died at.
    died_at: Vec<Option<usize>>,
    /// First `(panel, stage)` at which some task exhausted the ladder.
    failed_at: Option<(usize, CaqrStage)>,
    /// Liveness at the end of the run (at failure or completion).
    final_alive: Vec<bool>,
    /// Post-factorization Q phases in execution order — empty unless
    /// the schedule strikes a Q stage or the spec arms Q protection.
    q_phases: Vec<QPhase>,
}

/// Walk the kill schedule through the panel sequence exactly as the
/// sequential coordinator would, recording liveness and ladder
/// decisions at every stage.  Consumes the schedule's entries (they
/// are one-shot), which is fine: this runs once per `execute` and
/// nothing else fires them.
fn simulate_timeline(
    spec: &CaqrSpec,
    plan: &PanelPlan,
    policy: RecoveryPolicy,
    c: usize,
) -> Timeline {
    let procs = spec.procs;
    let mut alive = vec![true; procs];
    let mut died_at: Vec<Option<usize>> = vec![None; procs];
    let mut tl = Timeline {
        alive_start: Vec::with_capacity(plan.panels()),
        alive_factor: Vec::with_capacity(plan.panels()),
        alive_update: Vec::with_capacity(plan.panels()),
        factor_rebuild: Vec::with_capacity(plan.panels()),
        update_lost: Vec::with_capacity(plan.panels()),
        respawns: Vec::with_capacity(plan.panels()),
        died_at: Vec::new(),
        failed_at: None,
        final_alive: Vec::new(),
        q_phases: Vec::new(),
    };
    let groups = holder_groups(procs, policy);
    let use_checksums = policy.uses_checksums() && c > 0;
    'panels: for k in 0..plan.panels() {
        tl.alive_start.push(alive.clone());
        for r in 0..procs {
            if alive[r] && spec.schedule.fire(r, k, CaqrStage::Factor) {
                alive[r] = false;
                died_at[r] = Some(k);
            }
        }
        tl.alive_factor.push(alive.clone());
        if factor_task_ranks(plan, k, policy).into_iter().any(|r| alive[r]) {
            tl.factor_rebuild.push(None);
        } else {
            // Every factor replica is dead: the checksum rung rebuilds
            // the wiped pairs' input shards and re-executes — if the
            // policy has the rung, a survivor exists, and enough
            // checksum shards survive.
            let alive_start = &tl.alive_start[k];
            let holders: Vec<&Vec<usize>> =
                groups.iter().filter(|g| g.iter().any(|&r| alive_start[r])).collect();
            let lost: Vec<usize> = holders
                .iter()
                .enumerate()
                .filter(|(_, g)| !g.iter().any(|&r| alive[r]))
                .map(|(h, _)| h)
                .collect();
            let exec_rank = (0..procs).find(|&r| alive[r]);
            let feasible = use_checksums
                && exec_rank.is_some()
                && lost.len() <= live_checksums(plan, k, c, &alive).len();
            match (feasible, exec_rank) {
                (true, Some(rank)) => tl.factor_rebuild.push(Some(FactorRebuild {
                    holder_count: holders.len(),
                    lost,
                    exec_rank: rank,
                })),
                _ => {
                    tl.failed_at = Some((k, CaqrStage::Factor));
                    break 'panels;
                }
            }
        }
        for r in 0..procs {
            if alive[r] && spec.schedule.fire(r, k, CaqrStage::Update) {
                alive[r] = false;
                died_at[r] = Some(k);
            }
        }
        tl.alive_update.push(alive.clone());
        let lost: Vec<usize> = (0..plan.update_blocks(k))
            .filter(|&j| {
                !update_task_ranks(plan, k, j, policy).into_iter().any(|r| alive[r])
            })
            .collect();
        if !lost.is_empty() {
            let feasible =
                use_checksums && lost.len() <= live_checksums(plan, k, c, &alive).len();
            if !feasible {
                tl.failed_at = Some((k, CaqrStage::Update));
                break 'panels;
            }
        }
        tl.update_lost.push(lost);
        let mut respawns = 0u64;
        if spec.algo == Algo::SelfHealing {
            for r in 0..procs {
                if !alive[r] {
                    alive[r] = true;
                    died_at[r] = None;
                    respawns += 1;
                }
            }
        }
        tl.respawns.push(respawns);
    }
    // The post-factorization Q phases, armed only when the schedule
    // strikes one or the spec asks for Q protection — un-armed runs
    // (everything the parity suite pins) walk the identical timeline
    // as before.
    let q_armed = spec.protect_q || spec.schedule.has_q_stage();
    if q_armed && tl.failed_at.is_none() {
        let panels = plan.panels();
        for (idx, stage) in [CaqrStage::QAssembly, CaqrStage::ApplyQ].into_iter().enumerate() {
            let pk = panels + idx;
            for r in 0..procs {
                if alive[r] && spec.schedule.fire_stage(r, stage) {
                    alive[r] = false;
                    died_at[r] = Some(panels);
                }
            }
            let alive_phase = alive.clone();
            let lost: Vec<usize> = (0..panels)
                .filter(|&j| {
                    !update_task_ranks(plan, pk, j, policy).into_iter().any(|r| alive[r])
                })
                .collect();
            if !lost.is_empty() {
                let feasible =
                    use_checksums && lost.len() <= live_checksums(plan, pk, c, &alive).len();
                if !feasible {
                    tl.failed_at = Some((panels, stage));
                    tl.q_phases.push(QPhase { stage, alive: alive_phase, lost, respawns: 0 });
                    break;
                }
            }
            let mut respawns = 0u64;
            if spec.algo == Algo::SelfHealing {
                for r in 0..procs {
                    if !alive[r] {
                        alive[r] = true;
                        died_at[r] = None;
                        respawns += 1;
                    }
                }
            }
            tl.q_phases.push(QPhase { stage, alive: alive_phase, lost, respawns });
        }
    }
    tl.died_at = died_at;
    tl.final_alive = alive;
    tl
}

/// One replica's factor output: the packed panel, its tau, and (under
/// the Blocked profile) the compact-WY factor the update tasks consume.
type FactorOut = (Vec<f64>, Vec<f64>, Option<Arc<WyFactor>>);
type FactorMap = BTreeMap<usize, FactorOut>;
type UpdateMap = BTreeMap<(usize, usize), Vec<f64>>;
type ChecksumMap = BTreeMap<(usize, usize), Vec<f64>>;

/// A factor stage in flight: the task latch plus the replica deposits.
struct FactorStage {
    tasks: TaskGroup,
    results: Arc<Mutex<FactorMap>>,
}

/// Per-run task context threaded into every factor task: the kernel
/// profile, the working precision, and the (backend-selected) f64
/// factor core.  One shared `Copy` value per run, so every replica of
/// every panel runs the identical core with the identical rounding —
/// replica bit-identity holds per backend and per precision by
/// construction.
#[derive(Clone, Copy)]
struct FactorCtx {
    profile: KernelProfile,
    precision: Precision,
    /// `factor_panel_f64` (host) or `factor_panel_chunked_f64`
    /// (threaded backend plan) — both the same packed convention.
    factor_core: fn(&mut [f64], usize, usize, &mut [f64]),
}

/// Spawn one factor task per live replica over a shared panel snapshot.
fn spawn_factor(
    pool: &WorkerPool,
    replicas: &[usize],
    snap: Arc<Vec<f64>>,
    rows: usize,
    cols: usize,
    ctx: FactorCtx,
) -> FactorStage {
    let results: Arc<Mutex<FactorMap>> = Arc::new(Mutex::new(BTreeMap::new()));
    let tasks = TaskGroup::new(pool.clone());
    for &rank in replicas {
        let snap = Arc::clone(&snap);
        let out = Arc::clone(&results);
        tasks.spawn(move || {
            let mut wbuf = (*snap).clone();
            let mut t = vec![0.0f64; cols];
            let wy = match ctx.profile {
                KernelProfile::Reference => {
                    (ctx.factor_core)(&mut wbuf, rows, cols, &mut t);
                    None
                }
                KernelProfile::Blocked => {
                    Some(Arc::new(wy::factor_panel_blocked_f64(&mut wbuf, rows, cols, &mut t)))
                }
            };
            // Mixed precision: the task-boundary rounding.  Every
            // replica rounds the identical bits, so the harvest's
            // bit-identity assert is untouched; under F64 this is a
            // no-op branch and the bytes are exactly the old path's.
            if ctx.precision.is_f32() {
                round_f32_in_place(&mut wbuf);
                round_f32_in_place(&mut t);
            }
            out.lock().unwrap().insert(rank, (wbuf, t, wy));
        });
    }
    FactorStage { tasks, results }
}

/// Take the lowest-ranked surviving replica's factor (debug builds
/// assert the redundancy invariant: every deposit is bit-identical).
fn harvest_factor(stage: &FactorStage, k: usize) -> FactorOut {
    let mut fr = stage.results.lock().unwrap();
    #[cfg(debug_assertions)]
    {
        let mut vals = fr.values();
        if let Some((w0, t0, _)) = vals.next() {
            for (wi, ti, _) in vals {
                debug_assert!(
                    w0.iter().zip(wi).all(|(a, b)| a.to_bits() == b.to_bits())
                        && t0.iter().zip(ti).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "panel {k}: factor replicas diverged"
                );
            }
        }
    }
    #[cfg(not(debug_assertions))]
    let _ = k;
    let chosen = *fr.keys().next().expect("at least one live replica deposited");
    fr.remove(&chosen).expect("just looked it up")
}

/// The checksum rung of the factor stage: rebuild the wiped holder
/// groups' row shards of the panel snapshot from the rotated checksum
/// shards, then re-dispatch the factor to the surviving rank.
///
/// The snapshot round-trips one encode + one solve, so the re-executed
/// factor differs from the clean run by `O(c·n·ε·‖A‖)` — the bound
/// `tests/integration_abft.rs` pins.  Surviving shards keep their
/// exact bytes.
fn rebuild_factor_snapshot(
    snap: &[f64],
    rows: usize,
    cols: usize,
    rb: &FactorRebuild,
    c: usize,
    avail: &[usize],
) -> Result<Vec<f64>> {
    let enc = Encoder::new(c);
    let shards = Encoder::shard_rows(rows, rb.holder_count);
    let parts: Vec<&[f64]> =
        shards.iter().map(|&(s, e)| &snap[s * cols..e * cols]).collect();
    let lens: Vec<usize> = parts.iter().map(|p| p.len()).collect();
    let pad = lens.iter().copied().max().unwrap_or(0);
    let checks_all = enc.encode(1, &lens, &parts, pad);
    let opts: Vec<Option<&[f64]>> = parts
        .iter()
        .enumerate()
        .map(|(h, p)| if rb.lost.contains(&h) { None } else { Some(*p) })
        .collect();
    let checks: Vec<(usize, &[f64])> =
        avail.iter().map(|&l| (l, checks_all[l].as_slice())).collect();
    let rebuilt = enc.reconstruct(1, &lens, &opts, &checks, pad)?;
    let mut out = snap.to_vec();
    for (h, data) in rebuilt {
        let (s, _) = shards[h];
        out[s * cols..s * cols + data.len()].copy_from_slice(&data);
    }
    Ok(out)
}

/// Execute one validated spec end to end on pooled workers.
pub(crate) fn execute(spec: &CaqrSpec, pool: &WorkerPool) -> Result<CaqrResult> {
    spec.validate()?;
    let plan = spec.plan();
    let profile = spec.profile.unwrap_or_default();
    let parallelism = spec.parallelism.unwrap_or_default();
    let precision = spec.precision;
    // The in-process backend plan picks the factor core every replica
    // runs (the one op whose arithmetic differs between backends —
    // the slab ops are bitwise, so routing them is a wall-clock-only
    // decision made at the executor, not here).
    let backend = spec.backend.clone().unwrap_or_default();
    let factor_core: fn(&mut [f64], usize, usize, &mut [f64]) =
        match backend.select(KernelOp::LeafQr) {
            BackendChoice::Host => factor_panel_f64,
            BackendChoice::Threaded => factor_panel_chunked_f64,
        };
    let fctx = FactorCtx { profile, precision, factor_core };
    // One resolution point for the protection knobs: an explicit
    // policy/checksum pair, or the failure-model-adaptive choice.
    let (policy, checksums) = spec.resolved_protection();
    let (m, n) = (spec.m, spec.n);
    let a = spec.input_matrix();
    let started = Instant::now();

    let tl = simulate_timeline(spec, &plan, policy, checksums);

    // The factorization state, f64 end to end (one terminal rounding).
    let mut w: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    let mut tau = vec![0.0f64; n];
    let mut metrics = MetricsSnapshot::default();
    let mut panel_survival: Vec<PanelSurvival> = Vec::with_capacity(plan.panels());
    let mut failed_at: Option<(usize, CaqrStage)> = None;
    // Factor stage the lookahead dispatched for the *next* panel.
    let mut pending: Option<FactorStage> = None;
    let encoder = Encoder::new(checksums);

    'panels: for k in 0..plan.panels() {
        let (c0, c1) = plan.col_range(k);
        let rows = m - c0;
        let cols = c1 - c0;
        let mut panel_reconstructions = 0u64;

        // ---------------------------------------------- factor stage
        if tl.failed_at == Some((k, CaqrStage::Factor)) {
            failed_at = tl.failed_at;
            break 'panels;
        }
        let alive_f = &tl.alive_factor[k];
        let stall_t0 = Instant::now();
        let stage = match pending.take() {
            Some(stage) => {
                // Dispatched early by the lookahead; a hit means it
                // finished while panel k−1's updates were draining.
                if stage.tasks.live_tasks() == 0 {
                    metrics.lookahead_hits += 1;
                }
                stage
            }
            None => {
                let mut snap = vec![0.0f64; rows * cols];
                for i in 0..rows {
                    for j in 0..cols {
                        snap[i * cols + j] = w[(c0 + i) * n + (c0 + j)];
                    }
                }
                match &tl.factor_rebuild[k] {
                    Some(rb) => {
                        // Checksum rung: every replica is gone —
                        // rebuild the wiped shards, re-execute on the
                        // lowest-ranked survivor.
                        let avail = live_checksums(&plan, k, checksums, alive_f);
                        let mut snap2 = rebuild_factor_snapshot(
                            &snap, rows, cols, rb, checksums, &avail,
                        )?;
                        // Mixed precision: the state is f32-representable,
                        // so rounding the f64-reconstructed shards snaps
                        // them back onto the exact lost values whenever
                        // the solve's error is below half an f32 ulp.
                        if precision.is_f32() {
                            round_f32_in_place(&mut snap2);
                        }
                        panel_reconstructions += rb.lost.len() as u64;
                        metrics.checksum_reconstructions += rb.lost.len() as u64;
                        metrics.pair_wipes_survived += 1;
                        spawn_factor(
                            pool,
                            &[rb.exec_rank],
                            Arc::new(snap2),
                            rows,
                            cols,
                            fctx,
                        )
                    }
                    None => {
                        let replicas: Vec<usize> = factor_task_ranks(&plan, k, policy)
                            .into_iter()
                            .filter(|&r| alive_f[r])
                            .collect();
                        spawn_factor(pool, &replicas, Arc::new(snap), rows, cols, fctx)
                    }
                }
            }
        };
        stage.tasks.wait_idle();
        metrics.panel_stall_ns += stall_t0.elapsed().as_nanos() as u64;
        let owner = plan.factor_owner(k);
        let factor_recovered = !alive_f[owner];
        let (panel_buf, panel_tau, panel_wy) = harvest_factor(&stage, k);
        let panel_shared = Arc::new((panel_buf, panel_tau));

        // ---------------------------------------------- update stage
        if tl.failed_at == Some((k, CaqrStage::Update)) {
            failed_at = tl.failed_at;
            break 'panels;
        }
        let alive_u = &tl.alive_update[k];
        let blocks = plan.update_blocks(k);
        let lost = &tl.update_lost[k];
        let assignee_sets: Vec<Vec<usize>> = (0..blocks)
            .map(|j| {
                update_task_ranks(&plan, k, j, policy)
                    .into_iter()
                    .filter(|&r| alive_u[r])
                    .collect()
            })
            .collect();
        // Snapshot every trailing block up front: the update tasks
        // consume them, and (when checksums are armed) so does the
        // encoder.
        let mut widths = Vec::with_capacity(blocks);
        let mut bsnaps: Vec<Arc<Vec<f64>>> = Vec::with_capacity(blocks);
        for j in 0..blocks {
            let (t0, t1) = plan.update_cols(k, j);
            let bk = t1 - t0;
            let mut bsnap = vec![0.0f64; rows * bk];
            for i in 0..rows {
                for c in 0..bk {
                    bsnap[i * bk + c] = w[(c0 + i) * n + (t0 + c)];
                }
            }
            widths.push(bk);
            bsnaps.push(Arc::new(bsnap));
        }
        let update_results: Arc<Mutex<UpdateMap>> = Arc::new(Mutex::new(BTreeMap::new()));
        let checksum_results: Arc<Mutex<ChecksumMap>> =
            Arc::new(Mutex::new(BTreeMap::new()));
        // Block 0 (the lookahead block) gets its own latch so the
        // coordinator can dispatch panel k+1's factor the moment both
        // of its copies are in, while the remaining blocks drain.  A
        // stage that needs reconstruction is a barrier instead.
        let do_lookahead = lost.is_empty();
        let look_block = plan.lookahead_block(k).filter(|_| do_lookahead);
        let look_group = TaskGroup::new(pool.clone());
        let rest_group = TaskGroup::new(pool.clone());
        let mut spawned = 0u64;
        let spawn_update = |group: &TaskGroup,
                            rank: usize,
                            key_is_checksum: Option<usize>,
                            j: usize,
                            bsnap: Arc<Vec<f64>>,
                            bk: usize| {
            let panel_shared = Arc::clone(&panel_shared);
            let panel_wy = panel_wy.clone();
            let out = Arc::clone(&update_results);
            let cout = Arc::clone(&checksum_results);
            let gemm_pool = pool.clone();
            group.spawn(move || {
                let mut blk = (*bsnap).clone();
                match &panel_wy {
                    Some(wy) => {
                        // Blocked path: the WY GEMMs may fan out across
                        // the same elastic pool (bit-neutral — every
                        // thread count reproduces the sequential bits).
                        WY_SCRATCH.with(|scratch| {
                            wy::apply_wyt_pooled(
                                wy,
                                &mut blk,
                                bk,
                                &mut scratch.borrow_mut(),
                                &gemm_pool,
                                parallelism.gemm_threads(),
                            );
                        });
                    }
                    None => {
                        let (pan, t) = &*panel_shared;
                        apply_update_f64(pan, rows, cols, t, &mut blk, bk);
                    }
                }
                // Mixed precision: data tasks round at the boundary;
                // checksum tasks do NOT — the coded rung keeps its f64
                // headroom over the f32 data it protects.
                if precision.is_f32() && key_is_checksum.is_none() {
                    round_f32_in_place(&mut blk);
                }
                match key_is_checksum {
                    Some(l) => cout.lock().unwrap().insert((l, rank), blk),
                    None => out.lock().unwrap().insert((j, rank), blk),
                };
            });
        };
        for (j, asg) in assignee_sets.iter().enumerate() {
            let group = if look_block == Some(j) { &look_group } else { &rest_group };
            for &rank in asg {
                spawned += 1;
                spawn_update(group, rank, None, j, Arc::clone(&bsnaps[j]), widths[j]);
            }
        }
        // Checksum-update tasks: the same kernel over the Vandermonde
        // combinations of the block snapshots.  They ride along every
        // panel the policy arms them — paying the (measured) encode
        // cost — but their outputs are consumed only on reconstruction.
        let pad = widths.iter().copied().max().unwrap_or(0);
        if checksums > 0 && blocks > 0 {
            let brefs: Vec<&[f64]> = bsnaps.iter().map(|b| b.as_slice()).collect();
            let csnaps = encoder.encode(rows, &widths, &brefs, pad);
            for (l, csnap) in csnaps.into_iter().enumerate() {
                let csnap = Arc::new(csnap);
                for rank in plan
                    .checksum_assignees(k, l)
                    .into_iter()
                    .filter(|&r| alive_u[r])
                {
                    spawned += 1;
                    spawn_update(&rest_group, rank, Some(l), 0, Arc::clone(&csnap), pad);
                }
            }
        }
        metrics.update_tasks += spawned;

        let mut panel_recoveries = 0u64;
        let mut written = vec![false; blocks];
        let harvest_block = |j: usize,
                             asg: &[usize],
                             ur: &mut UpdateMap,
                             w: &mut [f64],
                             panel_recoveries: &mut u64|
         -> Vec<f64> {
            let block_owner = plan.update_owner(k, j);
            let source = if asg.contains(&block_owner) {
                block_owner
            } else {
                // The owner died mid-update: harvest the replica's
                // copy instead (bit-identical — both ran the same
                // deterministic kernel on the same snapshot).
                *panel_recoveries += 1;
                asg[0]
            };
            let blk = ur.remove(&(j, source)).expect("assigned task deposited its block");
            let (t0, t1) = plan.update_cols(k, j);
            let bk = t1 - t0;
            for i in 0..rows {
                for c in 0..bk {
                    w[(c0 + i) * n + (t0 + c)] = blk[i * bk + c];
                }
            }
            blk
        };

        // ------------------------------------ lookahead dispatch
        look_group.wait_idle();
        if let Some(j0) = look_block {
            {
                let mut ur = update_results.lock().unwrap();
                harvest_block(j0, &assignee_sets[j0], &mut ur, &mut w, &mut panel_recoveries);
            }
            written[j0] = true;
            // Panel k+1's factor region (rows c1.., cols c1..c2) is
            // fully contained in the block just harvested: dispatch
            // its factor tasks now, overlapping the remaining updates.
            // (A doomed or rebuilt next factor — no live replica —
            // dispatches nothing and is handled sequentially.)
            if let Some(alive_next) = tl.alive_factor.get(k + 1) {
                let replicas_next: Vec<usize> = factor_task_ranks(&plan, k + 1, policy)
                    .into_iter()
                    .filter(|&r| alive_next[r])
                    .collect();
                if !replicas_next.is_empty() {
                    let (n0, n1) = plan.col_range(k + 1);
                    let (next_rows, next_cols) = (m - n0, n1 - n0);
                    let mut snap = vec![0.0f64; next_rows * next_cols];
                    for i in 0..next_rows {
                        for j in 0..next_cols {
                            snap[i * next_cols + j] = w[(n0 + i) * n + (n0 + j)];
                        }
                    }
                    pending = Some(spawn_factor(
                        pool,
                        &replicas_next,
                        Arc::new(snap),
                        next_rows,
                        next_cols,
                        fctx,
                    ));
                }
            }
        }

        // ------------------------------------ remaining updates
        rest_group.wait_idle();
        let mut survivor_blocks: Vec<Option<Vec<f64>>> = vec![None; blocks];
        {
            let mut ur = update_results.lock().unwrap();
            for (j, asg) in assignee_sets.iter().enumerate() {
                if !written[j] && !lost.contains(&j) {
                    let blk =
                        harvest_block(j, asg, &mut ur, &mut w, &mut panel_recoveries);
                    if !lost.is_empty() {
                        survivor_blocks[j] = Some(blk);
                    }
                }
            }
        }
        // ------------------------------------ checksum rung (updates)
        if !lost.is_empty() {
            let cr = checksum_results.lock().unwrap();
            let avail = live_checksums(&plan, k, checksums, alive_u);
            let mut checks: Vec<(usize, &[f64])> = Vec::with_capacity(avail.len());
            for &l in &avail {
                // Lowest-ranked live holder's deposit; holders compute
                // identical bits (same snapshot, same kernel).
                let rank = plan
                    .checksum_assignees(k, l)
                    .into_iter()
                    .find(|&r| alive_u[r])
                    .expect("live_checksums guarantees a live holder");
                checks.push((l, cr.get(&(l, rank)).expect("holder deposited").as_slice()));
            }
            let opts: Vec<Option<&[f64]>> = (0..blocks)
                .map(|j| {
                    if lost.contains(&j) {
                        None
                    } else if written[j] {
                        // The lookahead never harvests early on a
                        // reconstruction panel, so every survivor was
                        // stashed above.
                        unreachable!("reconstruction panels run sequentially")
                    } else {
                        Some(survivor_blocks[j].as_deref().expect("survivor stashed"))
                    }
                })
                .collect();
            let rebuilt = encoder.reconstruct(rows, &widths, &opts, &checks, pad)?;
            for (j, mut blk) in rebuilt {
                // Mixed precision: a reconstructed block re-enters the
                // f32-representable state through the same rounding a
                // surviving task applied (within the coded rung's
                // f32-level bound, not bit-exactly — the bound the
                // property suite pins).
                if precision.is_f32() {
                    round_f32_in_place(&mut blk);
                }
                let (t0, t1) = plan.update_cols(k, j);
                let bk = t1 - t0;
                for i in 0..rows {
                    for c in 0..bk {
                        w[(c0 + i) * n + (t0 + c)] = blk[i * bk + c];
                    }
                }
            }
            panel_reconstructions += lost.len() as u64;
            metrics.checksum_reconstructions += lost.len() as u64;
            metrics.pair_wipes_survived += 1;
        }
        metrics.update_recoveries += panel_recoveries;
        // Write the factored panel (and its tau) into the state.
        {
            let (pan, ptau) = &*panel_shared;
            for i in 0..rows {
                for j in 0..cols {
                    w[(c0 + i) * n + (c0 + j)] = pan[i * cols + j];
                }
            }
            tau[c0..c1].copy_from_slice(ptau);
        }

        // --------------------------------------------- panel boundary
        let respawns = tl.respawns[k];
        metrics.respawns += respawns;
        metrics.panels_completed += 1;
        panel_survival.push(PanelSurvival {
            panel: k,
            alive_after: alive_u.iter().filter(|&&x| x).count() + respawns as usize,
            factor_recovered,
            update_recoveries: panel_recoveries,
            checksum_reconstructions: panel_reconstructions,
            respawns,
        });
    }
    // Every dispatched lookahead stage is consumed by the next panel's
    // factor stage (which always runs before that panel's update-failure
    // break), and none is dispatched when the next panel's factor stage
    // is doomed or rebuilt (no live replica) — so nothing can be left
    // in flight.
    debug_assert!(pending.is_none(), "lookahead factor stage left unconsumed");

    // ------------------------------------------ post-factorization Q
    // The coded Q phases (assembly of the explicit thin Q, then Qᵀ·A),
    // armed only when the schedule strikes them or the spec asks for Q
    // protection.  Both phases run the same task shape as the update
    // stage: one column shard per panel, replicated across the owner
    // pair, with `c` checksum tasks riding along on the Vandermonde
    // combinations of the input shards — the reflector chain is linear,
    // so a checksum's output IS the combination of the shard outputs,
    // and a pair wipe is solved back out through the encoder.
    let mut q_out: Option<Vec<f64>> = None;
    let mut qt_out: Option<Vec<f64>> = None;
    if !tl.q_phases.is_empty() && failed_at.is_none() {
        let panels_n = plan.panels();
        // Per-panel packed reflectors + tau, extracted once from the
        // factored state and shared (f64, bit-exact) across all tasks.
        let mut panel_refl: Vec<Arc<(Vec<f64>, Vec<f64>)>> = Vec::with_capacity(panels_n);
        for k in 0..panels_n {
            let (c0, c1) = plan.col_range(k);
            let (rows_k, cols_k) = (m - c0, c1 - c0);
            let mut pan = vec![0.0f64; rows_k * cols_k];
            for i in 0..rows_k {
                for j in 0..cols_k {
                    pan[i * cols_k + j] = w[(c0 + i) * n + (c0 + j)];
                }
            }
            panel_refl.push(Arc::new((pan, tau[c0..c1].to_vec())));
        }
        let a64: Arc<Vec<f64>> =
            Arc::new(a.data().iter().map(|&x| x as f64).collect::<Vec<f64>>());
        let col_meta: Vec<(usize, usize)> = (0..panels_n).map(|j| plan.col_range(j)).collect();
        let widths: Vec<usize> = col_meta.iter().map(|&(s, e)| e - s).collect();
        let pad = widths.iter().copied().max().unwrap_or(0);

        for ph in &tl.q_phases {
            if tl.failed_at == Some((panels_n, ph.stage)) {
                failed_at = tl.failed_at;
                break;
            }
            let pk = panels_n + usize::from(ph.stage == CaqrStage::ApplyQ);
            let alive_q = &ph.alive;
            // Input shards: identity column panels (assembly) or the
            // original input's column panels (apply).
            let mut shards: Vec<Arc<Vec<f64>>> = Vec::with_capacity(panels_n);
            for (j, &(s0, _)) in col_meta.iter().enumerate() {
                let wj = widths[j];
                let mut buf = vec![0.0f64; m * wj];
                if ph.stage == CaqrStage::QAssembly {
                    for c in 0..wj {
                        buf[(s0 + c) * wj + c] = 1.0;
                    }
                } else {
                    for i in 0..m {
                        buf[i * wj..i * wj + wj]
                            .copy_from_slice(&a64[i * n + s0..i * n + s0 + wj]);
                    }
                }
                shards.push(Arc::new(buf));
            }
            let results: Arc<Mutex<UpdateMap>> = Arc::new(Mutex::new(BTreeMap::new()));
            let chk_results: Arc<Mutex<ChecksumMap>> = Arc::new(Mutex::new(BTreeMap::new()));
            let group = TaskGroup::new(pool.clone());
            let mut spawned = 0u64;
            let spawn_chain = |rank: usize,
                               key_is_checksum: Option<usize>,
                               j: usize,
                               shard: Arc<Vec<f64>>,
                               wj: usize| {
                let refl = panel_refl.clone();
                let meta = col_meta.clone();
                let out = Arc::clone(&results);
                let cout = Arc::clone(&chk_results);
                let stage = ph.stage;
                group.spawn(move || {
                    let mut buf = (*shard).clone();
                    if stage == CaqrStage::QAssembly {
                        // Q·E = H_0·…·H_{p−1}·E: rightmost panel first.
                        for (k, r) in refl.iter().enumerate().rev() {
                            let (pan, pt) = &**r;
                            let c0 = meta[k].0;
                            apply_q_f64(pan, m - c0, pt.len(), pt, &mut buf[c0 * wj..], wj);
                        }
                    } else {
                        // Qᵀ·A = H_{p−1}·…·H_0·A: panel 0 first.
                        for (k, r) in refl.iter().enumerate() {
                            let (pan, pt) = &**r;
                            let c0 = meta[k].0;
                            apply_update_f64(pan, m - c0, pt.len(), pt, &mut buf[c0 * wj..], wj);
                        }
                    }
                    // Mixed precision: same boundary rule as the panel
                    // updates — data shards round, checksum chains
                    // keep their f64 headroom.
                    if precision.is_f32() && key_is_checksum.is_none() {
                        round_f32_in_place(&mut buf);
                    }
                    match key_is_checksum {
                        Some(l) => cout.lock().unwrap().insert((l, rank), buf),
                        None => out.lock().unwrap().insert((j, rank), buf),
                    };
                });
            };
            let assignee_sets: Vec<Vec<usize>> = (0..panels_n)
                .map(|j| {
                    update_task_ranks(&plan, pk, j, policy)
                        .into_iter()
                        .filter(|&r| alive_q[r])
                        .collect()
                })
                .collect();
            for (j, asg) in assignee_sets.iter().enumerate() {
                for &rank in asg {
                    spawned += 1;
                    spawn_chain(rank, None, j, Arc::clone(&shards[j]), widths[j]);
                }
            }
            if checksums > 0 {
                let srefs: Vec<&[f64]> = shards.iter().map(|s| s.as_slice()).collect();
                let csnaps = encoder.encode(m, &widths, &srefs, pad);
                for (l, csnap) in csnaps.into_iter().enumerate() {
                    let csnap = Arc::new(csnap);
                    for rank in plan
                        .checksum_assignees(pk, l)
                        .into_iter()
                        .filter(|&r| alive_q[r])
                    {
                        spawned += 1;
                        spawn_chain(rank, Some(l), 0, Arc::clone(&csnap), pad);
                    }
                }
            }
            metrics.update_tasks += spawned;
            group.wait_idle();

            let mut recov = 0u64;
            let mut outputs: Vec<Option<Vec<f64>>> = vec![None; panels_n];
            {
                let mut ur = results.lock().unwrap();
                for (j, asg) in assignee_sets.iter().enumerate() {
                    if ph.lost.contains(&j) {
                        continue;
                    }
                    let owner = plan.update_owner(pk, j);
                    let source = if asg.contains(&owner) {
                        owner
                    } else {
                        // Owner died mid-phase: the replica's copy is
                        // bit-identical (same shard, same chain).
                        recov += 1;
                        asg[0]
                    };
                    outputs[j] =
                        Some(ur.remove(&(j, source)).expect("assigned q task deposited"));
                }
            }
            if !ph.lost.is_empty() {
                let cr = chk_results.lock().unwrap();
                let avail = live_checksums(&plan, pk, checksums, alive_q);
                let mut checks: Vec<(usize, &[f64])> = Vec::with_capacity(avail.len());
                for &l in &avail {
                    let rank = plan
                        .checksum_assignees(pk, l)
                        .into_iter()
                        .find(|&r| alive_q[r])
                        .expect("live_checksums guarantees a live holder");
                    checks.push((l, cr.get(&(l, rank)).expect("holder deposited").as_slice()));
                }
                let opts: Vec<Option<&[f64]>> = outputs.iter().map(|o| o.as_deref()).collect();
                let rebuilt = encoder.reconstruct(m, &widths, &opts, &checks, pad)?;
                for (j, mut blk) in rebuilt {
                    if precision.is_f32() {
                        round_f32_in_place(&mut blk);
                    }
                    outputs[j] = Some(blk);
                }
                metrics.checksum_reconstructions += ph.lost.len() as u64;
                metrics.pair_wipes_survived += 1;
            }
            metrics.update_recoveries += recov;
            metrics.respawns += ph.respawns;
            let mut full = vec![0.0f64; m * n];
            for (j, &(s0, _)) in col_meta.iter().enumerate() {
                let wj = widths[j];
                let blk = outputs[j].as_ref().expect("every shard harvested or rebuilt");
                for i in 0..m {
                    full[i * n + s0..i * n + s0 + wj].copy_from_slice(&blk[i * wj..i * wj + wj]);
                }
            }
            if ph.stage == CaqrStage::QAssembly {
                q_out = Some(full);
            } else {
                qt_out = Some(full);
            }
        }
    }

    let statuses: Vec<ProcStatus> = (0..spec.procs)
        .map(|r| {
            if tl.final_alive[r] {
                ProcStatus::Alive
            } else {
                ProcStatus::Dead { at_round: tl.died_at[r].unwrap_or(0) as u32 }
            }
        })
        .collect();
    let wall = started.elapsed();

    let (factors, final_r, verification) = if failed_at.is_none() {
        // The single f64 -> f32 rounding of the whole run.
        let packed = Matrix::from_vec(m, n, w.iter().map(|&x| x as f32).collect());
        let tau32: Vec<f32> = tau.iter().map(|&x| x as f32).collect();
        let mut r = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                r[(i, j)] = packed[(i, j)];
            }
        }
        let verification = if spec.verify { Some(verify::verify_r(&a, &r)) } else { None };
        (Some(PackedQr { packed, tau: tau32 }), Some(r), verification)
    } else {
        (None, None, None)
    };
    // The Q-phase outputs round through f32 exactly once, like the
    // factors; a failed run yields neither.
    let to_f32 = |v: Vec<f64>| Matrix::from_vec(m, n, v.iter().map(|&x| x as f32).collect());
    let (q, qt_a) = if failed_at.is_none() {
        (q_out.map(to_f32), qt_out.map(to_f32))
    } else {
        (None, None)
    };

    Ok(CaqrResult {
        algo: spec.algo,
        profile,
        policy,
        checksums,
        precision,
        procs: spec.procs,
        panels: plan.panels(),
        failed_at,
        factors,
        final_r,
        q,
        qt_a,
        statuses,
        metrics,
        panel_survival,
        wall,
        verification,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::{CaqrKillSchedule, PairWipeSchedule};

    fn run(spec: CaqrSpec) -> CaqrResult {
        let pool = WorkerPool::new();
        let res = execute(&spec, &pool).unwrap();
        pool.shutdown();
        res
    }

    #[test]
    fn fault_free_matches_reference_bitwise() {
        let spec = CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4);
        let a = spec.input_matrix();
        let res = run(spec);
        assert!(res.success());
        assert_eq!(res.profile, KernelProfile::Reference);
        assert_eq!(res.policy, RecoveryPolicy::Replica);
        let reference = crate::linalg::householder_qr_reference(&a);
        let f = res.factors.as_ref().unwrap();
        assert_eq!(f.packed.data(), reference.packed.data(), "packed must be bit-identical");
        assert_eq!(f.tau, reference.tau, "tau must be bit-identical");
        assert!(res.verification.unwrap().ok);
        assert_eq!(res.metrics.panels_completed, 3);
        assert_eq!(res.metrics.update_recoveries, 0);
        assert_eq!(res.metrics.checksum_reconstructions, 0);
        assert_eq!(res.dead_count(), 0);
        // Lookahead is observable but never exceeds the panels that
        // have a successor.
        assert!(res.metrics.lookahead_hits <= 2);
        assert!(res.metrics.panel_stall_ns > 0, "panel 0 always stalls on its factor");
    }

    #[test]
    fn update_strike_recovers_identical_bits() {
        let clean = run(CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4));
        let struck = run(
            CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4)
                .with_schedule(CaqrKillSchedule::at(&[(1, 0, CaqrStage::Update)])),
        );
        assert!(struck.success());
        assert!(struck.metrics.update_recoveries > 0, "owner's blocks came from the replica");
        assert_eq!(
            struck.final_r.as_ref().unwrap().data(),
            clean.final_r.as_ref().unwrap().data(),
            "recovered R must be bit-identical"
        );
        assert_eq!(struck.dead_count(), 1, "redundant semantics: the dead stay dead");
    }

    #[test]
    fn pair_wipe_fails_at_the_bound() {
        let res = run(
            CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4)
                .with_schedule(CaqrKillSchedule::at(&[
                    (2, 0, CaqrStage::Update),
                    (3, 0, CaqrStage::Update),
                ])),
        );
        assert!(!res.success(), "both copies of a block lost -> run lost");
        assert_eq!(res.failed_at, Some((0, CaqrStage::Update)));
        assert!(res.final_r.is_none());
        assert_eq!(res.metrics.update_tasks, 0, "no update task spawns on the failing panel");
    }

    #[test]
    fn self_healing_respawns_at_panel_boundaries() {
        let res = run(
            CaqrSpec::new(Algo::SelfHealing, 4, 24, 12, 4)
                .with_schedule(CaqrKillSchedule::at(&[(1, 0, CaqrStage::Update)])),
        );
        assert!(res.success());
        assert_eq!(res.metrics.respawns, 1);
        assert_eq!(res.dead_count(), 0, "healed world ends at full size");
        assert!(res.panel_survival[0].respawns == 1 && res.panel_survival[0].alive_after == 4);
    }

    #[test]
    fn single_process_world_has_no_redundancy_but_works() {
        let spec = CaqrSpec::new(Algo::Redundant, 1, 16, 8, 3);
        let a = spec.input_matrix();
        let res = run(spec);
        assert!(res.success());
        let reference = crate::linalg::householder_qr_reference(&a);
        assert_eq!(res.factors.unwrap().packed.data(), reference.packed.data());
    }

    #[test]
    fn blocked_profile_is_deterministic_and_close_to_reference() {
        let spec = || {
            CaqrSpec::new(Algo::Redundant, 4, 32, 16, 4)
                .with_profile(KernelProfile::Blocked)
        };
        let a = spec().input_matrix();
        let r1 = run(spec());
        let r2 = run(spec());
        assert!(r1.success());
        assert_eq!(r1.profile, KernelProfile::Blocked);
        assert_eq!(
            r1.final_r.as_ref().unwrap().data(),
            r2.final_r.as_ref().unwrap().data(),
            "blocked profile must be run-to-run bit-deterministic"
        );
        let reference = crate::linalg::householder_qr_reference(&a).r();
        assert!(
            r1.final_r.as_ref().unwrap().max_abs_diff(&reference) < 1e-3,
            "blocked profile must agree with the oracle numerically"
        );
        assert!(r1.verification.unwrap().ok);
    }

    #[test]
    fn blocked_profile_recovers_bitwise_against_its_own_clean_run() {
        let mk = |kills: &[(usize, usize, CaqrStage)]| {
            CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4)
                .with_profile(KernelProfile::Blocked)
                .with_schedule(CaqrKillSchedule::at(kills))
        };
        let clean = run(mk(&[]));
        let struck = run(mk(&[(1, 0, CaqrStage::Update)]));
        assert!(struck.success());
        assert!(struck.metrics.update_recoveries > 0);
        assert_eq!(
            struck.final_r.as_ref().unwrap().data(),
            clean.final_r.as_ref().unwrap().data(),
            "blocked recovery must reproduce the clean blocked bits"
        );
    }

    #[test]
    fn hybrid_survives_the_pair_wipe_replication_cannot() {
        // The same schedule as `pair_wipe_fails_at_the_bound`, one
        // checksum armed: the lost block is reconstructed and the run
        // completes — the tentpole property of the ABFT layer.
        let wipe = PairWipeSchedule::new(2, 0, CaqrStage::Update);
        let res = run(
            CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4)
                .with_schedule(wipe.schedule())
                .with_policy(RecoveryPolicy::Hybrid)
                .with_checksums(1),
        );
        assert!(res.success(), "hybrid must ride through the pair wipe");
        assert_eq!(res.policy, RecoveryPolicy::Hybrid);
        assert_eq!(res.checksums, 1);
        assert!(res.metrics.pair_wipes_survived >= 1);
        assert!(res.metrics.checksum_reconstructions >= 1);
        assert!(res.verification.unwrap().ok, "reconstructed R must still verify");
        assert_eq!(res.dead_count(), 2);
    }

    #[test]
    fn zero_failure_checksummed_run_is_bitwise_identical() {
        let clean = run(CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4));
        for policy in [RecoveryPolicy::Hybrid, RecoveryPolicy::Checksum] {
            let coded = run(
                CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4)
                    .with_policy(policy)
                    .with_checksums(2),
            );
            assert!(coded.success());
            assert_eq!(
                coded.final_r.as_ref().unwrap().data(),
                clean.final_r.as_ref().unwrap().data(),
                "{policy}: checksum tasks must be bystanders with zero failures"
            );
            assert_eq!(coded.metrics.checksum_reconstructions, 0);
            assert_eq!(coded.metrics.pair_wipes_survived, 0);
        }
    }

    #[test]
    fn q_protection_assembles_a_valid_q_and_qt_a() {
        let spec = CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4).with_q_protection(true);
        let a = spec.input_matrix();
        let res = run(spec);
        assert!(res.success());
        let q = res.q.as_ref().expect("armed run assembles Q");
        let qt_a = res.qt_a.as_ref().expect("armed run applies Qᵀ");
        let r = res.final_r.as_ref().unwrap();
        assert_eq!(q.shape(), (24, 12));
        assert_eq!(qt_a.shape(), (24, 12));
        // Qᵀ·Q ≈ I (thin-Q orthonormality).
        for i in 0..12 {
            for j in 0..12 {
                let mut dot = 0.0f64;
                for k in 0..24 {
                    dot += q[(k, i)] as f64 * q[(k, j)] as f64;
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4, "QᵀQ[{i},{j}] = {dot}");
            }
        }
        // Q·R ≈ A.
        for i in 0..24 {
            for j in 0..12 {
                let mut dot = 0.0f64;
                for k in 0..12 {
                    dot += q[(i, k)] as f64 * r[(k, j)] as f64;
                }
                assert!((dot - a[(i, j)] as f64).abs() < 1e-3, "QR[{i},{j}] far from A");
            }
        }
        // The top block of Qᵀ·A reproduces R.
        for i in 0..12 {
            for j in 0..12 {
                assert!(
                    (qt_a[(i, j)] - r[(i, j)]).abs() < 1e-3,
                    "QᵀA[{i},{j}] far from R"
                );
            }
        }
        // An un-armed run pays for none of this.
        let plain = run(CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4));
        assert!(plain.q.is_none() && plain.qt_a.is_none());
    }

    #[test]
    fn q_phase_single_strike_recovers_identical_bits() {
        let clean = run(CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4).with_q_protection(true));
        for stage in [CaqrStage::QAssembly, CaqrStage::ApplyQ] {
            // A Q-stage kill arms the phases by itself; the dead
            // owner's shard is harvested from its replica, bitwise.
            let struck = run(
                CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4)
                    .with_schedule(CaqrKillSchedule::at(&[(1, 0, stage)])),
            );
            assert!(struck.success(), "{stage:?}: replica must carry the strike");
            assert_eq!(
                struck.q.as_ref().unwrap().data(),
                clean.q.as_ref().unwrap().data(),
                "{stage:?}: recovered Q must be bit-identical"
            );
            assert_eq!(
                struck.qt_a.as_ref().unwrap().data(),
                clean.qt_a.as_ref().unwrap().data(),
                "{stage:?}: recovered QᵀA must be bit-identical"
            );
            assert!(struck.metrics.update_recoveries > 0);
            assert_eq!(struck.metrics.checksum_reconstructions, 0);
        }
    }

    #[test]
    fn q_phase_pair_wipe_survives_hybrid_c1_within_bound() {
        // P=8, 3 panels: pair {6,7} owns exactly one assembly shard,
        // pair {4,5} exactly one apply shard — a pair wipe costs one
        // shard, reconstructed from the single armed checksum.
        let clean = run(CaqrSpec::new(Algo::Redundant, 8, 24, 12, 4).with_q_protection(true));
        let cases = [
            (CaqrStage::QAssembly, [6usize, 7usize]),
            (CaqrStage::ApplyQ, [4usize, 5usize]),
        ];
        for (stage, pair) in cases {
            // Self-Healing respawns the wiped pair at the phase
            // boundary, so each wipe costs exactly one shard.
            let struck = run(
                CaqrSpec::new(Algo::SelfHealing, 8, 24, 12, 4)
                    .with_schedule(CaqrKillSchedule::at(&[
                        (pair[0], 0, stage),
                        (pair[1], 0, stage),
                    ]))
                    .with_policy(RecoveryPolicy::Hybrid)
                    .with_checksums(1),
            );
            assert!(struck.success(), "{stage:?}: hybrid c=1 must ride the pair wipe");
            assert!(struck.metrics.pair_wipes_survived >= 1);
            assert!(struck.metrics.checksum_reconstructions >= 1);
            assert_eq!(struck.metrics.respawns, 2, "{stage:?}: pair respawned at the boundary");
            // Reconstruction round-trips the encoder: bounded, and at
            // these sizes far inside the c·n·ε·‖A‖ envelope.
            assert!(
                struck.q.as_ref().unwrap().max_abs_diff(clean.q.as_ref().unwrap()) < 1e-3,
                "{stage:?}: reconstructed Q must stay within the ABFT bound"
            );
            assert!(
                struck
                    .qt_a
                    .as_ref()
                    .unwrap()
                    .max_abs_diff(clean.qt_a.as_ref().unwrap())
                    < 1e-3,
                "{stage:?}: reconstructed QᵀA must stay within the ABFT bound"
            );
        }
    }

    #[test]
    fn q_phase_pair_wipe_aborts_without_the_checksum_rung() {
        let res = run(
            CaqrSpec::new(Algo::Redundant, 8, 24, 12, 4)
                .with_schedule(CaqrKillSchedule::at(&[
                    (6, 0, CaqrStage::QAssembly),
                    (7, 0, CaqrStage::QAssembly),
                ])),
        );
        assert!(!res.success(), "replication-only must abort on a Q-phase pair wipe");
        assert_eq!(res.failed_at, Some((3, CaqrStage::QAssembly)));
        assert!(res.q.is_none() && res.qt_a.is_none() && res.final_r.is_none());
    }

    #[test]
    fn zero_failure_coded_q_phases_are_bitwise_bystanders() {
        let plain = run(CaqrSpec::new(Algo::Redundant, 8, 24, 12, 4).with_q_protection(true));
        let coded = run(
            CaqrSpec::new(Algo::Redundant, 8, 24, 12, 4)
                .with_q_protection(true)
                .with_policy(RecoveryPolicy::Hybrid)
                .with_checksums(2),
        );
        assert!(coded.success());
        assert_eq!(
            coded.q.as_ref().unwrap().data(),
            plain.q.as_ref().unwrap().data(),
            "checksum tasks must not perturb the assembled Q"
        );
        assert_eq!(
            coded.qt_a.as_ref().unwrap().data(),
            plain.qt_a.as_ref().unwrap().data(),
            "checksum tasks must not perturb QᵀA"
        );
        assert_eq!(coded.metrics.checksum_reconstructions, 0);
    }

    #[test]
    fn f64_precision_is_the_byte_identical_default() {
        // The precision plumbing must be invisible at F64: explicit
        // F64 and the untouched default produce the same bytes as each
        // other (and the bitwise oracle pins above already tie the
        // default to the pre-plumbing behaviour).
        let plain = run(CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4));
        let explicit = run(
            CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4).with_precision(Precision::F64),
        );
        assert_eq!(explicit.precision, Precision::F64);
        assert_eq!(
            explicit.final_r.as_ref().unwrap().data(),
            plain.final_r.as_ref().unwrap().data()
        );
        assert_eq!(
            explicit.factors.as_ref().unwrap().packed.data(),
            plain.factors.as_ref().unwrap().packed.data()
        );
    }

    #[test]
    fn f32_precision_is_deterministic_and_close_to_the_oracle() {
        let spec = || CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4).with_precision(Precision::F32);
        let a = spec().input_matrix();
        let r1 = run(spec());
        let r2 = run(spec());
        assert!(r1.success());
        assert_eq!(r1.precision, Precision::F32);
        assert_eq!(
            r1.final_r.as_ref().unwrap().data(),
            r2.final_r.as_ref().unwrap().data(),
            "f32 runs must be run-to-run bit-deterministic"
        );
        let reference = crate::linalg::householder_qr_reference(&a).r();
        assert!(
            r1.final_r.as_ref().unwrap().max_abs_diff(&reference) < 1e-3,
            "f32 data path must stay within f32-level error of the f64 oracle"
        );
    }

    #[test]
    fn f32_single_strike_recovers_its_own_clean_bits() {
        // Replicas round identically, so replica harvest stays
        // bit-exact under mixed precision — the invariant that makes
        // f32 CAQR fault-tolerant at all.
        let mk = |kills: &[(usize, usize, CaqrStage)]| {
            CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4)
                .with_precision(Precision::F32)
                .with_schedule(CaqrKillSchedule::at(kills))
        };
        let clean = run(mk(&[]));
        for stage in [CaqrStage::Factor, CaqrStage::Update] {
            let struck = run(mk(&[(1, 0, stage)]));
            assert!(struck.success(), "{stage:?}: replica must carry the f32 strike");
            assert_eq!(
                struck.final_r.as_ref().unwrap().data(),
                clean.final_r.as_ref().unwrap().data(),
                "{stage:?}: f32 single-strike recovery must be bit-identical"
            );
        }
    }

    #[test]
    fn f32_hybrid_pair_wipe_reconstructs_within_the_f32_bound() {
        // The mixed-precision contract: f64 checksums over f32 data
        // ride through a pair wipe with f32-level (not bit-exact)
        // reconstruction error.
        let clean = run(
            CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4).with_precision(Precision::F32),
        );
        let wipe = PairWipeSchedule::new(2, 0, CaqrStage::Update);
        let struck = run(
            CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4)
                .with_precision(Precision::F32)
                .with_schedule(wipe.schedule())
                .with_policy(RecoveryPolicy::Hybrid)
                .with_checksums(1),
        );
        assert!(struck.success(), "f64 checksums must carry the f32 pair wipe");
        assert!(struck.metrics.pair_wipes_survived >= 1);
        assert!(
            struck
                .final_r
                .as_ref()
                .unwrap()
                .max_abs_diff(clean.final_r.as_ref().unwrap())
                < 1e-3,
            "f32 reconstruction must stay within the f32 column-wise bound"
        );
    }

    #[test]
    fn threaded_backend_caqr_is_deterministic_and_recovers_bitwise() {
        use crate::runtime::BackendPlan;
        // The chunked-reduction factor core replaces factor_panel_f64
        // on every replica at once: runs are deterministic, recovery
        // stays bit-identical against the run's own clean bits, and
        // the result stays numerically tied to the oracle.
        let mk = |kills: &[(usize, usize, CaqrStage)]| {
            CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4)
                .with_backend(BackendPlan::threaded())
                .with_schedule(CaqrKillSchedule::at(kills))
        };
        let a = mk(&[]).input_matrix();
        let c1 = run(mk(&[]));
        let c2 = run(mk(&[]));
        assert!(c1.success());
        assert_eq!(
            c1.final_r.as_ref().unwrap().data(),
            c2.final_r.as_ref().unwrap().data(),
            "threaded-plan runs must be run-to-run bit-deterministic"
        );
        let reference = crate::linalg::householder_qr_reference(&a).r();
        assert!(c1.final_r.as_ref().unwrap().max_abs_diff(&reference) < 1e-3);
        let struck = run(mk(&[(1, 0, CaqrStage::Update)]));
        assert!(struck.success());
        assert!(struck.metrics.update_recoveries > 0);
        assert_eq!(
            struck.final_r.as_ref().unwrap().data(),
            c1.final_r.as_ref().unwrap().data(),
            "threaded-plan recovery must reproduce its own clean bits"
        );
    }

    #[test]
    fn checksum_policy_reconstructs_unreplicated_losses() {
        // Under the un-replicated policy a single death loses its
        // blocks outright; the checksum rung carries them.
        let res = run(
            CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4)
                .with_policy(RecoveryPolicy::Checksum)
                .with_checksums(1)
                .with_schedule(CaqrKillSchedule::at(&[(1, 0, CaqrStage::Update)])),
        );
        assert!(res.success());
        assert_eq!(res.metrics.update_recoveries, 0, "no replicas to recover from");
        assert!(res.metrics.checksum_reconstructions >= 1);
        assert!(res.verification.unwrap().ok);
    }
}
