//! One CAQR factorization over the engine's worker pool.
//!
//! The coordinator walks the [`PanelPlan`] panel by panel.  Per panel:
//!
//! 1. **Factor stage** — fire the `(rank, k, Factor)` kills, then
//!    spawn one factor task per *live* member of the panel's replica
//!    pair.  Every replica factors its own copy of the identical f64
//!    snapshot with identical arithmetic, so the copies are
//!    bit-identical (debug builds assert it); the harvest takes the
//!    lowest-ranked survivor's copy.
//! 2. **Update stage** — fire the `(rank, k, Update)` kills, then
//!    spawn the replicated trailing-update tasks (owner + buddy per
//!    block).  A kill between spawn and harvest models the paper's
//!    "process dies mid-update": the dead rank's results are
//!    discarded, and each of its blocks is harvested from the
//!    surviving replica instead — a *recovery*, counted in the
//!    metrics.  If both members of a pair are dead the block has no
//!    surviving copy and the run fails (`replication − 1` exceeded).
//! 3. **Panel boundary** — Self-Healing respawns the dead (REBUILD),
//!    restoring capacity for the next panel; Redundant lets the world
//!    shrink.
//!
//! All inter-task data is `Arc`-shared f64 (never rounded through
//! f32), which is what keeps the fault-tolerant path bit-identical to
//! the failure-free oracle.
//!
//! [`PanelPlan`]: crate::tsqr::PanelPlan

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::engine::{TaskGroup, WorkerPool};
use crate::error::Result;
use crate::fault::CaqrStage;
use crate::linalg::view::{apply_update_f64, factor_panel_f64};
use crate::linalg::{Matrix, PackedQr};
use crate::tsqr::{Algo, verify};
use crate::ulfm::{MetricsSnapshot, ProcStatus};

use super::{CaqrResult, CaqrSpec, PanelSurvival};

/// Execute one validated spec end to end on pooled workers.
pub(crate) fn execute(spec: &CaqrSpec, pool: &WorkerPool) -> Result<CaqrResult> {
    spec.validate()?;
    let plan = spec.plan();
    let (m, n) = (spec.m, spec.n);
    let a = spec.input_matrix();
    let started = Instant::now();

    // The factorization state, f64 end to end (one terminal rounding).
    let mut w: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    let mut tau = vec![0.0f64; n];
    let mut alive = vec![true; spec.procs];
    let mut died_at: Vec<Option<usize>> = vec![None; spec.procs];
    let mut metrics = MetricsSnapshot::default();
    let mut panel_survival: Vec<PanelSurvival> = Vec::with_capacity(plan.panels());
    let mut failed_at: Option<(usize, CaqrStage)> = None;

    'panels: for k in 0..plan.panels() {
        let (c0, c1) = plan.col_range(k);
        let rows = m - c0;
        let cols = c1 - c0;

        // ---------------------------------------------- factor stage
        for r in 0..spec.procs {
            if alive[r] && spec.schedule.fire(r, k, CaqrStage::Factor) {
                alive[r] = false;
                died_at[r] = Some(k);
            }
        }
        let replicas: Vec<usize> =
            plan.factor_replicas(k).into_iter().filter(|&r| alive[r]).collect();
        if replicas.is_empty() {
            failed_at = Some((k, CaqrStage::Factor));
            break 'panels;
        }
        // One immutable snapshot of the panel region (rows c0.., cols
        // c0..c1); every replica factors its own working copy of it.
        let mut snap = vec![0.0f64; rows * cols];
        for i in 0..rows {
            for j in 0..cols {
                snap[i * cols + j] = w[(c0 + i) * n + (c0 + j)];
            }
        }
        let snap = Arc::new(snap);
        type FactorMap = BTreeMap<usize, (Vec<f64>, Vec<f64>)>;
        let factor_results: Arc<Mutex<FactorMap>> = Arc::new(Mutex::new(BTreeMap::new()));
        let tasks = TaskGroup::new(pool.clone());
        for &rank in &replicas {
            let snap = Arc::clone(&snap);
            let out = Arc::clone(&factor_results);
            tasks.spawn(move || {
                let mut wbuf = (*snap).clone();
                let mut t = vec![0.0f64; cols];
                factor_panel_f64(&mut wbuf, rows, cols, &mut t);
                out.lock().unwrap().insert(rank, (wbuf, t));
            });
        }
        tasks.wait_idle();
        let owner = plan.factor_owner(k);
        let factor_recovered = !alive[owner];
        let (panel_buf, panel_tau) = {
            let mut fr = factor_results.lock().unwrap();
            #[cfg(debug_assertions)]
            {
                // The redundancy invariant: replicas are bit-identical.
                let mut vals = fr.values();
                if let Some((w0, t0)) = vals.next() {
                    for (wi, ti) in vals {
                        debug_assert!(
                            w0.iter().zip(wi).all(|(a, b)| a.to_bits() == b.to_bits())
                                && t0.iter().zip(ti).all(|(a, b)| a.to_bits() == b.to_bits()),
                            "panel {k}: factor replicas diverged"
                        );
                    }
                }
            }
            let chosen = *fr.keys().next().expect("at least one live replica deposited");
            fr.remove(&chosen).expect("just looked it up")
        };
        let panel_shared = Arc::new((panel_buf, panel_tau));

        // ---------------------------------------------- update stage
        for r in 0..spec.procs {
            if alive[r] && spec.schedule.fire(r, k, CaqrStage::Update) {
                alive[r] = false;
                died_at[r] = Some(k);
            }
        }
        let blocks = plan.update_blocks(k);
        // Resolve assignees up front: a block whose owner AND replica
        // are both dead has no surviving copy — the run is lost before
        // anything needs to be spawned.
        let mut assignee_sets: Vec<Vec<usize>> = Vec::with_capacity(blocks);
        for j in 0..blocks {
            let asg: Vec<usize> =
                plan.update_assignees(k, j).into_iter().filter(|&r| alive[r]).collect();
            if asg.is_empty() {
                failed_at = Some((k, CaqrStage::Update));
                break 'panels;
            }
            assignee_sets.push(asg);
        }
        type UpdateMap = BTreeMap<(usize, usize), Vec<f64>>;
        let update_results: Arc<Mutex<UpdateMap>> = Arc::new(Mutex::new(BTreeMap::new()));
        let tasks = TaskGroup::new(pool.clone());
        let mut spawned = 0u64;
        for (j, asg) in assignee_sets.iter().enumerate() {
            let (t0, t1) = plan.update_cols(k, j);
            let bk = t1 - t0;
            let mut bsnap = vec![0.0f64; rows * bk];
            for i in 0..rows {
                for c in 0..bk {
                    bsnap[i * bk + c] = w[(c0 + i) * n + (t0 + c)];
                }
            }
            let bsnap = Arc::new(bsnap);
            for &rank in asg {
                let panel_shared = Arc::clone(&panel_shared);
                let bsnap = Arc::clone(&bsnap);
                let out = Arc::clone(&update_results);
                spawned += 1;
                tasks.spawn(move || {
                    let (pan, t) = &*panel_shared;
                    let mut blk = (*bsnap).clone();
                    apply_update_f64(pan, rows, cols, t, &mut blk, bk);
                    out.lock().unwrap().insert((j, rank), blk);
                });
            }
        }
        tasks.wait_idle();
        metrics.update_tasks += spawned;
        let mut panel_recoveries = 0u64;
        {
            let mut ur = update_results.lock().unwrap();
            for (j, asg) in assignee_sets.iter().enumerate() {
                let block_owner = plan.update_owner(k, j);
                let source = if asg.contains(&block_owner) {
                    block_owner
                } else {
                    // The owner died mid-update: harvest the replica's
                    // bit-identical copy instead.
                    panel_recoveries += 1;
                    asg[0]
                };
                let blk = ur.remove(&(j, source)).expect("assigned task deposited its block");
                let (t0, t1) = plan.update_cols(k, j);
                let bk = t1 - t0;
                for i in 0..rows {
                    for c in 0..bk {
                        w[(c0 + i) * n + (t0 + c)] = blk[i * bk + c];
                    }
                }
            }
        }
        metrics.update_recoveries += panel_recoveries;
        // Write the factored panel (and its tau) into the state.
        {
            let (pan, ptau) = &*panel_shared;
            for i in 0..rows {
                for j in 0..cols {
                    w[(c0 + i) * n + (c0 + j)] = pan[i * cols + j];
                }
            }
            tau[c0..c1].copy_from_slice(ptau);
        }

        // --------------------------------------------- panel boundary
        let mut respawns = 0u64;
        if spec.algo == Algo::SelfHealing {
            for r in 0..spec.procs {
                if !alive[r] {
                    alive[r] = true;
                    died_at[r] = None;
                    respawns += 1;
                }
            }
        }
        metrics.respawns += respawns;
        metrics.panels_completed += 1;
        panel_survival.push(PanelSurvival {
            panel: k,
            alive_after: alive.iter().filter(|&&x| x).count(),
            factor_recovered,
            update_recoveries: panel_recoveries,
            respawns,
        });
    }

    let statuses: Vec<ProcStatus> = (0..spec.procs)
        .map(|r| {
            if alive[r] {
                ProcStatus::Alive
            } else {
                ProcStatus::Dead { at_round: died_at[r].unwrap_or(0) as u32 }
            }
        })
        .collect();
    let wall = started.elapsed();

    let (factors, final_r, verification) = if failed_at.is_none() {
        // The single f64 -> f32 rounding of the whole run.
        let packed = Matrix::from_vec(m, n, w.iter().map(|&x| x as f32).collect());
        let tau32: Vec<f32> = tau.iter().map(|&x| x as f32).collect();
        let mut r = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                r[(i, j)] = packed[(i, j)];
            }
        }
        let verification = if spec.verify { Some(verify::verify_r(&a, &r)) } else { None };
        (Some(PackedQr { packed, tau: tau32 }), Some(r), verification)
    } else {
        (None, None, None)
    };

    Ok(CaqrResult {
        algo: spec.algo,
        procs: spec.procs,
        panels: plan.panels(),
        failed_at,
        factors,
        final_r,
        statuses,
        metrics,
        panel_survival,
        wall,
        verification,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::CaqrKillSchedule;

    fn run(spec: CaqrSpec) -> CaqrResult {
        let pool = WorkerPool::new();
        let res = execute(&spec, &pool).unwrap();
        pool.shutdown();
        res
    }

    #[test]
    fn fault_free_matches_reference_bitwise() {
        let spec = CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4);
        let a = spec.input_matrix();
        let res = run(spec);
        assert!(res.success());
        let reference = crate::linalg::householder_qr_reference(&a);
        let f = res.factors.as_ref().unwrap();
        assert_eq!(f.packed.data(), reference.packed.data(), "packed must be bit-identical");
        assert_eq!(f.tau, reference.tau, "tau must be bit-identical");
        assert!(res.verification.unwrap().ok);
        assert_eq!(res.metrics.panels_completed, 3);
        assert_eq!(res.metrics.update_recoveries, 0);
        assert_eq!(res.dead_count(), 0);
    }

    #[test]
    fn update_strike_recovers_identical_bits() {
        let clean = run(CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4));
        let struck = run(
            CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4)
                .with_schedule(CaqrKillSchedule::at(&[(1, 0, CaqrStage::Update)])),
        );
        assert!(struck.success());
        assert!(struck.metrics.update_recoveries > 0, "owner's blocks came from the replica");
        assert_eq!(
            struck.final_r.as_ref().unwrap().data(),
            clean.final_r.as_ref().unwrap().data(),
            "recovered R must be bit-identical"
        );
        assert_eq!(struck.dead_count(), 1, "redundant semantics: the dead stay dead");
    }

    #[test]
    fn pair_wipe_fails_at_the_bound() {
        let res = run(
            CaqrSpec::new(Algo::Redundant, 4, 24, 12, 4)
                .with_schedule(CaqrKillSchedule::at(&[
                    (2, 0, CaqrStage::Update),
                    (3, 0, CaqrStage::Update),
                ])),
        );
        assert!(!res.success(), "both copies of a block lost -> run lost");
        assert_eq!(res.failed_at, Some((0, CaqrStage::Update)));
        assert!(res.final_r.is_none());
    }

    #[test]
    fn self_healing_respawns_at_panel_boundaries() {
        let res = run(
            CaqrSpec::new(Algo::SelfHealing, 4, 24, 12, 4)
                .with_schedule(CaqrKillSchedule::at(&[(1, 0, CaqrStage::Update)])),
        );
        assert!(res.success());
        assert_eq!(res.metrics.respawns, 1);
        assert_eq!(res.dead_count(), 0, "healed world ends at full size");
        assert!(res.panel_survival[0].respawns == 1 && res.panel_survival[0].alive_after == 4);
    }

    #[test]
    fn single_process_world_has_no_redundancy_but_works() {
        let spec = CaqrSpec::new(Algo::Redundant, 1, 16, 8, 3);
        let a = spec.input_matrix();
        let res = run(spec);
        assert!(res.success());
        let reference = crate::linalg::householder_qr_reference(&a);
        assert_eq!(res.factors.unwrap().packed.data(), reference.packed.data());
    }
}
