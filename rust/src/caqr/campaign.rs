//! Batched CAQR execution: run many [`CaqrSpec`]s through one engine
//! and aggregate survival + recovery statistics — the CAQR counterpart
//! of [`crate::engine::Campaign`], shaped for the Monte-Carlo sweeps
//! over panel counts in [`crate::analysis::fullsim`].

use std::time::{Duration, Instant};

use crate::analysis::SurvivalEstimate;
use crate::engine::{CaqrJobHandle, Engine};
use crate::error::Result;
use crate::tsqr::Algo;
use crate::ulfm::MetricsSnapshot;

use super::{CaqrResult, CaqrSpec};

/// Compact per-run outcome kept for every campaign member (full
/// [`CaqrResult`]s — packed factors included — are not retained).
#[derive(Debug, Clone)]
pub struct CaqrRecord {
    /// Position in the campaign's spec list.
    pub index: usize,
    /// The spec's input-matrix seed.
    pub seed: u64,
    /// Failure semantics the run used.
    pub algo: Algo,
    /// World size.
    pub procs: usize,
    /// Did the factorization complete?
    pub success: bool,
    /// Panels fully completed before the run ended.
    pub panels_completed: u64,
    /// Ranks dead at the end of the run.
    pub dead: usize,
    /// `None` when verification was skipped.
    pub verified_ok: Option<bool>,
    /// Task/recovery counters.
    pub metrics: MetricsSnapshot,
    /// Wall clock of the run.
    pub wall: Duration,
}

impl CaqrRecord {
    fn from_result(index: usize, seed: u64, res: &CaqrResult) -> Self {
        Self {
            index,
            seed,
            algo: res.algo,
            procs: res.procs,
            success: res.success(),
            panels_completed: res.metrics.panels_completed,
            dead: res.dead_count(),
            verified_ok: res.verification.as_ref().map(|v| v.ok),
            metrics: res.metrics,
            wall: res.wall,
        }
    }
}

/// A batch of CAQR runs bound to an engine.  Built by
/// [`Engine::caqr_campaign`]; consumed by [`CaqrCampaign::run`].
pub struct CaqrCampaign<'e> {
    engine: &'e Engine,
    specs: Vec<CaqrSpec>,
    concurrency: usize,
}

impl<'e> CaqrCampaign<'e> {
    pub(crate) fn new(engine: &'e Engine, specs: Vec<CaqrSpec>) -> Self {
        Self { engine, specs, concurrency: 1 }
    }

    /// Number of runs pipelined concurrently (default 1: sequential).
    pub fn concurrency(mut self, window: usize) -> Self {
        self.concurrency = window.max(1);
        self
    }

    /// Runs in the campaign.
    pub fn len(&self) -> usize {
        self.specs.len()
    }

    /// True when the campaign holds no specs.
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Execute every spec and aggregate.  Validation is eager: any
    /// invalid spec fails the campaign before the first run starts.
    /// (Orchestration — sequential vs sliding window — is shared with
    /// the TSQR campaign: `engine::campaign::drive`.)
    pub fn run(self) -> Result<CaqrCampaignReport> {
        for spec in &self.specs {
            spec.validate()?;
        }
        let started = Instant::now();
        let seeds: Vec<u64> = self.specs.iter().map(|s| s.seed).collect();
        let mut records: Vec<CaqrRecord> = Vec::with_capacity(self.specs.len());

        let engine = self.engine;
        crate::engine::drive(
            self.specs,
            self.concurrency,
            |spec| engine.run_caqr(spec),
            |spec| engine.submit_caqr(spec),
            CaqrJobHandle::wait,
            |index, res| records.push(CaqrRecord::from_result(index, seeds[index], &res)),
        )?;

        Ok(CaqrCampaignReport { records, total_wall: started.elapsed() })
    }
}

/// Aggregated outcome of one CAQR campaign.
#[derive(Debug)]
pub struct CaqrCampaignReport {
    /// One record per run, in spec order.
    pub records: Vec<CaqrRecord>,
    /// Wall clock of the whole campaign.
    pub total_wall: Duration,
}

impl CaqrCampaignReport {
    /// Runs executed.
    pub fn runs(&self) -> u64 {
        self.records.len() as u64
    }

    /// Runs whose factorization completed.
    pub fn successes(&self) -> u64 {
        self.records.iter().filter(|r| r.success).count() as u64
    }

    /// Survival statistics over the campaign (probability + 95% CI).
    pub fn survival(&self) -> SurvivalEstimate {
        SurvivalEstimate { trials: self.runs(), successes: self.successes() }
    }

    /// Counters summed over every run.
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut total = MetricsSnapshot::default();
        for r in &self.records {
            total.merge(&r.metrics);
        }
        total
    }

    /// Completed runs per second of campaign wall clock.
    pub fn throughput(&self) -> f64 {
        let secs = self.total_wall.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.runs() as f64 / secs
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let est = self.survival();
        let m = self.metrics();
        format!(
            "caqr runs={} successes={} rate={:.3}±{:.3} panels={} update_tasks={} \
             recoveries={} respawns={} throughput={:.1}/s",
            self.runs(),
            self.successes(),
            est.probability(),
            est.ci95(),
            m.panels_completed,
            m.update_tasks,
            m.update_recoveries,
            m.respawns,
            self.throughput(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::CaqrKillSchedule;

    fn small(seed: u64) -> CaqrSpec {
        CaqrSpec::new(Algo::Redundant, 4, 16, 8, 4).with_seed(seed)
    }

    #[test]
    fn sequential_campaign_aggregates() {
        let engine = Engine::host();
        let report = engine.caqr_campaign((0..4).map(small)).run().unwrap();
        assert_eq!(report.runs(), 4);
        assert_eq!(report.successes(), 4);
        assert!((report.survival().probability() - 1.0).abs() < 1e-12);
        assert_eq!(report.metrics().panels_completed, 8, "2 panels x 4 runs");
        assert!(report.metrics().update_tasks > 0);
        assert!(report.summary().contains("caqr runs=4"), "{}", report.summary());
    }

    #[test]
    fn concurrent_campaign_matches_sequential() {
        let engine = Engine::host();
        let specs = || {
            (0..6u64).map(|s| {
                small(s)
                    .with_verify(false)
                    .with_schedule(CaqrKillSchedule::random_updates(4, 2, 1, s))
            })
        };
        let seq = engine.caqr_campaign(specs()).run().unwrap();
        let conc = engine.caqr_campaign(specs()).concurrency(3).run().unwrap();
        let key = |r: &CaqrRecord| {
            (r.index, r.seed, r.success, r.dead, r.metrics.update_recoveries)
        };
        let a: Vec<_> = seq.records.iter().map(key).collect();
        let b: Vec<_> = conc.records.iter().map(key).collect();
        assert_eq!(a, b, "concurrency must not change per-run outcomes");
    }

    #[test]
    fn invalid_spec_fails_eagerly() {
        let engine = Engine::host();
        let specs = vec![small(1), CaqrSpec::new(Algo::Baseline, 4, 16, 8, 4)];
        assert!(engine.caqr_campaign(specs).run().is_err());
    }
}
