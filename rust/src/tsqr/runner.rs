//! Run specification and result types, plus the one-shot `run` entry
//! point.
//!
//! Since the engine redesign the run *lifecycle* (worker scheduling,
//! quiescence, result gathering) lives in `crate::engine::exec`; this
//! module keeps the public vocabulary — [`Algo`], [`RunSpec`],
//! [`RunResult`] — and [`run`], now a thin shim over a single-use
//! [`crate::engine::Engine`].  Long-lived callers should hold an
//! `Engine` and reuse it: same semantics, amortized setup.

use std::sync::Arc;
use std::time::Duration;

use crate::error::{Error, Result};
use crate::fault::KillSchedule;
use crate::linalg::Matrix;
use crate::runtime::Executor;
use crate::ulfm::world::MetricsSnapshot;
use crate::ulfm::{ProcStatus, Rank};

use super::algorithms::ProcOutcome;
use super::context::Ctx;
use super::trace::{Event, Trace};
use super::verify::Verification;

/// Which of the paper's algorithms to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algo {
    /// Algorithm 1 — plain TSQR (ABORT on failure).
    Baseline,
    /// Algorithm 2 — Redundant TSQR.
    Redundant,
    /// Algorithm 3 — Replace TSQR.
    Replace,
    /// Algorithms 4–6 — Self-Healing TSQR.
    SelfHealing,
    /// Comparator: TSQR + diskless neighbour checkpointing [17]
    /// (see `crate::checkpoint`) — robustness bought with extra
    /// messages instead of redundant computation.
    Checkpointed,
}

impl Algo {
    /// The paper's four algorithms (Algorithms 1–6).
    pub const ALL: [Algo; 4] = [Algo::Baseline, Algo::Redundant, Algo::Replace, Algo::SelfHealing];
    /// Everything, including the checkpointing comparator.
    pub const ALL_WITH_COMPARATORS: [Algo; 5] = [
        Algo::Baseline,
        Algo::Redundant,
        Algo::Replace,
        Algo::SelfHealing,
        Algo::Checkpointed,
    ];

    /// Stable CLI/report name.
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Baseline => "baseline",
            Algo::Redundant => "redundant",
            Algo::Replace => "replace",
            Algo::SelfHealing => "self-healing",
            Algo::Checkpointed => "checkpointed",
        }
    }

    /// Does the algorithm perform the redundant buddy *exchange*
    /// (everyone keeps computing) rather than the one-way send?
    pub fn is_redundant_family(&self) -> bool {
        matches!(self, Algo::Redundant | Algo::Replace | Algo::SelfHealing)
    }
}

impl std::str::FromStr for Algo {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "baseline" | "tsqr" => Ok(Algo::Baseline),
            "redundant" => Ok(Algo::Redundant),
            "replace" => Ok(Algo::Replace),
            "self-healing" | "selfhealing" | "sh" => Ok(Algo::SelfHealing),
            "checkpointed" | "checkpoint" | "ckpt" => Ok(Algo::Checkpointed),
            _ => Err(Error::Config(format!(
                "unknown algorithm '{s}' (baseline|redundant|replace|self-healing|checkpointed)"
            ))),
        }
    }
}

impl std::fmt::Display for Algo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Everything needed to run one factorization.
#[derive(Clone)]
pub struct RunSpec {
    /// Which algorithm to run.
    pub algo: Algo,
    /// Simulated world size.
    pub procs: usize,
    /// Leaf panel rows per process.
    pub rows_per_proc: usize,
    /// Matrix columns.
    pub cols: usize,
    /// Input-matrix seed.
    pub seed: u64,
    /// Fault-injection schedule.
    pub schedule: Arc<KillSchedule>,
    /// Kernel executor.  Note: specs submitted to an
    /// [`crate::engine::Engine`] run on the *engine's* executor — this
    /// field only matters for the one-shot [`run`] path.
    pub executor: Executor,
    /// Collect an execution trace (off on the bench hot path).
    pub collect_trace: bool,
    /// Verify the final R against the host oracle (skippable for large
    /// Monte-Carlo sweeps where only survival matters).
    pub verify: bool,
    /// Zero-copy input override: when set, this shared matrix is
    /// factored instead of generating one from `seed` — N queued jobs
    /// over the same data share a single allocation (the service
    /// layer's shared-input path).  Shape must be
    /// `procs·rows_per_proc × cols` ([`validate`](Self::validate)
    /// checks).
    pub input: Option<Arc<Matrix>>,
}

impl RunSpec {
    /// Sensible defaults for a small fault-free run.
    pub fn new(algo: Algo, procs: usize, rows_per_proc: usize, cols: usize) -> Self {
        Self {
            algo,
            procs,
            rows_per_proc,
            cols,
            seed: 42,
            schedule: Arc::new(KillSchedule::none()),
            executor: Executor::host(),
            collect_trace: false,
            verify: true,
            input: None,
        }
    }

    /// Replace the fault-injection schedule.
    pub fn with_schedule(mut self, s: KillSchedule) -> Self {
        self.schedule = Arc::new(s);
        self
    }

    /// Replace the executor (one-shot path only; engines override it).
    pub fn with_executor(mut self, e: Executor) -> Self {
        self.executor = e;
        self
    }

    /// Replace the input-matrix seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Toggle trace collection.
    pub fn with_trace(mut self, on: bool) -> Self {
        self.collect_trace = on;
        self
    }

    /// Toggle oracle verification.
    pub fn with_verify(mut self, on: bool) -> Self {
        self.verify = on;
        self
    }

    /// Share an input matrix zero-copy: the run factors `input`
    /// directly (no per-job `Matrix::random` materialization), so many
    /// specs can reference one allocation through the `Arc`.
    pub fn with_input(mut self, input: impl Into<Arc<Matrix>>) -> Self {
        self.input = Some(input.into());
        self
    }

    /// Check shape and algorithm/world-size compatibility.
    pub fn validate(&self) -> Result<()> {
        if self.procs == 0 {
            return Err(Error::Config("procs must be >= 1".into()));
        }
        if self.rows_per_proc < self.cols {
            return Err(Error::Config(format!(
                "leaf panels must be tall-skinny: rows_per_proc {} < cols {}",
                self.rows_per_proc, self.cols
            )));
        }
        if self.algo.is_redundant_family() && !self.procs.is_power_of_two() {
            return Err(Error::Config(format!(
                "{} requires a power-of-two world (got {}): the replica-group \
                 structure of §III-B3 is only defined there",
                self.algo.name(),
                self.procs
            )));
        }
        if self.algo == Algo::Checkpointed && !self.procs.is_power_of_two() {
            return Err(Error::Config(
                "checkpointed TSQR partners within the reduction tree; procs must be a power of two"
                    .into(),
            ));
        }
        if let Some(input) = &self.input {
            let want = (self.procs * self.rows_per_proc, self.cols);
            if input.shape() != want {
                return Err(Error::Config(format!(
                    "shared input shape {:?} does not match spec shape {:?} \
                     (procs*rows_per_proc x cols)",
                    input.shape(),
                    want
                )));
            }
        }
        Ok(())
    }

    /// The full input matrix this spec factors (deterministic in seed).
    /// Ignores any shared-input override — see
    /// [`resolve_input`](Self::resolve_input) for what a run actually
    /// factors.
    pub fn input_matrix(&self) -> Matrix {
        Matrix::random(self.procs * self.rows_per_proc, self.cols, self.seed)
    }

    /// The matrix a run of this spec factors: the shared zero-copy
    /// override when present (`Arc` clone, no data copy), otherwise a
    /// fresh seed-deterministic [`input_matrix`](Self::input_matrix).
    pub fn resolve_input(&self) -> Arc<Matrix> {
        match &self.input {
            Some(m) => Arc::clone(m),
            None => Arc::new(self.input_matrix()),
        }
    }

    /// The per-process scratch high-water mark of this run (leaf vs
    /// combine, precomputed from the tree plan) — what the engine
    /// warms executor workspaces to before spawning rank bodies.
    pub fn workspace_shape(&self) -> (usize, usize) {
        crate::tsqr::plan::TreePlan::new(self.procs.max(1))
            .workspace_shape(self.rows_per_proc, self.cols)
    }
}

/// Outcome of one run.
#[derive(Debug)]
pub struct RunResult {
    /// The algorithm that ran.
    pub spec_algo: Algo,
    /// World size.
    pub procs: usize,
    /// Final status of every rank.
    pub statuses: Vec<ProcStatus>,
    /// Ranks that finished holding the final R.
    pub r_holders: Vec<Rank>,
    /// The final R (canonicalized) if any process finished with one.
    pub final_r: Option<Matrix>,
    /// Max |Δ| between the canonical R's of different holders (the
    /// redundancy-consistency check; 0 when holders agree bitwise).
    pub holder_disagreement: f64,
    /// Communication counters of the run.
    pub metrics: MetricsSnapshot,
    /// Collected events (empty unless the spec enabled tracing).
    pub trace: Trace,
    /// Wall clock of the run.
    pub wall: Duration,
    /// Oracle verdict (when the spec asked for verification).
    pub verification: Option<Verification>,
}

impl RunResult {
    /// Success under each algorithm's own semantics (§III-B1/C1/D1):
    /// baseline/checkpointed need the tree root to hold R; the
    /// redundant family needs at least one survivor holding R.
    pub fn success(&self) -> bool {
        match self.spec_algo {
            Algo::Baseline | Algo::Checkpointed => {
                self.statuses.first().map(|s| s.has_final_r()).unwrap_or(false)
            }
            _ => !self.r_holders.is_empty(),
        }
    }

    /// Self-Healing extra guarantee (§III-D1): world restored to full
    /// size, i.e. every rank finished holding the final R.
    pub fn fully_healed(&self) -> bool {
        self.statuses.iter().all(|s| s.has_final_r())
    }

    /// Ranks dead at the end of the run.
    pub fn dead_count(&self) -> usize {
        self.statuses.iter().filter(|s| matches!(s, ProcStatus::Dead { .. })).count()
    }
}

/// Wrapper around one process body: translates its outcome into world
/// status, trace events and the result map.  Public because the
/// Self-Healing respawn path spawns replacement processes through it.
pub fn run_process_wrapper(ctx: Ctx, body: impl FnOnce() -> ProcOutcome) -> ProcOutcome {
    let outcome = body();
    if let ProcOutcome::FinalR(r) = &outcome {
        ctx.deposit_result(Arc::clone(r)); // share the handle, no copy
    }
    if let Some(kind) = outcome.exit_kind() {
        ctx.world.exit(ctx.rank, kind);
        ctx.trace.emit(Event::Exited { rank: ctx.rank, kind });
    }
    outcome
}

/// Run one factorization end to end (one-shot convenience).
///
/// This is a thin shim over a single-use [`crate::engine::Engine`]
/// built around the spec's executor: identical semantics to the
/// original spawn-per-run lifecycle (per-algorithm success criteria,
/// holder-disagreement check, verification oracle), with the worker
/// pool torn down on return.  Callers issuing many runs should build
/// one `Engine` (or a `Campaign`) and reuse it.
pub fn run(spec: &RunSpec) -> Result<RunResult> {
    crate::engine::Engine::with_executor(spec.executor.clone()).run(spec.clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_validation() {
        assert!(RunSpec::new(Algo::Redundant, 4, 16, 4).validate().is_ok());
        assert!(RunSpec::new(Algo::Redundant, 6, 16, 4).validate().is_err(), "pow2 only");
        assert!(RunSpec::new(Algo::Baseline, 6, 16, 4).validate().is_ok(), "baseline any P");
        assert!(RunSpec::new(Algo::Baseline, 4, 2, 4).validate().is_err(), "wide leaf");
        assert!(RunSpec::new(Algo::Baseline, 0, 8, 4).validate().is_err());
        assert!(RunSpec::new(Algo::Checkpointed, 6, 16, 4).validate().is_err());
    }

    #[test]
    fn algo_parsing_and_names() {
        assert_eq!("baseline".parse::<Algo>().unwrap(), Algo::Baseline);
        assert_eq!("sh".parse::<Algo>().unwrap(), Algo::SelfHealing);
        assert_eq!("ckpt".parse::<Algo>().unwrap(), Algo::Checkpointed);
        assert_eq!(Algo::Replace.name(), "replace");
        assert!("nope".parse::<Algo>().is_err());
        assert!(Algo::Redundant.is_redundant_family());
        assert!(!Algo::Baseline.is_redundant_family());
        assert!(!Algo::Checkpointed.is_redundant_family());
        assert_eq!(format!("{}", Algo::SelfHealing), "self-healing");
    }

    #[test]
    fn input_matrix_deterministic() {
        let s = RunSpec::new(Algo::Baseline, 2, 8, 4);
        assert_eq!(s.input_matrix(), s.input_matrix());
        assert_eq!(s.input_matrix().shape(), (16, 4));
    }

    #[test]
    fn shared_input_is_zero_copy_and_shape_checked() {
        let spec = RunSpec::new(Algo::Redundant, 4, 16, 4);
        let shared = Arc::new(spec.input_matrix());

        // Wrong shape is a Config error at validate time, not a panic
        // inside a worker.
        let bad = spec.clone().with_input(Matrix::random(8, 4, 1));
        assert!(matches!(bad.validate(), Err(Error::Config(_))));

        // Right shape: resolve_input hands back the SAME allocation.
        let good = spec.clone().with_input(Arc::clone(&shared));
        good.validate().unwrap();
        assert!(Arc::ptr_eq(&good.resolve_input(), &shared), "no copy on resolve");
        // Cloning the spec clones the Arc, not the matrix.
        let also = good.clone();
        assert!(Arc::ptr_eq(&also.resolve_input(), &shared));

        // Without an override, resolve_input falls back to the seeded
        // generator.
        assert_eq!(*spec.resolve_input(), spec.input_matrix());
    }

    #[test]
    fn fault_free_redundant_small() {
        let spec = RunSpec::new(Algo::Redundant, 4, 16, 4);
        let res = run(&spec).unwrap();
        assert!(res.success());
        assert_eq!(res.r_holders, vec![0, 1, 2, 3]);
        assert_eq!(res.holder_disagreement, 0.0, "replicas must be bit-identical");
        assert!(res.verification.as_ref().unwrap().ok);
    }
}
