//! Result verification: is the R the algorithms produced actually the
//! R factor of the input matrix?
//!
//! R of a (full-rank) QR factorization is unique up to row signs, so
//! everything is compared in canonical form (non-negative diagonal)
//! against the host-side Householder oracle in `linalg::qr`.

use crate::linalg::{Matrix, qr_r};

/// Verification verdict for a final R.
#[derive(Debug, Clone)]
pub struct Verification {
    /// max |R − R_ref| over entries (both canonicalized).
    pub max_abs_err: f64,
    /// ‖R − R_ref‖_F / ‖R_ref‖_F.
    pub rel_fro_err: f64,
    /// Strictly-lower-triangular part is numerically zero.
    pub upper_triangular: bool,
    /// Overall pass at the default tolerance.
    pub ok: bool,
}

/// Default acceptance tolerance: f32 kernels accumulate across
/// log2(P)+1 factorization levels, so allow a generous single-precision
/// envelope (scaled comparisons stay well below this for sane inputs).
pub const DEFAULT_TOL: f64 = 5e-3;

/// Compare a computed final R against the host oracle's R of `a`.
pub fn verify_r(a: &Matrix, r: &Matrix) -> Verification {
    let r_ref = qr_r(a); // canonical by construction
    let r_can = r.canonicalize_r();
    let max_abs_err = r_can.max_abs_diff(&r_ref);
    let rel_fro_err = r_can.rel_fro_err(&r_ref);
    let upper_triangular = r_can.is_upper_triangular(1e-5);
    let ok = rel_fro_err < DEFAULT_TOL && upper_triangular;
    Verification { max_abs_err, rel_fro_err, upper_triangular, ok }
}

/// Full QR check (used by examples): rebuild Q explicitly and measure
/// ‖A − QR‖/‖A‖ and ‖I − QᵀQ‖.
pub fn verify_qr(a: &Matrix, q: &Matrix, r: &Matrix) -> (f64, f64) {
    crate::linalg::qr_residuals(a, q, r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::householder_qr;

    #[test]
    fn oracle_r_verifies_itself() {
        let a = Matrix::random(64, 8, 5);
        let v = verify_r(&a, &qr_r(&a));
        assert!(v.ok);
        assert_eq!(v.max_abs_err, 0.0);
    }

    #[test]
    fn sign_flipped_r_still_verifies() {
        let a = Matrix::random(32, 4, 6);
        let mut r = qr_r(&a);
        for j in 0..4 {
            r[(1, j)] = -r[(1, j)]; // flip one row's signs
        }
        assert!(verify_r(&a, &r).ok, "verification must be sign-invariant");
    }

    #[test]
    fn wrong_r_fails() {
        let a = Matrix::random(32, 4, 7);
        let wrong = qr_r(&Matrix::random(32, 4, 8));
        assert!(!verify_r(&a, &wrong).ok);
    }

    #[test]
    fn non_triangular_fails() {
        let a = Matrix::random(16, 4, 9);
        let mut r = qr_r(&a);
        r[(3, 0)] = 1.0;
        let v = verify_r(&a, &r);
        assert!(!v.upper_triangular && !v.ok);
    }

    #[test]
    fn full_qr_residuals_small_for_exact_factorization() {
        let a = Matrix::random(48, 6, 10);
        let f = householder_qr(&a);
        let (rel, ortho) = verify_qr(&a, &f.q(), &f.r());
        assert!(rel < 1e-5, "rel {rel}");
        assert!(ortho < 1e-4, "ortho {ortho}");
    }
}
