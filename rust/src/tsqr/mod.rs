//! The paper's contribution: fault-tolerant, communication-avoiding
//! TSQR (§III).
//!
//! * [`plan`]       — reduction-tree structure, buddies, replica groups
//! * [`panel`]      — CAQR panel sequencing over per-panel tree plans
//! * [`algorithms`] — Algorithms 1–6 as simulated-process bodies
//! * [`runner`]     — run lifecycle, result gathering
//! * [`trace`]      — machine-checkable execution traces (Figures 1–5)
//! * [`verify`]     — final-R verification against the host oracle
//! * [`context`]    — the per-process handle bundle

pub mod algorithms;
pub mod context;
pub mod panel;
pub mod plan;
pub mod qfactor;
pub mod runner;
pub mod trace;
pub mod verify;

pub use algorithms::ProcOutcome;
pub use context::Ctx;
pub use panel::PanelPlan;
pub use plan::TreePlan;
pub use qfactor::QrTree;
pub use runner::{Algo, RunResult, RunSpec, run};
pub use trace::{Event, Trace, TraceSink};
pub use verify::{Verification, verify_r};
