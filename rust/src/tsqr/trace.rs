//! Structured execution traces — the machinery that regenerates the
//! paper's Figures 1–5 as machine-checkable event streams.
//!
//! Every simulated process emits `Event`s through a cheap `TraceSink`;
//! the runner collects them into a `Trace`, which offers both assertion
//! helpers (used by tests/benches to check the figures' claims) and an
//! ASCII rendering (what `repro trace` prints).

use std::sync::Mutex;
use std::sync::mpsc;

use crate::ulfm::{ExitKind, Rank};

/// One thing that happened on one simulated process.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// Local leaf factorization (Algorithm 1 line 1).
    LeafQr { rank: Rank },
    /// Baseline: sent R̃ to the buddy and left the tree.
    Send { rank: Rank, to: Rank, round: u32 },
    /// Baseline: received buddy's R̃.
    Recv { rank: Rank, from: Rank, round: u32 },
    /// Redundant family: full sendrecv exchange with a peer.
    Exchange { rank: Rank, with: Rank, round: u32 },
    /// Local QR of the concatenated pair (tree node compute).
    Combine { rank: Rank, round: u32 },
    /// A communication attempt observed the ULFM failure error.
    PeerFailed { rank: Rank, peer: Rank, round: u32 },
    /// Replace TSQR: found a live replica of the dead buddy (Alg. 3 l.6).
    ReplicaFound { rank: Rank, dead: Rank, replica: Rank, round: u32 },
    /// Self-Healing: this rank triggered a respawn of a dead peer.
    Respawn { rank: Rank, dead: Rank, round: u32 },
    /// A respawned process recovered its state from a replica (Alg. 5).
    Recovered { rank: Rank, from: Rank, round: u32 },
    /// Fault injector crashed this rank at this round boundary.
    Killed { rank: Rank, round: u32 },
    /// Process left the algorithm.
    Exited { rank: Rank, kind: ExitKind },
}

impl Event {
    /// The rank the event happened on.
    pub fn rank(&self) -> Rank {
        match self {
            Event::LeafQr { rank }
            | Event::Send { rank, .. }
            | Event::Recv { rank, .. }
            | Event::Exchange { rank, .. }
            | Event::Combine { rank, .. }
            | Event::PeerFailed { rank, .. }
            | Event::ReplicaFound { rank, .. }
            | Event::Respawn { rank, .. }
            | Event::Recovered { rank, .. }
            | Event::Killed { rank, .. }
            | Event::Exited { rank, .. } => *rank,
        }
    }
}

/// Shared sink handed to every process.  `None` disables tracing (the
/// benches' hot path records nothing).
#[derive(Clone, Default)]
pub struct TraceSink(Option<mpsc::Sender<Event>>);

impl TraceSink {
    /// A sink that drops every event (the bench hot path).
    pub fn disabled() -> Self {
        Self(None)
    }

    /// A live sink plus the collector that drains it.
    pub fn channel() -> (Self, TraceCollector) {
        let (tx, rx) = mpsc::channel();
        (Self(Some(tx)), TraceCollector(Mutex::new(rx)))
    }

    /// Record one event (no-op when disabled).
    #[inline]
    pub fn emit(&self, ev: Event) {
        if let Some(tx) = &self.0 {
            let _ = tx.send(ev);
        }
    }

    /// True when events are being recorded.
    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }
}

/// Receiver side; drained once after the run.
pub struct TraceCollector(Mutex<mpsc::Receiver<Event>>);

impl TraceCollector {
    /// Drain everything emitted so far (call after all sinks dropped).
    pub fn drain(&self) -> Trace {
        let rx = self.0.lock().unwrap();
        Trace { events: rx.try_iter().collect() }
    }
}

/// The collected event stream of one run.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    /// Every recorded event, in arrival order.
    pub events: Vec<Event>,
}

impl Trace {
    /// Number of recorded events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Every event of one rank, in order.
    pub fn of_rank(&self, rank: Rank) -> Vec<&Event> {
        self.events.iter().filter(|e| e.rank() == rank).collect()
    }

    /// Ranks that performed a combine at `round`.
    pub fn combiners_at(&self, round: u32) -> Vec<Rank> {
        let mut v: Vec<Rank> = self
            .events
            .iter()
            .filter_map(|e| match e {
                Event::Combine { rank, round: r } if *r == round => Some(*rank),
                _ => None,
            })
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Exchange partners at `round` as sorted (low, high) pairs.
    pub fn exchange_pairs_at(&self, round: u32) -> Vec<(Rank, Rank)> {
        let mut v: Vec<(Rank, Rank)> = self
            .events
            .iter()
            .filter_map(|e| match e {
                Event::Exchange { rank, with, round: r } if *r == round => {
                    Some((*rank.min(with), *rank.max(with)))
                }
                _ => None,
            })
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Events matching a predicate.
    pub fn count(&self, pred: impl Fn(&Event) -> bool) -> usize {
        self.events.iter().filter(|e| pred(e)).count()
    }

    /// Every `(rank, exit kind)` pair, in exit order.
    pub fn exits(&self) -> Vec<(Rank, ExitKind)> {
        self.events
            .iter()
            .filter_map(|e| match e {
                Event::Exited { rank, kind } => Some((*rank, *kind)),
                _ => None,
            })
            .collect()
    }

    /// ASCII rendering: one lane per rank, grouped by round — the
    /// textual analogue of the paper's Figures 1–5.
    pub fn render(&self, procs: usize, rounds: u32) -> String {
        let mut out = String::new();
        let lane = |s: &mut String, rank: Rank, text: &str| {
            s.push_str(&format!("  P{rank}: {text}\n"));
        };
        out.push_str("round L (leaf factorizations)\n");
        for r in 0..procs {
            if self.events.iter().any(|e| matches!(e, Event::LeafQr { rank } if *rank == r)) {
                lane(&mut out, r, "QR(A_local)");
            }
        }
        for s in 0..rounds {
            out.push_str(&format!("round {s}\n"));
            for r in 0..procs {
                let mut acts: Vec<String> = Vec::new();
                for e in &self.events {
                    if e.rank() != r {
                        continue;
                    }
                    match e {
                        Event::Send { to, round, .. } if *round == s => {
                            acts.push(format!("send R̃ -> P{to}, done"))
                        }
                        Event::Recv { from, round, .. } if *round == s => {
                            acts.push(format!("recv R̃ <- P{from}"))
                        }
                        Event::Exchange { with, round, .. } if *round == s => {
                            acts.push(format!("exchange R̃ <-> P{with}"))
                        }
                        Event::Combine { round, .. } if *round == s => {
                            acts.push("QR([R̃;R̃'])".to_string())
                        }
                        Event::PeerFailed { peer, round, .. } if *round == s => {
                            acts.push(format!("FAIL: P{peer} dead"))
                        }
                        Event::ReplicaFound { dead, replica, round, .. } if *round == s => {
                            acts.push(format!("replica of P{dead}: P{replica}"))
                        }
                        Event::Respawn { dead, round, .. } if *round == s => {
                            acts.push(format!("spawnNew(P{dead})"))
                        }
                        Event::Recovered { from, round, .. } if *round == s => {
                            acts.push(format!("recovered state <- P{from}"))
                        }
                        Event::Killed { round, .. } if *round == s => {
                            acts.push("✗ CRASH".to_string())
                        }
                        _ => {}
                    }
                }
                if !acts.is_empty() {
                    lane(&mut out, r, &acts.join("; "));
                }
            }
        }
        out.push_str("final\n");
        for (rank, kind) in self.exits() {
            lane(
                &mut out,
                rank,
                match kind {
                    ExitKind::CompletedWithR => "holds final R ✓",
                    ExitKind::CompletedWithoutR => "done (no R, sent upstream)",
                    ExitKind::GaveUpPeerFailed => "exited: needed data from failed process",
                    ExitKind::GaveUpNoReplica => "exited: no live replica",
                },
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sink_collects_events() {
        let (sink, coll) = TraceSink::channel();
        sink.emit(Event::LeafQr { rank: 0 });
        sink.emit(Event::Combine { rank: 0, round: 1 });
        let sink2 = sink.clone();
        sink2.emit(Event::Exchange { rank: 1, with: 0, round: 0 });
        drop((sink, sink2));
        let tr = coll.drain();
        assert_eq!(tr.len(), 3);
        assert_eq!(tr.of_rank(0).len(), 2);
        assert_eq!(tr.combiners_at(1), vec![0]);
    }

    #[test]
    fn disabled_sink_is_silent() {
        let sink = TraceSink::disabled();
        assert!(!sink.is_enabled());
        sink.emit(Event::LeafQr { rank: 3 }); // must not panic
    }

    #[test]
    fn exchange_pairs_deduplicate_both_sides() {
        let (sink, coll) = TraceSink::channel();
        sink.emit(Event::Exchange { rank: 0, with: 1, round: 0 });
        sink.emit(Event::Exchange { rank: 1, with: 0, round: 0 });
        sink.emit(Event::Exchange { rank: 2, with: 3, round: 0 });
        drop(sink);
        assert_eq!(coll.drain().exchange_pairs_at(0), vec![(0, 1), (2, 3)]);
    }

    #[test]
    fn render_mentions_crash_and_result() {
        let (sink, coll) = TraceSink::channel();
        sink.emit(Event::LeafQr { rank: 0 });
        sink.emit(Event::Killed { rank: 1, round: 0 });
        sink.emit(Event::Exited { rank: 0, kind: ExitKind::CompletedWithR });
        drop(sink);
        let txt = coll.drain().render(2, 1);
        assert!(txt.contains("CRASH"));
        assert!(txt.contains("holds final R"));
        assert!(txt.contains("QR(A_local)"));
    }
}
