//! The paper's four algorithms, one function per process (one OS thread each).
//!
//! Each function is the body of one simulated MPI rank.  They map
//! line-for-line onto the paper's listings:
//!
//! * [`baseline`]      — Algorithm 1 (TSQR; not fault tolerant)
//! * [`redundant`]     — Algorithm 2 (Redundant TSQR)
//! * [`replace`]       — Algorithm 3 (Replace TSQR)
//! * [`self_healing`]  — Algorithms 4–6 (Self-Healing TSQR)
//!
//! Exchange = `post` (send half) + `fetch` (recv half) on the world's
//! post board; `Error::RankFailed` is the ULFM `FAIL` the listings
//! branch on.  Fault injection happens at each round boundary via
//! `ctx.maybe_die(round)` — "crashed at the end of step s" in the
//! paper's step-granular model.

use std::sync::Arc;

use crate::linalg::Matrix;
use crate::ulfm::{ExitKind, Rank};

use super::context::Ctx;
use super::trace::Event;

/// How one process left the computation (the wrapper in runner.rs
/// translates this into world status + trace events).
///
/// The final R travels as an `Arc`: the same immutable allocation a
/// process posted to the board is what it deposits as its result — no
/// terminal deep copy.
#[derive(Debug, Clone)]
pub enum ProcOutcome {
    /// Finished the algorithm holding the final R.
    FinalR(Arc<Matrix>),
    /// Finished its role without the final R (baseline sender).
    DoneNoR,
    /// Returned early: a needed peer failed (Alg. 2 line 7).
    GaveUpPeerFailed,
    /// Returned early: no live replica of the needed data (Alg. 3 line 8).
    GaveUpNoReplica,
    /// Crashed by the fault injector.
    Killed,
}

impl ProcOutcome {
    /// The world-status this outcome translates to (`None` for a
    /// crash: the world already marked the rank Dead).
    pub fn exit_kind(&self) -> Option<ExitKind> {
        match self {
            ProcOutcome::FinalR(_) => Some(ExitKind::CompletedWithR),
            ProcOutcome::DoneNoR => Some(ExitKind::CompletedWithoutR),
            ProcOutcome::GaveUpPeerFailed => Some(ExitKind::GaveUpPeerFailed),
            ProcOutcome::GaveUpNoReplica => Some(ExitKind::GaveUpNoReplica),
            ProcOutcome::Killed => None, // world already marked Dead
        }
    }
}

/// Algorithm 1 — plain TSQR.  Binary reduction tree: the higher rank of
/// each pair sends its R̃ and is done; the lower rank receives, stacks,
/// re-factorizes.  Any failure aborts the computation (ABORT
/// semantics): a process that observes a failed peer simply ends, which
/// cascades to everything upstream of it.
pub fn baseline(ctx: Ctx, a: Matrix) -> ProcOutcome {
    let rank = ctx.rank;
    let mut r = match ctx.leaf_qr(&a) {
        Ok(f) => f.r,
        Err(_) => return ProcOutcome::GaveUpPeerFailed,
    };
    for round in 0..ctx.plan.rounds() {
        if ctx.maybe_die(round).is_err() {
            return ProcOutcome::Killed;
        }
        if !ctx.plan.participates(rank, round) {
            // Defensive: a non-participant already returned as a sender
            // in an earlier round; it can never own the final R.
            return ProcOutcome::DoneNoR;
        }
        let Some(buddy) = ctx.plan.buddy(rank, round) else {
            continue; // non-pow2 pass-through round
        };
        if ctx.plan.is_sender(rank, round) {
            // I am a sender: ship R̃ to the buddy (sharing my handle —
            // the board takes the same Arc), my job is done.
            ctx.world.post(rank, round, r);
            ctx.trace.emit(Event::Send { rank, to: buddy, round });
            return ProcOutcome::DoneNoR;
        }
        // I am a receiver.
        match ctx.world.fetch(buddy, round) {
            Ok(theirs) => {
                ctx.trace.emit(Event::Recv { rank, from: buddy, round });
                match ctx.combine(round, &r, &theirs, rank, buddy) {
                    Ok(next) => r = next,
                    Err(_) => return ProcOutcome::GaveUpPeerFailed,
                }
            }
            Err(e) if e.is_rank_failure() => {
                ctx.trace.emit(Event::PeerFailed { rank, peer: buddy, round });
                return ProcOutcome::GaveUpPeerFailed;
            }
            Err(_) => return ProcOutcome::GaveUpPeerFailed,
        }
    }
    // Only the tree root reaches this point with the final R.
    ProcOutcome::FinalR(r)
}

/// Algorithm 2 — Redundant TSQR.  Buddies *exchange* R̃ (sendrecv), both
/// stack and re-factorize; every surviving process ends with the final
/// R.  On a failed exchange the process returns (line 7) — survivors
/// carry on unknowingly.
pub fn redundant(ctx: Ctx, a: Matrix) -> ProcOutcome {
    let rank = ctx.rank;
    let mut r = match ctx.leaf_qr(&a) {
        Ok(f) => f.r,
        Err(_) => return ProcOutcome::GaveUpPeerFailed,
    };
    for round in 0..ctx.plan.rounds() {
        if ctx.maybe_die(round).is_err() {
            return ProcOutcome::Killed;
        }
        let Some(buddy) = ctx.plan.buddy(rank, round) else {
            continue;
        };
        // sendrecv: post my half first (refcount bump, not a copy —
        // R̃ is immutable once posted), then await the buddy's.
        ctx.world.post(rank, round, Arc::clone(&r));
        match ctx.world.fetch(buddy, round) {
            Ok(theirs) => {
                ctx.trace.emit(Event::Exchange { rank, with: buddy, round });
                let (g_me, g_them) = (ctx.plan.group(rank, round), ctx.plan.group(buddy, round));
                match ctx.combine(round, &r, &theirs, g_me, g_them) {
                    Ok(next) => r = next,
                    Err(_) => return ProcOutcome::GaveUpPeerFailed,
                }
            }
            Err(e) if e.is_rank_failure() => {
                // Line 7: FAIL == sendrecv -> return.
                ctx.trace.emit(Event::PeerFailed { rank, peer: buddy, round });
                return ProcOutcome::GaveUpPeerFailed;
            }
            Err(_) => return ProcOutcome::GaveUpPeerFailed,
        }
    }
    // "All the surviving processes reach this point and own the final R."
    ProcOutcome::FinalR(r)
}

/// Algorithm 3 — Replace TSQR.  Fault-free execution is identical to
/// Redundant; on a failed exchange the process *finds a replica* of the
/// dead buddy's data (any live rank in the buddy's group at this level)
/// and exchanges with it instead.  Only if no replica survives does it
/// give up.
pub fn replace(ctx: Ctx, a: Matrix) -> ProcOutcome {
    let rank = ctx.rank;
    let mut r = match ctx.leaf_qr(&a) {
        Ok(f) => f.r,
        Err(_) => return ProcOutcome::GaveUpPeerFailed,
    };
    for round in 0..ctx.plan.rounds() {
        if ctx.maybe_die(round).is_err() {
            return ProcOutcome::Killed;
        }
        let Some(buddy) = ctx.plan.buddy(rank, round) else {
            continue;
        };
        ctx.world.post(rank, round, Arc::clone(&r));
        let (partner, theirs) = match ctx.world.fetch(buddy, round) {
            Ok(m) => (buddy, m),
            Err(e) if e.is_rank_failure() => {
                ctx.trace.emit(Event::PeerFailed { rank, peer: buddy, round });
                // Line 6: b = findReplica(b) — any holder of the same
                // data (the buddy's group at this level).  A replica
                // that posted its round-s R̃ and died afterwards still
                // delivers (buffered-send semantics); otherwise wait on
                // a live replica to post.  NoReplica ⇒ line 8: no copy
                // of this submatrix survives.
                let replicas = ctx.plan.replicas_of(buddy, round);
                match ctx.world.fetch_from_group(&replicas, rank, round) {
                    Ok((q, m)) => {
                        ctx.trace
                            .emit(Event::ReplicaFound { rank, dead: buddy, replica: q, round });
                        (q, m)
                    }
                    Err(_) => return ProcOutcome::GaveUpNoReplica,
                }
            }
            Err(_) => return ProcOutcome::GaveUpNoReplica,
        };
        ctx.trace.emit(Event::Exchange { rank, with: partner, round });
        let (g_me, g_them) = (ctx.plan.group(rank, round), ctx.plan.group(buddy, round));
        match ctx.combine(round, &r, &theirs, g_me, g_them) {
            Ok(next) => r = next,
            Err(_) => return ProcOutcome::GaveUpNoReplica,
        }
    }
    ProcOutcome::FinalR(r)
}

/// Algorithms 4+6 — Self-Healing TSQR, primary process: leaf QR
/// (Algorithm 4) then the shared round loop.
pub fn self_healing(ctx: Ctx, a: Matrix) -> ProcOutcome {
    let r = match ctx.leaf_qr(&a) {
        Ok(f) => f.r,
        Err(_) => return ProcOutcome::GaveUpPeerFailed,
    };
    sh_rounds(ctx, r, 0)
}

/// Algorithm 6 — the `shtsqr` round loop, entered at `start_round`
/// (0 for primaries, the failure round for respawned replacements).
///
/// On a failed exchange the process *respawns* the dead buddy
/// (`spawnNew`, REBUILD semantics) and retries: the replacement
/// recovers the buddy's state from a replica (Algorithm 5) and posts
/// for this round, unblocking us.
pub fn sh_rounds(ctx: Ctx, mut r: Arc<Matrix>, start_round: u32) -> ProcOutcome {
    let rank = ctx.rank;
    for round in start_round..ctx.plan.rounds() {
        if ctx.maybe_die(round).is_err() {
            return ProcOutcome::Killed;
        }
        let Some(buddy) = ctx.plan.buddy(rank, round) else {
            continue;
        };
        ctx.world.post(rank, round, Arc::clone(&r));
        let theirs = match ctx.world.fetch_peer(buddy, round) {
            crate::ulfm::PeerFetch::Post(m) => m,
            outcome => {
                // Buddy unreachable (ULFM failure → spawnNew) or a
                // still-recovering replacement respawned by a peer at a
                // LATER round (it enters the computation there and will
                // never post for this round — waiting would starve us).
                if matches!(outcome, crate::ulfm::PeerFetch::Unreachable) {
                    ctx.trace.emit(Event::PeerFailed { rank, peer: buddy, round });
                    if ctx.world.respawn_at(buddy, round) {
                        // spawnNew(b): launch the replacement on the
                        // run's worker pool — it recovers its state
                        // from a replica (Alg. 5) and rejoins from
                        // this round.
                        ctx.trace.emit(Event::Respawn { rank, dead: buddy, round });
                        let rctx = ctx.for_rank(buddy);
                        ctx.tasks.spawn(move || {
                            super::runner::run_process_wrapper(rctx.clone(), || {
                                sh_recover(rctx.clone(), round)
                            });
                        });
                    }
                }
                // Either way, take the buddy's round-s data from ANY
                // replica of its group — bit-identical to what the
                // buddy/replacement would post.  NoReplica ⇒ the
                // group's data is gone (the 2^s − 1 bound exceeded).
                let replicas = ctx.plan.replicas_of(buddy, round);
                match ctx.world.fetch_from_group(&replicas, rank, round) {
                    Ok((_, m)) => m,
                    Err(_) => return ProcOutcome::GaveUpNoReplica,
                }
            }
        };
        ctx.trace.emit(Event::Exchange { rank, with: buddy, round });
        let (g_me, g_them) = (ctx.plan.group(rank, round), ctx.plan.group(buddy, round));
        match ctx.combine(round, &r, &theirs, g_me, g_them) {
            Ok(next) => r = next,
            Err(_) => return ProcOutcome::GaveUpNoReplica,
        }
    }
    ProcOutcome::FinalR(r)
}

/// Algorithm 5 — process restart: a freshly spawned replacement fetches
/// the dead incarnation's state (R̃ at `round`) from any replica, then
/// joins the round loop at `round`.
pub fn sh_recover(ctx: Ctx, round: u32) -> ProcOutcome {
    let rank = ctx.rank;
    let candidates: Vec<Rank> = ctx.plan.replicas_of(rank, round);
    // Block until a replica's round-`round` post is available (posts
    // survive their author — buffered-send semantics), or until no
    // candidate can ever produce one: still-recovering replacements
    // hold no data and do not count as sources, which is what keeps two
    // recoveries in the same dead group from waiting on each other.
    // The recovered state is shared, not copied: it is bit-identical
    // to what the dead incarnation held, and immutable either way.
    let state: Arc<Matrix> = match ctx.world.fetch_from_group(&candidates, rank, round) {
        Ok((q, m)) => {
            ctx.trace.emit(Event::Recovered { rank, from: q, round });
            m
        }
        Err(_) => {
            // The paper's bound (2^s − 1) was exceeded for this group.
            return ProcOutcome::GaveUpNoReplica;
        }
    };
    sh_rounds(ctx, state, round)
}
