//! The Q factor of TSQR — the path the paper defers ("If the Q matrix
//! is computed, it will work again when the moment comes, after the
//! computation of the R is done", §III-A).
//!
//! TSQR's Q is implicit: Q = diag(Q_leaf_0..Q_leaf_{P−1}) · Q_tree,
//! where every tree node contributes the (2n × n) Q of its combine.
//! This module materializes the thin Q (or applies Qᵀ to a RHS) by
//! replaying the reduction tree *top-down*, reusing the same AOT
//! kernels (`build_q` / `apply_qt`) the factorization used.
//!
//! It works on a [`QrTree`] — the per-node factorizations retained by a
//! sequential tree run through the [`Executor`] — and is what the
//! least-squares and panel examples build on.

use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::runtime::{Executor, Factorization};

/// Retained factorizations of one TSQR run over `leaves` leaves:
/// level 0 holds the leaf factorizations, level k > 0 the combines.
#[derive(Debug)]
pub struct QrTree {
    /// Leaf count (a power of two).
    pub leaves: usize,
    /// Matrix columns.
    pub cols: usize,
    /// Rows per leaf panel.
    pub rows_per_leaf: usize,
    /// `levels[0]` = leaf factorizations (one per leaf);
    /// `levels[k]` = combine factorizations (leaves >> k of them).
    pub levels: Vec<Vec<Factorization>>,
}

impl QrTree {
    /// Factor `a` over a `leaves`-leaf TSQR tree, retaining every node.
    /// `leaves` must be a power of two dividing `a.rows()`.
    pub fn factor(exec: &Executor, a: &Matrix, leaves: usize) -> Result<QrTree> {
        if !leaves.is_power_of_two() {
            return Err(Error::Config(format!("leaves must be a power of two, got {leaves}")));
        }
        if a.rows() % leaves != 0 {
            return Err(Error::Config(format!(
                "rows {} not divisible by leaves {leaves}",
                a.rows()
            )));
        }
        let rows = a.rows() / leaves;
        if rows < a.cols() {
            return Err(Error::Config("leaf panels must be tall-skinny".into()));
        }
        let mut levels: Vec<Vec<Factorization>> = Vec::new();
        let mut current: Vec<Factorization> = (0..leaves)
            .map(|i| exec.leaf_qr(&a.row_block(i * rows, (i + 1) * rows)))
            .collect::<Result<_>>()?;
        while current.len() > 1 {
            let next: Vec<Factorization> = current
                .chunks(2)
                .map(|pair| exec.combine(&pair[0].r, &pair[1].r))
                .collect::<Result<_>>()?;
            levels.push(current);
            current = next;
        }
        levels.push(current); // the root
        Ok(QrTree { leaves, cols: a.cols(), rows_per_leaf: rows, levels })
    }

    /// The final R factor (root of the tree).
    pub fn r(&self) -> &Matrix {
        &self.levels.last().expect("non-empty tree")[0].r
    }

    /// Number of tree levels (log2(leaves) + 1).
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// Apply Qᵀ to `b` (m × k): returns the full m × k product; its top
    /// n rows are the least-squares RHS.  Replays the tree bottom-up:
    /// leaf Qᵀ first, then each combine's Qᵀ on the stacked tops.
    pub fn apply_qt(&self, exec: &Executor, b: &Matrix) -> Result<Matrix> {
        let n = self.cols;
        if b.rows() != self.leaves * self.rows_per_leaf {
            return Err(Error::Config(format!(
                "rhs rows {} != matrix rows {}",
                b.rows(),
                self.leaves * self.rows_per_leaf
            )));
        }
        // Leaf stage: full Qᵀb per leaf; keep tops for the tree, tails
        // for the final assembly.
        let mut tops: Vec<Matrix> = Vec::with_capacity(self.leaves);
        let mut tails: Vec<Matrix> = Vec::with_capacity(self.leaves);
        for (i, f) in self.levels[0].iter().enumerate() {
            let rhs = b.row_block(i * self.rows_per_leaf, (i + 1) * self.rows_per_leaf);
            let qtb = exec.apply_qt(f, &rhs)?;
            tops.push(qtb.row_block(0, n));
            tails.push(qtb.row_block(n, qtb.rows()));
        }
        // Tree stages.
        let mut tail_stack: Vec<Vec<Matrix>> = vec![tails];
        for level in &self.levels[1..] {
            let mut next_tops = Vec::with_capacity(level.len());
            let mut level_tails = Vec::with_capacity(level.len());
            for (j, f) in level.iter().enumerate() {
                let stacked = tops[2 * j].vstack(&tops[2 * j + 1]);
                let qtc = exec.apply_qt(f, &stacked)?;
                next_tops.push(qtc.row_block(0, n));
                level_tails.push(qtc.row_block(n, 2 * n));
            }
            tops = next_tops;
            tail_stack.push(level_tails);
        }
        // Assemble: the product's top n rows are the root top; the rest
        // reverses the splitting order.  For the library's main use
        // (least squares) only the top matters; we still return the full
        // vector for completeness by concatenating root top + tails in
        // reverse level order.
        let mut out = tops.pop().expect("root");
        for level_tails in tail_stack.iter().rev() {
            for t in level_tails {
                out = out.vstack(t);
            }
        }
        Ok(out)
    }

    /// Materialize the thin Q (m × n) top-down: start from the root's
    /// identity and push each node's Q through its children.
    pub fn build_q(&self, exec: &Executor) -> Result<Matrix> {
        let n = self.cols;
        // Per-node n×n blocks flowing down the tree; start at the root.
        let mut blocks: Vec<Matrix> = vec![Matrix::eye(n, n)];
        // Walk combine levels from root down to just above the leaves.
        for level in self.levels[1..].iter().rev() {
            let mut next = Vec::with_capacity(level.len() * 2);
            for (f, blk) in level.iter().zip(&blocks) {
                // Q_node is (2n × n): its product with blk splits into
                // the two children's inflow.
                let q_node = exec.build_q(f)?; // (2n, n)
                let prod = q_node.matmul(blk); // (2n, n)
                next.push(prod.row_block(0, n));
                next.push(prod.row_block(n, 2 * n));
            }
            blocks = next;
        }
        // Leaf stage: Q_leaf (m_i × n) times the inflow block.
        let mut q = Matrix::zeros(0, n);
        for (f, blk) in self.levels[0].iter().zip(&blocks) {
            let q_leaf = exec.build_q(f)?; // (rows, n)
            q = if q.rows() == 0 { q_leaf.matmul(blk) } else { q.vstack(&q_leaf.matmul(blk)) };
        }
        Ok(q)
    }

    /// Solve min‖Ax − b‖ using the retained tree: x = R⁻¹ (Qᵀb)[:n].
    pub fn least_squares(&self, exec: &Executor, b: &Matrix) -> Result<Matrix> {
        let qtb = self.apply_qt(exec, b)?;
        exec.backsolve(self.r(), &qtb.row_block(0, self.cols))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{qr_r, qr_residuals};

    fn exec() -> Executor {
        Executor::host()
    }

    #[test]
    fn tree_r_matches_direct_qr() {
        let a = Matrix::random(128, 8, 1);
        let t = QrTree::factor(&exec(), &a, 4).unwrap();
        assert_eq!(t.depth(), 3);
        assert!(t.r().canonicalize_r().max_abs_diff(&qr_r(&a)) < 1e-4);
    }

    #[test]
    fn build_q_reconstructs_a() {
        let a = Matrix::random(64, 4, 2);
        let t = QrTree::factor(&exec(), &a, 4).unwrap();
        let q = t.build_q(&exec()).unwrap();
        assert_eq!(q.shape(), (64, 4));
        let (rel, ortho) = qr_residuals(&a, &q, t.r());
        assert!(rel < 1e-4, "A != QR: {rel}");
        assert!(ortho < 1e-3, "Q not orthonormal: {ortho}");
    }

    #[test]
    fn apply_qt_top_is_least_squares_rhs() {
        let a = Matrix::random(96, 6, 3);
        let xt = Matrix::random(6, 1, 4);
        let b = a.matmul(&xt);
        let t = QrTree::factor(&exec(), &a, 2).unwrap();
        let x = t.least_squares(&exec(), &b).unwrap();
        assert!(x.max_abs_diff(&xt) < 5e-2, "{}", x.max_abs_diff(&xt));
    }

    #[test]
    fn apply_qt_consistent_with_explicit_q() {
        let a = Matrix::random(32, 4, 5);
        let b = Matrix::random(32, 2, 6);
        let t = QrTree::factor(&exec(), &a, 2).unwrap();
        let qtb_top = t.apply_qt(&exec(), &b).unwrap().row_block(0, 4);
        let q = t.build_q(&exec()).unwrap();
        let explicit = q.transpose().matmul(&b);
        assert!(qtb_top.max_abs_diff(&explicit) < 1e-4);
    }

    #[test]
    fn single_leaf_degenerates() {
        let a = Matrix::random(16, 4, 7);
        let t = QrTree::factor(&exec(), &a, 1).unwrap();
        assert_eq!(t.depth(), 1);
        assert!(t.r().canonicalize_r().max_abs_diff(&qr_r(&a)) < 1e-5);
        let q = t.build_q(&exec()).unwrap();
        let (rel, _) = qr_residuals(&a, &q, t.r());
        assert!(rel < 1e-5);
    }

    #[test]
    fn validation_errors() {
        let a = Matrix::random(12, 4, 8);
        assert!(QrTree::factor(&exec(), &a, 3).is_err(), "non-pow2 leaves");
        assert!(QrTree::factor(&exec(), &a, 8).is_err(), "12 not divisible by 8... and wide");
        let t = QrTree::factor(&exec(), &a, 2).unwrap();
        assert!(t.apply_qt(&exec(), &Matrix::zeros(10, 1)).is_err(), "rhs shape");
    }
}
